"""MoE layer: dispatch engines agree, capacity drops, aux loss behavior."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline: deterministic shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.configs import get_arch
from repro.models.moe import _capacity, moe_apply, moe_specs
from repro.models.common import init_params


def _setup(cfg, key, B=2, S=16):
    params = init_params(moe_specs(cfg, jnp.float32), key)
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, cfg.d_model))
    return params, x


def test_dispatch_engines_agree():
    cfg = dataclasses.replace(get_arch("mixtral-8x7b").reduced(),
                              capacity_factor=8.0)  # no drops
    params, x = _setup(cfg, jax.random.PRNGKey(0))
    y1, a1 = moe_apply(cfg, params, x, dispatch="einsum")
    y2, a2 = moe_apply(cfg, params, x, dispatch="scatter")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-5)


@pytest.mark.slow  # 10 random shapes -> 10 XLA compiles (~18 s)
@given(st.integers(1, 3), st.integers(4, 32), st.sampled_from(["einsum", "scatter"]))
@settings(max_examples=10, deadline=None)
def test_moe_output_finite(B, S, dispatch):
    cfg = get_arch("mixtral-8x7b").reduced()
    params, x = _setup(cfg, jax.random.PRNGKey(B * 100 + S), B, S)
    y, aux = moe_apply(cfg, params, x, dispatch=dispatch)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux) >= 0.0


def test_capacity_formula():
    cfg = get_arch("mixtral-8x7b")  # E=8, k=2, cf=1.25
    c = _capacity(cfg, 4096)
    assert c == 1280
    assert _capacity(cfg, 1) == 4  # floor of 4, rounded to multiple of 4


def test_capacity_drops_tokens():
    """With tiny capacity, outputs of dropped tokens are zero (+shared)."""
    cfg = dataclasses.replace(get_arch("mixtral-8x7b").reduced(),
                              capacity_factor=0.02)
    params, x = _setup(cfg, jax.random.PRNGKey(2), 1, 64)
    y, _ = moe_apply(cfg, params, x, dispatch="einsum")
    # most rows should be exactly 0 (dropped; mixtral has no shared expert)
    norms = np.linalg.norm(np.asarray(y[0]), axis=-1)
    assert (norms == 0).sum() > 32


def test_shared_expert_always_active():
    cfg = dataclasses.replace(get_arch("deepseek-v3-671b").reduced(),
                              capacity_factor=0.02)
    params, x = _setup(cfg, jax.random.PRNGKey(3), 1, 64)
    y, _ = moe_apply(cfg, params, x, dispatch="einsum")
    norms = np.linalg.norm(np.asarray(y[0]), axis=-1)
    assert (norms > 0).all()  # shared expert output survives drops


def test_gradients_flow_through_both_dispatches():
    cfg = dataclasses.replace(get_arch("mixtral-8x7b").reduced(),
                              capacity_factor=8.0)
    params, x = _setup(cfg, jax.random.PRNGKey(4))
    for dispatch in ("einsum", "scatter"):
        g = jax.grad(lambda p: jnp.sum(
            moe_apply(cfg, p, x, dispatch=dispatch)[0] ** 2))(params)
        gn = sum(float(jnp.sum(v ** 2)) for v in g.values())
        assert np.isfinite(gn) and gn > 0, dispatch
