"""Cost function vs a brute-force oracle."""
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline: deterministic shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import CartGrid, Stencil, evaluate
from repro.core.cost import node_of_rank_blocked


def brute_cost(grid, stencil, node_of_pos, weighted=False):
    j = 0.0
    per_node = {}
    for r in range(grid.size):
        c = np.array(grid.coord_of(r))
        for off, w in zip(stencil.offsets, stencil.weights):
            t = c + np.array(off)
            if ((t < 0) | (t >= np.array(grid.dims))).any():
                continue
            tr = grid.rank_of(tuple(t))
            if node_of_pos[r] != node_of_pos[tr]:
                ww = w if weighted else 1.0
                j += ww
                per_node[node_of_pos[r]] = per_node.get(node_of_pos[r], 0) + ww
    return j, max(per_node.values(), default=0.0)


@given(st.integers(2, 5), st.integers(2, 5), st.integers(2, 4),
       st.sampled_from(["nn", "comp", "hops"]), st.booleans())
@settings(max_examples=30, deadline=None)
def test_evaluate_matches_bruteforce(h, w, n_nodes, sname, weighted):
    grid = CartGrid((h, w))
    st_map = {"nn": Stencil.nearest_neighbor(2),
              "comp": Stencil.component(2),
              "hops": Stencil.nn_with_hops(2)}
    stencil = st_map[sname]
    if weighted:
        stencil = Stencil(stencil.offsets,
                          tuple(1.0 + i for i in range(stencil.k)))
    rng = np.random.default_rng(h * 100 + w * 10 + n_nodes)
    node_of_pos = rng.integers(0, n_nodes, size=grid.size)
    cost = evaluate(grid, stencil, node_of_pos, num_nodes=n_nodes,
                    weighted=weighted)
    bj, bm = brute_cost(grid, stencil, node_of_pos, weighted)
    assert cost.j_sum == bj
    assert cost.j_max == bm


def test_blocked_rows_cost_known_value():
    # 4x4 grid, 4 nodes of 4 (one row each), nearest neighbor: every
    # vertical edge crosses: 2 directed x 4 cols x 3 row-gaps = 24
    grid = CartGrid((4, 4))
    node_of_pos = node_of_rank_blocked([4] * 4)
    c = evaluate(grid, Stencil.nearest_neighbor(2), node_of_pos, 4)
    assert c.j_sum == 24
    assert c.j_max == 8  # middle rows talk up and down
