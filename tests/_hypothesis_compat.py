"""Minimal offline stand-in for the slice of the `hypothesis` API this
suite uses (`given`, `settings`, `assume`, and the `strategies` functions
`integers`, `booleans`, `sampled_from`, `lists`, `floats`).

Real hypothesis does adaptive search and shrinking; this shim just replays
each property over ``max_examples`` pseudo-random examples drawn from a
per-test deterministic RNG (seeded from the test's qualified name), so the
suite collects and runs green without network access.  If hypothesis is
installed the test modules import it instead and none of this is used.
"""
from __future__ import annotations

import hashlib
import types
from typing import Any, Callable, List, Sequence

import numpy as np

__all__ = ["given", "settings", "assume", "strategies", "HealthCheck"]

_DEFAULT_MAX_EXAMPLES = 25
_SETTINGS_ATTR = "_shim_max_examples"
_WRAPPED_ATTR = "_shim_wrapped"


class UnsatisfiedAssumption(Exception):
    """Raised by assume(False); the example is silently discarded."""


def assume(condition: Any) -> bool:
    if not condition:
        raise UnsatisfiedAssumption()
    return True


class SearchStrategy:
    """A draw function wrapped for composition."""

    def __init__(self, draw: Callable[[np.random.Generator], Any]):
        self._draw = draw

    def example(self, rng: np.random.Generator) -> Any:
        return self._draw(rng)

    def map(self, fn: Callable[[Any], Any]) -> "SearchStrategy":
        return SearchStrategy(lambda rng: fn(self._draw(rng)))

    def filter(self, pred: Callable[[Any], bool]) -> "SearchStrategy":
        def draw(rng: np.random.Generator) -> Any:
            for _ in range(1000):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise UnsatisfiedAssumption()
        return SearchStrategy(draw)


def _integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: int(rng.integers(min_value, max_value + 1)))


def _booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: bool(rng.integers(0, 2)))


def _sampled_from(elements: Sequence[Any]) -> SearchStrategy:
    elems = list(elements)
    if not elems:
        raise ValueError("sampled_from requires a non-empty sequence")
    return SearchStrategy(lambda rng: elems[int(rng.integers(len(elems)))])


def _floats(min_value: float = 0.0, max_value: float = 1.0,
            **_ignored: Any) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: float(rng.uniform(min_value, max_value)))


def _lists(elements: SearchStrategy, min_size: int = 0,
           max_size: int = 10, **_ignored: Any) -> SearchStrategy:
    def draw(rng: np.random.Generator) -> List[Any]:
        size = int(rng.integers(min_size, max_size + 1))
        return [elements.example(rng) for _ in range(size)]
    return SearchStrategy(draw)


strategies = types.SimpleNamespace(
    integers=_integers, booleans=_booleans, sampled_from=_sampled_from,
    floats=_floats, lists=_lists, SearchStrategy=SearchStrategy)


class HealthCheck:
    """Placeholder so ``suppress_health_check=[...]`` parses."""
    all = classmethod(lambda cls: [])
    too_slow = data_too_large = filter_too_much = None


def settings(max_examples: int | None = None, deadline: Any = None,
             **_ignored: Any) -> Callable:
    """Record max_examples on the test (order-independent wrt @given)."""
    def decorate(fn: Callable) -> Callable:
        setattr(fn, _SETTINGS_ATTR, max_examples)
        inner = getattr(fn, _WRAPPED_ATTR, None)
        if inner is not None:   # @settings applied outside @given
            setattr(inner, _SETTINGS_ATTR, max_examples)
        return fn
    return decorate


def given(*arg_strategies: SearchStrategy,
          **kw_strategies: SearchStrategy) -> Callable:
    def decorate(fn: Callable) -> Callable:
        seed = int.from_bytes(
            hashlib.sha256(fn.__qualname__.encode()).digest()[:8], "big")

        # NB: zero-arg signature on purpose — pytest must not mistake the
        # property's parameters for fixtures.
        def runner():
            n = (getattr(fn, _SETTINGS_ATTR, None)
                 or getattr(runner, _SETTINGS_ATTR, None)
                 or _DEFAULT_MAX_EXAMPLES)
            rng = np.random.default_rng(seed)
            ran, attempts = 0, 0
            while ran < n and attempts < n * 50:
                attempts += 1
                args = [s.example(rng) for s in arg_strategies]
                kwargs = {k: s.example(rng) for k, s in kw_strategies.items()}
                try:
                    fn(*args, **kwargs)
                except UnsatisfiedAssumption:
                    continue
                ran += 1

        runner.__name__ = fn.__name__
        runner.__qualname__ = fn.__qualname__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        setattr(runner, _WRAPPED_ATTR, fn)
        return runner
    return decorate
