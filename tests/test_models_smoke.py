"""Per-architecture smoke tests (deliverable f): every assigned arch as a
REDUCED config of the same family runs one forward + one train step on CPU,
asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.models import lm
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.runtime.steps import make_train_step


def _batch(cfg, key, B=2, S=16):
    ki, kt = jax.random.split(key)
    batch = {"inputs": jax.random.randint(ki, (B, S), 0, cfg.vocab),
             "targets": jax.random.randint(kt, (B, S), 0, cfg.vocab)}
    if cfg.family == "encdec":
        batch["src"] = jax.random.normal(key, (B, cfg.src_len, cfg.d_model))
    if cfg.num_patches:
        batch["patches"] = jax.random.normal(
            key, (B, cfg.num_patches, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_reduced_forward_shapes_and_finite(arch):
    cfg = get_arch(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = lm.init(cfg, key)
    B, S = 2, 16
    batch = _batch(cfg, key, B, S)
    logits, aux, _ = lm.forward(cfg, params, batch)
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert np.isfinite(np.asarray(logits, dtype=np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_reduced_train_step(arch):
    cfg = get_arch(arch).reduced()
    key = jax.random.PRNGKey(1)
    params = lm.init(cfg, key)
    specs = lm.param_specs(cfg)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    opt = init_opt_state(specs, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg))
    batch = _batch(cfg, key)
    p1, o1, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    moved = any(not np.allclose(np.asarray(params[k], np.float32),
                                np.asarray(p1[k], np.float32))
                for k in params)
    assert moved


def test_full_config_dimensions_exact():
    """The exact published dimensions from the assignment block."""
    want = {
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "deepseek-v3-671b": (61, 7168, 128, 128, 18432, 129280),
        "mamba2-130m": (24, 768, 1, 1, 0, 50280),
        "yi-34b": (60, 7168, 56, 8, 20480, 64000),
        "granite-3-8b": (40, 4096, 32, 8, 12800, 49155),
        "granite-20b": (52, 6144, 48, 1, 24576, 49152),
        "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
    }
    for name, (L, d, H, K, ff, V) in want.items():
        cfg = get_arch(name)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab) == (L, d, H, K, ff, V), name


def test_param_counts_near_published():
    approx = {"mixtral-8x7b": 46.7e9, "yi-34b": 34.4e9, "qwen3-8b": 8.2e9,
              "granite-20b": 28.2e9, "internvl2-76b": 70.6e9,
              "mamba2-130m": 0.13e9, "zamba2-2.7b": 2.4e9}
    for name, want in approx.items():
        got = get_arch(name).param_count()
        assert abs(got - want) / want < 0.08, (name, got, want)
    # deepseek: 671B + ~11B MTP
    ds = get_arch("deepseek-v3-671b").param_count()
    assert 650e9 < ds < 700e9
