"""Single-flight dedup of concurrent cold misses in PlanServer (ISSUE 10).

Without the per-key latch, N simultaneous requests for one uncached
(problem, plan) each run the full solve — up to ``threads`` redundant
anneals per cold key.  With it, exactly one leader solves while the
followers park and re-enter as cache hits.  These tests drive a server
whose cache is artificially slowed so concurrent arrivals on one key are
guaranteed, then assert on the cache counters: one miss, one put, and at
least one recorded wait.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import MappingProblem, PlanCache, Stencil
from repro.serving import PlanServer

PROB = MappingProblem((8, 8), Stencil.nearest_neighbor(2), (16,) * 4)
PLAN = "annealed:hyperplane"


class SlowCache(PlanCache):
    """PlanCache whose cold-path solve holds the key long enough for the
    other server threads to arrive while it is still in flight."""

    def __init__(self, delay_s=0.2, **kw):
        super().__init__(**kw)
        self.delay_s = delay_s

    def solve(self, problem, plan, **kw):
        # peek without touching the hit/miss counters the tests assert on
        if f"sol:{problem.content_hash()}:{plan.key}" not in self._mem:
            time.sleep(self.delay_s)
        return super().solve(problem, plan, **kw)


def test_concurrent_cold_misses_solve_once():
    cache = SlowCache(maxsize=64)
    with PlanServer(cache=cache, threads=3).start() as srv:
        tickets = [srv.submit(PROB, plan=PLAN) for _ in range(4)]
        sols = [t.result(timeout=60) for t in tickets]
    assert cache.misses == 1
    assert cache.puts == 1
    assert sum(s.from_cache for s in sols) == 3
    for s in sols[1:]:
        assert np.array_equal(s.assignment, sols[0].assignment)
        assert (s.j_max, s.j_sum) == (sols[0].j_max, sols[0].j_sum)
    assert srv.stats()["single_flight_waits"] >= 1


def test_distinct_keys_do_not_serialize():
    # different plans on one problem are different keys: no waits recorded
    cache = SlowCache(delay_s=0.05, maxsize=64)
    with PlanServer(cache=cache, threads=2).start() as srv:
        t1 = srv.submit(PROB, plan="annealed:hyperplane")
        t2 = srv.submit(PROB, plan="refined:hyperplane")
        s1, s2 = t1.result(timeout=60), t2.result(timeout=60)
    assert not s1.from_cache and not s2.from_cache
    assert cache.misses == 2
    assert srv.stats()["single_flight_waits"] == 0


def test_leader_failure_promotes_follower():
    # a leader that dies releases the latch; a follower retries as the
    # next leader instead of deadlocking or surfacing the stale error.
    cache = SlowCache(delay_s=0.2, maxsize=64)
    fail_first = {"armed": True}
    orig = SlowCache.solve

    def flaky(self, problem, plan, **kw):
        if fail_first.pop("armed", False):
            time.sleep(0.1)
            raise RuntimeError("injected leader failure")
        return orig(self, problem, plan, **kw)

    cache.solve = flaky.__get__(cache)
    with PlanServer(cache=cache, threads=2).start() as srv:
        tickets = [srv.submit(PROB, plan=PLAN) for _ in range(2)]
        results, errors = [], []
        for t in tickets:
            try:
                results.append(t.result(timeout=60))
            except Exception as e:       # noqa: BLE001 - injected failure
                errors.append(e)
    assert len(results) >= 1            # the follower still completed
    assert cache.puts == 1
    assert np.array_equal(np.bincount(results[0].assignment, minlength=4),
                          np.full(4, 16))


def test_stats_key_present_when_idle():
    with PlanServer(threads=1).start() as srv:
        assert srv.stats()["single_flight_waits"] == 0
