"""Warm-start incremental plan repair (core.repair + remap.repair_layout).

The acceptance bar this module pins: on the three churn scenarios (pod
loss, pod rejoin, slow pod) the repaired solution stays within 5% of the
cold elastic-portfolio solve on both J_max and J_sum, at no more than half
the cold solve's wall-time.  Plus the structural invariants: the repaired
assignment is a bijection honoring the survivor capacities, positions of
churn-untouched pods do not move when pinning is on, and the plan cache
keys repaired solutions under the post-churn signature without evicting
pre-churn entries.
"""
import time

import numpy as np
import pytest

from repro.core import (MappingProblem, PlanCache, RepairInapplicable,
                        RepairStage, Stencil, elastic_portfolio_plan,
                        parse_plan, repair_layout, repair_seed,
                        transfer_positions)
from repro.core.grid import CartGrid
from repro.core.repair import absorbed_node_sizes, downweighted_node_sizes
from repro.runtime.straggler import FleetStragglerMonitor, StragglerMonitor

#: byte-weighted ring stencil (the runtime's stencil_for_plan idiom:
#: data-parallel traffic outweighs model-parallel) — finer J granularity
#: than unit weights, which is what the 5% quality band is measured on.
WST = Stencil(((1, 0), (-1, 0), (0, 1), (0, -1)),
              (3.0, 3.0, 1.0, 1.0), name="ring-w")

EPS = 0.05          # repair-vs-cold quality band
LATENCY_FRAC = 0.5  # repair must cost at most this fraction of cold


def _cold(shape, sizes):
    prob = MappingProblem(tuple(shape), WST, tuple(sizes))
    t0 = time.perf_counter()
    sol = elastic_portfolio_plan().solve(prob)
    return sol, time.perf_counter() - t0


def _repair(prev, sizes, shape, node_map=None):
    best = None
    t = float("inf")
    for _ in range(2):      # min-of-2: timing is the flaky axis, not quality
        t0 = time.perf_counter()
        sol = repair_layout(prev, sizes, mesh_shape=shape,
                            node_map=node_map, cache=False)
        t = min(t, time.perf_counter() - t0)
        best = sol
    return best, t


SCENARIOS = {
    # whole-pod loss, runtime-style re-mesh (n, chips) -> (n-1, chips)
    "loss": dict(prev_shape=(8, 16), prev_sizes=(16,) * 8,
                 shape=(7, 16), sizes=(16,) * 7,
                 node_map=[0, 1, 2, 3, 4, 5, 7]),
    # pod rejoin: mesh grows back
    "add": dict(prev_shape=(7, 16), prev_sizes=(16,) * 7,
                shape=(8, 16), sizes=(16,) * 8,
                node_map=[0, 1, 2, 3, 4, 5, 6, -1]),
    # slow-but-alive pod: weighted-node re-solve, same mesh
    "slow": dict(prev_shape=(8, 16), prev_sizes=(16,) * 8,
                 shape=(8, 16),
                 sizes=tuple(downweighted_node_sizes((16,) * 8, 3, 2.0)),
                 node_map=None),
}


@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
def test_repair_matches_cold_at_fraction_of_cost(scenario):
    s = SCENARIOS[scenario]
    prev, _ = _cold(s["prev_shape"], s["prev_sizes"])
    cold, cold_t = _cold(s["shape"], s["sizes"])
    rep, rep_t = _repair(prev, s["sizes"], s["shape"], s["node_map"])
    # bijection over the survivors
    counts = np.bincount(rep.assignment, minlength=len(s["sizes"]))
    assert counts.tolist() == list(s["sizes"])
    # quality: within EPS of the cold elastic portfolio, both objectives
    assert rep.j_max <= (1 + EPS) * cold.j_max
    assert rep.j_sum <= (1 + EPS) * cold.j_sum
    # latency: at most LATENCY_FRAC of the cold solve
    assert rep_t <= LATENCY_FRAC * cold_t, \
        f"repair {rep_t * 1e3:.0f}ms vs cold {cold_t * 1e3:.0f}ms"
    # warm path taken (no silent cold fallback)
    st = rep.stage_stats[0]
    assert st["kind"] == "repair" and not st["used_fallback"]


def test_repair_pinned_positions_do_not_move():
    """Same-shape capacity shuffle between two pods: every position owned
    by an untouched pod must stay exactly where the previous solution put
    it (the pinned invariant the monitor-driven repair path relies on)."""
    prev, _ = _cold((6, 8), (8,) * 6)
    new_sizes = (8, 8, 4, 12, 8, 8)         # pod 2 sheds 4 chips to pod 3
    grid = CartGrid((6, 8))
    rs = repair_seed(grid, WST, prev.assignment, (6, 8), (8,) * 6,
                     new_sizes)
    assert rs.pinned.sum() > 0
    stage = RepairStage(prev)
    sr = stage.run(grid, WST, new_sizes)
    assert sr.stats["pinned"] == int(rs.pinned.sum()) > 0
    np.testing.assert_array_equal(sr.assignment[rs.pinned],
                                  prev.assignment[rs.pinned])
    counts = np.bincount(sr.assignment, minlength=6)
    assert counts.tolist() == list(new_sizes)


def test_repair_cache_keys_by_survivor_signature():
    cache = PlanCache()
    prev_prob = MappingProblem((6, 8), WST, (8,) * 6)
    plan = elastic_portfolio_plan()
    prev = plan.solve(prev_prob, cache)
    new_sizes = (8, 8, 4, 12, 8, 8)
    r1 = repair_layout(prev, new_sizes, cache=cache)
    assert not r1.from_cache
    # repeated re-mesh onto the same survivors: served from cache
    r2 = repair_layout(prev, new_sizes, cache=cache)
    assert r2.from_cache and r2.key() == r1.key()
    np.testing.assert_array_equal(r1.assignment, r2.assignment)
    # the pre-churn entry is untouched by the repair's put
    again = plan.solve(prev_prob, cache)
    assert again.from_cache and again.key() == prev.key()
    # a different survivor signature is a different entry
    r3 = repair_layout(prev, (8, 8, 12, 4, 8, 8), cache=cache)
    assert not r3.from_cache


def test_repair_node_map_validation():
    prev, _ = _cold((4, 4), (4,) * 4)
    with pytest.raises(ValueError, match="node_map has"):
        repair_layout(prev, (4,) * 4, node_map=[0, 1])
    with pytest.raises(ValueError, match="out of range"):
        repair_layout(prev, (4,) * 4, node_map=[0, 1, 2, 9])
    with pytest.raises(ValueError, match="twice"):
        repair_layout(prev, (4,) * 4, node_map=[0, 1, 2, 2])
    # node-count change without a node_map is inapplicable, not a guess;
    # fallback=False surfaces it, the default cold-solves instead
    with pytest.raises(RepairInapplicable, match="pass node_map"):
        repair_layout(prev, (4, 4, 4, 2, 2), mesh_shape=(4, 4),
                      fallback=False)
    sol = repair_layout(prev, (4, 4, 4, 2, 2), mesh_shape=(4, 4))
    assert sol.stage_stats[0]["used_fallback"]
    with pytest.raises(ValueError, match="post-churn mesh_shape"):
        repair_layout(prev, (4, 4, 4))      # device count shrank, no shape


def test_transfer_positions_rescale():
    grid = CartGrid((4, 4))
    np.testing.assert_array_equal(transfer_positions(grid, (4, 4)),
                                  np.arange(16))
    # 1-D doubling: cell-centred rescale pairs each new cell with its
    # geometric pre-image
    tr = transfer_positions(CartGrid((8,)), (4,))
    np.testing.assert_array_equal(tr, [0, 0, 1, 1, 2, 2, 3, 3])
    with pytest.raises(RepairInapplicable, match="rank"):
        transfer_positions(CartGrid((4, 4)), (16,))


def test_repair_plan_grammar():
    prev, _ = _cold((4, 4), (4,) * 4)
    plan = parse_plan("repair", previous=prev)
    assert "repair[" in plan.key and "prev=" in plan.key
    sol = plan.solve(MappingProblem((4, 4), WST, (4, 4, 2, 6)))
    assert np.bincount(sol.assignment,
                       minlength=4).tolist() == [4, 4, 2, 6]
    # options + fallback spelling; the fallback plan rides in the key
    plan2 = parse_plan("repair[k=2,sa_moves=10]:hyperplane", previous=prev)
    assert "fallback=" in plan2.key
    # node-count change -> the spelled fallback cold-solves
    sol2 = plan2.solve(MappingProblem((4, 4), WST, (6, 6, 4)))
    assert sol2.stage_stats[0]["used_fallback"]
    # refine prefixes chain over repair like any base
    plan3 = parse_plan("portfolio[k=2]:repair:hyperplane", previous=prev)
    sol3 = plan3.solve(MappingProblem((4, 4), WST, (4, 4, 2, 6)))
    assert sol3.key() <= sol.key()
    with pytest.raises(ValueError, match="previous"):
        parse_plan("repair")
    with pytest.raises(ValueError, match="previous"):
        parse_plan("hyperplane", previous=prev)


def test_churn_size_helpers():
    assert absorbed_node_sizes([4, 4, 4, 4], 1) == [6, 5, 5]
    assert downweighted_node_sizes([16] * 4, 2, 2.0) == [19, 19, 8, 18]
    assert sum(downweighted_node_sizes([16] * 4, 2, 2.0)) == 64
    with pytest.raises(ValueError):
        absorbed_node_sizes([4], 0)
    with pytest.raises(ValueError):
        downweighted_node_sizes([4, 4], 0, 0.5)


def test_persistent_slow_pod_escalates_within_bounded_steps():
    """A pod persistently 2x slow — below remap_ratio (2.5) every step —
    must still escalate to "remap" within warmup + patience steps of the
    slowdown onset (the streak-accumulation bugfix)."""
    m = StragglerMonitor()          # warn_ratio=1.5, patience=3, warmup=3
    step = 0
    for _ in range(6):
        assert m.record(step, 1.0) is None
        step += 1
    actions = []
    for i in range(m.patience + 1):
        actions.append(m.record(step, 2.0))
        step += 1
    assert "remap" in actions
    assert actions.index("remap") < m.patience
    assert m.ewma == pytest.approx(1.0)     # slow steps never leak in


def test_fleet_monitor_isolates_the_slow_node():
    fleet = FleetStragglerMonitor(patience=2, warmup=2)
    actions_seen = {}
    for step in range(12):
        dts = {0: 1.0, 1: 1.0, 2: 1.0 if step < 5 else 2.0}
        for node, act in fleet.record(step, dts).items():
            actions_seen.setdefault(node, []).append((step, act))
    assert set(actions_seen) == {2}
    assert any(a == "remap" for _, a in actions_seen[2])
    first_remap = min(s for s, a in actions_seen[2] if a == "remap")
    assert first_remap <= 5 + fleet.warmup + fleet.patience
    assert all(n for n, *_ in fleet.events)     # events carry the node


def test_ewma_not_seeded_from_anomalous_first_step():
    """Warm-up median seeding: a 20x slow step 0 (compilation) must not
    poison the baseline — the steady-state steps afterwards set it."""
    m = StragglerMonitor(warmup=3)
    m.record(0, 20.0)
    m.record(1, 1.0)
    m.record(2, 1.0)
    assert m.ewma == pytest.approx(1.0)     # median of [20, 1, 1]
    assert m.record(3, 1.1) is None         # healthy vs the sane baseline
