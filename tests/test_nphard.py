"""Executable NP-hardness reduction (paper §IV, Thm IV.3)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline: deterministic shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.cost import evaluate
from repro.core.nphard import (assignment_from_3way, grid_partition_brute,
                               reduce_3way_to_grid, three_way_partition_brute)


def test_paper_example_instance():
    # Fig. 3: I' = {6,3,3,2,2,2}, D = [6,2]... (paper draws the transpose);
    # our construction: D = [3, 6], Q = 2*6-6 = 6
    inst = reduce_3way_to_grid([6, 3, 3, 2, 2, 2])
    assert inst.grid.dims == (3, 6)
    assert inst.budget == 6
    colors = three_way_partition_brute(inst.node_sizes)
    assert colors is not None
    a = assignment_from_3way(inst, colors)
    c = evaluate(inst.grid, inst.stencil, a, num_nodes=6)
    assert c.j_sum <= inst.budget


@given(st.lists(st.integers(1, 6), min_size=3, max_size=7))
@settings(max_examples=40, deadline=None)
def test_reduction_forward_and_backward(items):
    if sum(items) % 3 != 0:
        with pytest.raises(ValueError):
            reduce_3way_to_grid(items)
        return
    inst = reduce_3way_to_grid(items)
    colors = three_way_partition_brute(items)
    mapping = grid_partition_brute(inst)
    # yes-instance of 3WAY  <=>  GRID-PARTITION achieves Q
    if colors is not None:
        a = assignment_from_3way(inst, colors)
        c = evaluate(inst.grid, inst.stencil, a, num_nodes=len(items))
        assert c.j_sum <= inst.budget
        assert mapping is not None
    else:
        assert mapping is None
