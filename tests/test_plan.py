"""Plan-layer contract: grammar<->plan parity, the serving cache, budgets,
and the `cart_create` facade.

Pinned invariants:
  * parity — for EVERY spelling in ``available_mappers()`` (and chained
    prefixes), ``parse_plan(name).solve(problem)`` returns the same
    assignment bit-exactly as ``get_mapper(name)`` on the refine_suite
    ``--tiny`` instances;
  * cache — hit/miss/eviction counters, content-keyed identity (changing
    stencil *weights* must miss), disk spill round-trip, and the
    acceptance claim: a warm cache makes a repeated mesh build >= 10x
    faster than the cold portfolio solve;
  * chained prefixes — appending a lexicographic refine stage never
    worsens ``(J_max, J_sum)`` (property test);
  * option grammar — negative numbers / scientific notation parse, and
    errors name the full spelling.
"""
import json
import math
import time

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline: deterministic shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import (CartGrid, MapperInapplicable, MappingPlan,
                        MappingProblem, PlanCache, Stencil, available_mappers,
                        cart_create, evaluate, get_mapper, mapped_device_array,
                        parse_plan)
from repro.core.mapping import parse_mapper_options, split_mapper_name
from repro.core.plan import default_plan_cache
from repro.core.refine import (BaseStage, RefineStage, ScheduledRefiner,
                               SwapRefiner)

# the refine_suite --tiny instances
TINY = [
    ("2d-8x8-hom", (8, 8), (16,) * 4),
    ("2d-6x8-ragged", (6, 8), (16, 16, 10, 6)),
    ("3d-4x4x4-hom", (4, 4, 4), (16,) * 4),
]

CHAINED = ("refined2:refined:hyperplane",
           "portfolio[k=2,sa_moves=40]:refined:kdtree",
           "annealed[sa_moves=50]:refined[policy=steepest]:blocked")


def _problem(dims, sizes, stencil=None):
    return MappingProblem(dims, stencil or Stencil.nearest_neighbor(len(dims)),
                          sizes)


# ---------------------------------------------------------------------------
# parity: the string grammar is a thin front-end onto plans


@pytest.mark.parametrize("label,dims,sizes", TINY)
def test_parse_plan_parity_with_get_mapper_all_spellings(label, dims, sizes):
    """Acceptance: every available_mappers() spelling solves bit-exactly
    equal through the plan API and the Mapper API."""
    grid = CartGrid(dims)
    stencil = Stencil.nearest_neighbor(len(dims))
    problem = _problem(dims, sizes, stencil)
    for name in available_mappers():
        plan = parse_plan(name)
        try:
            via_mapper = get_mapper(name).assignment(grid, stencil,
                                                     list(sizes))
        except MapperInapplicable:
            with pytest.raises(MapperInapplicable):
                plan.solve(problem)
            continue
        sol = plan.solve(problem)
        np.testing.assert_array_equal(sol.assignment, via_mapper,
                                      err_msg=f"{name} on {label}")
        cost = evaluate(grid, stencil, via_mapper, num_nodes=len(sizes))
        assert (sol.j_max, sol.j_sum) == (cost.j_max, cost.j_sum)


def test_parse_plan_parity_chained_prefixes():
    """Chained prefixes work identically through both front-ends, one
    refine stage per prefix, applied inner-first."""
    dims, sizes = (8, 8), (16,) * 4
    grid = CartGrid(dims)
    stencil = Stencil.nearest_neighbor(2)
    problem = _problem(dims, sizes, stencil)
    for name in CHAINED:
        plan = parse_plan(name)
        assert len(plan.stages) == 3
        assert isinstance(plan.stages[0], BaseStage)
        assert all(isinstance(s, RefineStage) for s in plan.stages[1:])
        sol = plan.solve(problem)
        via_mapper = get_mapper(name).assignment(grid, stencil, list(sizes))
        np.testing.assert_array_equal(sol.assignment, via_mapper, err_msg=name)


def test_plan_key_canonical_and_kwargs_merge():
    assert parse_plan("portfolio[seed=3,k=8]:hyperplane").key \
        == "portfolio[k=8,seed=3]:hyperplane"
    # kwargs configure the outermost refiner and land in the key; bracket
    # options win on conflict (same rule as get_mapper)
    assert parse_plan("refined:kdtree", policy="steepest").key \
        == "refined[policy=steepest]:kdtree"
    assert parse_plan("portfolio[k=4]:hyperplane", k=16).key \
        == "portfolio[k=4]:hyperplane"
    assert parse_plan("refined2:refined:hyperplane").key \
        == "refined2:refined:hyperplane"
    # base kwargs (no prefix) are part of the spelling too
    assert parse_plan("random", seed=7).key == "random{seed=7}"
    m = get_mapper("annealed[sa_moves=50]:kdtree")
    assert m.plan_key == "annealed[sa_moves=50]:kdtree"


def test_get_mapper_fallback_and_budget_kwargs_still_work():
    """Wrapper-level knobs survive the parse_plan rewrite: `fallback`
    starts refinement from another base when the primary is inapplicable
    (nodecart on ragged sizes), `budget` caps stage swaps — via kwargs or
    bracket options, through both front-ends."""
    dims, sizes = (6, 8), (16, 16, 10, 6)          # ragged: nodecart raises
    grid = CartGrid(dims)
    stencil = Stencil.nearest_neighbor(2)
    with pytest.raises(MapperInapplicable):
        get_mapper("refined:nodecart").assignment(grid, stencil, list(sizes))
    a = get_mapper("refined:nodecart",
                   fallback="blocked").assignment(grid, stencil, list(sizes))
    np.testing.assert_array_equal(np.bincount(a, minlength=4), sizes)
    plan = parse_plan("annealed[fallback=blocked,budget=5]:nodecart")
    assert plan.stages[0].fallback is not None
    assert plan.stages[1].budget == 5
    assert plan.key == "annealed@budget=5:nodecart@fallback=blocked"
    sol = plan.solve(_problem(dims, sizes, stencil))
    assert sum(s.get("swaps", 0) for s in sol.stage_stats) <= 5
    via_mapper = get_mapper(
        "annealed[fallback=blocked,budget=5]:nodecart").assignment(
        grid, stencil, list(sizes))
    np.testing.assert_array_equal(sol.assignment, via_mapper)


def test_hand_built_stages_never_share_keys_across_configs():
    """Cache-identity soundness: two differently-configured hand-built
    plans (no spelled options) must have different keys — and neither may
    collide with the bare parsed spelling."""
    from repro.core.mapping import RandomMapper
    p1 = MappingPlan([BaseStage("hyperplane"),
                      ScheduledRefiner(anneal=True, seed=1,
                                       sa_moves=300).as_stage()])
    p2 = MappingPlan([BaseStage("hyperplane"),
                      ScheduledRefiner(anneal=True, seed=2,
                                       sa_moves=50).as_stage()])
    parsed = parse_plan("annealed:hyperplane")
    assert p1.key != p2.key
    assert p1.key != parsed.key and p2.key != parsed.key
    # equal configs do share (deduplication, not just safety)
    p1b = MappingPlan([BaseStage("hyperplane"),
                       ScheduledRefiner(anneal=True, seed=1,
                                        sa_moves=300).as_stage()])
    assert p1.key == p1b.key
    # instance-built base mappers carry their configuration too
    assert MappingPlan([BaseStage(RandomMapper(seed=9))]).key \
        != MappingPlan([BaseStage(RandomMapper(seed=1))]).key
    # and the cache really separates them
    cache = PlanCache()
    problem = _problem((8, 8), (16,) * 4)
    s1 = cache.solve(problem, p1)
    s2 = cache.solve(problem, p2)
    assert not s2.from_cache and cache.misses == 2


def test_unkeyable_plans_bypass_the_cache():
    """A stage whose configuration has no stable spelling (nested objects
    would render as memory-address reprs) must never enter the cache."""
    from repro.core import RefinedMapper
    inner = RefinedMapper("hyperplane")            # nested objects in vars()
    plan = MappingPlan([BaseStage(inner)])
    assert not plan.cacheable
    cache = PlanCache()
    s1 = cache.solve(_problem((8, 8), (16,) * 4), plan)
    s2 = cache.solve(_problem((8, 8), (16,) * 4), plan)
    assert not s1.from_cache and not s2.from_cache
    assert cache.stats()["puts"] == 0
    # and to_mapper propagates "no stable key" instead of a bogus one
    assert plan.to_mapper().plan_key is None
    # a foreign refiner without config() is likewise unkeyed
    class Alien:
        def __init__(self):
            self.helper = object()
        def refine(self, *a, **k):                 # pragma: no cover
            raise NotImplementedError
    assert not RefineStage(Alien()).cacheable
    # cacheable plans still advertise it
    assert parse_plan("annealed:hyperplane").cacheable
    assert MappingPlan([BaseStage("hyperplane"),
                        SwapRefiner().as_stage()]).cacheable


def test_refine_stage_rejects_assignment_violating_node_sizes():
    """The blocked-allocation guard: a base whose assignment doesn't
    realize node_sizes must raise, not silently corrupt the bijection."""
    grid, stencil = CartGrid((4, 4)), Stencil.nearest_neighbor(2)
    bad = np.repeat([0, 1], [10, 6])               # node_sizes say [8, 8]
    with pytest.raises(AssertionError, match="node_sizes"):
        SwapRefiner().as_stage().run(grid, stencil, (8, 8), bad)


def test_device_layout_cache_key_is_canonical():
    """Equivalent spellings (reordered bracket options, get_mapper
    instances) share one cache entry."""
    from repro.core import device_layout
    dims, sizes = (8, 8), [16] * 4
    stencil = Stencil.nearest_neighbor(2)
    cache = PlanCache()
    spelled = "annealed[sa_moves=50,seed=1]:hyperplane"
    reordered = "annealed[seed=1,sa_moves=50]:hyperplane"
    L1 = device_layout(spelled, dims, stencil, sizes, cache=cache)
    L2 = device_layout(reordered, dims, stencil, sizes, cache=cache)
    L3 = device_layout(get_mapper(spelled), dims, stencil, sizes, cache=cache)
    assert (cache.hits, cache.misses) == (2, 1)
    np.testing.assert_array_equal(L1, L2)
    np.testing.assert_array_equal(L1, L3)


def test_parse_plan_unknown_names_raise():
    with pytest.raises(KeyError, match="unknown mapper"):
        parse_plan("nope")
    with pytest.raises(KeyError, match=r"base of 'refined:nope'"):
        parse_plan("refined:nope")
    with pytest.raises(ValueError, match="first stage"):
        MappingPlan([RefineStage(SwapRefiner())])


def test_solution_layout_matches_device_layout_rowmajor():
    from repro.core import device_layout
    dims, sizes = (6, 8), (16, 16, 10, 6)
    problem = _problem(dims, sizes)
    sol = parse_plan("refined:hyperplane").solve(problem)
    L = device_layout("refined:hyperplane", dims, problem.stencil,
                      list(sizes), intra_order="rowmajor", cache=False)
    np.testing.assert_array_equal(sol.layout(), L)


# ---------------------------------------------------------------------------
# bracket-option grammar: negative numbers, scientific notation, errors


def test_parse_mapper_options_negative_and_scientific():
    out = parse_mapper_options("t0=1e-2,seed=-3,x=+4,y=-2.5E3,z=1e3,w=.5")
    assert out == {"t0": 0.01, "seed": -3, "x": 4, "y": -2500.0,
                   "z": 1000.0, "w": 0.5}
    assert isinstance(out["seed"], int) and isinstance(out["z"], float)
    # through the full spelling (the ISSUE's example)
    prefix, opts, base = split_mapper_name("annealed[t0=1e-2]:hyperplane")
    assert (prefix, opts, base) == ("annealed", {"t0": 0.01}, "hyperplane")
    sched = parse_plan("annealed[sa_moves=50,tol=1e-9]:blocked").stages[1]
    assert sched.refiner.tol == 1e-9


def test_parse_mapper_options_errors_name_full_spelling():
    with pytest.raises(ValueError, match=r"'annealed\[k\]:hyperplane'"):
        split_mapper_name("annealed[k]:hyperplane")
    with pytest.raises(ValueError, match=r"'portfolio\[k=1,k=2\]:kdtree'"):
        parse_plan("portfolio[k=1,k=2]:kdtree")
    # chained: the error quotes the ORIGINAL spelling, not the inner rest
    with pytest.raises(ValueError,
                       match=r"'portfolio:annealed\[=3\]:kdtree'"):
        parse_plan("portfolio:annealed[=3]:kdtree")


# ---------------------------------------------------------------------------
# the serving cache


def test_plan_cache_hit_miss_and_weights_invalidate():
    dims, sizes = (8, 8), (16,) * 4
    cache = PlanCache()
    plan = parse_plan("refined:hyperplane")
    p1 = _problem(dims, sizes)
    s1 = cache.solve(p1, plan)
    assert (cache.hits, cache.misses) == (0, 1) and not s1.from_cache
    s2 = cache.solve(_problem(dims, sizes), plan)     # equal content, new obj
    assert (cache.hits, cache.misses) == (1, 1) and s2.from_cache
    np.testing.assert_array_equal(s1.assignment, s2.assignment)
    assert s2.key() == s1.key() and s2.stage_stats

    # changing stencil WEIGHTS (same offsets) must miss
    heavy = Stencil(p1.stencil.offsets, (8.0,) + (1.0,) * (p1.stencil.k - 1))
    assert _problem(dims, sizes, heavy).content_hash() != p1.content_hash()
    cache.solve(_problem(dims, sizes, heavy), plan)
    assert cache.misses == 2
    # different plan, different node sizes, different objective: all miss
    cache.solve(p1, parse_plan("refined2:hyperplane"))
    cache.solve(_problem(dims, (20, 16, 14, 14)), plan)
    cache.solve(MappingProblem(dims, p1.stencil, sizes, objective="j_max"),
                plan)
    assert cache.misses == 5 and cache.hits == 1


def test_plan_cache_hits_are_isolated_from_caller_mutation():
    """Warm hits hand back fresh copies: mutating a returned solution must
    not corrupt the live cache entry (serving-grade contract)."""
    cache = PlanCache()
    plan = parse_plan("refined:hyperplane")
    problem = _problem((8, 8), (16,) * 4)
    cache.solve(problem, plan)
    warm = cache.solve(problem, plan)
    warm.stage_stats[1]["swaps"] = "CORRUPTED"
    warm.assignment[:] = -1
    clean = cache.solve(problem, plan)
    assert clean.stage_stats[1]["swaps"] != "CORRUPTED"
    assert clean.assignment.min() >= 0
    # layout hits too
    L1 = cache.layout(problem, plan.key, "rowmajor",
                      lambda: np.arange(64).reshape(8, 8))
    L1[:] = -1
    L2 = cache.layout(problem, plan.key, "rowmajor", lambda: 1 / 0)
    assert L2.min() >= 0


def test_split_mapper_list_and_dryrun_order_suffix():
    """CLI list splitting respects bracket commas, and the dry-run's +rm
    order suffix never bites a signed bracket-option value."""
    import os
    from repro.core.mapping import split_mapper_list
    saved = os.environ.get("XLA_FLAGS")         # dryrun import sets 512 fake
    try:                                        # devices; don't leak it
        from repro.launch.dryrun import _split_order
    finally:
        if saved is None:
            os.environ.pop("XLA_FLAGS", None)
        else:                                   # pragma: no cover
            os.environ["XLA_FLAGS"] = saved
    assert split_mapper_list(
        "blocked,portfolio[k=8,seed=3]:kdtree,hyperplane+rm") \
        == ["blocked", "portfolio[k=8,seed=3]:kdtree", "hyperplane+rm"]
    assert _split_order("hyperplane+rm") == ("hyperplane", "rm")
    assert _split_order("annealed[tol=+1e-9]:hyperplane") \
        == ("annealed[tol=+1e-9]:hyperplane", "")
    base, order = _split_order("annealed[tol=+1e-9]:hyperplane+rm")
    assert order == "rm"
    assert parse_plan(base).stages[1].refiner.tol == 1e-9


def test_plan_cache_lru_eviction_and_clear():
    cache = PlanCache(maxsize=2)
    for i in range(3):
        cache.put(f"k{i}", {"v": i})
    assert cache.evictions == 1 and cache.get("k0") is None
    assert cache.get("k2")["v"] == 2
    cache.clear()
    assert cache.stats() == {"size": 0, "hits": 0, "misses": 0,
                             "disk_hits": 0, "puts": 0, "evictions": 0,
                             "corrupt_drops": 0, "expired": 0,
                             "invalidations": 0, "disk_evictions": 0}


def test_plan_cache_disk_spill_roundtrip(tmp_path):
    dims, sizes = (6, 8), (16, 16, 10, 6)
    plan = parse_plan("refined:hyperplane")
    c1 = PlanCache(disk_dir=tmp_path)
    sol = c1.solve(_problem(dims, sizes), plan)
    assert list(tmp_path.glob("*.json"))
    # a fresh cache (fresh process, conceptually) reads the spill back
    c2 = PlanCache(disk_dir=tmp_path)
    warm = c2.solve(_problem(dims, sizes), plan)
    assert warm.from_cache and c2.disk_hits == 1 and c2.misses == 0
    np.testing.assert_array_equal(warm.assignment, sol.assignment)
    assert warm.key() == sol.key()


def test_plan_cache_env_dir_read_at_construction(tmp_path, monkeypatch):
    """Regression: ``$REPRO_MAPS_CACHE_DIR`` set *after* import must still
    direct ``PlanCache(disk_dir=True)`` spills — the pre-fix code froze
    the path into ``DEFAULT_CACHE_DIR`` at import time, so late env
    changes (pytest monkeypatching, embedders configuring before first
    use) were silently ignored."""
    from repro.core.plan import default_cache_dir
    target = tmp_path / "late-env"
    monkeypatch.setenv("REPRO_MAPS_CACHE_DIR", str(target))
    assert default_cache_dir() == target
    cache = PlanCache(disk_dir=True)
    assert cache.disk_dir == target
    cache.put("k", {"v": 1})
    assert list(target.glob("*.json"))
    # a second late change moves the NEXT construction, not existing ones
    other = tmp_path / "other"
    monkeypatch.setenv("REPRO_MAPS_CACHE_DIR", str(other))
    assert cache.disk_dir == target
    assert PlanCache(disk_dir=True).disk_dir == other
    # unset: falls back to the documented default
    monkeypatch.delenv("REPRO_MAPS_CACHE_DIR")
    assert default_cache_dir().name == "repro-maps"


def test_plan_cache_corrupt_spill_is_miss_and_dropped(tmp_path):
    """A truncated/corrupt spill file is a *miss*, never an exception, and
    the bad file is deleted so it cannot poison every future read."""
    plan = parse_plan("refined:hyperplane")
    problem = _problem((8, 8), (16,) * 4)
    c1 = PlanCache(disk_dir=tmp_path)
    c1.solve(problem, plan)
    path = next(tmp_path.glob("*.json"))
    key = f"sol:{problem.content_hash()}:{plan.key}"
    for garbage in ('{"key": tru',                  # truncated JSON
                    "[1, 2, 3]",                    # valid JSON, not a dict
                    '"just a string"',
                    json.dumps({"key": key}),       # right key, no value
                    json.dumps({"key": key, "value": 7})):  # non-dict value
        path.write_text(garbage)
        fresh = PlanCache(disk_dir=tmp_path)
        assert fresh.get(key) is None, garbage
        assert (fresh.misses, fresh.disk_hits) == (1, 0), garbage
        assert fresh.corrupt_drops == 1, garbage
        assert not path.exists(), garbage           # dropped, not left to rot
        assert "corrupt_drops" in fresh.stats()
    # a valid spill for a *different* key (hash-prefix collision) is a
    # plain miss: the file is someone else's entry and must survive
    path.write_text(json.dumps({"key": "other", "value": {"x": 1}}))
    fresh = PlanCache(disk_dir=tmp_path)
    assert fresh.get(key) is None and fresh.corrupt_drops == 0
    assert path.exists()
    # and after the drop, a re-solve repopulates the spill cleanly
    path.unlink()
    c2 = PlanCache(disk_dir=tmp_path)
    sol = c2.solve(problem, plan)
    assert not sol.from_cache
    assert PlanCache(disk_dir=tmp_path).solve(problem, plan).from_cache


def test_plan_cache_stale_tmp_cleanup(tmp_path):
    """A crashed writer's abandoned .tmp (per-writer unique name — nobody
    will ever finish it) is swept on the next put; fresh in-flight ones
    are left alone."""
    import os as _os
    stale = tmp_path / "deadbeef.12345.aaaaaaaa.tmp"
    stale.write_text('{"key": "never finis')
    _os.utime(stale, (time.time() - 3600, time.time() - 3600))
    fresh = tmp_path / "cafebabe.12346.bbbbbbbb.tmp"
    fresh.write_text("in flight")
    cache = PlanCache(disk_dir=tmp_path)
    cache.put("k", {"v": 1})
    assert not stale.exists()
    assert fresh.exists()
    assert cache.get("k") == {"v": 1}


def _hammer_put(args):
    """Worker for the concurrent-put stress: every process spills the same
    key (plus one private key) many times into one shared dir."""
    disk_dir, wid, n = args
    cache = PlanCache(disk_dir=disk_dir)
    for i in range(n):
        cache.put("shared", {"writer": wid, "i": i})
        cache.put(f"private-{wid}", {"writer": wid, "i": i})
    return cache.get("shared") is not None


def test_plan_cache_concurrent_put_stress(tmp_path):
    """Many processes spilling the same key concurrently: unique tmp names
    + flock'd atomic publish mean the spill file is always one writer's
    complete JSON — never interleaved, never truncated — and no .tmp
    litter survives."""
    import multiprocessing as mp
    if "fork" not in mp.get_all_start_methods():
        pytest.skip("needs fork start method")
    ctx = mp.get_context("fork")
    with ctx.Pool(4) as pool:
        ok = pool.map(_hammer_put, [(str(tmp_path), w, 25) for w in range(4)])
    assert all(ok)
    assert not list(tmp_path.glob("*.tmp"))
    reader = PlanCache(disk_dir=tmp_path)
    got = reader.get("shared")
    assert got is not None and got["i"] == 24      # some writer's last put
    assert reader.corrupt_drops == 0
    for w in range(4):
        assert reader.get(f"private-{w}") == {"writer": w, "i": 24}


def test_warm_cache_mesh_build_10x_faster_than_cold_portfolio():
    """Acceptance: a warm PlanCache makes a repeated mesh build >= 10x
    faster than the cold solve on a portfolio row, proven by hit counters
    (mapped_device_array is make_mapped_mesh minus the jax Mesh wrapper)."""
    dims, sizes = (8, 8), [22, 16, 16, 10]          # ragged portfolio row
    stencil = Stencil.nearest_neighbor(2)
    devices = list(range(math.prod(dims)))
    cache = PlanCache()
    name = "portfolio[k=4]:hyperplane"
    t0 = time.perf_counter()
    cold = mapped_device_array(devices, name, dims, stencil, 16,
                               node_sizes=sizes, cache=cache)
    t_cold = time.perf_counter() - t0
    assert (cache.hits, cache.misses) == (0, 1)
    t0 = time.perf_counter()
    warm = mapped_device_array(devices, name, dims, stencil, 16,
                               node_sizes=sizes, cache=cache)
    t_warm = time.perf_counter() - t0
    assert (cache.hits, cache.misses) == (1, 1)
    np.testing.assert_array_equal(np.vectorize(int)(cold),
                                  np.vectorize(int)(warm))
    assert t_warm < t_cold / 10.0, (t_cold, t_warm)


def test_elastic_auto_upgrade_is_cacheable():
    """The ragged-pod ensure_refined upgrade carries a stable plan_key, so
    even a *plain* mapper name reuses its elastic portfolio solve."""
    dims, sizes = (6, 4), [8, 8, 5, 3]
    stencil = Stencil.nearest_neighbor(2)
    devices = list(range(24))
    cache = PlanCache()
    for _ in range(2):
        arr = mapped_device_array(devices, "hyperplane", dims, stencil, 8,
                                  node_sizes=sizes, cache=cache)
    assert (cache.hits, cache.misses) == (1, 1)
    # ad-hoc instances (no plan_key) never pollute the cache
    from repro.core.mapping import HyperplaneMapper
    mapped_device_array(devices, HyperplaneMapper(), dims, stencil, 8,
                        node_sizes=sizes, auto_refine=False, cache=cache)
    assert (cache.hits, cache.misses) == (1, 1)


# ---------------------------------------------------------------------------
# per-stage budgets


def test_refine_stage_budget_caps_swaps():
    dims, sizes = (8, 8), (16,) * 4
    grid, stencil = CartGrid(dims), Stencil.nearest_neighbor(2)
    base = get_mapper("random").assignment(grid, stencil, list(sizes))
    free = SwapRefiner().as_stage().run(grid, stencil, sizes, base)
    assert free.stats["swaps"] > 2
    for budget in (0, 1, 2):
        capped = SwapRefiner().as_stage(budget=budget).run(
            grid, stencil, sizes, base)
        assert capped.stats["swaps"] <= budget
    sched = ScheduledRefiner(anneal=True, sa_moves=30).as_stage(budget=3).run(
        grid, stencil, sizes, base)
    assert sched.stats["swaps"] <= 3
    # a budgeted stage still never loses the lexicographic guarantee
    k_in = evaluate(grid, stencil, base, num_nodes=4)
    k_out = evaluate(grid, stencil, sched.assignment, num_nodes=4)
    assert (k_out.j_max, k_out.j_sum) <= (k_in.j_max, k_in.j_sum)


# ---------------------------------------------------------------------------
# chained-prefix lexicographic improvement (property)


@given(st.integers(0, 10_000), st.sampled_from(["hyperplane", "random",
                                                "kdtree"]))
@settings(max_examples=12, deadline=None)
def test_chained_prefix_lexicographic_improvement(seed, base):
    """Appending a lexicographic refine stage to any plan never worsens
    (J_max, J_sum): `refined2:refined:<base>` <= `refined:<base>`."""
    rng = np.random.default_rng(seed)
    n_nodes = int(rng.integers(2, 5))
    per = int(rng.integers(3, 7))
    dims = (n_nodes * per,) if rng.integers(2) else (n_nodes, per)
    sizes = (per,) * n_nodes if len(dims) == 1 \
        else (dims[1],) * n_nodes
    problem = _problem(dims, sizes)
    inner = parse_plan(f"refined:{base}").solve(problem)
    chained = parse_plan(f"refined2:refined:{base}").solve(problem)
    assert chained.key() <= inner.key(), (dims, sizes, base)


# ---------------------------------------------------------------------------
# cart_create facade


def test_cart_create_cold_then_warm():
    cache = PlanCache()
    r1 = cart_create((8, 8), node_sizes=[16] * 4, cache=cache)
    assert not r1.from_cache and (cache.hits, cache.misses) == (0, 1)
    r2 = cart_create((8, 8), node_sizes=[16] * 4, cache=cache)
    assert r2.from_cache and (cache.hits, cache.misses) == (1, 1)
    np.testing.assert_array_equal(r1.layout, r2.layout)
    assert r1.layout.shape == (8, 8)
    assert sorted(r1.layout.reshape(-1).tolist()) == list(range(64))
    assert r1.plan_key == "annealed:hyperplane"       # the documented default
    # the default-cache path works too (no explicit cache object)
    r3 = cart_create((8, 8), node_sizes=[16] * 4)
    np.testing.assert_array_equal(r3.layout, r1.layout)
    assert default_plan_cache().puts >= 1


def test_cart_create_chips_per_pod_and_ragged_tail():
    r = cart_create((6, 4), chips_per_pod=9, plan="refined:hyperplane",
                    cache=False)
    assert r.problem.node_sizes == (9, 9, 6) and r.problem.is_ragged
    counts = np.bincount(r.solution.assignment, minlength=3)
    np.testing.assert_array_equal(counts, [9, 9, 6])
    with pytest.raises(ValueError, match="node_sizes or chips_per_pod"):
        cart_create((4, 4))


def test_cart_create_reorder_false_is_blocked():
    r = cart_create((4, 4), chips_per_pod=4, reorder=False, cache=False)
    np.testing.assert_array_equal(r.layout.reshape(-1), np.arange(16))
    assert r.plan_key == "blocked"


def test_cart_create_beats_blocked_on_stencil():
    blocked = cart_create((8, 8), chips_per_pod=16, reorder=False,
                          cache=False)
    mapped = cart_create((8, 8), chips_per_pod=16, cache=False)
    assert (mapped.j_max, mapped.j_sum) <= (blocked.j_max, blocked.j_sum)


# ---------------------------------------------------------------------------
# cross-engine parity matrix: serial / mp / device portfolio spellings

#: one spelling per execution engine, same portfolio configuration.  The
#: execution backend is part of the cache identity (PR-5 faithfulness
#: rule), so the keys must be pairwise DISTINCT while every family shows
#: identical cache *behavior*: canonical key, cacheable, miss-then-hit.
ENGINE_FAMILIES = {
    "serial": "portfolio[k=3,sa_moves=30]:hyperplane",
    "mp": "sharded[k=3,sa_moves=30,shards=2]:hyperplane",
    "device": "device[k=3,sa_moves=30]:hyperplane",
}


def test_cross_engine_parity_matrix_keys_and_cache_behavior():
    """Every engine spelling that accepts a backend/engine option behaves
    identically through the plan layer: the spelled name IS the canonical
    key (round-trips through parse_plan), the plan is cacheable, and a
    repeat solve is a cache hit — while the keys stay pairwise distinct so
    one engine's cached assignment is never served for another's."""
    problem = _problem((8, 8), (16,) * 4)
    keys = {}
    for family, name in ENGINE_FAMILIES.items():
        plan = parse_plan(name)
        assert plan.key == name, f"{family}: non-canonical key"
        assert parse_plan(plan.key).key == plan.key     # round-trip
        assert plan.cacheable, f"{family}: must be cacheable"
        assert get_mapper(name).plan_key == name
        cache = PlanCache()
        s1 = cache.solve(problem, plan)
        s2 = cache.solve(problem, plan)
        assert not s1.from_cache and s2.from_cache, \
            f"{family}: miss-then-hit broken"
        np.testing.assert_array_equal(s1.assignment, s2.assignment)
        keys[family] = plan.key
    assert len(set(keys.values())) == len(keys), \
        f"engine keys must be pairwise distinct: {keys}"
    # one shared cache never crosses engines: three solves, three misses
    cache = PlanCache()
    for name in ENGINE_FAMILIES.values():
        cache.solve(problem, parse_plan(name))
    assert cache.misses == len(ENGINE_FAMILIES) and cache.hits == 0


def test_ad_hoc_device_instances_bypass_the_cache():
    """A hand-built device refiner carrying an engine_factory has no
    stable spelling (the factory is an opaque object), so its stage and
    any plan containing it must be uncacheable — same contract as nested
    foreign objects in test_unkeyable_plans_bypass_the_cache."""
    from repro.core import DevicePortfolioRefiner
    from repro.core.refine.device import DeviceLadderEngine
    ad_hoc = DevicePortfolioRefiner(k=2, sa_moves=30,
                                    engine_factory=DeviceLadderEngine)
    stage = ad_hoc.as_stage()
    assert not stage.cacheable
    plan = MappingPlan([BaseStage("hyperplane"), stage])
    assert not plan.cacheable
    assert plan.to_mapper().plan_key is None
    cache = PlanCache()
    problem = _problem((8, 8), (16,) * 4)
    s1 = cache.solve(problem, plan)
    s2 = cache.solve(problem, plan)
    assert not s1.from_cache and not s2.from_cache
    assert cache.stats()["puts"] == 0
    # the factory really is used: identical configuration, same result
    np.testing.assert_array_equal(s1.assignment, s2.assignment)
    # the same configuration without the factory is cacheable
    assert DevicePortfolioRefiner(k=2, sa_moves=30).as_stage().cacheable


# ---------------------------------------------------------------------------
# serving-cache extensions: TTL, invalidation, disk budget, concurrency


def test_plan_cache_ttl_expiry_mem_and_disk(tmp_path):
    """A TTL'd entry serves until its deadline then reads as a miss — in
    memory AND through the disk spill (the expiry rides inside the blob,
    so a fresh cache over the same directory honors it too)."""
    cache = PlanCache(disk_dir=tmp_path, ttl_s=0.05)
    cache.put("sol:h1:planA", {"v": 1})
    assert cache.get("sol:h1:planA")["v"] == 1
    time.sleep(0.08)
    assert cache.get("sol:h1:planA") is None
    assert cache.expired >= 1
    # the expired spill file was dropped on read, not left to rot
    c2 = PlanCache(disk_dir=tmp_path)
    assert c2.get("sol:h1:planA") is None
    # per-put override: ttl_s=None pins the entry forever
    cache.put("sol:h1:planB", {"v": 2}, ttl_s=None)
    time.sleep(0.08)
    assert cache.get("sol:h1:planB")["v"] == 2


def test_plan_cache_invalidate_by_problem_hash(tmp_path):
    """invalidate(problem_hash) drops every entry of that problem —
    solutions and layouts, memory and disk — and leaves other problems'
    entries untouched."""
    cache = PlanCache(disk_dir=tmp_path)
    cache.put("sol:aaa:planA", {"v": 1})
    cache.put("lay:aaa:planA:rowmajor", {"v": 2})
    cache.put("sol:bbb:planA", {"v": 3})
    assert cache.invalidate("aaa") == 2
    assert cache.invalidations == 2
    assert cache.get("sol:aaa:planA") is None
    assert cache.get("lay:aaa:planA:rowmajor") is None
    assert cache.get("sol:bbb:planA")["v"] == 3
    # disk spills of the invalidated problem are gone for fresh readers
    c2 = PlanCache(disk_dir=tmp_path)
    assert c2.get("sol:aaa:planA") is None
    assert c2.get("sol:bbb:planA")["v"] == 3
    assert cache.invalidate("zzz") == 0


def test_plan_cache_disk_budget_evicts_lru_order(tmp_path):
    """Regression for the disk-budget sweep's eviction ORDER: the sweep
    must drop oldest-mtime spills first, and a disk *read* refreshes the
    entry's mtime — so a recently-read entry survives a newer-but-unread
    one."""
    pad = "x" * 200
    cache = PlanCache(maxsize=1, disk_dir=tmp_path, max_disk_bytes=600)
    cache.put("sol:h1:k0", {"v": 0, "pad": pad})
    time.sleep(0.05)
    cache.put("sol:h2:k1", {"v": 1, "pad": pad})      # k0 falls out of mem
    time.sleep(0.05)
    assert cache.get("sol:h1:k0")["v"] == 0           # disk hit -> mtime now
    assert cache.disk_hits == 1
    cache.put("sol:h3:k2", {"v": 2, "pad": pad})      # budget forces a sweep
    assert cache.disk_evictions >= 1
    # k1 (oldest mtime) was evicted; the freshly-read k0 survived
    c2 = PlanCache(disk_dir=tmp_path)
    assert c2.get("sol:h2:k1") is None
    assert c2.get("sol:h1:k0")["v"] == 0
    assert c2.get("sol:h3:k2")["v"] == 2
    st = cache.stats()
    assert st["disk_bytes"] <= 600 and st["disk_files"] == 2


def test_plan_cache_concurrent_ttl_and_invalidate(tmp_path):
    """Satellite: multi-threaded get/put with TTL expiry racing
    invalidation — no exceptions, and the counters stay consistent (every
    lookup is exactly one hit, one disk hit, or one miss)."""
    import threading
    cache = PlanCache(maxsize=16, disk_dir=tmp_path, ttl_s=0.02)
    stop = threading.Event()
    errors = []
    lookups = [0] * 4

    def worker(i):
        k = 0
        try:
            while not stop.is_set():
                key = f"sol:h{i}:k{k % 8}"
                cache.put(key, {"v": k}, ttl_s=0.01 if k % 3 else None)
                got = cache.get(key)
                assert got is None or isinstance(got["v"], int)
                lookups[i] += 1
                k += 1
        except BaseException as e:          # surfaced to the main thread
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    t_end = time.perf_counter() + 0.6
    while time.perf_counter() < t_end:
        for i in range(4):
            cache.invalidate(f"h{i}")
        time.sleep(0.01)
    stop.set()
    for t in threads:
        t.join(timeout=10)
    assert not errors
    st = cache.stats()
    # every lookup is exactly one hit or one miss (disk hits count as
    # hits — the entry was served — plus the disk_hits sub-counter)
    assert st["hits"] + st["misses"] == sum(lookups)
    assert st["disk_hits"] <= st["hits"]
    assert st["size"] <= 16
    assert all(isinstance(v, int) and v >= 0 for v in st.values())
