"""Unit + property tests for CartGrid / Stencil / dims_create."""
import math

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline: deterministic shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import CartGrid, Stencil, dims_create


def test_grid_roundtrip():
    g = CartGrid((3, 4, 5))
    assert g.size == 60
    for r in [0, 1, 17, 59]:
        assert g.rank_of(g.coord_of(r)) == r


def test_grid_coords_row_major():
    g = CartGrid((2, 3))
    np.testing.assert_array_equal(
        g.coords(), [[0, 0], [0, 1], [0, 2], [1, 0], [1, 1], [1, 2]])


def test_shift_ranks_truncates_at_border():
    g = CartGrid((2, 2))
    valid, tgt = g.shift_ranks((0, 1))
    np.testing.assert_array_equal(valid, [True, False, True, False])
    assert tgt[0] == 1 and tgt[2] == 3


def test_shift_ranks_periodic():
    g = CartGrid((2, 2), periodic=(False, True))
    valid, tgt = g.shift_ranks((0, 1))
    assert valid.all()
    np.testing.assert_array_equal(tgt, [1, 0, 3, 2])


@given(st.integers(1, 512), st.integers(1, 4))
@settings(max_examples=60, deadline=None)
def test_dims_create_properties(p, nd):
    dims = dims_create(p, nd)
    assert len(dims) == nd
    assert math.prod(dims) == p
    assert list(dims) == sorted(dims, reverse=True)  # MPI spec: decreasing


def test_paper_stencils_2d():
    nn = Stencil.nearest_neighbor(2)
    assert set(nn.offsets) == {(1, 0), (-1, 0), (0, 1), (0, -1)}
    comp = Stencil.component(2)
    assert set(comp.offsets) == {(1, 0), (-1, 0)}
    hops = Stencil.nn_with_hops(2)
    assert set(hops.offsets) == {(1, 0), (-1, 0), (0, 1), (0, -1),
                                 (2, 0), (-2, 0), (3, 0), (-3, 0)}


def test_stencil_axis_stats():
    hops = Stencil.nn_with_hops(2)
    np.testing.assert_array_equal(hops.axis_comm_counts(), [6, 2])
    np.testing.assert_array_equal(hops.extents(), [6, 2])
    cos2 = hops.cos2_sums()
    assert cos2[0] > cos2[1]  # dim 0 carries more traffic


def test_component_distortion_zero_on_silent_dim():
    comp = Stencil.component(2)  # communicates along dim 0 only
    alpha = comp.distortion_factors()
    assert alpha[1] == 0.0 and alpha[0] > 0


def test_flat_interface_roundtrip():
    # the paper's MPIX_Cart_stencil_comm flattened stencil[] array
    s = Stencil.from_flat([1, 0, -1, 0, 0, 1, 0, -1], ndims=2, k=4)
    assert set(s.offsets) == set(Stencil.nearest_neighbor(2).offsets)


def test_stencil_rejects_bad_input():
    with pytest.raises(ValueError):
        Stencil(((0, 0),))  # self-loop
    with pytest.raises(ValueError):
        Stencil(((1, 0), (1, 0)))  # duplicate
    with pytest.raises(ValueError):
        Stencil(((1, 0),), weights=(0.0,))  # non-positive weight
