"""Checkpoint I/O + manager: roundtrip, atomicity, corruption, rotation."""
import json
from pathlib import Path

import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_arrays, save_arrays


def _state(seed=0):
    rng = np.random.default_rng(seed)
    return {"params": {"layers/w": rng.standard_normal((4, 8)).astype(np.float32),
                       "embed": rng.standard_normal((16, 4)).astype(np.float32)},
            "opt": {"m/layers/w": np.zeros((4, 8), np.float32),
                    "count": np.asarray(7, np.int32)}}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=False)
    state = _state()
    mgr.save(10, state)
    step, restored = mgr.restore()
    assert step == 10
    np.testing.assert_array_equal(restored["params"]["layers/w"],
                                  state["params"]["layers/w"])
    np.testing.assert_array_equal(restored["opt"]["count"], 7)


def test_uncommitted_checkpoint_ignored(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=False)
    mgr.save(10, _state())
    mgr.save(20, _state(1))
    (mgr.path(20) / "COMMIT").unlink()  # simulate crash mid-publish
    step, _ = mgr.restore()
    assert step == 10


def test_corrupted_checkpoint_falls_back(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=5, async_save=False)
    mgr.save(10, _state())
    mgr.save(20, _state(1))
    # corrupt step 20's payload but keep META/COMMIT
    f = mgr.path(20) / "host0.npz"
    data = bytearray(f.read_bytes())
    data[100:200] = b"\x00" * 100
    f.write_bytes(bytes(data))
    step, restored = mgr.restore()
    assert step == 10 and restored is not None


def test_rotation_keeps_last_k(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
    for s in (10, 20, 30, 40):
        mgr.save(s, _state(s))
    assert mgr.steps() == [30, 40]


def test_async_save_then_restore(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=True)
    mgr.save(5, _state())
    mgr.wait()
    assert mgr.latest_step() == 5


def test_digest_detects_bitflip(tmp_path):
    save_arrays(tmp_path / "c", {"x": np.arange(100, dtype=np.float32)})
    # flip a byte in the payload
    f = tmp_path / "c" / "host0.npz"
    data = bytearray(f.read_bytes())
    data[-10] ^= 0xFF
    f.write_bytes(bytes(data))
    with pytest.raises(Exception):
        load_arrays(tmp_path / "c")
