"""Partitioning layer: divisibility fallback, axis conflicts, no-mesh no-op,
device layout construction for meshes."""
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import CartGrid, Stencil, device_layout, get_mapper, layout_cost
from repro.sharding.partition import Partitioning, ParamSpec


class FakeMesh:
    """Duck-typed mesh: Partitioning only reads .shape."""
    def __init__(self, shape):
        self.shape = shape


def _part(shape=None):
    p = Partitioning(mesh=FakeMesh(shape or {"data": 16, "model": 16}))
    return p


def test_spec_basic():
    p = _part()
    assert p.spec(("fsdp", "tp"), (64, 32)) == P("data", "model")


def test_pod_axis_dropped_on_single_pod():
    p = _part({"data": 16, "model": 16})
    assert p.spec(("batch", None), (256, 4)) == P("data", None)
    p2 = _part({"pod": 2, "data": 16, "model": 16})
    assert p2.spec(("batch", None), (256, 4)) == P(("pod", "data"), None)


def test_divisibility_fallback():
    p = _part()
    # 56 heads on a 16-way axis -> replicate + record
    assert p.spec(("heads",), (56,)) == P(None)
    assert len(p.fallbacks) == 1


def test_axis_conflict_first_come_first_served():
    p = _part()
    # E=256 divides: expert wins the model axis, tp dropped
    assert p.spec(("expert", "fsdp", "tp"), (256, 7168, 2048)) == \
        P("model", "data", None)
    # E=8 doesn't divide: falls back, tp picks model up
    assert p.spec(("expert", "fsdp", "tp"), (8, 4096, 14336)) == \
        P(None, "data", "model")


def test_no_mesh_constrain_is_noop():
    import jax.numpy as jnp
    p = Partitioning(mesh=None)
    x = jnp.ones((4, 4))
    assert p.constrain(x, "batch", None) is x


def test_param_spec_validates_rank():
    with pytest.raises(ValueError):
        ParamSpec((4, 4), np.float32, ("fsdp",))


# -- device layout / remap ---------------------------------------------------
def test_device_layout_is_permutation():
    st = Stencil.nearest_neighbor(2)
    for mname in ("blocked", "stencil_strips", "hyperplane", "kdtree"):
        L = device_layout(get_mapper(mname), (16, 16), st, [64] * 4)
        assert sorted(L.reshape(-1).tolist()) == list(range(256))


def test_blocked_layout_is_identity():
    st = Stencil.nearest_neighbor(2)
    L = device_layout(get_mapper("blocked"), (4, 4), st, [8, 8])
    np.testing.assert_array_equal(L.reshape(-1), np.arange(16))


def test_mapped_layout_reduces_cross_node_edges():
    """The integration-level claim: a mapped layout has lower J than a
    pathological one, measured by layout_cost."""
    st = Stencil.nearest_neighbor(2)
    sizes = [16] * 4
    rng = np.random.default_rng(0)
    L_mapped = device_layout(get_mapper("stencil_strips"), (8, 8), st, sizes)
    L_rand = np.arange(64)
    rng.shuffle(L_rand)
    j_mapped = layout_cost(L_mapped, st, sizes).j_sum
    j_rand = layout_cost(L_rand.reshape(8, 8), st, sizes).j_sum
    assert j_mapped < j_rand


def test_layout_cost_heterogeneous_tail_pod():
    """Elastic case: last pod smaller after a failure."""
    st = Stencil.nearest_neighbor(2)
    L = device_layout(get_mapper("hyperplane"), (8, 8), st, [24, 24, 16])
    c = layout_cost(L, st, [24, 24, 16])
    assert c.j_sum > 0 and len(c.per_node) == 3
