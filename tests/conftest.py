import os
import sys

# Tests must see exactly ONE device (the dry-run alone uses 512 fake ones).
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# make tests/_hypothesis_compat.py importable under any pytest invocation
sys.path.insert(0, os.path.dirname(__file__))

import numpy as np
import pytest


def pytest_configure(config):
    # registered in pytest.ini too; kept here so bare `pytest tests/foo.py`
    # from another rootdir doesn't warn about an unknown marker.
    config.addinivalue_line(
        "markers",
        "slow: multi-minute subprocess/end-to-end tests "
        "(deselected by default; run with -m slow)")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
