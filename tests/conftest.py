import os
import sys

# Tests must see exactly ONE device (the dry-run alone uses 512 fake ones).
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
