"""Beyond-paper features: SWA ring KV cache, byte-weighted mappers,
hierarchical intra-node layout ordering (EXPERIMENTS.md §Perf)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core import CartGrid, Stencil, device_layout, get_mapper, layout_cost
from repro.models import lm


def test_ring_cache_matches_dense_decode_chain():
    """Decode chains through a window-sized ring cache bit-match the dense
    full-length cache once everything older than the window is masked."""
    key = jax.random.PRNGKey(0)
    base = dataclasses.replace(get_arch("mixtral-8x7b").reduced(),
                               capacity_factor=8.0, sliding_window=8)
    ring = dataclasses.replace(base, swa_ring_cache=True)
    params = lm.init(base, key)
    B, S = 2, 24
    toks = jax.random.randint(key, (B, S), 0, base.vocab)

    def chain(cfg, n_pre):
        caches = lm.init_caches(cfg, B, max_len=S + 4)
        lg, caches = lm.prefill(cfg, params,
                                {"inputs": toks[:, :n_pre],
                                 "targets": toks[:, :n_pre]}, caches)
        outs = [lg]
        for i in range(n_pre, S):
            lg, caches = lm.decode_step(cfg, params, toks[:, i], caches,
                                        pos=jnp.asarray(i, jnp.int32))
            outs.append(lg)
        return jnp.stack(outs)

    # prefill longer than the window exercises the ring roll-in too
    dense = chain(base, 12)
    ringo = chain(ring, 12)
    assert float(jnp.max(jnp.abs(dense - ringo))) < 1e-3


def test_ring_cache_allocation_is_window_sized():
    ring = dataclasses.replace(get_arch("mixtral-8x7b").reduced(),
                               sliding_window=8, swa_ring_cache=True)
    caches = lm.init_caches(ring, batch=2, max_len=100)
    seq_dims = {l.shape[2] for l in jax.tree.leaves(caches)
                if hasattr(l, "ndim") and l.ndim == 5}
    assert seq_dims == {8}  # (layers, B, S_alloc, K, hd)


# -- byte-weighted mapping (beyond-paper) ------------------------------------
def test_weighted_mapper_prefers_light_axis_cut():
    """Axis 0 carries 100x the bytes of axis 1: the weighted hyperplane must
    cut across axis 1 (cheap) even though unit-weight Eq.(2) is indifferent."""
    st = Stencil(((1, 0), (-1, 0), (0, 1), (0, -1)),
                 weights=(100.0, 100.0, 1.0, 1.0))
    grid = CartGrid((8, 8))
    sizes = [32, 32]
    jw = get_mapper("hyperplane", weighted=True).cost(grid, st, sizes,
                                                      weighted=True)
    ju = get_mapper("hyperplane").cost(grid, st, sizes, weighted=True)
    assert jw.j_sum <= ju.j_sum
    # weighted cut crosses only light edges: 8 pairs x 2 dir x w=1 = 16
    assert jw.j_sum == 16.0


def test_weighted_kdtree_splits_heavy_axis_last():
    st = Stencil(((1, 0), (-1, 0), (0, 1), (0, -1)),
                 weights=(100.0, 100.0, 1.0, 1.0))
    grid = CartGrid((8, 8))
    jw = get_mapper("kdtree", weighted=True).cost(grid, st, [32, 32],
                                                  weighted=True)
    ju = get_mapper("kdtree").cost(grid, st, [32, 32], weighted=True)
    assert jw.j_sum <= ju.j_sum


# -- hierarchical intra-node order (beyond-paper) -----------------------------
def test_rowmajor_intra_order_preserves_node_assignment():
    st = Stencil.nearest_neighbor(2)
    sizes = [32, 32]
    L1 = device_layout(get_mapper("hyperplane"), (8, 8), st, sizes)
    L2 = device_layout(get_mapper("hyperplane"), (8, 8), st, sizes,
                       intra_order="rowmajor")
    # same J (node assignment unchanged) ...
    assert layout_cost(L1, st, sizes).j_sum == layout_cost(L2, st, sizes).j_sum
    # ... but rowmajor order is monotone within each node
    owner = np.repeat([0, 1], 32)
    for nd in (0, 1):
        chips = L2.reshape(-1)[owner[L2.reshape(-1)] == nd]
        assert list(chips) == sorted(chips)
    assert sorted(L2.reshape(-1).tolist()) == list(range(64))
