"""Runtime: fault-tolerant trainer, straggler monitor, serve loop, data."""
import numpy as np
import pytest

import jax

from repro.configs import get_arch
from repro.configs.base import ShapeSpec
from repro.data.synthetic import DataConfig, global_batches, host_batch
from repro.models import lm
from repro.optim import AdamWConfig
from repro.runtime import (FaultInjector, Request, ServeLoop,
                           StragglerMonitor, Trainer)

SHAPE = ShapeSpec("t", seq_len=32, global_batch=8, kind="train")


def _trainer(tmp_path=None, **kw):
    cfg = get_arch("granite-3-8b").reduced()
    return Trainer(cfg, SHAPE,
                   opt_cfg=AdamWConfig(lr=3e-3, warmup_steps=5,
                                       total_steps=200),
                   data_cfg=DataConfig(mode="memorize", corpus_len=128),
                   ckpt_dir=str(tmp_path) if tmp_path else None, **kw)


def test_loss_decreases(tmp_path):
    res = _trainer(tmp_path).run(25)
    assert res.steps_done == 25
    assert res.final_loss < res.losses[0]


def test_crash_restart_resumes_from_checkpoint(tmp_path):
    tr = _trainer(tmp_path, ckpt_every=10,
                  fault=FaultInjector(schedule={15: "step_crash"}))
    res = tr.run(30)
    assert res.restarts == 1
    assert res.steps_done == 30  # re-ran 10-15 after restore from step 10


def test_node_loss_elastic_remap(tmp_path):
    tr = _trainer(tmp_path, ckpt_every=10,
                  fault=FaultInjector(schedule={12: "node_loss:1"}),
                  num_nodes=2)
    res = tr.run(20)
    assert res.restarts == 1 and res.remaps >= 1
    assert len(tr.alive_nodes) == 1
    assert res.final_loss < res.losses[0]


def test_second_node_loss_warm_repairs(tmp_path):
    """The first loss cold-solves (no previous topology solution); the
    second warm-repairs from it — res.repairs counts only the warm path."""
    cfg = get_arch("granite-3-8b").reduced()
    # batch sharding divides by every intermediate node count (4 -> 3 -> 2)
    shape = ShapeSpec("t", seq_len=32, global_batch=12, kind="train")
    tr = Trainer(cfg, shape,
                 opt_cfg=AdamWConfig(lr=3e-3, warmup_steps=5,
                                     total_steps=200),
                 data_cfg=DataConfig(mode="memorize", corpus_len=128),
                 ckpt_dir=str(tmp_path), ckpt_every=5,
                 fault=FaultInjector(schedule={6: "node_loss:1",
                                               12: "node_loss:2"}),
                 # wall-clock step noise (compiles after each re-mesh) must
                 # not trigger the live straggler path in this test
                 straggler=StragglerMonitor(warn_ratio=1e9, remap_ratio=1e9),
                 num_nodes=4)
    res = tr.run(18)
    assert res.restarts == 2 and res.remaps == 2
    assert res.repairs >= 1
    assert tr.alive_nodes == [0, 3]


def test_straggler_monitor_detects():
    m = StragglerMonitor(patience=2)
    for i in range(10):
        m.record(i, 1.0)
    assert m.record(10, 2.0) == "warn"     # warn-band: streak starts here
    # a severe (>= remap_ratio) step escalates once the streak is >= 2 —
    # warn-band steps accumulate toward remap instead of resetting
    assert m.record(11, 5.0) == "remap"
    assert m.ewma == pytest.approx(1.0, rel=0.3)  # outliers excluded


def test_serve_loop_completes_requests():
    cfg = get_arch("qwen3-8b").reduced()
    params = lm.init(cfg, jax.random.PRNGKey(0))
    loop = ServeLoop(cfg, params, batch_slots=2, max_len=48)
    reqs = [Request(rid=i, prompt=np.arange(4 + i, dtype=np.int32),
                    max_new_tokens=5) for i in range(5)]
    loop.run(reqs)
    assert all(r.done for r in reqs)
    assert all(len(r.out_tokens) == 5 for r in reqs)
    assert all(0 <= t < cfg.vocab for r in reqs for t in r.out_tokens)


# -- data pipeline -----------------------------------------------------------
def test_data_shard_composition_invariant():
    """Global batch content must not depend on how many hosts shard it."""
    cfg = get_arch("qwen3-8b").reduced()
    g1 = next(global_batches(cfg, SHAPE, DataConfig(), num_shards=1))
    g2 = next(global_batches(cfg, SHAPE, DataConfig(), num_shards=4))
    # each shard is generated independently; composition differs across
    # shard counts but *per-shard* data is deterministic:
    b1 = host_batch(cfg, SHAPE, DataConfig(), step=3, shard=2, num_shards=4)
    b2 = host_batch(cfg, SHAPE, DataConfig(), step=3, shard=2, num_shards=4)
    np.testing.assert_array_equal(b1["inputs"], b2["inputs"])
    assert g1["inputs"].shape == g2["inputs"].shape == (8, 32)


def test_data_steps_differ():
    cfg = get_arch("qwen3-8b").reduced()
    b1 = host_batch(cfg, SHAPE, DataConfig(), step=0, shard=0, num_shards=1)
    b2 = host_batch(cfg, SHAPE, DataConfig(), step=1, shard=0, num_shards=1)
    assert not np.array_equal(b1["inputs"], b2["inputs"])


def test_memorize_mode_tokens_in_vocab():
    cfg = get_arch("qwen3-8b").reduced()
    b = host_batch(cfg, SHAPE, DataConfig(mode="memorize"), 0, 0, 1)
    assert b["inputs"].min() >= 0 and b["inputs"].max() < cfg.vocab
