"""Multi-start annealing portfolio engine: bit-exact parity of the batched
K-state deltas with K scalar IncrementalCost tracks, the portfolio-vs-
annealed dominance guarantee (ladder 0 reproduces the scalar annealed
trajectory), early-kill behaviour, the `portfolio[k=8]:` option-parsing
grammar, and weighted="auto" resolution through the refine stack.

Parity assertions use == / array_equal, not isclose: the portfolio path
keeps the same integer crossing counts and the same ascending-offset float
accumulation as the scalar path, so any drift is a bug.
"""
import time

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline: deterministic shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import (CartGrid, IncrementalCost, PortfolioCost,
                        PortfolioRefiner, RefinedMapper, ScheduledRefiner,
                        Stencil, SwapRefiner, available_mappers, evaluate,
                        get_mapper, parse_mapper_options, split_mapper_name)

STENCILS = {
    "nn": Stencil.nearest_neighbor,
    "comp": Stencil.component,
    "hops": Stencil.nn_with_hops,
}


def random_instance(rng, d=None, max_nodes=6):
    d = d or int(rng.integers(1, 4))
    dims = tuple(int(rng.integers(2, 6)) for _ in range(d))
    periodic = tuple(bool(rng.integers(2)) for _ in range(d))
    grid = CartGrid(dims, periodic=periodic)
    n_nodes = int(rng.integers(2, max_nodes + 1))
    node_of_pos = rng.integers(0, n_nodes, size=grid.size)
    return grid, n_nodes, node_of_pos


# ---------------------------------------------------------------------------
# PortfolioCost: batched K-state deltas bit-exact vs K scalar tracks
@given(st.integers(0, 10_000), st.sampled_from(sorted(STENCILS)),
       st.booleans())
@settings(max_examples=40, deadline=None)
def test_portfolio_deltas_bit_exact_vs_scalar(seed, sname, weighted):
    """Each row of swap_deltas equals the scalar delta_swap/peek_per_node
    of an IncrementalCost tracking the same assignment, bit for bit; after
    commits the full state (counts, j_sum, per_node, boundary) stays in
    lock-step with the K scalar tracks."""
    rng = np.random.default_rng(seed)
    grid, n_nodes, _ = random_instance(rng)
    stencil = STENCILS[sname](grid.ndim)
    K = int(rng.integers(1, 5))
    assigns = rng.integers(0, n_nodes, size=(K, grid.size))
    pc = PortfolioCost(grid, stencil, assigns, num_nodes=n_nodes,
                       weighted=weighted)
    ics = [IncrementalCost(grid, stencil, a, num_nodes=n_nodes,
                           weighted=weighted) for a in assigns]
    for _ in range(3):
        rows = np.unique(rng.integers(0, K, size=K))
        P = rng.integers(0, grid.size, size=rows.size)
        Q = rng.integers(0, grid.size, size=rows.size)
        d = pc.swap_deltas(rows, P, Q, with_loads=True, with_counts=True)
        assert d.size == rows.size
        for i, r in enumerate(rows):
            sd = ics[r].delta_swap(int(P[i]), int(Q[i]))
            assert np.array_equal(d.d_count_off[i], sd.d_count_off)
            assert d.d_j_sum[i] == sd.d_j_sum
            peek = ics[r].peek_per_node(sd)
            assert np.array_equal(d.new_per_node[i], peek)
            assert d.new_j_max[i] == peek.max(initial=0.0)
        keep = np.nonzero(rng.random(rows.size) < 0.5)[0]
        pc.commit(d, keep)
        for i in keep:
            ics[rows[i]].apply_swap(int(P[i]), int(Q[i]))
        masks = pc.boundary_masks()
        for r in range(K):
            assert np.array_equal(pc.node[r], ics[r].node_of_pos)
            assert pc.j_sum()[r] == ics[r].j_sum
            assert pc.j_max()[r] == ics[r].j_max
            assert np.array_equal(pc.per_node()[r], ics[r].per_node)
            assert np.array_equal(np.nonzero(masks[r])[0],
                                  ics[r].boundary_positions())
            check = ics[r].cost()
            assert pc.cost(r).j_sum == check.j_sum
            assert pc.cost(r).j_max == check.j_max


def test_portfolio_cost_validates_input():
    grid = CartGrid((4, 4))
    st2 = Stencil.nearest_neighbor(2)
    with pytest.raises(ValueError):
        PortfolioCost(grid, st2, np.zeros(16, dtype=np.int64), num_nodes=2)
    pc = PortfolioCost(grid, st2, np.zeros((3, 16), dtype=np.int64),
                       num_nodes=2)
    with pytest.raises(ValueError):
        pc.swap_deltas([0, 1], [2, 3], [4])          # length mismatch
    with pytest.raises(ValueError):
        pc.swap_deltas([5], [0], [1])                # row out of range
    with pytest.raises(ValueError):
        pc.swap_deltas([0], [0], [99])               # position out of range
    with pytest.raises(ValueError):
        pc.apply_swaps([1, 1], [0, 2], [3, 4])       # duplicate row
    d = pc.swap_deltas([0], [0], [1], with_loads=True, with_counts=False)
    with pytest.raises(ValueError):
        pc.commit(d)                                 # needs with_counts
    empty = pc.swap_deltas(np.empty(0, np.int64), np.empty(0, np.int64),
                           np.empty(0, np.int64), with_loads=True,
                           with_counts=True)
    assert empty.size == 0
    pc.commit(empty)                                 # no-op commit is fine


# ---------------------------------------------------------------------------
# PortfolioRefiner: dominance, determinism, invariants
@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_portfolio_never_worse_than_annealed_same_seed(seed):
    """portfolio: ladder 0 replays the annealed ladder of the same seed
    (same rng draw order, bit-equal energies on unit weights), so the
    portfolio's lexicographic best can never lose to annealed."""
    rng = np.random.default_rng(seed)
    grid, n_nodes, node_of_pos = random_instance(rng, max_nodes=4)
    stencil = Stencil.nearest_neighbor(grid.ndim)
    kwargs = dict(rounds=2, max_passes=3, sa_moves=40)
    ann = ScheduledRefiner(anneal=True, seed=seed, **kwargs).refine(
        grid, stencil, node_of_pos, num_nodes=n_nodes)
    port = PortfolioRefiner(k=3, seed=seed, **kwargs).refine(
        grid, stencil, node_of_pos, num_nodes=n_nodes)
    assert (port.final.j_max, port.final.j_sum) \
        <= (ann.final.j_max, ann.final.j_sum)
    # portfolio is itself a refiner: never worse than its input, preserves
    # the scheduler allocation, and reports exact costs
    assert (port.final.j_max, port.final.j_sum) \
        <= (port.initial.j_max, port.initial.j_sum)
    np.testing.assert_array_equal(
        np.bincount(port.assignment, minlength=n_nodes),
        np.bincount(node_of_pos, minlength=n_nodes))
    check = evaluate(grid, stencil, port.assignment, num_nodes=n_nodes)
    assert check.j_sum == port.final.j_sum
    assert check.j_max == port.final.j_max


def test_portfolio_k1_is_exactly_annealed():
    """With one start the portfolio IS the annealed schedule: same
    assignment, same final cost, bit for bit."""
    rng = np.random.default_rng(7)
    grid = CartGrid((8, 8))
    stencil = Stencil.nn_with_hops(2)
    a = rng.permutation(np.repeat(np.arange(4), 16))
    ann = ScheduledRefiner(anneal=True, seed=3).refine(grid, stencil, a,
                                                       num_nodes=4)
    port = PortfolioRefiner(k=1, seed=3).refine(grid, stencil, a,
                                                num_nodes=4)
    np.testing.assert_array_equal(ann.assignment, port.assignment)
    assert (ann.final.j_sum, ann.final.j_max) \
        == (port.final.j_sum, port.final.j_max)


def test_portfolio_deterministic():
    rng = np.random.default_rng(5)
    grid = CartGrid((8, 8))
    stencil = Stencil.nearest_neighbor(2)
    a = rng.permutation(np.repeat(np.arange(4), 16))
    r1 = PortfolioRefiner(k=4, seed=11).refine(grid, stencil, a, num_nodes=4)
    r2 = PortfolioRefiner(k=4, seed=11).refine(grid, stencil, a, num_nodes=4)
    np.testing.assert_array_equal(r1.assignment, r2.assignment)
    assert r1.stats["ladder_keys"] == r2.stats["ladder_keys"]


def test_portfolio_early_kill_never_kills_ladder_zero():
    """kill_factor=1.0 is maximally aggressive (any start whose best-seen
    J_max trails the leader dies at the next temperature boundary) — the
    dominance guarantee must survive because ladder 0 is exempt."""
    rng = np.random.default_rng(19)
    grid = CartGrid((8, 8))
    stencil = Stencil.nearest_neighbor(2)
    a = rng.permutation(np.repeat(np.arange(8), 8))
    kwargs = dict(rounds=2, max_passes=3, sa_moves=60)
    ann = ScheduledRefiner(anneal=True, seed=2, **kwargs).refine(
        grid, stencil, a, num_nodes=8)
    port = PortfolioRefiner(k=6, seed=2, kill_factor=1.0, **kwargs).refine(
        grid, stencil, a, num_nodes=8)
    assert (port.final.j_max, port.final.j_sum) \
        <= (ann.final.j_max, ann.final.j_sum)
    none = PortfolioRefiner(k=6, seed=2, kill_factor=None, **kwargs).refine(
        grid, stencil, a, num_nodes=8)
    assert none.stats["killed"] == 0
    assert (none.final.j_max, none.final.j_sum) \
        <= (port.final.j_max, port.final.j_sum)  # killing only loses cands
    assert port.stats["polished"] >= 1
    assert port.stats["k"] == 6 and len(port.stats["ladder_keys"]) == 6


def test_portfolio_validates_config():
    with pytest.raises(ValueError):
        PortfolioRefiner(k=0)
    with pytest.raises(ValueError):
        PortfolioRefiner(kill_factor=0.5)
    assert PortfolioRefiner(seeds=[9, 4]).k == 2
    assert PortfolioRefiner(kill_factor=None).kill_factor is None


def test_portfolio_duplicate_seeds_dedupe_warn_and_honest_config():
    """Duplicate explicit seeds replay identical trajectories — they are
    deduped order-preserved with a warning, and config() (the stage layer's
    cache identity) reflects the deduped tuple so two spellings of the same
    effective portfolio share one cache key."""
    with pytest.warns(UserWarning, match="duplicate portfolio seeds"):
        r = PortfolioRefiner(seeds=[3, 3, 5, 3])
    assert r.seeds == (3, 5) and r.k == 2
    assert r.config()["seeds"] == (3, 5)
    assert r.config() == PortfolioRefiner(seeds=[3, 5]).config()
    # the deduped portfolio IS the clean one, bit for bit
    rng = np.random.default_rng(0)
    grid = CartGrid((6, 6))
    stencil = Stencil.nearest_neighbor(2)
    a = rng.permutation(np.repeat(np.arange(3), 12))
    with pytest.warns(UserWarning):
        dup = PortfolioRefiner(seeds=[3, 3, 5], sa_moves=40)
    clean = PortfolioRefiner(seeds=[3, 5], sa_moves=40)
    np.testing.assert_array_equal(
        dup.refine(grid, stencil, a, num_nodes=3).assignment,
        clean.refine(grid, stencil, a, num_nodes=3).assignment)
    # an all-duplicate list still leaves one ladder (never zero starts)
    with pytest.warns(UserWarning):
        assert PortfolioRefiner(seeds=[7, 7]).k == 1


# ---------------------------------------------------------------------------
# registry: portfolio: prefix + bracket-option grammar
def test_portfolio_prefix_resolves_for_every_mapper():
    from repro.core.mapping import MAPPERS
    for name in sorted(MAPPERS):
        m = get_mapper(f"portfolio:{name}")
        assert isinstance(m, RefinedMapper)
        assert isinstance(m.refiner, PortfolioRefiner)
        assert m.name == f"portfolio:{name}"
    assert "portfolio:blocked" in available_mappers()
    with pytest.raises(KeyError):
        get_mapper("portfolio:doesnotexist")


def test_bracket_options_configure_the_refiner():
    m = get_mapper("portfolio[k=3,seed=5]:kdtree")
    assert m.refiner.seeds == (5, 6, 7)
    m = get_mapper("portfolio[k=2,kill_factor=1.25]:blocked")
    assert m.refiner.k == 2 and m.refiner.kill_factor == 1.25
    m = get_mapper("portfolio[kill_factor=none]:blocked")
    assert m.refiner.kill_factor is None
    # bracket options win over call kwargs (the name is the spec)
    m = get_mapper("portfolio[k=3]:blocked", k=6, sa_moves=10)
    assert m.refiner.k == 3 and m.refiner.schedule.sa_moves == 10
    # the grammar covers every refine prefix
    m = get_mapper("annealed[seed=9]:hyperplane")
    assert isinstance(m.refiner, ScheduledRefiner) and m.refiner.seed == 9
    m = get_mapper("refined[policy=steepest]:blocked")
    assert m.refiner.policy == "steepest"
    m = get_mapper("refined2[rounds=2]:blocked")
    assert m.refiner.rounds == 2


def test_mapper_name_parsing_contract():
    assert split_mapper_name("hyperplane") is None
    assert split_mapper_name("portfolio:kdtree") == ("portfolio", {}, "kdtree")
    prefix, opts, base = split_mapper_name("portfolio[k=8,seed=3]:kdtree")
    assert (prefix, base) == ("portfolio", "kdtree")
    assert opts == {"k": 8, "seed": 3}
    assert parse_mapper_options("a=1,b=2.5,c=true,d=x") == {
        "a": 1, "b": 2.5, "c": True, "d": "x"}
    with pytest.raises(ValueError):
        parse_mapper_options("k")            # no '='
    with pytest.raises(ValueError):
        parse_mapper_options("k=1,k=2")      # duplicate key
    with pytest.raises(ValueError):
        get_mapper("portfolio[k]:blocked")


def test_portfolio_mapper_not_worse_than_annealed_on_ragged():
    """The registry-level guarantee on the suite's tiny ragged instance."""
    grid = CartGrid((6, 8))
    stencil = Stencil.nearest_neighbor(2)
    sizes = [16, 16, 10, 6]
    for base in ("random", "kdtree"):
        ann = get_mapper(f"annealed:{base}").cost(grid, stencil, sizes)
        port = get_mapper(f"portfolio[k=3]:{base}").cost(grid, stencil, sizes)
        assert (port.j_max, port.j_sum) <= (ann.j_max, ann.j_sum), base


# ---------------------------------------------------------------------------
# weighted="auto": byte-weighted and unit-weight objectives, one code path
def test_weighted_auto_resolution():
    unit = Stencil.nearest_neighbor(2)
    heavy = Stencil(unit.offsets, (4.0, 4.0, 1.0, 1.0))   # dyadic => exact
    assert not unit.is_weighted and heavy.is_weighted
    grid = CartGrid((6, 6))
    a = np.repeat(np.arange(3), 12)
    assert not IncrementalCost(grid, unit, a, num_nodes=3,
                               weighted="auto").weighted
    assert IncrementalCost(grid, heavy, a, num_nodes=3,
                           weighted="auto").weighted
    assert not IncrementalCost(grid, heavy, a, num_nodes=3,
                               weighted=False).weighted
    w = evaluate(grid, heavy, a, num_nodes=3, weighted="auto")
    assert w.j_sum == evaluate(grid, heavy, a, num_nodes=3,
                               weighted=True).j_sum
    assert w.j_sum != evaluate(grid, heavy, a, num_nodes=3,
                               weighted=False).j_sum


def test_refiners_score_weighted_stencils_in_bytes():
    """With default weighted="auto" every refiner optimizes the byte
    objective on a weighted stencil; the weighted result is never worse in
    bytes than the input and matches a weighted re-evaluation exactly
    (dyadic weights)."""
    rng = np.random.default_rng(3)
    grid = CartGrid((8, 8))
    heavy = Stencil(Stencil.nearest_neighbor(2).offsets,
                    (8.0, 8.0, 1.0, 1.0))
    a = rng.permutation(np.repeat(np.arange(4), 16))
    base = evaluate(grid, heavy, a, num_nodes=4, weighted=True)
    for refiner in (SwapRefiner(max_passes=4),
                    ScheduledRefiner(rounds=2, max_passes=3),
                    PortfolioRefiner(k=2, rounds=2, max_passes=3,
                                     sa_moves=30)):
        res = refiner.refine(grid, heavy, a, num_nodes=4)
        check = evaluate(grid, heavy, res.assignment, num_nodes=4,
                         weighted=True)
        assert res.final.j_sum == check.j_sum
        assert res.final.j_sum < base.j_sum     # bytes actually optimized
        np.testing.assert_array_equal(
            np.bincount(res.assignment, minlength=4),
            np.bincount(a, minlength=4))


# ---------------------------------------------------------------------------
# acceptance: K=8 on the full suite's ragged instances (slow)
@pytest.mark.slow
def test_portfolio_k8_acceptance_on_suite_ragged_rows():
    """portfolio[k=8] is lexicographically <= annealed on every full-suite
    ragged (instance, stencil, mapper) row, at < 8x the annealed wall-time
    wherever the annealed run takes long enough to time (>= 0.2s)."""
    cases = [((16, 28), [256, 192]), ((12, 8, 8), [128] * 5 + [96, 32])]
    for dims, sizes in cases:
        grid = CartGrid(dims)
        for sfn in (Stencil.nearest_neighbor, Stencil.nn_with_hops):
            stencil = sfn(grid.ndim)
            for base in ("random", "kdtree", "hyperplane"):
                a = get_mapper(base).assignment(grid, stencil, sizes)
                t0 = time.perf_counter()
                ann = ScheduledRefiner(anneal=True).refine(
                    grid, stencil, a, num_nodes=len(sizes))
                t_ann = time.perf_counter() - t0
                t0 = time.perf_counter()
                port = PortfolioRefiner(k=8).refine(
                    grid, stencil, a, num_nodes=len(sizes))
                t_port = time.perf_counter() - t0
                assert (port.final.j_max, port.final.j_sum) \
                    <= (ann.final.j_max, ann.final.j_sum), (dims, base)
                if t_ann >= 0.2:
                    assert t_port < 8 * t_ann, (dims, base, t_port, t_ann)
