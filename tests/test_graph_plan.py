"""Plan/cache/facade integration of the graph layer (ISSUE 10).

Covers the ``graph:`` problem flavor (bit-identical labels and costs vs
the grid path, independent cache keys), graph-payload problems (hashing,
caching, serving), base bracket options (`graphgreedy[seed=3]` canonical
keys, composition under refine prefixes), and the `graph_create` facade.
"""
import numpy as np
import pytest

from repro.core import (CommGraph, MappingProblem, PlanCache, Stencil,
                        arch_comm_graph, get_mapper, graph_create,
                        parse_plan)

ST = Stencil.nearest_neighbor(2)
PROB = MappingProblem((4, 4), ST, (4, 4, 4, 4))

SPELLINGS = ["blocked", "graphgreedy", "refined:hyperplane",
             "annealed:kdtree", "portfolio[k=2]:graphgreedy",
             "hier:blocked", "refined2:nodecart",
             "sharded[shards=2,k=2]:stencil_strips"]


# ---------------------------------------------------------------------------
# graph: flavor


@pytest.mark.parametrize("spelling", SPELLINGS)
def test_graph_flavor_bit_identical(spelling):
    s1 = parse_plan(spelling).solve(PROB)
    s2 = parse_plan("graph:" + spelling).solve(PROB)
    assert np.array_equal(s1.assignment, s2.assignment)
    assert (s1.j_max, s1.j_sum) == (s2.j_max, s2.j_sum)


def test_graph_flavor_key_and_cache_independent():
    p1 = parse_plan("annealed:hyperplane")
    p2 = parse_plan("graph:annealed:hyperplane")
    assert p2.key == "graph:" + p1.key
    assert p2.graph_flavor and not p1.graph_flavor
    c = PlanCache(maxsize=16)
    c.solve(PROB, p1), c.solve(PROB, p2)
    assert (c.hits, c.misses) == (0, 2)
    r1, r2 = c.solve(PROB, p1), c.solve(PROB, p2)
    assert (c.hits, c.misses) == (2, 2)
    assert r1.from_cache and r2.from_cache


def test_graph_flavor_parse_errors():
    with pytest.raises(ValueError):
        parse_plan("graph:")
    with pytest.raises(KeyError):
        parse_plan("graph:nosuch")


def test_graph_flavor_has_no_mapper_form():
    with pytest.raises(TypeError):
        parse_plan("graph:annealed:hyperplane").to_mapper()


# ---------------------------------------------------------------------------
# graph-payload problems


def test_provenance_problem_hash_matches_stencil_problem():
    g = CommGraph.from_stencil(PROB.grid(), ST)
    gp = MappingProblem.from_graph(g, (4, 4, 4, 4))
    assert gp.mesh_shape == (4, 4)
    assert gp.content_hash() == PROB.content_hash()
    # so a cache warmed by the stencil problem serves the graph problem
    c = PlanCache(maxsize=16)
    plan = parse_plan("annealed:hyperplane")
    c.solve(PROB, plan)
    assert c.solve(gp, plan).from_cache


def test_pure_graph_problem_solves_and_caches():
    g = arch_comm_graph("granite-3-8b", 32, permute_seed=1)
    prob = MappingProblem.from_graph(g, (4,) * 8)
    assert prob.mesh_shape == (32,)
    plan = parse_plan("graph:annealed:graphgreedy")
    c = PlanCache(maxsize=16)
    s1 = c.solve(prob, plan)
    assert np.array_equal(np.bincount(s1.assignment, minlength=8),
                          np.full(8, 4))
    s2 = c.solve(prob, plan)
    assert s2.from_cache and np.array_equal(s1.assignment, s2.assignment)
    # a different graph is a different problem
    g2 = arch_comm_graph("granite-3-8b", 32, permute_seed=2)
    p2 = MappingProblem.from_graph(g2, (4,) * 8)
    assert p2.content_hash() != prob.content_hash()


def test_graph_size_mismatch_rejected():
    g = arch_comm_graph("granite-3-8b", 32)
    with pytest.raises(ValueError):
        MappingProblem.from_graph(g, (4,) * 4)


def test_graph_problem_through_plan_server():
    from repro.serving import PlanServer
    g = arch_comm_graph("granite-3-8b", 32, permute_seed=1)
    prob = MappingProblem.from_graph(g, (4,) * 8)
    with PlanServer(threads=2).start() as srv:
        t = srv.submit(prob, plan="graph:annealed:graphgreedy")
        sol = t.result(timeout=60)
        assert np.array_equal(np.bincount(sol.assignment, minlength=8),
                              np.full(8, 4))
        t2 = srv.submit(prob, plan="graph:annealed:graphgreedy")
        assert t2.result(timeout=60).from_cache


# ---------------------------------------------------------------------------
# base bracket options (satellite 1)


def test_base_bracket_canonical_key():
    p = parse_plan("graphgreedy[seed=3,max_passes=2]")
    assert p.key == "graphgreedy{max_passes=2,seed=3}"
    assert p.cacheable
    assert p.stages[0].mapper.seed == 3
    assert p.stages[0].mapper.max_passes == 2


def test_base_bracket_composes_under_prefixes():
    p = parse_plan("annealed:graphgreedy[seed=3]")
    assert p.key == "annealed:graphgreedy{seed=3}"
    s = p.solve(PROB)
    assert np.array_equal(np.bincount(s.assignment, minlength=4),
                          np.full(4, 4))
    # equal-config spellings share a cache entry
    c = PlanCache(maxsize=16)
    c.solve(PROB, p)
    assert c.solve(PROB, parse_plan("annealed:graphgreedy[seed=3]")).from_cache


def test_base_bracket_wins_over_kwargs():
    p = parse_plan("graphgreedy[seed=3]", seed=9)
    assert p.stages[0].mapper.seed == 3


def test_base_bracket_through_get_mapper():
    m = get_mapper("graphgreedy[seed=3]")
    assert m.seed == 3
    assert m.plan_key == "graphgreedy{seed=3}"


def test_base_bracket_errors():
    with pytest.raises(KeyError):
        parse_plan("nosuch[seed=3]")
    with pytest.raises(TypeError):
        parse_plan("graphgreedy[bogus_option=3]")
    with pytest.raises(ValueError):
        parse_plan("graphgreedy[seed]")


# ---------------------------------------------------------------------------
# facade


def test_graph_create_facade():
    g = arch_comm_graph("granite-3-8b", 32, permute_seed=1)
    r = graph_create(g, chips_per_pod=4, cache=False)
    assert r.plan_key.startswith("graph:")
    assert r.layout.shape == (32,)
    assert sorted(r.layout.tolist()) == list(range(32))
    # reorder=False is the blocked identity
    r0 = graph_create(g, chips_per_pod=4, reorder=False, cache=False)
    assert np.array_equal(r0.layout, np.arange(32))
    assert (r.j_max, r.j_sum) <= (r0.j_max, r0.j_sum)
    with pytest.raises(ValueError):
        graph_create(g)
    with pytest.raises(ValueError):
        graph_create(g, node_sizes=(4,) * 8, chips_per_pod=4)


def test_graph_create_stencil_provenance_keeps_mesh_shape():
    g = CommGraph.from_stencil(PROB.grid(), ST)
    r = graph_create(g, node_sizes=(4, 4, 4, 4), cache=False)
    assert r.layout.shape == (4, 4)
