"""HLO parser + link simulator unit tests (synthetic HLO snippets)."""
import numpy as np
import pytest

from repro.analysis.hlo import parse_hlo, _parse_groups
from repro.analysis.linksim import simulate
from repro.topology.machine import MachineSpec

HLO = """
HloModule test

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

%body (p: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %p = (s32[], f32[128,256]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[128,256]{1,0} get-tuple-element(%p), index=1
  %ag = f32[128,512]{1,0} all-gather(%x), channel_id=1, replica_groups=[4,2]<=[8], dimensions={1}, use_global_device_ids=true
  %w = f32[512,256]{1,0} constant({...})
  %d = f32[128,256]{1,0} dot(%ag, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[128,256]) tuple(%i2, %d)
}

%cond (p: (s32[], f32[128,256])) -> pred[] {
  %p = (s32[], f32[128,256]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[128,256]) -> f32[] {
  %x = f32[128,256]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %t0 = (s32[], f32[128,256]) tuple(%zero, %x)
  %wh = (s32[], f32[128,256]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  %xf = f32[128,256]{1,0} get-tuple-element(%wh), index=1
  %ar = f32[128,256]{1,0} all-reduce(%xf), channel_id=2, replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
  ROOT %s = f32[] reduce(%ar, %zero), dimensions={0,1}, to_apply=%add
}
"""


def test_parse_collectives_with_trip_counts():
    mod = parse_hlo(HLO)
    colls = {c.name: c for c in mod.collectives()}
    assert colls["ag"].multiplier == 10.0
    assert colls["ag"].opcode == "all-gather"
    # all-gather payload = result / group size = 128*512*4 / 2
    assert colls["ag"].payload_bytes == 128 * 512 * 4 / 2
    assert colls["ag"].groups == [[0, 1], [2, 3], [4, 5], [6, 7]]
    assert colls["ar"].multiplier == 1.0
    assert colls["ar"].groups == [[0, 1, 2, 3], [4, 5, 6, 7]]


def test_dot_flops_loop_corrected():
    mod = parse_hlo(HLO)
    # dot inside while: 2*128*256*512 per iter, 10 iters
    assert mod.dot_flops() == 2 * 128 * 256 * 512 * 10


def test_iota_group_transpose():
    groups = _parse_groups("replica_groups=[2,4]<=[4,2]T(1,0)")
    assert groups == [[0, 2, 4, 6], [1, 3, 5, 7]]


def test_explicit_groups():
    groups = _parse_groups("replica_groups={{0,3},{1,2}}")
    assert groups == [[0, 3], [1, 2]]


# ---------------------------------------------------------------------------
def _mk_stat(opcode, payload, groups, mult=1.0, pairs=None):
    from repro.analysis.hlo import CollectiveStat
    return CollectiveStat(opcode=opcode, name="x", computation="e",
                          payload_bytes=payload, result_bytes=payload,
                          groups=groups, pairs=pairs, multiplier=mult)


def test_linksim_intra_vs_inter_pod():
    m = MachineSpec(num_pods=2, torus=(2, 2))  # 8 chips
    # group entirely in pod 0 -> no DCI
    r = simulate([_mk_stat("all-reduce", 1000.0, [[0, 1, 2, 3]])],
                 np.arange(8), m)
    assert r.dci_total == 0 and r.ici_total > 0
    # group spanning pods -> DCI traffic on exactly 2 ring edges
    r2 = simulate([_mk_stat("all-reduce", 1000.0, [[0, 1, 4, 5]])],
                  np.arange(8), m)
    assert r2.dci_total > 0
    per_edge = 2 * 1000.0 * 3 / 4
    assert r2.dci_total == pytest.approx(2 * per_edge)


def test_linksim_permutation_changes_dci():
    """The point of the paper: the device layout decides DCI traffic."""
    m = MachineSpec(num_pods=2, torus=(2, 2))
    stat = _mk_stat("collective-permute", 100.0, None,
                    pairs=[(i, (i + 1) % 8) for i in range(8)])
    good = np.arange(8)                      # neighbors stay in-pod mostly
    bad = np.array([0, 4, 1, 5, 2, 6, 3, 7])  # alternating pods
    r_good = simulate([stat], good, m)
    r_bad = simulate([stat], bad, m)
    assert r_bad.dci_total > r_good.dci_total


def test_linksim_all_to_all_routes_pairs():
    m = MachineSpec(num_pods=1, torus=(2, 2))
    r = simulate([_mk_stat("all-to-all", 400.0, [[0, 1, 2, 3]])],
                 np.arange(4), m)
    # each ordered pair moves payload/G = 100 bytes; 12 pairs
    assert r.ici_total >= 12 * 100.0
