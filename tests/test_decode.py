"""Decode consistency: teacher-forced forward logits must match the
prefill + decode_step path for every architecture family."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_arch
from repro.models import lm
from repro.models.lm import _encode


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_decode_matches_forward(arch):
    cfg = get_arch(arch).reduced()
    if cfg.n_experts:
        # avoid MoE capacity drops (decode never drops; forward would)
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    key = jax.random.PRNGKey(0)
    params = lm.init(cfg, key)
    B, S = 2, 12
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"inputs": toks, "targets": toks}
    if cfg.family == "encdec":
        batch["src"] = jax.random.normal(key, (B, cfg.src_len, cfg.d_model))
    if cfg.num_patches:
        batch["patches"] = jax.random.normal(key, (B, cfg.num_patches,
                                                   cfg.d_model))
    npz = cfg.num_patches or 0
    logits_full, _, _ = lm.forward(cfg, params, batch)
    caches = lm.init_caches(cfg, B, max_len=S + 8 + npz)
    pre = {k: (v[:, :S - 2] if k in ("inputs", "targets") else v)
           for k, v in batch.items()}
    enc_out = None
    if cfg.family == "encdec":
        enc_out, _ = _encode(cfg, params, batch["src"])
    lg, caches = lm.prefill(cfg, params, pre, caches)
    # vocab-padding mask only applies on the serve path
    ref = jnp.where(jnp.arange(cfg.vocab_padded) < cfg.vocab,
                    logits_full[:, S - 3], -1e30)
    assert float(jnp.max(jnp.abs(lg - ref))) < 5e-3
    lg1, caches = lm.decode_step(cfg, params, toks[:, S - 2], caches,
                                 enc_out=enc_out,
                                 pos=jnp.asarray(S - 2 + npz, jnp.int32))
    ref1 = jnp.where(jnp.arange(cfg.vocab_padded) < cfg.vocab,
                     logits_full[:, S - 2], -1e30)
    assert float(jnp.max(jnp.abs(lg1 - ref1))) < 5e-3


def test_two_step_decode_chain():
    cfg = get_arch("qwen3-8b").reduced()
    key = jax.random.PRNGKey(3)
    params = lm.init(cfg, key)
    B, S = 1, 10
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    logits_full, _, _ = lm.forward(cfg, params,
                                   {"inputs": toks, "targets": toks})
    caches = lm.init_caches(cfg, B, max_len=S + 4)
    lg, caches = lm.prefill(cfg, params, {"inputs": toks[:, :S - 3],
                                          "targets": toks[:, :S - 3]}, caches)
    for i in range(3):
        pos = S - 3 + i
        lg, caches = lm.decode_step(cfg, params, toks[:, pos], caches,
                                    pos=jnp.asarray(pos, jnp.int32))
        err = float(jnp.max(jnp.abs(lg - logits_full[:, pos])))
        assert err < 5e-3, (i, err)
