"""Device-resident portfolio conformance suite.

Draw-for-draw parity between the device engine and the numpy kernel is
impossible (different rng generators), so correctness is pinned as a
contract instead:

* **integer-exact count state** — the device-resident stacked crossing
  counts must equal a from-scratch numpy recount of the device
  assignments after *every* temperature boundary, and the reported
  (J_max, J_sum) keys must match ``evaluate`` on the fetched states
  (dyadic weights, so float32 on-device accumulation is exact);
* **alive-mask monotonicity** — a killed ladder freezes: no accepted
  proposals, state bit-stable across subsequent temperatures;
* **seed determinism** — the device rng stream is a pure function of the
  per-ladder seed: equal seeds reproduce runs exactly, and a ladder's
  trajectory is independent of which other seeds share the batch;
* **pinned dominance** — at equal proposal budget (same K, same
  schedule), the device portfolio's final (J_max, J_sum) is
  lexicographically never worse than ``portfolio[k=K]`` across the
  refine_suite tiny instances (the device's structural edge: per-ladder
  best-seen candidates plus polish over all unique survivors, vs the
  host's top-3);
* **K-scaling** — at equal total proposal budget, K=256 stacked ladders
  run under 4x the wall-time of K=8 (the bench pins the same claim at
  K=1024 in ``results/BENCH_7.json``);
* **delegation** — ``max_swaps``/``pinned`` runs and jax-less
  environments fall back to the single-process host portfolio, so every
  ``device[...]:`` spelling works everywhere.
"""
import copy
import time

import numpy as np
import pytest

jax = pytest.importorskip("jax")

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline: deterministic shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import (CartGrid, DevicePortfolioRefiner, PlanCache,
                        PortfolioRefiner, Stencil, available_mappers,
                        evaluate, get_mapper, parse_plan,
                        stacked_crossing_counts)
from repro.core.plan import MappingProblem
from repro.core.refine.device import DeviceLadderEngine, jax_ready

# the refine_suite --tiny instances (same rows as benchmarks.refine_suite)
TINY = [
    ("2d-8x8-hom", (8, 8), [16] * 4),
    ("2d-6x8-ragged", (6, 8), [16, 16, 10, 6]),
    ("3d-4x4x4-hom", (4, 4, 4), [16] * 4),
]

#: dyadic edge weights: float32 dot products of integer counts are exact,
#: so device keys can be compared to the float64 reference with ==
W_STENCIL = Stencil(((1, 0), (-1, 0), (0, 1), (0, -1)),
                    (2.0, 2.0, 0.5, 0.5), name="ring-dyadic")


def _instance(seed, dims=(6, 7), n_nodes=5):
    grid = CartGrid(dims)
    rng = np.random.default_rng(seed)
    sizes = np.full(n_nodes, grid.size // n_nodes)
    sizes[: grid.size - sizes.sum()] += 1
    return grid, rng.permutation(np.repeat(np.arange(n_nodes), sizes))


def _exact_keys(grid, stencil, nodes, n_nodes):
    """Reference (J_max, J_sum) per row from a numpy recount."""
    co, cn = stacked_crossing_counts(grid, stencil, nodes, n_nodes,
                                     use_jax="numpy")
    w = stencil.weight_array()
    per = (cn.astype(np.float64) * w[None, None, :]).sum(axis=2)
    return per.max(axis=1), (co.astype(np.float64) * w[None, :]).sum(axis=1)


# ---------------------------------------------------------------------------
# invariant: the resident integer count state is exact at every boundary


def test_count_state_integer_exact_after_every_boundary():
    """After each temperature (including one with a spawned restart row),
    the device count state equals a from-scratch numpy recount of the
    fetched assignments — integer ==, no tolerance — and the reported
    keys match ``evaluate`` exactly."""
    grid, start = _instance(3)
    eng = DeviceLadderEngine(grid, W_STENCIL, start, seeds=(0, 1, 2),
                             num_nodes=5, weighted=True, restart_slots=1)
    alive = np.ones(3, dtype=bool)
    rows = eng.rows
    for ti, T in enumerate((2.0, 1.0, 0.5, 0.25)):
        rep = eng.run_temperature(np.full(rows, T), 30, alive,
                                  np.full(rows, 1e-2))
        snap = eng.snapshot()
        co, cn = stacked_crossing_counts(grid, W_STENCIL, snap["nodes"], 5,
                                         use_jax="numpy")
        np.testing.assert_array_equal(cn, eng.counts())
        jm, js = _exact_keys(grid, W_STENCIL, snap["nodes"], 5)
        np.testing.assert_array_equal(rep.j_max, jm)
        np.testing.assert_array_equal(rep.j_sum, js)
        for i in range(3):          # the reference metric agrees row-wise
            c = evaluate(grid, W_STENCIL, snap["nodes"][i], num_nodes=5,
                         weighted=True)
            assert (c.j_max, c.j_sum) == (rep.j_max[i], rep.j_sum[i])
        if ti == 1:                 # mid-run restart spawn, then keep going
            assert eng.spawn_restart(snap["nodes"][0], seed=77) == 0
    assert eng.spawn_restart(start, seed=78) is None    # slots exhausted


@given(seed=st.integers(0, 10**6), k=st.integers(2, 4),
       sa_moves=st.integers(1, 30))
@settings(max_examples=5)
def test_boundary_report_bounds(seed, k, sa_moves):
    """Device boundary reports satisfy the shared engine contract:
    accepted within [0, sa_moves], zero for dead rows, done sticky."""
    grid, start = _instance(seed % 97)
    eng = DeviceLadderEngine(grid, Stencil.nearest_neighbor(2), start,
                             seeds=tuple(range(k)), num_nodes=5)
    alive = np.ones(k, dtype=bool)
    alive[k - 1] = False
    rep = eng.run_temperature(np.full(k, 1.0), sa_moves, alive,
                              np.full(k, 1e-2))
    assert np.all(rep.accepted >= 0) and np.all(rep.accepted <= sa_moves)
    assert rep.accepted[k - 1] == 0
    done1 = rep.done.copy()
    rep2 = eng.run_temperature(np.full(k, 0.5), sa_moves, alive,
                               np.full(k, 1e-2))
    assert np.all(rep2.done >= done1)           # sticky


# ---------------------------------------------------------------------------
# invariant: alive-mask monotonicity (kill == freeze)


def test_killed_ladder_freezes_bit_stable():
    grid, start = _instance(11)
    eng = DeviceLadderEngine(grid, Stencil.nearest_neighbor(2), start,
                             seeds=(4, 5, 6), num_nodes=5)
    alive = np.ones(3, dtype=bool)
    eng.run_temperature(np.full(3, 2.0), 40, alive, np.full(3, 1e-2))
    alive[1] = False                            # kill at the boundary
    frozen = eng.states()[1].copy()
    frozen_cn = eng.counts()[1].copy()
    for T in (1.0, 0.5, 0.25):
        rep = eng.run_temperature(np.full(3, T), 40, alive,
                                  np.full(3, 1e-2))
        assert rep.accepted[1] == 0
        np.testing.assert_array_equal(eng.states()[1], frozen)
        np.testing.assert_array_equal(eng.counts()[1], frozen_cn)


# ---------------------------------------------------------------------------
# invariant: seed-determinism of the device rng stream


def test_seed_determinism_and_batch_independence():
    """Same seeds => identical trajectories; and a ladder's stream depends
    only on its own seed, not on which seeds ride in the batch."""
    grid, start = _instance(21)
    st_ = Stencil.nearest_neighbor(2)
    kw = dict(num_nodes=5)
    e1 = DeviceLadderEngine(grid, st_, start, seeds=(5, 6), **kw)
    e2 = DeviceLadderEngine(grid, st_, start, seeds=(5, 6), **kw)
    e3 = DeviceLadderEngine(grid, st_, start, seeds=(5, 9), **kw)
    alive = np.ones(2, dtype=bool)
    for T in (2.0, 1.0):
        r1 = e1.run_temperature(np.full(2, T), 50, alive, np.full(2, 1e-2))
        r2 = e2.run_temperature(np.full(2, T), 50, alive, np.full(2, 1e-2))
        r3 = e3.run_temperature(np.full(2, T), 50, alive, np.full(2, 1e-2))
        np.testing.assert_array_equal(r1.accepted, r2.accepted)
        np.testing.assert_array_equal(e1.states(), e2.states())
        # row 0 (seed 5) is identical even though row 1's seed changed
        np.testing.assert_array_equal(e1.states()[0], e3.states()[0])
        assert r1.accepted[0] == r3.accepted[0]


def test_refiner_is_deterministic_end_to_end():
    grid, start = _instance(31)
    st_ = Stencil.nearest_neighbor(2)
    r1 = DevicePortfolioRefiner(k=4, sa_moves=40).refine(
        grid, st_, start, num_nodes=5)
    r2 = DevicePortfolioRefiner(k=4, sa_moves=40).refine(
        grid, st_, start, num_nodes=5)
    np.testing.assert_array_equal(r1.assignment, r2.assignment)
    assert (r1.final.j_max, r1.final.j_sum) \
        == (r2.final.j_max, r2.final.j_sum)


# ---------------------------------------------------------------------------
# pinned dominance: never worse than portfolio[k=K] at equal budget


@pytest.mark.parametrize("base", ["hyperplane", "random"])
@pytest.mark.parametrize("label,dims,sizes", TINY)
def test_device_dominates_portfolio_at_equal_budget(label, dims, sizes,
                                                    base):
    """The acceptance claim, on the refine_suite tiny instances: at equal
    proposal budget (same K, same schedule) the device portfolio is
    lexicographically (J_max, J_sum) never worse than ``portfolio[k=K]``.
    The device's edge is structural, not stochastic: 2K candidates
    (end states plus device-tracked per-ladder walk minima) and polish
    over every unique survivor instead of the host's top-3.
    ``benchmarks.refine_suite --device`` machine-checks the same claim
    over the full base-mapper matrix into results/BENCH_7.json."""
    grid = CartGrid(dims)
    stencil = Stencil.nearest_neighbor(len(dims))
    dev = get_mapper(f"device[k=32,sa_moves=40,polish_top=none]:{base}")
    host = get_mapper(f"portfolio[k=32,sa_moves=40]:{base}")
    cd = evaluate(grid, stencil, dev.assignment(grid, stencil, sizes),
                  num_nodes=len(sizes))
    ch = evaluate(grid, stencil, host.assignment(grid, stencil, sizes),
                  num_nodes=len(sizes))
    assert (cd.j_max, cd.j_sum) <= (ch.j_max, ch.j_sum), \
        f"device worse than portfolio on {label}/{base}"


def test_refiner_preserves_sizes_and_never_worsens():
    for label, dims, sizes in TINY:
        grid = CartGrid(dims)
        st_ = Stencil.nearest_neighbor(len(dims))
        rng = np.random.default_rng(7)
        start = rng.permutation(np.repeat(np.arange(len(sizes)), sizes))
        res = DevicePortfolioRefiner(k=4, sa_moves=40).refine(
            grid, st_, start, num_nodes=len(sizes))
        np.testing.assert_array_equal(
            np.bincount(res.assignment, minlength=len(sizes)), sizes)
        assert (res.final.j_max, res.final.j_sum) \
            <= (res.initial.j_max, res.initial.j_sum)
        assert res.stats["backend"].startswith("device[")
        assert res.stats["proposals"] == 4 * 4 * 40     # rows*temps*moves


# ---------------------------------------------------------------------------
# K-scaling: batching amortizes — the accelerator claim at test scale


def test_k_scaling_equal_budget_wall_time():
    """At equal total proposal budget, K=256 stacked ladders cost < 4x the
    wall-time of K=8 (jit warm, min-of-3).  The lock-step vmapped kernel
    makes per-proposal cost roughly K-independent; BENCH_7 pins the same
    measurement at K=1024."""
    grid, start = _instance(5, dims=(8, 8), n_nodes=4)
    st_ = Stencil.nearest_neighbor(2)
    budget = 2560                               # proposals per temperature
    walls = {}
    for K in (8, 256):
        moves = budget // K
        eng = DeviceLadderEngine(grid, st_, start,
                                 seeds=tuple(range(K)), num_nodes=4)
        alive = np.ones(K, dtype=bool)
        temps, eps = np.full(K, 1.0), np.full(K, 1e-2)
        eng.run_temperature(temps, moves, alive, eps)       # compile
        best = np.inf
        for _ in range(3):
            t0 = time.perf_counter()
            eng.run_temperature(temps, moves, alive, eps)
            best = min(best, time.perf_counter() - t0)
        walls[K] = best
    assert walls[256] < 4.0 * walls[8], walls


# ---------------------------------------------------------------------------
# grammar, plan cache, and delegation


def test_device_grammar_plan_key_and_cache():
    assert any(n.startswith("device:") for n in available_mappers())
    plan = parse_plan("device[sa_moves=40,k=4]:hyperplane")
    assert plan.key == "device[k=4,sa_moves=40]:hyperplane"
    assert plan.cacheable
    cache = PlanCache()
    problem = MappingProblem((8, 8), Stencil.nearest_neighbor(2), (16,) * 4)
    s1 = cache.solve(problem, plan)
    s2 = cache.solve(problem, plan)
    assert not s1.from_cache and s2.from_cache
    np.testing.assert_array_equal(s1.assignment, s2.assignment)


def test_budgeted_and_pinned_runs_delegate_to_host():
    """max_swaps and pinned masks are host-kernel semantics (move-level
    coupling); the device refiner must hand them to the single-process
    portfolio rather than approximate them."""
    grid, start = _instance(41)
    st_ = Stencil.nearest_neighbor(2)
    res = DevicePortfolioRefiner(k=3, sa_moves=30, max_swaps=10).refine(
        grid, st_, start, num_nodes=5)
    assert res.stats["delegated"] == "max_swaps"
    assert res.stats["backend"] == "host-fallback"
    assert res.swaps <= 10
    ref = copy.copy(PortfolioRefiner(k=3, sa_moves=30))
    ref.max_swaps = 10
    host = ref.refine(grid, st_, start, num_nodes=5)
    np.testing.assert_array_equal(res.assignment, host.assignment)

    pinned = np.zeros(grid.size, dtype=bool)
    pinned[:10] = True
    res = DevicePortfolioRefiner(k=3, sa_moves=30).refine(
        grid, st_, start, num_nodes=5, pinned=pinned)
    assert res.stats["delegated"] == "pinned"
    np.testing.assert_array_equal(res.assignment[pinned], start[pinned])


def test_jax_ready_probe_is_cached_and_true_here():
    assert jax_ready() is True      # the test image bakes jax in
    assert jax_ready() is True      # second call hits the cache


def test_device_restarts_spawn_from_pool():
    """Kill-heavy instance with adaptive control on: killed ladders fund
    restart rows (static preallocated slots), restart seeds are fresh,
    and the count-state invariant holds at the end."""
    grid = CartGrid((10, 12))
    st_ = Stencil.nn_with_hops(2)
    rng = np.random.default_rng(51)
    start = rng.permutation(np.repeat(np.arange(4), (32, 32, 32, 24)))
    res = DevicePortfolioRefiner(
        k=6, sa_moves=60, kill_factor=1.0, restarts="auto", retune=True,
        rounds=1, max_passes=2,
        temperatures=(4.0, 2.0, 1.0, 0.5, 0.25)).refine(
        grid, st_, start, num_nodes=4)
    assert res.stats["killed"] > 0, "instance no longer kill-heavy"
    assert res.stats["restarted"] > 0
    assert res.stats["restart_slots"] == 6
    assert not set(res.stats["restart_seeds"]) & set(res.stats["seeds"])
    assert (res.final.j_max, res.final.j_sum) \
        <= (res.initial.j_max, res.initial.j_sum)
