"""SSD (mamba2) correctness: chunked scan vs naive sequential recurrence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline: deterministic shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.configs import get_arch
from repro.models.ssm import _ssd_chunked


def naive_ssd(xh, dt, A, Bc, Cc):
    """Sequential reference: h_t = h_{t-1}*exp(dt_t*A) + dt_t*B_t (x) x_t."""
    B, L, H, P = xh.shape
    N = Bc.shape[-1]
    h = np.zeros((B, H, P, N))
    ys = np.zeros((B, L, H, P))
    xh, dt, Bc, Cc = map(np.asarray, (xh, dt, Bc, Cc))
    A = np.asarray(A)
    for t in range(L):
        decay = np.exp(dt[:, t] * A[None, :])            # (B,H)
        dBx = np.einsum("bh,bn,bhp->bhpn", dt[:, t], Bc[:, t], xh[:, t])
        h = h * decay[..., None, None] + dBx
        ys[:, t] = np.einsum("bn,bhpn->bhp", Cc[:, t], h)
    return ys, h


def _rand(key, *shape):
    return jax.random.normal(key, shape, jnp.float32)


@pytest.mark.slow  # 15 random shapes -> 15 XLA compiles (~35 s)
@given(st.integers(1, 2), st.integers(3, 40), st.integers(1, 3),
       st.integers(2, 8), st.integers(2, 8), st.sampled_from([4, 8, 16]))
@settings(max_examples=15, deadline=None)
def test_chunked_matches_naive(B, L, H, P, N, chunk):
    cfg = dataclasses.replace(get_arch("mamba2-130m").reduced(),
                              ssm_chunk=chunk)
    k = jax.random.PRNGKey(B * 1000 + L * 10 + H)
    ks = jax.random.split(k, 5)
    xh = _rand(ks[0], B, L, H, P)
    dt = jax.nn.softplus(_rand(ks[1], B, L, H))
    A = -jnp.exp(_rand(ks[2], H) * 0.5)
    Bc = _rand(ks[3], B, L, N)
    Cc = _rand(ks[4], B, L, N)
    y, hT = _ssd_chunked(cfg, xh, dt, A, Bc, Cc)
    y_ref, h_ref = naive_ssd(xh, dt, A, Bc, Cc)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(hT), h_ref, atol=2e-4, rtol=1e-3)


def test_chunked_matches_naive_quick():
    """Tier-1 stand-in for the slow property: two fixed shapes, one with a
    ragged final chunk, one chunk-aligned."""
    inner = (getattr(test_chunked_matches_naive, "_shim_wrapped", None)
             or getattr(getattr(test_chunked_matches_naive, "hypothesis",
                                None), "inner_test", None))
    assert inner is not None, "expected a @given-wrapped property"
    for B, L, H, P, N, chunk in [(1, 13, 2, 4, 3, 8), (2, 16, 1, 8, 4, 4)]:
        inner(B, L, H, P, N, chunk)


def test_final_state_feeds_decode():
    """Prefill final state == state after naive recurrence, so decode
    continues exactly (already covered end-to-end by test_decode)."""
    cfg = get_arch("mamba2-130m").reduced()
    k = jax.random.PRNGKey(0)
    ks = jax.random.split(k, 5)
    B, L, H, P, N = 1, 20, 2, 4, 4
    xh = _rand(ks[0], B, L, H, P)
    dt = jax.nn.softplus(_rand(ks[1], B, L, H))
    A = -jnp.exp(_rand(ks[2], H) * 0.5)
    Bc = _rand(ks[3], B, L, N)
    Cc = _rand(ks[4], B, L, N)
    _, hT = _ssd_chunked(cfg, xh, dt, A, Bc, Cc)
    _, h_ref = naive_ssd(xh, dt, A, Bc, Cc)
    np.testing.assert_allclose(np.asarray(hT), h_ref, atol=1e-4, rtol=1e-3)
