"""Contract of the hierarchical multilevel mapping stage (``hier:`` —
:mod:`repro.core.refine.hier`).

Pinned invariants:

* grammar — nested per-level solver spellings
  (``hier[levels=rack:portfolio[k=8],pod:annealed]:<base>``) round-trip
  through ``split_mapper_name`` / ``parse_plan`` with a stable canonical
  key, while the pre-existing option-grammar errors
  (``annealed[k]:hyperplane``) stay pinned;
* MaskedGrid — restricted problems are *induced subgraphs*: an edge is
  valid only when both endpoints are active, so inactive positions carry
  zero load and flat refiners run on them unmodified;
* parity — ``parse_plan("hier...").solve`` equals
  ``get_mapper("hier...")`` bit-exactly, and the composed assignment
  always realizes the node sizes, never lexicographically worse than its
  input;
* subtree cache — per-level sub-solutions are individually content-keyed,
  so an elastic re-mesh that churns ONE subtree re-solves only that
  subtree (siblings and the top split are cache hits), and the cache is
  bypassed whenever a stage ``budget=`` caps swaps (replayed counts must
  not evade the cap);
* budgets — ``hier[budget=N]`` obeys the plan layer's accepted-swap
  contract: total reported swaps <= N.
"""
import numpy as np
import pytest

from repro.core import (CartGrid, MappingProblem, Stencil, available_mappers,
                        evaluate, get_mapper, parse_plan)
from repro.core.mapping import split_mapper_name
from repro.core.refine import (HierRefiner, MaskedGrid, RefineStage,
                               hier_subtree_cache)

NESTED = "hier[levels=rack:portfolio[k=8],pod:annealed]:hyperplane"


@pytest.fixture(autouse=True)
def _fresh_subtree_cache():
    hier_subtree_cache().clear()
    yield
    hier_subtree_cache().clear()


# ---------------------------------------------------------------------------
# grammar


def test_split_mapper_name_hier_nested_options():
    prefix, opts, base = split_mapper_name(NESTED)
    assert prefix == "hier"
    assert opts == {"levels": "rack:portfolio[k=8],pod:annealed"}
    assert base == "hyperplane"
    # brackets inside the option value keep the base scan balanced
    prefix, opts, base = split_mapper_name(
        "hier[fanouts=4x2,solver=annealed[sa_moves=50]]:kdtree")
    assert opts == {"fanouts": "4x2", "solver": "annealed[sa_moves=50]"}
    assert base == "kdtree"
    # chaining: hier composes with other prefixes
    prefix, opts, rest = split_mapper_name("hier:annealed:blocked")
    assert (prefix, rest) == ("hier", "annealed:blocked")


def test_hier_spellings_listed_and_keys_canonical():
    assert any(n.startswith("hier") for n in available_mappers())
    assert parse_plan(NESTED).key == NESTED            # already canonical
    assert parse_plan("hier[solver=annealed,depth=2]:blocked").key \
        == "hier[depth=2,solver=annealed]:blocked"     # options sorted
    assert get_mapper(NESTED).plan_key == NESTED
    assert parse_plan(NESTED).cacheable


def test_pinned_option_errors_survive_the_hier_grammar():
    """The continuation rule that lets level-solver spellings ride inside
    option values must not swallow the pinned bad-option errors."""
    with pytest.raises(ValueError, match=r"'annealed\[k\]:hyperplane'"):
        split_mapper_name("annealed[k]:hyperplane")
    with pytest.raises(ValueError, match="expected key=value"):
        split_mapper_name("hier[bare]:blocked")
    assert split_mapper_name("hier[levels=rack:annealed]:blocked") is not None


def test_hier_rejects_bad_trees_and_solvers():
    grid, st_ = CartGrid((4, 4)), Stencil.nearest_neighbor(2)
    a = np.repeat(np.arange(4), 4)
    with pytest.raises(ValueError, match="multiply"):
        HierRefiner(fanouts="3x2").refine(grid, st_, a, num_nodes=4)
    with pytest.raises(ValueError, match="fanouts"):
        HierRefiner(fanouts="2xq").refine(grid, st_, a, num_nodes=4)
    with pytest.raises(ValueError, match="names 1 levels"):
        HierRefiner(fanouts="2x2", levels="only_one").refine(
            grid, st_, a, num_nodes=4)
    with pytest.raises(ValueError, match="cannot nest"):
        HierRefiner(fanouts="2x2", solver="hier").refine(
            grid, st_, a, num_nodes=4)
    with pytest.raises(ValueError, match="refine-prefix chain"):
        HierRefiner(fanouts="2x2", solver="blocked").refine(
            grid, st_, a, num_nodes=4)
    with pytest.raises(ValueError, match="depth"):
        HierRefiner(depth=0)
    with pytest.raises(ValueError, match="polish"):
        HierRefiner(polish=-1)


# ---------------------------------------------------------------------------
# MaskedGrid semantics


def test_masked_grid_is_induced_subgraph():
    base = CartGrid((4, 4))
    active = np.zeros(16, dtype=bool)
    active[[0, 1, 2, 4, 5, 6]] = True                  # a 2x3 corner block
    mg = MaskedGrid(base, active)
    valid, tr = mg.shift_ranks((0, 1))                 # east neighbor
    # inside-to-inside edges survive, anything touching outside is cut
    assert valid[0] and tr[0] == 1
    assert valid[5] and tr[5] == 6
    assert not valid[2]                                # 2 -> 3 leaves mask
    assert not valid[3]                                # source inactive
    full_valid, _ = base.shift_ranks((0, 1))
    assert np.array_equal(valid, full_valid & active & active[tr])
    # geometry is untouched: indices stay global
    assert mg.size == 16 and mg.dims == (4, 4)
    with pytest.raises(ValueError, match="active mask"):
        MaskedGrid(base, np.ones(8, dtype=bool))


def test_masked_grid_inactive_positions_carry_zero_load():
    base = CartGrid((4, 4))
    active = np.zeros(16, dtype=bool)
    active[:8] = True
    mg = MaskedGrid(base, active)
    st_ = Stencil.nearest_neighbor(2)
    # all inactive positions on one ghost label: they contribute nothing
    a = np.where(active, np.arange(16) // 4, 2)        # labels 0,1 + ghost 2
    cost = evaluate(mg, st_, a, num_nodes=3)
    assert cost.per_node[2] == 0.0


# ---------------------------------------------------------------------------
# parity + composition


TINY = [((8, 8), (16,) * 4), ((6, 8), (16, 16, 10, 6)),
        ((4, 4, 4), (16,) * 4)]


@pytest.mark.parametrize("dims,sizes", TINY)
def test_hier_plan_mapper_parity(dims, sizes):
    grid = CartGrid(dims)
    st_ = Stencil.nearest_neighbor(len(dims))
    problem = MappingProblem(dims, st_, sizes)
    for name in ("hier:hyperplane", "hier[solver=refined]:blocked",
                 "hier[fanouts=2x2,polish=16]:kdtree"):
        hier_subtree_cache().clear()
        sol = parse_plan(name).solve(problem)
        hier_subtree_cache().clear()
        via_mapper = get_mapper(name).assignment(grid, st_, list(sizes))
        np.testing.assert_array_equal(sol.assignment, via_mapper,
                                      err_msg=f"{name} on {dims}")
        np.testing.assert_array_equal(
            np.bincount(sol.assignment, minlength=len(sizes)), sizes)


def test_hier_never_worse_and_stats_shape():
    grid, st_ = CartGrid((8, 8)), Stencil.nearest_neighbor(2)
    sizes = [16] * 4
    base = get_mapper("random").assignment(grid, st_, sizes)
    res = HierRefiner(fanouts="2x2").refine(grid, st_, base, num_nodes=4)
    assert (res.final.j_max, res.final.j_sum) \
        <= (res.initial.j_max, res.initial.j_sum)
    s = res.stats
    assert s["solves"] >= 1 and len(s["levels"]) == 2
    assert [l["fanout"] for l in s["levels"]] == [2, 2]
    assert "composed" in s and "polish_swaps" in s


def test_hier_per_level_solvers_apply():
    grid, st_ = CartGrid((8, 8)), Stencil.nearest_neighbor(2)
    sizes = [16] * 4
    base = get_mapper("blocked").assignment(grid, st_, sizes)
    r = HierRefiner(fanouts="2x2", levels="rack:refined,pod:annealed")
    res = r.refine(grid, st_, base, num_nodes=4)
    assert [l["name"] for l in res.stats["levels"]] == ["rack", "pod"]
    assert [l["solver"] for l in res.stats["levels"]] \
        == ["refined", "annealed"]
    np.testing.assert_array_equal(
        np.bincount(res.assignment, minlength=4), sizes)


# ---------------------------------------------------------------------------
# the subtree cache: churn re-solves only the churned subtree


def test_subtree_cache_elastic_churn_resolves_only_churned_subtree():
    """Re-meshing with one subtree's pod sizes permuted ([4,4,3,5] ->
    [4,4,5,3]: group totals unchanged) must hit the cache for the top
    split and the untouched sibling, and re-solve ONLY subtree 1."""
    grid, st_ = CartGrid((4, 4)), Stencil.nearest_neighbor(2)
    r = HierRefiner(fanouts="2x2")
    a1 = get_mapper("blocked").assignment(grid, st_, [4, 4, 3, 5])
    res1 = r.refine(grid, st_, a1, num_nodes=4)
    assert res1.stats["cache_hits"] == 0
    cold_solves = res1.stats["cache_misses"]
    assert cold_solves == res1.stats["solves"] == 3    # top + 2 subtrees

    a2 = get_mapper("blocked").assignment(grid, st_, [4, 4, 5, 3])
    res2 = r.refine(grid, st_, a2, num_nodes=4)
    assert res2.stats["cache_hits"] == 2               # top + subtree 0
    assert res2.stats["cache_misses"] == 1             # only subtree 1
    assert res2.stats["solves"] == 1

    # identical re-mesh: pure hits, zero solves, identical labels
    res3 = r.refine(grid, st_, a1, num_nodes=4)
    assert res3.stats["solves"] == 0
    assert res3.stats["cache_hits"] == 3
    np.testing.assert_array_equal(res3.assignment, res1.assignment)


def test_subtree_cache_disabled_and_content_keyed():
    grid, st_ = CartGrid((4, 4)), Stencil.nearest_neighbor(2)
    a = get_mapper("blocked").assignment(grid, st_, [4] * 4)
    r = HierRefiner(fanouts="2x2", cache=False)
    r.refine(grid, st_, a, num_nodes=4)
    assert hier_subtree_cache().stats()["puts"] == 0
    # stencil weights are part of the key: heavier weights must re-solve
    r2 = HierRefiner(fanouts="2x2")
    r2.refine(grid, st_, a, num_nodes=4)
    heavy = Stencil(st_.offsets, (8.0,) + (1.0,) * (st_.k - 1))
    res = r2.refine(grid, heavy, a, num_nodes=4)
    assert res.stats["cache_hits"] == 0


# ---------------------------------------------------------------------------
# budgets: the stage swap cap holds, and caching never evades it


def test_hier_budget_caps_swaps_and_bypasses_cache():
    dims, sizes = (8, 8), (16,) * 4
    grid, st_ = CartGrid(dims), Stencil.nearest_neighbor(2)
    problem = MappingProblem(dims, st_, sizes)
    # warm the subtree cache with an unbudgeted run of the same config
    parse_plan("hier[fanouts=2x2]:random").solve(problem)
    warm_puts = hier_subtree_cache().stats()["puts"]
    assert warm_puts >= 1
    for budget in (0, 2, 5):
        plan = parse_plan(f"hier[fanouts=2x2,budget={budget}]:random")
        stage = plan.stages[1]
        assert isinstance(stage, RefineStage) and stage.budget == budget
        sol = plan.solve(problem)
        assert sum(s.get("swaps", 0) for s in sol.stage_stats) <= budget
        k_in = parse_plan("random").solve(problem)
        assert (sol.j_max, sol.j_sum) <= (k_in.j_max, k_in.j_sum)
    # budgeted runs neither read nor wrote the subtree cache
    assert hier_subtree_cache().stats()["puts"] == warm_puts
    assert hier_subtree_cache().hits == 0


def test_hier_polish_budget_counts_toward_cap():
    grid, st_ = CartGrid((8, 8)), Stencil.nearest_neighbor(2)
    sizes = [16] * 4
    base = get_mapper("random").assignment(grid, st_, sizes)
    r = HierRefiner(fanouts="2x2", polish=64, max_swaps=4)
    res = r.refine(grid, st_, base, num_nodes=4)
    assert res.swaps <= 4
