"""Closing the loop between the mapping metrics and simulated link
traffic: replaying a mapping's stencil communication through
analysis.linksim must reproduce J_sum / J_max exactly on the DCI counters
(dci_total == J_sum, max_dci_pod == J_max for unit weights — same
directed, source-counted accounting), and therefore rank base vs refined
vs annealed vs portfolio layouts monotonically with their J_max.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline: deterministic shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.analysis.linksim import (machine_for_nodes, replay_assignment,
                                    simulate, stencil_collectives)
from repro.core import CartGrid, Stencil, evaluate, get_mapper
from repro.topology.machine import LevelSpec, V5E_POD

STENCILS = {
    "nn": Stencil.nearest_neighbor,
    "comp": Stencil.component,
    "hops": Stencil.nn_with_hops,
}

# the EXPERIMENTS.md homogeneous grids (tiny + one full-suite instance)
GRIDS = [
    ((8, 8), [16] * 4),
    ((4, 4, 4), [16] * 4),
    ((8, 8, 8), [64] * 8),
]

VARIANTS = ("base", "refined", "annealed", "portfolio[k=3]")


def _mapper_name(variant, base):
    return base if variant == "base" else f"{variant}:{base}"


# ---------------------------------------------------------------------------
# exactness: the simulator's DCI counters ARE the paper metrics
@given(st.integers(0, 10_000), st.sampled_from(sorted(STENCILS)))
@settings(max_examples=25, deadline=None)
def test_replay_dci_equals_cost_metrics(seed, sname):
    """Random homogeneous instances: replaying an arbitrary assignment
    gives dci_total == J_sum and max_dci_pod == J_max exactly."""
    rng = np.random.default_rng(seed)
    n_nodes = int(rng.integers(2, 6))
    per = int(rng.integers(2, 7))
    dims = (n_nodes, per) if rng.integers(2) else (per, n_nodes)
    grid = CartGrid(dims, periodic=(bool(rng.integers(2)),) * 2)
    stencil = STENCILS[sname](2)
    sizes = [grid.size // n_nodes] * n_nodes
    a = rng.permutation(np.repeat(np.arange(n_nodes), sizes[0]))
    cost = evaluate(grid, stencil, a, num_nodes=n_nodes)
    rep = replay_assignment(grid, stencil, a, sizes)
    assert rep.dci_total == cost.j_sum
    assert rep.max_dci_pod() == cost.j_max
    np.testing.assert_array_equal(rep.dci_pod_egress, cost.per_node)


def test_replay_weighted_stencil_counts_bytes():
    grid = CartGrid((6, 6))
    heavy = Stencil(Stencil.nearest_neighbor(2).offsets,
                    (8.0, 8.0, 1.0, 1.0))
    a = np.repeat(np.arange(3), 12)
    cost_w = evaluate(grid, heavy, a, num_nodes=3, weighted=True)
    rep = replay_assignment(grid, heavy, a, [12] * 3)       # weighted=True
    assert rep.dci_total == cost_w.j_sum
    assert rep.max_dci_pod() == cost_w.j_max
    rep_unit = replay_assignment(grid, heavy, a, [12] * 3, weighted=False)
    cost_u = evaluate(grid, heavy, a, num_nodes=3, weighted=False)
    assert rep_unit.dci_total == cost_u.j_sum


def test_stencil_collectives_shape():
    grid = CartGrid((4, 4), periodic=(True, False))
    stencil = Stencil.nearest_neighbor(2)
    colls = stencil_collectives(grid, stencil)
    assert len(colls) == stencil.k
    for c, off in zip(colls, stencil.offsets):
        assert c.opcode == "collective-permute"
        valid, tgt = grid.shift_ranks(off)
        assert len(c.pairs) == int(valid.sum())
        for s, t in c.pairs:
            assert tgt[s] == t
    # replay respects the machine's pod structure: one pod => no DCI
    rep = simulate(colls, np.arange(16), machine_for_nodes([16]))
    assert rep.dci_total == 0.0 and rep.ici_total > 0.0


def test_machine_for_nodes_homogeneous_and_ragged():
    m = machine_for_nodes([8] * 6)
    assert m.num_pods == 6 and m.chips_per_pod == 8
    # ragged allocations get a per-pod-torus machine (elastic pods)
    r = machine_for_nodes([16, 12])
    assert r.num_pods == 2 and r.num_chips == 28
    assert r.node_sizes() == [16, 12]
    assert [r.pod_of(c) for c in (0, 15, 16, 27)] == [0, 0, 1, 1]
    assert r.torus_coord(16) == (0,) and r.torus_coord(27) == (11,)
    # hop path stays inside the pod's own ring (size 12, not 16)
    path = r.torus_hop_path(27, 16)
    assert len(path) == 1 and path[0][2] == +1        # wraps 11 -> 0
    with pytest.raises(ValueError):
        machine_for_nodes([8, 0])


def test_machine_for_nodes_near_square_torus_matches_v5e():
    """Regression: a 256-chip pod must model as V5E_POD's real (16, 16)
    ICI torus, not the pre-fix 1-d 256-ring, and the replay must be
    ICI-identical to the hand-built V5E_POD spec.  An explicit ``torus=``
    still overrides."""
    m = machine_for_nodes([256])
    assert m.torus == (16, 16) == V5E_POD.torus
    grid, stencil = CartGrid((16, 16)), Stencil.nearest_neighbor(2)
    colls = stencil_collectives(grid, stencil)
    layout = np.arange(256)
    auto = simulate(colls, layout, m)
    ref = simulate(colls, layout, V5E_POD)
    assert auto.ici_total == ref.ici_total
    assert auto.max_ici_link() == ref.max_ici_link()
    # the old 1-d model inflated hop counts: the ring walks up to 128
    # hops where the square torus needs at most 16
    ring = simulate(colls, layout, machine_for_nodes([256], torus=(256,)))
    assert ring.ici_total > auto.ici_total
    # factorization corner cases
    assert machine_for_nodes([12] * 2).torus == (4, 3)
    assert machine_for_nodes([7] * 3).torus == (7,)        # prime: 1-d ring
    assert machine_for_nodes([1]).torus == (1,)
    # explicit override must hold the pod exactly
    assert machine_for_nodes([16] * 4, torus=(4, 4)).torus == (4, 4)
    with pytest.raises(ValueError, match="does not hold"):
        machine_for_nodes([16] * 4, torus=(4, 2))
    with pytest.raises(ValueError, match="ragged"):
        machine_for_nodes([16, 12], torus=(4, 4))


def test_replay_per_level_egress_parity():
    """Deep-machine replay: per-level DCI egress at the finest (pod)
    level equals the flat dci_pod_egress exactly (the parity invariant),
    and coarser levels only aggregate — total rack-crossing bytes can
    never exceed total pod-crossing bytes."""
    grid, stencil = CartGrid((8, 8)), Stencil.nearest_neighbor(2)
    sizes = [4] * 16
    levels = (LevelSpec("rack", 4), LevelSpec("pod", 4))
    a = get_mapper("hyperplane").assignment(grid, stencil, sizes)
    rep = replay_assignment(grid, stencil, a, sizes, levels=levels)
    cost = evaluate(grid, stencil, a, num_nodes=16)
    assert rep.dci_total == cost.j_sum
    assert rep.max_dci_pod() == cost.j_max
    np.testing.assert_array_equal(rep.level_egress["pod"],
                                  rep.dci_pod_egress)
    assert rep.max_level_egress("pod") == rep.max_dci_pod()
    assert rep.level_egress["rack"].shape == (4,)
    assert rep.level_egress["rack"].sum() <= rep.dci_total
    # rack egress is exactly the cross-rack slice of the pair traffic
    rack_of = {p: p // 4 for p in range(16)}
    cross_rack = sum(b for (pa, pb), b in rep.dci_pair_bytes.items()
                     if rack_of[pa] != rack_of[pb])
    assert rep.level_egress["rack"].sum() == cross_rack
    # a flat machine reports no level counters
    flat = replay_assignment(grid, stencil, a, sizes)
    assert flat.level_egress == {}


@given(st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_replay_dci_equals_cost_metrics_ragged(seed):
    """Ragged (elastic) allocations close the same loop: per-pod torus
    sizes, dci_total == J_sum and max_dci_pod == J_max exactly."""
    rng = np.random.default_rng(seed)
    n_nodes = int(rng.integers(2, 6))
    sizes = [int(rng.integers(2, 9)) for _ in range(n_nodes)]
    total = sum(sizes)
    dims = (total,) if rng.integers(2) else (2, -(-total // 2))
    if int(np.prod(dims)) != total:       # odd total: keep it 1-d
        dims = (total,)
    grid = CartGrid(dims)
    stencil = Stencil.nearest_neighbor(len(dims))
    a = rng.permutation(np.repeat(np.arange(n_nodes), sizes))
    cost = evaluate(grid, stencil, a, num_nodes=n_nodes)
    rep = replay_assignment(grid, stencil, a, sizes)
    assert rep.dci_total == cost.j_sum
    assert rep.max_dci_pod() == cost.j_max
    np.testing.assert_array_equal(rep.dci_pod_egress, cost.per_node)


# ---------------------------------------------------------------------------
# the loop-closer: simulated DCI bottleneck is monotone in J_max across
# base -> refined -> annealed -> portfolio on the EXPERIMENTS grids
@pytest.mark.parametrize("dims,sizes", GRIDS[:2])
@pytest.mark.parametrize("sname", sorted(STENCILS))
def test_replay_monotone_with_jmax_rank(dims, sizes, sname):
    grid = CartGrid(dims)
    stencil = STENCILS[sname](grid.ndim)
    rows = []
    for base in ("random", "hyperplane"):
        per_variant = {}
        for variant in VARIANTS:
            a = get_mapper(_mapper_name(variant, base)).assignment(
                grid, stencil, sizes)
            cost = evaluate(grid, stencil, a, num_nodes=len(sizes))
            rep = replay_assignment(grid, stencil, a, sizes)
            assert rep.max_dci_pod() == cost.j_max     # exact, per variant
            per_variant[variant] = (cost.j_max, rep.max_dci_pod())
        rows.append((base, per_variant))
    for base, per_variant in rows:
        ranked = sorted(per_variant.values())
        dci = [d for _, d in ranked]
        assert dci == sorted(dci), (base, per_variant)  # monotone with rank
        # and the refinement chain never increases the simulated bottleneck
        assert per_variant["portfolio[k=3]"][1] <= per_variant["base"][1]
        assert per_variant["annealed"][1] <= per_variant["base"][1]


@pytest.mark.slow
def test_replay_monotone_full_grid():
    """The full-suite 8x8x8 instance (slower: portfolio on 512 cells)."""
    dims, sizes = GRIDS[2]
    grid = CartGrid(dims)
    stencil = Stencil.nearest_neighbor(3)
    dci = {}
    for variant in VARIANTS:
        a = get_mapper(_mapper_name(variant, "random")).assignment(
            grid, stencil, sizes)
        cost = evaluate(grid, stencil, a, num_nodes=len(sizes))
        rep = replay_assignment(grid, stencil, a, sizes)
        assert rep.max_dci_pod() == cost.j_max
        dci[variant] = (cost.j_max, rep.max_dci_pod())
    assert dci["portfolio[k=3]"][1] <= dci["annealed"][1] <= dci["base"][1]
