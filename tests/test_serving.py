"""Serving layer: persistent shard workers + the resident plan server.

Pinned invariants:

* **bit-identity** — the resident persistent-worker engine
  (:class:`~repro.serving.ResidentShardedRefiner`) returns exactly the
  stateless ``sharded[...]`` engine's assignment and ladder keys at equal
  config (the property that lets the server cache resident results under
  the unchanged plan key), including with restarts/retune on;
* **pool lifecycle** — worker processes all join on close (no orphans),
  close is idempotent, a crashed pool degrades to the stateless fallback
  with the identical result;
* **server protocol** — submits are admission-bounded
  (:class:`~repro.serving.AdmissionError` when the queue is full), warm
  repeats are cache hits, ``invalidate`` forces recompute, concurrent
  submits all complete with consistent counters;
* **anytime** — a deadlined request always resolves to a *valid*
  assignment (scheduler cardinalities realized); uncut anytime reruns are
  deterministic; deadline-cut results never enter the cache;
* **repair routing** — ``remap.repair_layout(server=...)`` returns the
  same solution as the direct call, through the server's queue.
"""
import multiprocessing
import threading
import time

import numpy as np
import pytest

from repro.core import CartGrid, Stencil, evaluate, get_mapper, parse_plan
from repro.core.plan import MappingProblem, PlanCache
from repro.core.refine.sharded import ShardedPortfolioRefiner
from repro.serving import (AdmissionError, PlanClient, PlanServer,
                           ResidentShardedRefiner, ShardWorkerPool,
                           register_topology)

DIMS, SIZES = (6, 8), (16, 16, 10, 6)
PLAN = "sharded[shards=2,k=4,restarts=auto]:hyperplane"


def _instance():
    grid = CartGrid(DIMS)
    stencil = Stencil.nearest_neighbor(2)
    start = get_mapper("hyperplane").assignment(grid, stencil, list(SIZES))
    return grid, stencil, start


def _assert_valid(assignment, sizes=SIZES):
    np.testing.assert_array_equal(
        np.sort(np.bincount(np.asarray(assignment), minlength=len(sizes))),
        np.sort(np.asarray(sizes)))


# ---------------------------------------------------------------------------
# resident engine: bit-identity + pool lifecycle


@pytest.mark.parametrize("kw", [
    dict(shards=2, k=4, restarts="auto"),
    dict(shards=3, k=8, restarts="auto", retune=True),
])
def test_resident_bit_identical_to_stateless(kw):
    grid, stencil, start = _instance()
    kw = dict(kw, seed=7, rounds=1, max_passes=2, sa_moves=40)
    want = ShardedPortfolioRefiner(backend="serial", **kw).refine(
        grid, stencil, start.copy(), num_nodes=len(SIZES))
    with ResidentShardedRefiner(backend="serial", **kw) as resident:
        got = resident.refine(grid, stencil, start.copy(),
                              num_nodes=len(SIZES))
    np.testing.assert_array_equal(got.assignment, want.assignment)
    assert got.stats["ladder_keys"] == want.stats["ladder_keys"]
    assert (got.final.j_max, got.final.j_sum) \
        == (want.final.j_max, want.final.j_sum)
    assert got.stats["ipc"]["step_bytes"] > 0


def test_worker_pool_lifecycle_no_orphans():
    before = set(p.pid for p in multiprocessing.active_children())
    pool = ShardWorkerPool(workers=2)
    assert pool.alive and pool.workers == 2
    pids = pool.broadcast(("ping",))
    assert sorted(pids) == sorted(p.pid for p in pool._procs)
    pool.close()
    pool.close()                               # idempotent
    assert not pool.alive
    after = set(p.pid for p in multiprocessing.active_children())
    assert after <= before


def test_crashed_pool_falls_back_to_stateless():
    """Workers dying mid-run must degrade to the stateless engine with the
    bit-identical result (and without wedging the coordinator)."""
    grid, stencil, start = _instance()
    kw = dict(shards=2, k=4, seed=3, rounds=1, max_passes=2, sa_moves=40)
    want = ShardedPortfolioRefiner(backend="serial", **kw).refine(
        grid, stencil, start.copy(), num_nodes=len(SIZES))
    pool = ShardWorkerPool(workers=2)
    orig_rm = pool.request_many

    def sabotage(msgs):
        # kill every worker the moment the first temperature dispatches:
        # the ("crash",) hook os._exit()s the children, so the pending
        # recv raises WorkerPoolError mid-run
        if msgs and msgs[0][1][0] == "step":
            pool.request_many = orig_rm
            pool.broadcast(("crash",))
        return orig_rm(msgs)

    pool.request_many = sabotage
    refiner = ResidentShardedRefiner(pool=pool, backend="serial", **kw)
    got = refiner.refine(grid, stencil, start.copy(), num_nodes=len(SIZES))
    np.testing.assert_array_equal(got.assignment, want.assignment)
    assert got.stats["ladder_keys"] == want.stats["ladder_keys"]
    assert got.stats["backend"] == "resident-fallback"
    pool.close()


def test_dead_pool_self_heals_before_run():
    """A pool found dead *before* the run is replaced with a fresh owned
    pool (self-healing), keeping the resident path — not the fallback."""
    grid, stencil, start = _instance()
    kw = dict(shards=2, k=4, seed=3, rounds=1, max_passes=2, sa_moves=40)
    want = ShardedPortfolioRefiner(backend="serial", **kw).refine(
        grid, stencil, start.copy(), num_nodes=len(SIZES))
    dead = ShardWorkerPool(workers=2)
    dead.close()
    refiner = ResidentShardedRefiner(pool=dead, backend="serial", **kw)
    got = refiner.refine(grid, stencil, start.copy(), num_nodes=len(SIZES))
    np.testing.assert_array_equal(got.assignment, want.assignment)
    assert got.stats["backend"] == "resident"
    refiner.close()


# ---------------------------------------------------------------------------
# the server


def test_server_serves_bit_identical_and_warm_hits():
    problem = MappingProblem(DIMS, Stencil.nearest_neighbor(2), SIZES)
    want = parse_plan(PLAN).solve(problem)
    with PlanServer(threads=1, shard_workers=2) as srv:
        cold = srv.submit(problem, plan=PLAN).result(timeout=300)
        assert not cold.from_cache
        np.testing.assert_array_equal(cold.assignment, want.assignment)
        assert (cold.j_max, cold.j_sum) == (want.j_max, want.j_sum)
        warm = srv.submit(problem, plan=PLAN).result(timeout=60)
        assert warm.from_cache
        np.testing.assert_array_equal(warm.assignment, want.assignment)
        # invalidate forces a recompute to the same answer
        assert srv.invalidate(problem) == 1
        again = srv.submit(problem, plan=PLAN).result(timeout=300)
        assert not again.from_cache
        np.testing.assert_array_equal(again.assignment, want.assignment)
        st = srv.stats()
        assert st["completed"] == 3 and st["errors"] == 0
        assert "latency_p50_ms" in st


def test_server_bounded_admission_rejects_when_full():
    srv = PlanServer(threads=1, shard_workers=1, max_queue=1)
    gate = threading.Event()
    orig = srv._solve

    def gated(*args, **kwargs):
        gate.wait(timeout=60)
        return orig(*args, **kwargs)

    srv._solve = gated
    problem = MappingProblem(DIMS, Stencil.nearest_neighbor(2), SIZES)
    with srv:
        t1 = srv.submit(problem, plan="blocked")
        deadline = time.perf_counter() + 10
        while srv.inflight == 0 and time.perf_counter() < deadline:
            time.sleep(0.005)                   # t1 now held by the gate
        t2 = srv.submit(problem, plan="blocked")    # fills the queue
        with pytest.raises(AdmissionError):
            srv.submit(problem, plan="blocked")
        assert srv.stats()["rejected"] == 1
        gate.set()
        assert t1.result(timeout=60) is not None
        assert t2.result(timeout=60) is not None
    with pytest.raises(AdmissionError):         # stopped server rejects
        srv.submit(problem, plan="blocked")


def test_server_concurrent_submits_all_complete():
    with PlanServer(threads=2, shard_workers=1, max_queue=64) as srv:
        cli = PlanClient(srv)
        tickets = [
            cli.cart_create_async(DIMS, node_sizes=SIZES,
                                  plan="refined:hyperplane")
            for _ in range(12)
        ]
        results = [t.result(timeout=300) for t in tickets]
        for r in results:
            np.testing.assert_array_equal(r.layout, results[0].layout)
        st = srv.stats()
        assert st["completed"] == 12 and st["errors"] == 0
        assert st["queue_depth"] == 0 and st["inflight"] == 0
        # at most one cold solve per solver thread can race the first
        # miss (no single-flight dedup); everything else is a cache hit
        assert sum(1 for r in results if r.from_cache) >= 12 - srv.threads


def test_server_error_requests_surface_to_ticket():
    with PlanServer(threads=1) as srv:
        t = srv.submit(mesh_shape=(4, 4), node_sizes=(8, 8),
                       plan="no-such-plan")
        with pytest.raises(KeyError):
            t.result(timeout=60)
        assert srv.stats()["errors"] == 1


def test_server_warm_up_registry():
    name = "test-serving-tiny"
    register_topology(name, lambda: MappingProblem(
        (4, 4), Stencil.nearest_neighbor(2), (4, 4, 4, 4)))
    with PlanServer(threads=1, default_plan="refined:hyperplane") as srv:
        first = srv.warm_up(names=[name])
        assert first == {"swept": 1, "already_cached": 0}
        second = srv.warm_up(names=[name])
        assert second == {"swept": 1, "already_cached": 1}
        t = srv.submit(mesh_shape=(4, 4), node_sizes=(4, 4, 4, 4))
        assert t.result(timeout=60).from_cache
        assert srv.stats()["warmed"] == 2


# ---------------------------------------------------------------------------
# anytime


def test_server_anytime_valid_and_deterministic_uncut():
    problem = MappingProblem(DIMS, Stencil.nearest_neighbor(2), SIZES)
    with PlanServer(threads=1, shard_workers=2) as srv:
        # generous deadline: run completes uncut, result is deterministic
        a1 = srv.submit(problem, plan=PLAN, deadline_ms=300_000)
        r1 = a1.result(timeout=300)
        _assert_valid(r1.assignment)
        assert not a1.anytime_cut
        a2 = srv.submit(problem, plan=PLAN, deadline_ms=300_000)
        r2 = a2.result(timeout=300)
        np.testing.assert_array_equal(r2.assignment, r1.assignment)
        assert r2.from_cache                   # uncut -> @anytime cached
        # near-zero deadline: still a valid plan, flagged cut, not cached
        srv.cache.clear()
        a3 = srv.submit(problem, plan=PLAN, deadline_ms=1)
        r3 = a3.result(timeout=300)
        _assert_valid(r3.assignment)
        assert a3.anytime_cut
        assert srv.stats()["anytime_cuts"] == 1
        a4 = srv.submit(problem, plan=PLAN, deadline_ms=1)
        assert not a4.result(timeout=300).from_cache
        cost = evaluate(CartGrid(DIMS), problem.stencil, r3.assignment,
                        num_nodes=len(SIZES))
        assert (cost.j_max, cost.j_sum) == (r3.j_max, r3.j_sum)


def test_anytime_never_worse_than_start():
    """The deadline-cut result must always dominate the start candidate
    (consider() keeps the lexicographic best seen)."""
    grid, stencil, start = _instance()
    base = evaluate(grid, stencil, start, num_nodes=len(SIZES))
    kw = dict(shards=2, k=4, seed=11, rounds=1, max_passes=2, sa_moves=40)
    for deadline in (0.0, 0.05):
        with ResidentShardedRefiner(backend="serial", **kw) as r:
            res = r.refine_anytime(grid, stencil, start.copy(),
                                   num_nodes=len(SIZES),
                                   deadline_s=deadline)
        _assert_valid(res.assignment)
        assert (res.final.j_max, res.final.j_sum) \
            <= (base.j_max, base.j_sum)
        assert res.stats["polished"] == 0


# ---------------------------------------------------------------------------
# repair routing


def test_repair_routes_through_server():
    from repro.core.remap import repair_layout
    problem = MappingProblem((8, 8), Stencil.nearest_neighbor(2),
                             (16,) * 4)
    prev = parse_plan("refined:hyperplane").solve(problem)
    survivors = (16, 16, 22, 10)
    direct = repair_layout(prev, survivors, cache=False)
    with PlanServer(threads=1) as srv:
        served = repair_layout(prev, survivors, server=srv)
        np.testing.assert_array_equal(served.assignment, direct.assignment)
        assert (served.j_max, served.j_sum) == (direct.j_max, direct.j_sum)
        assert srv.stats()["completed"] == 1
        with pytest.raises(ValueError):
            repair_layout(prev, survivors, server=srv, cache=PlanCache())
