"""Batched refinement engine: bit-exact parity of batch_swap_deltas with the
scalar delta path, ScheduledRefiner schedule invariants, the refined2:/
annealed: registry spellings, elastic auto-refinement in
mapped_device_array, and a wall-time guard pinning the batch engine's
speedup over the PR-1 scalar loop.

Parity assertions use == / array_equal, not isclose: the batch path
accumulates the same integer crossing counts in the same offset order as
the scalar path, so any drift is a bug.
"""
import time

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline: deterministic shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import (CartGrid, IncrementalCost, MapperInapplicable,
                        RefinedMapper, ScheduledRefiner, Stencil, SwapRefiner,
                        available_mappers, evaluate, get_mapper, layout_cost,
                        mapped_device_array)
from repro.core.mapping import MAPPERS
from repro.core.remap import ensure_refined

STENCILS = {
    "nn": Stencil.nearest_neighbor,
    "comp": Stencil.component,
    "hops": Stencil.nn_with_hops,
}


def random_instance(rng, d=None, max_nodes=6):
    d = d or int(rng.integers(1, 4))
    dims = tuple(int(rng.integers(2, 6)) for _ in range(d))
    periodic = tuple(bool(rng.integers(2)) for _ in range(d))
    grid = CartGrid(dims, periodic=periodic)
    n_nodes = int(rng.integers(2, max_nodes + 1))
    node_of_pos = rng.integers(0, n_nodes, size=grid.size)
    return grid, n_nodes, node_of_pos


# ---------------------------------------------------------------------------
# batch_swap_deltas parity with the scalar path
@given(st.integers(0, 10_000), st.sampled_from(sorted(STENCILS)),
       st.booleans())
@settings(max_examples=60, deadline=None)
def test_batch_deltas_bit_exact_vs_scalar(seed, sname, weighted):
    """Random grids/stencils/assignments: every batched row equals the
    scalar delta_swap / peek_per_node result bit-for-bit."""
    rng = np.random.default_rng(seed)
    grid, n_nodes, node_of_pos = random_instance(rng)
    stencil = STENCILS[sname](grid.ndim)
    ic = IncrementalCost(grid, stencil, node_of_pos, num_nodes=n_nodes,
                         weighted=weighted)
    m = int(rng.integers(1, 32))
    P = rng.integers(0, grid.size, size=m)
    Q = rng.integers(0, grid.size, size=m)
    bd = ic.batch_swap_deltas(P, Q, with_loads=True)
    assert bd.size == m
    for i in range(m):
        d = ic.delta_swap(int(P[i]), int(Q[i]))
        assert np.array_equal(bd.d_count_off[i], d.d_count_off)
        assert bd.d_j_sum[i] == d.d_j_sum
        peek = ic.peek_per_node(d)
        assert np.array_equal(bd.new_per_node[i], peek)
        assert bd.new_j_max[i] == peek.max(initial=0.0)


def test_batch_deltas_validates_input():
    grid = CartGrid((4, 4))
    ic = IncrementalCost(grid, Stencil.nearest_neighbor(2),
                         np.zeros(16, dtype=np.int64), num_nodes=2)
    with pytest.raises(ValueError):
        ic.batch_swap_deltas([0, 1], [2])
    with pytest.raises(ValueError):
        ic.batch_swap_deltas([0], [99])
    bd = ic.batch_swap_deltas(np.empty(0, dtype=np.int64),
                              np.empty(0, dtype=np.int64), with_loads=True)
    assert bd.size == 0 and bd.new_j_max.shape == (0,)


# ---------------------------------------------------------------------------
# batch SwapRefiner engine invariants
@given(st.integers(0, 10_000), st.sampled_from(["j_sum", "j_max"]),
       st.sampled_from(["first", "steepest"]))
@settings(max_examples=25, deadline=None)
def test_batch_refiner_monotonic_and_cardinality_preserving(seed, objective,
                                                            policy):
    rng = np.random.default_rng(seed)
    grid, n_nodes, node_of_pos = random_instance(rng, max_nodes=4)
    stencil = Stencil.nearest_neighbor(grid.ndim)
    refiner = SwapRefiner(objective=objective, policy=policy, max_passes=3,
                          engine="batch")
    res = refiner.refine(grid, stencil, node_of_pos, num_nodes=n_nodes)
    if objective == "j_max":
        assert (res.final.j_max, res.final.j_sum) \
            <= (res.initial.j_max, res.initial.j_sum)
    else:
        assert res.final.j_sum <= res.initial.j_sum
    np.testing.assert_array_equal(
        np.bincount(res.assignment, minlength=n_nodes),
        np.bincount(node_of_pos, minlength=n_nodes))
    check = evaluate(grid, stencil, res.assignment, num_nodes=n_nodes)
    assert check.j_sum == res.final.j_sum
    assert check.j_max == res.final.j_max


def test_batch_refiner_matches_scalar_quality():
    """Both engines run the same search; on a converged run the batch
    engine must reach a J_sum no worse than the scalar reference."""
    rng = np.random.default_rng(11)
    grid = CartGrid((10, 10))
    stencil = Stencil.nearest_neighbor(2)
    a = rng.permutation(np.repeat(np.arange(5), 20))
    js = {}
    for eng in ("scalar", "batch"):
        res = SwapRefiner(engine=eng, max_passes=20).refine(
            grid, stencil, a, num_nodes=5)
        js[eng] = res.final.j_sum
    assert js["batch"] <= js["scalar"]


def test_batch_refiner_rejects_bad_engine():
    with pytest.raises(ValueError):
        SwapRefiner(engine="gpu")


# ---------------------------------------------------------------------------
# ScheduledRefiner invariants
@given(st.integers(0, 10_000), st.booleans())
@settings(max_examples=15, deadline=None)
def test_scheduled_never_worsens_lexicographically(seed, anneal):
    """(J_max, J_sum) of the returned assignment is lexicographically no
    worse than the input's — the schedule considers the input a candidate."""
    rng = np.random.default_rng(seed)
    grid, n_nodes, node_of_pos = random_instance(rng, max_nodes=4)
    stencil = Stencil.nearest_neighbor(grid.ndim)
    ref = ScheduledRefiner(rounds=2, max_passes=3, anneal=anneal,
                           sa_moves=40, seed=seed)
    res = ref.refine(grid, stencil, node_of_pos, num_nodes=n_nodes)
    assert (res.final.j_max, res.final.j_sum) \
        <= (res.initial.j_max, res.initial.j_sum)
    np.testing.assert_array_equal(
        np.bincount(res.assignment, minlength=n_nodes),
        np.bincount(node_of_pos, minlength=n_nodes))
    check = evaluate(grid, stencil, res.assignment, num_nodes=n_nodes)
    assert check.j_sum == res.final.j_sum
    assert check.j_max == res.final.j_max


def test_scheduled_jmax_no_worse_than_plain_refined():
    """The schedule's first phase IS the default refined: pass, so its
    selected result can never exceed refined:'s J_max (acceptance
    criterion, checked here on the ragged-pod suite instances)."""
    cases = [((16, 28), [256, 192]), ((6, 8), [16, 16, 10, 6]),
             ((12, 8, 8), [128] * 5 + [96, 32])]
    for dims, sizes in cases:
        grid = CartGrid(dims)
        stencil = Stencil.nearest_neighbor(grid.ndim)
        for base in ("hyperplane", "kdtree", "random"):
            plain = get_mapper(f"refined:{base}").cost(grid, stencil, sizes)
            sched = get_mapper(f"refined2:{base}").cost(grid, stencil, sizes)
            ann = get_mapper(f"annealed:{base}").cost(grid, stencil, sizes)
            assert sched.j_max <= plain.j_max, (dims, base)
            assert ann.j_max <= plain.j_max, (dims, base)


def test_scheduled_deterministic():
    rng = np.random.default_rng(5)
    grid = CartGrid((8, 8))
    stencil = Stencil.nn_with_hops(2)
    a = rng.permutation(np.repeat(np.arange(4), 16))
    r1 = ScheduledRefiner(anneal=True, seed=3).refine(grid, stencil, a,
                                                      num_nodes=4)
    r2 = ScheduledRefiner(anneal=True, seed=3).refine(grid, stencil, a,
                                                      num_nodes=4)
    np.testing.assert_array_equal(r1.assignment, r2.assignment)
    assert (r1.final.j_sum, r1.final.j_max) == (r2.final.j_sum, r2.final.j_max)


def test_scheduled_validates_config():
    with pytest.raises(ValueError):
        ScheduledRefiner(objectives=())
    with pytest.raises(ValueError):
        ScheduledRefiner(rounds=-1)
    # rounds=0 is valid: skip the deterministic rounds, ladder/polish only
    # (the repair warm path's pinned portfolio uses it)
    assert ScheduledRefiner(rounds=0).rounds == 0
    with pytest.raises(ValueError):
        ScheduledRefiner(objectives=("nope",))


# ---------------------------------------------------------------------------
# registry spellings
def test_new_prefixes_resolve_for_every_mapper():
    for name in sorted(MAPPERS):
        for prefix in ("refined2", "annealed"):
            m = get_mapper(f"{prefix}:{name}")
            assert isinstance(m, RefinedMapper)
            assert isinstance(m.refiner, ScheduledRefiner)
            assert m.name == f"{prefix}:{name}"
        assert get_mapper(f"annealed:{name}").refiner.anneal
        assert not get_mapper(f"refined2:{name}").refiner.anneal
    listed = available_mappers()
    for prefix in ("refined:", "refined2:", "annealed:"):
        assert prefix + "blocked" in listed
    with pytest.raises(KeyError):
        get_mapper("refined2:doesnotexist")


def test_prefix_kwargs_configure_the_refiner():
    m = get_mapper("refined2:hyperplane", rounds=2, sa_moves=10)
    assert m.refiner.rounds == 2 and m.refiner.sa_moves == 10
    m = get_mapper("annealed:blocked", seed=9)
    assert m.refiner.seed == 9


# ---------------------------------------------------------------------------
# elastic ragged pods: refinement at mesh construction time
def test_mapped_device_array_auto_refines_ragged():
    """A pod that lost chips gets the scheduled-refinement upgrade without
    the caller naming it: (J_max, J_sum) is lexicographically no worse than
    the unrefined layout, on both the ragged-tail path and explicit
    surviving node_sizes."""
    stencil = Stencil.nearest_neighbor(2)
    devices = list(range(48))
    for kwargs in ({"chips_per_pod": 20},                       # ragged tail
                   {"chips_per_pod": 16,
                    "node_sizes": [16, 16, 10, 6]}):            # elastic pods
        arrs = {}
        for auto in (False, True):
            arrs[auto] = mapped_device_array(devices, "hyperplane", (6, 8),
                                             stencil, auto_refine=auto,
                                             **kwargs)
        sizes = kwargs.get("node_sizes")
        if sizes is None:
            full, rem = divmod(48, kwargs["chips_per_pod"])
            sizes = [kwargs["chips_per_pod"]] * full + [rem]
        base = layout_cost(np.vectorize(int)(arrs[False]), stencil, sizes)
        ref = layout_cost(np.vectorize(int)(arrs[True]), stencil, sizes)
        assert (ref.j_max, ref.j_sum) <= (base.j_max, base.j_sum)
        assert sorted(arrs[True].reshape(-1)) == devices


def test_mapped_device_array_homogeneous_unchanged():
    """Uniform pods never trigger the auto-upgrade (bit-identical layout)."""
    stencil = Stencil.nearest_neighbor(2)
    devices = list(range(48))
    a = mapped_device_array(devices, "hyperplane", (6, 8), stencil, 12)
    b = mapped_device_array(devices, "hyperplane", (6, 8), stencil, 12,
                            auto_refine=False)
    np.testing.assert_array_equal(np.vectorize(int)(a), np.vectorize(int)(b))


def test_mapped_device_array_validates_node_sizes():
    stencil = Stencil.nearest_neighbor(2)
    with pytest.raises(ValueError):
        mapped_device_array(list(range(48)), "blocked", (6, 8), stencil, 16,
                            node_sizes=[16, 16, 10])


def test_ensure_refined_idempotent():
    from repro.core import PortfolioRefiner
    assert ensure_refined("refined:kdtree") == "refined:kdtree"
    assert ensure_refined("annealed:kdtree") == "annealed:kdtree"
    assert ensure_refined("portfolio[k=2]:kdtree") == "portfolio[k=2]:kdtree"
    m = get_mapper("refined:blocked")
    assert ensure_refined(m) is m
    for wrapped in (ensure_refined("kdtree"),
                    ensure_refined(get_mapper("kdtree"))):
        assert isinstance(wrapped, RefinedMapper)
        assert isinstance(wrapped.refiner, PortfolioRefiner)
        assert wrapped.name == "portfolio:kdtree"
        assert wrapped.fallback is not None  # ragged-inapplicable bases too


def test_auto_refine_covers_inapplicable_base():
    """Nodecart cannot map ragged node sizes at all; the elastic upgrade
    must still refine (from the blocked fallback) instead of silently
    falling back to the unrefined identity layout."""
    stencil = Stencil.nearest_neighbor(2)
    devices = list(range(48))
    sizes = [16, 16, 10, 6]
    with pytest.raises(MapperInapplicable):
        get_mapper("nodecart").assignment(CartGrid((6, 8)), stencil, sizes)
    arr = mapped_device_array(devices, "nodecart", (6, 8), stencil, 16,
                              node_sizes=sizes)
    ident = mapped_device_array(devices, "blocked", (6, 8), stencil, 16,
                                node_sizes=sizes, auto_refine=False)
    cost = layout_cost(np.vectorize(int)(arr), stencil, sizes)
    base = layout_cost(np.vectorize(int)(ident), stencil, sizes)
    assert sorted(arr.reshape(-1)) == devices
    assert (cost.j_max, cost.j_sum) < (base.j_max, base.j_sum)


# ---------------------------------------------------------------------------
# wall-time guard
def test_batch_steepest_pass_faster_than_scalar():
    """One 48x48 steepest sweep: the batched frontier engine must beat the
    scalar loop by a wide margin (acceptance asks >=10x; we assert a
    conservative 5x so a loaded CI box can't flake) and agree with it on
    monotonicity."""
    rng = np.random.default_rng(0)
    grid = CartGrid((48, 48))
    stencil = Stencil.nearest_neighbor(2)
    a = rng.permutation(np.repeat(np.arange(48), 48))
    times = {}
    for eng in ("scalar", "batch"):
        refiner = SwapRefiner(policy="steepest", max_passes=1, engine=eng)
        t0 = time.perf_counter()
        res = refiner.refine(grid, stencil, a, num_nodes=48)
        times[eng] = time.perf_counter() - t0
        assert res.final.j_sum <= res.initial.j_sum
    assert times["batch"] * 5 < times["scalar"], times
