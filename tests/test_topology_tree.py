"""Property suite for the machine hierarchy model
(:class:`repro.topology.machine.TopologyTree`) and the chip-addressing
contract of both machine spec classes.

Pinned properties:

* structural invariants — leaf count equals the chip count, per-level
  node counts are prefix products of the fan-outs (so the last level has
  exactly ``num_pods`` nodes), and sibling chip ranges tile the parent's
  range exactly;
* ragged round-trip — ``TopologyTree(sizes).node_sizes() == sizes`` and
  per-subtree chip counts are sums of ``pod_sizes`` slices;
* hier composition bijection — a :class:`~repro.core.refine.hier.HierRefiner`
  pass over any balanced instance returns an assignment with *exactly*
  the input's node cardinalities (the property its internal composition
  assert enforces, checked here from the outside on random instances);
* chip addressing — ``pod_of``/``torus_coord`` raise :class:`ValueError`
  on out-of-range chip ids (-1 and ``num_chips``) in **both**
  :class:`~repro.topology.machine.MachineSpec` and
  :class:`~repro.topology.machine.RaggedMachineSpec`; the pre-fix code
  silently returned a phantom pod id for both.
"""
import math

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline: deterministic shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import CartGrid, Stencil, evaluate
from repro.core.refine import HierRefiner, hier_subtree_cache
from repro.topology.machine import (LevelSpec, MachineSpec,
                                    RaggedMachineSpec, TopologyTree,
                                    V5E_4RACK, V5E_POD)


def _random_levels(rng, max_levels=3, max_fanout=4):
    n_levels = int(rng.integers(1, max_levels + 1))
    return tuple(LevelSpec(f"l{i}", int(rng.integers(1, max_fanout + 1)))
                 for i in range(n_levels))


# ---------------------------------------------------------------------------
# structural invariants


@given(st.integers(0, 10_000))
@settings(max_examples=50, deadline=None)
def test_tree_leaf_and_level_counts(seed):
    rng = np.random.default_rng(seed)
    levels = _random_levels(rng)
    num_pods = math.prod(l.fanout for l in levels)
    sizes = [int(rng.integers(1, 9)) for _ in range(num_pods)]
    tree = TopologyTree(sizes, levels)
    assert tree.depth == len(levels)
    assert tree.num_pods == num_pods
    assert tree.leaf_count() == tree.num_chips == sum(sizes)
    # node counts are prefix products of the fan-outs
    for lvl in range(tree.depth + 1):
        assert tree.num_nodes_at(lvl) == math.prod(
            l.fanout for l in levels[:lvl])
    assert tree.num_nodes_at(0) == 1
    assert tree.num_nodes_at(tree.depth) == num_pods


@given(st.integers(0, 10_000))
@settings(max_examples=50, deadline=None)
def test_tree_sibling_ranges_tile_parent(seed):
    """Children's pod/chip ranges partition the parent's range, and
    ``child_sizes`` sums to ``chip_count`` at every internal node."""
    rng = np.random.default_rng(seed)
    levels = _random_levels(rng)
    num_pods = math.prod(l.fanout for l in levels)
    sizes = [int(rng.integers(1, 9)) for _ in range(num_pods)]
    tree = TopologyTree(sizes, levels)
    for lvl in range(tree.depth):
        f = tree.fanout_at(lvl)
        for j in range(tree.num_nodes_at(lvl)):
            plo, phi = tree.pod_range(lvl, j)
            clo, chi = tree.chip_range(lvl, j)
            kids_p, kids_c = [], []
            for c in range(f):
                k = j * f + c
                kids_p.append(tree.pod_range(lvl + 1, k))
                kids_c.append(tree.chip_range(lvl + 1, k))
            assert kids_p[0][0] == plo and kids_p[-1][1] == phi
            assert kids_c[0][0] == clo and kids_c[-1][1] == chi
            for (a, b), (c_, d) in zip(kids_p, kids_p[1:]):
                assert b == c_        # contiguous, no gaps or overlap
            assert sum(tree.child_sizes(lvl, j)) == tree.chip_count(lvl, j)
    # pods' children are the chips themselves
    for p in range(tree.num_pods):
        assert tree.child_sizes(tree.depth, p) == [1] * sizes[p]
        assert tree.chip_count(tree.depth, p) == sizes[p]


@given(st.integers(0, 10_000))
@settings(max_examples=50, deadline=None)
def test_tree_ragged_node_sizes_round_trip(seed):
    rng = np.random.default_rng(seed)
    levels = _random_levels(rng)
    num_pods = math.prod(l.fanout for l in levels)
    sizes = [int(rng.integers(1, 9)) for _ in range(num_pods)]
    tree = TopologyTree(sizes, levels)
    assert tree.node_sizes() == sizes
    # level ancestors are consistent with pod ranges
    for pod in range(num_pods):
        for lvl in range(tree.depth + 1):
            j = tree.level_node_of_pod(pod, lvl)
            lo, hi = tree.pod_range(lvl, j)
            assert lo <= pod < hi


def test_tree_default_single_level_and_validation():
    t = TopologyTree([4, 2, 3])                     # default: one pod level
    assert t.depth == 1 and t.num_pods == 3 and t.num_chips == 9
    assert t.node_sizes() == [4, 2, 3]
    with pytest.raises(ValueError):
        TopologyTree([])
    with pytest.raises(ValueError):
        TopologyTree([4, 0])
    with pytest.raises(ValueError):                  # fan-outs don't multiply
        TopologyTree([4] * 6, (LevelSpec("a", 2), LevelSpec("b", 2)))
    with pytest.raises(ValueError):
        LevelSpec("bad", 0)
    with pytest.raises(ValueError):
        t.num_nodes_at(5)
    with pytest.raises(ValueError):
        t.pod_range(1, 3)
    with pytest.raises(ValueError):                  # pods have no one fanout
        t.fanout_at(1)


def test_machine_levels_validation_and_tree():
    tree = V5E_4RACK.topology_tree()
    assert tree.depth == 2 and tree.num_pods == 16
    assert tree.leaf_count() == V5E_4RACK.num_chips == 16 * 256
    assert [l.name for l in tree.levels] == ["rack", "pod"]
    assert tree.chip_range(1, 0) == (0, 4 * 256)     # rack 0 = pods 0..3
    with pytest.raises(ValueError):                  # 2*3 != 4 pods
        MachineSpec(num_pods=4, torus=(2,),
                    levels=(LevelSpec("a", 2), LevelSpec("b", 3)))
    flat = V5E_POD.topology_tree()                   # levels=() default
    assert flat.depth == 1 and flat.num_pods == 1
    assert flat.node_sizes() == [256]


# ---------------------------------------------------------------------------
# chip addressing: out-of-range ids raise (regression — the pre-fix
# ``pod_of`` happily returned ``chip // chips_per_pod`` for any int)


@pytest.mark.parametrize("machine", [
    MachineSpec(num_pods=3, torus=(2, 2)),           # 12 chips
    RaggedMachineSpec(pod_sizes=(5, 3, 4)),          # 12 chips, ragged
    V5E_4RACK,
])
def test_pod_of_boundary_ids(machine):
    n = machine.num_chips
    assert machine.pod_of(0) == 0
    assert machine.pod_of(n - 1) == machine.num_pods - 1
    for bad in (-1, n, n + 7):
        with pytest.raises(ValueError):
            machine.pod_of(bad)
        with pytest.raises(ValueError):
            machine.torus_coord(bad)


def test_ragged_pod_of_interior_boundaries():
    r = RaggedMachineSpec(pod_sizes=(5, 3, 4))
    assert [r.pod_of(c) for c in (4, 5, 7, 8, 11)] == [0, 1, 1, 2, 2]


# ---------------------------------------------------------------------------
# hier composition bijection


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_hier_assignment_bijection(seed):
    """On random balanced instances, the composed hierarchical assignment
    realizes exactly the input's node cardinalities — node i keeps its
    size, every position keeps exactly one node."""
    rng = np.random.default_rng(seed)
    f1, f2 = int(rng.integers(2, 4)), int(rng.integers(2, 4))
    n = f1 * f2
    per = int(rng.integers(2, 5))
    grid = CartGrid((n, per))
    stencil = Stencil.nearest_neighbor(2)
    a = rng.permutation(np.repeat(np.arange(n), per))
    hier_subtree_cache().clear()
    res = HierRefiner(fanouts=f"{f1}x{f2}", solver="refined").refine(
        grid, stencil, a, num_nodes=n)
    out = np.asarray(res.assignment)
    assert out.shape == a.shape
    np.testing.assert_array_equal(np.bincount(out, minlength=n),
                                  np.bincount(a, minlength=n))
    # and never lexicographically worse than its input
    assert (res.final.j_max, res.final.j_sum) \
        <= (res.initial.j_max, res.initial.j_sum)


# ---------------------------------------------------------------------------
# ragged-aware fan-out derivation (derive_fanouts / TopologyTree.derive /
# MachineSpec.topology_tree(depth=...))


def test_derive_fanouts_ragged_round_trip():
    """A ragged allocation derives fan-outs from the actual chip counts:
    the tree round-trips node_sizes exactly, and its level-1 subtree chip
    totals are no more skewed than the pod-count-only dims_create split
    (here: perfectly balanced 16/16 vs dims_create's 8..12 spread)."""
    from repro.core.grid import dims_create
    from repro.topology.machine import derive_fanouts
    sizes = (4, 4, 4, 4, 2, 2, 6, 6)

    def spread(fanouts):
        starts = np.concatenate(([0], np.cumsum(sizes)))
        groups = np.diff(starts[::math.prod(fanouts[1:])])
        return int(groups.max() - groups.min())

    fo = derive_fanouts(sizes, depth=2)
    assert math.prod(fo) == len(sizes)
    assert spread(fo) <= spread(tuple(dims_create(len(sizes), 2)))
    assert spread(fo) == 0                      # this instance balances

    tree = TopologyTree.derive(sizes, depth=2)
    assert tree.depth == 2
    assert tree.node_sizes() == list(sizes)     # exact round-trip
    assert tree.num_chips == sum(sizes)
    # sibling subtrees at level 1 carry equal chip counts
    totals = [tree.chip_range(1, i)[1] - tree.chip_range(1, i)[0]
              for i in range(tree.num_nodes_at(1))]
    assert len(set(totals)) == 1


def test_derive_fanouts_uniform_keeps_dims_create():
    """Uniform pods score 0 imbalance for every factorization, so the
    derivation must return exactly the dims_create fan-outs (bit-compat
    with the pre-derivation contiguous-equal-groups assumption)."""
    from repro.core.grid import dims_create
    from repro.topology.machine import derive_fanouts
    for n, depth in ((8, 2), (12, 2), (16, 3), (7, 2)):
        assert derive_fanouts([16] * n, depth) == tuple(dims_create(n, depth))


def test_machine_topology_tree_depth_derivation():
    """MachineSpec.topology_tree(depth=) derives for level-less machines,
    ragged specs use their true sizes, and machines with declared levels
    reject a conflicting re-derivation."""
    ragged = RaggedMachineSpec(pod_sizes=(4, 4, 4, 4, 2, 2, 6, 6))
    tree = ragged.topology_tree(depth=2)
    assert tree.node_sizes() == list(ragged.pod_sizes)
    assert tree.depth == 2
    flat = MachineSpec(num_pods=6, torus=(2, 2)).topology_tree(depth=2)
    assert flat.depth == 2 and flat.num_pods == 6
    with pytest.raises(ValueError):
        V5E_4RACK.topology_tree(depth=3)     # declares 2 levels
    # depth matching the declaration is a no-op passthrough
    assert V5E_4RACK.topology_tree(depth=len(V5E_4RACK.levels)).depth \
        == len(V5E_4RACK.levels)
