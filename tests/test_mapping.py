"""Mapping algorithm invariants + paper-claim regressions.

Invariants (hypothesis, every algorithm):
  * rank->coordinate is a bijection onto the grid;
  * the scheduler allocation is respected (node i owns exactly n_i cells);
  * per-rank distributed forms agree with the batch form.

Paper claims (§VI.C / §VI.D, machine-independent):
  * Hyperplane and Stencil Strips beat Nodecart on J_sum for all three
    stencils on the headline instances;
  * k-d tree and Stencil Strips find the optimal component-stencil mapping
    (J_max == 2 per interior node);
  * every algorithm improves on blocked; random is worst;
  * Thm V.1/V.2: a suitable hyperplane split always exists with balance
    >= 1/2 when p = C*n.
"""
import math

import numpy as np
import pytest
try:
    from hypothesis import assume, given, settings, strategies as st
except ImportError:  # offline: deterministic shim
    from _hypothesis_compat import assume, given, settings, strategies as st

from repro.core import (CartGrid, MapperInapplicable, Stencil, dims_create,
                        evaluate, get_mapper)
from repro.core.mapping import MAPPERS, check_bijection
from repro.core.mapping.hyperplane import HyperplaneMapper, _find_split
from repro.core.mapping.kdtree import KDTreeMapper
from repro.core.mapping.stencil_strips import StencilStripsMapper

STENCILS = {
    "nn": Stencil.nearest_neighbor,
    "comp": Stencil.component,
    "hops": Stencil.nn_with_hops,
}


def make_instance(n_nodes, ppn, d):
    dims = dims_create(n_nodes * ppn, d)
    return CartGrid(dims), [ppn] * n_nodes


@given(st.sampled_from(sorted(MAPPERS)), st.integers(2, 6), st.integers(2, 9),
       st.integers(2, 3), st.sampled_from(sorted(STENCILS)))
@settings(max_examples=40, deadline=None)
def test_mapper_invariants(mname, n_nodes, ppn, d, sname):
    grid, sizes = make_instance(n_nodes, ppn, d)
    stencil = STENCILS[sname](d)
    mapper = get_mapper(mname, max_passes=2) if mname == "graphgreedy" \
        else get_mapper(mname)
    try:
        coords = mapper.coords(grid, stencil, sizes)
    except MapperInapplicable:
        assume(False)
    check_bijection(coords, grid.dims)
    assignment = mapper.assignment(grid, stencil, sizes)
    counts = np.bincount(assignment, minlength=n_nodes)
    np.testing.assert_array_equal(counts, sizes)


@given(st.integers(2, 5), st.integers(2, 8), st.integers(2, 3))
@settings(max_examples=25, deadline=None)
def test_heterogeneous_node_sizes(n_nodes, base, d):
    """The paper's contribution over Nodecart: heterogeneous n_i works."""
    sizes = [base + (i % 3) for i in range(n_nodes)]
    dims = dims_create(sum(sizes), d)
    grid = CartGrid(dims)
    stencil = Stencil.nearest_neighbor(d)
    for mname in ("hyperplane", "kdtree", "stencil_strips"):
        a = get_mapper(mname).assignment(grid, stencil, sizes)
        np.testing.assert_array_equal(np.bincount(a, minlength=n_nodes), sizes)


@given(st.integers(2, 6), st.integers(2, 9), st.integers(2, 3))
@settings(max_examples=30, deadline=None)
def test_per_rank_forms_agree(n_nodes, ppn, d):
    grid, sizes = make_instance(n_nodes, ppn, d)
    stencil = Stencil.nearest_neighbor(d)
    hp = HyperplaneMapper()
    batch = hp.coords(grid, stencil, sizes)
    for r in [0, grid.size // 2, grid.size - 1]:
        assert tuple(batch[r]) == hp.coord_of_rank(grid.dims, stencil, ppn, r)
    kd = KDTreeMapper()
    batch = kd.coords(grid, stencil, sizes)
    for r in [0, grid.size // 3, grid.size - 1]:
        assert tuple(batch[r]) == kd.coord_of_rank(grid.dims, stencil, 0, r)


def test_strips_closed_form_matches_enumeration():
    # divisible case: 8x8 grid, n=16, nearest neighbor -> strips of 4
    grid = CartGrid((8, 8))
    stencil = Stencil.nearest_neighbor(2)
    m = StencilStripsMapper()
    batch = m.coords(grid, stencil, [16] * 4)
    for r in range(grid.size):
        assert tuple(batch[r]) == m.coord_of_rank(grid.dims, stencil, 16, r)


@given(st.integers(2, 12), st.integers(2, 16), st.integers(2, 3))
@settings(max_examples=40, deadline=None)
def test_hyperplane_split_exists_and_balanced(C, n, d):
    """Thm V.1 (existence) + Thm V.2 (|g'|/|g''| >= 1/2)."""
    dims = list(dims_create(C * n, d))
    cos2 = Stencil.nearest_neighbor(d).cos2_sums()
    split = _find_split(dims, cos2, n)
    assert split is not None, f"no split for dims={dims}, n={n}"
    i, d_left = split
    left = d_left * math.prod(dims) // dims[i]
    right = math.prod(dims) - left
    assert left % n == 0 and right % n == 0
    assert min(left, right) / max(left, right) >= 0.5 - 1e-9


# ---------------------------------------------------------------------------
# paper §VI quality claims on the headline instances
@pytest.mark.parametrize("N,n,dims", [(50, 48, (50, 48)), (100, 48, (75, 64))])
def test_paper_quality_ordering(N, n, dims):
    grid = CartGrid(dims)
    sizes = [n] * N
    for sname, stencil in [("nn", Stencil.nearest_neighbor(2)),
                           ("hops", Stencil.nn_with_hops(2)),
                           ("comp", Stencil.component(2))]:
        j = {}
        for mname in ("blocked", "nodecart", "hyperplane", "kdtree",
                      "stencil_strips", "random"):
            j[mname] = get_mapper(mname).cost(grid, stencil, sizes).j_sum
        # the paper's headline ordering
        assert j["hyperplane"] < j["nodecart"] < j["blocked"], (sname, j)
        assert j["stencil_strips"] < j["nodecart"], (sname, j)
        assert j["kdtree"] < j["blocked"], (sname, j)
        assert j["random"] > j["blocked"] * 0.9, (sname, j)


def test_component_optimal_kdtree_and_strips():
    """§VI.D: 'only k-d tree and Stencil Strips managed to find an optimal
    mapping, where each compute node has two outgoing communication edges'."""
    grid = CartGrid((50, 48))
    stencil = Stencil.component(2)
    for mname in ("kdtree", "stencil_strips"):
        c = get_mapper(mname).cost(grid, stencil, [48] * 50)
        assert c.j_max == 2, mname


def test_nodecart_inapplicable_cases():
    """Nodecart needs homogeneous n with n | p — exactly the cases the
    paper's algorithms are 'also applicable to' (contribution 2)."""
    stencil = Stencil.nearest_neighbor(2)
    # n does not divide p
    with pytest.raises(MapperInapplicable):
        get_mapper("nodecart").coords(CartGrid((5, 7)), stencil, [4] * 9)
    # heterogeneous node sizes
    with pytest.raises(MapperInapplicable):
        get_mapper("nodecart").coords(CartGrid((4, 3)), stencil, [5, 4, 3])


def test_nodecart_applicable_beats_blocked():
    grid = CartGrid((8, 8))
    stencil = Stencil.nearest_neighbor(2)
    jb = get_mapper("blocked").cost(grid, stencil, [16] * 4).j_sum
    jn = get_mapper("nodecart").cost(grid, stencil, [16] * 4).j_sum
    assert jn < jb
