"""Sharded adaptive portfolio engine: shard-count invariance (bit-identical
to the single-process portfolio for any shard count), multiprocessing-
backend parity, restart-from-leader dominance, accept-rate retune bounds,
the killed-budget pool accounting, the `sharded[...]:` grammar/plan/cache
wiring, and the jax.vmap stacked-counts path.

Invariance assertions use array_equal / ==, not isclose: the sharded
coordinator replays the single-process engine's floats exactly (same
kernel, same merge order), so any drift is a bug.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline: deterministic shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import (CartGrid, PlanCache, PortfolioCost, PortfolioRefiner,
                        RefinedMapper, ShardedPortfolioRefiner, Stencil,
                        available_mappers, device_layout, ensure_refined,
                        evaluate, get_mapper, parse_plan,
                        stacked_crossing_counts)

#: a schedule small enough for tests but long enough that kills, restarts,
#: and several retune boundaries actually happen.
KW = dict(rounds=1, max_passes=2, sa_moves=60,
          temperatures=(4.0, 2.0, 1.0, 0.5, 0.25))

#: an instance where aggressive early-kill (kill_factor=1.0) reliably
#: kills ladders, so the adaptive pool has budget to redistribute.
KILL_DIMS, KILL_SIZES = (10, 12), (32, 32, 32, 24)


def _kill_instance(seed):
    grid = CartGrid(KILL_DIMS)
    stencil = Stencil.nn_with_hops(2)
    rng = np.random.default_rng(seed)
    a = rng.permutation(np.repeat(np.arange(len(KILL_SIZES)), KILL_SIZES))
    return grid, stencil, a


# ---------------------------------------------------------------------------
# shard-count invariance: bit-identical to the single-process portfolio


@pytest.mark.parametrize("dims,sizes", [((8, 8), (16,) * 4),
                                        ((6, 8), (16, 16, 10, 6))])
def test_shard_count_invariance_bit_identical(dims, sizes):
    """Acceptance: sharded[shards=S,k=K] == portfolio[k=K] bit for bit, for
    any S, when adaptive control is off — same assignment, same final
    (J_max, J_sum), same swap/pass counts."""
    grid = CartGrid(dims)
    stencil = Stencil.nearest_neighbor(2)
    rng = np.random.default_rng(5)
    a = rng.permutation(np.repeat(np.arange(len(sizes)), sizes))
    kw = dict(rounds=2, max_passes=3, sa_moves=40)
    ref = PortfolioRefiner(k=6, seed=3, **kw).refine(
        grid, stencil, a, num_nodes=len(sizes))
    for S in (1, 2, 3, 4, 6):
        sh = ShardedPortfolioRefiner(shards=S, k=6, seed=3,
                                     backend="serial", **kw).refine(
            grid, stencil, a, num_nodes=len(sizes))
        np.testing.assert_array_equal(sh.assignment, ref.assignment,
                                      err_msg=f"shards={S}")
        assert (sh.final.j_max, sh.final.j_sum) \
            == (ref.final.j_max, ref.final.j_sum)
        assert (sh.swaps, sh.passes) == (ref.swaps, ref.passes)
        assert sh.stats["ladder_keys"] == ref.stats["ladder_keys"]
        assert sh.stats["killed"] == ref.stats["killed"]
        assert sh.stats["shards"] == min(S, 6)


def test_shard_invariance_on_kill_heavy_weighted_instance():
    """The kill rule sees the *global* leader at every boundary, so shard
    invariance must survive an instance with real kills — and byte-weighted
    scoring (weighted='auto') rides through the sharded payloads."""
    grid, stencil, a = _kill_instance(1)
    heavy = Stencil(stencil.offsets,
                    tuple(8.0 if i < 2 else 1.0
                          for i in range(stencil.k)))
    for st_ in (stencil, heavy):
        ref = PortfolioRefiner(k=6, seed=1, kill_factor=1.0, **KW).refine(
            grid, st_, a, num_nodes=len(KILL_SIZES))
        assert ref.stats["killed"] > 0      # the scenario is exercised
        for S in (2, 4):
            sh = ShardedPortfolioRefiner(
                shards=S, k=6, seed=1, kill_factor=1.0, backend="serial",
                **KW).refine(grid, st_, a, num_nodes=len(KILL_SIZES))
            np.testing.assert_array_equal(sh.assignment, ref.assignment)
            assert sh.stats["killed"] == ref.stats["killed"]


def test_mp_backend_matches_serial():
    """The multiprocessing backend ships picklable per-block tasks and must
    return exactly what the in-process blocks return."""
    grid, stencil, a = _kill_instance(2)
    kw = dict(shards=2, k=4, seed=2, rounds=1, max_passes=2, sa_moves=40)
    serial = ShardedPortfolioRefiner(backend="serial", **kw).refine(
        grid, stencil, a, num_nodes=len(KILL_SIZES))
    mp_res = ShardedPortfolioRefiner(backend="mp", **kw).refine(
        grid, stencil, a, num_nodes=len(KILL_SIZES))
    np.testing.assert_array_equal(serial.assignment, mp_res.assignment)
    assert serial.stats["ladder_keys"] == mp_res.stats["ladder_keys"]
    assert mp_res.stats["backend"] == "mp"


# ---------------------------------------------------------------------------
# adaptive control: restart-from-leader dominance + pool accounting


@given(st.integers(0, 10_000))
@settings(max_examples=8, deadline=None)
def test_adaptive_restarts_never_worse_than_portfolio(seed):
    """Restart ladders are pure extra candidates (originals replay the
    single-process engine exactly; restarts never feed the kill rule), so
    adaptive-on is lexicographically never worse than portfolio[k=K]."""
    grid, stencil, a = _kill_instance(seed)
    base = PortfolioRefiner(k=5, seed=seed, kill_factor=1.0, **KW).refine(
        grid, stencil, a, num_nodes=len(KILL_SIZES))
    ad = ShardedPortfolioRefiner(
        shards=3, k=5, seed=seed, kill_factor=1.0, restarts="auto",
        retune=True, backend="serial", **KW).refine(
        grid, stencil, a, num_nodes=len(KILL_SIZES))
    assert (ad.final.j_max, ad.final.j_sum) \
        <= (base.final.j_max, base.final.j_sum)
    # exact reported costs + preserved scheduler allocation
    check = evaluate(grid, stencil, ad.assignment,
                     num_nodes=len(KILL_SIZES))
    assert (check.j_max, check.j_sum) == (ad.final.j_max, ad.final.j_sum)
    np.testing.assert_array_equal(
        np.bincount(ad.assignment, minlength=len(KILL_SIZES)),
        np.bincount(a, minlength=len(KILL_SIZES)))


def test_restart_pool_accounting_and_cap():
    """Killed ladders fund the restart pool; restarts only spend what the
    pool holds, an int `restarts` caps the total, and restarts=None spawns
    none."""
    grid, stencil, a = _kill_instance(1)
    common = dict(shards=2, k=6, seed=1, kill_factor=1.0,
                  backend="serial", **KW)
    auto = ShardedPortfolioRefiner(restarts="auto", **common).refine(
        grid, stencil, a, num_nodes=len(KILL_SIZES))
    assert auto.stats["killed"] > 0
    assert auto.stats["restarted"] > 0
    # every restart was funded by a killed ladder's unspent temperatures
    assert auto.stats["restarted"] <= auto.stats["killed"]
    assert auto.stats["pool_moves_left"] >= 0
    capped = ShardedPortfolioRefiner(restarts=1, **common).refine(
        grid, stencil, a, num_nodes=len(KILL_SIZES))
    assert capped.stats["restarted"] <= 1
    off = ShardedPortfolioRefiner(restarts=None, **common).refine(
        grid, stencil, a, num_nodes=len(KILL_SIZES))
    assert off.stats["restarted"] == 0 and off.stats["restart_t_mults"] == []


def test_accept_rate_retune_bounds():
    """Retune moves a restart ladder's temperature multiplier in the
    documented direction — up when the accept rate is below the band, down
    when above — and always stays inside retune_bounds (clamped, never
    runaway)."""
    grid, stencil, a = _kill_instance(1)
    common = dict(shards=2, k=6, seed=1, kill_factor=1.0, restarts="auto",
                  retune=True, backend="serial", **KW)
    # a band no walk can satisfy from below: every boundary doubles, so the
    # multiplier must hit (and never exceed) the upper clamp
    bounds = (0.5, 2.0)
    hot = ShardedPortfolioRefiner(accept_band=(0.95, 0.99),
                                  retune_bounds=bounds, **common).refine(
        grid, stencil, a, num_nodes=len(KILL_SIZES))
    mults = hot.stats["restart_t_mults"]
    assert mults and all(bounds[0] <= m <= bounds[1] for m in mults)
    assert max(mults) == bounds[1]
    # the mirror: any acceptance is "too hot", so multipliers only shrink
    cold = ShardedPortfolioRefiner(accept_band=(0.0, 0.0),
                                   retune_bounds=bounds, **common).refine(
        grid, stencil, a, num_nodes=len(KILL_SIZES))
    mults = cold.stats["restart_t_mults"]
    assert mults and all(bounds[0] <= m <= bounds[1] for m in mults)
    assert min(mults) < 1.0
    # retune is restart-only, so dominance survives it (structural)
    base = PortfolioRefiner(k=6, seed=1, kill_factor=1.0, **KW).refine(
        grid, stencil, a, num_nodes=len(KILL_SIZES))
    for res in (hot, cold):
        assert (res.final.j_max, res.final.j_sum) \
            <= (base.final.j_max, base.final.j_sum)


def test_restarts_auto_with_zero_sa_moves_terminates():
    """Regression: a zero-proposal schedule (sa_moves=0) makes a restart
    cost nothing — the spawn loop must not spin forever handing out free
    restarts (every other engine accepts sa_moves=0 and completes)."""
    grid, stencil, a = _kill_instance(1)
    res = ShardedPortfolioRefiner(
        shards=2, k=4, seed=1, kill_factor=1.0, restarts="auto",
        backend="serial", rounds=1, max_passes=2, sa_moves=0).refine(
        grid, stencil, a, num_nodes=len(KILL_SIZES))
    assert res.stats["restarted"] == 0
    base = PortfolioRefiner(k=4, seed=1, kill_factor=1.0, rounds=1,
                            max_passes=2, sa_moves=0).refine(
        grid, stencil, a, num_nodes=len(KILL_SIZES))
    np.testing.assert_array_equal(res.assignment, base.assignment)


def test_sharded_validates_config():
    with pytest.raises(ValueError):
        ShardedPortfolioRefiner(shards=0)
    with pytest.raises(ValueError):
        ShardedPortfolioRefiner(restarts=-1)
    with pytest.raises(ValueError):
        ShardedPortfolioRefiner(backend="cluster")
    with pytest.raises(ValueError):
        ShardedPortfolioRefiner(accept_band=(0.9, 0.1))
    with pytest.raises(ValueError):
        ShardedPortfolioRefiner(retune_bounds=(2.0, 4.0))  # must bracket 1
    with pytest.warns(UserWarning, match="duplicate portfolio seeds"):
        r = ShardedPortfolioRefiner(seeds=[4, 4, 9])
    assert r.seeds == (4, 9) and r.k == 2
    assert r.config()["seeds"] == (4, 9)          # honest cache identity


# ---------------------------------------------------------------------------
# grammar / plan / cache wiring


def test_sharded_grammar_stage_and_registry():
    m = get_mapper("sharded[shards=2,k=3,sa_moves=40]:hyperplane")
    assert isinstance(m, RefinedMapper)
    assert isinstance(m.refiner, ShardedPortfolioRefiner)
    assert m.refiner.shards == 2 and m.refiner.k == 3
    assert m.name == "sharded:hyperplane"
    assert "sharded:blocked" in available_mappers()
    # canonical plan key: bracket options sorted, stable across spellings
    assert parse_plan("sharded[k=3,shards=2]:hyperplane").key \
        == parse_plan("sharded[shards=2,k=3]:hyperplane").key
    # restarts=auto / retune=true coerce through the option grammar
    r = get_mapper("sharded[restarts=auto,retune=true,k=2]:blocked").refiner
    assert r.restarts == "auto" and r.retune is True
    r = get_mapper("sharded[restarts=3]:blocked").refiner
    assert r.restarts == 3
    # already-refined spellings pass through ensure_refined unchanged
    assert ensure_refined("sharded[k=2]:hyperplane") == "sharded[k=2]:hyperplane"
    # plans carry the stage; cacheable (all-plain config)
    plan = parse_plan("sharded[k=2,sa_moves=30]:kdtree")
    assert plan.cacheable
    assert ShardedPortfolioRefiner(k=2).as_stage().cacheable


def test_bare_sharded_equals_bare_portfolio():
    """`sharded:<base>` and `portfolio:<base>` share every schedule default,
    so the bare spellings are bit-identical."""
    grid = CartGrid((6, 8))
    stencil = Stencil.nearest_neighbor(2)
    sizes = [16, 16, 10, 6]
    a_sh = get_mapper("sharded:kdtree").assignment(grid, stencil, sizes)
    a_pf = get_mapper("portfolio:kdtree").assignment(grid, stencil, sizes)
    np.testing.assert_array_equal(a_sh, a_pf)


def test_sharded_layouts_cache_and_thread_through_device_layout():
    dims, sizes = (8, 8), [16] * 4
    stencil = Stencil.nearest_neighbor(2)
    cache = PlanCache()
    name = "sharded[shards=2,k=2,sa_moves=30]:hyperplane"
    L1 = device_layout(name, dims, stencil, sizes, cache=cache)
    L2 = device_layout(name, dims, stencil, sizes, cache=cache)
    assert (cache.hits, cache.misses) == (1, 1)
    np.testing.assert_array_equal(L1, L2)
    assert sorted(L1.reshape(-1).tolist()) == list(range(64))


def test_budgeted_sharded_delegates_to_single_process():
    """A max_swaps budget couples every ladder through one shared counter —
    the single-process engine IS that semantics, so the budgeted sharded
    stage must equal the budgeted portfolio bit for bit (and respect the
    per-stage cap)."""
    grid = CartGrid((8, 8))
    stencil = Stencil.nearest_neighbor(2)
    sizes = (16,) * 4
    base = get_mapper("random").assignment(grid, stencil, list(sizes))
    kw = dict(k=3, seed=2, rounds=2, max_passes=3, sa_moves=40)
    for budget in (0, 3, 7):
        sh = ShardedPortfolioRefiner(shards=2, **kw).as_stage(
            budget=budget).run(grid, stencil, sizes, base)
        pf = PortfolioRefiner(**kw).as_stage(budget=budget).run(
            grid, stencil, sizes, base)
        np.testing.assert_array_equal(sh.assignment, pf.assignment)
        assert sh.stats["swaps"] <= budget
        assert sh.result.stats["backend"] == "single-process"


# ---------------------------------------------------------------------------
# the jax.vmap stacked-counts path


def test_stacked_crossing_counts_matches_portfolio_cost():
    """The counts kernel (numpy path, and the jax.vmap path when jax is
    importable) is bit-equal to PortfolioCost's own init loop, and feeding
    the counts back in reproduces the full state."""
    rng = np.random.default_rng(11)
    grid = CartGrid((5, 6), periodic=(True, False))
    stencil = Stencil.nn_with_hops(2)
    A = rng.integers(0, 4, size=(3, grid.size))
    pc = PortfolioCost(grid, stencil, A, num_nodes=4)
    co, cn = stacked_crossing_counts(grid, stencil, A, 4, use_jax=False)
    np.testing.assert_array_equal(co, pc._count_off)
    np.testing.assert_array_equal(cn, pc._count_node)
    try:
        import jax  # noqa: F401
        co_j, cn_j = stacked_crossing_counts(grid, stencil, A, 4,
                                             use_jax=True)
        np.testing.assert_array_equal(co_j, co)
        np.testing.assert_array_equal(cn_j, cn)
    except ImportError:
        pass
    pre = PortfolioCost(grid, stencil, A, num_nodes=4, counts=(co, cn))
    np.testing.assert_array_equal(pre.per_node(), pc.per_node())
    assert pre.j_sum().tolist() == pc.j_sum().tolist()
    with pytest.raises(ValueError, match="wrong shapes"):
        PortfolioCost(grid, stencil, A, num_nodes=4, counts=(co, cn[:2]))


def test_vmap_counts_refine_is_bit_identical():
    """vmap_counts only changes who computes the integer counts — the
    refinement result must not move."""
    grid, stencil, a = _kill_instance(3)
    kw = dict(shards=2, k=4, seed=3, backend="serial",
              rounds=1, max_passes=2, sa_moves=40)
    off = ShardedPortfolioRefiner(vmap_counts=False, **kw).refine(
        grid, stencil, a, num_nodes=len(KILL_SIZES))
    on = ShardedPortfolioRefiner(vmap_counts=True, **kw).refine(
        grid, stencil, a, num_nodes=len(KILL_SIZES))
    np.testing.assert_array_equal(off.assignment, on.assignment)
    assert off.stats["ladder_keys"] == on.stats["ladder_keys"]


# ---------------------------------------------------------------------------
# counts backend selection: explicit option, import-order independence


def test_counts_backend_explicit_values_bit_equal():
    """"numpy" and "jax" are explicit backend spellings; both produce the
    same integer counts, and bogus values are rejected at construction."""
    rng = np.random.default_rng(13)
    grid = CartGrid((6, 5))
    stencil = Stencil.nn_with_hops(2)
    A = rng.integers(0, 4, size=(3, grid.size))
    co_n, cn_n = stacked_crossing_counts(grid, stencil, A, 4,
                                         use_jax="numpy")
    co_j, cn_j = stacked_crossing_counts(grid, stencil, A, 4, use_jax="jax")
    np.testing.assert_array_equal(co_n, co_j)
    np.testing.assert_array_equal(cn_n, cn_j)
    with pytest.raises(ValueError, match="vmap_counts"):
        ShardedPortfolioRefiner(vmap_counts="cuda")
    # the option is part of config(), so it is cache-identity material
    assert ShardedPortfolioRefiner(
        vmap_counts="numpy").config()["vmap_counts"] == "numpy"


def test_counts_backend_auto_is_importability_not_import_order():
    """Regression (satellite): "auto" used to consult sys.modules, so the
    first call's backend depended on whether anything had imported jax
    yet.  It must key on *importability* (find_spec) — stable for the
    process regardless of import order."""
    import importlib.util
    import sys

    from repro.core.refine import sharded as sh

    assert "jax" in sys.modules        # the suite has long since imported it
    spec_backup = sh._JAX_SPEC
    real_find_spec = importlib.util.find_spec
    try:
        # simulate a jax-less environment; with jax still in sys.modules,
        # the old sys.modules probe would (wrongly) say "jax"
        sh._JAX_SPEC = None
        importlib.util.find_spec = lambda name, *a: (
            None if name == "jax" else real_find_spec(name, *a))
        assert sh._jax_importable() is False
        assert sh._resolve_counts_backend("auto") is False
        # and the cached verdict is sticky: restoring find_spec without
        # resetting the cache does not flip it mid-process
        importlib.util.find_spec = real_find_spec
        assert sh._resolve_counts_backend("auto") is False
    finally:
        importlib.util.find_spec = real_find_spec
        sh._JAX_SPEC = spec_backup
    # back in the real environment: importable, so "auto" means jax
    sh._JAX_SPEC = None
    try:
        assert sh._resolve_counts_backend("auto") is True
    finally:
        sh._JAX_SPEC = spec_backup
    # explicit spellings resolve independently of the probe
    assert sh._resolve_counts_backend("numpy") is False
    assert sh._resolve_counts_backend("jax") is True
    assert sh._resolve_counts_backend(True) is True
    assert sh._resolve_counts_backend(False) is False


# ---------------------------------------------------------------------------
# restart-ladder seeding: never collide with explicit user seeds


def test_restart_seeder_warns_and_shifts_on_collision():
    """A restart seed landing on an explicit portfolio seed must shift
    past every colliding value with a warning — a restart ladder may never
    replay an original trajectory."""
    from repro.core.refine.engine import RestartSeeder
    seeder = RestartSeeder((0, 5, 6), start=5)
    with pytest.warns(UserWarning, match="collides with an explicit"):
        assert seeder() == 7            # 5 and 6 are both taken
    assert seeder() == 8                # stream continues past the shift
    # the default stream (max+1) never collides: no warning expected
    import warnings as _warnings
    clean = RestartSeeder((3, 9, 4))
    with _warnings.catch_warnings():
        _warnings.simplefilter("error")
        assert [clean() for _ in range(3)] == [10, 11, 12]
    with pytest.raises(ValueError, match="at least one"):
        RestartSeeder(())


def test_restart_seeds_are_fresh_and_reported():
    """End to end on a kill-heavy run with explicit seeds: the restart
    seeds reported in stats are unique and disjoint from the originals."""
    grid, stencil, a = _kill_instance(2)
    res = ShardedPortfolioRefiner(
        shards=2, seeds=(11, 3, 7, 5), kill_factor=1.0, restarts="auto",
        backend="serial", rounds=1, max_passes=2, sa_moves=60,
        temperatures=(4.0, 2.0, 1.0, 0.5, 0.25)).refine(
        grid, stencil, a, num_nodes=len(KILL_SIZES))
    assert res.stats["restarted"] > 0, "instance no longer kill-heavy"
    restart_seeds = res.stats["restart_seeds"]
    assert len(restart_seeds) == res.stats["restarted"]
    assert len(set(restart_seeds)) == len(restart_seeds)
    assert not set(restart_seeds) & {11, 3, 7, 5}
    assert min(restart_seeds) > 11      # max(seeds)+1 counting upward


# ---------------------------------------------------------------------------
# crash injection: a worker raising mid-run must not orphan the pool


def test_sharded_crash_leaves_no_orphans(monkeypatch):
    """Regression for the ProcessPoolExecutor leak: when a worker task
    raises mid-run, the engine must fall back to the serial path (same
    result — the coordinator state is untouched) AND still shut the
    executor down (the try/finally), leaving no orphaned children."""
    import multiprocessing as mp
    import os
    import repro.core.refine.sharded as sh
    grid, stencil, a = _kill_instance(3)
    kw = dict(shards=2, k=4, seed=3, rounds=1, max_passes=2, sa_moves=40)
    want = ShardedPortfolioRefiner(backend="serial", **kw).refine(
        grid, stencil, a, num_nodes=len(KILL_SIZES))

    before = set(p.pid for p in mp.active_children())
    parent = os.getpid()
    real = sh._block_step

    def boom(payload):
        if os.getpid() != parent:     # fork children inherit the patch
            raise RuntimeError("injected worker crash")
        return real(payload)

    monkeypatch.setattr(sh, "_block_step", boom)
    res = ShardedPortfolioRefiner(backend="mp", **kw).refine(
        grid, stencil, a, num_nodes=len(KILL_SIZES))
    assert res.stats["backend"] == "serial-fallback"
    np.testing.assert_array_equal(res.assignment, want.assignment)
    assert res.stats["ladder_keys"] == want.stats["ladder_keys"]
    # the finally-shutdown joined every pool process: nothing new survives
    after = set(p.pid for p in mp.active_children())
    assert after <= before
