"""Float-weight accumulation parity: evaluate vs IncrementalCost (ISSUE 10).

``IncrementalCost`` reconstructs per-node loads as ``w * count`` per
offset; ``evaluate`` used to add ``w`` once per crossing edge instead.
The two orders differ in the last ulp for non-dyadic weights — e.g. six
additions of 0.1 give 0.6 where ``0.1 * 6`` gives 0.6000000000000001 —
so the documented "within an ulp" caveat was real.  ``evaluate`` now
accumulates ``w * bincount`` per offset, the same op sequence, and these
tests pin bit-exact equality for arbitrary float weights (they fail on
the pre-fix accumulation by construction).
"""
import numpy as np
import pytest

from repro.core import (CartGrid, IncrementalCost, PortfolioCost, Stencil,
                        evaluate)


def test_regression_w01_six_crossings_bit_exact():
    # a 1-D line of 7 positions, node 0 owning position 0..5 alternating
    # with node 1 so one node sources exactly 6 crossing edges under one
    # offset of weight 0.1: repeated addition gives 0.6, w * count gives
    # 0.6000000000000001 — pre-fix, evaluate and IncrementalCost disagreed
    # in the last bit.
    grid = CartGrid((12,))
    st = Stencil(((1,),), weights=(0.1,))
    a = np.array([0, 1, 0, 1, 0, 1, 0, 1, 0, 1, 0, 1])
    c = evaluate(grid, st, a, num_nodes=2, weighted=True)
    ic = IncrementalCost(grid, st, a, num_nodes=2, weighted=True)
    assert c.per_node[0] == np.float64(0.1) * 6        # the multiply order
    assert np.array_equal(c.per_node, ic.per_node)
    assert c.j_sum == ic.j_sum
    assert c.j_max == ic.j_max


@pytest.mark.parametrize("seed", range(4))
def test_random_float_weights_bit_exact(seed):
    rng = np.random.default_rng(seed)
    grid = CartGrid((6, 7), periodic=(True, False))
    st = Stencil(((1, 0), (0, 1), (-1, 0), (0, -1)),
                 weights=tuple(rng.uniform(0.05, 3.0, size=4)))
    n = 5
    a = rng.integers(0, n, size=grid.size)
    c = evaluate(grid, st, a, num_nodes=n, weighted=True)
    ic = IncrementalCost(grid, st, a, num_nodes=n, weighted=True)
    assert np.array_equal(c.per_node, ic.per_node)
    assert c.j_sum == ic.j_sum
    assert c.j_max == ic.j_max
    # the stacked portfolio state agrees row-for-row too
    A = np.stack([a, rng.integers(0, n, size=grid.size)])
    pc = PortfolioCost(grid, st, A, num_nodes=n, weighted=True)
    assert pc.j_max()[0] == c.j_max
    assert pc.j_sum()[0] == c.j_sum


def test_unit_weights_unchanged():
    # integer sums were exact before and after the fix — pinned so the
    # linksim replay exactness contracts (dci_total == j_sum) survive.
    grid = CartGrid((8, 8))
    st = Stencil.nearest_neighbor(2)
    rng = np.random.default_rng(1)
    a = rng.integers(0, 4, size=64)
    c = evaluate(grid, st, a, num_nodes=4)
    assert c.j_sum == float(int(c.j_sum))
    assert np.array_equal(c.per_node, np.round(c.per_node))
