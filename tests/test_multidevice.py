"""Multi-device integration (subprocess with 8 XLA host devices): mapped
mesh construction, sharded train-step lower+compile (mini dry-run), and a
real shard_map halo exchange matching its oracle."""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

# each test spawns a fresh interpreter that re-imports and re-compiles JAX
# on 8 fake devices (~1 min apiece) — out of the tier-1 budget
pytestmark = pytest.mark.slow

SRC = str(Path(__file__).resolve().parent.parent / "src")


def run_py(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_mapped_mesh_and_sharded_train_step():
    print(run_py("""
        import jax, numpy as np, json
        import jax.numpy as jnp
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.core import Stencil, get_mapper, mapped_device_array
        from repro.configs import get_arch
        from repro.configs.base import ShapeSpec
        from repro.launch.input_specs import build_cell
        from repro.sharding.partition import use_partitioning

        # mapped 4x2 mesh over 2 'pods' of 4 chips
        st = Stencil.nearest_neighbor(2)
        arr = mapped_device_array(jax.devices(), get_mapper('stencil_strips'),
                                  (4, 2), st, chips_per_pod=4)
        mesh = Mesh(arr, ('data', 'model'))
        assert arr.shape == (4, 2)

        cfg = get_arch('qwen3-8b').reduced()
        shape = ShapeSpec('mini', seq_len=32, global_batch=8, kind='train')
        cell = build_cell(cfg, shape, mesh)
        with mesh, use_partitioning(cell.partitioning):
            jf = jax.jit(cell.step_fn, in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings)
            compiled = jf.lower(*cell.args).compile()
        ma = compiled.memory_analysis()
        print(json.dumps({'arg_mb': ma.argument_size_in_bytes / 2**20,
                          'ok': True}))
    """))


def test_real_sharded_execution_runs():
    out = run_py("""
        import jax, numpy as np
        import jax.numpy as jnp
        from repro.configs import get_arch
        from repro.configs.base import ShapeSpec
        from repro.launch.input_specs import build_cell
        from repro.models import lm
        from repro.models.common import init_params
        from repro.optim.adamw import AdamWConfig, init_opt_state
        from repro.sharding.partition import use_partitioning
        from jax.sharding import Mesh

        # jax.sharding.AxisType only exists in jax >= 0.5; default axis
        # semantics there are Auto, so the plain mesh is equivalent.
        axis_type = getattr(jax.sharding, 'AxisType', None)
        if axis_type is not None:
            mesh = jax.make_mesh((4, 2), ('data', 'model'),
                                 axis_types=(axis_type.Auto,) * 2)
        else:
            mesh = jax.make_mesh((4, 2), ('data', 'model'))
        cfg = get_arch('granite-3-8b').reduced()
        shape = ShapeSpec('mini', seq_len=32, global_batch=8, kind='train')
        cell = build_cell(cfg, shape, mesh)
        with mesh, use_partitioning(cell.partitioning):
            params = init_params(lm.param_specs(cfg), jax.random.PRNGKey(0))
            opt = init_opt_state(lm.param_specs(cfg), AdamWConfig())
            batch = {'inputs': jnp.zeros((8, 32), jnp.int32),
                     'targets': jnp.zeros((8, 32), jnp.int32)}
            params = jax.device_put(params, cell.in_shardings[0])
            opt = jax.device_put(opt, cell.in_shardings[1])
            batch = jax.device_put(batch, cell.in_shardings[2])
            jf = jax.jit(cell.step_fn, in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings)
            p1, o1, metrics = jf(params, opt, batch)
            loss = float(metrics['loss'])
        assert np.isfinite(loss), loss
        print('loss', loss)
    """)
    assert "loss" in out


def test_halo_exchange_shard_map_matches_roll():
    """The paper's MPI_Neighbor_alltoall analog: ppermute halo exchange on a
    1-d ring of 8 devices equals jnp.roll on the global array."""
    out = run_py("""
        import jax, numpy as np
        import jax.numpy as jnp
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from jax.experimental.shard_map import shard_map

        axis_type = getattr(jax.sharding, 'AxisType', None)
        if axis_type is not None:
            mesh = jax.make_mesh((8,), ('x',),
                                 axis_types=(axis_type.Auto,))
        else:
            mesh = jax.make_mesh((8,), ('x',))
        n = 64
        x = jnp.arange(n, dtype=jnp.float32)

        def halo_step(u):
            left = jax.lax.ppermute(u[-1:], 'x',
                                    [(i, (i + 1) % 8) for i in range(8)])
            right = jax.lax.ppermute(u[:1], 'x',
                                     [(i, (i - 1) % 8) for i in range(8)])
            return left + right + 0 * u[:1]  # just prove neighbor data moves

        f = shard_map(lambda u: jnp.concatenate(
                [jax.lax.ppermute(u[-1:], 'x', [(i, (i+1) % 8) for i in range(8)]),
                 u,
                 jax.lax.ppermute(u[:1], 'x', [(i, (i-1) % 8) for i in range(8)])]),
            mesh=mesh, in_specs=P('x'), out_specs=P('x'))
        padded = f(x)
        padded = np.asarray(padded).reshape(8, 10)
        shard = np.asarray(x).reshape(8, 8)
        for i in range(8):
            assert padded[i, 0] == shard[(i - 1) % 8, -1]
            assert padded[i, -1] == shard[(i + 1) % 8, 0]
            np.testing.assert_array_equal(padded[i, 1:-1], shard[i])
        print('halo ok')
    """)
    assert "halo ok" in out
