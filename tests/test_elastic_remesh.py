"""Elastic re-mesh integration: a runtime.fault node_loss drives
make_mapped_mesh(node_sizes=survivors) end-to-end in a dry-run (subprocess
with fake XLA host devices, the launch.dryrun idiom), and the surviving
layout must be a device bijection whose (J_max, J_sum) is no worse than
the blocked fallback.  A second, in-process test covers the same elastic
path through mapped_device_array without jax mesh construction.
"""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np

from repro.core import (MappingProblem, Stencil, elastic_portfolio_plan,
                        layout_cost, mapped_device_array, repair_layout)
from repro.core.remap import apply_layout
from repro.core.repair import downweighted_node_sizes
from repro.runtime.fault import FaultInjector, SimulatedFault
from repro.runtime.straggler import FleetStragglerMonitor

SRC = str(Path(__file__).resolve().parent.parent / "src")


def run_py(code: str, devices: int) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def drive_node_loss(schedule, node_sizes, chips_lost=2):
    """Step a FaultInjector until its node_loss fires; return survivors."""
    inj = FaultInjector(schedule=schedule)
    sizes = list(node_sizes)
    fired = None
    for step in range(10):
        try:
            inj.check(step)
        except SimulatedFault as f:
            assert f.kind == "node_loss"
            fired = f
            sizes[f.node] -= chips_lost
    assert fired is not None, "fault never fired"
    assert all(s > 0 for s in sizes)
    return sizes, fired


def test_node_loss_remesh_dry_run():
    """End-to-end dry-run: 4 pods x 4 chips, pod 1 loses 2 chips at step 3;
    the re-mesh onto 14 survivors must build a real jax Mesh that is a
    bijection over the surviving devices with (J_max, J_sum) no worse than
    the blocked fallback (and no worse than the unrefined mapper layout —
    the ragged auto-upgrade engaged)."""
    out = run_py("""
        import json
        import numpy as np
        from repro.core import Stencil, layout_cost, mapped_device_array
        from repro.launch.mesh import make_mapped_mesh
        from repro.runtime.fault import FaultInjector, SimulatedFault
        import jax

        stencil = Stencil.nearest_neighbor(2)
        node_sizes = [4, 4, 4, 4]
        inj = FaultInjector(schedule={3: "node_loss:1"})
        for step in range(6):
            try:
                inj.check(step)
            except SimulatedFault as f:
                node_sizes[f.node] -= 2          # pod 1 keeps 2 of 4 chips

        survivors = sum(node_sizes)
        devices = jax.devices()[:survivors]
        mesh = make_mapped_mesh("hyperplane", mesh_shape=(7, 2),
                                axes=("data", "model"), stencil=stencil,
                                devices=devices, node_sizes=node_sizes)
        ids = np.vectorize(lambda d: d.id)(mesh.devices)

        def cost_of(arr):
            c = layout_cost(np.vectorize(lambda d: d.id)(arr), stencil,
                            node_sizes)
            return [c.j_max, c.j_sum]

        blocked = mapped_device_array(devices, "blocked", (7, 2), stencil, 4,
                                      node_sizes=node_sizes,
                                      auto_refine=False)
        unrefined = mapped_device_array(devices, "hyperplane", (7, 2),
                                        stencil, 4, node_sizes=node_sizes,
                                        auto_refine=False)
        refined = layout_cost(ids, stencil, node_sizes)
        print(json.dumps({
            "node_sizes": node_sizes,
            "mesh_shape": list(mesh.devices.shape),
            "axes": list(mesh.axis_names),
            "ids": sorted(int(i) for i in ids.reshape(-1)),
            "refined": [refined.j_max, refined.j_sum],
            "blocked": cost_of(blocked),
            "unrefined": cost_of(unrefined),
        }))
    """, devices=14)
    res = json.loads(out.strip().splitlines()[-1])
    assert res["node_sizes"] == [4, 2, 4, 4]            # the fault fired
    assert res["mesh_shape"] == [7, 2]
    assert res["axes"] == ["data", "model"]
    assert res["ids"] == list(range(14))                # bijection over survivors
    assert tuple(res["refined"]) <= tuple(res["blocked"])
    assert tuple(res["refined"]) <= tuple(res["unrefined"])


def test_node_loss_elastic_layout_in_process():
    """Same elastic flow without jax: fault -> survivors -> ragged
    mapped_device_array; the portfolio auto-upgrade must beat (or tie) the
    blocked fallback lexicographically and keep the device set intact."""
    stencil = Stencil.nearest_neighbor(2)
    survivors, fault = drive_node_loss({2: "node_loss:2"}, [16, 16, 16, 16],
                                       chips_lost=6)
    assert fault.step == 2 and fault.node == 2
    assert survivors == [16, 16, 10, 16]
    devices = list(range(sum(survivors)))               # 58 fake chips
    arr = mapped_device_array(devices, "hyperplane", (2, 29), stencil, 16,
                              node_sizes=survivors)
    blocked = mapped_device_array(devices, "blocked", (2, 29), stencil, 16,
                                  node_sizes=survivors, auto_refine=False)
    ref = layout_cost(np.vectorize(int)(arr), stencil, survivors)
    base = layout_cost(np.vectorize(int)(blocked), stencil, survivors)
    assert sorted(arr.reshape(-1)) == devices
    assert (ref.j_max, ref.j_sum) <= (base.j_max, base.j_sum)


def test_node_loss_whole_pod_remesh_in_process():
    """Losing an entire pod leaves a homogeneous survivor set: the re-mesh
    still produces a bijection and auto_refine stays out of the way (no
    ragged upgrade needed)."""
    stencil = Stencil.nearest_neighbor(2)
    inj = FaultInjector(schedule={1: "node_loss:3"})
    sizes = [8, 8, 8, 8]
    for step in range(3):
        try:
            inj.check(step)
        except SimulatedFault as f:
            sizes.pop(f.node)
    assert sizes == [8, 8, 8]
    devices = list(range(24))
    arr = mapped_device_array(devices, "hyperplane", (6, 4), stencil, 8,
                              node_sizes=sizes)
    assert sorted(arr.reshape(-1)) == devices
    cost = layout_cost(np.vectorize(int)(arr), stencil, sizes)
    base = layout_cost(
        np.vectorize(int)(mapped_device_array(devices, "blocked", (6, 4),
                                              stencil, 8, node_sizes=sizes,
                                              auto_refine=False)),
        stencil, sizes)
    assert cost.j_sum <= base.j_sum


def test_uniform_shrink_gets_refinement(monkeypatch):
    """Every pod shrinking by the same amount leaves *uniform* node_sizes
    that no longer match the original chips_per_pod split — the elastic
    upgrade must engage there too, not only for ragged survivors (it used
    to key off raggedness alone and skip the uniform-shrink re-mesh)."""
    import repro.core.remap as remap_mod
    calls = []
    orig = remap_mod.ensure_refined

    def spy(mapper):
        calls.append(mapper)
        return orig(mapper)

    monkeypatch.setattr(remap_mod, "ensure_refined", spy)
    stencil = Stencil.nearest_neighbor(2)
    devices = list(range(24))
    remap_mod.mapped_device_array(devices, "hyperplane", (6, 4), stencil,
                                  chips_per_pod=16, node_sizes=[8, 8, 8],
                                  cache=False)
    assert calls, "uniform shrink (16 -> 8 chips/pod) must auto-refine"
    calls.clear()
    remap_mod.mapped_device_array(devices, "hyperplane", (6, 4), stencil,
                                  chips_per_pod=8, node_sizes=[8, 8, 8],
                                  cache=False)
    assert not calls, "sizes matching the homogeneous split: no upgrade"


def test_straggler_monitor_drives_warm_repair_end_to_end():
    """The full slow-pod loop in-process: fleet monitor flags the 2x pod,
    its capacity is down-weighted, repair_layout warm-starts from the
    serving solution, and remap.apply_layout re-permutes the surviving
    devices — a bijection whose churn-untouched positions kept their
    device assignment pinned."""
    stencil = Stencil.nearest_neighbor(2)
    sizes = (8,) * 6
    prev = elastic_portfolio_plan().solve(
        MappingProblem((6, 8), stencil, sizes))

    fleet = FleetStragglerMonitor(patience=2, warmup=2)
    slow_node = None
    for step in range(12):
        dts = {n: (2.1 if n == 4 and step >= 5 else 1.0) for n in range(6)}
        for node, action in fleet.record(step, dts).items():
            if action == "remap":
                slow_node = node
                break
        if slow_node is not None:
            break
    assert slow_node == 4, "monitor must isolate the persistently slow pod"

    dw = downweighted_node_sizes(sizes, slow_node, 2.0)
    assert sum(dw) == sum(sizes) and dw[slow_node] < sizes[slow_node]
    sol = repair_layout(prev, dw, cache=False)
    st = sol.stage_stats[0]
    assert st["kind"] == "repair" and not st["used_fallback"]
    assert np.bincount(sol.assignment, minlength=6).tolist() == dw

    devices = list(range(48))               # stand-ins, pod-major order
    arr = apply_layout(devices, sol.layout())
    assert sorted(int(d) for d in arr.reshape(-1)) == devices
    assert arr.shape == (6, 8)


def test_repair_mapped_mesh_dry_run():
    """Whole-pod loss end-to-end with a real jax Mesh: the pre-churn mesh
    solution is repaired onto the survivors via repair_mapped_mesh (warm
    path, no cold fallback) and the rebuilt Mesh is a bijection over the
    surviving devices."""
    out = run_py("""
        import json
        import numpy as np
        from repro.core import MappingProblem, Stencil, elastic_portfolio_plan
        from repro.launch.mesh import repair_mapped_mesh
        from repro.runtime.fault import FaultInjector, SimulatedFault
        import jax

        stencil = Stencil.nearest_neighbor(2)
        prev = elastic_portfolio_plan().solve(
            MappingProblem((4, 4), stencil, (4, 4, 4, 4)))

        inj = FaultInjector(schedule={2: "node_loss:1"})
        fault = None
        for step in range(4):
            try:
                inj.check(step)
            except SimulatedFault as f:
                fault = f
        survivors = fault.survivors([4, 4, 4, 4])
        node_map = fault.survivor_map(4)

        devices = jax.devices()[:sum(survivors)]
        mesh, sol = repair_mapped_mesh(prev, survivors, devices=devices,
                                       mesh_shape=(3, 4), stencil=stencil,
                                       node_map=node_map, cache=False)
        ids = np.vectorize(lambda d: d.id)(mesh.devices)
        print(json.dumps({
            "survivors": survivors,
            "node_map": node_map,
            "mesh_shape": list(mesh.devices.shape),
            "axes": list(mesh.axis_names),
            "ids": sorted(int(i) for i in ids.reshape(-1)),
            "kind": sol.stage_stats[0]["kind"],
            "used_fallback": sol.stage_stats[0]["used_fallback"],
        }))
    """, devices=16)
    res = json.loads(out.strip().splitlines()[-1])
    assert res["survivors"] == [4, 4, 4]
    assert res["node_map"] == [0, 2, 3]
    assert res["mesh_shape"] == [3, 4]
    assert res["axes"] == ["data", "model"]
    assert res["ids"] == list(range(12))
    assert res["kind"] == "repair" and not res["used_fallback"]
