"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles,
executed with interpret=True on CPU (deliverable c)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline: deterministic shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import Stencil
from repro.kernels.attention.ops import flash_attention
from repro.kernels.attention.ref import attention_ref
from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.stencil.ops import stencil_apply
from repro.kernels.stencil.ref import stencil_ref

DTYPES = [np.float32, jnp.bfloat16]


def tol(dtype):
    return 5e-2 if dtype == jnp.bfloat16 else 1e-5


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("hw", [(8, 16), (16, 128), (33, 40)])
@pytest.mark.parametrize("sname", ["nn", "hops", "comp"])
def test_stencil_kernel_sweep(dtype, hw, sname):
    H, W = hw
    st_obj = {"nn": Stencil.nearest_neighbor(2),
              "hops": Stencil.nn_with_hops(2),
              "comp": Stencil.component(2)}[sname]
    offsets = st_obj.offsets
    halo = int(np.abs(np.asarray(offsets)).max())
    weights = tuple(1.0 / st_obj.k for _ in range(st_obj.k))
    rng = np.random.default_rng(0)
    u = jnp.asarray(rng.standard_normal((H + 2 * halo, W + 2 * halo)),
                    dtype=dtype)
    out = stencil_apply(u, offsets, weights, halo=halo, interpret=True)
    ref = stencil_ref(u, offsets, weights, halo=halo)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol(dtype))


@given(st.integers(1, 3), st.integers(1, 33), st.sampled_from([128, 256, 384]))
@settings(max_examples=12, deadline=None)
def test_rmsnorm_kernel_property(b, rows, d):
    rng = np.random.default_rng(rows * d)
    x = jnp.asarray(rng.standard_normal((b, rows, d)), dtype=jnp.float32)
    w = jnp.asarray(rng.standard_normal((d,)), dtype=jnp.float32)
    out = rmsnorm(x, w, interpret=True)
    ref = rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


@pytest.mark.parametrize("dtype", DTYPES)
def test_rmsnorm_dtypes(dtype):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((4, 64, 256)), dtype=dtype)
    w = jnp.asarray(rng.standard_normal((256,)), dtype=dtype)
    out = rmsnorm(x, w, interpret=True)
    ref = rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol(dtype))


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("Sq,Sk,window", [(64, 64, None), (128, 128, 32),
                                          (64, 128, None), (96, 96, None)])
def test_flash_attention_sweep(dtype, Sq, Sk, window):
    B, H, K, D = 1, 4, 2, 32
    rng = np.random.default_rng(Sq + Sk)
    q = jnp.asarray(rng.standard_normal((B, Sq, H, D)), dtype=dtype)
    k = jnp.asarray(rng.standard_normal((B, Sk, K, D)), dtype=dtype)
    v = jnp.asarray(rng.standard_normal((B, Sk, K, D)), dtype=dtype)
    causal = Sq == Sk
    out = flash_attention(q, k, v, causal=causal, window=window,
                          interpret=True)
    ref = flash_attention(q, k, v, causal=causal, window=window,
                          use_pallas=False)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol(dtype) * 2)


def test_flash_matches_model_blocked_sdpa():
    """The Pallas kernel and the model's jnp double-scan agree."""
    from repro.models.attention import _blocked_sdpa
    B, S, K, G, D = 1, 256, 2, 2, 16
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.standard_normal((B, S, K, G, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, K, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, K, D)), jnp.float32)
    pos = jnp.arange(S)
    out_model = _blocked_sdpa(q, k, v, pos, pos, True, None,
                              1.0 / np.sqrt(D), q_block=64, kv_block=64)
    out_kernel = flash_attention(q.reshape(B, S, K * G, D), k, v,
                                 causal=True, interpret=True)
    np.testing.assert_allclose(np.asarray(out_model).reshape(B, S, K * G, D),
                               np.asarray(out_kernel), atol=1e-4)


def test_model_level_pallas_attention_flag():
    """cfg.use_pallas_attention routes model attention through the Pallas
    kernel (interpret on CPU) and matches the jnp path end to end."""
    import dataclasses
    import jax
    from repro.configs import get_arch
    from repro.models import lm
    cfg = get_arch("qwen3-8b").reduced()
    cfgp = dataclasses.replace(cfg, use_pallas_attention=True)
    key = jax.random.PRNGKey(0)
    params = lm.init(cfg, key)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab)
    batch = {"inputs": toks, "targets": toks}
    l0, _, _ = lm.forward(cfg, params, batch)
    l1, _, _ = lm.forward(cfgp, params, batch)
    np.testing.assert_allclose(np.asarray(l0, np.float32),
                               np.asarray(l1, np.float32), atol=2e-3)


@pytest.mark.parametrize("dims", [(4, 8, 16), (6, 12, 20)])
@pytest.mark.parametrize("sname", ["nn3", "hops3"])
def test_stencil3d_kernel(dims, sname):
    from repro.kernels.stencil.ref import stencil3d_ref
    from repro.kernels.stencil.stencil import stencil3d_pallas
    st_obj = (Stencil.nearest_neighbor(3) if sname == "nn3"
              else Stencil.nn_with_hops(3, hops=(2,)))
    offsets = st_obj.offsets
    halo = int(np.abs(np.asarray(offsets)).max())
    weights = tuple(1.0 / st_obj.k for _ in range(st_obj.k))
    rng = np.random.default_rng(1)
    D, H, W = dims
    u = jnp.asarray(rng.standard_normal((D + 2 * halo, H + 2 * halo,
                                         W + 2 * halo)), jnp.float32)
    out = stencil3d_pallas(u, offsets, weights, halo, interpret=True)
    ref = stencil3d_ref(u, offsets, weights, halo)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
