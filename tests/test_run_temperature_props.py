"""Property-based contract of the numpy ladder kernel
(:func:`repro.core.refine.portfolio.run_temperature`) — the host side of
the engine interface every portfolio backend (serial / sharded / device)
speaks.  The properties pinned here are exactly the ones the device
engine's conformance suite (``tests/test_device_portfolio.py``) re-checks
on accelerator state, so a drift in either implementation shows up as a
broken shared contract, not a silent divergence:

* accepted-count bounds — ``0 <= accepted[i] <= sa_moves``, and exactly 0
  for dead or done ladders;
* done/alive interaction — dead and done ladders are excluded from the
  boundary snapshot, never consume their rng stream, and their state
  freezes; ``done`` only ever flips False -> True (sticky);
* rng-replay determinism — re-running from a deep-copied (state, rng)
  pair reproduces accepted counts, assignments, and done flags exactly;
* batch independence — a ladder's trajectory depends only on its own rng
  and start state, never on which batch it ran in (the property the
  sharded engine's bit-identity rests on);
* budget cap — the kernel checks the budget before each batched move, so
  the overshoot is bounded by one batch: ``sum(accepted) < budget + K``.
"""
import copy

import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline: deterministic shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import CartGrid, PortfolioCost, Stencil
from repro.core.refine.portfolio import run_temperature

DIMS = [(6, 6), (8, 8), (6, 8), (4, 4, 4)]


def _ladders(seed, k, dims=(6, 6), n_nodes=4):
    """A (pc, rngs, done) triple on a random balanced-ish assignment."""
    grid = CartGrid(dims)
    stencil = Stencil.nearest_neighbor(len(dims))
    rng = np.random.default_rng(seed)
    sizes = np.full(n_nodes, grid.size // n_nodes)
    sizes[: grid.size - sizes.sum()] += 1
    start = rng.permutation(np.repeat(np.arange(n_nodes), sizes))
    pc = PortfolioCost(grid, stencil,
                       np.broadcast_to(start, (k, grid.size)),
                       num_nodes=n_nodes)
    rngs = [np.random.default_rng(seed + 100 + i) for i in range(k)]
    return pc, rngs, np.zeros(k, dtype=bool)


@given(seed=st.integers(0, 10**6), k=st.integers(1, 5),
       sa_moves=st.integers(1, 50), dead=st.integers(0, 4),
       dims=st.sampled_from(DIMS))
@settings(max_examples=15)
def test_accepted_bounds_and_dead_rows_frozen(seed, k, sa_moves, dead, dims):
    """0 <= accepted <= sa_moves everywhere; a dead ladder accepts
    nothing, keeps its assignment, and its rng stream is never touched."""
    pc, rngs, done = _ladders(seed, k, dims)
    alive = np.ones(k, dtype=bool)
    alive[min(dead, k - 1)] = dead < k  # sometimes all alive
    dead_rows = np.nonzero(~alive)[0]
    frozen_states = pc.node[dead_rows].copy()
    frozen_rng = [copy.deepcopy(rngs[i].bit_generator.state)
                  for i in dead_rows]
    accepted = run_temperature(pc, rngs, alive, done, np.full(k, 1.0),
                               sa_moves, np.full(k, 1e-2))
    assert accepted.shape == (k,)
    assert np.all(accepted >= 0) and np.all(accepted <= sa_moves)
    assert np.all(accepted[dead_rows] == 0)
    np.testing.assert_array_equal(pc.node[dead_rows], frozen_states)
    for j, i in enumerate(dead_rows):
        assert rngs[i].bit_generator.state == frozen_rng[j]


@given(seed=st.integers(0, 10**6), k=st.integers(2, 5),
       sa_moves=st.integers(1, 40))
@settings(max_examples=15)
def test_done_ladders_freeze_and_skip_rng(seed, k, sa_moves):
    """A ladder already marked done behaves exactly like a dead one (no
    proposals, no rng draws) and done flags are sticky — the kernel never
    clears one."""
    pc, rngs, done = _ladders(seed, k)
    done[0] = True
    state0 = pc.node[0].copy()
    rng0 = copy.deepcopy(rngs[0].bit_generator.state)
    accepted = run_temperature(pc, rngs, np.ones(k, dtype=bool), done,
                               np.full(k, 0.5), sa_moves, np.full(k, 1e-2))
    assert accepted[0] == 0
    np.testing.assert_array_equal(pc.node[0], state0)
    assert rngs[0].bit_generator.state == rng0
    assert done[0]                       # sticky


@given(seed=st.integers(0, 10**6), k=st.integers(1, 4),
       sa_moves=st.integers(1, 40), temp=st.floats(1e-3, 4.0))
@settings(max_examples=15)
def test_rng_replay_determinism(seed, k, sa_moves, temp):
    """Deep-copying (pc, rngs, done) and replaying the call reproduces the
    run bit for bit — accepted counts, assignments, loads, done flags."""
    pc, rngs, done = _ladders(seed, k)
    pc2 = copy.deepcopy(pc)
    rngs2 = copy.deepcopy(rngs)
    done2 = done.copy()
    alive = np.ones(k, dtype=bool)
    temps, eps = np.full(k, temp), np.full(k, 1e-2)
    a1 = run_temperature(pc, rngs, alive, done, temps, sa_moves, eps)
    a2 = run_temperature(pc2, rngs2, alive, done2, temps, sa_moves, eps)
    np.testing.assert_array_equal(a1, a2)
    np.testing.assert_array_equal(pc.node, pc2.node)
    np.testing.assert_array_equal(done, done2)
    np.testing.assert_array_equal(pc.j_max(), pc2.j_max())
    np.testing.assert_array_equal(pc.j_sum(), pc2.j_sum())


@given(seed=st.integers(0, 10**6), k=st.integers(2, 5),
       sa_moves=st.integers(5, 40))
@settings(max_examples=10)
def test_batch_composition_independence(seed, k, sa_moves):
    """Ladder i advanced inside a K-batch equals ladder i advanced alone
    with the same seed — the kernel's per-ladder rng/state isolation (what
    the sharded engine's shard-count invariance is built on)."""
    pc, rngs, done = _ladders(seed, k)
    solo_states = []
    for i in range(k):
        pc1, _, done1 = _ladders(seed, 1)
        rngs1 = [np.random.default_rng(seed + 100 + i)]
        run_temperature(pc1, rngs1, np.ones(1, dtype=bool), done1,
                        np.full(1, 1.0), sa_moves, np.full(1, 1e-2))
        solo_states.append(pc1.node[0].copy())
    run_temperature(pc, rngs, np.ones(k, dtype=bool), done,
                    np.full(k, 1.0), sa_moves, np.full(k, 1e-2))
    for i in range(k):
        np.testing.assert_array_equal(pc.node[i], solo_states[i],
                                      err_msg=f"ladder {i} diverged")


@given(seed=st.integers(0, 10**6), k=st.integers(1, 5),
       sa_moves=st.integers(1, 40), budget=st.integers(0, 30))
@settings(max_examples=15)
def test_budget_cap_overshoot_bounded_by_one_batch(seed, k, sa_moves,
                                                   budget):
    """The budget is checked before each batched move (one accept per
    participating ladder), so the total overshoots by strictly less than
    one batch: ``sum(accepted) < budget + K``; budget=0 accepts nothing."""
    pc, rngs, done = _ladders(seed, k)
    accepted = run_temperature(pc, rngs, np.ones(k, dtype=bool), done,
                               np.full(k, 2.0), sa_moves, np.full(k, 1e-2),
                               budget=budget)
    assert accepted.sum() < budget + k
    if budget == 0:
        assert accepted.sum() == 0
