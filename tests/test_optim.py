"""Optimizer: AdamW math, quantized state, clipping, schedules, compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline: deterministic shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.optim import (AdamWConfig, adamw_update, dequantize_blockwise,
                         ef_compress, ef_decompress, init_error_state,
                         init_opt_state, quantize_blockwise)
from repro.sharding.partition import ParamSpec


def _specs():
    return {"w": ParamSpec((8, 16), jnp.float32, (None, None)),
            "b": ParamSpec((16,), jnp.float32, (None,))}


def _params(key):
    specs = _specs()
    return {k: jax.random.normal(jax.random.fold_in(key, i), v.shape)
            for i, (k, v) in enumerate(sorted(specs.items()))}


def test_adamw_first_step_matches_reference():
    cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
                      clip_norm=None, schedule="constant")
    params = _params(jax.random.PRNGKey(0))
    grads = {k: jnp.ones_like(v) for k, v in params.items()}
    state = init_opt_state(_specs(), cfg)
    p1, s1, _ = adamw_update(params, grads, state, cfg)
    # bias-corrected first step of Adam with g=1 everywhere: update = lr
    for k in params:
        np.testing.assert_allclose(np.asarray(params[k] - p1[k]),
                                   0.1, rtol=1e-5)


def test_weight_decay_only_on_matrices():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.5, clip_norm=None,
                      schedule="constant")
    params = _params(jax.random.PRNGKey(1))
    grads = {k: jnp.zeros_like(v) for k, v in params.items()}
    state = init_opt_state(_specs(), cfg)
    p1, _, _ = adamw_update(params, grads, state, cfg)
    # 1-d bias: no decay, zero grad -> unchanged
    np.testing.assert_allclose(np.asarray(p1["b"]), np.asarray(params["b"]))
    assert not np.allclose(np.asarray(p1["w"]), np.asarray(params["w"]))


def test_clipping_bounds_update():
    cfg = AdamWConfig(lr=1.0, clip_norm=1e-3, schedule="constant",
                      weight_decay=0.0)
    params = _params(jax.random.PRNGKey(2))
    grads = {k: 1e6 * jnp.ones_like(v) for k, v in params.items()}
    state = init_opt_state(_specs(), cfg)
    _, _, metrics = adamw_update(params, grads, state, cfg)
    assert float(metrics["grad_norm"]) > 1e5  # reported pre-clip


@given(st.integers(1, 4), st.sampled_from([16, 100, 128, 300]))
@settings(max_examples=20, deadline=None)
def test_quantize_roundtrip_error_bound(rows, d):
    rng = np.random.default_rng(rows * d)
    x = jnp.asarray(rng.standard_normal((rows, d)) * 3.0, jnp.float32)
    q, s = quantize_blockwise(x)
    deq = dequantize_blockwise(q, s, d)
    # absmax int8: error <= scale/2 = max|block|/254
    err = np.abs(np.asarray(deq) - np.asarray(x))
    assert err.max() <= float(jnp.abs(x).max()) / 127.0 + 1e-6


def test_quantized_state_specs_smaller():
    specs = {"w": ParamSpec((1024, 1024), jnp.bfloat16, (None, None))}
    fp = init_opt_state(specs, AdamWConfig(quantized=False))
    q = init_opt_state(specs, AdamWConfig(quantized=True))
    bytes_fp = sum(np.asarray(v).nbytes for v in fp.values())
    bytes_q = sum(np.asarray(v).nbytes for v in q.values())
    assert bytes_q < bytes_fp / 3


def test_quantized_adamw_tracks_fp32():
    cfgq = AdamWConfig(lr=0.05, quantized=True, clip_norm=None,
                       schedule="constant", weight_decay=0.0)
    cfgf = AdamWConfig(lr=0.05, quantized=False, clip_norm=None,
                       schedule="constant", weight_decay=0.0)
    specs = {"w": ParamSpec((64, 128), jnp.float32, (None, None))}
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 128))}
    sq, sf = init_opt_state(specs, cfgq), init_opt_state(specs, cfgf)
    pq, pf = dict(params), dict(params)
    key = jax.random.PRNGKey(1)
    for i in range(5):
        g = {"w": jax.random.normal(jax.random.fold_in(key, i), (64, 128))}
        pq, sq, _ = adamw_update(pq, g, sq, cfgq)
        pf, sf, _ = adamw_update(pf, g, sf, cfgf)
    diff = float(jnp.max(jnp.abs(pq["w"] - pf["w"])))
    scale = float(jnp.max(jnp.abs(pf["w"] - params["w"])))
    assert diff < 0.1 * scale  # quantized tracks full-precision closely


def test_schedule_warmup_cosine_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(cfg.lr_at(jnp.asarray(s))) for s in range(0, 101, 5)]
    assert lrs[0] < lrs[1] <= 1.0          # warmup rises
    assert abs(lrs[2] - 1.0) < 0.02        # peak at end of warmup
    assert lrs[-1] == pytest.approx(0.1, abs=0.02)  # decays to min ratio


def test_error_feedback_compression_unbiased_over_steps():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal((8, 256)), jnp.float32)}
    err = init_error_state(g)
    total_deq = np.zeros((8, 256))
    for _ in range(20):
        q, s, err = ef_compress(g, err)
        deq = ef_decompress(q, s, {"w": (8, 256)})
        total_deq += np.asarray(deq["w"])
    # accumulated transmitted gradient converges to 20*g (error feedback)
    np.testing.assert_allclose(total_deq / 20, np.asarray(g["w"]), atol=2e-2)
