"""End-to-end behaviour tests for the paper's system: train -> checkpoint ->
crash -> resume -> serve, plus the mapping feature integrated in the mesh
layer (device permutation quality on the production topology)."""
import numpy as np
import pytest

import jax

from repro.configs import get_arch
from repro.configs.base import ShapeSpec
from repro.core import Stencil, device_layout, get_mapper, layout_cost
from repro.data.synthetic import DataConfig
from repro.models import lm
from repro.optim import AdamWConfig
from repro.runtime import FaultInjector, Request, ServeLoop, Trainer


def test_train_crash_resume_serve(tmp_path):
    """Full lifecycle on a reduced arch."""
    cfg = get_arch("qwen3-8b").reduced()
    shape = ShapeSpec("sys", seq_len=32, global_batch=8, kind="train")
    tr = Trainer(cfg, shape,
                 opt_cfg=AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=100),
                 data_cfg=DataConfig(mode="memorize", corpus_len=96),
                 ckpt_dir=str(tmp_path), ckpt_every=10,
                 fault=FaultInjector(schedule={13: "step_crash"}))
    res = tr.run(30)
    assert res.restarts == 1
    assert res.final_loss < res.losses[0] * 0.8

    # resume in a *new* trainer from the checkpoint
    tr2 = Trainer(cfg, shape,
                  opt_cfg=AdamWConfig(lr=3e-3, warmup_steps=5,
                                      total_steps=100),
                  data_cfg=DataConfig(mode="memorize", corpus_len=96),
                  ckpt_dir=str(tmp_path))
    params, _, start = tr2._resume_or_init()
    assert start == 30

    # serve from the trained weights
    loop = ServeLoop(cfg, params, batch_slots=2, max_len=64)
    reqs = [Request(rid=i, prompt=np.arange(6, dtype=np.int32),
                    max_new_tokens=4) for i in range(3)]
    loop.run(reqs)
    assert all(r.done for r in reqs)


def test_production_mesh_mapping_quality():
    """On the 2-pod/512-chip production grid, the paper's algorithms place
    the byte-heavy mesh axes inside pods: J_sum(mapped) <= J_sum(blocked)
    and both beat random (machine-independent metric, paper §VI.C)."""
    from repro.launch.mesh import stencil_for_plan
    from repro.configs import SHAPES
    cfg = get_arch("qwen3-8b")
    stencil = stencil_for_plan(cfg, SHAPES["train_4k"], multi_pod=True)
    sizes = [256, 256]
    shape = (2, 16, 16)
    j = {}
    for m in ("blocked", "stencil_strips", "hyperplane", "random"):
        L = device_layout(get_mapper(m), shape, stencil, sizes)
        j[m] = layout_cost(L, stencil, sizes).j_sum
    assert j["stencil_strips"] <= j["blocked"] * 1.01
    assert j["hyperplane"] <= j["blocked"] * 1.01
    assert j["random"] > j["stencil_strips"]


def test_elastic_heterogeneous_mapping_after_pod_loss():
    """After losing a pod slice, mapping still respects surviving capacity
    (the paper's heterogeneous n_i case keeps the system runnable)."""
    stencil = Stencil.nearest_neighbor(2)
    sizes = [256, 192]  # pod 1 lost 64 chips
    L = device_layout(get_mapper("hyperplane"), (16, 28), stencil, sizes)
    c = layout_cost(L, stencil, sizes)
    assert len(c.per_node) == 2
    # blocked on the same ragged allocation is no better
    Lb = device_layout(get_mapper("blocked"), (16, 28), stencil, sizes)
    cb = layout_cost(Lb, stencil, sizes)
    assert c.j_sum <= cb.j_sum
