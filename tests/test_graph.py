"""Property suite for CommGraph (ISSUE 10).

The contract under test, layer by layer:

* **Stencil round-trip is bit-exact** — `CommGraph.from_stencil` stores
  the per-offset ``shift_ranks`` arrays as its slots, so the graph path
  builds the identical ``NeighborTable``, identical J_sum/J_max/per-node
  loads, and identical scalar *and* batched swap deltas as the grid path.
* **Slot decomposition is sound** — every slot of a general graph is a
  partial permutation (≤1 out-edge per source, ≤1 in-edge per target),
  the slots partition the edge set, and per-slot weights are uniform.
* **HLO extraction matches the wire model** — per-participant out-weight
  sums equal ``CollectiveStat.wire_bytes_per_device()``.
* **hier-on-graph** — the masked-subgraph analog keeps the bijection and
  is lexicographically never worse than its base.
"""
import numpy as np
import pytest

from repro.core import (CartGrid, CommGraph, GraphGrid, IncrementalCost,
                        MappingProblem, MaskedGraphGrid, NeighborTable,
                        PortfolioCost, Stencil, arch_comm_graph,
                        blocked_assignment, evaluate, parse_plan)


def _random_assignment(p, node_sizes, seed):
    rng = np.random.default_rng(seed)
    return rng.permutation(np.repeat(np.arange(len(node_sizes)),
                                     node_sizes))


GRIDS = [
    (CartGrid((6, 8), periodic=(True, False)), Stencil.nearest_neighbor(2)),
    (CartGrid((5, 7)), Stencil.nearest_neighbor(2)),
    (CartGrid((4, 4, 3), periodic=(True, True, False)),
     Stencil.nearest_neighbor(3)),
    (CartGrid((6, 6)),
     Stencil(((1, 0), (0, 1), (-1, 0), (0, -1), (1, 1)),
             weights=(3.0, 1.5, 3.0, 0.1, 2.25))),
]


# ---------------------------------------------------------------------------
# stencil round-trip


@pytest.mark.parametrize("gi", range(len(GRIDS)))
def test_from_stencil_neighbor_table_bit_identical(gi):
    grid, st = GRIDS[gi]
    g = CommGraph.from_stencil(grid, st)
    t1 = NeighborTable.build(grid, st)
    t2 = NeighborTable.from_graph(g)
    assert np.array_equal(t1.out_valid, t2.out_valid)
    assert np.array_equal(t1.out_tgt[t1.out_valid], t2.out_tgt[t2.out_valid])
    assert np.array_equal(t1.in_valid, t2.in_valid)
    assert np.array_equal(t1.in_src[t1.in_valid], t2.in_src[t2.in_valid])


@pytest.mark.parametrize("gi", range(len(GRIDS)))
def test_round_trip_costs_bit_identical(gi):
    grid, st = GRIDS[gi]
    g = CommGraph.from_stencil(grid, st)
    gg, gs = g.grid(), g.slot_stencil()
    n = 6
    sizes = [grid.size // n] * n
    sizes[0] += grid.size - sum(sizes)
    for seed in range(3):
        a = _random_assignment(grid.size, sizes, seed)
        c1 = evaluate(grid, st, a, num_nodes=n, weighted="auto")
        c2 = evaluate(gg, gs, a, num_nodes=n, weighted="auto")
        assert c1.j_sum == c2.j_sum
        assert c1.j_max == c2.j_max
        assert np.array_equal(c1.per_node, c2.per_node)


@pytest.mark.parametrize("gi", range(len(GRIDS)))
def test_round_trip_swap_deltas_identical(gi):
    grid, st = GRIDS[gi]
    g = CommGraph.from_stencil(grid, st)
    n = 4
    sizes = [grid.size // n] * n
    sizes[0] += grid.size - sum(sizes)
    a = _random_assignment(grid.size, sizes, 7)
    ic1 = IncrementalCost(grid, st, a, num_nodes=n, weighted="auto")
    ic2 = IncrementalCost.from_graph(g, a, num_nodes=n)
    assert ic1.j_sum == ic2.j_sum
    assert ic1.j_max == ic2.j_max
    rng = np.random.default_rng(3)
    ps = rng.integers(0, grid.size, size=24)
    qs = rng.integers(0, grid.size, size=24)
    keep = ps != qs
    ps, qs = ps[keep], qs[keep]
    for p, q in zip(ps, qs):
        d1 = ic1.delta_swap(int(p), int(q))
        d2 = ic2.delta_swap(int(p), int(q))
        assert d1.d_j_sum == d2.d_j_sum
        assert np.array_equal(d1.d_count_off, d2.d_count_off)
        assert d1.d_count_node == d2.d_count_node
    b1 = ic1.batch_swap_deltas(ps, qs, with_loads=True)
    b2 = ic2.batch_swap_deltas(ps, qs, with_loads=True)
    assert np.array_equal(b1.d_j_sum, b2.d_j_sum)
    assert np.array_equal(b1.d_count_off, b2.d_count_off)
    assert np.array_equal(b1.new_per_node, b2.new_per_node)
    assert np.array_equal(b1.new_j_max, b2.new_j_max)


def test_round_trip_portfolio_cost_identical():
    grid, st = GRIDS[0]
    g = CommGraph.from_stencil(grid, st)
    n = 4
    sizes = (12, 12, 12, 12)
    A = np.stack([_random_assignment(grid.size, sizes, s) for s in range(3)])
    pc1 = PortfolioCost(grid, st, A, num_nodes=n, weighted="auto")
    pc2 = PortfolioCost.from_graph(g, A, num_nodes=n)
    assert np.array_equal(pc1.j_sum(), pc2.j_sum())
    assert np.array_equal(pc1.j_max(), pc2.j_max())


# ---------------------------------------------------------------------------
# slot decomposition of general graphs


def _random_graph(seed, n=24, m=120, weight_pool=(1.0, 2.0, 5.0)):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, size=m)
    dst = rng.integers(0, n, size=m)
    w = rng.choice(weight_pool, size=m)
    keep = src != dst
    return CommGraph.from_edges(n, src[keep], dst[keep], w[keep])


@pytest.mark.parametrize("seed", range(5))
def test_slot_decomposition_is_sound(seed):
    g = _random_graph(seed)
    covered = {}
    for w, valid, tgt in g.slots():
        srcs = np.nonzero(valid)[0]
        dsts = tgt[srcs]
        # partial permutation: ≤1 out per src (by construction of `valid`)
        # and ≤1 in per dst
        assert len(np.unique(dsts)) == len(dsts)
        for s, d in zip(srcs, dsts):
            assert (int(s), int(d)) not in covered, "edge in two slots"
            covered[(int(s), int(d))] = covered.get((int(s), int(d)), 0) + w
    # the decomposition partitions the coalesced edge set exactly
    expect = {}
    src_of = np.repeat(np.arange(g.n), np.diff(g.indptr))
    for s, d, w in zip(src_of, g.indices, g.weights):
        expect[(int(s), int(d))] = float(w)
    assert covered == expect


def test_graph_evaluate_equals_brute_force_edge_sum():
    g = _random_graph(11)
    node = _random_assignment(g.n, (6, 6, 6, 6), 2)
    c = evaluate(g.grid(), g.slot_stencil(), node, num_nodes=4,
                 weighted="auto")
    src_of = np.repeat(np.arange(g.n), np.diff(g.indptr))
    crossing = node[src_of] != node[g.indices]
    assert c.j_sum == pytest.approx(float(g.weights[crossing].sum()))
    per = np.zeros(4)
    np.add.at(per, node[src_of[crossing]], g.weights[crossing])
    assert c.per_node == pytest.approx(per)


def test_from_edges_canonical_and_hash_stable():
    n, src, dst, w = 8, [1, 3, 1, 5, 1], [2, 4, 2, 0, 6], [1.0, 2.0, 3.0, 1.0, 1.0]
    g1 = CommGraph.from_edges(n, src, dst, w)
    order = [4, 2, 0, 3, 1]
    g2 = CommGraph.from_edges(n, [src[i] for i in order],
                              [dst[i] for i in order],
                              [w[i] for i in order])
    assert np.array_equal(g1.indices, g2.indices)
    assert np.array_equal(g1.weights, g2.weights)
    assert g1.content_hash() == g2.content_hash()
    # duplicate (1, 2) coalesced to weight 4
    assert g1.num_edges == 4
    g3 = CommGraph.from_edges(n, src, dst, [1.0, 2.0, 3.0, 1.0, 2.0])
    assert g3.content_hash() != g1.content_hash()


def test_from_edges_drops_self_loops_and_nonpositive():
    g = CommGraph.from_edges(4, [0, 1, 2, 3], [0, 2, 1, 2],
                             [5.0, 1.0, 0.0, 2.0])
    assert g.num_edges == 2       # self-loop and zero-weight dropped
    with pytest.raises(ValueError):
        CommGraph.from_edges(4, [0], [0], [1.0])   # nothing left


# ---------------------------------------------------------------------------
# grid protocol


def test_graph_grid_protocol():
    g = _random_graph(3)
    gg = g.grid()
    assert gg.dims == (g.n,) and gg.periodic == (False,)
    assert gg.ndim == 1 and gg.size == g.n
    assert gg.coords().shape == (g.n, 1)
    with pytest.raises(ValueError):
        gg.shift_ranks((len(g.slots()) + 1,))


def test_masked_graph_grid_restricts_both_endpoints():
    g = _random_graph(5)
    gg = g.grid()
    mask = np.zeros(g.n, dtype=bool)
    mask[: g.n // 2] = True
    mg = gg.masked(mask)
    assert isinstance(mg, MaskedGraphGrid)
    st = g.slot_stencil()
    for off in st.offsets:
        v0, t0 = gg.shift_ranks(off)
        v1, t1 = mg.shift_ranks(off)
        assert np.array_equal(v1, v0 & mask & mask[t0])
        assert np.array_equal(t0, t1)
    assert mg.cache_token != gg.cache_token


def test_graph_grid_pickles():
    import pickle
    g = _random_graph(1)
    gg2 = pickle.loads(pickle.dumps(g.grid()))
    for off in g.slot_stencil().offsets:
        v1, t1 = g.grid().shift_ranks(off)
        v2, t2 = gg2.shift_ranks(off)
        assert np.array_equal(v1, v2) and np.array_equal(t1, t2)


# ---------------------------------------------------------------------------
# HLO extraction


def _mk_stat(opcode, payload, groups, pairs=None, multiplier=1.0):
    from repro.analysis.hlo import CollectiveStat
    return CollectiveStat(opcode=opcode, name=opcode, computation="main",
                          payload_bytes=payload, result_bytes=payload,
                          groups=groups, pairs=pairs, multiplier=multiplier)


class _FakeModule:
    name = "fake"

    def __init__(self, stats):
        self._stats = stats

    def collectives(self):
        return list(self._stats)


def test_from_hlo_ring_weights_match_wire_bytes():
    c = _mk_stat("all-reduce", 512.0, [[0, 1, 2, 3], [4, 5, 6, 7]],
                 multiplier=3.0)
    g = CommGraph.from_hlo(_FakeModule([c]))
    assert g.n == 8
    wire = c.wire_bytes_per_device()
    out_strength = np.add.reduceat(g.weights, g.indptr[:-1])
    assert out_strength == pytest.approx(np.full(8, wire))
    # ring: each member has exactly one out-edge, to the next member
    assert np.array_equal(np.diff(g.indptr), np.ones(8, dtype=np.int64))
    assert np.array_equal(g.indices, [1, 2, 3, 0, 5, 6, 7, 4])


def test_from_hlo_alltoall_weights_match_wire_bytes():
    c = _mk_stat("all-to-all", 4096.0, [[0, 1, 2, 3]])
    g = CommGraph.from_hlo(_FakeModule([c]), num_devices=4)
    wire = c.wire_bytes_per_device()
    out_strength = np.add.reduceat(g.weights, g.indptr[:-1])
    assert out_strength == pytest.approx(np.full(4, wire))
    assert g.num_edges == 12      # complete directed graph on the group


def test_from_hlo_permute_and_group_none():
    perm = _mk_stat("collective-permute", 100.0, None,
                    pairs=[(0, 1), (1, 2)], multiplier=2.0)
    ar = _mk_stat("all-reduce", 64.0, None)      # groups None = all devices
    g = CommGraph.from_hlo(_FakeModule([perm, ar]), num_devices=4)
    # permute edges at payload * multiplier
    src_of = np.repeat(np.arange(g.n), np.diff(g.indptr))
    w = {(int(s), int(d)): float(wt)
         for s, d, wt in zip(src_of, g.indices, g.weights)}
    ring_w = 2.0 * 64.0 * 3 / 4     # wire at the resolved g=4, not g=2
    assert w[(0, 1)] == pytest.approx(200.0 + ring_w)   # coalesced with ring
    assert w[(1, 2)] == pytest.approx(200.0 + ring_w)
    assert w[(2, 3)] == pytest.approx(ring_w)


def test_from_hlo_parse_text_end_to_end():
    hlo = """
HloModule m

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

ENTRY %main (x: f32[128,256]) -> f32[128,256] {
  %x = f32[128,256]{1,0} parameter(0)
  %ar = f32[128,256]{1,0} all-reduce(%x), channel_id=2, replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
  ROOT %r = f32[128,256]{1,0} copy(%ar)
}
"""
    g = CommGraph.from_hlo(hlo)
    assert g.n == 8
    assert g.num_edges == 8


# ---------------------------------------------------------------------------
# MoE / arch builders


def test_from_moe_group_structure_and_integral_weights():
    g = CommGraph.from_moe("mixtral-8x7b", 16)
    assert g.n == 16
    # EP groups of 8 consecutive devices, complete directed inside
    src_of = np.repeat(np.arange(g.n), np.diff(g.indptr))
    for s, d in zip(src_of, g.indices):
        assert s // 8 == d // 8
    assert g.num_edges == 2 * 8 * 7
    assert np.all(g.weights == np.round(g.weights))
    assert len(np.unique(g.weights)) == 1
    with pytest.raises(ValueError):
        CommGraph.from_moe("yi-34b", 16)          # dense arch: no experts


def test_arch_comm_graph_deterministic_and_integral():
    g1 = arch_comm_graph("qwen3-8b", 32, permute_seed=5)
    g2 = arch_comm_graph("qwen3-8b", 32, permute_seed=5)
    assert g1.content_hash() == g2.content_hash()
    g3 = arch_comm_graph("qwen3-8b", 32, permute_seed=6)
    assert g3.content_hash() != g1.content_hash()
    assert np.all(g1.weights == np.round(g1.weights))


# ---------------------------------------------------------------------------
# hier on graphs


def test_hier_on_graph_bijection_and_never_worse():
    g = arch_comm_graph("mixtral-8x7b", 32, permute_seed=3)
    sizes = (4,) * 8
    prob = MappingProblem.from_graph(g, sizes)
    base = parse_plan("graphgreedy").solve(prob)
    hier = parse_plan("hier:graphgreedy").solve(prob)
    assert np.array_equal(np.bincount(hier.assignment, minlength=8),
                          np.asarray(sizes))
    assert (hier.j_max, hier.j_sum) <= (base.j_max, base.j_sum)
