"""Refinement subsystem: incremental-cost parity, refiner invariants, and
the refined:<base> quality regression on the paper's stencils.

Parity is exact — IncrementalCost keeps integer crossing counts and
reconstructs floats in evaluate()'s accumulation order, so == (not isclose)
is the right assertion for unit weights.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline: deterministic shim
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import (CartGrid, IncrementalCost, MapperInapplicable,
                        RefinedMapper, Stencil, SwapRefiner, dims_create,
                        device_layout, evaluate, get_mapper, layout_cost,
                        node_of_rank_blocked, refine_assignment)
from repro.core.mapping import MAPPERS, available_mappers, check_bijection

STENCILS = {
    "nn": Stencil.nearest_neighbor,
    "comp": Stencil.component,
    "hops": Stencil.nn_with_hops,
}


def random_instance(rng, d=None, max_nodes=6):
    d = d or int(rng.integers(1, 4))
    dims = tuple(int(rng.integers(2, 6)) for _ in range(d))
    periodic = tuple(bool(rng.integers(2)) for _ in range(d))
    grid = CartGrid(dims, periodic=periodic)
    n_nodes = int(rng.integers(2, max_nodes + 1))
    node_of_pos = rng.integers(0, n_nodes, size=grid.size)
    return grid, n_nodes, node_of_pos


# ---------------------------------------------------------------------------
# IncrementalCost parity with full evaluate()
@given(st.integers(0, 10_000), st.sampled_from(sorted(STENCILS)))
@settings(max_examples=100, deadline=None)
def test_incremental_matches_evaluate_after_random_edits(seed, sname):
    """100+ randomized (grid, stencil, mapping) cases: state after arbitrary
    moves+swaps equals a fresh evaluate() bit-for-bit."""
    rng = np.random.default_rng(seed)
    grid, n_nodes, node_of_pos = random_instance(rng)
    stencil = STENCILS[sname](grid.ndim)
    ic = IncrementalCost(grid, stencil, node_of_pos, num_nodes=n_nodes)

    c0 = evaluate(grid, stencil, node_of_pos, num_nodes=n_nodes)
    assert ic.j_sum == c0.j_sum
    assert ic.j_max == c0.j_max
    assert np.array_equal(ic.per_node, c0.per_node)

    for _ in range(15):
        if rng.integers(2):
            p, q = rng.integers(0, grid.size, size=2)
            ic.apply_swap(int(p), int(q))
        else:
            ic.apply_move(int(rng.integers(grid.size)),
                          int(rng.integers(n_nodes)))
    c1 = evaluate(grid, stencil, ic.node_of_pos, num_nodes=n_nodes)
    assert ic.j_sum == c1.j_sum
    assert ic.j_max == c1.j_max
    assert np.array_equal(ic.per_node, c1.per_node)


@given(st.integers(0, 10_000))
@settings(max_examples=40, deadline=None)
def test_delta_predicts_applied_change(seed):
    """delta_swap/delta_move preview exactly the committed change."""
    rng = np.random.default_rng(seed)
    grid, n_nodes, node_of_pos = random_instance(rng)
    stencil = Stencil.nearest_neighbor(grid.ndim)
    ic = IncrementalCost(grid, stencil, node_of_pos, num_nodes=n_nodes)

    p, q = (int(x) for x in rng.integers(0, grid.size, size=2))
    before = ic.j_sum
    predicted = ic.delta_swap(p, q)
    peek = ic.peek_per_node(predicted)
    ic.apply_swap(p, q)
    assert ic.j_sum == before + predicted.d_j_sum
    assert np.array_equal(ic.per_node, peek)

    pos, node = int(rng.integers(grid.size)), int(rng.integers(n_nodes))
    before = ic.j_sum
    predicted = ic.delta_move(pos, node)
    ic.apply_move(pos, node)
    assert ic.j_sum == before + predicted.d_j_sum


def test_incremental_weighted_matches_evaluate():
    grid = CartGrid((6, 5))
    stencil = Stencil(((1, 0), (-1, 0), (0, 1), (0, -1)),
                      weights=(4.0, 4.0, 1.0, 1.0))
    rng = np.random.default_rng(7)
    node_of_pos = rng.integers(0, 3, size=grid.size)
    ic = IncrementalCost(grid, stencil, node_of_pos, num_nodes=3,
                         weighted=True)
    for _ in range(25):
        ic.apply_swap(int(rng.integers(grid.size)),
                      int(rng.integers(grid.size)))
    c = evaluate(grid, stencil, ic.node_of_pos, num_nodes=3, weighted=True)
    assert ic.j_sum == c.j_sum
    np.testing.assert_allclose(ic.per_node, c.per_node, rtol=0, atol=1e-9)


def test_incremental_rejects_bad_shapes():
    grid = CartGrid((4, 4))
    stencil = Stencil.nearest_neighbor(2)
    with pytest.raises(ValueError):
        IncrementalCost(grid, stencil, np.zeros(7, dtype=np.int64))
    ic = IncrementalCost(grid, stencil, np.zeros(16, dtype=np.int64),
                         num_nodes=2)
    with pytest.raises(ValueError):
        ic.delta_move(0, 5)


# ---------------------------------------------------------------------------
# SwapRefiner invariants
@given(st.integers(0, 10_000), st.sampled_from(["j_sum", "j_max"]),
       st.sampled_from(["first", "steepest"]))
@settings(max_examples=25, deadline=None)
def test_refiner_monotonic_and_cardinality_preserving(seed, objective, policy):
    rng = np.random.default_rng(seed)
    grid, n_nodes, node_of_pos = random_instance(rng, max_nodes=4)
    stencil = Stencil.nearest_neighbor(grid.ndim)
    refiner = SwapRefiner(objective=objective, policy=policy, max_passes=3)
    res = refiner.refine(grid, stencil, node_of_pos, num_nodes=n_nodes)
    # objective never increases
    assert res.final.j_sum <= res.initial.j_sum or objective == "j_max"
    if objective == "j_max":
        assert (res.final.j_max, res.final.j_sum) \
            <= (res.initial.j_max, res.initial.j_sum)
    # swaps preserve per-node cardinalities exactly
    np.testing.assert_array_equal(
        np.bincount(res.assignment, minlength=n_nodes),
        np.bincount(node_of_pos, minlength=n_nodes))
    # reported final cost is truthful
    check = evaluate(grid, stencil, res.assignment, num_nodes=n_nodes)
    assert check.j_sum == res.final.j_sum
    assert check.j_max == res.final.j_max


def test_refiner_fixpoint_on_optimal_blocked_strips():
    """An already-optimal strip partition admits no improving swap."""
    grid = CartGrid((8, 8))
    stencil = Stencil.nearest_neighbor(2)
    node_of_pos = get_mapper("stencil_strips").assignment(grid, stencil,
                                                          [16] * 4)
    res = refine_assignment(grid, stencil, node_of_pos, num_nodes=4)
    assert res.swaps == 0
    np.testing.assert_array_equal(res.assignment, node_of_pos)


def test_refiner_max_swaps_cap():
    rng = np.random.default_rng(3)
    grid = CartGrid((8, 8))
    stencil = Stencil.nearest_neighbor(2)
    node_of_pos = rng.permutation(np.repeat(np.arange(4), 16))
    res = SwapRefiner(max_swaps=2).refine(grid, stencil, node_of_pos,
                                          num_nodes=4)
    assert res.swaps <= 2


def test_refiner_validates_config():
    with pytest.raises(ValueError):
        SwapRefiner(objective="nope")
    with pytest.raises(ValueError):
        SwapRefiner(policy="nope")
    with pytest.raises(ValueError):
        SwapRefiner(max_passes=0)


# ---------------------------------------------------------------------------
# RefinedMapper integration
def test_refined_prefix_resolves_for_every_mapper():
    for name in sorted(MAPPERS):
        m = get_mapper(f"refined:{name}")
        assert isinstance(m, RefinedMapper)
        assert m.name == f"refined:{name}"
    assert f"refined:{sorted(MAPPERS)[0]}" in available_mappers()
    with pytest.raises(KeyError):
        get_mapper("refined:doesnotexist")


@pytest.mark.parametrize("d,dims,sizes", [
    (2, (10, 8), [16] * 5),           # 2D 5-point
    (3, (6, 4, 4), [16] * 6),         # 3D 7-point
])
def test_refined_no_worse_than_base_on_paper_stencils(d, dims, sizes):
    """refined:<base> J_sum <= base for every registered mapper on the 2D
    5-point and 3D 7-point stencils (acceptance criterion)."""
    grid = CartGrid(dims)
    stencil = Stencil.nearest_neighbor(d)
    for name in sorted(MAPPERS):
        try:
            base_cost = get_mapper(name).cost(grid, stencil, sizes)
        except MapperInapplicable:
            continue
        refined = get_mapper(f"refined:{name}")
        ref_cost = refined.cost(grid, stencil, sizes)
        assert ref_cost.j_sum <= base_cost.j_sum, (name, d)
        coords = refined.coords(grid, stencil, sizes)
        check_bijection(coords, grid.dims)


def test_refined_nodecart_regression():
    """refined:nodecart <= nodecart on the paper's stencil fixtures."""
    for d, dims, sizes in [(2, (8, 8), [16] * 4), (3, (8, 8, 8), [64] * 8)]:
        grid = CartGrid(dims)
        stencil = Stencil.nearest_neighbor(d)
        jb = get_mapper("nodecart").cost(grid, stencil, sizes).j_sum
        jr = get_mapper("refined:nodecart").cost(grid, stencil, sizes).j_sum
        assert jr <= jb


def test_refined_improves_random_substantially():
    grid = CartGrid((12, 12))
    stencil = Stencil.nearest_neighbor(2)
    sizes = [16] * 9
    jb = get_mapper("random").cost(grid, stencil, sizes).j_sum
    jr = get_mapper("refined:random").cost(grid, stencil, sizes).j_sum
    assert jr < jb  # local search must find at least one improving swap


def test_refined_respects_blocked_allocation():
    grid = CartGrid((6, 8))
    stencil = Stencil.nn_with_hops(2)
    sizes = [10, 14, 12, 12]  # heterogeneous
    m = get_mapper("refined:hyperplane")
    a = m.assignment(grid, stencil, sizes)
    np.testing.assert_array_equal(np.bincount(a, minlength=4), sizes)
    # the bijection places node i's ranks exactly on node i's positions
    coords = m.coords(grid, stencil, sizes)
    flat = np.ravel_multi_index(tuple(coords.T), grid.dims)
    owner = node_of_rank_blocked(sizes)
    np.testing.assert_array_equal(a[flat], owner)


def test_refined_through_device_layout_string_name():
    """remap accepts mapper names, including refined:<base>."""
    stencil = Stencil.nearest_neighbor(2)
    sizes = [16, 16, 16, 16]
    L_base = device_layout("random", (8, 8), stencil, sizes)
    L_ref = device_layout("refined:random", (8, 8), stencil, sizes)
    cb = layout_cost(L_base, stencil, sizes)
    cr = layout_cost(L_ref, stencil, sizes)
    assert sorted(L_ref.reshape(-1)) == list(range(64))
    assert cr.j_sum <= cb.j_sum
