"""Interactive explorer for the paper's mapping algorithms: pick an
instance, see every algorithm's J_sum/J_max, runtime, and an ASCII picture
of the node assignment (2-d grids).

Run:  PYTHONPATH=src python examples/remap_explorer.py --nodes 6 --ppn 8 \
          --stencil nn_with_hops
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.core import (CartGrid, MapperInapplicable, Stencil, dims_create,
                        get_mapper)

GLYPHS = "0123456789abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ"
STENCILS = {"nearest_neighbor": Stencil.nearest_neighbor,
            "nn_with_hops": Stencil.nn_with_hops,
            "component": Stencil.component}


def picture(grid, assignment):
    if grid.ndim != 2:
        return "(picture only for 2-d grids)"
    a = assignment.reshape(grid.dims)
    return "\n".join("".join(GLYPHS[v % len(GLYPHS)] for v in row)
                     for row in a)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=6)
    ap.add_argument("--ppn", type=int, default=8)
    ap.add_argument("--dims", type=int, default=2)
    ap.add_argument("--stencil", default="nearest_neighbor",
                    choices=sorted(STENCILS))
    ap.add_argument("--show", default="stencil_strips",
                    help="algorithm to draw (or 'all')")
    ap.add_argument("--refine", action="store_true",
                    help="also show each algorithm's swap-refined variant")
    ap.add_argument("--refine-prefix", default="refined",
                    choices=["refined", "refined2", "annealed", "portfolio"],
                    help="which refinement engine --refine compares")
    args = ap.parse_args()

    grid = CartGrid(dims_create(args.nodes * args.ppn, args.dims))
    stencil = STENCILS[args.stencil](args.dims)
    sizes = [args.ppn] * args.nodes
    print(f"grid {grid.dims}, stencil {args.stencil} (k={stencil.k}), "
          f"{args.nodes} nodes x {args.ppn}\n")
    print(f"{'algorithm':24s} {'J_sum':>8s} {'J_max':>8s} {'time':>10s}")
    results = {}
    algos = ["blocked", "hyperplane", "kdtree", "stencil_strips",
             "nodecart", "graphgreedy", "random"]
    if args.refine:
        algos += [f"{args.refine_prefix}:{a}" for a in algos]

    def make_mapper(name):
        # same base config in the bare and refined rows (graphgreedy's
        # max_passes would otherwise go to the refiner, not the base)
        if ":" in name:
            from repro.core import (PortfolioRefiner, RefinedMapper,
                                    ScheduledRefiner)
            prefix, base = name.split(":", 1)
            if prefix == "refined":
                refiner = None
            elif prefix == "portfolio":
                refiner = PortfolioRefiner(k=4)
            else:
                refiner = ScheduledRefiner(anneal=(prefix == "annealed"))
            return RefinedMapper(make_mapper(base), refiner=refiner,
                                 prefix=prefix)
        return (get_mapper(name, max_passes=4) if name == "graphgreedy"
                else get_mapper(name))

    for algo in algos:
        mapper = make_mapper(algo)
        t0 = time.perf_counter()
        try:
            assignment = mapper.assignment(grid, stencil, sizes)
        except MapperInapplicable as e:
            print(f"{algo:24s} {'n/a':>8s} {'n/a':>8s}  ({e})")
            continue
        dt = time.perf_counter() - t0
        from repro.core import evaluate
        c = evaluate(grid, stencil, assignment, num_nodes=args.nodes)
        results[algo] = assignment
        print(f"{algo:24s} {c.j_sum:8.0f} {c.j_max:8.0f} {dt*1e6:8.0f}us")

    to_show = list(results) if args.show == "all" else [args.show]
    for algo in to_show:
        if algo in results:
            print(f"\n{algo}:")
            print(picture(grid, results[algo]))


if __name__ == "__main__":
    main()
