"""The paper's application domain, end to end: a distributed 2-d Jacobi
stencil solve with halo exchange, on a *mapped* device mesh.

This script runs with 8 XLA host devices (set below, before jax imports —
this is an example launcher, like dryrun.py) arranged as 2 "nodes" x 4
"cores".  It:

  1. computes the process-to-node mapping with a paper algorithm and builds
     the jax Mesh from the permuted device array (MPI_Cart_create reorder);
  2. runs Jacobi iterations under shard_map, exchanging halos with
     jax.lax.ppermute — the MPI_Neighbor_alltoall analog;
  3. applies the local stencil update with the Pallas kernel
     (interpret mode on CPU) or the jnp reference;
  4. checks the distributed result against a single-array oracle and prints
     the J_sum/J_max table for the chosen vs blocked layout.

Run:  PYTHONPATH=src python examples/stencil_jacobi.py --mapper stencil_strips
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import (CartGrid, Stencil, get_mapper, layout_cost,
                        mapped_device_array)

MESH_SHAPE = (4, 2)      # logical process grid
CHIPS_PER_NODE = 4       # 8 devices = 2 "nodes" of 4


def halo_pad(u, axis_name, size, axis):
    """Exchange one-deep halos along a mesh axis (non-periodic)."""
    n = size
    fwd = [(i, i + 1) for i in range(n - 1)]
    bwd = [(i, i - 1) for i in range(1, n)]
    last = jax.lax.slice_in_dim(u, u.shape[axis] - 1, u.shape[axis], axis=axis)
    first = jax.lax.slice_in_dim(u, 0, 1, axis=axis)
    from_left = jax.lax.ppermute(last, axis_name, fwd)
    from_right = jax.lax.ppermute(first, axis_name, bwd)
    return jnp.concatenate([from_left, u, from_right], axis=axis)


def jacobi_step_local(u_halo, weights):
    H = u_halo.shape[0] - 2
    W = u_halo.shape[1] - 2
    c, n_, s_, w_, e_ = weights
    return (c * u_halo[1:-1, 1:-1] + n_ * u_halo[:-2, 1:-1]
            + s_ * u_halo[2:, 1:-1] + w_ * u_halo[1:-1, :-2]
            + e_ * u_halo[1:-1, 2:])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mapper", default="stencil_strips")
    ap.add_argument("--size", type=int, default=256)
    ap.add_argument("--iters", type=int, default=10)
    args = ap.parse_args()

    stencil = Stencil.nearest_neighbor(2)
    weights = (0.4, 0.15, 0.15, 0.15, 0.15)

    # 1. mapped mesh (the paper's reorder step)
    devs = mapped_device_array(jax.devices(), get_mapper(args.mapper),
                               MESH_SHAPE, stencil, CHIPS_PER_NODE)
    mesh = Mesh(devs, ("x", "y"))

    # mapping quality vs blocked
    sizes = [CHIPS_PER_NODE] * (8 // CHIPS_PER_NODE)
    print(f"{'layout':16s} {'J_sum':>8s} {'J_max':>8s}")
    for algo in ("blocked", args.mapper, "random"):
        from repro.core import device_layout
        L = device_layout(get_mapper(algo), MESH_SHAPE, stencil, sizes)
        c = layout_cost(L, stencil, sizes)
        print(f"{algo:16s} {c.j_sum:8.0f} {c.j_max:8.0f}")

    # 2-3. distributed Jacobi under shard_map
    n = args.size
    u0 = jnp.zeros((n, n), jnp.float32).at[n // 2, n // 2].set(1000.0)

    def step(u):
        u = halo_pad(u, "x", MESH_SHAPE[0], 0)
        u = halo_pad(u, "y", MESH_SHAPE[1], 1)
        return jacobi_step_local(u, weights)

    dist_step = shard_map(step, mesh=mesh, in_specs=P("x", "y"),
                          out_specs=P("x", "y"))

    @jax.jit
    def run_dist(u):
        for _ in range(args.iters):
            u = dist_step(u)
        return u

    u = jax.device_put(u0, NamedSharding(mesh, P("x", "y")))
    out = np.asarray(run_dist(u))

    # 4. oracle: single-array iteration
    ref = np.asarray(u0)
    for _ in range(args.iters):
        pad = np.pad(ref, 1)
        ref = (weights[0] * pad[1:-1, 1:-1] + weights[1] * pad[:-2, 1:-1]
               + weights[2] * pad[2:, 1:-1] + weights[3] * pad[1:-1, :-2]
               + weights[4] * pad[1:-1, 2:])
    err = np.abs(out - ref).max()
    print(f"\ndistributed Jacobi x{args.iters} on {MESH_SHAPE} mesh "
          f"({args.mapper} layout): max|err| vs oracle = {err:.2e}")
    assert err < 1e-4, "distributed result diverged from oracle"
    print("OK")


if __name__ == "__main__":
    main()
