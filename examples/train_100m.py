"""End-to-end training driver (deliverable b): a ~100M-param model for a few
hundred steps through the full stack — synthetic data pipeline, AdamW,
checkpoint/rotate/resume, fault injection, straggler monitor.

Presets:
  cpu30m  (default)  ~31M params, CPU-friendly: a few hundred steps in
                     minutes (what EXPERIMENTS.md records);
  mamba130m          the real assigned mamba2-130m (~130M): same driver,
                     slower per step on CPU — use --steps 30 for a smoke run;
  full               any --arch at published size (for real accelerators).

Run:  PYTHONPATH=src python examples/train_100m.py --steps 300
"""
import argparse
import dataclasses
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.configs import get_arch
from repro.configs.base import ArchConfig, ShapeSpec
from repro.data.synthetic import DataConfig
from repro.optim import AdamWConfig
from repro.runtime import FaultInjector, Trainer

CPU30M = ArchConfig(
    name="dense-31m", family="dense", n_layers=8, d_model=512, n_heads=8,
    n_kv_heads=4, d_ff=1536, vocab=8192, param_dtype="f32",
    compute_dtype="f32", remat="none", source="cpu demo preset")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="cpu30m",
                    choices=["cpu30m", "mamba130m", "full"])
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None,
                    help="default: runs/train_100m/<preset>")
    ap.add_argument("--inject-fault", default="",
                    help='e.g. "120:step_crash"')
    args = ap.parse_args()

    if args.preset == "cpu30m":
        cfg = CPU30M
    elif args.preset == "mamba130m":
        cfg = get_arch("mamba2-130m")
    else:
        cfg = get_arch(args.arch)
    cfg = dataclasses.replace(cfg, microbatches=1)
    if args.ckpt_dir is None:
        args.ckpt_dir = f"runs/train_100m/{args.preset}-{cfg.name}"

    from repro.models import lm
    n_params = sum(s.size for s in lm.param_specs(cfg).values())
    shape = ShapeSpec("e2e", seq_len=args.seq, global_batch=args.batch,
                      kind="train")
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M tokens/step="
          f"{args.batch * args.seq}")

    schedule = {}
    for item in args.inject_fault.split(","):
        if item:
            s, kind = item.split(":", 1)
            schedule[int(s)] = kind
    tr = Trainer(cfg, shape,
                 opt_cfg=AdamWConfig(lr=args.lr,
                                     warmup_steps=max(args.steps // 20, 1),
                                     total_steps=args.steps),
                 data_cfg=DataConfig(mode="memorize", corpus_len=4096),
                 ckpt_dir=args.ckpt_dir, ckpt_every=50,
                 fault=FaultInjector(schedule=schedule))
    t0 = time.time()
    res = tr.run(args.steps)
    dt = time.time() - t0
    toks = res.steps_done * args.batch * args.seq
    curve = {s: round(res.losses[s], 4)
             for s in range(0, len(res.losses), max(len(res.losses) // 10, 1))}
    print(json.dumps({
        "params_m": round(n_params / 1e6, 1),
        "steps": res.steps_done, "wall_s": round(dt, 1),
        "tokens_per_s": round(toks / dt, 1),
        "loss_first": round(res.losses[0], 4) if res.losses else None,
        "loss_last": round(res.final_loss, 4) if res.losses else None,
        "loss_curve": curve,
        "restarts": res.restarts}, indent=1))


if __name__ == "__main__":
    main()
