"""Quickstart: the two things this framework does.

1. Train a (reduced) assigned architecture with the fault-tolerant driver.
2. Compute a topology-aware process-to-node mapping (the paper's
   contribution) for the production mesh and show the inter-pod traffic it
   saves vs the blocked layout.

Run:  PYTHONPATH=src python examples/quickstart.py [--arch qwen3-8b]
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.configs import SHAPES, get_arch
from repro.configs.base import ShapeSpec
from repro.core import device_layout, get_mapper, layout_cost
from repro.data.synthetic import DataConfig
from repro.launch.mesh import stencil_for_plan
from repro.optim import AdamWConfig
from repro.runtime import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--steps", type=int, default=40)
    args = ap.parse_args()

    # -- 1. train a reduced config on CPU ---------------------------------
    cfg = get_arch(args.arch).reduced()
    shape = ShapeSpec("quickstart", seq_len=32, global_batch=8, kind="train")
    print(f"training {cfg.name} ({cfg.family}) for {args.steps} steps ...")
    tr = Trainer(cfg, shape,
                 opt_cfg=AdamWConfig(lr=3e-3, warmup_steps=5,
                                     total_steps=args.steps),
                 data_cfg=DataConfig(mode="memorize", corpus_len=128))
    res = tr.run(args.steps)
    print(f"  loss {res.losses[0]:.3f} -> {res.final_loss:.3f} "
          f"({res.steps_done} steps)")

    # -- 2. map the production mesh ----------------------------------------
    full = get_arch(args.arch)
    stencil = stencil_for_plan(full, SHAPES["train_4k"], multi_pod=True)
    sizes = [256, 256]          # 2 pods x 256 chips
    print(f"\nmapping the (pod=2, data=16, model=16) mesh for {full.name}:")
    print(f"{'algorithm':22s} {'edges x-pod':>12s} {'bytes x-pod':>14s}")
    algos = [("blocked", get_mapper("blocked")),
             ("hyperplane", get_mapper("hyperplane")),
             ("hyperplane+bytes", get_mapper("hyperplane", weighted=True)),
             ("kdtree", get_mapper("kdtree")),
             ("kdtree+bytes", get_mapper("kdtree", weighted=True)),
             ("stencil_strips", get_mapper("stencil_strips")),
             ("random", get_mapper("random"))]
    for name, mapper in algos:
        L = device_layout(mapper, (2, 16, 16), stencil, sizes)
        edges = layout_cost(L, stencil, sizes).j_sum
        bytes_ = layout_cost(L, stencil, sizes, weighted=True).j_sum
        print(f"{name:22s} {edges:12.0f} {bytes_:14.3e}")
    print("\n('+bytes' = our byte-weighted extension of the paper's unit-"
          "weight algorithms;\n lower bytes = less inter-pod traffic — see "
          "EXPERIMENTS.md §Perf)")


if __name__ == "__main__":
    main()
