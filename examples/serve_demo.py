"""Batched serving demo (deliverable b): continuous batching over a fixed
slot pool — admit, decode all active slots each step, free on completion.

Run:  PYTHONPATH=src python examples/serve_demo.py --arch qwen3-8b --requests 8
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import numpy as np

from repro.configs import get_arch
from repro.models import lm
from repro.runtime import Request, ServeLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    params = lm.init(cfg, jax.random.PRNGKey(0))
    loop = ServeLoop(cfg, params, batch_slots=args.slots, max_len=96)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, size=6 + i % 4,
                                        dtype=np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    loop.run(reqs)
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in reqs)
    print(f"{cfg.name}: {len(reqs)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s, {args.slots} slots)")
    for r in reqs[:3]:
        print(f"  req{r.rid}: prompt={r.prompt.tolist()} -> {r.out_tokens}")


if __name__ == "__main__":
    main()
