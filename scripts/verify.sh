#!/usr/bin/env bash
# Tier-1 verify: fast test suite + a smoke run of the refinement benchmark.
# No PYTHONPATH needed — pytest.ini sets pythonpath=src, and the benchmark
# is invoked with an explicit PYTHONPATH below.
#
#   scripts/verify.sh          # tier-1 (default, < ~2 min)
#   scripts/verify.sh --slow   # additionally run the -m slow tests
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pytest -x -q

if [[ "${1:-}" == "--slow" ]]; then
    python -m pytest -q -m slow
fi

# batched-engine parity + scheduled-refiner/portfolio invariants and the
# elastic re-mesh + linksim replay integration modules, run explicitly so a
# collection failure elsewhere can't mask a refinement regression
python -m pytest -q tests/test_refine_batch.py tests/test_portfolio.py \
    tests/test_elastic_remesh.py tests/test_linksim_replay.py

# smoke the whole refinement registry (refined: / refined2: / annealed: /
# portfolio:) incl. the linksim replay columns; the full K=8 sweep is the
# `-m slow` acceptance test (test_portfolio_k8_acceptance_on_suite_ragged_rows)
PYTHONPATH=src python -m benchmarks.refine_suite --tiny --linksim \
    --variants refined,refined2,annealed,portfolio[k=4]
echo "verify OK"
