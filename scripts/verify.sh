#!/usr/bin/env bash
# Tier-1 verify: fast test suite + a smoke run of the refinement benchmark.
# No PYTHONPATH needed — pytest.ini sets pythonpath=src, and the benchmark
# is invoked with an explicit PYTHONPATH below.
#
#   scripts/verify.sh          # tier-1 (default, < ~2 min)
#   scripts/verify.sh --slow   # additionally run the -m slow tests
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pytest -x -q

if [[ "${1:-}" == "--slow" ]]; then
    python -m pytest -q -m slow
fi

# batched-engine parity + scheduled-refiner/portfolio invariants, the
# sharded-portfolio engine (shard invariance, adaptive control, cache
# hardening), the elastic re-mesh + linksim replay integration modules,
# and the plan-layer contract (grammar<->plan parity, PlanCache,
# cart_create), run explicitly so a collection failure elsewhere can't
# mask a refinement regression
python -m pytest -q tests/test_refine_batch.py tests/test_portfolio.py \
    tests/test_sharded_portfolio.py \
    tests/test_run_temperature_props.py tests/test_device_portfolio.py \
    tests/test_elastic_remesh.py tests/test_linksim_replay.py \
    tests/test_plan.py tests/test_repair.py \
    tests/test_hier.py tests/test_topology_tree.py tests/test_serving.py \
    tests/test_graph.py tests/test_graph_plan.py \
    tests/test_cost_weight_parity.py tests/test_single_flight.py

# smoke the whole refinement registry (refined: / refined2: / annealed: /
# portfolio: / sharded:) incl. the linksim replay columns (ragged rows
# replay on per-pod torus sizes) and the matching-K sharded claim
# (bit-identity / adaptive superset); the full K=8 sweep is the `-m slow`
# acceptance test (test_portfolio_k8_acceptance_on_suite_ragged_rows)
PYTHONPATH=src python -m benchmarks.refine_suite --tiny --linksim \
    --variants "refined,refined2,annealed,portfolio[k=4],sharded[shards=2,k=4,restarts=auto]"

# the K-scaling claim, focused so it stays offline-sized: 4x the starts
# (K=32 sharded across 2 worker processes vs K=8 single-process) must cost
# < 4x the wall-time while never worsening (J_max, J_sum) vs annealed —
# run on the 16x28 ragged suite instance, where per-temperature work is
# chunky enough for the mp backend to amortize IPC
PYTHONPATH=src python -m benchmarks.refine_suite --instances 16x28 \
    --stencils hops --mappers hyperplane,random \
    --variants "annealed,portfolio[k=8],sharded[shards=2,k=32,restarts=auto,backend=mp]"

# sharded smoke: shard-count invariance of the grammar spelling — the
# sharded engine must be bit-identical to the single-process portfolio
PYTHONPATH=src python - <<'EOF'
import numpy as np
from repro.core import CartGrid, Stencil, get_mapper

grid, stencil, sizes = CartGrid((6, 8)), Stencil.nearest_neighbor(2), \
    [16, 16, 10, 6]
ref = get_mapper("portfolio[k=4]:hyperplane").assignment(grid, stencil,
                                                         sizes)
sh = get_mapper("sharded[shards=2,k=4]:hyperplane").assignment(grid,
                                                               stencil,
                                                               sizes)
np.testing.assert_array_equal(sh, ref)
print("sharded smoke OK: sharded[shards=2,k=4] == portfolio[k=4] bit-exact")
EOF

# device-portfolio suite: dominance vs the serial portfolio at equal
# proposal budget over the base-mapper matrix, plus the K-scaling sweep
# (K=1024 under 4x the K=8 wall-time at fixed budget) — exit 1 on any
# FAIL — and the machine-readable BENCH_7.json perf snapshot.
# JAX_PLATFORM_NAME=cpu keeps the run offline-reproducible.
mkdir -p results
JAX_PLATFORM_NAME=cpu PYTHONPATH=src python -m benchmarks.refine_suite \
    --device --json results/BENCH_7.json

# device smoke: the device: grammar spelling end to end — integer-exact
# count state, deterministic, sizes preserved, no host fallback
JAX_PLATFORM_NAME=cpu PYTHONPATH=src python - <<'EOF'
import numpy as np
from repro.core import CartGrid, Stencil, evaluate, get_mapper

grid, stencil, sizes = CartGrid((6, 8)), Stencil.nearest_neighbor(2), \
    [16, 16, 10, 6]
vm = get_mapper("device[k=4,sa_moves=40]:hyperplane")
a1 = vm.assignment(grid, stencil, sizes)
stats = vm.last_result.stats
assert stats["backend"].startswith("device["), stats["backend"]
assert np.bincount(a1, minlength=4).tolist() == sizes
a2 = get_mapper("device[k=4,sa_moves=40]:hyperplane").assignment(
    grid, stencil, sizes)
np.testing.assert_array_equal(a1, a2)
c = evaluate(grid, stencil, a1, num_nodes=4)
print(f"device smoke OK: backend={stats['backend']} "
      f"J=(max {c.j_max:.0f}, sum {c.j_sum:.0f}) "
      f"proposals={stats['proposals']}")
EOF

# hierarchical mapping suite: hier-vs-flat-portfolio on the 4096-chip
# 2-level machine (J_max within 5% at <= 25% of the wall-time) + the
# depth sweep vs blocked (strict J_sum win at every depth) — exit 1 on
# any FAIL — and the machine-readable BENCH_8.json perf snapshot
mkdir -p results
PYTHONPATH=src python -m benchmarks.refine_suite --hier \
    --json results/BENCH_8.json

# hier smoke: the hier: grammar spelling end to end — recursive restricted
# solves, subtree-cache hits on an identical re-mesh, sizes preserved
PYTHONPATH=src python - <<'EOF'
import numpy as np
from repro.core import CartGrid, Stencil, evaluate, get_mapper
from repro.core.refine import hier_subtree_cache

grid, stencil, sizes = CartGrid((8, 8)), Stencil.nearest_neighbor(2), \
    [16] * 4
hier_subtree_cache().clear()
vm = get_mapper("hier:hyperplane")
a1 = vm.assignment(grid, stencil, sizes)
stats = vm.last_result.stats
assert stats["backend"].startswith("hier["), stats["backend"]
assert stats["solves"] >= 1 and stats["cache_hits"] == 0
assert np.bincount(a1, minlength=4).tolist() == sizes
a2 = get_mapper("hier:hyperplane").assignment(grid, stencil, sizes)
np.testing.assert_array_equal(a1, a2)      # warm re-mesh: pure cache hits
c = evaluate(grid, stencil, a1, num_nodes=4)
print(f"hier smoke OK: backend={stats['backend']} "
      f"J=(max {c.j_max:.0f}, sum {c.j_sum:.0f}) "
      f"solves={stats['solves']} cache={hier_subtree_cache().stats()}")
EOF

# warm-start repair suite: repair-vs-cold on the loss/add/slow churn
# scenarios — quality within 5% on (J_max, J_sum), wall-time <= 50% of the
# cold elastic solve, warm path only (exit 1 on any FAIL) — and the
# machine-readable BENCH_6.json perf snapshot
mkdir -p results
PYTHONPATH=src python -m benchmarks.refine_suite --repair \
    --json results/BENCH_6.json

# repair smoke: monitor-driven slow-pod flow — down-weighted warm repair
# from a served solution, cached under the survivor signature
PYTHONPATH=src python - <<'EOF'
import numpy as np
from repro.core import (MappingProblem, PlanCache, Stencil,
                        elastic_portfolio_plan, repair_layout)
from repro.core.repair import downweighted_node_sizes

cache = PlanCache()
stencil = Stencil.nearest_neighbor(2)
prev = elastic_portfolio_plan().solve(
    MappingProblem((6, 8), stencil, (8,) * 6), cache)
dw = downweighted_node_sizes((8,) * 6, 4, 2.0)
rep = repair_layout(prev, dw, cache=cache)
assert not rep.from_cache
assert np.bincount(rep.assignment, minlength=6).tolist() == dw
st = rep.stage_stats[0]
assert st["kind"] == "repair" and not st["used_fallback"]
again = repair_layout(prev, dw, cache=cache)
assert again.from_cache and again.key() == rep.key()
print(f"repair smoke OK: J=(max {rep.j_max:.0f}, sum {rep.j_sum:.0f}) "
      f"pinned={st['pinned']} swaps={st['swaps']} cache={cache.stats()}")
EOF

# serving suite: resident persistent-worker engine bit-identical to the
# stateless sharded engine, measured per-boundary IPC >= 10x smaller,
# warm served cart_create p50 <= 0.1x cold, anytime valid within deadline
# at J_max <= 1.2x (exit 1 on any FAIL) — and the machine-readable
# BENCH_9.json perf snapshot
mkdir -p results
PYTHONPATH=src python -m benchmarks.serve_suite --json results/BENCH_9.json

# serve smoke: start server -> warm-up sweep over the topology registry ->
# concurrent submits (mixed warm/cold) -> anytime deadline hit on a fresh
# problem -> clean shutdown with no orphaned worker processes
PYTHONPATH=src python - <<'EOF'
import multiprocessing as mp
import numpy as np
from repro.core.plan import MappingProblem
from repro.core.stencil import Stencil
from repro.serving import PlanClient, PlanServer

plan = "sharded[shards=2,k=4,restarts=auto]:hyperplane"
with PlanServer(threads=2, shard_workers=2, default_plan=plan) as srv:
    warm = srv.warm_up()
    assert warm["swept"] >= 2, warm
    cli = PlanClient(srv)
    tickets = [cli.cart_create_async((6, 8), node_sizes=(16, 16, 10, 6))
               for _ in range(6)]
    results = [t.result(timeout=300) for t in tickets]
    for r in results[1:]:
        np.testing.assert_array_equal(r.layout, results[0].layout)
    fresh = MappingProblem((10, 12), Stencil.nearest_neighbor(2),
                           (32, 32, 32, 24))
    a = srv.submit(fresh, deadline_ms=200)
    sol = a.result(timeout=300)
    counts = np.bincount(sol.assignment, minlength=4)
    assert sorted(counts) == sorted((32, 32, 32, 24))
    st = srv.stats()
    assert st["errors"] == 0 and st["completed"] == 7, st
    assert st["warmed"] == warm["swept"], st
assert mp.active_children() == [], mp.active_children()
print(f"serve smoke OK: warm={warm} anytime_cut={a.anytime_cut} "
      f"latency={a.latency_s * 1e3:.0f}ms p50={st['latency_p50_ms']:.1f}ms "
      f"hit_rate={st['cache_hit_rate']:.2f}")
EOF

# graph-layer suite: every available_mappers() spelling bit-identical
# between the grid and graph: paths with independent cache keys, plus
# mapped-vs-blocked DCI on every registry arch with exact linksim replay
# agreement (exit 1 on any FAIL) — the --tiny smoke first (in-process
# spellings, 3 archs), then the full run emitting the machine-readable
# BENCH_10.json perf snapshot
mkdir -p results
PYTHONPATH=src python -m benchmarks.graph_suite --tiny
JAX_PLATFORM_NAME=cpu PYTHONPATH=src python -m benchmarks.graph_suite \
    --json results/BENCH_10.json

# graph smoke: extract a real arch comm graph -> map it through the graph:
# plan flavor -> replay the mapped traffic exactly, warm hit on re-solve
PYTHONPATH=src python - <<'EOF'
import numpy as np
from repro.analysis import replay_graph
from repro.core import PlanCache, arch_comm_graph, graph_create

cache = PlanCache()
g = arch_comm_graph("mixtral-8x7b", 64)
sizes = (8,) * 8
cold = graph_create(g, node_sizes=sizes, cache=cache)
assert cold.plan_key.startswith("graph:") and not cold.from_cache
rep = replay_graph(g, cold.solution.assignment, sizes)
assert rep.dci_total == cold.j_sum and rep.max_dci_pod() == cold.j_max
warm = graph_create(g, node_sizes=sizes, cache=cache)
assert warm.from_cache
np.testing.assert_array_equal(cold.layout, warm.layout)
blocked = graph_create(g, node_sizes=sizes, reorder=False, cache=False)
print(f"graph smoke OK: plan={cold.plan_key} edges={len(g.indices)} "
      f"Jsum {blocked.j_sum / cold.j_sum:.2f}x better than blocked "
      f"cache={cache.stats()}")
EOF

# cart_create smoke: cold solve -> warm cache hit, asserted via counters
PYTHONPATH=src python - <<'EOF'
import numpy as np
from repro.core import PlanCache, cart_create

cache = PlanCache()
cold = cart_create((8, 8), chips_per_pod=16, cache=cache)
assert (cache.hits, cache.misses) == (0, 1) and not cold.from_cache
warm = cart_create((8, 8), chips_per_pod=16, cache=cache)
assert (cache.hits, cache.misses) == (1, 1) and warm.from_cache
np.testing.assert_array_equal(cold.layout, warm.layout)
assert (warm.j_max, warm.j_sum) == (cold.j_max, cold.j_sum)
print(f"cart_create smoke OK: plan={cold.plan_key} "
      f"J=(max {cold.j_max:.0f}, sum {cold.j_sum:.0f}) "
      f"cache={cache.stats()}")
EOF
echo "verify OK"
