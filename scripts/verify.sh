#!/usr/bin/env bash
# Tier-1 verify: fast test suite + a smoke run of the refinement benchmark.
# No PYTHONPATH needed — pytest.ini sets pythonpath=src, and the benchmark
# is invoked with an explicit PYTHONPATH below.
#
#   scripts/verify.sh          # tier-1 (default, < ~2 min)
#   scripts/verify.sh --slow   # additionally run the -m slow tests
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pytest -x -q

if [[ "${1:-}" == "--slow" ]]; then
    python -m pytest -q -m slow
fi

PYTHONPATH=src python -m benchmarks.refine_suite --tiny
echo "verify OK"
