from . import attention, common, lm, moe, ssm, transformer

__all__ = ["attention", "common", "lm", "moe", "ssm", "transformer"]
