"""Shared model building blocks (pure JAX, no external NN library).

Parameters are flat dicts ``name -> jnp.ndarray`` described by
``ParamSpec``s (shape/dtype/logical axes/init), so initialization, sharding
specs and allocation-free dry-run structs all derive from one source.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..sharding.partition import ParamSpec, current_partitioning, shard

__all__ = ["init_params", "param_structs", "param_shardings", "rmsnorm",
           "apply_rope", "rope_freqs", "cross_entropy_loss", "count_params",
           "DTYPES"]

DTYPES = {"bf16": jnp.bfloat16, "f32": jnp.float32, "f16": jnp.float16}


def _init_one(key, spec: ParamSpec):
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "normal":
        scale = spec.init_scale / math.sqrt(max(spec.shape[0], 1))
        return (jax.random.normal(key, spec.shape, jnp.float32) * scale
                ).astype(spec.dtype)
    if spec.init == "scaled":  # scale given explicitly
        return (jax.random.normal(key, spec.shape, jnp.float32) * spec.init_scale
                ).astype(spec.dtype)
    raise ValueError(f"unknown init {spec.init!r}")


def init_params(specs: Dict[str, ParamSpec], key) -> Dict[str, jnp.ndarray]:
    out = {}
    for i, (name, spec) in enumerate(sorted(specs.items())):
        out[name] = _init_one(jax.random.fold_in(key, i), spec)
    return out


def param_structs(specs: Dict[str, ParamSpec]) -> Dict[str, jax.ShapeDtypeStruct]:
    """Allocation-free stand-ins for the dry-run."""
    return {name: jax.ShapeDtypeStruct(s.shape, s.dtype)
            for name, s in specs.items()}


def param_shardings(specs: Dict[str, ParamSpec], part=None) -> Dict[str, object]:
    part = part or current_partitioning()
    return {name: part.sharding(s.logical, s.shape)
            for name, s in specs.items()}


def count_params(specs: Dict[str, ParamSpec]) -> int:
    return sum(s.size for s in specs.values())


# -- numerics ----------------------------------------------------------------
def rmsnorm(x, w, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * w


def rope_freqs(head_dim: int, theta: float = 10000.0):
    """Inverse frequencies for rotary embeddings (half of head_dim)."""
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    hd = x.shape[-1]
    inv = jnp.asarray(rope_freqs(hd, theta), dtype=jnp.float32)
    ang = positions[..., :, None].astype(jnp.float32) * inv[None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)          # (..., seq, hd//2)
    cos = cos[..., :, None, :]
    sin = sin[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def cross_entropy_loss(logits, labels, z_loss: float = 0.0,
                       ignore_id: int = -1):
    """Mean token cross-entropy in f32 with optional z-loss stabilizer."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]
    ce = lse - gold
    if z_loss:
        ce = ce + z_loss * jnp.square(lse)
    mask = (labels != ignore_id).astype(jnp.float32)
    return jnp.sum(ce * mask) / jnp.maximum(jnp.sum(mask), 1.0)
