"""Mamba-2 (SSD — state-space duality) block, chunked scan implementation.

Faithful to the minimal SSD formulation (Dao & Gu 2024): per-head scalar
decay ``A``, state size N, head dim P; within chunks the quadratic "dual"
form, across chunks a linear state recurrence (lax.scan).  Decode keeps a
constant-size recurrent state — this is why mamba archs run the ``long_500k``
shape that full attention cannot.

Layout: x (B, L, d_model); internal (B, L, H, P) with H·P = expand·d_model.
Single B/C group (G=1) as in mamba2-130m.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..sharding.partition import ParamSpec, shard
from .common import rmsnorm

__all__ = ["ssm_specs", "ssm_apply", "init_ssm_cache", "SSMCache"]


class SSMCache(NamedTuple):
    state: jnp.ndarray      # (B, H, P, N)
    conv: jnp.ndarray       # (B, W-1, conv_dim) trailing inputs
    length: jnp.ndarray


def ssm_specs(cfg: ArchConfig, dtype) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    di = cfg.d_inner_ssm
    N, H, W = cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_conv
    conv_dim = di + 2 * N
    return {
        # separate projections (not one fused in_proj) so each output dim
        # divides the 16-way model axis: di and N shard, the tiny dt head
        # replicates — the fused (2di+2N+H)-wide projection would fall back
        # to full replication (divisibility rule, sharding/partition.py).
        "in_z": ParamSpec((d, di), dtype, ("fsdp", "tp")),
        "in_x": ParamSpec((d, di), dtype, ("fsdp", "tp")),
        "in_B": ParamSpec((d, N), dtype, ("fsdp", "tp")),
        "in_C": ParamSpec((d, N), dtype, ("fsdp", "tp")),
        "in_dt": ParamSpec((d, H), dtype, ("fsdp", None)),
        "conv_w": ParamSpec((W, conv_dim), dtype, (None, "tp"), init="scaled",
                            init_scale=0.1),
        "conv_b": ParamSpec((conv_dim,), dtype, ("tp",), init="zeros"),
        "A_log": ParamSpec((H,), jnp.float32, (None,), init="ones"),
        "dt_bias": ParamSpec((H,), jnp.float32, (None,), init="zeros"),
        "D": ParamSpec((H,), jnp.float32, (None,), init="ones"),
        "gate_norm": ParamSpec((di,), dtype, ("tp",), init="ones"),
        "out_proj": ParamSpec((di, d), dtype, ("tp", "fsdp")),
    }


def init_ssm_cache(cfg: ArchConfig, batch: int, dtype) -> SSMCache:
    H, P, N, W = (cfg.n_ssm_heads, cfg.ssm_headdim, cfg.ssm_state,
                  cfg.ssm_conv)
    conv_dim = cfg.d_inner_ssm + 2 * N
    return SSMCache(
        state=jnp.zeros((batch, H, P, N), jnp.float32),
        conv=jnp.zeros((batch, W - 1, conv_dim), dtype),
        length=jnp.zeros((), jnp.int32))


def _causal_conv(u, w, b, tail=None):
    """Depthwise causal conv along seq. u: (B, L, C), w: (W, C)."""
    W = w.shape[0]
    if tail is None:
        tail = jnp.zeros((u.shape[0], W - 1, u.shape[2]), u.dtype)
    up = jnp.concatenate([tail, u], axis=1)
    out = jnp.zeros_like(u)
    for i in range(W):
        out = out + up[:, i:i + u.shape[1], :] * w[i][None, None, :]
    new_tail = up[:, up.shape[1] - (W - 1):, :]
    return out + b, new_tail


def ssm_apply(cfg: ArchConfig, p: Dict[str, jnp.ndarray], x: jnp.ndarray,
              cache: Optional[SSMCache] = None
              ) -> Tuple[jnp.ndarray, Optional[SSMCache]]:
    B, L, d = x.shape
    di, N, H, P = (cfg.d_inner_ssm, cfg.ssm_state, cfg.n_ssm_heads,
                   cfg.ssm_headdim)
    A = -jnp.exp(p["A_log"])                        # (H,) negative decay

    z = x @ p["in_z"]
    xin = x @ p["in_x"]
    Bc = x @ p["in_B"]
    Cc = x @ p["in_C"]
    dt = x @ p["in_dt"]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,L,H)

    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)
    tail = cache.conv if cache is not None else None
    conv_out, new_tail = _causal_conv(conv_in, p["conv_w"], p["conv_b"], tail)
    conv_out = jax.nn.silu(conv_out)
    xin, Bc, Cc = jnp.split(conv_out, [di, di + N], axis=-1)
    xh = xin.reshape(B, L, H, P)

    if cache is not None and L == 1:
        # recurrent decode step
        dt1 = dt[:, 0]                                   # (B,H)
        decay = jnp.exp(dt1 * A[None, :])                # (B,H)
        dBx = jnp.einsum("bh,bn,bhp->bhpn", dt1, Bc[:, 0].astype(jnp.float32),
                         xh[:, 0].astype(jnp.float32))
        state = cache.state * decay[..., None, None] + dBx
        y = jnp.einsum("bn,bhpn->bhp", Cc[:, 0].astype(jnp.float32), state)
        y = y + p["D"][None, :, None] * xh[:, 0].astype(jnp.float32)
        y = y.reshape(B, 1, di)
        new_cache = SSMCache(state, new_tail, cache.length + 1)
    else:
        y, final_state = _ssd_chunked(cfg, xh, dt, A, Bc, Cc)
        y = y + (p["D"][None, None, :, None] * xh.astype(jnp.float32))
        y = y.reshape(B, L, di)
        new_cache = None
        if cache is not None:  # prefill
            new_cache = SSMCache(final_state, new_tail,
                                 jnp.asarray(L, jnp.int32))

    y = y.astype(x.dtype) * jax.nn.silu(z)
    y = rmsnorm(y, p["gate_norm"])
    return y @ p["out_proj"], new_cache


def _ssd_chunked(cfg: ArchConfig, xh, dt, A, Bc, Cc):
    """Chunked SSD: quadratic within chunks, linear scan across chunks.

    xh: (B, L, H, P); dt: (B, L, H) f32; Bc/Cc: (B, L, N).
    Returns y (B, L, H, P) f32 and final state (B, H, P, N) f32.
    """
    B, L, H, P = xh.shape
    N = Bc.shape[-1]
    Q = min(cfg.ssm_chunk, L)
    pad = (-L) % Q
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bc = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0)))
        Cc = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))
    nc = (L + pad) // Q

    xc = xh.reshape(B, nc, Q, H, P).astype(jnp.float32)
    dtc = dt.reshape(B, nc, Q, H)
    Bcc = Bc.reshape(B, nc, Q, N).astype(jnp.float32)
    Ccc = Cc.reshape(B, nc, Q, N).astype(jnp.float32)

    a = dtc * A[None, None, None, :]                  # (B,nc,Q,H) negative
    acum = jnp.cumsum(a, axis=2)

    # intra-chunk (dual / attention-like) term
    rel = acum[:, :, :, None, :] - acum[:, :, None, :, :]   # (B,nc,Q,Q,H)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    # mask the *input* of exp: above the diagonal rel > 0 can overflow, and
    # where(mask, exp(rel), 0) would still propagate inf*0 = nan gradients.
    rel = jnp.where(causal[None, None, :, :, None], rel, -jnp.inf)
    decay = jnp.exp(rel)
    scores = jnp.einsum("bcin,bcjn->bcij", Ccc, Bcc)         # (B,nc,Q,Q)
    w = scores[..., None] * decay * dtc[:, :, None, :, :]    # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w, xc)

    # chunk summary states
    last = acum[:, :, -1:, :]                                 # (B,nc,1,H)
    wj = jnp.exp(last - acum) * dtc                           # (B,nc,Q,H)
    S_chunk = jnp.einsum("bcjh,bcjn,bcjhp->bchpn", wj, Bcc, xc)
    chunk_decay = jnp.exp(jnp.sum(a, axis=2))                 # (B,nc,H)

    def scan_body(h, inp):
        s_c, dec = inp
        h_next = h * dec[..., None, None] + s_c
        return h_next, h  # emit state *before* this chunk

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    hT, h_prevs = jax.lax.scan(
        scan_body,
        h0,
        (S_chunk.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)                # (B,nc,H,P,N)

    y_inter = jnp.einsum("bcin,bchpn->bcihp", Ccc, h_prevs)
    y_inter = y_inter * jnp.exp(acum)[..., None]
    y = (y_intra + y_inter).reshape(B, nc * Q, H, P)
    return y[:, :L], hT
