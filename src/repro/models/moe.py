"""Mixture-of-Experts layer (Mixtral / DeepSeek-V3 style).

Routing: softmax top-k with capacity factor.  Two dispatch engines:

  * ``einsum`` — GShard-style one-hot dispatch/combine einsums.  The
    paper-faithful baseline every MoE system starts from; its dispatch
    einsum burns 2·T·E·C·d FLOPs which for DeepSeek's 256 experts rivals
    the expert FFN compute itself (visible in the roofline useful_ratio).
  * ``scatter`` — capacity-slot scatter/gather dispatch (no matmul): each
    token computes its slot via a cumsum over expert one-hots and is moved
    with scatter-add; saves the dispatch FLOPs entirely (beyond-paper
    optimization measured in EXPERIMENTS.md §Perf).

Experts are sharded over the "expert" logical axis (EP on the mesh's model
axis); resharding token buffers between data- and expert-sharded layouts is
what produces the all-to-all collectives in the compiled module.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..sharding.partition import ParamSpec, shard

__all__ = ["moe_specs", "moe_apply"]


def moe_specs(cfg: ArchConfig, dtype) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    f = cfg.d_ff_expert or cfg.d_ff
    E = cfg.n_experts
    specs = {
        "router": ParamSpec((d, E), jnp.float32, ("fsdp", None)),
        # ("expert","fsdp","tp"): EP over the model axis when E divides it
        # (DeepSeek 256e); otherwise experts replicate across "model" and
        # d_ff takes the model axis instead — TP-within-expert, the standard
        # plan for E < mesh (Mixtral 8e).  Conflict resolution in
        # Partitioning.spec guarantees the model axis is used at most once.
        "w_gate": ParamSpec((E, d, f), dtype, ("expert", "fsdp", "tp")),
        "w_up": ParamSpec((E, d, f), dtype, ("expert", "fsdp", "tp")),
        "w_down": ParamSpec((E, f, d), dtype, ("expert", "tp", "fsdp")),
    }
    for s in range(cfg.n_shared_experts):
        specs[f"shared{s}/w_gate"] = ParamSpec((d, f), dtype, ("fsdp", "tp"))
        specs[f"shared{s}/w_up"] = ParamSpec((d, f), dtype, ("fsdp", "tp"))
        specs[f"shared{s}/w_down"] = ParamSpec((f, d), dtype, ("tp", "fsdp"))
    return specs


def _capacity(cfg: ArchConfig, tokens_per_group: int) -> int:
    c = int(cfg.top_k * tokens_per_group * cfg.capacity_factor / cfg.n_experts)
    return max(4, -(-c // 4) * 4)


def _expert_ffn(xe, w_gate, w_up, w_down):
    """xe: (E, C, d) dispatched tokens; SwiGLU per expert."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, w_gate))
    h = h * jnp.einsum("ecd,edf->ecf", xe, w_up)
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def moe_apply(cfg: ArchConfig, p: Dict[str, jnp.ndarray], x: jnp.ndarray,
              dispatch: str = "einsum") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (out, aux_loss)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = _capacity(cfg, S)

    logits = (x.astype(jnp.float32) @ p["router"])          # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)                 # (B, S, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=(0, 1))                        # (E,)
    onehot_topk = jax.nn.one_hot(idx, E, dtype=jnp.float32)  # (B,S,k,E)
    ce = jnp.mean(onehot_topk.sum(2), axis=(0, 1)) / k
    aux = cfg.router_aux_weight * E * jnp.sum(me * ce)

    # position of each (token, choice) within its expert's capacity buffer
    flat_choice = onehot_topk.reshape(B, S * k, E)
    pos_in_expert = (jnp.cumsum(flat_choice, axis=1) - 1.0).reshape(B, S, k, E)
    pos = jnp.einsum("bske,bske->bsk", pos_in_expert, onehot_topk)
    keep = pos < C
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)

    if dispatch == "einsum":
        # GShard: dispatch mask (B, S, k, E, C) contracted immediately
        cap_onehot = jax.nn.one_hot(
            jnp.where(keep, pos, C).astype(jnp.int32), C + 1,
            dtype=x.dtype)[..., :C]                            # (B,S,k,C)
        disp = jnp.einsum("bske,bskc->bsec", onehot_topk.astype(x.dtype),
                          cap_onehot)                          # (B,S,E,C)
        xe = jnp.einsum("bsec,bsd->becd", disp, x)
        xe = shard(xe, "batch", "expert", None, None)
        ye = jax.vmap(lambda xb: _expert_ffn(xb, p["w_gate"], p["w_up"],
                                             p["w_down"]))(xe)
        ye = shard(ye, "batch", "expert", None, None)
        comb = jnp.einsum("bske,bskc,bsk->bsec", onehot_topk.astype(x.dtype),
                          cap_onehot, gate_vals.astype(x.dtype))
        out = jnp.einsum("bsec,becd->bsd", comb, ye)
    elif dispatch == "scatter":
        # capacity-slot scatter: no dispatch matmuls
        slot = jnp.where(keep, idx * C + pos.astype(jnp.int32), E * C)
        slot = slot.reshape(B, S * k).astype(jnp.int32)
        xk = jnp.repeat(x, k, axis=1)                          # (B, S*k, d)
        buf = jnp.zeros((B, E * C + 1, d), x.dtype)
        xe = jax.vmap(lambda b, s, v: b.at[s].add(v))(buf, slot, xk)
        xe = xe[:, :E * C].reshape(B, E, C, d)
        xe = shard(xe, "batch", "expert", None, None)
        ye = jax.vmap(lambda xb: _expert_ffn(xb, p["w_gate"], p["w_up"],
                                             p["w_down"]))(xe)
        ye = shard(ye, "batch", "expert", None, None)
        yflat = ye.reshape(B, E * C, d)
        ypad = jnp.concatenate([yflat, jnp.zeros((B, 1, d), ye.dtype)], axis=1)
        yk = jax.vmap(lambda yb, s: yb[s])(ypad, slot)         # (B, S*k, d)
        yk = yk.reshape(B, S, k, d)
        out = jnp.einsum("bskd,bsk->bsd", yk, gate_vals.astype(x.dtype))
    else:
        raise ValueError(f"unknown moe dispatch {dispatch!r}")

    for s in range(cfg.n_shared_experts):
        h = jax.nn.silu(x @ p[f"shared{s}/w_gate"]) * (x @ p[f"shared{s}/w_up"])
        out = out + h @ p[f"shared{s}/w_down"]
    return out.astype(x.dtype), aux
