"""Top-level language models for all assigned architecture families.

Public API (everything the launcher / examples / tests use):
  * ``param_specs(cfg)``      flat dict name -> ParamSpec
  * ``init(cfg, key)``        materialized params
  * ``forward(cfg, params, batch)``          -> (logits, aux)
  * ``loss_fn(cfg, params, batch)``          -> (loss, metrics)
  * ``init_caches(cfg, batch, max_len)``     decode caches
  * ``prefill(cfg, params, batch, caches)``  -> (last_logits, caches)
  * ``decode_step(cfg, params, token, caches)`` -> (logits, caches)

Batches are dicts: tokens (B, S) int32 "inputs"/"targets"; VLM adds
"patches" (B, P, d_model) stub patch embeddings; enc-dec adds "src"
(B, T_src, d_model) stub frame embeddings (modality frontends are stubs per
the assignment — ``input_specs`` provides precomputed embeddings).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..sharding.partition import ParamSpec, shard
from .common import DTYPES, cross_entropy_loss, init_params, rmsnorm
from .transformer import (add_prefix, decoder_stack, encoder_stack,
                          hybrid_stack, init_layer_caches, layer_specs,
                          stack_specs, sub)

__all__ = ["param_specs", "init", "forward", "loss_fn", "init_caches",
           "prefill", "decode_step"]


def _dtype(cfg: ArchConfig):
    return DTYPES[cfg.param_dtype]


def param_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    dt = _dtype(cfg)
    d, V = cfg.d_model, cfg.vocab_padded
    specs: Dict[str, ParamSpec] = {
        "embed": ParamSpec((V, d), dt, ("vocab", "fsdp"), init="scaled",
                           init_scale=0.02),
        "final_norm": ParamSpec((d,), dt, (None,), init="ones"),
    }
    if not cfg.tie_embeddings:
        specs["head"] = ParamSpec((d, V), dt, ("fsdp", "vocab"))

    if cfg.family == "hybrid":
        G = cfg.n_layers // cfg.attn_every
        ssm_layer = layer_specs(cfg, dt, "ssm")
        grouped = {k: ParamSpec((G, cfg.attn_every) + s.shape, s.dtype,
                                (None, None) + s.logical, s.init, s.init_scale)
                   for k, s in ssm_layer.items()}
        specs.update(add_prefix(grouped, "layers"))
        specs.update(add_prefix(layer_specs(cfg, dt, "decoder"), "shared_attn"))
    elif cfg.family == "encdec":
        dec = layer_specs(cfg, dt, "decoder_cross")
        specs.update(add_prefix(stack_specs(dec, cfg.n_layers), "layers"))
        enc = layer_specs(cfg, dt, "encoder")
        specs.update(add_prefix(stack_specs(enc, cfg.n_enc_layers), "enc_layers"))
        specs["enc_final_norm"] = ParamSpec((d,), dt, (None,), init="ones")
    else:
        kind = "ssm" if cfg.family == "ssm" else "decoder"
        nd = cfg.n_dense_layers if cfg.n_experts else 0
        if nd:  # DeepSeek-style leading dense layers before the MoE stack
            dense = layer_specs(cfg, dt, "decoder_dense")
            specs.update(add_prefix(stack_specs(dense, nd), "dense_layers"))
        layer = layer_specs(cfg, dt, kind)
        specs.update(add_prefix(stack_specs(layer, cfg.n_layers - nd), "layers"))

    if cfg.num_patches:  # VLM stub frontend projection
        specs["patch_proj"] = ParamSpec((d, d), dt, ("fsdp", "tp"))
    if cfg.mtp_depth:    # DeepSeek multi-token prediction module
        specs["mtp_norm_h"] = ParamSpec((d,), dt, (None,), init="ones")
        specs["mtp_norm_e"] = ParamSpec((d,), dt, (None,), init="ones")
        specs["mtp_proj"] = ParamSpec((2 * d, d), dt, ("fsdp", "tp"))
        specs.update(add_prefix(
            stack_specs(layer_specs(cfg, dt, "decoder"), cfg.mtp_depth), "mtp_layers"))
    return specs


def init(cfg: ArchConfig, key) -> Dict[str, jnp.ndarray]:
    return init_params(param_specs(cfg), key)


# ---------------------------------------------------------------------------
def _embed(cfg, params, tokens):
    x = params["embed"][tokens]
    return shard(x, "batch", "seq", "embed")


def _head(cfg, params, x, mask_padding: bool = False):
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = x @ w.astype(x.dtype)
    if mask_padding and cfg.vocab_padded != cfg.vocab:
        # serve paths: padded vocab entries must never win an argmax
        keep = jnp.arange(cfg.vocab_padded) < cfg.vocab
        logits = jnp.where(keep, logits, -1e30)
    return shard(logits, "batch", "seq", "vocab")


def _positions(batch_tokens, offset=0):
    S = batch_tokens.shape[1]
    return jnp.arange(S, dtype=jnp.int32) + offset


def _backbone(cfg: ArchConfig, params, x, positions, caches=None,
              enc_out=None, moe_dispatch="einsum"):
    """Run the layer stack for any family. Returns (hidden, aux, caches)."""
    if cfg.family == "hybrid":
        c, sc = (None, None) if caches is None else caches
        h, aux, nc, nsc = hybrid_stack(cfg, sub(params, "layers"),
                                       sub(params, "shared_attn"), x, positions,
                                       caches=c, shared_caches=sc)
        return h, aux, (None if caches is None else (nc, nsc))
    kind = "ssm" if cfg.family == "ssm" else (
        "decoder_cross" if cfg.family == "encdec" else "decoder")
    nd = cfg.n_dense_layers if cfg.n_experts else 0
    if nd:
        dense_c, moe_c = (None, None) if caches is None else caches
        h, aux0, ndc = decoder_stack(cfg, sub(params, "dense_layers"), x,
                                     positions, kind="decoder_dense",
                                     caches=dense_c, n_layers=nd)
        h, aux, nc = decoder_stack(cfg, sub(params, "layers"), h, positions,
                                   kind=kind, caches=moe_c, enc_out=enc_out,
                                   moe_dispatch=moe_dispatch,
                                   n_layers=cfg.n_layers - nd)
        return h, aux + aux0, (None if caches is None else (ndc, nc))
    h, aux, nc = decoder_stack(cfg, sub(params, "layers"), x, positions,
                               kind=kind, caches=caches, enc_out=enc_out,
                               moe_dispatch=moe_dispatch)
    return h, aux, nc


def _encode(cfg, params, src):
    pos = jnp.arange(src.shape[1], dtype=jnp.int32)
    enc, aux = encoder_stack(cfg, sub(params, "enc_layers"), src, pos)
    return rmsnorm(enc, params["enc_final_norm"]), aux


def forward(cfg: ArchConfig, params, batch: Dict[str, Any],
            moe_dispatch: str = "einsum"):
    """Training/eval forward. Returns (logits, aux_loss)."""
    tokens = batch["inputs"]
    x = _embed(cfg, params, tokens)
    enc_out = None
    aux_total = jnp.zeros((), jnp.float32)
    if cfg.family == "encdec":
        enc_out, enc_aux = _encode(cfg, params, batch["src"].astype(x.dtype))
        aux_total += enc_aux
    if cfg.num_patches and "patches" in batch:
        pe = batch["patches"].astype(x.dtype) @ params["patch_proj"]
        x = jnp.concatenate([pe, x], axis=1)
    positions = _positions(x[:, :, 0] if x.ndim == 3 else x)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    h, aux, _ = _backbone(cfg, params, x, positions, enc_out=enc_out,
                          moe_dispatch=moe_dispatch)
    aux_total += aux
    if cfg.num_patches and "patches" in batch:
        h = h[:, -tokens.shape[1]:]
    hidden = rmsnorm(h, params["final_norm"])
    logits = _head(cfg, params, hidden)
    if cfg.mtp_depth and cfg.use_mtp_loss:
        # one-step MTP: combine hidden_t with embedding of token t+1
        emb_next = jnp.roll(_embed(cfg, params, tokens), -1, axis=1)
        mtp_in = jnp.concatenate(
            [rmsnorm(h, params["mtp_norm_h"]),
             rmsnorm(emb_next, params["mtp_norm_e"])], axis=-1) @ params["mtp_proj"]
        mtp_h, mtp_aux, _ = decoder_stack(cfg, sub(params, "mtp_layers"),
                                          mtp_in, positions,
                                          n_layers=cfg.mtp_depth,
                                          moe_dispatch=moe_dispatch)
        aux_total += mtp_aux
        mtp_logits = _head(cfg, params, rmsnorm(mtp_h, params["final_norm"]))
        return logits, aux_total, mtp_logits
    return logits, aux_total, None


def loss_fn(cfg: ArchConfig, params, batch, moe_dispatch: str = "einsum"):
    logits, aux, mtp_logits = forward(cfg, params, batch,
                                      moe_dispatch=moe_dispatch)
    loss = cross_entropy_loss(logits, batch["targets"])
    metrics = {"ce": loss, "aux": aux}
    if mtp_logits is not None:
        # MTP predicts one token further: shift targets by one more step
        t2 = jnp.concatenate(
            [batch["targets"][:, 1:],
             jnp.full_like(batch["targets"][:, :1], -1)], axis=1)
        mtp_loss = cross_entropy_loss(mtp_logits, t2)
        metrics["mtp"] = mtp_loss
        loss = loss + 0.3 * mtp_loss
    total = loss + aux
    metrics["loss"] = total
    return total, metrics


# ---------------------------------------------------------------------------
# serving
def init_caches(cfg: ArchConfig, batch: int, max_len: int):
    dt = _dtype(cfg)
    if cfg.family == "hybrid":
        G = cfg.n_layers // cfg.attn_every
        ssm = init_layer_caches(cfg, cfg.attn_every, batch, max_len, dt, "ssm")
        ssm = jax.tree.map(lambda a: jnp.broadcast_to(a, (G,) + a.shape), ssm)
        attn = init_layer_caches(cfg, G, batch, max_len, dt, "decoder")
        return (ssm, attn)
    kind = "ssm" if cfg.family == "ssm" else "decoder"
    nd = cfg.n_dense_layers if cfg.n_experts else 0
    if nd:
        return (init_layer_caches(cfg, nd, batch, max_len, dt, kind),
                init_layer_caches(cfg, cfg.n_layers - nd, batch, max_len, dt,
                                  kind))
    return init_layer_caches(cfg, cfg.n_layers, batch, max_len, dt, kind)


def prefill(cfg: ArchConfig, params, batch, caches,
            moe_dispatch: str = "einsum"):
    """Process the prompt; returns (last-token logits, filled caches)."""
    tokens = batch["inputs"]
    x = _embed(cfg, params, tokens)
    enc_out = None
    if cfg.family == "encdec":
        enc_out, _ = _encode(cfg, params, batch["src"].astype(x.dtype))
    if cfg.num_patches and "patches" in batch:
        pe = batch["patches"].astype(x.dtype) @ params["patch_proj"]
        x = jnp.concatenate([pe, x], axis=1)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    h, _, caches = _backbone(cfg, params, x, positions, caches=caches,
                             enc_out=enc_out, moe_dispatch=moe_dispatch)
    hidden = rmsnorm(h[:, -1:], params["final_norm"])
    return _head(cfg, params, hidden, mask_padding=True)[:, 0], caches


def decode_step(cfg: ArchConfig, params, token, caches, *, enc_out=None,
                pos=None, moe_dispatch: str = "einsum"):
    """One decode step. token: (B,) int32. Returns (logits (B, V), caches)."""
    x = _embed(cfg, params, token[:, None])
    if pos is None:
        pos = _cache_length(cfg, caches)
    positions = pos[None] if pos.ndim == 0 else pos
    positions = jnp.reshape(positions, (1,)).astype(jnp.int32)
    h, _, caches = _backbone(cfg, params, x, positions, caches=caches,
                             enc_out=enc_out)
    hidden = rmsnorm(h, params["final_norm"])
    return _head(cfg, params, hidden, mask_padding=True)[:, 0], caches


def _cache_length(cfg, caches):
    leaves = jax.tree.leaves(caches)
    # 'length' leaves are scalar int32 stacked over layers
    for leaf in leaves:
        if leaf.dtype == jnp.int32:
            return leaf.reshape(-1)[0]
    return jnp.zeros((), jnp.int32)
