"""Layer stacks: decoder / encoder / SSM / hybrid, with scan-over-layers and
configurable remat — the compile-size and activation-memory levers the §Perf
loop tunes.

Parameters are flat dicts; layer-stacked leaves carry a leading (L,) dim and
are scanned with ``lax.scan`` (keeps the HLO one-layer-sized, which is what
makes 61-layer x 512-device dry-runs compile quickly).  Caches follow the
same convention: leaves stacked over layers, scanned alongside params.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..sharding.partition import ParamSpec, shard
from .attention import (attn_apply, attn_specs, init_kv_cache, init_mla_cache,
                        mla_apply, mla_specs)
from .common import rmsnorm
from .moe import moe_apply, moe_specs
from .ssm import init_ssm_cache, ssm_apply, ssm_specs

__all__ = ["layer_specs", "stack_specs", "decoder_stack", "encoder_stack",
           "hybrid_stack", "init_layer_caches", "sub", "add_prefix",
           "remat_wrap"]


def sub(params: Dict[str, Any], prefix: str) -> Dict[str, Any]:
    pl = prefix + "/"
    return {k[len(pl):]: v for k, v in params.items() if k.startswith(pl)}


def add_prefix(specs: Dict[str, Any], prefix: str) -> Dict[str, Any]:
    return {f"{prefix}/{k}": v for k, v in specs.items()}


def stack_specs(specs: Dict[str, ParamSpec], n: int) -> Dict[str, ParamSpec]:
    return {k: ParamSpec((n,) + s.shape, s.dtype, (None,) + s.logical,
                         s.init, s.init_scale) for k, s in specs.items()}


# ---------------------------------------------------------------------------
def mlp_specs(cfg: ArchConfig, dtype, d_ff: Optional[int] = None):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    return {
        "w_gate": ParamSpec((d, f), dtype, ("fsdp", "tp")),
        "w_up": ParamSpec((d, f), dtype, ("fsdp", "tp")),
        "w_down": ParamSpec((f, d), dtype, ("tp", "fsdp")),
    }


def mlp_apply(p, x):
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    h = shard(h, "batch", "seq", "tp")
    return h @ p["w_down"]


def norm_spec(cfg: ArchConfig, dtype) -> ParamSpec:
    return ParamSpec((cfg.d_model,), dtype, (None,), init="ones")


def layer_specs(cfg: ArchConfig, dtype, kind: str) -> Dict[str, ParamSpec]:
    """One layer's parameter specs. kind: decoder|decoder_cross|encoder|ssm."""
    if kind == "ssm":
        return {"norm": norm_spec(cfg, dtype),
                **add_prefix(ssm_specs(cfg, dtype), "ssm")}
    specs: Dict[str, ParamSpec] = {"attn_norm": norm_spec(cfg, dtype)}
    if cfg.use_mla:
        specs.update(add_prefix(mla_specs(cfg, dtype), "attn"))
    else:
        specs.update(add_prefix(attn_specs(cfg, dtype), "attn"))
    if kind == "decoder_cross":
        specs["cross_norm"] = norm_spec(cfg, dtype)
        specs.update(add_prefix(attn_specs(cfg, dtype), "cross"))
    specs["ffn_norm"] = norm_spec(cfg, dtype)
    if cfg.n_experts > 0 and kind in ("decoder", "decoder_cross"):
        specs.update(add_prefix(moe_specs(cfg, dtype), "moe"))
    else:
        specs.update(add_prefix(mlp_specs(cfg, dtype), "mlp"))
    return specs


def layer_apply(cfg: ArchConfig, p, x, positions, *, kind: str,
                cache=None, enc_out=None, moe_dispatch: str = "einsum"):
    """Returns (x, aux, new_cache)."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "ssm":
        h, new_cache = ssm_apply(cfg, sub(p, "ssm"), rmsnorm(x, p["norm"]),
                                 cache=cache)
        return x + h, aux, new_cache
    h = rmsnorm(x, p["attn_norm"])
    if cfg.use_mla:
        h, new_cache = mla_apply(cfg, sub(p, "attn"), h, positions, cache=cache)
    else:
        h, new_cache = attn_apply(cfg, sub(p, "attn"), h, positions,
                                  causal=(kind != "encoder"), cache=cache)
    x = x + h
    if kind == "decoder_cross" and enc_out is not None:
        h = rmsnorm(x, p["cross_norm"])
        h, _ = attn_apply(cfg, sub(p, "cross"), h, positions, causal=False,
                          kv_override=(enc_out, enc_out))
        x = x + h
    h = rmsnorm(x, p["ffn_norm"])
    if cfg.n_experts > 0 and kind in ("decoder", "decoder_cross"):
        h, aux = moe_apply(cfg, sub(p, "moe"), h, dispatch=moe_dispatch)
    else:
        h = mlp_apply(sub(p, "mlp"), h)
    x = x + h
    x = shard(x, "batch", "seq", "embed")
    return x, aux, new_cache


def remat_wrap(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)  # "full": save nothing


# ---------------------------------------------------------------------------
def init_layer_caches(cfg: ArchConfig, n_layers: int, batch: int, max_len: int,
                      dtype, kind: str):
    if kind == "ssm":
        one = init_ssm_cache(cfg, batch, dtype)
    elif cfg.use_mla:
        one = init_mla_cache(cfg, batch, max_len, dtype)
    else:
        one = init_kv_cache(cfg, batch, max_len, dtype)
    return jax.tree.map(lambda a: jnp.broadcast_to(a, (n_layers,) + a.shape),
                        one)


def decoder_stack(cfg: ArchConfig, params, x, positions, *, kind="decoder",
                  caches=None, enc_out=None, n_layers=None,
                  moe_dispatch="einsum"):
    """params: flat dict of layer-stacked leaves. Returns (x, aux, caches)."""
    L = n_layers or cfg.n_layers

    def body(carry, xs):
        h, aux = carry
        layer_p, layer_cache = xs
        h, a, new_cache = layer_apply(cfg, layer_p, h, positions, kind=kind,
                                      cache=layer_cache, enc_out=enc_out,
                                      moe_dispatch=moe_dispatch)
        return (h, aux + a), new_cache

    body = remat_wrap(body, cfg.remat)
    if cfg.scan_layers:
        (x, aux), new_caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                            (params, caches))
    else:
        aux = jnp.zeros((), jnp.float32)
        new_list = []
        for i in range(L):
            layer_p = jax.tree.map(lambda a: a[i], params)
            layer_c = jax.tree.map(lambda a: a[i], caches) if caches is not None else None
            (x, aux), nc = body((x, aux), (layer_p, layer_c))
            new_list.append(nc)
        new_caches = (jax.tree.map(lambda *xs: jnp.stack(xs), *new_list)
                      if caches is not None else None)
    return x, aux, new_caches


def encoder_stack(cfg: ArchConfig, params, x, positions):
    out, aux, _ = decoder_stack(cfg, params, x, positions, kind="encoder",
                                n_layers=cfg.n_enc_layers)
    return out, aux


def hybrid_stack(cfg: ArchConfig, params, shared_p, x, positions, *,
                 caches=None, shared_caches=None):
    """Zamba2-style: groups of ``attn_every`` SSM layers, each followed by one
    *shared-weight* attention+MLP block (own activations/caches per use).

    params: SSM layer leaves stacked (G, attn_every, ...); shared_p: single
    attention block params; shared_caches: KV caches stacked (G, ...).
    """
    G = cfg.n_layers // cfg.attn_every

    def group_body(carry, xs):
        h, aux = carry
        group_p, group_cache, sh_cache = xs

        def inner(carry2, xs2):
            h2, aux2 = carry2
            lp, lc = xs2
            h2, a, nc = layer_apply(cfg, lp, h2, positions, kind="ssm",
                                    cache=lc)
            return (h2, aux2 + a), nc

        (h, aux), new_group_cache = jax.lax.scan(inner, (h, aux),
                                                 (group_p, group_cache))
        h, a2, new_sh_cache = layer_apply(cfg, shared_p, h, positions,
                                          kind="decoder", cache=sh_cache)
        return (h, aux + a2), (new_group_cache, new_sh_cache)

    group_body = remat_wrap(group_body, cfg.remat)
    (x, aux), (new_caches, new_shared) = jax.lax.scan(
        group_body, (x, jnp.zeros((), jnp.float32)),
        (params, caches, shared_caches))
    return x, aux, new_caches, new_shared
