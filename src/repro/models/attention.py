"""Attention variants: GQA/MHA/MQA (+ qk-norm, sliding window), blocked
flash-style attention for long prefill, KV caches for decode, and DeepSeek
MLA with the absorbed decode path.

Layout conventions:
  activations: (batch, seq, d_model)
  q/k/v:       (batch, seq, heads, head_dim)
  GQA grouping: q heads reshaped to (kv_heads, group) for shared-KV einsums.

KV cache: slots carry an explicit absolute-position array ``pos`` so the
same code path serves both the dense cache (slot i holds position i) and the
**ring cache** for sliding-window attention (slot = position mod window —
the beyond-paper long-context optimization; cfg.swa_ring_cache): masking is
always computed from stored positions, never from slot indices.
"""
from __future__ import annotations

import math
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..sharding.partition import ParamSpec, shard
from .common import apply_rope, rmsnorm

__all__ = ["attn_specs", "attn_apply", "init_kv_cache", "mla_specs",
           "mla_apply", "init_mla_cache", "KVCache", "MLACache"]

NEG_INF = -1e30
EMPTY_POS = -(2 ** 30)


class KVCache(NamedTuple):
    k: jnp.ndarray       # (B, S_alloc, K, hd)
    v: jnp.ndarray       # (B, S_alloc, K, hd)
    pos: jnp.ndarray     # (S_alloc,) absolute position per slot (EMPTY_POS = empty)
    length: jnp.ndarray  # () tokens seen so far


class MLACache(NamedTuple):
    c_kv: jnp.ndarray    # (B, S_max, kv_lora)
    k_rope: jnp.ndarray  # (B, S_max, rope_dim)
    length: jnp.ndarray


# ---------------------------------------------------------------------------
# masks / softmax helpers
def _mask(pos_q, pos_k, causal: bool, window: Optional[int], valid_k=None):
    m = jnp.ones((pos_q.shape[-1], pos_k.shape[-1]), bool)
    if causal:
        m &= pos_q[:, None] >= pos_k[None, :]
    if window is not None:
        m &= (pos_q[:, None] - pos_k[None, :]) < window
    if valid_k is not None:
        m &= valid_k[None, :]
    return m


def _sdpa(q, k, v, mask, scale):
    """Plain attention. q:(B,Sq,K,G,hd) k:(B,Sk,K,hd) v:(B,Sk,K,hv)."""
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q, k).astype(jnp.float32) * scale
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgqs,bskv->bqkgv", w, v)


def _blocked_sdpa(q, k, v, pos_q, pos_k, causal, window, scale,
                  q_block: int = 512, kv_block: int = 1024):
    """Flash-style online-softmax attention, double-blocked with lax.scan.

    Memory per step is O(q_block * kv_block) instead of O(Sq * Sk); compute
    covers all block pairs (masked), which the roofline accounts as the
    standard 2x causal overhead (hillclimb item: Pallas kernel / block
    skipping).
    """
    B, Sq, K, G, hd = q.shape
    Sk = k.shape[1]
    hv = v.shape[-1]
    qb = min(q_block, Sq)
    kb = min(kv_block, Sk)
    # pad to multiples
    nq, nk = -(-Sq // qb), -(-Sk // kb)
    pq = nq * qb - Sq
    pk = nk * kb - Sk
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    posq = jnp.pad(pos_q, (0, pq), constant_values=-1)
    posk = jnp.pad(pos_k, (0, pk), constant_values=2**30)

    qs = qp.reshape(B, nq, qb, K, G, hd).transpose(1, 0, 2, 3, 4, 5)
    pqs = posq.reshape(nq, qb)
    ks = kp.reshape(B, nk, kb, K, hd).transpose(1, 0, 2, 3, 4)
    vs = vp.reshape(B, nk, kb, K, hv).transpose(1, 0, 2, 3, 4)
    pks = posk.reshape(nk, kb)

    def q_step(_, qx):
        qi, pqi = qx

        def kv_step(carry, kx):
            acc, m, l = carry
            ki, vi, pki = kx
            s = jnp.einsum("bqkgd,bskd->bkgqs", qi, ki).astype(jnp.float32) * scale
            msk = _mask(pqi, pki, causal, window)
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskv->bkgqv", p.astype(vi.dtype), vi).astype(jnp.float32)
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((B, K, G, qb, hv), jnp.float32)
        m0 = jnp.full((B, K, G, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, qb), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0), (ks, vs, pks))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.transpose(0, 3, 1, 2, 4)  # (B, qb, K, G, hv)

    _, outs = jax.lax.scan(q_step, None, (qs, pqs))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * qb, K, G, hv)
    return out[:, :Sq].astype(v.dtype)


# ---------------------------------------------------------------------------
# GQA family
def attn_specs(cfg: ArchConfig, dtype) -> Dict[str, ParamSpec]:
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    specs = {
        "wq": ParamSpec((d, H * hd), dtype, ("fsdp", "tp")),
        "wk": ParamSpec((d, K * hd), dtype, ("fsdp", "tp")),
        "wv": ParamSpec((d, K * hd), dtype, ("fsdp", "tp")),
        "wo": ParamSpec((H * hd, d), dtype, ("tp", "fsdp")),
    }
    if cfg.qk_norm:
        specs["q_norm"] = ParamSpec((hd,), dtype, (None,), init="ones")
        specs["k_norm"] = ParamSpec((hd,), dtype, (None,), init="ones")
    return specs


def _alloc_len(cfg: ArchConfig, max_len: int) -> int:
    if getattr(cfg, "swa_ring_cache", False) and cfg.sliding_window:
        return min(max_len, cfg.sliding_window)
    return max_len


def init_kv_cache(cfg: ArchConfig, batch: int, max_len: int, dtype) -> KVCache:
    K, hd = cfg.n_kv_heads, cfg.hd
    S = _alloc_len(cfg, max_len)
    return KVCache(
        k=jnp.zeros((batch, S, K, hd), dtype),
        v=jnp.zeros((batch, S, K, hd), dtype),
        pos=jnp.full((S,), EMPTY_POS, jnp.int32),
        length=jnp.zeros((), jnp.int32))


def attn_apply(cfg: ArchConfig, p: Dict[str, jnp.ndarray], x: jnp.ndarray,
               positions: jnp.ndarray, *, causal: bool = True,
               cache: Optional[KVCache] = None,
               kv_override: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
               window: Optional[int] = None,
               ) -> Tuple[jnp.ndarray, Optional[KVCache]]:
    """x: (B, S, d). decode when cache is not None and S == 1.

    kv_override: (k_src, v_src) for cross-attention (enc-dec): keys/values
    computed from encoder output positions instead of x.
    """
    B, S, d = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    G = H // K
    window = window if window is not None else cfg.sliding_window

    q = (x @ p["wq"]).reshape(B, S, H, hd)
    src = kv_override[0] if kv_override is not None else x
    k = (src @ p["wk"]).reshape(B, src.shape[1], K, hd)
    v = ((kv_override[1] if kv_override is not None else x) @ p["wv"]
         ).reshape(B, src.shape[1], K, hd)

    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])

    if kv_override is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, S, K, G, hd)

    if cache is not None and kv_override is None and S == 1:
        # decode: append the new token (ring slot = pos mod alloc when the
        # ring cache is on; dense slot otherwise), attend over stored
        # positions.  (Prefill — S > 1 — must NOT take this path: it goes
        # through the blocked path below and then writes the cache.)
        S_alloc = cache.k.shape[1]
        slot = jnp.mod(cache.length, S_alloc)
        new_k = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype),
                                             (0, slot, 0, 0))
        new_v = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype),
                                             (0, slot, 0, 0))
        new_pos = jax.lax.dynamic_update_slice(
            cache.pos, positions.astype(jnp.int32), (slot,))
        valid = new_pos >= 0
        mask = _mask(positions, new_pos, causal, window, valid_k=valid)
        out = _sdpa(qg, new_k, new_v, mask, scale)
        cache = KVCache(new_k, new_v, new_pos, cache.length + S)
    else:
        pos_k = (jnp.arange(src.shape[1]) if kv_override is not None
                 else positions)
        if (getattr(cfg, "use_pallas_attention", False)
                and kv_override is None and S == src.shape[1]):
            # Pallas flash kernel (kernels/attention): contiguous positions
            # only (training/prefill); interpret-mode on CPU backends.
            from ..kernels.attention.ops import flash_attention
            out = flash_attention(q, k, v, causal=causal, window=window
                                  ).reshape(B, S, K, G, hd)
        elif S * src.shape[1] <= 1 << 22:  # small: plain attention
            mask = _mask(positions, pos_k, causal and kv_override is None, window)
            out = _sdpa(qg, k, v, mask, scale)
        else:
            out = _blocked_sdpa(qg, k, v, positions, pos_k,
                                causal and kv_override is None, window, scale)
        if cache is not None:  # prefill into cache
            from ..sharding.partition import current_partitioning
            part = current_partitioning()
            if part.rules.get("seq_kv") and part.rules.get("prefill_kv_constrain"):
                # reshard k/v to the cache's KV-length sharding *before* the
                # cache write, so the update is a local dynamic-update-slice
                # instead of a replicate-then-partition all-reduce (§Perf)
                k = part.constrain(k, "batch", "seq_kv", None, None)
                v = part.constrain(v, "batch", "seq_kv", None, None)
            S_alloc = cache.k.shape[1]
            if S <= S_alloc:
                new_k = jax.lax.dynamic_update_slice(
                    cache.k, k.astype(cache.k.dtype), (0, 0, 0, 0))
                new_v = jax.lax.dynamic_update_slice(
                    cache.v, v.astype(cache.v.dtype), (0, 0, 0, 0))
                new_pos = jax.lax.dynamic_update_slice(
                    cache.pos, positions.astype(jnp.int32), (0,))
            else:
                # ring cache + long prompt: keep the trailing window, placed
                # at slot = position mod S_alloc
                shift = S % S_alloc
                new_k = jnp.roll(k[:, S - S_alloc:], shift, axis=1
                                 ).astype(cache.k.dtype)
                new_v = jnp.roll(v[:, S - S_alloc:], shift, axis=1
                                 ).astype(cache.v.dtype)
                new_pos = jnp.roll(positions[S - S_alloc:], shift
                                   ).astype(jnp.int32)
            cache = KVCache(new_k, new_v, new_pos, jnp.asarray(S, jnp.int32))

    out = out.reshape(B, S, H * hd).astype(x.dtype)
    return out @ p["wo"], cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3)
def mla_specs(cfg: ArchConfig, dtype) -> Dict[str, ParamSpec]:
    d, H = cfg.d_model, cfg.n_heads
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    return {
        "wq_a": ParamSpec((d, cfg.q_lora_rank), dtype, ("fsdp", None)),
        "q_norm": ParamSpec((cfg.q_lora_rank,), dtype, (None,), init="ones"),
        "wq_b": ParamSpec((cfg.q_lora_rank, H * qk), dtype, (None, "tp")),
        "wkv_a": ParamSpec((d, cfg.kv_lora_rank + cfg.qk_rope_dim), dtype,
                           ("fsdp", None)),
        "kv_norm": ParamSpec((cfg.kv_lora_rank,), dtype, (None,), init="ones"),
        "wk_b": ParamSpec((cfg.kv_lora_rank, H * cfg.qk_nope_dim), dtype,
                          (None, "tp")),
        "wv_b": ParamSpec((cfg.kv_lora_rank, H * cfg.v_head_dim), dtype,
                          (None, "tp")),
        "wo": ParamSpec((H * cfg.v_head_dim, d), dtype, ("tp", "fsdp")),
    }


def init_mla_cache(cfg: ArchConfig, batch: int, max_len: int, dtype) -> MLACache:
    return MLACache(
        c_kv=jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        k_rope=jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
        length=jnp.zeros((), jnp.int32))


def mla_apply(cfg: ArchConfig, p: Dict[str, jnp.ndarray], x: jnp.ndarray,
              positions: jnp.ndarray, *, cache: Optional[MLACache] = None,
              ) -> Tuple[jnp.ndarray, Optional[MLACache]]:
    B, S, d = x.shape
    H = cfg.n_heads
    nd, rd, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    scale = 1.0 / math.sqrt(nd + rd)

    q = rmsnorm(x @ p["wq_a"], p["q_norm"]) @ p["wq_b"]
    q = q.reshape(B, S, H, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv_a = x @ p["wkv_a"]
    c_kv = rmsnorm(kv_a[..., :cfg.kv_lora_rank], p["kv_norm"])
    k_rope = apply_rope(kv_a[..., None, cfg.kv_lora_rank:], positions,
                        cfg.rope_theta)[..., 0, :]  # single shared rope head

    if cache is not None and S == 1:
        # absorbed decode: queries projected into the latent space so the
        # cache stays compressed (the MLA serving trick).
        start = cache.length
        c_all = jax.lax.dynamic_update_slice(
            cache.c_kv, c_kv.astype(cache.c_kv.dtype), (0, start, 0))
        r_all = jax.lax.dynamic_update_slice(
            cache.k_rope, k_rope.astype(cache.k_rope.dtype), (0, start, 0))
        wk_b = p["wk_b"].reshape(cfg.kv_lora_rank, H, nd)
        q_lat = jnp.einsum("bshn,lhn->bshl", q_nope, wk_b)  # (B,1,H,latent)
        s_lat = jnp.einsum("bshl,btl->bhst", q_lat, c_all)
        s_rope = jnp.einsum("bshr,btr->bhst", q_rope, r_all)
        scores = (s_lat + s_rope).astype(jnp.float32) * scale
        pos_k = jnp.arange(c_all.shape[1])
        valid = pos_k < (start + S)
        mask = (positions[:, None] >= pos_k[None, :]) & valid[None, :]
        scores = jnp.where(mask[None, None], scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1).astype(c_all.dtype)
        o_lat = jnp.einsum("bhst,btl->bshl", w, c_all)
        wv_b = p["wv_b"].reshape(cfg.kv_lora_rank, H, vd)
        out = jnp.einsum("bshl,lhv->bshv", o_lat, wv_b)
        cache = MLACache(c_all, r_all, cache.length + S)
    else:
        k_nope = (c_kv @ p["wk_b"]).reshape(B, S, H, nd)
        value = (c_kv @ p["wv_b"]).reshape(B, S, H, vd)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, rd))],
            axis=-1)
        qfull = jnp.concatenate([q_nope, q_rope], axis=-1)
        qg = qfull.reshape(B, S, H, 1, nd + rd)
        if S * S <= 1 << 22:
            mask = _mask(positions, positions, True, None)
            out = _sdpa(qg, k, value, mask, scale)
        else:
            out = _blocked_sdpa(qg, k, value, positions, positions, True,
                                None, scale)
        out = out.reshape(B, S, H, vd)
        if cache is not None:
            c_all = jax.lax.dynamic_update_slice(
                cache.c_kv, c_kv.astype(cache.c_kv.dtype), (0, 0, 0))
            r_all = jax.lax.dynamic_update_slice(
                cache.k_rope, k_rope.astype(cache.k_rope.dtype), (0, 0, 0))
            cache = MLACache(c_all, r_all, jnp.asarray(S, jnp.int32))

    out = out.reshape(B, S, H * vd).astype(x.dtype)
    return out @ p["wo"], cache
