"""Physical machine model (TPU analog of the paper's compute-node cluster).

The paper's abstraction: N nodes of n cores, fast intra-node / slow
inter-node communication.  Ours: ``num_pods`` pods of ``chips_per_pod``
chips; within a pod chips sit on a 2-d ICI torus with per-link bandwidth
``ici_bw``; pods are connected by DCI with per-chip bandwidth ``dci_bw``
(slower, the analog of the inter-node network).

Default constants are TPU v5e (the assignment's roofline constants):
197 TFLOP/s bf16, 819 GB/s HBM, 16 GiB HBM, ~50 GB/s per ICI link.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Sequence, Tuple

import numpy as np

__all__ = ["MachineSpec", "RaggedMachineSpec", "V5E_POD", "V5E_2POD"]


@dataclass(frozen=True)
class MachineSpec:
    name: str = "tpu-v5e"
    num_pods: int = 1
    torus: Tuple[int, ...] = (16, 16)        # intra-pod ICI torus shape
    peak_flops_bf16: float = 197e12          # per chip
    hbm_bw: float = 819e9                    # bytes/s per chip
    hbm_bytes: float = 16 * 2**30            # per chip
    ici_bw: float = 50e9                     # bytes/s per ICI link (per dir)
    dci_bw: float = 6.25e9                   # bytes/s per chip across pods
    vmem_bytes: float = 128 * 2**20          # VMEM per chip (v5e ~128MB)

    @property
    def chips_per_pod(self) -> int:
        return int(math.prod(self.torus))

    @property
    def num_chips(self) -> int:
        return self.num_pods * self.chips_per_pod

    # -- chip addressing ----------------------------------------------------
    def pod_of(self, chip: int) -> int:
        return chip // self.chips_per_pod

    def torus_coord(self, chip: int) -> Tuple[int, ...]:
        return tuple(int(c) for c in
                     np.unravel_index(chip % self.chips_per_pod, self.torus))

    def node_sizes(self) -> list[int]:
        """The paper's N x n allocation: pods as nodes."""
        return [self.chips_per_pod] * self.num_pods

    def torus_hop_path(self, a: int, b: int) -> list[Tuple[int, Tuple[int, ...], int]]:
        """Dimension-ordered shortest-path routing between two chips in the
        same pod.  Returns a list of directed link identifiers
        ``(axis, from_coord, direction)`` traversed."""
        assert self.pod_of(a) == self.pod_of(b)
        ca, cb = list(self.torus_coord(a)), list(self.torus_coord(b))
        links = []
        for ax, size in enumerate(self.torus):
            while ca[ax] != cb[ax]:
                fwd = (cb[ax] - ca[ax]) % size
                bwd = (ca[ax] - cb[ax]) % size
                step = +1 if fwd <= bwd else -1
                links.append((ax, tuple(ca), step))
                ca[ax] = (ca[ax] + step) % size
        return links

    def __post_init__(self):
        if self.num_pods < 1 or self.chips_per_pod < 1:
            raise ValueError("machine must have at least one pod and one chip")


@dataclass(frozen=True)
class RaggedMachineSpec(MachineSpec):
    """Machine with per-pod chip counts (elastic allocations after chip
    loss).  Pod i holds ``pod_sizes[i]`` chips on a 1-d ICI ring; chips are
    numbered pod-major (pod 0's chips first), matching the blocked rank
    allocation.  ``num_pods``/``torus`` are derived — ``torus`` is set to
    the *smallest* pod's ring so bandwidth-derived quantities
    (``LinkReport.times``) stay conservative.
    """

    pod_sizes: Tuple[int, ...] = ()

    def __post_init__(self):
        sizes = tuple(int(s) for s in self.pod_sizes)
        if not sizes or any(s < 1 for s in sizes):
            raise ValueError(f"pod_sizes must be positive, got {self.pod_sizes}")
        object.__setattr__(self, "pod_sizes", sizes)
        object.__setattr__(self, "num_pods", len(sizes))
        object.__setattr__(self, "torus", (min(sizes),))
        starts = (0,) + tuple(np.cumsum(sizes).tolist())
        object.__setattr__(self, "_starts", starts)
        super().__post_init__()

    @property
    def num_chips(self) -> int:
        return sum(self.pod_sizes)

    def node_sizes(self) -> list[int]:
        return list(self.pod_sizes)

    def pod_of(self, chip: int) -> int:
        return int(np.searchsorted(np.asarray(self._starts), chip,
                                   side="right")) - 1

    def torus_coord(self, chip: int) -> Tuple[int, ...]:
        return (chip - self._starts[self.pod_of(chip)],)

    def torus_hop_path(self, a: int, b: int) -> list[Tuple[int, Tuple[int, ...], int]]:
        pod = self.pod_of(a)
        assert pod == self.pod_of(b)
        size = self.pod_sizes[pod]
        ca, cb = self.torus_coord(a)[0], self.torus_coord(b)[0]
        links = []
        while ca != cb:
            fwd = (cb - ca) % size
            bwd = (ca - cb) % size
            step = +1 if fwd <= bwd else -1
            links.append((0, (ca,), step))
            ca = (ca + step) % size
        return links


V5E_POD = MachineSpec(name="tpu-v5e-256", num_pods=1, torus=(16, 16))
V5E_2POD = MachineSpec(name="tpu-v5e-2x256", num_pods=2, torus=(16, 16))
