"""Physical machine model (TPU analog of the paper's compute-node cluster).

The paper's abstraction: N nodes of n cores, fast intra-node / slow
inter-node communication.  Ours: ``num_pods`` pods of ``chips_per_pod``
chips; within a pod chips sit on a 2-d ICI torus with per-link bandwidth
``ici_bw``; pods are connected by DCI with per-chip bandwidth ``dci_bw``
(slower, the analog of the inter-node network).

Deep machines additionally carry a ``levels`` description — the grouping
hierarchy *from the root down to the pods* (e.g. rack → pod), each level a
:class:`LevelSpec` with a fan-out (children per parent) and a per-chip
bandwidth across that level's boundary.  The fan-outs must multiply to
``num_pods``; chips are the implicit leaf level below pods.
:meth:`MachineSpec.topology_tree` materializes the hierarchy as a
:class:`TopologyTree`, the navigation object the hierarchical mapper
(``hier:`` — :mod:`repro.core.refine.hier`) and the per-level linksim
replay (:mod:`repro.analysis.linksim`) share.

Default constants are TPU v5e (the assignment's roofline constants):
197 TFLOP/s bf16, 819 GB/s HBM, 16 GiB HBM, ~50 GB/s per ICI link.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["LevelSpec", "MachineSpec", "RaggedMachineSpec", "TopologyTree",
           "derive_fanouts", "V5E_POD", "V5E_2POD", "V5E_4RACK"]


def _ordered_factorizations(n: int, depth: int):
    """All ordered ``depth``-tuples of positive ints multiplying to ``n``
    (fan-outs of 1 allowed: a level may be trivial, e.g. a prime pod
    count at depth 2)."""
    if depth == 1:
        yield (n,)
        return
    for f in range(1, n + 1):
        if n % f == 0:
            for rest in _ordered_factorizations(n // f, depth - 1):
                yield (f,) + rest


def derive_fanouts(node_sizes: Sequence[int], depth: int = 2) \
        -> Tuple[int, ...]:
    """Per-level fan-outs grouping ``len(node_sizes)`` pods into a
    ``depth``-level hierarchy, derived from the *actual* per-pod chip
    counts instead of assuming contiguous equal pod groups.

    The balanced pod-count split (``dims_create`` on ``len(node_sizes)``)
    is only right for uniform pods: on a ragged allocation it can lump
    every large pod under one parent, so subtree chip counts — the
    restricted problems the hierarchical mapper solves — end up wildly
    skewed.  This derivation scores every ordered factorization of the pod
    count by the total chip imbalance of the contiguous groups it induces
    (sum over grouping levels of ``max - min`` subtree chips) and returns
    the most balanced one; ties prefer the balanced ``dims_create`` split,
    then squarer factors.  Uniform pods score 0 for every candidate, so
    uniform machines keep the exact ``dims_create`` fan-outs.
    """
    sizes = [int(s) for s in node_sizes]
    if not sizes or any(s < 1 for s in sizes):
        raise ValueError(f"node_sizes must be positive, got {node_sizes!r}")
    n, depth = len(sizes), max(1, int(depth))
    starts = np.concatenate(([0], np.cumsum(np.asarray(sizes,
                                                       dtype=np.int64))))

    def score(fo: Tuple[int, ...]) -> int:
        total = 0
        for level in range(1, len(fo)):      # grouping cuts above the pods
            stride = math.prod(fo[level:])
            groups = np.diff(starts[::stride])
            total += int(groups.max() - groups.min())
        return total

    from repro.core.grid import dims_create   # lazy: keeps topology light
    balanced = tuple(dims_create(n, depth))
    best = min(_ordered_factorizations(n, depth),
               key=lambda fo: (score(fo), max(fo), fo))
    return balanced if score(balanced) == score(best) else best


@dataclass(frozen=True)
class LevelSpec:
    """One grouping level of a machine hierarchy: every node of the level
    above splits into ``fanout`` children; ``bw`` is the per-chip bandwidth
    (bytes/s) across this level's boundary (0.0 = unspecified)."""

    name: str
    fanout: int
    bw: float = 0.0

    def __post_init__(self):
        if int(self.fanout) < 1:
            raise ValueError(f"level {self.name!r} fanout must be >= 1, "
                             f"got {self.fanout}")
        object.__setattr__(self, "fanout", int(self.fanout))


@dataclass(frozen=True)
class MachineSpec:
    name: str = "tpu-v5e"
    num_pods: int = 1
    torus: Tuple[int, ...] = (16, 16)        # intra-pod ICI torus shape
    peak_flops_bf16: float = 197e12          # per chip
    hbm_bw: float = 819e9                    # bytes/s per chip
    hbm_bytes: float = 16 * 2**30            # per chip
    ici_bw: float = 50e9                     # bytes/s per ICI link (per dir)
    dci_bw: float = 6.25e9                   # bytes/s per chip across pods
    vmem_bytes: float = 128 * 2**20          # VMEM per chip (v5e ~128MB)
    #: grouping hierarchy root -> pods (fan-outs multiply to ``num_pods``);
    #: empty = the flat machine (one implicit "pod" level).
    levels: Tuple[LevelSpec, ...] = ()

    @property
    def chips_per_pod(self) -> int:
        return int(math.prod(self.torus))

    @property
    def num_chips(self) -> int:
        return self.num_pods * self.chips_per_pod

    # -- chip addressing ----------------------------------------------------
    def _check_chip(self, chip: int) -> int:
        chip = int(chip)
        if not 0 <= chip < self.num_chips:
            raise ValueError(f"chip id {chip} out of range for "
                             f"{self.name!r} with {self.num_chips} chips")
        return chip

    def pod_of(self, chip: int) -> int:
        return self._check_chip(chip) // self.chips_per_pod

    def torus_coord(self, chip: int) -> Tuple[int, ...]:
        chip = self._check_chip(chip)
        return tuple(int(c) for c in
                     np.unravel_index(chip % self.chips_per_pod, self.torus))

    def node_sizes(self) -> list[int]:
        """The paper's N x n allocation: pods as nodes."""
        return [self.chips_per_pod] * self.num_pods

    def topology_tree(self, depth: Optional[int] = None) -> "TopologyTree":
        """The machine's grouping hierarchy as a navigable tree.

        Machines without an explicit ``levels`` description can request a
        ``depth``-level hierarchy derived from the actual per-pod chip
        counts (:func:`derive_fanouts`) — ragged allocations get balanced
        subtree chip counts instead of the contiguous-equal-groups
        assumption."""
        if self.levels:
            if depth is not None and depth != len(self.levels):
                raise ValueError(
                    f"{self.name!r} declares {len(self.levels)} levels; "
                    f"cannot re-derive at depth {depth}")
            return TopologyTree(self.node_sizes(), self.levels)
        if depth is not None and int(depth) >= 1:
            return TopologyTree.derive(self.node_sizes(), int(depth))
        return TopologyTree(self.node_sizes(), self.levels)

    def torus_hop_path(self, a: int, b: int) -> list[Tuple[int, Tuple[int, ...], int]]:
        """Dimension-ordered shortest-path routing between two chips in the
        same pod.  Returns a list of directed link identifiers
        ``(axis, from_coord, direction)`` traversed."""
        assert self.pod_of(a) == self.pod_of(b)
        ca, cb = list(self.torus_coord(a)), list(self.torus_coord(b))
        links = []
        for ax, size in enumerate(self.torus):
            while ca[ax] != cb[ax]:
                fwd = (cb[ax] - ca[ax]) % size
                bwd = (ca[ax] - cb[ax]) % size
                step = +1 if fwd <= bwd else -1
                links.append((ax, tuple(ca), step))
                ca[ax] = (ca[ax] + step) % size
        return links

    def __post_init__(self):
        if self.num_pods < 1 or self.chips_per_pod < 1:
            raise ValueError("machine must have at least one pod and one chip")
        if self.levels:
            object.__setattr__(self, "levels", tuple(self.levels))
            fan = math.prod(l.fanout for l in self.levels)
            if fan != self.num_pods:
                raise ValueError(
                    f"level fan-outs {[l.fanout for l in self.levels]} "
                    f"multiply to {fan}, machine has {self.num_pods} pods")


@dataclass(frozen=True)
class RaggedMachineSpec(MachineSpec):
    """Machine with per-pod chip counts (elastic allocations after chip
    loss).  Pod i holds ``pod_sizes[i]`` chips on a 1-d ICI ring; chips are
    numbered pod-major (pod 0's chips first), matching the blocked rank
    allocation.  ``num_pods``/``torus`` are derived — ``torus`` is set to
    the *smallest* pod's ring so bandwidth-derived quantities
    (``LinkReport.times``) stay conservative.
    """

    pod_sizes: Tuple[int, ...] = ()

    def __post_init__(self):
        sizes = tuple(int(s) for s in self.pod_sizes)
        if not sizes or any(s < 1 for s in sizes):
            raise ValueError(f"pod_sizes must be positive, got {self.pod_sizes}")
        object.__setattr__(self, "pod_sizes", sizes)
        object.__setattr__(self, "num_pods", len(sizes))
        object.__setattr__(self, "torus", (min(sizes),))
        starts = (0,) + tuple(np.cumsum(sizes).tolist())
        object.__setattr__(self, "_starts", starts)
        super().__post_init__()

    @property
    def num_chips(self) -> int:
        return sum(self.pod_sizes)

    def node_sizes(self) -> list[int]:
        return list(self.pod_sizes)

    def pod_of(self, chip: int) -> int:
        chip = self._check_chip(chip)
        return int(np.searchsorted(np.asarray(self._starts), chip,
                                   side="right")) - 1

    def torus_coord(self, chip: int) -> Tuple[int, ...]:
        return (chip - self._starts[self.pod_of(chip)],)

    def torus_hop_path(self, a: int, b: int) -> list[Tuple[int, Tuple[int, ...], int]]:
        pod = self.pod_of(a)
        assert pod == self.pod_of(b)
        size = self.pod_sizes[pod]
        ca, cb = self.torus_coord(a)[0], self.torus_coord(b)[0]
        links = []
        while ca != cb:
            fwd = (cb - ca) % size
            bwd = (ca - cb) % size
            step = +1 if fwd <= bwd else -1
            links.append((0, (ca,), step))
            ca = (ca + step) % size
        return links


class TopologyTree:
    """Rooted tree over a machine's chips: root → grouping levels
    (``levels``, root-to-pods) → pods → chip leaves.

    Nodes are addressed ``(level, index)``: level 0 is the root (one node),
    level ``depth`` holds the pods (``num_pods`` nodes), and node
    ``(l, j)``'s children are the level-``l+1`` nodes
    ``j*fanout .. (j+1)*fanout - 1`` — pods stay contiguous under every
    subtree, so a subtree is fully described by a pod range.  Ragged pod
    sizes are first-class: per-subtree chip counts are sums of
    ``pod_sizes`` slices.
    """

    def __init__(self, pod_sizes: Sequence[int],
                 levels: Sequence[LevelSpec] = ()):
        sizes = tuple(int(s) for s in pod_sizes)
        if not sizes or any(s < 1 for s in sizes):
            raise ValueError(f"pod_sizes must be positive, got {pod_sizes}")
        if not levels:
            levels = (LevelSpec("pod", len(sizes)),)
        levels = tuple(levels)
        fan = math.prod(l.fanout for l in levels)
        if fan != len(sizes):
            raise ValueError(
                f"level fan-outs {[l.fanout for l in levels]} multiply to "
                f"{fan}, tree has {len(sizes)} pods")
        self.pod_sizes = sizes
        self.levels = levels
        self._chip_starts = np.concatenate(
            ([0], np.cumsum(np.asarray(sizes, dtype=np.int64))))

    @classmethod
    def derive(cls, pod_sizes: Sequence[int], depth: int = 2,
               level_names: Sequence[str] = ()) -> "TopologyTree":
        """Build a ``depth``-level tree whose fan-outs are derived from
        the actual ``pod_sizes`` grouping (:func:`derive_fanouts`) —
        the ragged-aware counterpart of assuming equal contiguous pod
        groups."""
        fanouts = derive_fanouts(pod_sizes, depth)
        names = (list(level_names) or
                 [f"l{i + 1}" for i in range(len(fanouts))])
        if len(names) != len(fanouts):
            raise ValueError(f"{len(names)} level names for "
                             f"{len(fanouts)} levels")
        return cls(pod_sizes,
                   tuple(LevelSpec(nm, f) for nm, f in zip(names, fanouts)))

    # -- shape ---------------------------------------------------------------
    @property
    def depth(self) -> int:
        """Number of grouping levels (pods live at level ``depth``)."""
        return len(self.levels)

    @property
    def num_pods(self) -> int:
        return len(self.pod_sizes)

    @property
    def num_chips(self) -> int:
        return int(self._chip_starts[-1])

    def leaf_count(self) -> int:
        return self.num_chips

    def node_sizes(self) -> List[int]:
        """Round-trips ``machine.node_sizes()`` (pods as nodes)."""
        return list(self.pod_sizes)

    def num_nodes_at(self, level: int) -> int:
        """Node count at ``level`` (0 = root, ``depth`` = pods)."""
        if not 0 <= level <= self.depth:
            raise ValueError(f"level {level} out of range 0..{self.depth}")
        return math.prod(l.fanout for l in self.levels[:level])

    def fanout_at(self, level: int) -> int:
        """Children per node of a level-``level`` node (chips below pods)."""
        if level == self.depth:
            raise ValueError("pods have per-pod chip counts, not one fanout")
        return self.levels[level].fanout

    # -- navigation ----------------------------------------------------------
    def _pod_stride(self, level: int) -> int:
        return math.prod(l.fanout for l in self.levels[level:])

    def pod_range(self, level: int, index: int) -> Tuple[int, int]:
        """Contiguous pod ids ``[lo, hi)`` under node ``(level, index)``."""
        n = self.num_nodes_at(level)
        if not 0 <= index < n:
            raise ValueError(f"node index {index} out of range for level "
                             f"{level} with {n} nodes")
        stride = self._pod_stride(level)
        return index * stride, (index + 1) * stride

    def chip_range(self, level: int, index: int) -> Tuple[int, int]:
        """Contiguous chip ids ``[lo, hi)`` under node ``(level, index)``."""
        lo, hi = self.pod_range(level, index)
        return int(self._chip_starts[lo]), int(self._chip_starts[hi])

    def chip_count(self, level: int, index: int) -> int:
        lo, hi = self.chip_range(level, index)
        return hi - lo

    def child_sizes(self, level: int, index: int) -> List[int]:
        """Chip counts of the children of node ``(level, index)`` — the
        restricted problem's "node sizes" for the hierarchical mapper."""
        if level == self.depth:                  # a pod: children are chips
            return [1] * self.chip_count(level, index)
        lo, _ = self.pod_range(level, index)
        f = self.levels[level].fanout
        stride = self._pod_stride(level + 1)
        return [int(self._chip_starts[lo + (c + 1) * stride]
                    - self._chip_starts[lo + c * stride]) for c in range(f)]

    def level_node_of_pod(self, pod: int, level: int) -> int:
        """The level-``level`` ancestor of ``pod``."""
        if not 0 <= int(pod) < self.num_pods:
            raise ValueError(f"pod id {pod} out of range for "
                             f"{self.num_pods} pods")
        return int(pod) // self._pod_stride(level)

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        shape = "x".join(str(l.fanout) for l in self.levels)
        return (f"TopologyTree(levels={shape}, pods={self.num_pods}, "
                f"chips={self.num_chips})")


V5E_POD = MachineSpec(name="tpu-v5e-256", num_pods=1, torus=(16, 16))
V5E_2POD = MachineSpec(name="tpu-v5e-2x256", num_pods=2, torus=(16, 16))
#: a deep machine: 4 racks x 4 pods of 256 chips, with per-level bandwidth
#: (DCI within a rack, thinner spine across racks).
V5E_4RACK = MachineSpec(name="tpu-v5e-4x4x256", num_pods=16, torus=(16, 16),
                        levels=(LevelSpec("rack", 4, bw=3.125e9),
                                LevelSpec("pod", 4, bw=6.25e9)))
