from .machine import V5E_2POD, V5E_POD, MachineSpec

__all__ = ["MachineSpec", "V5E_POD", "V5E_2POD"]
