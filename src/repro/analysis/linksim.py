"""Per-link traffic simulation (the J_sum/J_max analog on real topology).

Takes the collectives of a compiled module (``hlo.CollectiveStat``), a device
layout (logical mesh position -> physical chip), and a ``MachineSpec``; plays
each collective with a canonical schedule and accumulates bytes on every
physical link:

  * all-reduce / all-gather / reduce-scatter: logical ring over the group's
    members sorted by physical chip id (a topology-aware runtime's ring);
    bytes per ring edge from the standard ring-algorithm volumes.
  * all-to-all: pairwise traffic B/G between all member pairs (groups are
    small in practice — EP/TP axes); for G > ``a2a_route_limit`` we skip
    per-pair routing and use the uniform bisection approximation.
  * collective-permute: explicit source-target pairs, payload B each.

Intra-pod edges are routed dimension-ordered on the pod's ICI torus; each
traversed link accumulates the bytes.  Inter-pod edges accumulate on the
(pod, pod) DCI counter and each endpoint's DCI egress.

Outputs mirror the paper's metrics: ``dci_total`` ~ J_sum (inter-node
traffic), ``dci_per_pod`` max ~ J_max (bottleneck node), plus estimated
times from link bandwidths — this is what the mapping algorithms optimize.
"""
from __future__ import annotations

import math
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from ..topology.machine import LevelSpec, MachineSpec, RaggedMachineSpec

from .hlo import CollectiveStat

__all__ = ["LinkReport", "simulate", "stencil_collectives",
           "graph_collectives", "machine_for_nodes", "replay_assignment",
           "replay_graph"]


@dataclass
class LinkReport:
    ici_link_bytes: Dict[Tuple[int, int, Tuple[int, ...], int], float]
    dci_pair_bytes: Dict[Tuple[int, int], float]
    dci_pod_egress: np.ndarray          # (num_pods,)
    ici_total: float = 0.0
    dci_total: float = 0.0
    #: per grouping level (``machine.levels``): egress bytes per level
    #: node, attributed wherever the two endpoints' ancestors at that
    #: level differ.  The finest (pod) level always equals
    #: ``dci_pod_egress`` — the parity invariant the tests pin.
    level_egress: Dict[str, np.ndarray] = field(default_factory=dict)

    def max_ici_link(self) -> float:
        return max(self.ici_link_bytes.values(), default=0.0)

    def max_dci_pod(self) -> float:
        return float(self.dci_pod_egress.max(initial=0.0))

    def max_level_egress(self, level: str) -> float:
        return float(self.level_egress[level].max(initial=0.0))

    def times(self, machine: MachineSpec) -> Dict[str, float]:
        t_ici = self.max_ici_link() / machine.ici_bw
        pod_dci_bw = machine.dci_bw * machine.chips_per_pod
        t_dci = self.max_dci_pod() / pod_dci_bw if machine.num_pods > 1 else 0.0
        return {"t_ici_bottleneck": t_ici, "t_dci_bottleneck": t_dci,
                "t_comm": max(t_ici, t_dci)}

    def summary(self) -> Dict[str, float]:
        return {
            "ici_total_bytes": self.ici_total,
            "dci_total_bytes": self.dci_total,       # ~ J_sum
            "max_ici_link_bytes": self.max_ici_link(),
            "max_dci_pod_bytes": self.max_dci_pod(),  # ~ J_max
        }


def _route(machine: MachineSpec, report: LinkReport, a: int, b: int, bytes_: float):
    """Accumulate bytes for one directed chip-to-chip transfer."""
    if bytes_ <= 0 or a == b:
        return
    pa, pb = machine.pod_of(a), machine.pod_of(b)
    if pa != pb:
        key = (min(pa, pb), max(pa, pb))
        report.dci_pair_bytes[key] += bytes_
        report.dci_pod_egress[pa] += bytes_
        report.dci_total += bytes_
        for name, of_pod in getattr(report, "_level_of_pod", {}).items():
            ga, gb = int(of_pod[pa]), int(of_pod[pb])
            if ga != gb:
                report.level_egress[name][ga] += bytes_
        return
    path = machine.torus_hop_path(a, b)
    for link in path:
        report.ici_link_bytes[(pa,) + link] += bytes_
    report.ici_total += bytes_ * max(1, len(path))


def simulate(collectives: Iterable[CollectiveStat], layout_flat: np.ndarray,
             machine: MachineSpec, a2a_route_limit: int = 64) -> LinkReport:
    """Simulate collective traffic.

    Args:
      layout_flat: (num_devices,) physical chip id for each logical mesh
        position (``mesh.devices.flatten()`` order — the order the HLO's
        global device ids refer to).
    """
    n = len(layout_flat)
    report = LinkReport(ici_link_bytes=defaultdict(float),
                        dci_pair_bytes=defaultdict(float),
                        dci_pod_egress=np.zeros(machine.num_pods))
    if machine.levels:
        # per-level replay: precompute each pod's ancestor at every
        # grouping level once (pods are contiguous under every subtree)
        tree = machine.topology_tree()
        pods = np.arange(machine.num_pods)
        report._level_of_pod = {
            spec.name: pods // tree._pod_stride(lvl)
            for lvl, spec in enumerate(machine.levels, start=1)}
        report.level_egress = {
            spec.name: np.zeros(tree.num_nodes_at(lvl))
            for lvl, spec in enumerate(machine.levels, start=1)}
    for c in collectives:
        groups = c.groups
        if c.pairs is not None:
            for (src, dst) in c.pairs:
                _route(machine, report,
                       int(layout_flat[src]), int(layout_flat[dst]),
                       c.payload_bytes * c.multiplier)
            continue
        if groups is None:
            groups = [list(range(n))]
        for grp in groups:
            chips = sorted(int(layout_flat[g]) for g in grp)
            g = len(chips)
            if g <= 1:
                continue
            b = c.payload_bytes * c.multiplier
            if c.opcode.startswith("all-to-all") or c.opcode.startswith("ragged"):
                if g <= a2a_route_limit:
                    per_pair = b / g
                    for i in range(g):
                        for j in range(g):
                            if i != j:
                                _route(machine, report, chips[i], chips[j], per_pair)
                else:  # uniform approximation: half the traffic crosses any cut
                    pods = {machine.pod_of(ch) for ch in chips}
                    cross = b * (g - 1) / g * (len(pods) - 1) / max(len(pods), 1)
                    for ch in chips:
                        pa = machine.pod_of(ch)
                        report.dci_pod_egress[pa] += cross / g
                    report.dci_total += cross
                continue
            # ring schedules
            if c.opcode.startswith("all-reduce"):
                per_edge = 2.0 * b * (g - 1) / g
            elif c.opcode.startswith("all-gather"):
                per_edge = b * (g - 1)
            elif c.opcode.startswith("reduce-scatter"):
                per_edge = b * (g - 1) / g
            else:
                per_edge = b
            for i in range(g):
                _route(machine, report, chips[i], chips[(i + 1) % g], per_edge)
    return report


# ---------------------------------------------------------------------------
# Closing the loop: replay a *mapping* through the link simulator.
#
# The mapping algorithms optimize the abstract J_sum/J_max edge metrics;
# these helpers turn a stencil + node-of-position assignment into the
# equivalent collective-permute traffic and play it on a pods-as-nodes
# MachineSpec.  For unit weights the simulated ``max_dci_pod`` equals J_max
# and ``dci_total`` equals J_sum *exactly* (same directed source-counted
# accounting), which is what lets tests and `refine_suite --linksim` assert
# that better mapping metrics really mean less simulated bottleneck DCI
# traffic.

def stencil_collectives(grid, stencil, weighted=True) -> List[CollectiveStat]:
    """One collective-permute per stencil offset: a (src, dst) pair for
    every valid shifted rank, payload = the offset's byte weight
    (``weighted="auto"``/False supported as in the cost functions)."""
    from ..core.stencil import resolve_weighted
    use_w = resolve_weighted(weighted, stencil)
    colls = []
    for j, off in enumerate(stencil.offsets):
        valid, tgt = grid.shift_ranks(off)
        src = np.nonzero(valid)[0]
        colls.append(CollectiveStat(
            opcode="collective-permute", name=f"stencil-offset-{j}",
            computation="stencil-replay",
            payload_bytes=float(stencil.weights[j]) if use_w else 1.0,
            result_bytes=0.0, groups=None,
            pairs=list(zip(src.tolist(), tgt[src].tolist())),
            multiplier=1.0))
    return colls


def _near_square_torus(n: int) -> Tuple[int, ...]:
    """Factor ``n`` chips into the most-square 2-d torus (largest divisor
    ``a <= sqrt(n)`` -> ``(n//a, a)``); primes (and 1) stay a 1-d ring.
    256 -> (16, 16), matching ``V5E_POD``'s real intra-pod topology."""
    a = 1
    for d in range(int(math.isqrt(n)), 1, -1):
        if n % d == 0:
            a = d
            break
    return (n // a, a) if a > 1 else (n,)


def machine_for_nodes(node_sizes: Sequence[int],
                      name: str = "stencil-replay",
                      torus: Optional[Sequence[int]] = None,
                      levels: Sequence[LevelSpec] = ()) -> MachineSpec:
    """Pods-as-nodes machine for replaying mapping assignments.

    Homogeneous allocations get a uniform :class:`MachineSpec` whose
    intra-pod torus is the *near-square* factorization of the pod size
    (``[256]*k`` -> a (16,16) torus, V5E_POD's real shape — not the 1-d
    ring the pre-fix code modeled); pass ``torus`` to override the shape
    explicitly.  Ragged allocations (per-pod torus sizes — elastic pods
    after chip loss) get a :class:`~repro.topology.machine.RaggedMachineSpec`
    (1-d per-pod rings; an explicit ``torus`` is rejected there), so the
    elastic path closes the same ``dci_total == J_sum`` /
    ``max_dci_pod == J_max`` loop the homogeneous one does.  ``levels``
    (grouping :class:`~repro.topology.machine.LevelSpec` s, fan-outs
    multiplying to the pod count) switches on the per-level
    ``LinkReport.level_egress`` replay."""
    sizes = [int(s) for s in node_sizes]
    if any(s < 1 for s in sizes):
        raise ValueError(f"node sizes must be positive, got {sizes}")
    if len(set(sizes)) == 1:
        shape = _near_square_torus(sizes[0]) if torus is None \
            else tuple(int(t) for t in torus)
        if math.prod(shape) != sizes[0]:
            raise ValueError(f"torus {shape} does not hold a pod of "
                             f"{sizes[0]} chips")
        return MachineSpec(name=name, num_pods=len(sizes), torus=shape,
                           levels=tuple(levels))
    if torus is not None:
        raise ValueError("ragged pods route on per-pod 1-d rings; "
                         "an explicit torus shape only applies to "
                         "homogeneous allocations")
    return RaggedMachineSpec(name=name, pod_sizes=tuple(sizes),
                             levels=tuple(levels))


def replay_assignment(grid, stencil, node_of_pos: np.ndarray,
                      node_sizes: Sequence[int], weighted=True,
                      machine: Optional[MachineSpec] = None,
                      levels: Sequence[LevelSpec] = ()) -> LinkReport:
    """Simulate a mapping's stencil traffic on physical links.

    Ranks are assigned blocked (rank r on node r // n) with each node's
    grid positions taken in row-major order — the same convention as
    ``remap.device_layout(intra_order="rowmajor")`` — so the logical
    position -> chip layout is fully determined by the assignment.
    ``levels`` (when no explicit ``machine`` is given) builds the replay
    machine with a grouping hierarchy, so the report additionally carries
    per-level DCI egress (``LinkReport.level_egress``).
    """
    from ..core.cost import rowmajor_rank_layout
    node_of_pos = np.asarray(node_of_pos, dtype=np.int64)
    if machine is None:
        machine = machine_for_nodes(node_sizes, levels=levels)
    return simulate(stencil_collectives(grid, stencil, weighted=weighted),
                    rowmajor_rank_layout(node_of_pos), machine)


def graph_collectives(graph) -> List[CollectiveStat]:
    """One weighted collective-permute per slot of a
    :class:`~repro.core.graph.CommGraph`'s partial-permutation
    decomposition — every graph edge appears in exactly one slot, so the
    replayed traffic *is* the graph, edge for edge, weight for weight
    (:func:`stencil_collectives` on the graph's grid/slot-stencil
    forms)."""
    return stencil_collectives(graph.grid(), graph.slot_stencil(),
                               weighted=True)


def replay_graph(graph, node_of_pos: np.ndarray,
                 node_sizes: Sequence[int],
                 machine: Optional[MachineSpec] = None,
                 levels: Sequence[LevelSpec] = ()) -> LinkReport:
    """Replay a mapped :class:`~repro.core.graph.CommGraph`'s traffic on
    physical links (:func:`replay_assignment` over the graph forms).

    With whole-byte edge weights (all shipped graph builders round to
    integers) the report is *exact*: ``dci_total`` equals the graph
    J_sum and ``max_dci_pod()`` the graph J_max of the assignment,
    bit-for-bit — the machine-checkable contract the graph benchmark
    pins on every arch config.
    """
    ggrid, gstencil = graph.grid(), graph.slot_stencil()
    return replay_assignment(ggrid, gstencil, node_of_pos, node_sizes,
                             weighted=True, machine=machine, levels=levels)
