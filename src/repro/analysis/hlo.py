"""Mini parser for optimized HLO text (``compiled.as_text()``).

Why we parse ourselves instead of trusting ``cost_analysis()``:
XLA's HloCostAnalysis visits every computation **once** — the body of a
``while`` loop (which is how ``lax.scan`` over layers compiles) is *not*
multiplied by its trip count, so both FLOPs and bytes are undercounted by a
factor of ``num_layers`` for scanned models, and collectives inside the loop
are similarly invisible to naive line counting.  We therefore:

  * split the module into computations,
  * build the call graph (``body=``/``condition=`` for while, ``calls=`` for
    fusions/calls, ``branch_computations`` for conditionals, ``to_apply`` for
    reducers),
  * propagate *execution multipliers* from the entry computation, scaling
    while bodies by their ``known_trip_count`` backend config,
  * and then account dots (FLOPs), op bytes (≈ bytes accessed, post-fusion),
    and collectives (payload bytes, replica groups) with those multipliers.

All quantities are **per device** (the module is the SPMD per-partition
program); multiply by the number of participating chips for global values.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["HloModule", "CollectiveStat", "parse_hlo", "DTYPE_BYTES"]

DTYPE_BYTES = {
    "pred": 1, "s2": 0.25, "u2": 0.25, "s4": 0.5, "u4": 0.5,
    "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1, "f8e4m3b11fnuz": 1,
    "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "c64": 8, "c128": 16, "token": 0,
    "f4e2m1fn": 0.5, "e8m0fnu": 1,
}

_SHAPE_RE = re.compile(
    r"\b(" + "|".join(sorted(DTYPE_BYTES, key=len, reverse=True)) + r")\[([0-9,]*)\]")

COLLECTIVE_OPCODES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
)

_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*(?P<rest>.*)$")
_OPCODE_RE = re.compile(r"^(?P<type>.*?)\s*\b(?P<opcode>[a-z][a-z0-9\-]*)\(")
_COMP_HDR_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?(?P<name>[\w.\-]+)\s+\((?P<params>.*?)\)\s*->")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{(\{[0-9,{}\s]*\})\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")
_PAIRS_RE = re.compile(r"source_target_pairs=\{((?:\{\d+,\d+\},?)*)\}")

# ops whose own line should not contribute to the bytes estimate
_NO_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "custom-call", "partition-id",
    "replica-id", "rng-get-and-update-state", "opt-barrier",
}


def _shape_bytes(type_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for x in dims.split(","):
                n *= int(x)
        total += n * DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        out.append((dt, tuple(int(x) for x in dims.split(",")) if dims else ()))
    return out


@dataclass
class HloOp:
    name: str
    opcode: str
    result_type: str
    args_str: str
    attrs_str: str
    operands: List[str] = field(default_factory=list)

    @property
    def result_bytes(self) -> float:
        return _shape_bytes(self.result_type)


@dataclass
class Computation:
    name: str
    ops: Dict[str, HloOp] = field(default_factory=dict)
    order: List[str] = field(default_factory=list)
    shape_of: Dict[str, str] = field(default_factory=dict)
    is_entry: bool = False


@dataclass
class CollectiveStat:
    opcode: str
    name: str
    computation: str
    payload_bytes: float          # per-device operand payload of one execution
    result_bytes: float
    groups: Optional[List[List[int]]]  # device-id groups (None = all devices)
    pairs: Optional[List[Tuple[int, int]]]  # collective-permute only
    multiplier: float             # loop-corrected execution count

    @property
    def group_size(self) -> Optional[int]:
        if self.groups:
            return len(self.groups[0])
        return None

    @property
    def total_payload(self) -> float:
        return self.payload_bytes * self.multiplier

    def wire_bytes_per_device(self) -> float:
        """Bytes one participant moves over its links, ring/pairwise model."""
        g = self.group_size or 2
        b = self.payload_bytes
        if self.opcode.startswith("all-reduce"):
            w = 2.0 * b * (g - 1) / g
        elif self.opcode.startswith("all-gather"):
            w = b * (g - 1)             # b is the pre-gather shard here
        elif self.opcode.startswith("reduce-scatter"):
            w = b * (g - 1) / g         # b is the pre-scatter full buffer
        elif self.opcode.startswith("all-to-all") or self.opcode.startswith("ragged"):
            w = b * (g - 1) / g
        elif self.opcode.startswith("collective-permute"):
            w = b
        else:
            w = b
        return w * self.multiplier


def _split_paren_args(s: str, open_idx: int) -> Tuple[str, str]:
    """Given s with '(' at open_idx, return (inside, after_close)."""
    depth = 0
    for i in range(open_idx, len(s)):
        if s[i] == "(":
            depth += 1
        elif s[i] == ")":
            depth -= 1
            if depth == 0:
                return s[open_idx + 1:i], s[i + 1:]
    return s[open_idx + 1:], ""


def _parse_groups(attrs: str) -> Optional[List[List[int]]]:
    m = _GROUPS_IOTA_RE.search(attrs)
    if m:
        num_groups, group_size = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            perm = [int(x) for x in m.group(4).split(",")]
            ids = ids.transpose(perm)
        return ids.reshape(num_groups, group_size).tolist()
    m = _GROUPS_EXPLICIT_RE.search(attrs)
    if m:
        body = m.group(1)
        groups = []
        for grp in re.findall(r"\{([0-9,\s]*)\}", body):
            grp = grp.strip()
            if grp:
                groups.append([int(x) for x in grp.split(",")])
        return groups or None
    return None


def _parse_pairs(attrs: str) -> Optional[List[Tuple[int, int]]]:
    m = _PAIRS_RE.search(attrs)
    if not m:
        return None
    return [tuple(int(x) for x in p.split(","))
            for p in re.findall(r"\{(\d+,\d+)\}", m.group(1))]


class HloModule:
    def __init__(self, computations: Dict[str, Computation], entry: str):
        self.computations = computations
        self.entry = entry
        self._mults: Optional[Dict[str, Tuple[float, float]]] = None

    # -- call-graph multipliers ---------------------------------------------
    def multipliers(self) -> Dict[str, Tuple[float, float]]:
        """comp name -> (exec_mult, mem_mult).

        exec_mult: how many times the computation runs per step (for FLOPs /
        collectives).  mem_mult: same but zeroed inside fusion bodies and
        reducer appliers, whose memory traffic is accounted at the call site.
        """
        if self._mults is not None:
            return self._mults
        mults: Dict[str, Tuple[float, float]] = {c: (0.0, 0.0) for c in self.computations}
        mults[self.entry] = (1.0, 1.0)
        # propagate in reverse topological order: process callers before
        # callees; iterate to fixpoint (call graph is a DAG, small).
        for _ in range(len(self.computations) + 2):
            changed = False
            for cname, comp in self.computations.items():
                em, mm = mults[cname]
                if em == 0.0 and mm == 0.0:
                    continue
                for op in comp.ops.values():
                    for callee, kind, factor in _callees(op):
                        if callee not in mults:
                            continue
                        if kind == "fusion":
                            add = (em * factor, 0.0)
                        elif kind == "applier":
                            add = (0.0, 0.0)
                        else:  # control flow
                            add = (em * factor, mm * factor)
                        cur = mults[callee]
                        new = (max(cur[0], add[0]), max(cur[1], add[1]))
                        if new != cur:
                            mults[callee] = new
                            changed = True
            if not changed:
                break
        self._mults = mults
        return mults

    # -- aggregate statistics -------------------------------------------------
    def collectives(self) -> List[CollectiveStat]:
        out = []
        mults = self.multipliers()
        for cname, comp in self.computations.items():
            em, _ = mults[cname]
            if em == 0.0:
                continue
            for op in comp.ops.values():
                if not op.opcode.startswith(COLLECTIVE_OPCODES):
                    continue
                if op.opcode.endswith("-done"):
                    continue
                res_b = op.result_bytes
                # async start ops produce (operand, result) tuples: halve
                if op.opcode.endswith("-start"):
                    res_b /= 2.0
                payload = res_b
                opc = op.opcode.replace("-start", "")
                if opc.startswith("all-gather"):
                    # result is the gathered buffer; payload = one shard
                    groups = _parse_groups(op.attrs_str)
                    g = len(groups[0]) if groups else 1
                    payload = res_b / max(g, 1)
                out.append(CollectiveStat(
                    opcode=opc, name=op.name, computation=cname,
                    payload_bytes=payload, result_bytes=res_b,
                    groups=_parse_groups(op.attrs_str),
                    pairs=_parse_pairs(op.attrs_str),
                    multiplier=em))
        return out

    def dot_flops(self) -> float:
        """Loop-corrected matmul FLOPs per device."""
        total = 0.0
        mults = self.multipliers()
        for cname, comp in self.computations.items():
            em, _ = mults[cname]
            if em == 0.0:
                continue
            for op in comp.ops.values():
                if op.opcode == "dot":
                    total += em * _dot_flops(op, comp)
                elif op.opcode == "convolution":
                    total += em * _conv_flops(op, comp)
        return total

    def approx_bytes_accessed(self) -> float:
        """Loop-corrected per-device bytes estimate: sum over materializing
        ops of operand + result bytes (post-fusion HLO, so this approximates
        HBM traffic the way HloCostAnalysis does, but with trip counts).

        Slicing ops only touch the slice, not the buffer they slice from —
        without this, every scan-over-layers iteration would be charged the
        full stacked parameter array:
          * dynamic-slice / gather: 2x result (+indices);
          * dynamic-update-slice: 2x update slice (result aliases operand 0);
          * fusions: a fusion parameter consumed *only* by slicing ops inside
            the body is charged at the consumers' result sizes.
        """
        total = 0.0
        mults = self.multipliers()
        for cname, comp in self.computations.items():
            _, mm = mults[cname]
            if mm == 0.0:
                continue
            for op in comp.ops.values():
                if op.opcode in _NO_BYTES_OPS:
                    continue
                total += mm * self._op_bytes(op, comp)
        return total

    def _op_bytes(self, op: HloOp, comp: Computation) -> float:
        if op.opcode in ("dynamic-slice", "gather"):
            return 2.0 * op.result_bytes
        if op.opcode == "dynamic-update-slice":
            upd = (_shape_bytes(comp.shape_of.get(op.operands[1], ""))
                   if len(op.operands) > 1 else op.result_bytes)
            return 2.0 * upd
        if op.opcode == "scatter":
            upd = (_shape_bytes(comp.shape_of.get(op.operands[-1], ""))
                   if op.operands else op.result_bytes)
            return 2.0 * upd
        if op.opcode == "fusion":
            m = re.search(r"calls=%?([\w.\-]+)", op.attrs_str)
            callee = self.computations.get(m.group(1)) if m else None
            if callee is not None:
                return op.result_bytes + self._fusion_param_bytes(op, comp, callee)
        b = op.result_bytes
        for operand in op.operands:
            b += _shape_bytes(comp.shape_of.get(operand, ""))
        return b

    def _fusion_param_bytes(self, op: HloOp, comp: Computation,
                            callee: Computation) -> float:
        """Per-parameter contribution of a fusion's operands: parameters that
        are only sliced inside the body count at slice size."""
        # map parameter index -> param op name in callee
        param_names = {}
        for name, fop in callee.ops.items():
            if fop.opcode == "parameter":
                mi = re.match(r"^(\d+)", fop.args_str.strip())
                idx = int(mi.group(1)) if mi else len(param_names)
                param_names[name] = idx
        # consumers of each param
        sliced_bytes: Dict[str, float] = {}
        full: Dict[str, bool] = {n: False for n in param_names}
        for fop in callee.ops.values():
            for pos, operand in enumerate(fop.operands):
                if operand not in param_names:
                    continue
                if fop.opcode in ("dynamic-slice", "gather") and pos == 0:
                    sliced_bytes[operand] = sliced_bytes.get(operand, 0.0) + \
                        fop.result_bytes
                elif fop.opcode == "dynamic-update-slice" and pos == 0:
                    upd = (_shape_bytes(callee.shape_of.get(fop.operands[1], ""))
                           if len(fop.operands) > 1 else fop.result_bytes)
                    sliced_bytes[operand] = sliced_bytes.get(operand, 0.0) + upd
                else:
                    full[operand] = True
        total = 0.0
        for pname, idx in param_names.items():
            if idx < len(op.operands):
                pbytes = _shape_bytes(comp.shape_of.get(op.operands[idx], ""))
            else:
                pbytes = _shape_bytes(callee.shape_of.get(pname, ""))
            if full.get(pname, False) or pname not in sliced_bytes:
                total += pbytes
            else:
                total += min(pbytes, sliced_bytes[pname])
        return total

    def collective_payload_bytes(self) -> float:
        return sum(c.total_payload for c in self.collectives())

    def collective_wire_bytes(self) -> float:
        return sum(c.wire_bytes_per_device() for c in self.collectives())


def _callees(op: HloOp) -> List[Tuple[str, str, float]]:
    """(callee computation, kind, execution factor) triples for one op."""
    out = []
    attrs = op.attrs_str
    if op.opcode == "while":
        trip = 1.0
        m = _TRIP_RE.search(attrs)
        if m:
            trip = float(m.group(1))
        for key in ("body", "condition"):
            m2 = re.search(key + r"=%?([\w.\-]+)", attrs)
            if m2:
                out.append((m2.group(1), "control", trip if key == "body" else trip + 1))
    elif op.opcode == "fusion":
        m = re.search(r"calls=%?([\w.\-]+)", attrs)
        if m:
            out.append((m.group(1), "fusion", 1.0))
    elif op.opcode == "conditional":
        m = re.search(r"branch_computations=\{([^}]*)\}", attrs)
        if m:
            for name in re.findall(r"%?([\w.\-]+)", m.group(1)):
                out.append((name, "control", 1.0))
        for key in ("true_computation", "false_computation"):
            m2 = re.search(key + r"=%?([\w.\-]+)", attrs)
            if m2:
                out.append((m2.group(1), "control", 1.0))
    elif op.opcode == "call":
        m = re.search(r"to_apply=%?([\w.\-]+)", attrs)
        if m:
            out.append((m.group(1), "control", 1.0))
    else:
        m = re.search(r"to_apply=%?([\w.\-]+)", attrs)
        if m:
            out.append((m.group(1), "applier", 1.0))
        m = re.search(r"calls=%?([\w.\-]+)", attrs)
        if m:
            out.append((m.group(1), "fusion", 1.0))
    return out


def _contract_sizes(op: HloOp, comp: Computation, which: str, key: str) -> float:
    m = re.search(key + r"=\{([0-9,]*)\}", op.attrs_str)
    if not m or not op.operands:
        return 1.0
    dims_idx = [int(x) for x in m.group(1).split(",")] if m.group(1) else []
    operand = op.operands[0 if which == "lhs" else 1] if len(op.operands) > 1 else op.operands[0]
    shapes = _shape_dims(comp.shape_of.get(operand, ""))
    if not shapes:
        return 1.0
    dims = shapes[0][1]
    out = 1.0
    for i in dims_idx:
        if i < len(dims):
            out *= dims[i]
    return out


def _dot_flops(op: HloOp, comp: Computation) -> float:
    result_elems = 0.0
    for _, dims in _shape_dims(op.result_type):
        result_elems += float(np.prod(dims)) if dims else 1.0
    contract = _contract_sizes(op, comp, "lhs", "lhs_contracting_dims")
    return 2.0 * result_elems * contract


def _conv_flops(op: HloOp, comp: Computation) -> float:
    result_elems = 0.0
    for _, dims in _shape_dims(op.result_type):
        result_elems += float(np.prod(dims)) if dims else 1.0
    if len(op.operands) > 1:
        kshapes = _shape_dims(comp.shape_of.get(op.operands[1], ""))
        if kshapes:
            kelems = float(np.prod(kshapes[0][1])) if kshapes[0][1] else 1.0
            # 2 * out_elems * kernel_elems / out_features (rough)
            out_feat = kshapes[0][1][-1] if kshapes[0][1] else 1
            return 2.0 * result_elems * kelems / max(out_feat, 1)
    return 2.0 * result_elems


def parse_hlo(text: str) -> HloModule:
    computations: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if not stripped or stripped.startswith("//"):
            continue
        hdr = _COMP_HDR_RE.match(line)
        if hdr and stripped.endswith("{"):
            cur = Computation(name=hdr.group("name"))
            cur.is_entry = stripped.startswith("ENTRY")
            computations[cur.name] = cur
            if cur.is_entry:
                entry = cur.name
            # parameters: "p1: f32[2,3], p2: (f32[1], s32[])"
            params = hdr.group("params")
            for pm in re.finditer(r"([\w.\-]+)\s*:\s*([^,()]*(?:\([^)]*\))?[^,]*)", params):
                cur.shape_of[pm.group(1)] = pm.group(2)
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(stripped)
        if not m:
            continue
        name, rest = m.group("name"), m.group("rest")
        om = _OPCODE_RE.match(rest)
        if not om:
            continue
        opcode = om.group("opcode")
        type_str = om.group("type").strip()
        open_idx = om.end() - 1
        args, attrs = _split_paren_args(rest, open_idx)
        operands = re.findall(r"%([\w.\-]+)", args)
        if not operands:
            # newer syntax without % on operand refs: bare identifiers
            operands = [t.strip() for t in args.split(",")
                        if t.strip() and not _SHAPE_RE.search(t) and
                        re.match(r"^[\w.\-]+$", t.strip())]
        op = HloOp(name=name, opcode=opcode, result_type=type_str,
                   args_str=args, attrs_str=attrs, operands=operands)
        # parameter ops: record shape (type_str), opcode is 'parameter'
        cur.ops[name] = op
        cur.order.append(name)
        cur.shape_of[name] = type_str
    if entry is None:
        # fall back: last computation
        entry = list(computations)[-1]
    return HloModule(computations, entry)
