"""Roofline terms from a compiled dry-run artifact (assignment §Roofline).

All compiled-module quantities are per device (the SPMD per-partition
program); the roofline terms are therefore per-chip times directly:

  compute term    = flops_per_device / peak_FLOP/s
                 (== global_FLOPs / (chips * peak) for even sharding)
  memory term     = bytes_per_device / HBM_bw
  collective term = collective_bytes_per_device / link_bw

FLOPs/bytes use the loop-corrected HLO parser (`analysis.hlo`) because
XLA's HloCostAnalysis counts while bodies (lax.scan layers) only once; the
raw cost_analysis values are recorded alongside for transparency.
"""
from __future__ import annotations

import json
import math
from dataclasses import asdict, dataclass, field
from typing import Dict, Optional

from ..topology.machine import MachineSpec
from .hlo import HloModule

__all__ = ["RooflineReport", "roofline_from_module"]


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    # per-device quantities (loop-corrected)
    hlo_dot_flops: float
    hlo_bytes: float
    coll_payload_bytes: float
    coll_wire_bytes: float
    # raw XLA numbers (uncorrected, for transparency)
    xla_flops: float
    xla_bytes: float
    # memory proof
    arg_bytes_per_device: float
    temp_bytes_per_device: float
    output_bytes_per_device: float
    # analytic
    model_flops_global: float
    model_flops_full: float = 0.0   # 6ND + attention/SSM mixing term
    # machine (v5e defaults)
    peak_flops: float = 197e12
    hbm_bw: float = 819e9
    link_bw: float = 50e9
    hbm_bytes: float = 16 * 2**30

    @property
    def t_compute(self) -> float:
        return self.hlo_dot_flops / self.peak_flops

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / self.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.coll_wire_bytes / self.link_bw

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        """(MODEL_FLOPS + attention term) / (HLO flops over chips)."""
        total_hlo = self.hlo_dot_flops * self.chips
        num = self.model_flops_full or self.model_flops_global
        return num / total_hlo if total_hlo else float("nan")

    @property
    def useful_ratio_6nd(self) -> float:
        """Strict 6·N·D / HLO flops (the assignment's definition)."""
        total_hlo = self.hlo_dot_flops * self.chips
        return self.model_flops_global / total_hlo if total_hlo else float("nan")

    @property
    def step_time(self) -> float:
        """Roofline step-time lower bound: max of the three terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def mfu(self) -> float:
        """Model FLOPs utilization at the roofline bound."""
        denom = self.step_time * self.chips * self.peak_flops
        return self.model_flops_global / denom if denom else float("nan")

    @property
    def fits_hbm(self) -> bool:
        used = (self.arg_bytes_per_device + self.temp_bytes_per_device +
                self.output_bytes_per_device)
        return used <= self.hbm_bytes

    def row(self) -> Dict[str, object]:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective, "dominant": self.dominant,
            "model_flops": self.model_flops_global,
            "model_flops_full": self.model_flops_full,
            "useful_ratio_6nd": self.useful_ratio_6nd,
            "hlo_flops_per_dev": self.hlo_dot_flops,
            "useful_ratio": self.useful_ratio,
            "mfu_bound": self.mfu,
            "arg_gib_per_dev": self.arg_bytes_per_device / 2**30,
            "temp_gib_per_dev": self.temp_bytes_per_device / 2**30,
            "fits_hbm": self.fits_hbm,
        }

    def to_json(self) -> str:
        d = asdict(self)
        d.update({k: getattr(self, k) for k in
                  ("t_compute", "t_memory", "t_collective", "dominant",
                   "useful_ratio", "step_time", "mfu", "fits_hbm")})
        return json.dumps(d)


def roofline_from_module(module: HloModule, *, arch: str, shape: str,
                         mesh: str, chips: int, machine: MachineSpec,
                         model_flops_global: float,
                         model_flops_full: float = 0.0,
                         memory_stats=None, cost_analysis=None
                         ) -> RooflineReport:
    ma = memory_stats
    ca = cost_analysis or {}
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh, chips=chips,
        hlo_dot_flops=module.dot_flops(),
        hlo_bytes=module.approx_bytes_accessed(),
        coll_payload_bytes=module.collective_payload_bytes(),
        coll_wire_bytes=module.collective_wire_bytes(),
        xla_flops=float(ca.get("flops", float("nan"))),
        xla_bytes=float(ca.get("bytes accessed", float("nan"))),
        arg_bytes_per_device=float(getattr(ma, "argument_size_in_bytes", 0)),
        temp_bytes_per_device=float(getattr(ma, "temp_size_in_bytes", 0)),
        output_bytes_per_device=float(getattr(ma, "output_size_in_bytes", 0)),
        model_flops_global=model_flops_global,
        model_flops_full=model_flops_full or model_flops_global,
        peak_flops=machine.peak_flops_bf16,
        hbm_bw=machine.hbm_bw, link_bw=machine.ici_bw,
        hbm_bytes=machine.hbm_bytes)
