from .hlo import CollectiveStat, HloModule, parse_hlo
from .linksim import LinkReport, simulate
from .roofline import RooflineReport, roofline_from_module

__all__ = ["CollectiveStat", "HloModule", "parse_hlo", "LinkReport",
           "simulate", "RooflineReport", "roofline_from_module"]
