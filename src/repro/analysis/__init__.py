from .hlo import CollectiveStat, HloModule, parse_hlo
from .linksim import (LinkReport, graph_collectives, replay_assignment,
                      replay_graph, simulate)
from .roofline import RooflineReport, roofline_from_module

__all__ = ["CollectiveStat", "HloModule", "parse_hlo", "LinkReport",
           "simulate", "graph_collectives", "replay_assignment",
           "replay_graph", "RooflineReport", "roofline_from_module"]
