"""The resident plan server: mapping-as-a-service over the plan layer.

``cart_create`` is one call, but every call is a cold solver spin-up.
Production traffic is many concurrent ``cart_create``/re-mesh/repair
requests against a shared machine model — the regime where mapping cost
must be amortized against the application's communication volume.
:class:`PlanServer` is the serving loop for mappings, analogous to
``runtime/serve_loop.py``'s slot scheduler for training jobs:

* it **owns the shared** :class:`~repro.core.plan.PlanCache` (TTL +
  ``invalidate(problem_hash)`` + size-bounded disk spill — the PR-9 cache
  extensions) and warms it with a sweep over a registry of known
  topologies (:func:`register_topology` / :meth:`PlanServer.warm_up`);
* a **bounded admission queue** (``max_queue``) with per-request
  deadlines: a full queue rejects at submit time
  (:class:`AdmissionError`) instead of queueing unbounded latency;
* solver threads, each holding a persistent
  :class:`~repro.serving.workers.ShardWorkerPool` — ``sharded[...]``
  plans run on the resident engine
  (:class:`~repro.serving.workers.ResidentShardedRefiner`), whose results
  are bit-identical to the stateless engine and are therefore cached
  under the *same* plan key;
* an **anytime mode**: a request with ``deadline_ms`` returns the best
  valid plan found within its deadline (every portfolio temperature
  boundary is a valid cut point).  Deadline-*cut* results are
  timing-dependent and never enter the cache; an anytime run that
  completed uncut is deterministic (the anytime path never polishes) and
  is cached under ``<plan key>@anytime`` — never under the undeadlined
  key, which would poison warm full-quality serves.

:class:`~repro.serving.client.PlanClient` is the ergonomic front
(``submit`` / ``cart_create_async`` / ``stats``).
"""
from __future__ import annotations

import copy
import math
import queue
import threading
import time
from collections import OrderedDict, deque
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..core.plan import (MappingPlan, MappingProblem, MappingSolution,
                         PlanCache, blocked_node_sizes, parse_plan,
                         _jsonable_stats)
from ..core.refine.stage import RefineStage
from ..core.stencil import Stencil
from .workers import ResidentShardedRefiner, ShardWorkerPool

__all__ = ["PlanServer", "PlanTicket", "AdmissionError",
           "register_topology", "known_topologies", "DEFAULT_SERVE_PLAN"]

#: the server's default plan: resident-sharded refinement over the
#: hyperplane base (the spelling is the cache identity — the resident
#: engine serves it bit-identically to the stateless ``sharded:``).
DEFAULT_SERVE_PLAN = "sharded[shards=2,k=8,restarts=auto]:hyperplane"


class AdmissionError(RuntimeError):
    """Request rejected at submit time (queue full or server stopped)."""


# ---------------------------------------------------------------------------
# warm-up registry


_topology_registry: "OrderedDict[str, Callable[[], MappingProblem]]" = \
    OrderedDict()
_registry_lock = threading.Lock()


def register_topology(name: str,
                      factory: Callable[[], MappingProblem]) -> None:
    """Register a known topology for warm-up sweeps.  ``factory`` builds
    the :class:`MappingProblem` lazily (registration stays import-cheap);
    re-registering a name replaces it."""
    if not callable(factory):
        raise TypeError("factory must be a zero-arg MappingProblem factory")
    with _registry_lock:
        _topology_registry[str(name)] = factory


def known_topologies() -> Tuple[str, ...]:
    """Names registered for warm-up, in registration order."""
    with _registry_lock:
        return tuple(_topology_registry)


def _registry_get(names: Optional[Sequence[str]]) \
        -> List[Tuple[str, Callable[[], MappingProblem]]]:
    with _registry_lock:
        if names is None:
            return list(_topology_registry.items())
        return [(n, _topology_registry[n]) for n in names]


def _register_defaults() -> None:
    """Default registry: modest blocked v5e-style allocations (mesh shape,
    16-chip pods) — the shapes the quickstart and serve smoke warm."""
    register_topology(
        "v5e-4pod-8x8",
        lambda: MappingProblem((8, 8), Stencil.nearest_neighbor(2),
                               blocked_node_sizes(64, 16)))
    register_topology(
        "v5e-8pod-16x8",
        lambda: MappingProblem((16, 8), Stencil.nearest_neighbor(2),
                               blocked_node_sizes(128, 16)))


_register_defaults()


# ---------------------------------------------------------------------------
# tickets


class PlanTicket:
    """Future-shaped handle for one submitted request."""

    def __init__(self, deadline_s: Optional[float]):
        self.submitted_at = time.perf_counter()
        self.deadline_s = deadline_s
        self._event = threading.Event()
        self._solution: Optional[MappingSolution] = None
        self._error: Optional[BaseException] = None
        self.latency_s: Optional[float] = None
        self.deadline_missed = False
        self.anytime_cut = False

    @property
    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> MappingSolution:
        """Block until served; re-raises the solver's exception if the
        request failed."""
        if not self._event.wait(timeout):
            raise TimeoutError("plan request still in flight")
        if self._error is not None:
            raise self._error
        return self._solution

    # -- server side --------------------------------------------------------
    def _complete(self, solution: Optional[MappingSolution],
                  error: Optional[BaseException]) -> None:
        self.latency_s = time.perf_counter() - self.submitted_at
        if self.deadline_s is not None:
            self.deadline_missed = self.latency_s > self.deadline_s
        self._solution, self._error = solution, error
        self._event.set()


class _Request:
    __slots__ = ("kind", "args", "ticket")

    def __init__(self, kind: str, args: dict, ticket: PlanTicket):
        self.kind, self.args, self.ticket = kind, args, ticket


# ---------------------------------------------------------------------------
# the server


class PlanServer:
    """Long-lived mapping server: shared plan cache + bounded admission +
    persistent shard workers + deadlines/anytime.  See module docstring.

    Args:
      cache: the shared :class:`PlanCache` (default: a fresh one with
        ``maxsize=512``).  Hand one built with ``ttl_s`` /
        ``max_disk_bytes`` / ``disk_dir`` to get expiring, size-bounded
        spill behavior.
      threads: solver threads; each lazily creates one persistent
        :class:`ShardWorkerPool` of ``shard_workers`` processes.
      shard_workers: worker processes per solver thread's pool.
      max_queue: admission bound — submits beyond it raise
        :class:`AdmissionError` (and count as ``rejected``).
      default_plan: plan used when a request doesn't name one.
    """

    def __init__(self, cache: Optional[PlanCache] = None, threads: int = 2,
                 shard_workers: int = 2, max_queue: int = 64,
                 default_plan: Union[str, MappingPlan] = DEFAULT_SERVE_PLAN):
        if int(threads) < 1:
            raise ValueError("threads must be >= 1")
        if int(max_queue) < 1:
            raise ValueError("max_queue must be >= 1")
        self.cache = cache if cache is not None else PlanCache(maxsize=512)
        self.threads = int(threads)
        self.shard_workers = int(shard_workers)
        self.default_plan = default_plan
        self._queue: "queue.Queue[_Request]" = queue.Queue(
            maxsize=int(max_queue))
        self._stop = threading.Event()
        self._workers: List[threading.Thread] = []
        self._pools: List[ShardWorkerPool] = []
        self._pools_lock = threading.Lock()
        self._local = threading.local()
        self._stats_lock = threading.Lock()
        self._latencies: deque = deque(maxlen=2048)
        # single-flight: per-solution-key latch so concurrent cold misses
        # on one key run the solve once (followers wait, then hit cache)
        self._inflight_keys: Dict[str, threading.Event] = {}
        self._inflight_lock = threading.Lock()
        self.single_flight_waits = 0
        self.completed = 0
        self.errors = 0
        self.rejected = 0
        self.deadline_misses = 0
        self.anytime_cuts = 0
        self.inflight = 0
        self.warmed = 0
        self._started_at: Optional[float] = None

    # -- lifecycle ----------------------------------------------------------
    def start(self, warm: bool = False) -> "PlanServer":
        if self._workers:
            raise RuntimeError("server already started")
        self._stop.clear()
        self._started_at = time.perf_counter()
        for i in range(self.threads):
            t = threading.Thread(target=self._serve_loop,
                                 name=f"plan-server-{i}", daemon=True)
            t.start()
            self._workers.append(t)
        if warm:
            self.warm_up()
        return self

    def stop(self, timeout: float = 10.0) -> None:
        """Drain-free stop: running requests finish, queued requests are
        failed with :class:`AdmissionError`, worker pools close (every
        shard process joined)."""
        self._stop.set()
        for t in self._workers:
            t.join(timeout=timeout)
        self._workers = []
        while True:
            try:
                req = self._queue.get_nowait()
            except queue.Empty:
                break
            req.ticket._complete(None, AdmissionError("server stopped"))
        with self._pools_lock:
            pools, self._pools = self._pools, []
        for pool in pools:
            pool.close()

    def __enter__(self) -> "PlanServer":
        return self.start() if not self._workers else self

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- admission ----------------------------------------------------------
    def submit(self, problem: Optional[MappingProblem] = None, *,
               mesh_shape: Optional[Sequence[int]] = None,
               stencil: Optional[Stencil] = None,
               node_sizes: Optional[Sequence[int]] = None,
               chips_per_pod: Optional[int] = None,
               periodic: Optional[Sequence[bool]] = None,
               objective: str = "lex",
               plan: Union[None, str, MappingPlan] = None,
               deadline_ms: Optional[float] = None) -> PlanTicket:
        """Enqueue one mapping request; returns a :class:`PlanTicket`.

        Pass either a built :class:`MappingProblem` or the
        ``cart_create``-style fields (``mesh_shape`` + ``node_sizes`` /
        ``chips_per_pod`` + optional ``stencil``/``periodic``).
        ``deadline_ms`` makes the request anytime: the ticket resolves to
        the best valid plan found within the deadline."""
        if problem is None:
            if mesh_shape is None:
                raise ValueError("submit needs a problem or a mesh_shape")
            mesh_shape = tuple(int(d) for d in mesh_shape)
            p = math.prod(mesh_shape)
            if stencil is None:
                stencil = Stencil.nearest_neighbor(len(mesh_shape))
            if node_sizes is not None and chips_per_pod is not None:
                raise ValueError("pass node_sizes or chips_per_pod, "
                                 "not both")
            if node_sizes is not None:
                node_sizes = tuple(int(n) for n in node_sizes)
            elif chips_per_pod is not None:
                node_sizes = blocked_node_sizes(p, chips_per_pod)
            else:
                raise ValueError("submit needs node_sizes or chips_per_pod")
            problem = MappingProblem(mesh_shape, stencil, node_sizes,
                                     objective=objective,
                                     periodic=None if periodic is None
                                     else tuple(periodic))
        deadline_s = None if deadline_ms is None \
            else max(0.0, float(deadline_ms)) / 1e3
        ticket = PlanTicket(deadline_s)
        self._admit(_Request("solve", {"problem": problem, "plan": plan},
                             ticket))
        return ticket

    def submit_repair(self, previous, node_sizes: Sequence[int], *,
                      deadline_ms: Optional[float] = None,
                      **repair_options) -> PlanTicket:
        """Enqueue a warm-start repair (the runtime/remap churn path):
        equivalent to :func:`repro.core.remap.repair_layout` against the
        server's shared cache, but admission-controlled and counted like
        any other request."""
        deadline_s = None if deadline_ms is None \
            else max(0.0, float(deadline_ms)) / 1e3
        ticket = PlanTicket(deadline_s)
        self._admit(_Request("repair",
                             {"previous": previous,
                              "node_sizes": tuple(int(s)
                                                  for s in node_sizes),
                              "options": dict(repair_options)},
                             ticket))
        return ticket

    def _admit(self, req: _Request) -> None:
        if self._stop.is_set() or not self._workers:
            raise AdmissionError("server is not running")
        try:
            self._queue.put_nowait(req)
        except queue.Full:
            with self._stats_lock:
                self.rejected += 1
            raise AdmissionError(
                f"admission queue full ({self._queue.maxsize} pending)")

    # -- cache control -------------------------------------------------------
    def invalidate(self, problem: Union[str, MappingProblem]) -> int:
        """Drop every cached entry for one problem (accepts the problem or
        its ``content_hash()``)."""
        h = problem.content_hash() if isinstance(problem, MappingProblem) \
            else str(problem)
        return self.cache.invalidate(h)

    def warm_up(self, names: Optional[Sequence[str]] = None,
                plan: Union[None, str, MappingPlan] = None) -> Dict[str, int]:
        """Sweep the topology registry (or ``names``) through the solve
        path so production requests hit a warm cache.  Runs in the calling
        thread — a server can warm before opening admission."""
        solved = hits = 0
        for _name, factory in _registry_get(names):
            problem = factory()
            sol = self._solve(problem, self._resolve_plan(plan), None, None)
            hits += int(sol.from_cache)
            solved += 1
        with self._stats_lock:
            self.warmed += solved
        return {"swept": solved, "already_cached": hits}

    # -- solve path ----------------------------------------------------------
    def _resolve_plan(self, plan: Union[None, str, MappingPlan]) \
            -> MappingPlan:
        if plan is None:
            plan = self.default_plan
        # parse fresh (never share stage objects across threads): the
        # resident swap mutates the final stage's refiner
        return parse_plan(plan) if isinstance(plan, str) else plan

    def _thread_pool(self) -> ShardWorkerPool:
        pool = getattr(self._local, "pool", None)
        if pool is None or not pool.alive:
            pool = ShardWorkerPool(workers=self.shard_workers)
            self._local.pool = pool
            with self._pools_lock:
                self._pools.append(pool)
        return pool

    @staticmethod
    def _resident_stage(plan: MappingPlan) -> Optional[RefineStage]:
        """The final stage when this plan is resident-eligible: a
        ``sharded`` refine stage with no stage budget (a budget threads
        ``max_swaps``, which the sharded engine delegates to the
        single-process portfolio anyway)."""
        if not plan.stages:
            return None
        stage = plan.stages[-1]
        if (isinstance(stage, RefineStage) and stage.prefix == "sharded"
                and stage.budget is None
                and getattr(stage.refiner, "max_swaps", None) is None):
            return stage
        return None

    def _make_resident(self, stage: RefineStage) -> ResidentShardedRefiner:
        cfg = dict(stage.refiner.config())
        cfg["backend"] = "serial"          # fallback path stays inline
        return ResidentShardedRefiner(pool=self._thread_pool(), **cfg)

    def _solve(self, problem: MappingProblem,
               plan: MappingPlan, deadline_s: Optional[float],
               ticket: Optional[PlanTicket]) -> MappingSolution:
        stage = self._resident_stage(plan)
        if stage is not None:
            # never mutate the caller's plan: shallow-copy the final stage
            # before swapping its refiner (spec()/key are unchanged)
            stage = copy.copy(stage)
            plan = MappingPlan(tuple(plan.stages[:-1]) + (stage,),
                               name=plan.name, graph=plan.graph_flavor)
        if deadline_s is not None and stage is not None:
            return self._solve_anytime(problem, plan, stage,
                                       deadline_s, ticket)
        if stage is not None:
            # resident persistent-worker engine, bit-identical to the
            # stateless sharded engine -> same result, same cache key
            stage.refiner = self._make_resident(stage)
        if not plan.cacheable:
            return self.cache.solve(problem, plan)
        # single-flight: concurrent cold misses on one key would each run
        # the full solve (up to `threads` redundant anneals).  The first
        # arrival becomes the leader and solves; followers park on the
        # key's latch and re-enter when it publishes — their solve is then
        # a cache hit.  A follower that re-enters after a leader *failure*
        # simply becomes the next leader (retry, not deadlock).
        key = f"sol:{problem.content_hash()}:{plan.key}"
        while True:
            with self._inflight_lock:
                ev = self._inflight_keys.get(key)
                if ev is None:
                    ev = threading.Event()
                    self._inflight_keys[key] = ev
                    leader = True
                else:
                    leader = False
            if leader:
                try:
                    return self.cache.solve(problem, plan)
                finally:
                    with self._inflight_lock:
                        self._inflight_keys.pop(key, None)
                    ev.set()
            with self._stats_lock:
                self.single_flight_waits += 1
            ev.wait()

    def _solve_anytime(self, problem: MappingProblem, plan: MappingPlan,
                       stage: RefineStage, deadline_s: float,
                       ticket: Optional[PlanTicket]) -> MappingSolution:
        """Deadline-bounded solve.  The undeadlined cache entry serves
        instantly when present (strictly better than any cut); otherwise
        the uncut-anytime entry (``@anytime``) does.  A fresh run cuts at
        the first boundary past the deadline; only *uncut* runs — which
        are deterministic, the anytime path never polishes — are cached,
        under the ``@anytime`` key."""
        t0 = time.perf_counter()
        anytime_key = None
        if plan.cacheable:
            full = self.cache.get(f"sol:{problem.content_hash()}:{plan.key}")
            if full is None:
                anytime_key = (f"sol:{problem.content_hash()}:"
                               f"{plan.key}@anytime")
                full = self.cache.get(anytime_key)
            if full is not None:
                return MappingSolution(
                    assignment=np.array(full["assignment"], dtype=np.int64),
                    j_sum=float(full["j_sum"]), j_max=float(full["j_max"]),
                    problem=problem, plan_key=plan.key,
                    stage_stats=_jsonable_stats(full["stage_stats"]),
                    wall_time_s=float(full["wall_time_s"]), from_cache=True)

        grid, stencil = problem.grid(), problem.stencil
        sizes = problem.node_sizes
        assignment = None
        stage_stats: List[dict] = []
        for st in plan.stages[:-1]:
            r = st.run(grid, stencil, sizes, assignment)
            assignment = r.assignment
            stage_stats.append(r.stats)
        refiner = self._make_resident(stage)
        remaining = max(0.0, deadline_s - (time.perf_counter() - t0))
        res = refiner.refine_anytime(grid, stencil, assignment,
                                     num_nodes=len(sizes),
                                     deadline_s=remaining)
        cut = bool(res.stats.get("cut", False))
        if ticket is not None:
            ticket.anytime_cut = cut
        if cut:
            with self._stats_lock:
                self.anytime_cuts += 1
        stage_stats.append({"stage": stage.spec() + "@anytime",
                            "kind": "refine", **res.stats,
                            "initial": (res.initial.j_max,
                                        res.initial.j_sum),
                            "final": (res.final.j_max, res.final.j_sum)})
        wall = time.perf_counter() - t0
        sol = MappingSolution(
            assignment=res.assignment, j_sum=res.final.j_sum,
            j_max=res.final.j_max, problem=problem, plan_key=plan.key,
            stage_stats=_jsonable_stats(stage_stats), wall_time_s=wall,
            from_cache=False)
        if not cut and anytime_key is not None:
            # deterministic (uncut, unpolished) -> cacheable under the
            # @anytime key; cut results are timing-dependent: never cached
            self.cache.put(anytime_key, {
                "assignment": np.array(sol.assignment, dtype=np.int64),
                "j_sum": sol.j_sum, "j_max": sol.j_max,
                "stage_stats": sol.stage_stats,
                "wall_time_s": sol.wall_time_s,
            })
        return sol

    # -- the serve loop ------------------------------------------------------
    def _serve_loop(self) -> None:
        while not self._stop.is_set():
            try:
                req = self._queue.get(timeout=0.05)
            except queue.Empty:
                continue
            with self._stats_lock:
                self.inflight += 1
            ticket = req.ticket
            try:
                if req.kind == "repair":
                    from ..core.remap import repair_layout
                    sol = repair_layout(req.args["previous"],
                                        req.args["node_sizes"],
                                        cache=self.cache,
                                        **req.args["options"])
                else:
                    plan = self._resolve_plan(req.args["plan"])
                    deadline_s = ticket.deadline_s
                    if deadline_s is not None:
                        # deadline is end-to-end: queue wait eats budget
                        deadline_s = max(
                            0.0, deadline_s - (time.perf_counter()
                                               - ticket.submitted_at))
                    sol = self._solve(req.args["problem"], plan,
                                      deadline_s, ticket)
                ticket._complete(sol, None)
                with self._stats_lock:
                    self.completed += 1
                    self._latencies.append(ticket.latency_s)
                    if ticket.deadline_missed:
                        self.deadline_misses += 1
            except BaseException as e:          # noqa: BLE001 - report all
                ticket._complete(None, e)
                with self._stats_lock:
                    self.errors += 1
            finally:
                with self._stats_lock:
                    self.inflight -= 1

    # -- observability -------------------------------------------------------
    def stats(self) -> dict:
        """Queue depth, throughput/latency, deadline and cache health —
        the numbers the serving dashboard would scrape."""
        with self._stats_lock:
            lats = sorted(self._latencies)
            out = {
                "queue_depth": self._queue.qsize(),
                "inflight": self.inflight,
                "completed": self.completed,
                "errors": self.errors,
                "rejected": self.rejected,
                "deadline_misses": self.deadline_misses,
                "anytime_cuts": self.anytime_cuts,
                "single_flight_waits": self.single_flight_waits,
                "warmed": self.warmed,
                "threads": self.threads,
                "uptime_s": (0.0 if self._started_at is None
                             else time.perf_counter() - self._started_at),
            }
        if lats:
            out["latency_p50_ms"] = 1e3 * lats[len(lats) // 2]
            out["latency_p95_ms"] = 1e3 * lats[min(len(lats) - 1,
                                                   int(0.95 * len(lats)))]
        cs = self.cache.stats()
        looks = cs["hits"] + cs["misses"]
        out["cache"] = cs
        out["cache_hit_rate"] = (cs["hits"] / looks) if looks else 0.0
        with self._pools_lock:
            out["shard_workers"] = sum(p.workers for p in self._pools)
            out["ipc"] = {
                "bytes_out": sum(p.bytes_out for p in self._pools),
                "bytes_in": sum(p.bytes_in for p in self._pools),
                "messages": sum(p.messages for p in self._pools),
            }
        return out
