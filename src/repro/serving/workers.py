"""Persistent sharded-portfolio workers (the plan server's solve engine).

The stateless sharded engine (:mod:`repro.core.refine.sharded`) re-ships
every block's full state — the (b, p) assignment rows plus rng generators
— to a worker process *per block per temperature*, and ships the same
back: k x p x 8 bytes each way per boundary.  That is the right trade for
a one-shot refine (workers are stateless, any pool shape works), but a
resident server solving a stream of requests can do much better: keep the
block state *in* the worker across temperatures.

:class:`ShardWorkerPool` holds long-lived worker processes speaking a tiny
framed-pickle protocol over pipes; each worker keeps its blocks'
:class:`~repro.core.cost_delta.PortfolioCost` (assignment rows + integer
crossing counts) and rng generators resident between messages.  Per
temperature boundary only the small control plane crosses the wire:

* coordinator -> worker: the global alive mask slice, this temperature's
  scalar ``T`` and acceptance ``eps`` — O(b) bytes;
* worker -> coordinator: per-ladder leader keys ``(j_max, j_sum)``,
  accepted counts and done flags — O(b) bytes.

Everything trajectory-sized (assignments, rng state, crossing counts)
crosses exactly twice per request: once at ``init``, once at ``collect``.
All transport goes through ``send_bytes``/``recv_bytes`` of explicit
pickles, so the pool's byte counters are *measured* IPC, byte-exact — the
numbers ``benchmarks/serve_suite.py`` pins against the stateless
baseline's :func:`~repro.core.refine.sharded.measure_ipc`.

:class:`ResidentShardedRefiner` drives the pool.  It subclasses
:class:`~repro.core.refine.sharded.ShardedPortfolioRefiner` and overrides
*only* the ladder dispatch (``_sharded_ladders``): the shared prefix,
:class:`~repro.core.refine.engine.BoundaryController` kill/restart/retune
semantics, survivor selection and polish all run the inherited code, and
the workers advance ladders with the same
:func:`~repro.core.refine.portfolio.run_temperature` kernel on the same
resident integer count state — so results are **bit-identical** to
``sharded[...]`` at equal configuration (pinned by
``tests/test_serving.py`` and ``results/BENCH_9.json``).

Anytime mode: every temperature boundary is a valid cut point (ladder
rows always realize the scheduler cardinalities), so a deadline-bounded
refine stops at the first boundary past its deadline and selects from the
rounds output, the current rows, each row's *best-seen* boundary snapshot
(tracked worker-side, returned at collect) and any finished restarts —
always a valid plan, never a partial one.  Deadline-cut results are
timing-dependent and are therefore never cached under the deterministic
plan key (the server enforces this).
"""
from __future__ import annotations

import math
import multiprocessing
import os
import pickle
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.cost_delta import IncrementalCost, PortfolioCost
from ..core.grid import CartGrid
from ..core.refine.engine import BoundaryController, RestartSeeder
from ..core.refine.sharded import (ShardedPortfolioRefiner, _block_step,
                                   _memo_table)
from ..core.refine.portfolio import run_temperature
from ..core.refine.swap import RefineResult
from ..core.stencil import Stencil, resolve_weighted

__all__ = ["ShardWorkerPool", "ResidentShardedRefiner", "WorkerPoolError"]

_PROTO = pickle.HIGHEST_PROTOCOL

#: a worker that hasn't answered in this long is wedged, not slow — treat
#: the pool as broken rather than blocking a server thread forever.
_RECV_TIMEOUT_S = 600.0


class WorkerPoolError(RuntimeError):
    """A persistent worker died or stopped answering; the pool must be
    torn down (the refiner falls back to the inline engine)."""


# ---------------------------------------------------------------------------
# worker-process side


class _WorkerBlock:
    """One resident seed block: assignment rows + integer crossing counts
    (:class:`PortfolioCost`) + rng generators, persistent across
    temperatures.  Counts are integers, so the resident state is bit-equal
    to the state the stateless engine rebuilds from rows each temperature
    — residency changes bytes shipped, never trajectories."""

    def __init__(self, payload: dict):
        grid = CartGrid(tuple(payload["dims"]),
                        periodic=payload["periodic"])
        stencil = Stencil(payload["offsets"], payload["weights"])
        self.pc = PortfolioCost(grid, stencil,
                                np.asarray(payload["node"], dtype=np.int64),
                                num_nodes=payload["num_nodes"],
                                weighted=payload["weighted"],
                                table=_memo_table(grid, stencil))
        self.rngs = [np.random.default_rng(s) for s in payload["seeds"]]
        self.done = np.zeros(len(self.rngs), dtype=bool)
        self.sa_moves = int(payload["sa_moves"])
        # best-seen boundary snapshot per row (anytime-cut candidates);
        # seeded from the start state, so it is always finite and valid
        self.best_keys = np.stack([self.pc.j_max(), self.pc.j_sum()], axis=1)
        self.best_node = self.pc.node.copy()

    def step(self, alive: np.ndarray, temp: float, eps: float) -> dict:
        b = len(self.rngs)
        accepted = run_temperature(self.pc, self.rngs,
                                   np.asarray(alive, dtype=bool), self.done,
                                   np.full(b, float(temp)), self.sa_moves,
                                   np.full(b, float(eps)))
        j_max, j_sum = self.pc.j_max(), self.pc.j_sum()
        better = ((j_max < self.best_keys[:, 0]) |
                  ((j_max == self.best_keys[:, 0]) &
                   (j_sum < self.best_keys[:, 1])))
        if better.any():
            self.best_keys[better] = np.stack([j_max[better],
                                               j_sum[better]], axis=1)
            self.best_node[better] = self.pc.node[better]
        return {"j_max": j_max, "j_sum": j_sum,
                "accepted": np.asarray(accepted), "done": self.done.copy()}

    def fetch(self, row: int) -> np.ndarray:
        return self.pc.node[int(row)].copy()

    def collect(self) -> dict:
        return {"node": self.pc.node.copy(),
                "best_node": self.best_node.copy(),
                "best_keys": self.best_keys.copy()}


def _worker_main(conn) -> None:
    """Persistent worker loop: framed-pickle request/response over one
    pipe.  Module-level so it survives the spawn start method."""
    blocks: Dict[int, _WorkerBlock] = {}
    while True:
        try:
            msg = pickle.loads(conn.recv_bytes())
        except (EOFError, OSError):       # coordinator went away
            return
        try:
            kind = msg[0]
            if kind == "shutdown":
                conn.send_bytes(pickle.dumps(("bye",), _PROTO))
                return
            if kind == "ping":
                out = ("pong", os.getpid())
            elif kind == "reset":
                blocks.clear()
                out = ("ok",)
            elif kind == "init":
                blocks[int(msg[1])] = _WorkerBlock(msg[2])
                out = ("ok",)
            elif kind == "step":
                out = ("ok", blocks[int(msg[1])].step(**msg[2]))
            elif kind == "fetch":
                out = ("ok", blocks[int(msg[1])].fetch(msg[2]))
            elif kind == "collect":
                out = ("ok", blocks[int(msg[1])].collect())
            elif kind == "crash":         # test hook: die mid-protocol
                os._exit(17)
            else:
                out = ("error", f"unknown message kind {kind!r}")
        except Exception as e:            # never wedge the loop: report
            out = ("error", f"{type(e).__name__}: {e}")
        try:
            conn.send_bytes(pickle.dumps(out, _PROTO))
        except (BrokenPipeError, OSError):
            return


# ---------------------------------------------------------------------------
# coordinator side


class ShardWorkerPool:
    """Long-lived worker processes with per-worker pipes and measured byte
    accounting (``bytes_out`` / ``bytes_in`` count the exact framed pickle
    payloads).  Workers are daemonic (a dying server never strands them)
    and numpy-only (fork-safe; jax is never touched in children).
    """

    def __init__(self, workers: int = 2, start_method: Optional[str] = None):
        if int(workers) < 1:
            raise ValueError("workers must be >= 1")
        if start_method is None:
            methods = multiprocessing.get_all_start_methods()
            start_method = "fork" if "fork" in methods else "spawn"
        ctx = multiprocessing.get_context(start_method)
        self._procs = []
        self._conns = []
        for _ in range(int(workers)):
            parent, child = ctx.Pipe()
            proc = ctx.Process(target=_worker_main, args=(child,),
                               daemon=True)
            proc.start()
            child.close()
            self._procs.append(proc)
            self._conns.append(parent)
        self.bytes_out = 0
        self.bytes_in = 0
        self.messages = 0
        self._closed = False

    @property
    def workers(self) -> int:
        return len(self._procs)

    @property
    def alive(self) -> bool:
        return (not self._closed and
                all(p.is_alive() for p in self._procs))

    def _send(self, w: int, msg) -> None:
        data = pickle.dumps(msg, _PROTO)
        try:
            self._conns[w].send_bytes(data)
        except (BrokenPipeError, OSError) as e:
            raise WorkerPoolError(f"worker {w} unreachable: {e}") from e
        self.bytes_out += len(data)
        self.messages += 1

    def _recv(self, w: int):
        try:
            if not self._conns[w].poll(_RECV_TIMEOUT_S):
                raise WorkerPoolError(f"worker {w} timed out")
            data = self._conns[w].recv_bytes()
        except (EOFError, OSError) as e:
            raise WorkerPoolError(f"worker {w} died: {e}") from e
        self.bytes_in += len(data)
        out = pickle.loads(data)
        if out[0] == "error":
            raise WorkerPoolError(f"worker {w}: {out[1]}")
        return out[1] if len(out) > 1 else None

    def request(self, w: int, msg):
        """One synchronous round-trip to worker ``w``."""
        self._send(w, msg)
        return self._recv(w)

    def request_many(self, msgs: Sequence[Tuple[int, object]]) -> list:
        """Pipelined fan-out: send every message, then collect replies in
        send order (a worker answers its own messages in order, so
        multiple blocks on one worker serialize correctly)."""
        for w, msg in msgs:
            self._send(w, msg)
        return [self._recv(w) for w, _ in msgs]

    def broadcast(self, msg) -> list:
        return self.request_many([(w, msg) for w in range(self.workers)])

    def ipc_stats(self) -> Dict[str, int]:
        return {"bytes_out": self.bytes_out, "bytes_in": self.bytes_in,
                "bytes_total": self.bytes_out + self.bytes_in,
                "messages": self.messages, "workers": self.workers}

    def close(self, timeout: float = 5.0) -> None:
        """Graceful shutdown: ask, join, then terminate stragglers.  Every
        worker process is joined — the pool never orphans children."""
        if self._closed:
            return
        self._closed = True
        for w, conn in enumerate(self._conns):
            try:
                conn.send_bytes(pickle.dumps(("shutdown",), _PROTO))
            except (BrokenPipeError, OSError):
                pass
        for proc in self._procs:
            proc.join(timeout=timeout)
            if proc.is_alive():
                proc.terminate()
                proc.join(timeout=timeout)
        for conn in self._conns:
            try:
                conn.close()
            except OSError:
                pass

    def __enter__(self) -> "ShardWorkerPool":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):  # pragma: no cover - gc safety net
        try:
            self.close(timeout=0.5)
        except Exception:
            pass


class ResidentShardedRefiner(ShardedPortfolioRefiner):
    """Sharded portfolio refiner whose ladder state lives in a persistent
    :class:`ShardWorkerPool` instead of being re-shipped per temperature.

    Every inherited phase — the deterministic rounds prefix, boundary
    control (kill/restart/retune via the shared
    :class:`BoundaryController`), survivor selection, polish — runs the
    superclass code unchanged; only the per-temperature block dispatch is
    replaced.  Restart ladders run inline on the coordinator through the
    same :func:`_block_step` task the stateless engine uses (ladder
    trajectories are batch-composition invariant), so an undeadlined
    refine is bit-identical to ``sharded[...]`` at equal configuration.

    ``pool=None`` lazily creates (and owns) a pool sized
    ``min(shards, cpu)``; pass a shared pool to amortize worker startup
    across requests (the server does).  If a worker dies mid-refine the
    undeadlined path falls back to the inline serial engine (still
    bit-identical — correctness never depends on the pool), and the
    deadline path degrades to the best candidate seen so far.

    :meth:`refine_anytime` adds the deadline mode; see the module
    docstring for the cut invariants.
    """

    def __init__(self, pool: Optional[ShardWorkerPool] = None, **kwargs):
        kwargs.setdefault("backend", "serial")   # fallback path stays inline
        super().__init__(**kwargs)
        self._pool = pool
        self._owns_pool = False
        self._deadline_at: Optional[float] = None
        self._last_ipc: Optional[dict] = None

    def refine(self, grid: CartGrid, stencil: Stencil,
               node_of_pos: np.ndarray,
               num_nodes: Optional[int] = None) -> RefineResult:
        self._last_ipc = None
        res = super().refine(grid, stencil, node_of_pos, num_nodes)
        if self._last_ipc is not None:
            res.stats["ipc"] = self._last_ipc
        return res

    # -- pool plumbing -------------------------------------------------------
    def _ensure_pool(self) -> ShardWorkerPool:
        if self._pool is None or not self._pool.alive:
            if self._pool is not None and self._owns_pool:
                self._pool.close()
            self._pool = ShardWorkerPool(
                workers=min(max(1, self.shards), os.cpu_count() or 1))
            self._owns_pool = True
        return self._pool

    def close(self) -> None:
        if self._pool is not None and self._owns_pool:
            self._pool.close()
        self._pool = None

    def __enter__(self) -> "ResidentShardedRefiner":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- the resident ladder dispatch ---------------------------------------
    def _sharded_ladders(self, grid: CartGrid, stencil: Stencil,
                         start: np.ndarray,
                         num_nodes: Optional[int]) -> dict:
        try:
            return self._resident_ladders(grid, stencil, start, num_nodes,
                                          self._deadline_at)
        except WorkerPoolError:
            if self._deadline_at is not None:
                raise    # anytime caller degrades to its best-so-far
            # undeadlined: correctness must never depend on the pool — the
            # inline serial engine produces bit-identical ladders
            if self._pool is not None and self._owns_pool:
                self._pool.close()
                self._pool = None
            lad = super()._sharded_ladders(grid, stencil, start, num_nodes)
            lad["backend"] = "resident-fallback"
            lad.setdefault("cut_at", len(self.schedule.temperatures))
            return lad

    def _resident_ladders(self, grid: CartGrid, stencil: Stencil,
                          start: np.ndarray, num_nodes: Optional[int],
                          deadline_at: Optional[float]) -> dict:
        sched, port = self.schedule, self.portfolio
        K = self.k
        S = min(self.shards, K)
        pool = self._ensure_pool()
        W = pool.workers
        n_nodes = int(num_nodes) if num_nodes is not None \
            else int(start.max() + 1)
        weighted = resolve_weighted(sched.weighted, stencil)
        weights = stencil.weight_array() if weighted \
            else np.ones(stencil.k)
        t_scale = float(np.mean(weights))

        start_ic = IncrementalCost(grid, stencil, start, num_nodes=n_nodes,
                                   weighted=weighted)
        j_sum0, j_max0 = start_ic.j_sum, start_ic.j_max
        eps0 = float(1.0 / (1.0 + np.abs(j_sum0)))
        n_temps = len(sched.temperatures)
        ctrl = BoundaryController(
            k=K, kill_factor=port.kill_factor,
            start_keys=np.asarray([j_max0, j_sum0]),
            restarts=self.restarts, retune=self.retune,
            accept_band=self.accept_band, retune_bounds=self.retune_bounds,
            sa_moves=sched.sa_moves, n_temps=n_temps,
            seeder=RestartSeeder(self.seeds, start=self._restart_seed_base))
        alive = ctrl.alive
        cur_keys = np.broadcast_to(
            np.asarray([j_max0, j_sum0]), (K, 2)).copy()

        idx_blocks = [b for b in np.array_split(np.arange(K), S) if b.size]
        block_worker = [bi % W for bi in range(len(idx_blocks))]
        done_blocks = [np.zeros(b.size, dtype=bool) for b in idx_blocks]
        base_payload = {
            "dims": tuple(grid.dims), "periodic": tuple(grid.periodic),
            "offsets": stencil.offsets, "weights": stencil.weights,
            "weighted": weighted, "num_nodes": n_nodes,
            "sa_moves": sched.sa_moves,
        }
        restarts: List[dict] = []
        accepted = 0
        bytes0 = pool.bytes_out + pool.bytes_in

        # one-time state up: broadcast start rows + seeds per block
        pool.broadcast(("reset",))
        pool.request_many([
            (block_worker[bi],
             ("init", bi, {**base_payload,
                           "node": np.broadcast_to(
                               start, (b.size, grid.size)).copy(),
                           "seeds": [int(self.seeds[i]) for i in b]}))
            for bi, b in enumerate(idx_blocks)])
        init_bytes = pool.bytes_out + pool.bytes_in - bytes0

        def leader_state() -> Tuple[np.ndarray, float]:
            """Identical ranking to the stateless coordinator: alive
            originals then restarts on current lexicographic key, lowest
            index wins ties; an original leader's row is fetched from its
            worker (one p-row, only on restart spawn)."""
            cand = [((cur_keys[i, 0], cur_keys[i, 1], 0, i), None)
                    for i in range(K) if alive[i]]
            cand += [((r["j_max"], r["j_sum"], 1, j), r)
                     for j, r in enumerate(restarts)]
            key, r = min(cand, key=lambda c: c[0])
            if r is not None:
                return r["node"], r["j_sum"]
            i = key[3]
            for bi, b in enumerate(idx_blocks):
                pos = np.nonzero(b == i)[0]
                if pos.size:
                    row = pool.request(block_worker[bi],
                                       ("fetch", bi, int(pos[0])))
                    return np.asarray(row, dtype=np.int64), \
                        float(cur_keys[i, 1])
            raise AssertionError("leader not found")  # pragma: no cover

        boundary_s: List[float] = []
        cut_at = n_temps
        step_bytes0 = pool.bytes_out + pool.bytes_in
        for ti, T0 in enumerate(sched.temperatures):
            if deadline_at is not None:
                # predictive cut: don't start a boundary the last one's
                # duration says won't finish in time (the first boundary
                # has no estimate and may overshoot by its own length)
                est = boundary_s[-1] if boundary_s else 0.0
                if time.perf_counter() + est >= deadline_at:
                    cut_at = ti
                    break
            tb0 = time.perf_counter()
            T = max(T0 * t_scale, 1e-12)
            msgs, specs = [], []
            for bi, b in enumerate(idx_blocks):
                if not (alive[b] & ~done_blocks[bi]).any():
                    continue    # same skip rule as the stateless engine:
                    # cur_keys[b] stays frozen, no dispatch
                msgs.append((block_worker[bi],
                             ("step", bi, {"alive": alive[b],
                                           "temp": T, "eps": eps0})))
                specs.append(("orig", bi, b))
            # restart ladders advance inline through the *stateless* task
            # (their state is coordinator-resident already; trajectories
            # are batch-composition invariant, so one stacked batch is
            # bit-identical to the stateless engine's chunking)
            active = [r for r in restarts if not r["done"]]
            payloads = []
            if active:
                payloads.append({
                    **base_payload,
                    "node": np.stack([r["node"] for r in active]),
                    "rngs": [r["rng"] for r in active],
                    "alive": np.ones(len(active), dtype=bool),
                    "done": np.array([r["done"] for r in active]),
                    "temps": np.array(
                        [max(T0 * t_scale * r["t_mult"], 1e-12)
                         for r in active]),
                    "eps": np.array([r["eps"] for r in active]),
                })
            results = pool.request_many(msgs)
            for (kind, bi, b), res in zip(specs, results):
                accepted += int(res["accepted"].sum())
                done_blocks[bi] = res["done"]
                cur_keys[b] = np.stack([res["j_max"], res["j_sum"]], axis=1)
            for payload in payloads:
                res = _block_step(payload)
                accepted += int(res["accepted"].sum())
                for li, r in enumerate(active):
                    r.update(node=res["node"][li], rng=res["rngs"][li],
                             done=bool(res["done"][li]),
                             j_max=float(res["j_max"][li]),
                             j_sum=float(res["j_sum"][li]),
                             accepted_last=int(res["accepted"][li]))
            # temperature boundary: shared protocol over global keys
            ctrl.update_best(cur_keys)
            newly_killed = ctrl.kill()

            def spawn(seed: int) -> bool:
                node, lead_j_sum = leader_state()
                restarts.append({
                    "node": node.copy(),
                    "rng": np.random.default_rng(seed),
                    "seed": seed,
                    "done": False,
                    "eps": float(1.0 / (1.0 + abs(lead_j_sum))),
                    "t_mult": 1.0,
                    "j_max": math.inf, "j_sum": math.inf,
                    "accepted_last": 0,
                })
                return True

            ctrl.adapt(ti, newly_killed, restarts, spawn)
            boundary_s.append(time.perf_counter() - tb0)
        step_bytes = pool.bytes_out + pool.bytes_in - step_bytes0

        # one-time state down: final rows + per-row best-seen snapshots
        coll0 = pool.bytes_out + pool.bytes_in
        nodes = np.empty((K, grid.size), dtype=np.int64)
        best_nodes = np.empty((K, grid.size), dtype=np.int64)
        best_keys = np.empty((K, 2), dtype=np.float64)
        colls = pool.request_many([(block_worker[bi], ("collect", bi))
                                   for bi in range(len(idx_blocks))])
        for b, coll in zip(idx_blocks, colls):
            nodes[b] = coll["node"]
            best_nodes[b] = coll["best_node"]
            best_keys[b] = coll["best_keys"]
        collect_bytes = pool.bytes_out + pool.bytes_in - coll0

        if cut_at >= n_temps:
            # completed run: every restart ran >= 1 temperature (spawns
            # are gated on remaining budget), so its key is finite
            assert all(math.isfinite(r["j_max"]) for r in restarts)
        else:
            # a restart spawned at the cut boundary never ran: not a
            # candidate (its key is inf), drop it
            restarts = [r for r in restarts
                        if math.isfinite(r["j_max"])]
        n_boundaries = max(1, len(boundary_s))
        self._last_ipc = {"init_bytes": init_bytes,
                          "step_bytes": step_bytes,
                          "collect_bytes": collect_bytes,
                          "boundaries": len(boundary_s),
                          "step_bytes_per_boundary":
                              step_bytes / n_boundaries}
        return {"nodes": nodes, "lad_j_max": cur_keys[:, 0].copy(),
                "lad_j_sum": cur_keys[:, 1].copy(), "alive": alive,
                "restarts": restarts, "sa_accepted": accepted,
                "killed": ctrl.killed, "pool_moves": ctrl.pool_moves,
                "shards": S, "backend": "resident",
                "cut_at": cut_at, "boundary_s": boundary_s,
                "best_nodes": best_nodes, "best_keys": best_keys,
                "ipc": dict(self._last_ipc)}

    # -- anytime ------------------------------------------------------------
    def refine_anytime(self, grid: CartGrid, stencil: Stencil,
                       node_of_pos: np.ndarray,
                       num_nodes: Optional[int] = None,
                       deadline_s: Optional[float] = None) -> RefineResult:
        """Deadline-bounded refine: the best valid plan found within
        ``deadline_s`` seconds.

        Cut invariants: (1) phases are checked against the deadline at
        every boundary — before the rounds prefix, before each ladder
        temperature — and the first boundary past it stops the run; (2)
        every candidate considered (start, rounds output, current ladder
        rows, worker-side best-seen snapshots, finished restarts)
        realizes the scheduler cardinalities, so the returned assignment
        is always valid no matter where the cut lands; (3) the anytime
        path never polishes — its completed-run result is a deterministic
        function of the inputs, which is what lets the server cache
        *uncut* anytime results (cut results are timing-dependent and are
        never cached).  ``deadline_s=None`` delegates to the bit-identical
        undeadlined :meth:`refine`.
        """
        if deadline_s is None:
            return self.refine(grid, stencil, node_of_pos, num_nodes)
        t0 = time.perf_counter()
        deadline_at = t0 + max(0.0, float(deadline_s))
        sched = self.schedule
        cur = np.asarray(node_of_pos, dtype=np.int64).copy()
        initial = IncrementalCost(grid, stencil, cur, num_nodes=num_nodes,
                                  weighted=sched.weighted).cost()
        best, best_key = cur.copy(), (initial.j_max, initial.j_sum)

        def consider(candidate: np.ndarray, key: Tuple[float, float]):
            nonlocal best, best_key
            if key < best_key:
                best, best_key = candidate.copy(), key

        swaps = passes = 0
        cut_stage = "start"
        if time.perf_counter() < deadline_at:
            cur, swaps, passes = sched.run_rounds(grid, stencil, cur,
                                                  num_nodes, consider,
                                                  max_swaps=None)
            cut_stage = "rounds"
        lad = None
        if time.perf_counter() < deadline_at:
            self._deadline_at = deadline_at
            try:
                lad = self._sharded_ladders(grid, stencil, cur, num_nodes)
                cut_stage = "ladders"
            except WorkerPoolError:
                lad = None    # degrade: best-so-far is still valid
            finally:
                self._deadline_at = None
        cut_at, boundary_s = 0, []
        if lad is not None:
            swaps += lad["sa_accepted"]
            cut_at = lad.get("cut_at", len(sched.temperatures))
            boundary_s = lad.get("boundary_s", [])
            for i in range(self.k):
                consider(lad["nodes"][i],
                         (float(lad["lad_j_max"][i]),
                          float(lad["lad_j_sum"][i])))
            if "best_nodes" in lad:
                for i in range(self.k):
                    consider(lad["best_nodes"][i],
                             (float(lad["best_keys"][i, 0]),
                              float(lad["best_keys"][i, 1])))
            for r in lad["restarts"]:
                consider(r["node"].copy(), (r["j_max"], r["j_sum"]))

        final = IncrementalCost(grid, stencil, best, num_nodes=num_nodes,
                                weighted=sched.weighted).cost()
        wall = time.perf_counter() - t0
        n_temps = len(sched.temperatures)
        stats = {
            "k": self.k, "seeds": self.seeds,
            "shards": lad["shards"] if lad else 0,
            "backend": "resident-anytime",
            "deadline_s": float(deadline_s),
            "cut": lad is None or cut_at < n_temps,
            "cut_stage": cut_stage, "cut_at": cut_at, "n_temps": n_temps,
            "boundary_s": boundary_s,
            "max_boundary_s": max(boundary_s) if boundary_s else 0.0,
            "overshoot_s": max(0.0, wall - float(deadline_s)),
            "sa_accepted": lad["sa_accepted"] if lad else 0,
            "killed": lad["killed"] if lad else 0,
            "restarted": len(lad["restarts"]) if lad else 0,
            "polished": 0,
            "ipc": lad.get("ipc") if lad else None,
        }
        return RefineResult(assignment=best, initial=initial, final=final,
                            swaps=swaps, passes=passes, wall_time_s=wall,
                            stats=stats)
