"""Client front for the resident plan server.

:class:`PlanClient` wraps a :class:`~repro.serving.server.PlanServer`
with the shapes callers already know: ``cart_create_async`` mirrors
:func:`repro.core.plan.cart_create` argument-for-argument but returns a
:class:`CartTicket` immediately — the mapping solve proceeds on the
server's persistent shard workers while the caller overlaps other work
(allocating buffers, compiling) and collects the
:class:`~repro.core.plan.CartResult` when it needs the mesh.  ``submit``
is the lower-level form returning raw
:class:`~repro.core.plan.MappingSolution` tickets; ``repair_async``
routes the churn path; ``stats`` scrapes the server's health counters.
"""
from __future__ import annotations

from typing import Optional, Sequence, Union

from ..core.plan import (CartResult, MappingPlan, MappingProblem,
                         MappingSolution, Stencil)
from .server import PlanServer, PlanTicket

__all__ = ["PlanClient", "CartTicket"]


class CartTicket:
    """A :class:`PlanTicket` that resolves to a
    :class:`~repro.core.plan.CartResult` (problem + layout), the shape
    ``cart_create`` callers expect."""

    def __init__(self, ticket: PlanTicket, problem: MappingProblem):
        self._ticket = ticket
        self._problem = problem
        self._result: Optional[CartResult] = None

    @property
    def done(self) -> bool:
        return self._ticket.done

    @property
    def deadline_missed(self) -> bool:
        return self._ticket.deadline_missed

    @property
    def anytime_cut(self) -> bool:
        return self._ticket.anytime_cut

    @property
    def latency_s(self) -> Optional[float]:
        return self._ticket.latency_s

    def result(self, timeout: Optional[float] = None) -> CartResult:
        if self._result is None:
            sol: MappingSolution = self._ticket.result(timeout)
            self._result = CartResult(problem=self._problem,
                                      plan_key=sol.plan_key, solution=sol,
                                      layout=sol.layout())
        return self._result


class PlanClient:
    """Ergonomic facade over a running :class:`PlanServer`."""

    def __init__(self, server: PlanServer):
        self.server = server

    # -- raw solution tickets ------------------------------------------------
    def submit(self, problem: MappingProblem, *,
               plan: Union[None, str, MappingPlan] = None,
               deadline_ms: Optional[float] = None) -> PlanTicket:
        """Enqueue a built problem; the ticket resolves to a
        :class:`MappingSolution`."""
        return self.server.submit(problem, plan=plan,
                                  deadline_ms=deadline_ms)

    # -- the cart_create mirror ----------------------------------------------
    def cart_create_async(self, mesh_shape: Sequence[int],
                          stencil: Optional[Stencil] = None, *,
                          node_sizes: Optional[Sequence[int]] = None,
                          chips_per_pod: Optional[int] = None,
                          periodic: Optional[Sequence[bool]] = None,
                          objective: str = "lex",
                          plan: Union[None, str, MappingPlan] = None,
                          deadline_ms: Optional[float] = None) -> CartTicket:
        """:func:`~repro.core.plan.cart_create`, served: same arguments
        (``plan=None`` means the server's default plan), returns
        immediately with a :class:`CartTicket`.  ``deadline_ms`` makes the
        request anytime — the best valid layout within the deadline."""
        ticket = self.server.submit(
            mesh_shape=mesh_shape, stencil=stencil, node_sizes=node_sizes,
            chips_per_pod=chips_per_pod, periodic=periodic,
            objective=objective, plan=plan, deadline_ms=deadline_ms)
        # rebuild the problem the server solved (same normalization path)
        # so the CartTicket can shape the CartResult without a round-trip
        problem = self._problem_of(mesh_shape, stencil, node_sizes,
                                   chips_per_pod, periodic, objective)
        return CartTicket(ticket, problem)

    def cart_create(self, mesh_shape: Sequence[int],
                    stencil: Optional[Stencil] = None,
                    timeout: Optional[float] = None,
                    **kwargs) -> CartResult:
        """Synchronous convenience: ``cart_create_async(...).result()``."""
        return self.cart_create_async(mesh_shape, stencil,
                                      **kwargs).result(timeout)

    # -- the churn path ------------------------------------------------------
    def repair_async(self, previous, node_sizes: Sequence[int], *,
                     deadline_ms: Optional[float] = None,
                     **repair_options) -> PlanTicket:
        """Route a warm-start repair (``remap.repair_layout``) through the
        server's admission queue and shared cache."""
        return self.server.submit_repair(previous, node_sizes,
                                         deadline_ms=deadline_ms,
                                         **repair_options)

    # -- observability -------------------------------------------------------
    def stats(self) -> dict:
        """Server health: queue depth, per-request latency percentiles,
        cache hit rate, deadline misses — see :meth:`PlanServer.stats`."""
        return self.server.stats()

    def invalidate(self, problem: Union[str, MappingProblem]) -> int:
        return self.server.invalidate(problem)

    @staticmethod
    def _problem_of(mesh_shape, stencil, node_sizes, chips_per_pod,
                    periodic, objective) -> MappingProblem:
        import math
        from ..core.plan import blocked_node_sizes
        mesh_shape = tuple(int(d) for d in mesh_shape)
        if stencil is None:
            stencil = Stencil.nearest_neighbor(len(mesh_shape))
        if node_sizes is not None:
            node_sizes = tuple(int(n) for n in node_sizes)
        else:
            node_sizes = blocked_node_sizes(math.prod(mesh_shape),
                                            chips_per_pod)
        return MappingProblem(mesh_shape, stencil, node_sizes,
                              objective=objective,
                              periodic=None if periodic is None
                              else tuple(periodic))
