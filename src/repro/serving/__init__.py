"""Mapping-as-a-service: the resident plan server.

The serving layer turns the one-shot ``cart_create`` facade into a
long-lived service: a :class:`PlanServer` owns the shared plan cache
(TTL, invalidation, size-bounded disk spill, warm-up sweeps), admits
requests through a bounded queue with per-request deadlines, and runs
``sharded[...]`` plans on persistent shard workers
(:class:`ShardWorkerPool` / :class:`ResidentShardedRefiner`) that keep
block state resident across temperatures — only leader keys and
kill/restart masks cross the wire per boundary, and the result is
bit-identical to the stateless engine.  :class:`PlanClient` is the
caller-facing front (``submit`` / ``cart_create_async`` / ``stats``).
"""
from .client import CartTicket, PlanClient
from .server import (AdmissionError, DEFAULT_SERVE_PLAN, PlanServer,
                     PlanTicket, known_topologies, register_topology)
from .workers import (ResidentShardedRefiner, ShardWorkerPool,
                      WorkerPoolError)

__all__ = [
    "AdmissionError",
    "CartTicket",
    "DEFAULT_SERVE_PLAN",
    "PlanClient",
    "PlanServer",
    "PlanTicket",
    "ResidentShardedRefiner",
    "ShardWorkerPool",
    "WorkerPoolError",
    "known_topologies",
    "register_topology",
]
