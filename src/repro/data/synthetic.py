"""Deterministic synthetic data pipeline.

Real-cluster shape: every data-parallel host generates *its own shard* of
the global batch from (seed, step, shard_index) alone — no host-to-host
traffic, bit-identical across restarts (what makes checkpoint/restart and
elastic re-sharding reproducible).

Two sources:
  * ``lm_stream``  — unigram-mixture token stream (hash-based, stateless);
  * ``memorize``   — a small fixed corpus repeated, so optimizers actually
    drive the loss toward zero in examples/tests.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from ..configs.base import ArchConfig, ShapeSpec

__all__ = ["DataConfig", "host_batch", "global_batches", "batch_spec"]


@dataclass(frozen=True)
class DataConfig:
    seed: int = 17
    mode: str = "lm_stream"        # lm_stream | memorize
    corpus_len: int = 2048         # for memorize mode


def _rng(cfg: DataConfig, step: int, shard: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, shard]))


def host_batch(arch: ArchConfig, shape: ShapeSpec, data: DataConfig,
               step: int, shard: int, num_shards: int) -> Dict[str, np.ndarray]:
    """One host's shard of the global batch for `step`."""
    if shape.global_batch % num_shards:
        raise ValueError(f"global_batch {shape.global_batch} not divisible by "
                         f"{num_shards} shards")
    b = shape.global_batch // num_shards
    S = shape.seq_len
    rng = _rng(data, step, shard)
    if data.mode == "memorize":
        corpus = np.random.default_rng(data.seed).integers(
            0, arch.vocab, size=data.corpus_len, dtype=np.int32)
        starts = rng.integers(0, data.corpus_len - 1, size=b)
        idx = (starts[:, None] + np.arange(S + 1)[None, :]) % data.corpus_len
        seqs = corpus[idx]
    else:
        # unigram mixture: zipf-ish marginal + positional drift, stateless
        z = rng.zipf(1.3, size=(b, S + 1)).astype(np.int64)
        seqs = (z + rng.integers(0, 97, size=(b, S + 1))) % arch.vocab
        seqs = seqs.astype(np.int32)
    batch = {"inputs": seqs[:, :-1].astype(np.int32),
             "targets": seqs[:, 1:].astype(np.int32)}
    if arch.family == "encdec":
        batch["src"] = rng.standard_normal(
            (b, arch.src_len, arch.d_model)).astype(np.float32)
    if arch.num_patches:
        batch["patches"] = rng.standard_normal(
            (b, arch.num_patches, arch.d_model)).astype(np.float32)
    return batch


def global_batches(arch: ArchConfig, shape: ShapeSpec, data: DataConfig,
                   start_step: int = 0, num_shards: int = 1,
                   ) -> Iterator[Dict[str, np.ndarray]]:
    """Single-process iterator assembling all shards (CPU tests/examples)."""
    step = start_step
    while True:
        shards = [host_batch(arch, shape, data, step, s, num_shards)
                  for s in range(num_shards)]
        yield {k: np.concatenate([sh[k] for sh in shards], axis=0)
               for k in shards[0]}
        step += 1


def batch_spec(arch: ArchConfig, shape: ShapeSpec) -> Dict[str, Tuple]:
    """(shape, dtype) of every batch field — drives dry-run structs."""
    B = shape.global_batch
    S = shape.seq_len
    out = {"inputs": ((B, S), np.int32), "targets": ((B, S), np.int32)}
    if arch.family == "encdec":
        out["src"] = ((B, arch.src_len, arch.d_model), np.float32)
    if arch.num_patches:
        out["patches"] = ((B, arch.num_patches, arch.d_model), np.float32)
    return out
