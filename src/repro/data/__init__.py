from .synthetic import DataConfig, batch_spec, global_batches, host_batch

__all__ = ["DataConfig", "batch_spec", "global_batches", "host_batch"]
