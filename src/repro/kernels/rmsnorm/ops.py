"""jit'd wrapper for the fused RMSNorm kernel."""
from __future__ import annotations

from functools import partial

import jax

from .ref import rmsnorm_ref
from .rmsnorm import rmsnorm_pallas

__all__ = ["rmsnorm", "rmsnorm_ref"]


@partial(jax.jit, static_argnames=("eps", "use_pallas", "interpret"))
def rmsnorm(x, w, eps: float = 1e-6, use_pallas: bool = True,
            interpret: bool = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if use_pallas:
        return rmsnorm_pallas(x, w, eps, interpret=interpret)
    return rmsnorm_ref(x, w, eps)
