"""Pure-jnp oracle for the fused RMSNorm kernel (same math as
models.common.rmsnorm)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rmsnorm_ref"]


def rmsnorm_ref(x, w, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(dt) * w
