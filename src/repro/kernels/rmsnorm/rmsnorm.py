"""Pallas TPU kernel: fused RMSNorm.

One pass over a (rows_block, d) VMEM tile: f32 mean-of-squares reduction +
normalize + scale, no f32 materialization of the whole activation in HBM
(the pure-jnp path upcasts the full tensor — visible in the roofline's
memory term).  Rows blocked over a 1-d grid; d kept whole (lane dim,
multiple of 128 for the assigned archs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["rmsnorm_kernel", "rmsnorm_pallas"]


def rmsnorm_kernel(x_ref, w_ref, out_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    out_ref[...] = (y.astype(out_ref.dtype) * w_ref[...])


def rmsnorm_pallas(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6,
                   block_rows: int = 256, interpret: bool = False):
    """x: (..., d) -> same shape; w: (d,)."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    br = min(block_rows, rows)
    if rows % br:
        br = 1
    grid = (rows // br,)
    out = pl.pallas_call(
        functools.partial(rmsnorm_kernel, eps=eps),
        grid=grid,
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x2, w)
    return out.reshape(orig_shape)
