"""Pure-jnp oracle for the stencil kernel."""
from __future__ import annotations

from typing import Sequence, Tuple

import jax.numpy as jnp

__all__ = ["stencil_ref", "stencil3d_ref"]


def stencil_ref(u_halo: jnp.ndarray, offsets: Sequence[Tuple[int, int]],
                weights: Sequence[float], halo: int) -> jnp.ndarray:
    H = u_halo.shape[0] - 2 * halo
    W = u_halo.shape[1] - 2 * halo
    acc = jnp.zeros((H, W), jnp.float32)
    for (dy, dx), w in zip(offsets, weights):
        win = u_halo[halo + dy:halo + dy + H, halo + dx:halo + dx + W]
        acc = acc + win.astype(jnp.float32) * jnp.float32(w)
    return acc.astype(u_halo.dtype)


def stencil3d_ref(u_halo, offsets, weights, halo: int):
    D = u_halo.shape[0] - 2 * halo
    H = u_halo.shape[1] - 2 * halo
    W = u_halo.shape[2] - 2 * halo
    acc = jnp.zeros((D, H, W), jnp.float32)
    for (dz, dy, dx), w in zip(offsets, weights):
        win = u_halo[halo + dz:halo + dz + D, halo + dy:halo + dy + H,
                     halo + dx:halo + dx + W]
        acc = acc + win.astype(jnp.float32) * jnp.float32(w)
    return acc.astype(u_halo.dtype)
