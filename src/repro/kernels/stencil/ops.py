"""jit'd public wrapper for the stencil kernel: picks Pallas on TPU,
interpret mode elsewhere (CPU validation), oracle available for testing."""
from __future__ import annotations

from functools import partial
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from .ref import stencil_ref
from .stencil import stencil_pallas

__all__ = ["stencil_apply", "stencil_ref"]


@partial(jax.jit, static_argnames=("offsets", "weights", "halo", "use_pallas",
                                   "interpret"))
def stencil_apply(u_halo: jnp.ndarray,
                  offsets: Tuple[Tuple[int, int], ...],
                  weights: Tuple[float, ...],
                  halo: int,
                  use_pallas: bool = True,
                  interpret: bool = None) -> jnp.ndarray:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if use_pallas:
        return stencil_pallas(u_halo, offsets, weights, halo,
                              interpret=interpret)
    return stencil_ref(u_halo, offsets, weights, halo)
