"""Pallas TPU kernel: k-neighborhood stencil apply (the paper's compute).

Computes ``out[i,j] = sum_k w_k * u[i + R_k0, j + R_k1]`` over a 2-d local
shard with an attached halo of width ``h`` (the halo is what the mapped
``MPI_Neighbor_alltoall`` analog exchanges; see examples/stencil_jacobi.py).

TPU adaptation (DESIGN.md): the CUDA-style version threads one point per
thread; on TPU we tile the *output* over a 1-d grid of row panels sized to
the VPU lanes (multiples of 8x128) and keep the haloed input resident in
VMEM, reading k statically-shifted windows per tile.  Input residency in
VMEM bounds the shard size (~VMEM/4 elements); the production variant would
stream row panels with ``pl.Element`` indexing — recorded as a §Perf note.
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["stencil_kernel", "stencil_pallas", "stencil3d_kernel", "stencil3d_pallas"]


def stencil_kernel(u_ref, out_ref, *, offsets, weights, halo, tile_rows):
    """One grid step: compute a (tile_rows, W) output panel."""
    i = pl.program_id(0)
    r0 = i * tile_rows
    acc = None
    for (dy, dx), w in zip(offsets, weights):
        win = u_ref[pl.dslice(r0 + halo + dy, tile_rows),
                    pl.dslice(halo + dx, out_ref.shape[1])]
        term = win.astype(jnp.float32) * jnp.float32(w)
        acc = term if acc is None else acc + term
    out_ref[pl.dslice(r0, tile_rows), :] = acc.astype(out_ref.dtype)


def stencil_pallas(u_halo: jnp.ndarray, offsets: Sequence[Tuple[int, int]],
                   weights: Sequence[float], halo: int,
                   tile_rows: int = 8, interpret: bool = False) -> jnp.ndarray:
    """u_halo: (H + 2*halo, W + 2*halo) -> out: (H, W)."""
    H = u_halo.shape[0] - 2 * halo
    W = u_halo.shape[1] - 2 * halo
    if H % tile_rows:
        tile_rows = 1
    grid = (H // tile_rows,)
    kern = functools.partial(stencil_kernel, offsets=tuple(map(tuple, offsets)),
                             weights=tuple(float(w) for w in weights),
                             halo=halo, tile_rows=tile_rows)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec(u_halo.shape, lambda i: (0, 0))],
        out_specs=pl.BlockSpec((H, W), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((H, W), u_halo.dtype),
        interpret=interpret,
    )(u_halo)


def stencil3d_kernel(u_ref, out_ref, *, offsets, weights, halo, tile_z):
    """3-d variant: grid over z-slabs; each step reads the (tile_z + 2h)
    slab window and k statically-shifted (H, W) windows per z offset."""
    i = pl.program_id(0)
    z0 = i * tile_z
    H, W = out_ref.shape[1], out_ref.shape[2]
    acc = None
    for (dz, dy, dx), w in zip(offsets, weights):
        win = u_ref[pl.dslice(z0 + halo + dz, tile_z),
                    pl.dslice(halo + dy, H),
                    pl.dslice(halo + dx, W)]
        term = win.astype(jnp.float32) * jnp.float32(w)
        acc = term if acc is None else acc + term
    out_ref[pl.dslice(z0, tile_z), :, :] = acc.astype(out_ref.dtype)


def stencil3d_pallas(u_halo: jnp.ndarray, offsets, weights, halo: int,
                     tile_z: int = 4, interpret: bool = False) -> jnp.ndarray:
    """u_halo: (D+2h, H+2h, W+2h) -> out: (D, H, W)."""
    D = u_halo.shape[0] - 2 * halo
    H = u_halo.shape[1] - 2 * halo
    W = u_halo.shape[2] - 2 * halo
    if D % tile_z:
        tile_z = 1
    kern = functools.partial(stencil3d_kernel,
                             offsets=tuple(map(tuple, offsets)),
                             weights=tuple(float(w) for w in weights),
                             halo=halo, tile_z=tile_z)
    return pl.pallas_call(
        kern,
        grid=(D // tile_z,),
        in_specs=[pl.BlockSpec(u_halo.shape, lambda i: (0, 0, 0))],
        out_specs=pl.BlockSpec((D, H, W), lambda i: (0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((D, H, W), u_halo.dtype),
        interpret=interpret,
    )(u_halo)
