"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = ["attention_ref"]


def attention_ref(q, k, v, *, causal=True, window=None, scale=None):
    """q: (BH, Sq, d), k/v: (BH, Sk, d)."""
    d = q.shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    Sq, Sk = s.shape[-2:]
    pos_q = jnp.arange(Sq)[:, None]
    pos_k = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= pos_q >= pos_k
    if window is not None:
        mask &= (pos_q - pos_k) < window
    s = jnp.where(mask[None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w, v.astype(jnp.float32)).astype(q.dtype)
