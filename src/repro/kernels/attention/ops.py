"""jit'd wrapper: GQA-aware flash attention entry point."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .flash import flash_attention_pallas
from .ref import attention_ref

__all__ = ["flash_attention", "attention_ref"]


@partial(jax.jit, static_argnames=("causal", "window", "use_pallas",
                                   "interpret"))
def flash_attention(q, k, v, *, causal=True, window=None,
                    use_pallas: bool = True, interpret: bool = None):
    """q: (B, Sq, H, d); k/v: (B, Sk, K, d) with H = K*G (GQA broadcast
    handled here). Returns (B, Sq, H, d)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, Sq, H, d = q.shape
    K = k.shape[2]
    G = H // K
    kb = jnp.repeat(k, G, axis=2)
    vb = jnp.repeat(v, G, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, d)
    kf = kb.transpose(0, 2, 1, 3).reshape(B * H, -1, d)
    vf = vb.transpose(0, 2, 1, 3).reshape(B * H, -1, d)
    if use_pallas:
        out = flash_attention_pallas(qf, kf, vf, causal=causal, window=window,
                                     interpret=interpret)
    else:
        out = attention_ref(qf, kf, vf, causal=causal, window=window)
    return out.reshape(B, H, Sq, d).transpose(0, 2, 1, 3)
