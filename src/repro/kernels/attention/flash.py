"""Pallas TPU kernel: blocked flash attention (causal / sliding-window).

Grid = (batch*kv_heads*groups, q_blocks, kv_blocks) with the kv dimension
innermost and ``arbitrary`` semantics: running max / denominator / output
accumulate in VMEM scratch across kv steps and the output tile is emitted on
the last kv block.  BlockSpecs tile q/k/v into (block, head_dim) VMEM panels
(head_dim = 128 on the assigned archs — MXU aligned).

This is the TPU-native replacement for the jnp double-scan in
``models.attention._blocked_sdpa`` (same math, same oracle).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams in newer JAX; accept both
_CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")

__all__ = ["flash_kernel", "flash_attention_pallas"]

NEG_INF = -1e30


def flash_kernel(q_ref, k_ref, v_ref, out_ref, acc_ref, m_ref, l_ref, *,
                 scale, causal, window, q_block, kv_block, kv_steps):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                    # (qb, d)
    k = k_ref[0].astype(jnp.float32)                    # (kb, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    pos_q = qi * q_block + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    pos_k = ki * kv_block + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= pos_q >= pos_k
    if window is not None:
        mask &= (pos_q - pos_k) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
    v = v_ref[0].astype(jnp.float32)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == kv_steps - 1)
    def _emit():
        out_ref[0] = (acc_ref[...] /
                      jnp.maximum(l_ref[...], 1e-30)[:, None]
                      ).astype(out_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal=True, window=None, scale=None,
                           q_block: int = 256, kv_block: int = 256,
                           interpret: bool = False):
    """q: (BH, Sq, d), k/v: (BH, Sk, d) — heads pre-flattened, KV heads
    pre-broadcast (GQA grouping handled by the wrapper)."""
    BH, Sq, d = q.shape
    Sk = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qb = min(q_block, Sq)
    kb = min(kv_block, Sk)
    if Sq % qb:
        qb = Sq
    if Sk % kb:
        kb = Sk
    nq, nk = Sq // qb, Sk // kb
    grid = (BH, nq, nk)
    kern = functools.partial(flash_kernel, scale=scale, causal=causal,
                             window=window, q_block=qb, kv_block=kb,
                             kv_steps=nk)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, qb, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, kb, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, kb, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, qb, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qb, d), jnp.float32),
            pltpu.VMEM((qb,), jnp.float32),
            pltpu.VMEM((qb,), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
