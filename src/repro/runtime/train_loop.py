"""Fault-tolerant training driver.

Responsibilities: state init/resume, data feeding, stepping, checkpoint
rotation, fault recovery (restore + restart), elastic re-mesh on node loss,
straggler monitoring.  Runs on one CPU device (smoke/examples) and on real
meshes unchanged — device placement flows through the partitioning layer.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.manager import CheckpointManager
from ..configs.base import ArchConfig, ShapeSpec
from ..core import CartGrid, Stencil, get_mapper
from ..data.synthetic import DataConfig, host_batch
from ..models import lm
from ..models.common import init_params
from ..optim.adamw import AdamWConfig, init_opt_state
from .fault import FaultInjector, SimulatedFault
from .steps import make_train_step
from .straggler import StragglerMonitor

__all__ = ["Trainer", "TrainResult"]


@dataclass
class TrainResult:
    steps_done: int
    final_loss: float
    losses: list
    restarts: int
    remaps: int
    straggler_events: list


class Trainer:
    def __init__(self, cfg: ArchConfig, shape: ShapeSpec,
                 opt_cfg: Optional[AdamWConfig] = None,
                 data_cfg: Optional[DataConfig] = None,
                 ckpt_dir: Optional[str] = None,
                 ckpt_every: int = 20,
                 fault: Optional[FaultInjector] = None,
                 straggler: Optional[StragglerMonitor] = None,
                 num_nodes: int = 1,
                 seed: int = 0,
                 moe_dispatch: str = "einsum"):
        self.cfg, self.shape = cfg, shape
        self.opt_cfg = opt_cfg or AdamWConfig(lr=1e-3, warmup_steps=10,
                                              total_steps=1000)
        self.data_cfg = data_cfg or DataConfig()
        self.fault = fault or FaultInjector()
        self.straggler = straggler or StragglerMonitor()
        self.ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
        self.ckpt_every = ckpt_every
        self.seed = seed
        self.num_nodes = num_nodes          # simulated node count (elastic)
        self.alive_nodes = list(range(num_nodes))
        self.remaps = 0
        self._step_fn = jax.jit(make_train_step(cfg, self.opt_cfg,
                                                moe_dispatch=moe_dispatch))

    # ------------------------------------------------------------------
    def _init_state(self):
        specs = lm.param_specs(self.cfg)
        params = init_params(specs, jax.random.PRNGKey(self.seed))
        opt = init_opt_state(specs, self.opt_cfg)
        return params, opt, 0

    def _resume_or_init(self):
        if self.ckpt is not None:
            step, state = self.ckpt.restore()
            if state is not None:
                expected = set(lm.param_specs(self.cfg))
                if set(state.get("params", {})) != expected:
                    # checkpoint belongs to a different arch/config: ignore
                    # rather than load garbage (defensive restore)
                    return self._init_state()
                params = {k: jnp.asarray(v) for k, v in state["params"].items()}
                opt = {k: jnp.asarray(v) for k, v in state["opt"].items()}
                return params, opt, int(step)
        return self._init_state()

    def _batch(self, step: int) -> Dict[str, jnp.ndarray]:
        shards = [host_batch(self.cfg, self.shape, self.data_cfg, step, s,
                             max(len(self.alive_nodes), 1))
                  for s in range(max(len(self.alive_nodes), 1))]
        return {k: jnp.asarray(np.concatenate([sh[k] for sh in shards]))
                for k in shards[0]}

    def _elastic_remap(self, lost_node: int) -> None:
        """Drop a node and recompute the process-to-node mapping for the
        survivors (the paper's heterogeneous-n_i path).  On real hardware
        this would rebuild the jax Mesh from the surviving devices via
        ``core.remap.mapped_device_array``; here we recompute the mapping
        and shrink the data-parallel width."""
        if lost_node in self.alive_nodes and len(self.alive_nodes) > 1:
            self.alive_nodes.remove(lost_node)
        self.remaps += 1
        n = len(self.alive_nodes)
        # re-run the mapper on the shrunken allocation to verify feasibility
        grid = CartGrid((max(n, 1), 1))
        st = Stencil.component(2, axes=[0])
        get_mapper("hyperplane").assignment(grid, st, [1] * max(n, 1))

    # ------------------------------------------------------------------
    def run(self, num_steps: int, max_restarts: int = 5) -> TrainResult:
        params, opt, start = self._resume_or_init()
        losses = []
        restarts = 0
        step = start
        while step < num_steps:
            try:
                self.fault.check(step)
                t0 = time.perf_counter()
                batch = self._batch(step)
                params, opt, metrics = self._step_fn(params, opt, batch)
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                action = self.straggler.record(step, dt)
                if action == "remap":
                    self.remaps += 1  # evict+remap recommendation honored
                losses.append(loss)
                step += 1
                if self.ckpt is not None and (step % self.ckpt_every == 0
                                              or step == num_steps):
                    self.ckpt.save(step, {"params": params, "opt": opt},
                                   meta={"arch": self.cfg.name})
            except SimulatedFault as f:
                restarts += 1
                if restarts > max_restarts:
                    raise
                if f.kind == "node_loss":
                    self._elastic_remap(f.node if f.node is not None else 0)
                # restore from last durable state (or reinit)
                params, opt, step = self._resume_or_init()
        if self.ckpt is not None:
            self.ckpt.wait()
        return TrainResult(steps_done=step - start,
                           final_loss=losses[-1] if losses else float("nan"),
                           losses=losses, restarts=restarts,
                           remaps=self.remaps,
                           straggler_events=list(self.straggler.events))
