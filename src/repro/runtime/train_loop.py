"""Fault-tolerant training driver.

Responsibilities: state init/resume, data feeding, stepping, checkpoint
rotation, fault recovery (restore + restart), elastic re-mesh on node loss,
straggler monitoring.  Runs on one CPU device (smoke/examples) and on real
meshes unchanged — device placement flows through the partitioning layer.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.manager import CheckpointManager
from ..configs.base import ArchConfig, ShapeSpec
from ..core import Stencil
from ..core.plan import MappingProblem
from ..core.remap import elastic_portfolio_plan, repair_layout
from ..core.repair import downweighted_node_sizes
from ..data.synthetic import DataConfig, host_batch
from ..models import lm
from ..models.common import init_params
from ..optim.adamw import AdamWConfig, init_opt_state
from .fault import FaultInjector, SimulatedFault
from .steps import make_train_step
from .straggler import StragglerMonitor

__all__ = ["Trainer", "TrainResult"]


@dataclass
class TrainResult:
    steps_done: int
    final_loss: float
    losses: list
    restarts: int
    remaps: int
    straggler_events: list
    repairs: int = 0        # warm-start plan repairs (vs cold re-solves)


class Trainer:
    def __init__(self, cfg: ArchConfig, shape: ShapeSpec,
                 opt_cfg: Optional[AdamWConfig] = None,
                 data_cfg: Optional[DataConfig] = None,
                 ckpt_dir: Optional[str] = None,
                 ckpt_every: int = 20,
                 fault: Optional[FaultInjector] = None,
                 straggler: Optional[StragglerMonitor] = None,
                 num_nodes: int = 1,
                 seed: int = 0,
                 moe_dispatch: str = "einsum"):
        self.cfg, self.shape = cfg, shape
        self.opt_cfg = opt_cfg or AdamWConfig(lr=1e-3, warmup_steps=10,
                                              total_steps=1000)
        self.data_cfg = data_cfg or DataConfig()
        self.fault = fault or FaultInjector()
        self.straggler = straggler or StragglerMonitor()
        self.ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
        self.ckpt_every = ckpt_every
        self.seed = seed
        self.num_nodes = num_nodes          # simulated node count (elastic)
        self.alive_nodes = list(range(num_nodes))
        self.remaps = 0
        self.repairs = 0                    # warm-start repairs performed
        self._map_solution = None           # current topology's mapping
        self._step_fn = jax.jit(make_train_step(cfg, self.opt_cfg,
                                                moe_dispatch=moe_dispatch))

    #: simulated chips per node for the process-to-node mapping problem
    #: (the driver has no real devices; the mapping pipeline runs for real)
    _SIM_CHIPS = 4

    def _mapping_stencil(self) -> Stencil:
        return Stencil.component(2, axes=[0])

    def _solve_mapping_cold(self, n: int):
        """Cold-solve the n-node mapping (the elastic portfolio plan —
        what repair is the warm alternative to)."""
        problem = MappingProblem((max(n, 1), self._SIM_CHIPS),
                                 self._mapping_stencil(),
                                 (self._SIM_CHIPS,) * max(n, 1))
        return elastic_portfolio_plan().solve(problem)

    # ------------------------------------------------------------------
    def _init_state(self):
        specs = lm.param_specs(self.cfg)
        params = init_params(specs, jax.random.PRNGKey(self.seed))
        opt = init_opt_state(specs, self.opt_cfg)
        return params, opt, 0

    def _resume_or_init(self):
        if self.ckpt is not None:
            step, state = self.ckpt.restore()
            if state is not None:
                expected = set(lm.param_specs(self.cfg))
                if set(state.get("params", {})) != expected:
                    # checkpoint belongs to a different arch/config: ignore
                    # rather than load garbage (defensive restore)
                    return self._init_state()
                params = {k: jnp.asarray(v) for k, v in state["params"].items()}
                opt = {k: jnp.asarray(v) for k, v in state["opt"].items()}
                return params, opt, int(step)
        return self._init_state()

    def _batch(self, step: int) -> Dict[str, jnp.ndarray]:
        shards = [host_batch(self.cfg, self.shape, self.data_cfg, step, s,
                             max(len(self.alive_nodes), 1))
                  for s in range(max(len(self.alive_nodes), 1))]
        return {k: jnp.asarray(np.concatenate([sh[k] for sh in shards]))
                for k in shards[0]}

    def _elastic_remap(self, lost_node: int) -> None:
        """Drop a node and re-solve the process-to-node mapping for the
        survivors (the paper's heterogeneous-n_i path) — warm-started from
        the previous topology's solution when one exists
        (:func:`~repro.core.remap.repair_layout`), cold otherwise.  On real
        hardware the resulting ``solution.layout()`` would rebuild the jax
        Mesh from the surviving devices via ``remap.apply_layout``; here we
        run the mapping pipeline for real and shrink the data-parallel
        width."""
        prev_alive = list(self.alive_nodes)
        if lost_node in self.alive_nodes and len(self.alive_nodes) > 1:
            self.alive_nodes.remove(lost_node)
        self.remaps += 1
        n = max(len(self.alive_nodes), 1)
        prev = self._map_solution
        if prev is not None and prev.problem.num_nodes == len(prev_alive) \
                and n < len(prev_alive):
            # warm-start: survivors keep their old positions, the lost
            # node's share is re-homed and lightly annealed
            node_map = [prev_alive.index(a) for a in self.alive_nodes]
            self._map_solution = repair_layout(
                prev, (self._SIM_CHIPS,) * n,
                mesh_shape=(n, self._SIM_CHIPS), node_map=node_map)
            self.repairs += 1
        else:
            self._map_solution = self._solve_mapping_cold(n)
        self._map_solution.layout()     # the device permutation, realized

    def _straggler_repair(self, slow_node: int, factor: float = 2.0) -> None:
        """Honor a "remap" recommendation for a slow-but-alive node: a
        weighted-node re-solve with its capacity down-weighted (the node
        keeps ``1/factor`` of its share), warm-started from the current
        solution."""
        n = max(len(self.alive_nodes), 1)
        if self._map_solution is None or \
                self._map_solution.problem.num_nodes != n:
            self._map_solution = self._solve_mapping_cold(n)
        idx = self.alive_nodes.index(slow_node) \
            if slow_node in self.alive_nodes else 0
        sizes = downweighted_node_sizes(
            self._map_solution.problem.node_sizes, idx, factor)
        self._map_solution = repair_layout(self._map_solution, sizes)
        self.repairs += 1
        self._map_solution.layout()

    # ------------------------------------------------------------------
    def run(self, num_steps: int, max_restarts: int = 5) -> TrainResult:
        params, opt, start = self._resume_or_init()
        losses = []
        restarts = 0
        step = start
        while step < num_steps:
            try:
                self.fault.check(step)
                t0 = time.perf_counter()
                batch = self._batch(step)
                params, opt, metrics = self._step_fn(params, opt, batch)
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                action = self.straggler.record(step, dt)
                if action == "remap":
                    # evict+remap recommendation honored: the slow node
                    # (not identifiable from the aggregate step time in
                    # this simulated driver — take the last alive node)
                    # gets a down-weighted warm-start re-solve
                    self.remaps += 1
                    self._straggler_repair(self.alive_nodes[-1])
                losses.append(loss)
                step += 1
                if self.ckpt is not None and (step % self.ckpt_every == 0
                                              or step == num_steps):
                    self.ckpt.save(step, {"params": params, "opt": opt},
                                   meta={"arch": self.cfg.name})
            except SimulatedFault as f:
                restarts += 1
                if restarts > max_restarts:
                    raise
                if f.kind == "node_loss":
                    self._elastic_remap(f.node if f.node is not None else 0)
                # restore from last durable state (or reinit)
                params, opt, step = self._resume_or_init()
        if self.ckpt is not None:
            self.ckpt.wait()
        return TrainResult(steps_done=step - start,
                           final_loss=losses[-1] if losses else float("nan"),
                           losses=losses, restarts=restarts,
                           remaps=self.remaps, repairs=self.repairs,
                           straggler_events=list(self.straggler.events))
