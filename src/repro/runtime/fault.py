"""Fault injection + health model for the training driver.

On a real fleet, failures arrive as ICI/host errors or missed heartbeats;
here they are injected deterministically so the recovery paths (restore,
restart, elastic re-mesh, warm-start repair) are exercised by CPU tests.
Failure kinds:

  * "step_crash"        — transient: the step raises; driver restores from
                          the last checkpoint and continues (same topology);
  * "node_loss:N"       — persistent: pod/host N is gone; driver re-meshes
                          onto the survivors (heterogeneous node sizes —
                          the paper's n_i support doing real work) and
                          continues.  "node_loss:N:C" loses only C chips
                          of pod N (the pod survives, degraded).

Schedule entries are validated at construction — a malformed entry (e.g.
``"node_loss"`` with no pod index) used to surface as ``node=None`` deep
in the re-mesh path with no pod to drop; now it raises immediately with
the offending spelling.  :class:`SimulatedFault` carries enough to compute
the survivor topology (:meth:`SimulatedFault.survivors` /
:meth:`SimulatedFault.survivor_map`) so recovery code never re-parses.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

__all__ = ["SimulatedFault", "FaultInjector", "FAULT_KINDS"]

#: the vocabulary of injectable failures
FAULT_KINDS = ("step_crash", "node_loss")


class SimulatedFault(RuntimeError):
    """One injected failure.  ``node`` is the lost (or degraded) pod for
    "node_loss"; ``chips`` is how many of its chips are gone (``None`` =
    the whole pod)."""

    def __init__(self, kind: str, step: int, node: Optional[int] = None,
                 chips: Optional[int] = None):
        detail = ""
        if node is not None:
            detail = f" (node {node}" + \
                (f", {chips} chips" if chips is not None else "") + ")"
        super().__init__(f"simulated {kind} at step {step}" + detail)
        self.kind = kind
        self.step = step
        self.node = node
        self.chips = chips

    def survivors(self, node_sizes) -> List[int]:
        """The post-fault ``node_sizes``: pod ``node`` shrunk by ``chips``,
        or removed entirely for a whole-pod loss.  Raises for faults that
        do not change topology ("step_crash") or an out-of-range pod."""
        if self.kind != "node_loss":
            raise ValueError(f"{self.kind!r} does not change topology")
        sizes = [int(s) for s in node_sizes]
        if not 0 <= self.node < len(sizes):
            raise ValueError(f"lost node {self.node} out of range for "
                             f"{len(sizes)} nodes")
        if self.chips is None:
            sizes.pop(self.node)
            return sizes
        if not 0 < self.chips < sizes[self.node]:
            raise ValueError(
                f"node {self.node} has {sizes[self.node]} chips, cannot "
                f"lose {self.chips} (whole-pod loss omits the chip count)")
        sizes[self.node] -= self.chips
        return sizes

    def survivor_map(self, num_nodes: int) -> Optional[List[int]]:
        """``node_map`` for :func:`~repro.core.remap.repair_layout`:
        post-fault pod index -> pre-fault pod index.  ``None`` (identity)
        when the pod survives degraded; the surviving old indices in order
        for a whole-pod loss."""
        if self.kind != "node_loss" or self.chips is not None:
            return None
        return [i for i in range(int(num_nodes)) if i != self.node]


def _parse_entry(step: int, spec: str) -> SimulatedFault:
    """Validate one schedule entry and pre-build its fault."""
    parts = str(spec).split(":")
    kind = parts[0]
    if kind not in FAULT_KINDS:
        raise ValueError(f"unknown fault kind {kind!r} at step {step} "
                         f"(entry {spec!r}); choose from {FAULT_KINDS}")
    if kind == "step_crash":
        if len(parts) != 1:
            raise ValueError(f"step_crash takes no arguments, got {spec!r} "
                             f"at step {step}")
        return SimulatedFault(kind, step)
    # node_loss requires the pod index; optional chip count
    if len(parts) not in (2, 3):
        raise ValueError(
            f"malformed fault {spec!r} at step {step}: node_loss needs a "
            "pod index — 'node_loss:<node>' or 'node_loss:<node>:<chips>'")
    try:
        node = int(parts[1])
        chips = int(parts[2]) if len(parts) == 3 else None
    except ValueError:
        raise ValueError(f"malformed fault {spec!r} at step {step}: "
                         "node/chips must be integers") from None
    if node < 0:
        raise ValueError(f"fault {spec!r} at step {step}: pod index must "
                         "be >= 0")
    if chips is not None and chips <= 0:
        raise ValueError(f"fault {spec!r} at step {step}: chip count must "
                         "be positive")
    return SimulatedFault(kind, step, node, chips)


@dataclass
class FaultInjector:
    """schedule: step -> kind ("step_crash" | "node_loss:<node>[:<chips>]").
    Entries are validated eagerly at construction (malformed spellings
    raise here, not mid-training)."""
    schedule: Dict[int, str] = field(default_factory=dict)
    fired: set = field(default_factory=set)

    def __post_init__(self):
        self._parsed: Dict[int, SimulatedFault] = {
            int(step): _parse_entry(int(step), spec)
            for step, spec in self.schedule.items()}

    def check(self, step: int) -> None:
        if step in self._parsed and step not in self.fired:
            self.fired.add(step)
            raise self._parsed[step]
