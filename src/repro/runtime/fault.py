"""Fault injection + health model for the training driver.

On a real fleet, failures arrive as ICI/host errors or missed heartbeats;
here they are injected deterministically so the recovery paths (restore,
restart, elastic re-mesh) are exercised by CPU tests.  Failure kinds:

  * "step_crash"   — transient: the step raises; driver restores from the
                     last checkpoint and continues (same topology);
  * "node_loss"    — persistent: a pod/host is gone; driver re-meshes onto
                     the survivors (heterogeneous node sizes — the paper's
                     n_i support doing real work) and continues.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

__all__ = ["SimulatedFault", "FaultInjector"]


class SimulatedFault(RuntimeError):
    def __init__(self, kind: str, step: int, node: Optional[int] = None):
        super().__init__(f"simulated {kind} at step {step}"
                         + (f" (node {node})" if node is not None else ""))
        self.kind = kind
        self.step = step
        self.node = node


@dataclass
class FaultInjector:
    """schedule: step -> kind ("step_crash" | "node_loss[:node]")."""
    schedule: Dict[int, str] = field(default_factory=dict)
    fired: set = field(default_factory=set)

    def check(self, step: int) -> None:
        if step in self.schedule and step not in self.fired:
            self.fired.add(step)
            kind = self.schedule[step]
            node = None
            if ":" in kind:
                kind, node_s = kind.split(":", 1)
                node = int(node_s)
            raise SimulatedFault(kind, step, node)
