from .fault import FaultInjector, SimulatedFault
from .serve_loop import Request, ServeLoop
from .steps import make_decode_step, make_prefill_step, make_train_step
from .straggler import StragglerMonitor
from .train_loop import Trainer, TrainResult

__all__ = ["FaultInjector", "SimulatedFault", "Request", "ServeLoop",
           "make_train_step", "make_prefill_step", "make_decode_step",
           "StragglerMonitor", "Trainer", "TrainResult"]
