"""jit-able step functions: training (with microbatch gradient accumulation)
and serving (prefill / decode).  Shared by the real drivers and the dry-run.
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..models import lm
from ..optim.adamw import AdamWConfig, adamw_update

__all__ = ["make_train_step", "make_prefill_step", "make_decode_step"]


def make_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig,
                    moe_dispatch: str = "einsum"):
    """train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    With cfg.microbatches > 1 the global batch is split along the batch dim
    and gradients accumulate through a lax.scan — per-microbatch backward
    passes overlap with the (sharded) gradient reduce in XLA's schedule.
    Accumulation dtype = param dtype (bf16 for the big archs; DESIGN.md §7
    discusses the memory trade).
    """

    def loss_of(p, b):
        return lm.loss_fn(cfg, p, b, moe_dispatch=moe_dispatch)

    def train_step(params, opt_state, batch):
        M = cfg.microbatches
        if M > 1:
            mb = {k: v.reshape((M, v.shape[0] // M) + v.shape[1:])
                  for k, v in batch.items()}

            def micro(acc, b):
                (_, metrics), grads = jax.value_and_grad(
                    loss_of, has_aux=True)(params, b)
                acc = jax.tree.map(lambda a, g: a + g.astype(a.dtype),
                                   acc, grads)
                return acc, metrics

            acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params)
            grads, metrics_all = jax.lax.scan(micro, acc0, mb)
            grads = jax.tree.map(lambda g: g / M, grads)
            metrics = jax.tree.map(lambda m: jnp.mean(m, axis=0), metrics_all)
        else:
            (_, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params, batch)
        params, opt_state, opt_metrics = adamw_update(
            params, grads, opt_state, opt_cfg)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig, moe_dispatch: str = "einsum"):
    def prefill_step(params, batch, caches):
        return lm.prefill(cfg, params, batch, caches,
                          moe_dispatch=moe_dispatch)
    return prefill_step


def make_decode_step(cfg: ArchConfig, moe_dispatch: str = "einsum"):
    def decode_step(params, caches, token, pos):
        logits, caches = lm.decode_step(cfg, params, token, caches, pos=pos,
                                        moe_dispatch=moe_dispatch)
        return logits, caches
    return decode_step
