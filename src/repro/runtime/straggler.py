"""Straggler detection: per-step wall-time EWMA with a slow-step policy.

At fleet scale one slow host serializes every collective; the standard
mitigations are (a) replace/evict the host and re-map its shards, (b) shed
non-critical work.  The monitor implements the detection and recommends an
action; the driver wires it to the elastic re-mesh path.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

__all__ = ["StragglerMonitor"]


@dataclass
class StragglerMonitor:
    alpha: float = 0.2          # EWMA factor
    warn_ratio: float = 1.5     # step slower than ratio x EWMA -> warn
    remap_ratio: float = 2.5    # persistently slower -> recommend remap
    patience: int = 3           # consecutive slow steps before remap
    ewma: Optional[float] = None
    slow_streak: int = 0
    events: List[tuple] = field(default_factory=list)

    def record(self, step: int, dt: float) -> Optional[str]:
        if self.ewma is None:
            self.ewma = dt
            return None
        action = None
        if dt > self.remap_ratio * self.ewma:
            self.slow_streak += 1
            if self.slow_streak >= self.patience:
                action = "remap"
                self.slow_streak = 0
            else:
                action = "warn"
        elif dt > self.warn_ratio * self.ewma:
            self.slow_streak = 0
            action = "warn"
        else:
            self.slow_streak = 0
        # EWMA excludes extreme outliers so a single hiccup does not poison it
        if dt < self.remap_ratio * self.ewma:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        if action:
            self.events.append((step, dt, action))
        return action
