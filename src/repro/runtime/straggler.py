"""Straggler detection: per-step wall-time EWMA with a slow-step policy.

At fleet scale one slow host serializes every collective; the standard
mitigations are (a) replace/evict the host and re-map its shards — the
elastic repair path (:func:`~repro.core.remap.repair_layout` with
:func:`~repro.core.repair.downweighted_node_sizes`), (b) shed non-critical
work.  The monitor implements the detection and recommends an action; the
driver wires it to the warm-start repair path.

Escalation semantics (the load-bearing part):

* a *healthy* step (``dt <= warn_ratio * ewma``) resets the slow streak
  and updates the EWMA;
* **any** slow step (``dt > warn_ratio * ewma``) — warn band *or* beyond
  ``remap_ratio`` — extends the streak and is excluded from the EWMA, so a
  host persistently ~2x slow that oscillates below ``remap_ratio`` still
  escalates to "remap" after ``patience`` consecutive slow steps (it used
  to reset the streak on every warn-band step and never escalate);
* the EWMA is seeded from the *median* of the first ``warmup`` steps, not
  from step 0 alone — an anomalously slow first step (compilation, cold
  caches) otherwise poisons every later ratio.
"""
from __future__ import annotations

import statistics
from dataclasses import dataclass, field
from typing import Dict, List, Optional

__all__ = ["StragglerMonitor", "FleetStragglerMonitor"]


@dataclass
class StragglerMonitor:
    alpha: float = 0.2          # EWMA factor
    warn_ratio: float = 1.5     # step slower than ratio x EWMA -> slow
    remap_ratio: float = 2.5    # severe: 2 consecutive such steps -> remap
    patience: int = 3           # consecutive slow steps before remap
    warmup: int = 3             # steps whose median seeds the EWMA
    ewma: Optional[float] = None
    slow_streak: int = 0
    events: List[tuple] = field(default_factory=list)
    _warmup_buf: List[float] = field(default_factory=list, repr=False)

    def record(self, step: int, dt: float) -> Optional[str]:
        # warm-up: seed the EWMA from the median of the first steps so one
        # anomalously slow step 0 (compilation) cannot poison the baseline
        if self.ewma is None:
            self._warmup_buf.append(float(dt))
            if len(self._warmup_buf) >= max(1, self.warmup):
                self.ewma = float(statistics.median(self._warmup_buf))
                self._warmup_buf.clear()
            return None
        action = None
        if dt > self.warn_ratio * self.ewma:
            # warn band AND beyond-remap_ratio steps both extend the
            # streak: persistent ~2x slowness must escalate even when no
            # single step crosses remap_ratio
            self.slow_streak += 1
            severe = dt > self.remap_ratio * self.ewma
            # patience bounds warn-band escalation; a *repeated* severe
            # step (beyond remap_ratio) escalates after two in a row — but
            # a single severe hiccup alone never triggers a remap
            if self.slow_streak >= self.patience or \
                    (severe and self.slow_streak >= 2):
                action = "remap"
                self.slow_streak = 0
            else:
                action = "warn"
        else:
            self.slow_streak = 0
            # only healthy steps update the EWMA — warn-band steps used to
            # leak in and ratchet the baseline toward the straggler's pace
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        if action:
            self.events.append((step, dt, action))
        return action


@dataclass
class FleetStragglerMonitor:
    """Per-node straggler monitors sharing one policy: feed each node's
    step wall-time, get back the nodes needing action this step.  The
    driver turns a "remap" into a down-weighted repair
    (:func:`~repro.core.repair.downweighted_node_sizes` +
    :func:`~repro.core.remap.repair_layout`) for that node."""

    alpha: float = 0.2
    warn_ratio: float = 1.5
    remap_ratio: float = 2.5
    patience: int = 3
    warmup: int = 3
    monitors: Dict[int, StragglerMonitor] = field(default_factory=dict)

    def monitor(self, node: int) -> StragglerMonitor:
        if node not in self.monitors:
            self.monitors[node] = StragglerMonitor(
                alpha=self.alpha, warn_ratio=self.warn_ratio,
                remap_ratio=self.remap_ratio, patience=self.patience,
                warmup=self.warmup)
        return self.monitors[node]

    def record(self, step: int, node_dts: Dict[int, float]) \
            -> Dict[int, str]:
        """Record one step's per-node wall-times; returns ``{node:
        action}`` for the nodes whose monitor recommends one."""
        actions: Dict[int, str] = {}
        for node, dt in node_dts.items():
            a = self.monitor(int(node)).record(step, float(dt))
            if a:
                actions[int(node)] = a
        return actions

    @property
    def events(self) -> List[tuple]:
        """All (node, step, dt, action) events, step-ordered."""
        out = [(n, *e) for n, m in self.monitors.items() for e in m.events]
        return sorted(out, key=lambda t: (t[1], t[0]))
