"""Batched serving loop: fixed-slot continuous batching.

A request arrives with a prompt; the scheduler prefills it into a free slot
of the running batch and the decode loop advances every active slot each
step.  Slots free on EOS/max-tokens.  This is the serving analog the decode
shapes lower (one ``decode_step`` for the whole batch).

Single-slot-batch prefill keeps it simple (one prefill jit per prompt
length bucket); production would chunk-prefill — noted in DESIGN.md.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..models import lm
from .steps import make_decode_step

__all__ = ["Request", "ServeLoop"]


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False


class ServeLoop:
    def __init__(self, cfg: ArchConfig, params, batch_slots: int = 4,
                 max_len: int = 256):
        self.cfg = cfg
        self.params = params
        self.B = batch_slots
        self.max_len = max_len
        self.caches = lm.init_caches(cfg, 1, max_len)  # per-slot caches
        self.slot_caches = [lm.init_caches(cfg, 1, max_len)
                            for _ in range(batch_slots)]
        self.slot_req: List[Optional[Request]] = [None] * batch_slots
        self.slot_pos = [0] * batch_slots
        self.slot_last_tok = [0] * batch_slots
        self._decode = jax.jit(make_decode_step(cfg))

    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.slot_req):
            if r is None:
                return i
        return None

    def admit(self, req: Request) -> bool:
        slot = self._free_slot()
        if slot is None:
            return False
        batch = {"inputs": jnp.asarray(req.prompt[None, :], jnp.int32),
                 "targets": jnp.asarray(req.prompt[None, :], jnp.int32)}
        caches = lm.init_caches(self.cfg, 1, self.max_len)
        logits, caches = lm.prefill(self.cfg, self.params, batch, caches)
        tok = int(jnp.argmax(logits[0]))
        req.out_tokens.append(tok)
        self.slot_req[slot] = req
        self.slot_caches[slot] = caches
        self.slot_pos[slot] = len(req.prompt)
        self.slot_last_tok[slot] = tok
        return True

    def step(self) -> int:
        """Advance every active slot one token. Returns #active slots."""
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        for i in active:
            req = self.slot_req[i]
            tok = jnp.asarray([self.slot_last_tok[i]], jnp.int32)
            pos = jnp.asarray(self.slot_pos[i], jnp.int32)
            logits, self.slot_caches[i] = self._decode(
                self.params, self.slot_caches[i], tok, pos)
            nxt = int(jnp.argmax(logits[0]))
            req.out_tokens.append(nxt)
            self.slot_pos[i] += 1
            self.slot_last_tok[i] = nxt
            if (len(req.out_tokens) >= req.max_new_tokens
                    or (req.eos_id is not None and nxt == req.eos_id)
                    or self.slot_pos[i] >= self.max_len - 1):
                req.done = True
                self.slot_req[i] = None
        return len([r for r in self.slot_req if r is not None])

    def run(self, requests: List[Request], max_steps: int = 1000) -> None:
        pending = list(requests)
        steps = 0
        while (pending or any(r is not None for r in self.slot_req)) \
                and steps < max_steps:
            while pending and self._free_slot() is not None:
                self.admit(pending.pop(0))
            self.step()
            steps += 1
