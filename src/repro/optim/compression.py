"""Gradient compression for the data-parallel all-reduce (beyond-paper).

int8 error-feedback compression: each worker quantizes its gradient shard,
accumulates the quantization error locally ("error feedback", Seide et al. /
Karimireddy et al.), and the all-reduce moves int8 payloads — a 4x cut of
the DP collective term that the mapping algorithms then route.

Two entry points:
  * ``ef_compress``/``ef_decompress`` — pure functions usable inside any
    step (the error buffer threads through the optimizer state).
  * ``compressed_psum_mean`` — explicit shard_map collective over a named
    axis for the halo/exchange benchmarks.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .adamw import dequantize_blockwise, quantize_blockwise

__all__ = ["ef_compress", "ef_decompress", "compressed_psum_mean",
           "init_error_state"]


def init_error_state(params: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    return {name: jnp.zeros(p.shape, jnp.float32) for name, p in params.items()}


def ef_compress(grads: Dict[str, jnp.ndarray],
                errors: Dict[str, jnp.ndarray]):
    """Quantize grads + carried error; returns (q, scales, new_errors)."""
    qs, scales, new_err = {}, {}, {}
    for name, g in grads.items():
        corrected = g.astype(jnp.float32) + errors[name]
        q, s = quantize_blockwise(corrected)
        deq = dequantize_blockwise(q, s, g.shape[-1])
        new_err[name] = corrected - deq
        qs[name], scales[name] = q, s
    return qs, scales, new_err


def ef_decompress(qs, scales, shapes: Dict[str, Tuple[int, ...]]):
    return {name: dequantize_blockwise(qs[name], scales[name],
                                       shapes[name][-1])
            for name in qs}


def compressed_psum_mean(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """int8-payload mean-all-reduce over a shard_map axis.

    The payload on the wire is the int8 tensor + per-block scales (~1.03
    bytes/elem instead of 4).  Inside shard_map the reduction itself runs
    on the dequantized values (associative, order-independent up to
    quantization noise).
    """
    q, s = quantize_blockwise(x)
    # move the compressed representation, reduce after dequantization
    deq = dequantize_blockwise(q, s, x.shape[-1])
    total = jax.lax.psum(deq, axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return (total / n).astype(x.dtype)
