"""AdamW with optional int8-quantized moments (block-wise absmax).

Functional optax-style interface, but spec-driven: ``opt_state_specs`` maps
parameter ``ParamSpec``s to optimizer-state ``ParamSpec``s so the dry-run can
produce allocation-free state structs *and* shardings from one source.

Quantized moments (``quantized=True``) store m and v as int8 with per-block
(128-wide, last dim) f32 absmax scales: 1.008 bytes/param per moment instead
of 4 — the difference between DeepSeek-V3's optimizer state fitting on a
v5e pod or not (DESIGN.md §7).  This is a beyond-paper
distributed-optimization feature; §Perf measures its memory effect.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from functools import partial
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..sharding.partition import ParamSpec

__all__ = ["AdamWConfig", "opt_state_specs", "init_opt_state", "adamw_update",
           "global_norm", "clip_by_global_norm", "quantize_blockwise",
           "dequantize_blockwise", "quantize_blockwise_log",
           "dequantize_blockwise_log"]

_BLOCK = 128


# ---------------------------------------------------------------------------
# block-wise int8 quantization
def _pad_to_block(x):
    d = x.shape[-1]
    pad = (-d) % _BLOCK
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x, pad


def quantize_blockwise(x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (..., d) f32 -> (int8 (..., d), scales (..., ceil(d/128)) f32)."""
    orig_d = x.shape[-1]
    xp, pad = _pad_to_block(x.astype(jnp.float32))
    blocks = xp.reshape(xp.shape[:-1] + (-1, _BLOCK))
    scale = jnp.max(jnp.abs(blocks), axis=-1) / 127.0
    safe = jnp.maximum(scale, 1e-12)
    q = jnp.round(blocks / safe[..., None]).astype(jnp.int8)
    q = q.reshape(xp.shape)[..., :orig_d]
    return q, scale


def dequantize_blockwise(q, scale, orig_d: Optional[int] = None):
    orig_d = orig_d or q.shape[-1]
    qp, _ = _pad_to_block(q.astype(jnp.float32))
    blocks = qp.reshape(qp.shape[:-1] + (-1, _BLOCK))
    x = blocks * scale[..., None]
    return x.reshape(qp.shape)[..., :orig_d]


def _scale_shape(shape) -> Tuple[int, ...]:
    return tuple(shape[:-1]) + (max(1, -(-shape[-1] // _BLOCK)),)


# log-domain (dynamic) int8 quantization for the optimizer moments.
# Linear absmax codes have ~50% relative error near zero, enough to flip the
# sign of a small momentum EMA; a logarithmic code (bitsandbytes-style
# "dynamic" quantization) spends its 127 levels on *ratios*, giving a
# uniform <= 10^(DECADES/252) - 1 ~ 3.7% relative error across the block's
# whole dynamic range.  |q| in 1..127 encodes magnitude
# ``absmax * 10**(-DECADES * (127 - |q|) / 126)``; q = 0 encodes values
# below the 10^-DECADES window (and exact zeros).
_LOG_DECADES = 4.0


def quantize_blockwise_log(x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Signed log-domain codes: x (..., d) f32 -> (int8, absmax scales)."""
    orig_d = x.shape[-1]
    xp, _ = _pad_to_block(x.astype(jnp.float32))
    blocks = xp.reshape(xp.shape[:-1] + (-1, _BLOCK))
    scale = jnp.max(jnp.abs(blocks), axis=-1)
    safe = jnp.maximum(scale, 1e-30)
    ratio = jnp.abs(blocks) / safe[..., None]
    level = 127.0 + 126.0 * jnp.log10(jnp.maximum(ratio, 1e-30)) / _LOG_DECADES
    level = jnp.clip(jnp.round(level), 0.0, 127.0)
    q = (jnp.sign(blocks) * level).astype(jnp.int8)
    return q.reshape(xp.shape)[..., :orig_d], scale


def dequantize_blockwise_log(q, scale, orig_d: Optional[int] = None):
    orig_d = orig_d or q.shape[-1]
    qp, _ = _pad_to_block(q.astype(jnp.float32))
    blocks = qp.reshape(qp.shape[:-1] + (-1, _BLOCK))
    level = jnp.abs(blocks)
    mag = 10.0 ** (-_LOG_DECADES * (127.0 - level) / 126.0)
    x = jnp.where(level > 0, jnp.sign(blocks) * mag * scale[..., None], 0.0)
    return x.reshape(qp.shape)[..., :orig_d]


# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    quantized: bool = False
    schedule: str = "warmup_cosine"   # constant | warmup_cosine
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1

    def lr_at(self, step):
        if self.schedule == "constant":
            return jnp.asarray(self.lr, jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(self.warmup_steps, 1), 1.0)
        prog = jnp.clip((step - self.warmup_steps) /
                        jnp.maximum(self.total_steps - self.warmup_steps, 1),
                        0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        scale = self.min_lr_ratio + (1 - self.min_lr_ratio) * cos
        return self.lr * warm * scale


def opt_state_specs(param_specs: Dict[str, ParamSpec], cfg: AdamWConfig
                    ) -> Dict[str, ParamSpec]:
    """State specs mirroring the params (same logical sharding)."""
    out: Dict[str, ParamSpec] = {
        "count": ParamSpec((), jnp.int32, (), init="zeros"),
    }
    for name, s in param_specs.items():
        if cfg.quantized and s.size >= 4096:
            out[f"m_q/{name}"] = ParamSpec(s.shape, jnp.int8, s.logical, "zeros")
            out[f"v_q/{name}"] = ParamSpec(s.shape, jnp.int8, s.logical, "zeros")
            ss = _scale_shape(s.shape)
            slog = tuple(s.logical[:-1]) + (None,)
            out[f"m_s/{name}"] = ParamSpec(ss, jnp.float32, slog, "zeros")
            out[f"v_s/{name}"] = ParamSpec(ss, jnp.float32, slog, "zeros")
        else:
            out[f"m/{name}"] = ParamSpec(s.shape, jnp.float32, s.logical, "zeros")
            out[f"v/{name}"] = ParamSpec(s.shape, jnp.float32, s.logical, "zeros")
    return out


def init_opt_state(param_specs: Dict[str, ParamSpec], cfg: AdamWConfig):
    return {name: jnp.zeros(s.shape, s.dtype)
            for name, s in opt_state_specs(param_specs, cfg).items()}


def global_norm(grads) -> jnp.ndarray:
    leaves = jax.tree.leaves(grads)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in leaves))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    factor = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * factor
                                   ).astype(g.dtype), grads), norm


def adamw_update(params: Dict[str, jnp.ndarray], grads: Dict[str, jnp.ndarray],
                 state: Dict[str, jnp.ndarray], cfg: AdamWConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    if cfg.clip_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)
    count = state["count"] + 1
    lr = cfg.lr_at(count)
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    new_params = dict(params)
    new_state = {"count": count}
    for name, p in params.items():
        g = grads[name].astype(jnp.float32)
        quantized = f"m_q/{name}" in state
        if quantized:
            m = dequantize_blockwise_log(state[f"m_q/{name}"],
                                         state[f"m_s/{name}"], p.shape[-1])
            # v is stored as sqrt(v) in log-domain codes: the log code
            # bounds *relative* error (~3.7% on sqrt, ~7.5% on v) for every
            # magnitude in the block, so small second moments neither
            # collapse to 0 nor distort the m/sqrt(v) ratio.
            v = jnp.square(dequantize_blockwise_log(
                state[f"v_q/{name}"], state[f"v_s/{name}"], p.shape[-1]))
        else:
            m, v = state[f"m/{name}"], state[f"v/{name}"]
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        update = (m / c1) / (jnp.sqrt(v / c2) + cfg.eps)
        if quantized:
            # backstop against residual quantization zeros in v
            # (Adafactor-style per-element update clipping)
            update = jnp.clip(update, -3.0, 3.0)
        if cfg.weight_decay and p.ndim >= 2:
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        new_params[name] = (p.astype(jnp.float32) - lr * update).astype(p.dtype)
        if quantized:
            mq, ms = quantize_blockwise_log(m)
            vq, vs = quantize_blockwise_log(jnp.sqrt(v))
            new_state[f"m_q/{name}"], new_state[f"m_s/{name}"] = mq, ms
            new_state[f"v_q/{name}"], new_state[f"v_s/{name}"] = vq, vs
        else:
            new_state[f"m/{name}"], new_state[f"v/{name}"] = m, v
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics
