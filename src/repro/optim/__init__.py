from .adamw import (AdamWConfig, adamw_update, clip_by_global_norm,
                    dequantize_blockwise, global_norm, init_opt_state,
                    opt_state_specs, quantize_blockwise)
from .compression import (compressed_psum_mean, ef_compress, ef_decompress,
                          init_error_state)

__all__ = ["AdamWConfig", "adamw_update", "clip_by_global_norm",
           "global_norm", "init_opt_state", "opt_state_specs",
           "quantize_blockwise", "dequantize_blockwise",
           "compressed_psum_mean", "ef_compress", "ef_decompress",
           "init_error_state"]
