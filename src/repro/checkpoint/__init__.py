from .io import digest, is_committed, load_arrays, save_arrays
from .manager import CheckpointManager

__all__ = ["digest", "is_committed", "load_arrays", "save_arrays",
           "CheckpointManager"]
