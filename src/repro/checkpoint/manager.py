"""Checkpoint manager: rotation, resume, async save, corruption tolerance."""
from __future__ import annotations

import json
import re
import shutil
import threading
from pathlib import Path
from typing import Dict, Optional

import jax
import numpy as np

from .io import is_committed, load_arrays, save_arrays

__all__ = ["CheckpointManager"]

_STEP_RE = re.compile(r"^step_(\d+)$")


class CheckpointManager:
    def __init__(self, directory, keep: int = 3, async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    # -- discovery -----------------------------------------------------------
    def steps(self):
        out = []
        for p in self.dir.iterdir():
            m = _STEP_RE.match(p.name)
            if m and is_committed(p):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def path(self, step: int) -> Path:
        return self.dir / f"step_{step:08d}"

    # -- save ------------------------------------------------------------------
    def save(self, step: int, state: Dict[str, object],
             meta: Optional[dict] = None, block: bool = False) -> None:
        """state: dict of flat dicts (e.g. {"params": ..., "opt": ...})."""
        self.wait()  # one in-flight save at a time
        flat: Dict[str, np.ndarray] = {}
        for group, tree in state.items():
            for k, v in tree.items():
                flat[f"{group}\t{k}"] = np.asarray(jax.device_get(v))
        info = dict(meta or {})
        info["step"] = step

        def _do():
            save_arrays(self.path(step), flat, meta=info)
            self._rotate()

        if self.async_save and not block:
            self._thread = threading.Thread(target=_do, daemon=True)
            self._thread.start()
        else:
            _do()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _rotate(self):
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.path(s), ignore_errors=True)

    # -- restore ----------------------------------------------------------------
    def restore(self, step: Optional[int] = None, verify: bool = True):
        """Returns (step, {"params": flat, "opt": flat, ...}) or (None, None).
        Silently skips corrupted checkpoints, falling back to older ones."""
        self.wait()
        candidates = self.steps()
        if step is not None:
            candidates = [s for s in candidates if s == step]
        for s in reversed(candidates):
            try:
                flat = load_arrays(self.path(s), verify=verify)
            except Exception:
                continue  # torn/corrupt checkpoint: fall back to older
            state: Dict[str, Dict[str, np.ndarray]] = {}
            for k, v in flat.items():
                group, name = k.split("\t", 1)
                state.setdefault(group, {})[name] = v
            return s, state
        return None, None
