"""Sharded pytree checkpoint I/O.

Layout per step:
    <dir>/step_00000123/
        host0.npz            flat dict of arrays (one file per host shard)
        META.json            step, digest per array, config fingerprint
        COMMIT               empty marker written last (atomic publish)

Flat-dict params (our convention everywhere) make the on-disk format
trivially stable; digests catch torn writes; a checkpoint without COMMIT is
ignored by the manager (crash-consistent).
"""
from __future__ import annotations

import json
import os
import zlib
from pathlib import Path
from typing import Dict, Optional

import numpy as np

__all__ = ["save_arrays", "load_arrays", "digest", "is_committed"]


def digest(arr: np.ndarray) -> str:
    a = np.ascontiguousarray(arr)
    # sample large arrays: header + strided sample is enough to catch
    # truncation/corruption without hashing terabytes
    if a.nbytes > 1 << 22:
        view = a.reshape(-1).view(np.uint8)
        sample = np.concatenate([view[:4096], view[::max(1, len(view) // 4096)]])
        return f"{a.nbytes}:{zlib.crc32(sample.tobytes()):08x}"
    return f"{a.nbytes}:{zlib.crc32(a.tobytes()):08x}"


def save_arrays(path: Path, arrays: Dict[str, np.ndarray], host: int = 0,
                meta: Optional[dict] = None) -> None:
    path = Path(path)
    path.mkdir(parents=True, exist_ok=True)
    safe = {k.replace("/", "|"): np.asarray(v) for k, v in arrays.items()}
    tmp = path / f"host{host}.npz.tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **safe)
    os.replace(tmp, path / f"host{host}.npz")
    info = dict(meta or {})
    info["digests"] = {k: digest(v) for k, v in safe.items()}
    with open(path / "META.json.tmp", "w") as f:
        json.dump(info, f)
    os.replace(path / "META.json.tmp", path / "META.json")
    (path / "COMMIT").touch()


def is_committed(path: Path) -> bool:
    return (Path(path) / "COMMIT").exists()


def load_arrays(path: Path, host: int = 0, verify: bool = True
                ) -> Dict[str, np.ndarray]:
    path = Path(path)
    if not is_committed(path):
        raise FileNotFoundError(f"checkpoint {path} has no COMMIT marker")
    with np.load(path / f"host{host}.npz") as z:
        arrays = {k: z[k] for k in z.files}
    if verify:
        with open(path / "META.json") as f:
            meta = json.load(f)
        for k, v in arrays.items():
            want = meta["digests"].get(k)
            got = digest(v)
            if want is not None and want != got:
                raise IOError(f"digest mismatch for {k}: {want} != {got}")
    return {k.replace("|", "/"): v for k, v in arrays.items()}
