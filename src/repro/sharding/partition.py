"""Logical-axis partitioning (MaxText-style, adapted).

Model code annotates every parameter dimension and key activations with
*logical* axis names ("batch", "fsdp", "tp", "vocab", ...).  A
:class:`Partitioning` maps logical names to mesh axes and produces
``PartitionSpec``s / ``NamedSharding``s.  Two robustness rules:

  * divisibility fallback: a dim whose size is not divisible by the mesh
    axis size is replicated instead (recorded in ``fallbacks``) — this is
    what lets odd head counts (yi-34b's 56 heads) compile on a fixed 16-way
    model axis;
  * outside a mesh context (CPU smoke tests) all constraints are no-ops.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["Partitioning", "ParamSpec", "LOGICAL_DEFAULTS", "shard",
           "current_partitioning", "use_partitioning"]

AxisAssign = Optional[Union[str, Tuple[str, ...]]]

# default logical -> mesh-axis rules for the production meshes
LOGICAL_DEFAULTS: Dict[str, AxisAssign] = {
    "batch": ("pod", "data"),      # activation batch
    "fsdp": ("pod", "data"),       # weight dim sharded ZeRO-style; the pod
                                   # axis drops out automatically on the
                                   # single-pod mesh (spec() filters axes)
    "tp": ("model",),              # tensor-parallel weight dim
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "expert": ("model",),          # expert-parallel axis
    "embed": None,                 # d_model usually replicated in activations
    "seq": None,                   # sequence (context-parallel when set)
    "stage": None,                 # pipeline stage axis (when PP enabled)
    None: None,
}


@dataclass(frozen=True)
class ParamSpec:
    """Allocation-free parameter description (drives init, sharding and the
    dry-run's ShapeDtypeStructs)."""
    shape: Tuple[int, ...]
    dtype: object
    logical: Tuple[Optional[str], ...]
    init: str = "normal"       # normal | zeros | ones | scaled
    init_scale: float = 1.0

    def __post_init__(self):
        if len(self.logical) != len(self.shape):
            raise ValueError(f"logical axes {self.logical} rank != shape {self.shape}")

    @property
    def size(self) -> int:
        return int(math.prod(self.shape))


@dataclass
class Partitioning:
    mesh: Optional[Mesh] = None
    rules: Dict[str, AxisAssign] = field(default_factory=lambda: dict(LOGICAL_DEFAULTS))
    fallbacks: list = field(default_factory=list)

    def _axis_size(self, axes: Tuple[str, ...]) -> int:
        if self.mesh is None:
            return 1
        return int(np.prod([self.mesh.shape[a] for a in axes]))

    def spec(self, logical: Sequence[Optional[str]],
             shape: Optional[Sequence[int]] = None) -> P:
        """PartitionSpec for logical dim names, with divisibility fallback
        and first-come-first-served mesh-axis conflict resolution (a mesh
        axis may appear once per tensor: e.g. MoE weights annotated
        ("expert", "fsdp", "tp") use the model axis for "expert" when the
        expert count divides it — DeepSeek's 256 — and fall through to "tp"
        sharding of d_ff when it doesn't — Mixtral's 8)."""
        out = []
        used: set = set()
        for i, name in enumerate(logical):
            assign = self.rules.get(name, None)
            if assign is None:
                out.append(None)
                continue
            axes = (assign,) if isinstance(assign, str) else tuple(assign)
            # drop axes not present in the mesh (single-pod mesh has no
            # "pod") and axes already consumed by an earlier dim
            if self.mesh is not None:
                axes = tuple(a for a in axes if a in self.mesh.shape)
            axes = tuple(a for a in axes if a not in used)
            if not axes:
                out.append(None)
                continue
            if shape is not None and self.mesh is not None:
                size = self._axis_size(axes)
                if shape[i] % size != 0:
                    self.fallbacks.append((tuple(shape), i, name, axes))
                    out.append(None)
                    continue
            used.update(axes)
            out.append(axes[0] if len(axes) == 1 else axes)
        return P(*out)

    def sharding(self, logical: Sequence[Optional[str]],
                 shape: Optional[Sequence[int]] = None) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(logical, shape))

    def constrain(self, x, *logical: Optional[str]):
        """with_sharding_constraint when a mesh is active, else identity."""
        if self.mesh is None or getattr(self.mesh, "empty", False):
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec(logical, x.shape)))


# ---------------------------------------------------------------------------
# ambient partitioning context (so model code stays framework-free)
_CURRENT: list = [Partitioning(mesh=None)]


def current_partitioning() -> Partitioning:
    return _CURRENT[-1]


class use_partitioning:
    def __init__(self, part: Partitioning):
        self.part = part

    def __enter__(self):
        _CURRENT.append(self.part)
        return self.part

    def __exit__(self, *exc):
        _CURRENT.pop()


def shard(x, *logical: Optional[str]):
    """Constrain activation x to the ambient partitioning (no-op on CPU)."""
    return current_partitioning().constrain(x, *logical)
