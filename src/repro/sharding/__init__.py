from .partition import (LOGICAL_DEFAULTS, ParamSpec, Partitioning,
                        current_partitioning, shard, use_partitioning)

__all__ = ["LOGICAL_DEFAULTS", "ParamSpec", "Partitioning",
           "current_partitioning", "shard", "use_partitioning"]
