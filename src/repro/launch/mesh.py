"""Mesh construction — where the paper's technique becomes a JAX feature.

``make_production_mesh`` builds the assignment's fixed meshes; the
topology-aware variant ``make_mapped_mesh`` permutes the device ndarray with
one of the paper's mapping algorithms so that logical mesh coordinates that
exchange the most bytes land on the same pod / adjacent ICI links (the
``MPI_Cart_create(reorder=1)`` analog, DESIGN.md §2).

``stencil_for_plan`` derives the byte-weighted communication stencil of a
training/serving step from the architecture + parallelism plan:
  * data axis  — FSDP param all-gather + grad reduce-scatter: ring traffic =
    periodic ±1 stencil along "data" (and "pod" when the batch spans pods);
  * model axis — TP activation collectives + (MoE) expert all-to-all:
    periodic ±1 along "model", weight = per-step bytes.
All functions are allocation-free (a Mesh of ShapeDtypeStruct-only usage).
"""
from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

from ..configs.base import ArchConfig, ShapeSpec
from ..core import Stencil, mapped_device_array
from ..core.remap import apply_layout, repair_layout
from ..topology.machine import MachineSpec, V5E_2POD, V5E_POD

__all__ = ["make_production_mesh", "make_mapped_mesh", "repair_mapped_mesh",
           "stencil_for_plan", "machine_for", "mesh_axes"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def mesh_axes(multi_pod: bool) -> Tuple[str, ...]:
    return ("pod", "data", "model") if multi_pod else ("data", "model")


def machine_for(multi_pod: bool) -> MachineSpec:
    return V5E_2POD if multi_pod else V5E_POD


def stencil_for_plan(cfg: ArchConfig, shape: ShapeSpec,
                     multi_pod: bool = False) -> Stencil:
    """Byte-weighted ring stencil over the mesh grid for this (arch, shape)."""
    axes = mesh_axes(multi_pod)
    d = len(axes)
    param_bytes = cfg.param_count() * 2  # bf16
    if shape.kind == "train":
        dp_bytes = 3.0 * param_bytes       # fsdp all-gather + reduce-scatter
    else:
        dp_bytes = 0.25 * param_bytes      # weight gathers only
    act = shape.global_batch * min(shape.seq_len, 8192) * cfg.d_model * 2
    tp_bytes = 2.0 * cfg.n_layers * act    # per-layer activation collectives
    if cfg.n_experts:
        tp_bytes += 2.0 * cfg.n_layers * act * min(cfg.top_k, 4)  # EP a2a

    offsets, weights = [], []
    for ax_i, ax in enumerate(axes):
        w = dp_bytes if ax in ("pod", "data") else tp_bytes
        if w <= 0:
            continue
        for s in (+1, -1):
            v = [0] * d
            v[ax_i] = s
            offsets.append(tuple(v))
            weights.append(w)
    return Stencil(tuple(offsets), tuple(weights), name=f"plan-{cfg.name}")


def make_mapped_mesh(mapper_name: str, *, multi_pod: bool = False,
                     cfg: Optional[ArchConfig] = None,
                     shape: Optional[ShapeSpec] = None,
                     stencil: Optional[Stencil] = None,
                     devices: Optional[Sequence] = None,
                     node_sizes: Optional[Sequence[int]] = None,
                     auto_refine: bool = True,
                     mesh_shape: Optional[Sequence[int]] = None,
                     axes: Optional[Sequence[str]] = None,
                     chips_per_pod: Optional[int] = None,
                     cache=None) -> Mesh:
    """Production mesh with a paper-algorithm device permutation.

    ``node_sizes`` describes the surviving chips per pod for elastic
    operation (a pod that lost chips); with ``auto_refine`` (default) any
    ragged layout gets the mapper's multi-start annealing-portfolio upgrade
    (``portfolio:``) at mesh construction time, so degraded pods keep a
    good J_max without callers opting in via a prefixed name.

    ``mesh_shape`` / ``axes`` / ``chips_per_pod`` override the production
    defaults — the elastic path uses this to re-mesh onto an arbitrary
    survivor count (and tests to dry-run the whole flow on a handful of
    fake host devices).  ``mapper_name`` accepts every registry spelling,
    including bracket options (``"portfolio[k=8]:hyperplane"``) and
    chained prefixes (any :func:`~repro.core.plan.parse_plan` grammar).

    Solved layouts are served from the plan cache (``cache``: None ->
    process default, False -> off, or a
    :class:`~repro.core.plan.PlanCache`), so a repeated build of the same
    problem signature — elastic re-mesh onto the same survivors, serving
    restart, dry-run sweep cell — skips the mapper+refinement pipeline
    entirely.
    """
    if mesh_shape is None:
        mesh_shape = (2, 16, 16) if multi_pod else (16, 16)
        if axes is None:
            axes = mesh_axes(multi_pod)
    else:
        mesh_shape = tuple(int(x) for x in mesh_shape)
        if axes is None:
            if len(mesh_shape) not in (2, 3):
                raise ValueError("custom mesh_shape of rank "
                                 f"{len(mesh_shape)} needs explicit axes")
            axes = mesh_axes(multi_pod=len(mesh_shape) == 3)
        if node_sizes is None and chips_per_pod is None:
            # the production chips_per_pod (256) is meaningless for an
            # arbitrary shape and would silently collapse everything onto
            # one "node" — force the caller to say how pods are sized.
            raise ValueError("custom mesh_shape needs node_sizes or "
                             "chips_per_pod")
    if len(axes) != len(mesh_shape):
        raise ValueError(f"{len(axes)} axes for rank-{len(mesh_shape)} mesh")
    machine = machine_for(multi_pod)
    if chips_per_pod is None:
        chips_per_pod = machine.chips_per_pod
    if stencil is None:
        if cfg is None or shape is None:
            stencil = Stencil.nearest_neighbor(len(mesh_shape))
        else:
            stencil = stencil_for_plan(cfg, shape, multi_pod)
    devs = list(devices) if devices is not None else list(jax.devices())
    if len(devs) != math.prod(mesh_shape):
        raise ValueError(f"need {math.prod(mesh_shape)} devices, "
                         f"have {len(devs)} (dry-run sets XLA_FLAGS)")
    arr = mapped_device_array(devs, mapper_name, mesh_shape,
                              stencil, chips_per_pod,
                              node_sizes=node_sizes, auto_refine=auto_refine,
                              cache=cache)
    return Mesh(arr, tuple(axes))


def repair_mapped_mesh(previous, node_sizes: Sequence[int], *,
                       devices: Sequence,
                       mesh_shape: Optional[Sequence[int]] = None,
                       axes: Optional[Sequence[str]] = None,
                       stencil: Optional[Stencil] = None,
                       node_map: Optional[Sequence[Optional[int]]] = None,
                       cache=None, **repair_options):
    """Re-mesh after churn by *repairing* the previous solution instead of
    cold-solving (:func:`~repro.core.remap.repair_layout`): the survivors
    keep their positions, orphaned coordinates are re-homed to adjacent
    pods, and only the churn-affected pods are annealed.

    ``previous`` is the pre-churn
    :class:`~repro.core.plan.MappingSolution` (or ``CartResult``);
    ``node_sizes`` the surviving chips per pod (use
    :meth:`~repro.runtime.fault.SimulatedFault.survivors` /
    ``survivor_map`` to spell both after an injected fault);
    ``devices`` the surviving devices in pod-major order.  ``mesh_shape``
    defaults to the previous solution's shape when the survivor total
    still matches; a loss that shrinks the device count passes the new
    shape and repair transfers the assignment geometrically.

    Returns ``(Mesh, MappingSolution)`` — the solution is what the *next*
    repair warm-starts from, and it is cached under the survivor
    signature (pre-churn cache entries stay intact).
    """
    sol = repair_layout(previous, node_sizes, mesh_shape=mesh_shape,
                        stencil=stencil, node_map=node_map, cache=cache,
                        **repair_options)
    layout = sol.layout()
    if axes is None:
        if layout.ndim == 2:
            axes = ("data", "model")
        elif layout.ndim == 3:
            axes = ("pod", "data", "model")
        else:
            raise ValueError(f"pass axes for a rank-{layout.ndim} mesh")
    if len(axes) != layout.ndim:
        raise ValueError(f"{len(axes)} axes for rank-{layout.ndim} mesh")
    return Mesh(apply_layout(list(devices), layout), tuple(axes)), sol
