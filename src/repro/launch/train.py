"""Training launcher.

On this CPU container it runs reduced configs end-to-end (fault-tolerant
loop, checkpoints, data pipeline); on a real fleet the same driver runs the
full config — device placement flows through ``make_mapped_mesh`` and the
partitioning layer, nothing else changes.

  PYTHONPATH=src python -m repro.launch.train --arch granite-3-8b-reduced \
      --steps 100 --batch 8 --seq 64 --ckpt-dir runs/ckpt
"""
from __future__ import annotations

import argparse
import json

from repro.configs import get_arch
from repro.configs.base import ShapeSpec
from repro.data.synthetic import DataConfig
from repro.optim.adamw import AdamWConfig
from repro.runtime.fault import FaultInjector
from repro.runtime.train_loop import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--data", default="memorize", choices=["memorize", "lm_stream"])
    ap.add_argument("--quantized-opt", action="store_true")
    ap.add_argument("--moe-dispatch", default="einsum",
                    choices=["einsum", "scatter"])
    ap.add_argument("--inject-fault", default="",
                    help='e.g. "17:step_crash,25:node_loss:1"')
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    shape = ShapeSpec("cli", seq_len=args.seq, global_batch=args.batch,
                      kind="train")
    schedule = {}
    if args.inject_fault:
        for item in args.inject_fault.split(","):
            step, kind = item.split(":", 1)
            schedule[int(step)] = kind
    trainer = Trainer(
        cfg, shape,
        opt_cfg=AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                            total_steps=args.steps,
                            quantized=args.quantized_opt or cfg.quantized_opt_state),
        data_cfg=DataConfig(mode=args.data),
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        fault=FaultInjector(schedule=schedule), seed=args.seed,
        moe_dispatch=args.moe_dispatch)
    res = trainer.run(args.steps)
    print(json.dumps({
        "arch": cfg.name, "steps": res.steps_done,
        "loss_first": res.losses[0] if res.losses else None,
        "loss_last": res.final_loss, "restarts": res.restarts,
        "remaps": res.remaps,
        "straggler_events": len(res.straggler_events)}, indent=1))


if __name__ == "__main__":
    main()
