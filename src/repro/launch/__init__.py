from .input_specs import CellSpec, build_cell
from .mesh import (machine_for, make_mapped_mesh, make_production_mesh,
                   mesh_axes, stencil_for_plan)

__all__ = ["CellSpec", "build_cell", "machine_for", "make_mapped_mesh",
           "make_production_mesh", "mesh_axes", "stencil_for_plan"]
