import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST run before any jax import: the dry-run (and only the dry-run) needs
# 512 placeholder host devices for the production meshes.
"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the 16x16 single-pod mesh and the 2x16x16 multi-pod mesh; record memory,
cost, collective and roofline analysis (EXPERIMENTS.md §Dry-run/§Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out runs/dryrun \
      [--mappers blocked,hyperplane,portfolio[k=8]:hyperplane]

``--mappers`` accepts every ``parse_plan`` spelling (refinement prefixes,
bracket options, chained prefixes); each cell records per-mapper linksim
traffic plus DCI deltas against the blocked baseline.
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import numpy as np

from repro.analysis.hlo import parse_hlo
from repro.analysis.linksim import simulate
from repro.analysis.roofline import roofline_from_module
from repro.configs import ARCHS, SHAPES, get_arch, shape_applicable
from repro.core import Stencil, device_layout
from repro.launch.input_specs import build_cell
from repro.launch.mesh import (machine_for, make_mapped_mesh,
                               make_production_mesh, stencil_for_plan)
from repro.optim.adamw import AdamWConfig
from repro.sharding.partition import use_partitioning


def _split_order(mname: str):
    """``"hyperplane+rm" -> ("hyperplane", "rm")``: only the trailing
    ``+rm`` suffix selects intra-pod order — a ``+`` anywhere else (e.g. a
    signed bracket-option value, ``annealed[t0=+1e-2]:``) is part of the
    mapper spelling."""
    if mname.endswith("+rm"):
        return mname[:-3], "rm"
    return mname, ""


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             mappers=("blocked", "stencil_strips"), out_dir=None,
             moe_dispatch: str = "einsum", overrides=None, part_rules=None,
             verbose=True):
    cfg = get_arch(arch_name)
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch_name, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": why}
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    machine = machine_for(multi_pod)
    cell = build_cell(cfg, shape, mesh, moe_dispatch=moe_dispatch)
    if part_rules:
        cell.partitioning.rules.update(part_rules)
    with mesh, use_partitioning(cell.partitioning):
        jf = jax.jit(cell.step_fn, in_shardings=cell.in_shardings,
                     out_shardings=cell.out_shardings)
        lowered = jf.lower(*cell.args)
        compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    try:
        ca = compiled.cost_analysis() or {}
    except Exception:
        ca = {}
    hlo_text = compiled.as_text()
    module = parse_hlo(hlo_text)
    chips = int(np.prod(mesh.devices.shape))
    rep = roofline_from_module(
        module, arch=arch_name, shape=shape_name,
        mesh="multi" if multi_pod else "single", chips=chips,
        machine=machine, model_flops_global=cell.model_flops,
        model_flops_full=cell.model_flops_full,
        memory_stats=mem, cost_analysis=ca)

    # topology decomposition: play the collectives on physical links for
    # each candidate device layout (paper metric: DCI bytes ~ J_sum/J_max).
    # Mapper names accept the full parse_plan grammar (chained prefixes,
    # bracket options, e.g. "portfolio[k=8]:hyperplane"); solved layouts
    # come from the plan cache, so sweeping many (arch, shape) cells
    # re-solves each distinct (stencil, mapper) pair only once.
    colls = module.collectives()
    link_reports = {}
    plan_stencil = stencil_for_plan(cfg, shape, multi_pod)
    for mname in mappers:
        base, order = _split_order(mname)
        layout = device_layout(base, mesh.devices.shape,
                               plan_stencil, machine.node_sizes(),
                               intra_order="rowmajor" if order == "rm"
                               else "mapper")
        r = simulate(colls, layout.reshape(-1), machine)
        link_reports[mname] = {**r.summary(), **r.times(machine)}
    # per-mapper DCI deltas against the blocked baseline (first mapper when
    # blocked isn't in the sweep): negative = the mapping saves DCI bytes.
    base_name = next((m for m in link_reports
                      if _split_order(m)[0] == "blocked"),
                     next(iter(link_reports), None))
    if base_name is not None:
        ref = link_reports[base_name]
        for rep in link_reports.values():
            rep["dci_total_delta"] = (rep["dci_total_bytes"]
                                      - ref["dci_total_bytes"])
            rep["dci_max_delta"] = (rep["max_dci_pod_bytes"]
                                    - ref["max_dci_pod_bytes"])

    n_coll = {}
    coll_by_op = {}
    for c in colls:
        n_coll[c.opcode] = n_coll.get(c.opcode, 0) + 1
        coll_by_op[c.opcode] = coll_by_op.get(c.opcode, 0.0) + \
            c.wire_bytes_per_device()
    result = {
        "arch": arch_name, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single", "status": "ok",
        "chips": chips, "compile_s": round(t_compile, 2),
        "kind": cell.kind,
        "memory": {
            "argument_gib": mem.argument_size_in_bytes / 2**30,
            "temp_gib": mem.temp_size_in_bytes / 2**30,
            "output_gib": mem.output_size_in_bytes / 2**30,
            "alias_gib": mem.alias_size_in_bytes / 2**30,
            "fits_16gib": rep.fits_hbm,
        },
        "roofline": rep.row(),
        "collectives": n_coll,
        "coll_wire_by_op": coll_by_op,
        "coll_payload_bytes_per_dev": rep.coll_payload_bytes,
        "coll_wire_bytes_per_dev": rep.coll_wire_bytes,
        "linksim": link_reports,
        "linksim_baseline": base_name,
        "fallbacks": [str(f) for f in cell.partitioning.fallbacks[:8]],
    }
    if out_dir:
        out = Path(out_dir)
        out.mkdir(parents=True, exist_ok=True)
        fname = f"{arch_name}_{shape_name}_{'multi' if multi_pod else 'single'}.json"
        (out / fname).write_text(json.dumps(result, indent=1, default=float))
    if verbose:
        r = result["roofline"]
        print(f"[{result['mesh']:6s}] {arch_name:22s} {shape_name:12s} "
              f"compile={t_compile:6.1f}s dom={r['dominant']:10s} "
              f"tc={r['t_compute_s']:.3e} tm={r['t_memory_s']:.3e} "
              f"tx={r['t_collective_s']:.3e} useful={r['useful_ratio']:.2f} "
              f"arg/dev={result['memory']['argument_gib']:.2f}GiB "
              f"temp/dev={result['memory']['temp_gib']:.2f}GiB", flush=True)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="runs/dryrun")
    ap.add_argument("--mappers",
                    default="blocked,stencil_strips,hyperplane,kdtree,"
                            "portfolio:hyperplane",
                    help="comma list; any parse_plan spelling works "
                         "(portfolio[k=8]:hyperplane, "
                         "sharded[shards=4,k=64,restarts=auto]:hyperplane, "
                         "chained prefixes, +rm for rowmajor intra-pod "
                         "order)")
    ap.add_argument("--moe-dispatch", default="einsum",
                    choices=["einsum", "scatter"])
    args = ap.parse_args()

    archs = list(ARCHS) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    from repro.core import parse_plan
    from repro.core.mapping import split_mapper_list
    mappers = split_mapper_list(args.mappers)
    for m in mappers:                     # fail fast on typos, full spelling
        parse_plan(_split_order(m)[0])

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    results.append(run_cell(arch, shape, mp, mappers=mappers,
                                            out_dir=args.out,
                                            moe_dispatch=args.moe_dispatch))
                except Exception as e:
                    traceback.print_exc()
                    results.append({"arch": arch, "shape": shape,
                                    "mesh": "multi" if mp else "single",
                                    "status": "error", "error": repr(e)})
                    print(f"ERROR {arch} {shape} multi={mp}: {e!r}", flush=True)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\ndry-run complete: {n_ok} ok, {n_skip} skipped (documented), "
          f"{n_err} errors")
    Path(args.out).mkdir(parents=True, exist_ok=True)
    (Path(args.out) / "summary.json").write_text(
        json.dumps(results, indent=1, default=float))
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
