"""Allocation-free input specs + shardings for every (arch × shape) cell.

``build_cell`` returns everything the dry-run needs to lower a cell:
the step function, ShapeDtypeStruct arguments, and in/out shardings —
without allocating a single device buffer (the assignment's requirement:
full configs exist only as ShapeDtypeStructs).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig, ShapeSpec
from ..data.synthetic import batch_spec
from ..models import lm
from ..models.common import param_shardings, param_structs
from ..optim.adamw import AdamWConfig, opt_state_specs
from ..runtime.steps import make_decode_step, make_prefill_step, make_train_step
from ..sharding.partition import Partitioning, use_partitioning

__all__ = ["CellSpec", "build_cell", "batch_shardings", "cache_shardings"]


@dataclass
class CellSpec:
    arch: str
    shape: str
    kind: str
    step_fn: Callable
    args: Tuple
    in_shardings: Tuple
    out_shardings: Any
    partitioning: Partitioning
    model_flops: float
    model_flops_full: float = 0.0
    donate_argnums: Tuple[int, ...] = ()


def batch_shardings(cfg: ArchConfig, shape: ShapeSpec, part: Partitioning
                    ) -> Dict[str, NamedSharding]:
    out = {}
    for name, (shp, _) in batch_spec(cfg, shape).items():
        logical = ("batch",) + (None,) * (len(shp) - 1)
        out[name] = part.sharding(logical, shp)
    return out


def batch_structs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    return {name: jax.ShapeDtypeStruct(shp, dt)
            for name, (shp, dt) in batch_spec(cfg, shape).items()}


def cache_shardings(cfg: ArchConfig, caches_struct, shape: ShapeSpec,
                    max_len: int, part: Partitioning):
    """Value-matched specs: dims equal to the global batch shard over
    ("pod","data"); dims equal to the KV allocation length (max_len, or the
    SWA window for ring caches) shard over "model" (flash-decoding style
    length sharding)."""
    B = shape.global_batch
    kv_lens = {max_len}
    if cfg.swa_ring_cache and cfg.sliding_window:
        kv_lens.add(min(max_len, cfg.sliding_window))

    def leaf_spec(leaf):
        logical = []
        seen_batch = False
        for dim in leaf.shape:
            if dim == B and not seen_batch:
                logical.append("batch")
                seen_batch = True
            elif dim in kv_lens:
                logical.append("seq_kv")
            else:
                logical.append(None)
        return part.sharding(tuple(logical), leaf.shape)

    return jax.tree.map(leaf_spec, caches_struct)


def _partitioning(mesh: Mesh) -> Partitioning:
    part = Partitioning(mesh=mesh)
    # KV-length sharding rule used by the decode cells
    part.rules["seq_kv"] = ("model",)
    return part


def build_cell(cfg: ArchConfig, shape: ShapeSpec, mesh: Mesh,
               opt_cfg: Optional[AdamWConfig] = None,
               moe_dispatch: str = "einsum") -> CellSpec:
    import dataclasses
    part = _partitioning(mesh)
    opt_cfg = opt_cfg or AdamWConfig(quantized=cfg.quantized_opt_state)
    # each microbatch must still cover every batch shard: cap microbatches
    # at global_batch / n_batch_shards (internvl's mb=16 on the 32-wide
    # multi-pod batch axis would otherwise leave shards empty -> replication)
    bshards = 1
    for ax in ("pod", "data"):
        bshards *= mesh.shape.get(ax, 1)
    eff_mb = max(1, min(cfg.microbatches, shape.global_batch // max(bshards, 1)))
    if eff_mb != cfg.microbatches:
        cfg = dataclasses.replace(cfg, microbatches=eff_mb)
    specs = lm.param_specs(cfg)
    with use_partitioning(part):
        p_structs = param_structs(specs)
        p_shard = param_shardings(specs, part)

        if shape.kind == "train":
            o_specs = opt_state_specs(specs, opt_cfg)
            o_structs = param_structs(o_specs)
            o_shard = param_shardings(o_specs, part)
            b_structs = batch_structs(cfg, shape)
            b_shard = batch_shardings(cfg, shape, part)
            step = make_train_step(cfg, opt_cfg, moe_dispatch=moe_dispatch)
            return CellSpec(
                arch=cfg.name, shape=shape.name, kind="train",
                step_fn=step,
                args=(p_structs, o_structs, b_structs),
                in_shardings=(p_shard, o_shard, b_shard),
                out_shardings=(p_shard, o_shard, None),
                partitioning=part,
                model_flops=cfg.model_flops(shape),
                model_flops_full=cfg.model_flops(shape) + cfg.attn_flops(shape),
                donate_argnums=(0, 1))

        B = shape.global_batch
        # VLM prepends patch embeddings: the cache must hold them too
        max_len = shape.seq_len + cfg.num_patches
        if shape.kind == "prefill":
            caches_struct = jax.eval_shape(
                lambda: lm.init_caches(cfg, B, max_len))
            c_shard = cache_shardings(cfg, caches_struct, shape, max_len, part)
            # prompt occupies the sequence; batch of prompts
            b_structs = batch_structs(cfg, shape)
            b_shard = batch_shardings(cfg, shape, part)
            step = make_prefill_step(cfg, moe_dispatch=moe_dispatch)
            return CellSpec(
                arch=cfg.name, shape=shape.name, kind="prefill",
                step_fn=step,
                args=(p_structs, b_structs, caches_struct),
                in_shardings=(p_shard, b_shard, c_shard),
                out_shardings=None,
                partitioning=part,
                model_flops=cfg.model_flops(shape),
                model_flops_full=cfg.model_flops(shape) + cfg.attn_flops(shape),
                donate_argnums=(2,))

        # decode: one new token against a seq_len KV cache
        caches_struct = jax.eval_shape(lambda: lm.init_caches(cfg, B, max_len))
        c_shard = cache_shardings(cfg, caches_struct, shape, max_len, part)
        tok_struct = jax.ShapeDtypeStruct((B,), np.int32)
        tok_shard = part.sharding(("batch",), (B,))
        pos_struct = jax.ShapeDtypeStruct((), np.int32)
        pos_shard = part.sharding((), ())
        step = make_decode_step(cfg, moe_dispatch=moe_dispatch)
        return CellSpec(
            arch=cfg.name, shape=shape.name, kind="decode",
            step_fn=step,
            args=(p_structs, caches_struct, tok_struct, pos_struct),
            in_shardings=(p_shard, c_shard, tok_shard, pos_shard),
            out_shardings=(None, c_shard),
            partitioning=part,
            model_flops=cfg.model_flops(shape),
            model_flops_full=cfg.model_flops(shape) + cfg.attn_flops(shape),
            donate_argnums=(1,))
