"""Serving launcher: batched continuous-batching decode of a (reduced) model.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b-reduced \
      --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.models import lm
from repro.runtime.serve_loop import Request, ServeLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    params = lm.init(cfg, jax.random.PRNGKey(args.seed))
    loop = ServeLoop(cfg, params, batch_slots=args.slots,
                     max_len=args.max_len)
    rng = np.random.default_rng(args.seed)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, size=8 + i % 5,
                                        dtype=np.int32),
                    max_new_tokens=args.max_new)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    loop.run(reqs)
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in reqs)
    print(json.dumps({
        "arch": cfg.name, "requests": len(reqs),
        "completed": sum(r.done for r in reqs),
        "tokens": toks, "wall_s": round(dt, 3),
        "tok_per_s": round(toks / dt, 2)}, indent=1))


if __name__ == "__main__":
    main()
