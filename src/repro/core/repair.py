"""Warm-start incremental plan repair for elastic re-meshes (churn path).

At fleet scale node churn is the steady state — stragglers, spot
preemption, elastic scale-up/down — and a full cold re-solve through the
mapping pipeline on every event is the latency floor the runtime pays to
recover quality.  *Better Process Mapping and Sparse Quadratic Assignment*
(Schulz & Träff 2017) shows local search from a good initial assignment
dominates solving from scratch; this module is that observation applied to
the plan layer: instead of re-running base mapper + deterministic rounds +
annealing portfolio + polish on the post-churn problem, **seed** the search
from the previous solution restricted to the survivors and only repair what
churn actually touched.

The repair pipeline (:func:`repair_seed` + :class:`RepairStage`):

1. **transfer** — every position of the (possibly re-shaped) post-churn
   grid inherits the node its geometric pre-image held in the previous
   assignment (identity when the mesh shape is unchanged), translated
   through ``node_map`` (new node index -> old node index; ``-1`` marks a
   node that did not exist before churn);
2. **restrict** — positions whose node died are *orphans*; surviving nodes
   over their new capacity orphan their boundary-most positions (fewest
   same-node stencil neighbours) first;
3. **re-home** — orphans are greedily adopted by adjacent surviving nodes
   with free capacity (majority vote over stencil neighbours, repeated to a
   fixed point), remaining capacity is filled row-major — the result is a
   valid assignment (``bincount == node_sizes``) by construction;
4. **pinned anneal** — nodes untouched by churn (capacity unchanged, no
   position moved) are *pinned*: the K-ladder annealing portfolio
   (:class:`~repro.core.refine.PortfolioRefiner` with ``pinned=``) proposes
   swaps only among the affected nodes' positions, skipping the
   deterministic rounds and polish a cold solve pays for.

:class:`RepairStage` packages 1–4 as a first-class plan stage whose
``spec()`` hashes the previous assignment, so repaired solutions are
cached by :class:`~repro.core.plan.PlanCache` under the post-churn problem
signature (survivor node sizes) without ever colliding with — or
invalidating — the pre-churn entries.  The entry points callers use are
:func:`~repro.core.remap.repair_layout` (solution-level) and
:func:`~repro.launch.mesh.repair_mapped_mesh` (jax Mesh-level);
``parse_plan("repair:hyperplane", previous=sol)`` spells the same stage in
the plan grammar (the base after the colon is the cold fallback when the
previous solution is unusable).

Claim this module pins (tests/test_repair.py, BENCH_6.json): repair reaches
within epsilon of the cold elastic solve's (J_max, J_sum) at a small
fraction of its wall-time across node-loss, node-add, and slow-pod
(down-weighted capacity) scenarios.
"""
from __future__ import annotations

import hashlib
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .cost import evaluate
from .cost_delta import IncrementalCost, NeighborTable
from .grid import CartGrid
from .stencil import Stencil
from .refine.stage import BaseStage, Stage, StageResult, canon_options

__all__ = ["RepairInapplicable", "RepairSeed", "repair_seed",
           "transfer_positions", "RepairStage", "repair_plan",
           "downweighted_node_sizes", "absorbed_node_sizes"]


class RepairInapplicable(ValueError):
    """The previous solution cannot seed this problem (dimensionality
    mismatch, unmappable node sets, ...) — callers fall back to a cold
    solve."""


# ---------------------------------------------------------------------------
# churn arithmetic helpers (who gets the lost/slow node's share)


def absorbed_node_sizes(node_sizes: Sequence[int], lost: int) -> List[int]:
    """Node ``lost``'s processes absorbed by the survivors (fixed process
    grid, the paper's heterogeneous-n_i setting): its capacity is spread
    round-robin over the remaining nodes, largest-capacity first so the
    relative imbalance stays minimal.  Returns the survivor sizes (length
    ``len(node_sizes) - 1``; pair with ``node_map`` = the surviving old
    indices in order)."""
    sizes = [int(s) for s in node_sizes]
    if not 0 <= lost < len(sizes):
        raise ValueError(f"lost node {lost} out of range for {len(sizes)} "
                         "nodes")
    if len(sizes) < 2:
        raise ValueError("cannot absorb the only node")
    share = sizes.pop(lost)
    order = sorted(range(len(sizes)), key=lambda i: (-sizes[i], i))
    for j in range(share):
        sizes[order[j % len(sizes)]] += 1
    return sizes


def downweighted_node_sizes(node_sizes: Sequence[int], slow: int,
                            factor: float) -> List[int]:
    """Slow-but-alive pod as a weighted-node re-solve: node ``slow`` keeps
    ``round(size / factor)`` of its processes (at least 1) and the freed
    share is absorbed round-robin by the healthy nodes — same total, same
    process grid, so the repaired plan can be compared like-for-like with a
    cold solve of the down-weighted problem."""
    sizes = [int(s) for s in node_sizes]
    if not 0 <= slow < len(sizes):
        raise ValueError(f"slow node {slow} out of range for {len(sizes)} "
                         "nodes")
    if factor < 1.0:
        raise ValueError("slowdown factor must be >= 1.0")
    if len(sizes) < 2:
        return sizes
    keep = max(1, int(round(sizes[slow] / float(factor))))
    freed = sizes[slow] - keep
    sizes[slow] = keep
    order = sorted((i for i in range(len(sizes)) if i != slow),
                   key=lambda i: (-sizes[i], i))
    for j in range(freed):
        sizes[order[j % len(order)]] += 1
    return sizes


# ---------------------------------------------------------------------------
# seed construction


def transfer_positions(grid: CartGrid,
                       prev_shape: Sequence[int]) -> np.ndarray:
    """For every position of ``grid`` (the post-churn mesh), the position of
    the pre-churn ``prev_shape`` grid whose normalized coordinate is its
    geometric pre-image (identity when the shapes match).  This is what
    lets repair survive a mesh-shape change (a pod loss shrinks the device
    count, so the re-mesh rarely keeps the exact shape)."""
    prev_shape = tuple(int(d) for d in prev_shape)
    if len(prev_shape) != grid.ndim:
        raise RepairInapplicable(
            f"previous mesh rank {len(prev_shape)} != new rank {grid.ndim}")
    if prev_shape == grid.dims:
        return np.arange(grid.size, dtype=np.int64)
    old = np.asarray(prev_shape, dtype=np.int64)
    new = np.asarray(grid.dims, dtype=np.int64)
    # cell-centred rescale, clipped: old_i = floor((c + .5) * old / new)
    oc = ((grid.coords() * 2 + 1) * old) // (2 * new)
    oc = np.clip(oc, 0, old - 1)
    return np.ravel_multi_index(tuple(oc.T), prev_shape).astype(np.int64)


@dataclass
class RepairSeed:
    """A repaired starting assignment plus everything the pinned anneal and
    the caller's invariants need: which positions moved, which nodes churn
    touched, and which positions are therefore pinned."""

    assignment: np.ndarray        # (p,) valid: bincount == new node_sizes
    desire: np.ndarray            # (p,) transferred pre-churn node (-1 dead)
    moved: np.ndarray             # (p,) bool: ended away from pre-churn home
    affected_nodes: np.ndarray    # new node ids churn touched, ascending
    pinned: np.ndarray            # (p,) bool: safe to exclude from search
    orphans: int                  # positions whose node died / was evicted
    rehomed_adjacent: int         # orphans adopted by a stencil neighbour


def _same_node_score(table: NeighborTable, desire: np.ndarray) -> np.ndarray:
    """Per position: how many stencil edges (either direction) connect it to
    a position desiring the same (live) node — the inverse of boundary-ness,
    used to pick which positions an over-capacity node orphans first."""
    score = np.zeros(desire.shape[0], dtype=np.int64)
    for j in range(table.out_valid.shape[0]):
        valid, tgt = table.out_valid[j], table.out_tgt[j]
        same = valid & (desire >= 0) & (desire == desire[tgt])
        score += same
        np.add.at(score, tgt[same], 1)
    return score


def _grow_region(table: NeighborTable, seed: np.ndarray, score: np.ndarray,
                 over: np.ndarray, locked: np.ndarray, node: int,
                 capacity: int) -> None:
    """Claim a connected region of ``capacity`` positions for a newly added
    ``node``.  Preference order: orphaned (dead-node) cells, then cells of
    *over-capacity* donors (``over``: per-node desired-minus-capacity —
    stealing those is free, the donor must shed them anyway; this also
    lands the region exactly where a mesh-growth transfer duplicated
    cells), then boundary-most cells.  Mutates ``seed`` (claimed positions
    -> ``node``), ``over`` (stolen cells shed the donor's excess) and
    ``locked`` (claimed positions are off-limits to later growth and
    eviction)."""
    p = seed.shape[0]
    avail = ~locked

    def pressure(cells: np.ndarray) -> np.ndarray:
        # 1 = free to steal: orphaned cell, or donor still over capacity
        s = seed[cells]
        return np.where(s < 0, 1, (over[np.clip(s, 0, None)] > 0)
                        .astype(np.int64))

    cand = np.nonzero(avail)[0]
    if cand.size == 0:
        return
    order = np.lexsort((cand, score[cand], -pressure(cand)))
    start = int(cand[order[0]])
    in_region = np.zeros(p, dtype=bool)
    adj = np.zeros(p, dtype=np.int64)    # stencil edges into the region

    def take(pos: int) -> None:
        in_region[pos] = True
        if seed[pos] >= 0:
            over[seed[pos]] -= 1
        out = table.out_tgt[table.out_valid[:, pos], pos]
        inc = table.in_src[table.in_valid[:, pos], pos]
        np.add.at(adj, np.concatenate([out, inc]), 1)

    take(start)
    while int(in_region.sum()) < capacity:
        cand = np.nonzero(avail & ~in_region & (adj > 0))[0]
        if cand.size == 0:               # disconnected leftovers
            cand = np.nonzero(avail & ~in_region)[0]
            if cand.size == 0:
                break
        # free-to-steal first, then most-attached, then boundary-most
        order = np.lexsort((cand, score[cand], -adj[cand], -pressure(cand)))
        take(int(cand[order[0]]))
    seed[in_region] = node
    locked[in_region] = True


def repair_seed(grid: CartGrid, stencil: Stencil,
                prev_assignment: np.ndarray, prev_shape: Sequence[int],
                prev_node_sizes: Sequence[int],
                node_sizes: Sequence[int],
                node_map: Optional[Sequence[Optional[int]]] = None) \
        -> RepairSeed:
    """Build the warm-start assignment for the post-churn problem.

    ``node_map[i]`` is the pre-churn index of post-churn node ``i`` (``-1``
    or ``None`` for a node that is new).  Default: identity when the node
    counts match; anything else must be spelled by the caller (the
    survivors' old indices in order, e.g.
    :meth:`~repro.runtime.fault.SimulatedFault.survivor_map`).
    """
    prev_assignment = np.asarray(prev_assignment, dtype=np.int64).reshape(-1)
    prev_sizes = [int(s) for s in prev_node_sizes]
    sizes = np.asarray([int(s) for s in node_sizes], dtype=np.int64)
    n_old, n_new = len(prev_sizes), len(sizes)
    if prev_assignment.shape[0] != int(np.prod(prev_shape)):
        raise RepairInapplicable(
            f"previous assignment has {prev_assignment.shape[0]} positions, "
            f"previous shape {tuple(prev_shape)} needs "
            f"{int(np.prod(prev_shape))}")
    if int(sizes.sum()) != grid.size:
        raise ValueError(f"sum(node_sizes)={int(sizes.sum())} != mesh size "
                         f"{grid.size}")
    if (sizes <= 0).any():
        raise ValueError("node_sizes must be positive")
    if node_map is None:
        if n_new != n_old:
            raise RepairInapplicable(
                f"{n_old} nodes before churn, {n_new} after: pass node_map "
                "(new index -> old index, -1 for added nodes)")
        node_map = list(range(n_new))
    node_map = [-1 if m is None else int(m) for m in node_map]
    if len(node_map) != n_new:
        raise ValueError(f"node_map has {len(node_map)} entries for "
                         f"{n_new} nodes")
    old_to_new = np.full(n_old, -1, dtype=np.int64)
    for i, o in enumerate(node_map):
        if o < 0:
            continue
        if o >= n_old:
            raise ValueError(f"node_map[{i}]={o} out of range for {n_old} "
                             "pre-churn nodes")
        if old_to_new[o] >= 0:
            raise ValueError(f"node_map maps old node {o} twice")
        old_to_new[o] = i

    # 1. transfer: post-churn position -> pre-churn node -> post-churn node
    src = transfer_positions(grid, prev_shape)
    desire = old_to_new[prev_assignment[src]]      # -1 where the node died
    seed = desire.copy()

    table = NeighborTable.build(grid, stencil)
    score = _same_node_score(table, desire)

    # 1b. newly added nodes claim a *connected* region up-front, routed
    # through over-capacity donors' cells — a scattered fill would hand the
    # anneal a hopeless seed and the new node a worst-case J
    locked = np.zeros(grid.size, dtype=bool)
    over = (np.bincount(seed[seed >= 0], minlength=n_new)
            - sizes).astype(np.int64)
    for node in (i for i, o in enumerate(node_map) if o < 0):
        _grow_region(table, seed, score, over, locked, int(node),
                     int(sizes[node]))

    # 2. restrict to capacities: over-full nodes orphan boundary-most first
    counts = np.bincount(seed[seed >= 0], minlength=n_new)
    for node in np.nonzero(counts > sizes)[0]:
        pos = np.nonzero(seed == node)[0]
        order = pos[np.lexsort((pos, score[pos]))]   # lowest score first
        seed[order[:counts[node] - sizes[node]]] = -1

    orphans = int((seed < 0).sum())
    free = sizes - np.bincount(seed[seed >= 0], minlength=n_new)

    # 3. re-home orphans: neighbour majority vote, repeated to a fixed point
    rehomed_adjacent = 0
    while True:
        orphan_pos = np.nonzero(seed < 0)[0]
        if orphan_pos.size == 0:
            break
        progress = False
        for pos in orphan_pos:
            out = table.out_tgt[table.out_valid[:, pos], pos]
            inc = table.in_src[table.in_valid[:, pos], pos]
            nbr = seed[np.concatenate([out, inc])]
            nbr = nbr[nbr >= 0]
            nbr = nbr[free[nbr] > 0]
            if nbr.size == 0:
                continue
            votes = np.bincount(nbr, minlength=n_new)
            node = int(votes.argmax())               # ties -> smaller id
            seed[pos] = node
            free[node] -= 1
            rehomed_adjacent += 1
            progress = True
        if not progress:
            break
    leftover = np.nonzero(seed < 0)[0]
    if leftover.size:                   # disconnected pockets / empty new
        fill = np.repeat(np.arange(n_new), free)     # nodes: row-major fill
        seed[leftover] = fill
        free[:] = 0

    # 4. what churn touched: capacity-changed nodes + both end-points of
    # every move (the donor a position left *and* the node it landed on —
    # the restricted search needs at least the donors to trade with)
    moved = seed != desire
    affected = set(int(n) for n in np.unique(seed[moved]))
    affected |= set(int(n) for n in np.unique(desire[moved]) if n >= 0)
    for i, o in enumerate(node_map):
        if o < 0 or prev_sizes[o] != int(sizes[i]):
            affected.add(i)
    affected_nodes = np.asarray(sorted(affected), dtype=np.int64)
    pinned = ~np.isin(seed, affected_nodes)
    return RepairSeed(assignment=seed, desire=desire, moved=moved,
                      affected_nodes=affected_nodes, pinned=pinned,
                      orphans=orphans, rehomed_adjacent=rehomed_adjacent)


def _restricted_polish(ic: IncrementalCost, allowed: np.ndarray,
                       objective: str = "lex",
                       max_passes: int = 4, max_partners: int = 32,
                       budget: Optional[int] = None,
                       max_positions: Optional[int] = None,
                       tol: float = 1e-12) -> int:
    """First-improvement descent over boundary pairs drawn entirely from
    ``allowed`` positions — the pin-respecting stand-in for the schedule's
    phases (which have no notion of pinning).  ``objective="j_sum"``
    accepts any J_sum-reducing swap that does not worsen J_max (the
    schedule's J_sum phase, guarded); ``"lex"`` accepts lexicographic
    (J_max, J_sum) improvements.  ``max_positions`` caps the outer sweep to
    the costliest boundary positions (partners still come from the full
    boundary) — the J_max binding set sits at the front of the cost-sorted
    order, so a small cap keeps the J_max-relieving swaps while shedding
    the long tail of no-op probes.  Mutates ``ic``; returns accepted
    swaps."""
    swaps = 0
    for _ in range(max_passes):
        improved = False
        boundary = ic.boundary_positions()
        boundary = boundary[allowed[boundary]]
        per_node = ic.per_node
        cost_of = per_node[ic.node_of_pos[boundary]]
        # costliest nodes' positions first (the J_max binding set), cheapest
        # partners first — the ordering that relieves the max node soonest
        boundary = boundary[np.argsort(-cost_of, kind="stable")]
        for p in boundary[:max_positions]:
            if budget is not None and swaps >= budget:
                return swaps
            partners = boundary[ic.node_of_pos[boundary]
                                != ic.node_of_pos[p]]
            partners = partners[np.argsort(
                ic.per_node[ic.node_of_pos[partners]], kind="stable")]
            for q in partners[:max_partners]:
                d = ic.delta_swap(int(p), int(q))
                d_max = ic.peek_j_max(d) - ic.j_max
                if objective == "j_sum":
                    ok = d.d_j_sum < -tol and d_max <= tol
                else:
                    ok = d_max < -tol or (abs(d_max) <= tol
                                          and d.d_j_sum < -tol)
                if ok:
                    ic.apply_swap(int(p), int(q))
                    swaps += 1
                    improved = True
                    break
        if not improved:
            break
    return swaps


def _resplit_pairs(grid: CartGrid, stencil: Stencil,
                   assignment: np.ndarray, num_nodes: int,
                   nodes: Sequence[int], max_passes: int = 3,
                   tol: float = 1e-12) -> Tuple[np.ndarray, int]:
    """Deterministic two-node re-tiling over the *affected* nodes: for every
    pair, re-partition the union of their cells along each grid axis
    (coordinate-sorted prefix split, both orders) and keep the best
    lexicographic (J_max, J_sum) improvement.  This crosses the
    block-rotation barriers swap-based annealing cannot (rotating two 2x4
    blocks into two 4x2 blocks takes ~8 coordinated swaps through strictly
    worse states).  Only the pair's own positions change, so pinned
    positions stay untouched.  Only pairs *adjacent* in the current
    assignment (sharing at least one stencil edge) are tried — a prefix
    re-split of two regions that never touch cannot beat the split they
    already have, and skipping them turns the O(n^2) pair sweep into the
    O(boundary) sweep that keeps the all-nodes-affected repair path under
    its latency budget.  Returns ``(assignment, accepted)``."""
    nodes = [int(n) for n in nodes]
    coords = grid.coords()
    nbr = NeighborTable.build(grid, stencil)
    cur = np.asarray(assignment, dtype=np.int64).copy()
    c = evaluate(grid, stencil, cur, num_nodes=num_nodes, weighted="auto")
    cur_key = (c.j_max, c.j_sum)
    accepted = 0

    def node_adjacency(assign: np.ndarray) -> np.ndarray:
        adj = np.zeros((num_nodes, num_nodes), dtype=bool)
        for j in range(nbr.out_valid.shape[0]):
            v = nbr.out_valid[j]
            adj[assign[v], assign[nbr.out_tgt[j][v]]] = True
        return adj | adj.T

    for _ in range(max_passes):
        improved = False
        adj = node_adjacency(cur)
        for ai in range(len(nodes)):
            for bi in range(ai + 1, len(nodes)):
                a, b = nodes[ai], nodes[bi]
                if not adj[a, b]:
                    continue
                cells_a = np.nonzero(cur == a)[0]
                cells_b = np.nonzero(cur == b)[0]
                if cells_a.size == 0 or cells_b.size == 0:
                    continue
                union = np.concatenate([cells_a, cells_b])
                best_key, best_trial = cur_key, None
                for axis in range(grid.ndim):
                    order = np.lexsort(tuple(
                        coords[union, ax]
                        for ax in range(grid.ndim) if ax != axis
                    ) + (coords[union, axis],))
                    for first, second in ((a, b), (b, a)):
                        split = cells_a.size if first == a else cells_b.size
                        trial = cur.copy()
                        trial[union[order[:split]]] = first
                        trial[union[order[split:]]] = second
                        if np.array_equal(trial, cur):
                            continue
                        tc = evaluate(grid, stencil, trial,
                                      num_nodes=num_nodes, weighted="auto")
                        key = (tc.j_max, tc.j_sum)
                        if key[0] < best_key[0] - tol or \
                                (abs(key[0] - best_key[0]) <= tol
                                 and key[1] < best_key[1] - tol):
                            best_key, best_trial = key, trial
                if best_trial is not None:
                    cur, cur_key = best_trial, best_key
                    accepted += 1
                    improved = True
        if not improved:
            break
    return cur, accepted


def _relabel_overlap(fresh: np.ndarray, desire: np.ndarray,
                     sizes: np.ndarray) -> np.ndarray:
    """Permutation of node labels (within equal-capacity groups — anything
    else would break ``bincount == node_sizes``) maximizing the number of
    positions whose fresh label matches the transferred previous node, so a
    fresh re-tile migrates as few shards as possible.  Greedy on the
    overlap matrix; J_max/J_sum are label-invariant, so this never costs
    quality.  Returns ``perm`` with ``perm[fresh_label] = node id``."""
    n = int(sizes.shape[0])
    overlap = np.zeros((n, n), dtype=np.int64)
    mask = desire >= 0
    np.add.at(overlap, (fresh[mask], desire[mask]), 1)
    perm = np.full(n, -1, dtype=np.int64)
    taken = np.zeros(n, dtype=bool)
    order = np.argsort(-overlap, axis=None, kind="stable")
    for flat in order:
        lab, node = divmod(int(flat), n)
        if perm[lab] >= 0 or taken[node] or sizes[lab] != sizes[node]:
            continue
        perm[lab], taken[node] = node, True
    for lab in np.nonzero(perm < 0)[0]:       # zero-overlap leftovers
        node = next(i for i in np.nonzero(~taken)[0]
                    if sizes[i] == sizes[lab])
        perm[lab], taken[node] = node, True
    return perm


# ---------------------------------------------------------------------------
# the plan stage


def _previous_parts(previous) -> Tuple[np.ndarray, Tuple[int, ...],
                                       Tuple[int, ...]]:
    """Normalize ``previous``: a MappingSolution / CartResult, or an
    ``(assignment, mesh_shape, node_sizes)`` triple."""
    if hasattr(previous, "solution"):             # CartResult
        previous = previous.solution
    if hasattr(previous, "assignment") and hasattr(previous, "problem"):
        return (np.asarray(previous.assignment, dtype=np.int64),
                tuple(previous.problem.mesh_shape),
                tuple(previous.problem.node_sizes))
    try:
        assignment, shape, sizes = previous
    except (TypeError, ValueError):
        raise TypeError(
            "previous must be a MappingSolution/CartResult or an "
            "(assignment, mesh_shape, node_sizes) triple, got "
            f"{type(previous).__name__}") from None
    return (np.asarray(assignment, dtype=np.int64).reshape(-1),
            tuple(int(d) for d in shape), tuple(int(s) for s in sizes))


class RepairStage(Stage):
    """The ``repair:`` plan stage: produce the post-churn assignment by
    warm-starting from a previous solution (seed + pinned anneal) instead
    of running a base mapper cold.

    Args:
      previous: the pre-churn :class:`~repro.core.plan.MappingSolution`
        (or ``CartResult``, or an ``(assignment, mesh_shape, node_sizes)``
        triple).
      node_map: post-churn node index -> pre-churn node index (``-1`` /
        ``None`` = newly added node).  Default identity when counts match.
      k / seed / sa_moves / temperatures: the repair portfolio's annealing
        shape (short ladders — the seed is already good; the final
        near-zero temperature acts as a sampled greedy descent).  ``k=0``
        returns the raw seed unrefined.
      pin: exclude positions of churn-untouched nodes from the search
        (``False`` anneals the whole mesh from the seed — slower, and the
        pinned-position invariant no longer holds).
      max_swaps: accepted-swap budget for the anneal (per-stage plan
        budgets thread into this).
      grow_base: mesh-*growth* strategy (scale-up / pod rejoin at a larger
        shape).  A grown grid admits tilings the previous solution never
        contained, so warm-seeding systematically lands in a worse basin;
        instead the deterministic ``grow_base`` mapper re-tiles the new
        grid from scratch (cheap — no portfolio) and the labels are then
        permuted to maximize overlap with the transferred previous
        assignment, minimizing migration volume.  Set to ``""`` to force
        the warm seed even on growth.
      fallback: a :class:`~repro.core.plan.MappingPlan` solved cold when
        the previous solution cannot seed this problem
        (:class:`RepairInapplicable`); without one the error propagates.

    The stage spec hashes the previous assignment (+ provenance + options),
    so plans containing it are cacheable: the repaired solution lands in
    the :class:`~repro.core.plan.PlanCache` keyed by the *post-churn*
    problem hash — pre-churn entries are untouched by construction.
    """

    is_initial = True       # produces the plan's first assignment

    def __init__(self, previous,
                 node_map: Optional[Sequence[Optional[int]]] = None,
                 k: int = 4, seed: int = 0, sa_moves: int = 40,
                 temperatures: Sequence[float] = (0.35, 1e-6),
                 pin: bool = True, max_swaps: Optional[int] = None,
                 grow_base: str = "hyperplane", fallback=None):
        self.prev_assignment, self.prev_shape, self.prev_sizes = \
            _previous_parts(previous)
        self.node_map = None if node_map is None else \
            tuple(-1 if m is None else int(m) for m in node_map)
        if int(k) < 0:
            raise ValueError("k must be >= 0 (0 = seed only)")
        self.k = int(k)
        self.seed = int(seed)
        self.sa_moves = int(sa_moves)
        self.temperatures = tuple(float(t) for t in temperatures)
        self.pin = bool(pin)
        if max_swaps is not None and int(max_swaps) < 0:
            raise ValueError("max_swaps must be >= 0 (or None)")
        self.max_swaps = None if max_swaps is None else int(max_swaps)
        self.grow_base = str(grow_base)
        self.fallback = fallback
        self.cacheable = True if fallback is None \
            else getattr(fallback, "cacheable", False)

    # -- identity ----------------------------------------------------------
    def _prev_hash(self) -> str:
        h = hashlib.sha256()
        h.update(self.prev_assignment.astype("<i8").tobytes())
        h.update(repr((self.prev_shape, self.prev_sizes,
                       self.node_map)).encode())
        return h.hexdigest()[:16]

    def options(self) -> Dict[str, object]:
        return {"k": self.k, "seed": self.seed, "sa_moves": self.sa_moves,
                "temperatures": self.temperatures, "pin": self.pin,
                "max_swaps": self.max_swaps, "grow_base": self.grow_base}

    def spec(self) -> str:
        s = f"repair[{canon_options(self.options())}]" \
            f"{{prev={self._prev_hash()}}}"
        if self.fallback is not None:
            s += f"@fallback={self.fallback.key}"
        return s

    # -- execution ---------------------------------------------------------
    def _run_fallback(self, grid: CartGrid, stencil: Stencil,
                      node_sizes: Sequence[int], reason: str) -> StageResult:
        assignment = None
        stats: List[dict] = []
        for st in self.fallback.stages:
            sr = st.run(grid, stencil, node_sizes, assignment)
            assignment = sr.assignment
            stats.append(sr.stats)
        return StageResult(assignment=assignment,
                           stats={"stage": self.spec(), "kind": "repair",
                                  "used_fallback": True,
                                  "fallback_reason": reason,
                                  "fallback_stats": stats})

    def _run_grow(self, grid: CartGrid, stencil: Stencil,
                  node_sizes: Sequence[int], rs: RepairSeed,
                  t0: float) -> StageResult:
        """Mesh-growth path: a grown grid admits tilings the previous
        solution never contained, so the warm seed is a systematically
        worse basin at any anneal effort.  Re-tile fresh with the
        deterministic ``grow_base`` mapper, then permute labels for maximum
        overlap with the transferred previous assignment (the migration
        volume is the only warm artifact worth keeping — J is
        label-invariant)."""
        n = len(node_sizes)
        sizes = np.asarray(node_sizes, dtype=np.int64)
        base = BaseStage(self.grow_base, fallback="blocked")
        fresh = base.run(grid, stencil, node_sizes, None).assignment
        perm = _relabel_overlap(fresh, rs.desire, sizes)
        cur = perm[fresh]
        resplits = 0
        swaps = 0
        if self.k > 0 and grid.size > 1 and (self.max_swaps is None
                                             or self.max_swaps > 0):
            cur, resplits = _resplit_pairs(grid, stencil, cur, n,
                                           list(range(n)), max_passes=1)
            ic = IncrementalCost(grid, stencil, cur, num_nodes=n,
                                 weighted="auto")
            allowed = np.ones(grid.size, dtype=bool)
            swaps = _restricted_polish(ic, allowed, objective="lex",
                                       max_passes=1, max_partners=8,
                                       max_positions=32,
                                       budget=self.max_swaps)
            cur = ic.node_of_pos.copy()
            final_key = (ic.j_max, ic.j_sum)
        else:
            c = evaluate(grid, stencil, cur, num_nodes=n, weighted="auto")
            final_key = (c.j_max, c.j_sum)
        migrated = int((cur != rs.desire).sum())
        stats = {
            "stage": self.spec(), "kind": "repair", "used_fallback": False,
            "strategy": "grow-fresh", "grow_base": self.grow_base,
            "orphans": rs.orphans,
            "rehomed_adjacent": rs.rehomed_adjacent,
            "moved": migrated,
            "affected_nodes": list(range(n)),
            "pinned": 0,
            "pin": self.pin,
            "final": final_key,
            "swaps": swaps,
            "resplits": resplits,
            "wall_time_s": time.perf_counter() - t0,
        }
        return StageResult(assignment=cur, stats=stats)

    def run(self, grid: CartGrid, stencil: Stencil,
            node_sizes: Sequence[int],
            assignment: Optional[np.ndarray] = None) -> StageResult:
        if assignment is not None:
            raise ValueError("RepairStage must be the first stage of a plan")
        t0 = time.perf_counter()
        try:
            rs = repair_seed(grid, stencil, self.prev_assignment,
                             self.prev_shape, self.prev_sizes, node_sizes,
                             node_map=self.node_map)
        except RepairInapplicable as e:
            if self.fallback is None:
                raise
            return self._run_fallback(grid, stencil, node_sizes, str(e))
        # A *changed* mesh shape garbles the geometric transfer (the seed is
        # a rescale of the old tiling), and the new shape admits tilings the
        # previous solution never contained — on growth always, and on any
        # re-shape with uniform node sizes (where the deterministic base
        # mapper is at its strongest).  Re-tile fresh there; the warm seed
        # only survives as the relabeling target that minimizes migration.
        if self.grow_base and tuple(grid.dims) != self.prev_shape and \
                (grid.size > int(np.prod(self.prev_shape))
                 or len({int(s) for s in node_sizes}) == 1):
            return self._run_grow(grid, stencil, node_sizes, rs, t0)
        n = len(node_sizes)
        cur = rs.assignment
        allowed = ~rs.pinned if self.pin \
            else np.ones(grid.size, dtype=bool)
        ic = IncrementalCost(grid, stencil, cur, num_nodes=n,
                             weighted="auto")
        seed_key = (ic.j_max, ic.j_sum)
        swaps = 0
        resplits = 0
        final_key = seed_key
        if self.k > 0 and grid.size > 1 and (self.max_swaps is None
                                             or self.max_swaps > 0):
            from .refine import PortfolioRefiner

            def cap() -> Optional[int]:
                return None if self.max_swaps is None \
                    else max(0, self.max_swaps - swaps)

            # 1. pre-anneal re-tiling drops the seed into the right basin
            # before any stochastic moves are spent (a boundary-pair J_sum
            # descent here costs more than the anneal and finds less)
            cur, resplits = _resplit_pairs(grid, stencil, cur,
                                           n, rs.affected_nodes)
            # 2. short pinned annealing ladders (plateau escape)
            refiner = PortfolioRefiner(
                k=self.k, seed=self.seed, sa_moves=self.sa_moves,
                temperatures=self.temperatures, kill_factor=None,
                max_swaps=cap())
            res = refiner.refine(grid, stencil, cur, num_nodes=n,
                                 pinned=rs.pinned if self.pin else None)
            swaps += res.swaps
            # 3. deterministic pairwise re-tiling of the affected nodes —
            # the barrier-crossing move the local swap search lacks
            cur, post = _resplit_pairs(grid, stencil, res.assignment,
                                       n, rs.affected_nodes)
            resplits += post
            # 4. restricted lexicographic polish (short: the heavy lifting
            # already happened, this only irons out single-swap slack).
            # With nothing pinned the boundary set is the whole mesh and a
            # full polish would dominate the repair budget — one narrow
            # pass suffices after the unrestricted anneal.
            ic = IncrementalCost(grid, stencil, cur, num_nodes=n,
                                 weighted="auto")
            wide = bool(allowed.all())
            swaps += _restricted_polish(ic, allowed, objective="lex",
                                        max_passes=1 if wide else 2,
                                        max_partners=8 if wide else 16,
                                        max_positions=32 if wide else None,
                                        budget=cap())
            cur = ic.node_of_pos.copy()
            final_key = (ic.j_max, ic.j_sum)
        stats = {
            "stage": self.spec(), "kind": "repair", "used_fallback": False,
            "orphans": rs.orphans,
            "rehomed_adjacent": rs.rehomed_adjacent,
            "moved": int(rs.moved.sum()),
            "affected_nodes": [int(x) for x in rs.affected_nodes],
            "pinned": int(rs.pinned.sum()),
            "pin": self.pin,
            "seed_key": seed_key,
            "final": final_key,
            "swaps": swaps,
            "resplits": resplits,
            "wall_time_s": time.perf_counter() - t0,
        }
        return StageResult(assignment=cur, stats=stats)


def repair_plan(previous,
                node_map: Optional[Sequence[Optional[int]]] = None,
                fallback=None, **options):
    """A one-stage :class:`~repro.core.plan.MappingPlan` that repairs
    ``previous`` onto whatever problem it is solved against.  ``options``
    are :class:`RepairStage` knobs (``k``, ``sa_moves``, ``temperatures``,
    ``pin``, ``max_swaps``, ``seed``); ``fallback`` may be a plan spelling
    or a :class:`~repro.core.plan.MappingPlan`."""
    from .plan import MappingPlan, parse_plan
    if isinstance(fallback, str):
        fallback = parse_plan(fallback)
    return MappingPlan([RepairStage(previous, node_map=node_map,
                                    fallback=fallback, **options)],
                       name="repair")
