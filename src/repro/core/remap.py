"""Mapping -> device layout for `jax.sharding.Mesh` (the MPI_Cart_create
reorder analog on TPU, DESIGN.md §2).

A JAX mesh is an ndarray of devices; the array's layout decides which
physical chip owns which logical mesh coordinate.  Devices are enumerated
pod-major by the runtime (devices 0..C-1 = pod 0, C..2C-1 = pod 1, ...), so
"rank r lives on node r // C" is exactly the paper's blocked allocation, and
a mapper's rank->coordinate bijection is exactly the device permutation we
need: place device r at logical coordinate coord(r).
"""
from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple, Union

import numpy as np

from .cost import MappingCost, evaluate
from .grid import CartGrid
from .mapping import (Mapper, MapperInapplicable, get_mapper,
                      split_mapper_name)
from .refine import PortfolioRefiner, RefinedMapper
from .stencil import Stencil

__all__ = ["device_layout", "layout_cost", "mapped_device_array",
           "ensure_refined", "ELASTIC_PORTFOLIO_KWARGS"]


def device_layout(mapper: Union[Mapper, str], mesh_shape: Sequence[int],
                  stencil: Stencil, node_sizes: Sequence[int],
                  intra_order: str = "mapper") -> np.ndarray:
    """Return L with shape ``mesh_shape``: L[logical coord] = device index.

    ``intra_order`` (beyond-paper, DESIGN.md §2):
      * "mapper"   — the paper's bijection verbatim.  Within a node the
        rank order is whatever the recursion produced; the paper assumes
        homogeneous intra-node communication so this is free *for MPI* —
        but on a TPU pod the chips sit on a torus, and a scrambled
        intra-pod order lengthens ICI routes.
      * "rowmajor" — hierarchical: keep the algorithm's *node assignment*
        (same J_sum/J_max) but hand each node's grid positions to its chips
        in row-major position order, so mesh-adjacent coordinates sit on
        torus-adjacent chips.

    Falls back to the blocked layout if the algorithm is inapplicable
    (e.g. Nodecart on a non-factorizable configuration).
    """
    if isinstance(mapper, str):
        mapper = get_mapper(mapper)
    grid = CartGrid(tuple(mesh_shape))
    try:
        if intra_order == "rowmajor":
            node_of_pos = mapper.assignment(grid, stencil, node_sizes)
            sizes = np.asarray(node_sizes, dtype=np.int64)
            starts = np.concatenate([[0], np.cumsum(sizes)[:-1]])
            counters = np.zeros(len(sizes), dtype=np.int64)
            layout = np.empty(grid.size, dtype=np.int64)
            for pos in range(grid.size):
                nd = node_of_pos[pos]
                layout[pos] = starts[nd] + counters[nd]
                counters[nd] += 1
            return layout.reshape(tuple(mesh_shape))
        coords = mapper.coords(grid, stencil, node_sizes)
    except MapperInapplicable:
        return np.arange(grid.size).reshape(tuple(mesh_shape))
    layout = np.empty(grid.size, dtype=np.int64)
    flat = np.ravel_multi_index(tuple(coords.T), grid.dims)
    layout[flat] = np.arange(grid.size)
    return layout.reshape(tuple(mesh_shape))


def layout_cost(layout: np.ndarray, stencil: Stencil,
                node_sizes: Sequence[int],
                weighted: bool = False) -> MappingCost:
    """Evaluate J_sum/J_max of an arbitrary device layout (L[coord]=device).
    ``weighted=True`` uses the stencil's per-offset byte weights (inter-pod
    bytes instead of edge counts)."""
    mesh_shape = layout.shape
    grid = CartGrid(tuple(mesh_shape))
    sizes = np.asarray(node_sizes, dtype=np.int64)
    owner_of_device = np.repeat(np.arange(len(sizes)), sizes)
    node_of_pos = owner_of_device[layout.reshape(-1)]
    return evaluate(grid, stencil, node_of_pos, num_nodes=len(sizes),
                    weighted=weighted)


#: The elastic upgrade's portfolio shape: a handful of starts with a short
#: ladder — mesh construction is a one-off, but it should stay sub-second
#: at pod scale while still hopping the J_max plateaus a single
#: deterministic schedule stalls on.
ELASTIC_PORTFOLIO_KWARGS = dict(k=4, sa_moves=100,
                                temperatures=(1.0, 0.5, 0.25))


def ensure_refined(mapper: Union[Mapper, str]) -> Union[Mapper, str]:
    """Return ``mapper`` upgraded with local-search refinement unless it
    already is a refining variant.  Plain mappers are wrapped with the
    multi-start :class:`~repro.core.refine.PortfolioRefiner` (the
    bottleneck is what elastic degradation hurts, and a seed portfolio is
    what escapes its plateaus — :data:`ELASTIC_PORTFOLIO_KWARGS` keeps the
    search mesh-construction sized), with ``blocked`` as the starting point
    when the base itself is inapplicable to ragged sizes (e.g. Nodecart
    needs homogeneous nodes — refinement must still run); already-refined
    names (any ``<prefix>[opts]:`` spelling) and :class:`RefinedMapper`
    instances pass through unchanged."""
    if isinstance(mapper, str):
        if split_mapper_name(mapper) is not None:
            return mapper
        mapper = get_mapper(mapper)
    if isinstance(mapper, RefinedMapper):
        return mapper
    return RefinedMapper(mapper,
                         refiner=PortfolioRefiner(**ELASTIC_PORTFOLIO_KWARGS),
                         prefix="portfolio", fallback="blocked")


def mapped_device_array(devices: Sequence, mapper: Union[Mapper, str],
                        mesh_shape: Sequence[int], stencil: Stencil,
                        chips_per_pod: int,
                        node_sizes: Optional[Sequence[int]] = None,
                        auto_refine: bool = True) -> np.ndarray:
    """Arrange ``devices`` (pod-major order) into an ndarray for `Mesh`.

    ``node_sizes`` overrides the uniform ``chips_per_pod`` split for
    elastic operation: pass the *surviving* chips per pod after failures.
    With ``auto_refine`` (default), any ragged layout — heterogeneous
    ``node_sizes`` or a ragged tail pod — upgrades ``mapper`` to its
    multi-start annealing-portfolio variant at mesh construction time (see
    :func:`ensure_refined`), so callers no longer opt in by mapper name to
    recover mapping quality after a pod loses chips.
    """
    p = int(math.prod(mesh_shape))
    if len(devices) != p:
        raise ValueError(f"{len(devices)} devices != mesh size {p}")
    if node_sizes is not None:
        node_sizes = [int(n) for n in node_sizes]
        if sum(node_sizes) != p:
            raise ValueError(f"sum(node_sizes)={sum(node_sizes)} != mesh "
                             f"size {p}")
    elif p % chips_per_pod == 0:
        node_sizes = [chips_per_pod] * (p // chips_per_pod)
    else:  # ragged tail pod (elastic operation after failures)
        full, rem = divmod(p, chips_per_pod)
        node_sizes = [chips_per_pod] * full + [rem]
    if auto_refine and len(set(node_sizes)) > 1:
        mapper = ensure_refined(mapper)
    layout = device_layout(mapper, mesh_shape, stencil, node_sizes)
    dev_arr = np.empty(p, dtype=object)
    for i, d in enumerate(devices):
        dev_arr[i] = d
    return dev_arr[layout.reshape(-1)].reshape(tuple(mesh_shape))
