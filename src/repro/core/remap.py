"""Mapping -> device layout for `jax.sharding.Mesh` (the MPI_Cart_create
reorder analog on TPU, DESIGN.md §2).

A JAX mesh is an ndarray of devices; the array's layout decides which
physical chip owns which logical mesh coordinate.  Devices are enumerated
pod-major by the runtime (devices 0..C-1 = pod 0, C..2C-1 = pod 1, ...), so
"rank r lives on node r // C" is exactly the paper's blocked allocation, and
a mapper's rank->coordinate bijection is exactly the device permutation we
need: place device r at logical coordinate coord(r).
"""
from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple, Union

import numpy as np

from .cost import MappingCost, evaluate, rowmajor_rank_layout
from .grid import CartGrid
from .mapping import (Mapper, MapperInapplicable, get_mapper,
                      split_mapper_name)
from .plan import (MappingProblem, PlanCache, blocked_node_sizes, parse_plan,
                   resolve_cache)
from .refine import PortfolioRefiner, RefinedMapper
from .refine.stage import canon_options
from .stencil import Stencil

__all__ = ["device_layout", "layout_cost", "mapped_device_array",
           "apply_layout", "ensure_refined", "ELASTIC_PORTFOLIO_KWARGS"]


def apply_layout(devices: Sequence, layout: np.ndarray) -> np.ndarray:
    """Permute ``devices`` (pod-major runtime order) by ``L[logical coord]
    = device index`` into the object ndarray ``jax.sharding.Mesh``
    expects — the one place the permutation convention lives
    (``mapped_device_array`` and ``cart_create().mesh()`` both use it)."""
    layout = np.asarray(layout)
    p = int(math.prod(layout.shape))
    if len(devices) != p:
        raise ValueError(f"{len(devices)} devices != mesh size {p}")
    dev_arr = np.empty(p, dtype=object)
    for i, d in enumerate(devices):
        dev_arr[i] = d
    return dev_arr[layout.reshape(-1)].reshape(layout.shape)


def device_layout(mapper: Union[Mapper, str], mesh_shape: Sequence[int],
                  stencil: Stencil, node_sizes: Sequence[int],
                  intra_order: str = "mapper",
                  cache: Union[None, bool, PlanCache] = None) -> np.ndarray:
    """Return L with shape ``mesh_shape``: L[logical coord] = device index.

    ``intra_order`` (beyond-paper, DESIGN.md §2):
      * "mapper"   — the paper's bijection verbatim.  Within a node the
        rank order is whatever the recursion produced; the paper assumes
        homogeneous intra-node communication so this is free *for MPI* —
        but on a TPU pod the chips sit on a torus, and a scrambled
        intra-pod order lengthens ICI routes.
      * "rowmajor" — hierarchical: keep the algorithm's *node assignment*
        (same J_sum/J_max) but hand each node's grid positions to its chips
        in row-major position order, so mesh-adjacent coordinates sit on
        torus-adjacent chips.

    Falls back to the blocked layout if the algorithm is inapplicable
    (e.g. Nodecart on a non-factorizable configuration).

    ``cache``: layouts are served from the plan cache (default: the
    process-wide :func:`~repro.core.plan.default_plan_cache`; ``False``
    disables) whenever the mapper has a stable content key — a string
    spelling, or any mapper built by ``get_mapper``/``parse_plan``/
    ``ensure_refined`` (``plan_key``, a construction-time snapshot: clear
    it if you mutate the mapper afterwards).  Ad-hoc mapper instances
    without a key are never cached.
    """
    # canonical plan key (sorted bracket options), so equivalent spellings
    # and get_mapper instances of the same plan share one cache entry; the
    # spelling is parsed once — the key comes from the plan, and the cold
    # path materializes the mapper from the same parse.
    plan = parse_plan(mapper) if isinstance(mapper, str) else None
    key = plan.key if plan is not None else getattr(mapper, "plan_key", None)
    c = resolve_cache(cache)
    if c is not None and key is not None:
        problem = MappingProblem(tuple(mesh_shape), stencil,
                                 tuple(int(n) for n in node_sizes))
        return c.layout(
            problem, key, intra_order,
            lambda: _compute_layout(
                plan.to_mapper() if plan is not None else mapper,
                mesh_shape, stencil, node_sizes, intra_order))
    return _compute_layout(plan.to_mapper() if plan is not None else mapper,
                           mesh_shape, stencil, node_sizes, intra_order)


def _compute_layout(mapper: Mapper, mesh_shape: Sequence[int],
                    stencil: Stencil, node_sizes: Sequence[int],
                    intra_order: str) -> np.ndarray:
    grid = CartGrid(tuple(mesh_shape))
    try:
        if intra_order == "rowmajor":
            node_of_pos = mapper.assignment(grid, stencil, node_sizes)
            return rowmajor_rank_layout(node_of_pos).reshape(
                tuple(mesh_shape))
        coords = mapper.coords(grid, stencil, node_sizes)
    except MapperInapplicable:
        return np.arange(grid.size).reshape(tuple(mesh_shape))
    layout = np.empty(grid.size, dtype=np.int64)
    flat = np.ravel_multi_index(tuple(coords.T), grid.dims)
    layout[flat] = np.arange(grid.size)
    return layout.reshape(tuple(mesh_shape))


def layout_cost(layout: np.ndarray, stencil: Stencil,
                node_sizes: Sequence[int],
                weighted: bool = False) -> MappingCost:
    """Evaluate J_sum/J_max of an arbitrary device layout (L[coord]=device).
    ``weighted=True`` uses the stencil's per-offset byte weights (inter-pod
    bytes instead of edge counts)."""
    mesh_shape = layout.shape
    grid = CartGrid(tuple(mesh_shape))
    sizes = np.asarray(node_sizes, dtype=np.int64)
    owner_of_device = np.repeat(np.arange(len(sizes)), sizes)
    node_of_pos = owner_of_device[layout.reshape(-1)]
    return evaluate(grid, stencil, node_of_pos, num_nodes=len(sizes),
                    weighted=weighted)


#: The elastic upgrade's portfolio shape: a handful of starts with a short
#: ladder — mesh construction is a one-off, but it should stay sub-second
#: at pod scale while still hopping the J_max plateaus a single
#: deterministic schedule stalls on.
ELASTIC_PORTFOLIO_KWARGS = dict(k=4, sa_moves=100,
                                temperatures=(1.0, 0.5, 0.25))


def ensure_refined(mapper: Union[Mapper, str]) -> Union[Mapper, str]:
    """Return ``mapper`` upgraded with local-search refinement unless it
    already is a refining variant.  Plain mappers are wrapped with the
    multi-start :class:`~repro.core.refine.PortfolioRefiner` (the
    bottleneck is what elastic degradation hurts, and a seed portfolio is
    what escapes its plateaus — :data:`ELASTIC_PORTFOLIO_KWARGS` keeps the
    search mesh-construction sized), with ``blocked`` as the starting point
    when the base itself is inapplicable to ragged sizes (e.g. Nodecart
    needs homogeneous nodes — refinement must still run); already-refined
    names (any ``<prefix>[opts]:`` spelling, ``sharded[...]:`` included)
    and :class:`RefinedMapper` instances pass through unchanged.  Callers
    wanting the process-sharded engine for big elastic meshes spell it
    (``"sharded[shards=4,k=64,restarts=auto]:hyperplane"``) — the upgrade
    never second-guesses an explicit refining spelling."""
    if isinstance(mapper, str):
        if split_mapper_name(mapper) is not None:
            return mapper
        mapper = get_mapper(mapper)
    if isinstance(mapper, RefinedMapper):
        return mapper
    wrapped = RefinedMapper(
        mapper, refiner=PortfolioRefiner(**ELASTIC_PORTFOLIO_KWARGS),
        prefix="portfolio", fallback="blocked")
    # stable cache identity for the upgrade, iff the base itself has one —
    # same convention as the plan layer (the fallback marker rides on the
    # base segment, cf. BaseStage.spec)
    base_key = getattr(mapper, "plan_key", None)
    if base_key is not None:
        opts = canon_options(ELASTIC_PORTFOLIO_KWARGS)
        wrapped.plan_key = f"portfolio[{opts}]:{base_key}@fallback=blocked"
    return wrapped


def mapped_device_array(devices: Sequence, mapper: Union[Mapper, str],
                        mesh_shape: Sequence[int], stencil: Stencil,
                        chips_per_pod: int,
                        node_sizes: Optional[Sequence[int]] = None,
                        auto_refine: bool = True,
                        cache: Union[None, bool, PlanCache] = None) \
        -> np.ndarray:
    """Arrange ``devices`` (pod-major order) into an ndarray for `Mesh`.

    ``node_sizes`` overrides the uniform ``chips_per_pod`` split for
    elastic operation: pass the *surviving* chips per pod after failures.
    With ``auto_refine`` (default), any ragged layout — heterogeneous
    ``node_sizes`` or a ragged tail pod — upgrades ``mapper`` to its
    multi-start annealing-portfolio variant at mesh construction time (see
    :func:`ensure_refined`), so callers no longer opt in by mapper name to
    recover mapping quality after a pod loses chips.

    ``cache`` (default: the process-wide plan cache; ``False`` disables):
    the solved device layout is keyed by the full problem signature, so a
    repeated build — an elastic re-mesh onto the same survivors, or a
    serving-time mesh rebuild — reuses the solved assignment instead of
    re-annealing (see :class:`~repro.core.plan.PlanCache`).
    """
    p = int(math.prod(mesh_shape))
    if len(devices) != p:
        raise ValueError(f"{len(devices)} devices != mesh size {p}")
    if node_sizes is not None:
        node_sizes = [int(n) for n in node_sizes]
        if sum(node_sizes) != p:
            raise ValueError(f"sum(node_sizes)={sum(node_sizes)} != mesh "
                             f"size {p}")
    else:   # blocked split, ragged tail pod when it doesn't divide evenly
        node_sizes = list(blocked_node_sizes(p, chips_per_pod))
    if auto_refine and len(set(node_sizes)) > 1:
        mapper = ensure_refined(mapper)
    layout = device_layout(mapper, mesh_shape, stencil, node_sizes,
                           cache=cache)
    return apply_layout(devices, layout)
