"""Mapping -> device layout for `jax.sharding.Mesh` (the MPI_Cart_create
reorder analog on TPU, DESIGN.md §2).

A JAX mesh is an ndarray of devices; the array's layout decides which
physical chip owns which logical mesh coordinate.  Devices are enumerated
pod-major by the runtime (devices 0..C-1 = pod 0, C..2C-1 = pod 1, ...), so
"rank r lives on node r // C" is exactly the paper's blocked allocation, and
a mapper's rank->coordinate bijection is exactly the device permutation we
need: place device r at logical coordinate coord(r).
"""
from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple, Union

import numpy as np

from .cost import MappingCost, evaluate, rowmajor_rank_layout
from .grid import CartGrid
from .mapping import (Mapper, MapperInapplicable, get_mapper,
                      split_mapper_name)
from .plan import (MappingProblem, PlanCache, blocked_node_sizes, parse_plan,
                   resolve_cache)
from .refine import PortfolioRefiner, RefinedMapper
from .refine.stage import canon_options
from .stencil import Stencil

__all__ = ["device_layout", "layout_cost", "mapped_device_array",
           "apply_layout", "ensure_refined", "ELASTIC_PORTFOLIO_KWARGS",
           "elastic_portfolio_plan", "repair_layout"]


def apply_layout(devices: Sequence, layout: np.ndarray) -> np.ndarray:
    """Permute ``devices`` (pod-major runtime order) by ``L[logical coord]
    = device index`` into the object ndarray ``jax.sharding.Mesh``
    expects — the one place the permutation convention lives
    (``mapped_device_array`` and ``cart_create().mesh()`` both use it)."""
    layout = np.asarray(layout)
    p = int(math.prod(layout.shape))
    if len(devices) != p:
        raise ValueError(f"{len(devices)} devices != mesh size {p}")
    dev_arr = np.empty(p, dtype=object)
    for i, d in enumerate(devices):
        dev_arr[i] = d
    return dev_arr[layout.reshape(-1)].reshape(layout.shape)


def device_layout(mapper: Union[Mapper, str], mesh_shape: Sequence[int],
                  stencil: Stencil, node_sizes: Sequence[int],
                  intra_order: str = "mapper",
                  cache: Union[None, bool, PlanCache] = None) -> np.ndarray:
    """Return L with shape ``mesh_shape``: L[logical coord] = device index.

    ``intra_order`` (beyond-paper, DESIGN.md §2):
      * "mapper"   — the paper's bijection verbatim.  Within a node the
        rank order is whatever the recursion produced; the paper assumes
        homogeneous intra-node communication so this is free *for MPI* —
        but on a TPU pod the chips sit on a torus, and a scrambled
        intra-pod order lengthens ICI routes.
      * "rowmajor" — hierarchical: keep the algorithm's *node assignment*
        (same J_sum/J_max) but hand each node's grid positions to its chips
        in row-major position order, so mesh-adjacent coordinates sit on
        torus-adjacent chips.

    Falls back to the blocked layout if the algorithm is inapplicable
    (e.g. Nodecart on a non-factorizable configuration).

    ``cache``: layouts are served from the plan cache (default: the
    process-wide :func:`~repro.core.plan.default_plan_cache`; ``False``
    disables) whenever the mapper has a stable content key — a string
    spelling, or any mapper built by ``get_mapper``/``parse_plan``/
    ``ensure_refined`` (``plan_key``, a construction-time snapshot: clear
    it if you mutate the mapper afterwards).  Ad-hoc mapper instances
    without a key are never cached.
    """
    # canonical plan key (sorted bracket options), so equivalent spellings
    # and get_mapper instances of the same plan share one cache entry; the
    # spelling is parsed once — the key comes from the plan, and the cold
    # path materializes the mapper from the same parse.
    plan = parse_plan(mapper) if isinstance(mapper, str) else None
    key = plan.key if plan is not None else getattr(mapper, "plan_key", None)
    c = resolve_cache(cache)
    if c is not None and key is not None:
        problem = MappingProblem(tuple(mesh_shape), stencil,
                                 tuple(int(n) for n in node_sizes))
        return c.layout(
            problem, key, intra_order,
            lambda: _compute_layout(
                plan.to_mapper() if plan is not None else mapper,
                mesh_shape, stencil, node_sizes, intra_order))
    return _compute_layout(plan.to_mapper() if plan is not None else mapper,
                           mesh_shape, stencil, node_sizes, intra_order)


def _compute_layout(mapper: Mapper, mesh_shape: Sequence[int],
                    stencil: Stencil, node_sizes: Sequence[int],
                    intra_order: str) -> np.ndarray:
    grid = CartGrid(tuple(mesh_shape))
    try:
        if intra_order == "rowmajor":
            node_of_pos = mapper.assignment(grid, stencil, node_sizes)
            return rowmajor_rank_layout(node_of_pos).reshape(
                tuple(mesh_shape))
        coords = mapper.coords(grid, stencil, node_sizes)
    except MapperInapplicable:
        return np.arange(grid.size).reshape(tuple(mesh_shape))
    layout = np.empty(grid.size, dtype=np.int64)
    flat = np.ravel_multi_index(tuple(coords.T), grid.dims)
    layout[flat] = np.arange(grid.size)
    return layout.reshape(tuple(mesh_shape))


def layout_cost(layout: np.ndarray, stencil: Stencil,
                node_sizes: Sequence[int],
                weighted: bool = False) -> MappingCost:
    """Evaluate J_sum/J_max of an arbitrary device layout (L[coord]=device).
    ``weighted=True`` uses the stencil's per-offset byte weights (inter-pod
    bytes instead of edge counts)."""
    mesh_shape = layout.shape
    grid = CartGrid(tuple(mesh_shape))
    sizes = np.asarray(node_sizes, dtype=np.int64)
    owner_of_device = np.repeat(np.arange(len(sizes)), sizes)
    node_of_pos = owner_of_device[layout.reshape(-1)]
    return evaluate(grid, stencil, node_of_pos, num_nodes=len(sizes),
                    weighted=weighted)


#: The elastic upgrade's portfolio shape: a handful of starts with a short
#: ladder — mesh construction is a one-off, but it should stay sub-second
#: at pod scale while still hopping the J_max plateaus a single
#: deterministic schedule stalls on.
ELASTIC_PORTFOLIO_KWARGS = dict(k=4, sa_moves=100,
                                temperatures=(1.0, 0.5, 0.25))


def ensure_refined(mapper: Union[Mapper, str]) -> Union[Mapper, str]:
    """Return ``mapper`` upgraded with local-search refinement unless it
    already is a refining variant.  Plain mappers are wrapped with the
    multi-start :class:`~repro.core.refine.PortfolioRefiner` (the
    bottleneck is what elastic degradation hurts, and a seed portfolio is
    what escapes its plateaus — :data:`ELASTIC_PORTFOLIO_KWARGS` keeps the
    search mesh-construction sized), with ``blocked`` as the starting point
    when the base itself is inapplicable to ragged sizes (e.g. Nodecart
    needs homogeneous nodes — refinement must still run); already-refined
    names (any ``<prefix>[opts]:`` spelling, ``sharded[...]:`` included)
    and :class:`RefinedMapper` instances pass through unchanged.  Callers
    wanting the process-sharded engine for big elastic meshes spell it
    (``"sharded[shards=4,k=64,restarts=auto]:hyperplane"``) — the upgrade
    never second-guesses an explicit refining spelling."""
    if isinstance(mapper, str):
        if split_mapper_name(mapper) is not None:
            return mapper
        mapper = get_mapper(mapper)
    if isinstance(mapper, RefinedMapper):
        return mapper
    wrapped = RefinedMapper(
        mapper, refiner=PortfolioRefiner(**ELASTIC_PORTFOLIO_KWARGS),
        prefix="portfolio", fallback="blocked")
    # stable cache identity for the upgrade, iff the base itself has one —
    # same convention as the plan layer (the fallback marker rides on the
    # base segment, cf. BaseStage.spec)
    base_key = getattr(mapper, "plan_key", None)
    if base_key is not None:
        opts = canon_options(ELASTIC_PORTFOLIO_KWARGS)
        wrapped.plan_key = f"portfolio[{opts}]:{base_key}@fallback=blocked"
    return wrapped


def elastic_portfolio_plan(base: str = "hyperplane"):
    """The elastic upgrade as a :class:`~repro.core.plan.MappingPlan` —
    the exact stage chain :func:`ensure_refined` wraps mappers with
    (``base`` with a ``blocked`` inapplicability fallback, then the
    :data:`ELASTIC_PORTFOLIO_KWARGS` portfolio).  This is the cold-solve
    baseline the repair path falls back to — and is measured against —
    built programmatically because ``temperatures`` tuples are not
    spellable in bracket options."""
    from .plan import MappingPlan
    from .refine.stage import BaseStage
    return MappingPlan(
        [BaseStage(base, fallback="blocked"),
         PortfolioRefiner(**ELASTIC_PORTFOLIO_KWARGS).as_stage()],
        name=f"elastic-portfolio:{base}")


def repair_layout(previous, node_sizes: Sequence[int], *,
                  mesh_shape: Optional[Sequence[int]] = None,
                  stencil: Optional[Stencil] = None,
                  node_map: Optional[Sequence[Optional[int]]] = None,
                  fallback: Union[bool, str, None] = True,
                  cache: Union[None, bool, PlanCache] = None,
                  server=None,
                  **repair_options):
    """Warm-start re-solve after churn: repair ``previous`` (the pre-churn
    :class:`~repro.core.plan.MappingSolution` / ``CartResult``) onto the
    surviving ``node_sizes`` instead of solving cold.

    This is the churn path's entry point (ROADMAP open item 4): the
    previous assignment is restricted to the survivors, orphaned grid
    positions are greedily re-homed to adjacent surviving pods, and only
    the churn-affected pods' positions are annealed (everything else
    pinned) — see :mod:`repro.core.repair`.

    Args:
      previous: the pre-churn solution (``MappingSolution``, ``CartResult``,
        or an ``(assignment, mesh_shape, node_sizes)`` triple).
      node_sizes: surviving chips per pod.  For a slow-but-alive pod pass
        :func:`~repro.core.repair.downweighted_node_sizes` (the
        weighted-node re-solve with down-weighted capacity).
      mesh_shape: the post-churn mesh (default: the previous solution's
        shape when the survivor total still matches it; a device loss that
        shrinks the mesh must pass the new shape — repair transfers the
        assignment geometrically).
      stencil: communication stencil (default: the previous problem's).
      node_map: post-churn pod index -> pre-churn pod index (``-1``/None =
        newly added pod).  Default identity when the pod counts match;
        :meth:`~repro.runtime.fault.SimulatedFault.survivor_map` spells it
        for whole-pod losses.
      fallback: ``True`` -> cold-solve via :func:`elastic_portfolio_plan`
        when the previous solution cannot seed this problem; a string ->
        that plan spelling; ``False``/``None`` -> raise instead.
      cache: plan-cache policy (None -> process default).  The repaired
        solution is cached under the *post-churn* problem signature (the
        survivor ``node_sizes`` are part of the content hash), so
        pre-churn entries stay intact and a repeated re-mesh onto the
        same survivors is served without re-annealing.
      server: a running :class:`~repro.serving.PlanServer` — the repair is
        admission-controlled through its bounded queue and solved against
        its shared cache (``cache`` must then be left unset).  This is how
        the runtime churn path rides the serving layer.
      repair_options: :class:`~repro.core.repair.RepairStage` knobs
        (``k``, ``sa_moves``, ``temperatures``, ``pin``, ``max_swaps``).

    Returns the post-churn :class:`~repro.core.plan.MappingSolution`
    (``solution.layout()`` gives the device layout;
    :func:`~repro.launch.mesh.repair_mapped_mesh` builds the jax Mesh).
    """
    if server is not None:
        if cache is not None:
            raise ValueError("pass cache or server, not both: a served "
                             "repair always uses the server's shared cache")
        return server.submit_repair(
            previous, node_sizes, mesh_shape=mesh_shape, stencil=stencil,
            node_map=node_map, fallback=fallback, **repair_options).result()
    from .plan import MappingSolution
    from .repair import repair_plan
    if hasattr(previous, "solution"):               # CartResult
        previous = previous.solution
    node_sizes = tuple(int(n) for n in node_sizes)
    if isinstance(previous, MappingSolution):
        if mesh_shape is None:
            mesh_shape = previous.problem.mesh_shape
            if sum(node_sizes) != math.prod(mesh_shape):
                raise ValueError(
                    f"sum(node_sizes)={sum(node_sizes)} != previous mesh "
                    f"size {math.prod(mesh_shape)}: a churn that changes "
                    "the device count must pass the post-churn mesh_shape")
        if stencil is None:
            stencil = previous.problem.stencil
    elif mesh_shape is None or stencil is None:
        raise ValueError("repairing from a raw (assignment, shape, sizes) "
                         "triple needs explicit mesh_shape and stencil")
    if fallback is True:
        fb = elastic_portfolio_plan()
    elif isinstance(fallback, str):
        fb = parse_plan(fallback)
    else:
        fb = None
    plan = repair_plan(previous, node_map=node_map, fallback=fb,
                       **repair_options)
    problem = MappingProblem(tuple(mesh_shape), stencil, node_sizes)
    c = resolve_cache(cache)
    return plan.solve(problem, cache=c) if c is not None \
        else plan.solve(problem)


def mapped_device_array(devices: Sequence, mapper: Union[Mapper, str],
                        mesh_shape: Sequence[int], stencil: Stencil,
                        chips_per_pod: int,
                        node_sizes: Optional[Sequence[int]] = None,
                        auto_refine: bool = True,
                        cache: Union[None, bool, PlanCache] = None) \
        -> np.ndarray:
    """Arrange ``devices`` (pod-major order) into an ndarray for `Mesh`.

    ``node_sizes`` overrides the uniform ``chips_per_pod`` split for
    elastic operation: pass the *surviving* chips per pod after failures.
    With ``auto_refine`` (default), any ragged layout — heterogeneous
    ``node_sizes`` or a ragged tail pod — upgrades ``mapper`` to its
    multi-start annealing-portfolio variant at mesh construction time (see
    :func:`ensure_refined`), so callers no longer opt in by mapper name to
    recover mapping quality after a pod loses chips.

    ``cache`` (default: the process-wide plan cache; ``False`` disables):
    the solved device layout is keyed by the full problem signature, so a
    repeated build — an elastic re-mesh onto the same survivors, or a
    serving-time mesh rebuild — reuses the solved assignment instead of
    re-annealing (see :class:`~repro.core.plan.PlanCache`).
    """
    p = int(math.prod(mesh_shape))
    if len(devices) != p:
        raise ValueError(f"{len(devices)} devices != mesh size {p}")
    if node_sizes is not None:
        node_sizes = [int(n) for n in node_sizes]
        if sum(node_sizes) != p:
            raise ValueError(f"sum(node_sizes)={sum(node_sizes)} != mesh "
                             f"size {p}")
    else:   # blocked split, ragged tail pod when it doesn't divide evenly
        node_sizes = list(blocked_node_sizes(p, chips_per_pod))
    # any deviation from the homogeneous chips_per_pod split gets the
    # refinement upgrade: ragged survivors AND uniform shrinks (every pod
    # losing one chip, or whole-pod loss leaving equal survivors) — the
    # blocked split no longer matches the original topology either way.
    if auto_refine and node_sizes and (len(set(node_sizes)) > 1
                                       or node_sizes[0] != int(chips_per_pod)):
        mapper = ensure_refined(mapper)
    layout = device_layout(mapper, mesh_shape, stencil, node_sizes,
                           cache=cache)
    return apply_layout(devices, layout)
