"""Mapping cost functions (paper §II, "Optimization Problem").

``J_sum`` — total number of (directed) communication edges whose endpoints
live on different compute nodes.  The paper's edge set ``E`` contains one
edge per (rank, stencil offset) pair with a valid target, so a symmetric
stencil contributes two directed edges per undirected neighbour pair; this
matches the paper's accounting (each partition "outgoing edge" is counted at
both endpoints, cf. the Q = 2|N| - 6 bound in Thm IV.3).

``J_max`` — outgoing inter-node edge count of the bottleneck node.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from .grid import CartGrid
from .stencil import Stencil, resolve_weighted

__all__ = ["MappingCost", "evaluate", "node_of_rank_blocked",
           "blocked_assignment", "rowmajor_rank_layout"]


def rowmajor_rank_layout(node_of_pos: np.ndarray) -> np.ndarray:
    """``L[pos] = rank`` realizing a node-of-position assignment under the
    blocked allocation with each node's grid positions taken in row-major
    position order: blocked rank order is node-sorted, so a stable
    node-sort of positions lines rank r up with the r-th (node, position)
    pair.  The ONE implementation of this convention —
    ``remap.device_layout(intra_order="rowmajor")``,
    ``analysis.linksim.replay_assignment``, and
    ``plan.MappingSolution.layout`` all use it."""
    node_of_pos = np.asarray(node_of_pos)
    order = np.argsort(node_of_pos, kind="stable")
    layout = np.empty(node_of_pos.size, dtype=np.int64)
    layout[order] = np.arange(node_of_pos.size)
    return layout


@dataclass(frozen=True)
class MappingCost:
    j_sum: float
    j_max: float
    per_node: np.ndarray  # (N,) outgoing inter-node edge weight per node
    bottleneck: int       # argmax node id

    def __repr__(self) -> str:  # pragma: no cover
        return f"MappingCost(j_sum={self.j_sum}, j_max={self.j_max}, node={self.bottleneck})"


def node_of_rank_blocked(node_sizes: Sequence[int]) -> np.ndarray:
    """The scheduler's original allocation: ranks 0..n_0-1 on node 0, etc."""
    sizes = np.asarray(node_sizes, dtype=np.int64)
    if (sizes <= 0).any():
        raise ValueError("node sizes must be positive")
    return np.repeat(np.arange(len(sizes)), sizes)


def blocked_assignment(grid: CartGrid, node_sizes: Sequence[int]) -> np.ndarray:
    """node-of-grid-position for the identity (blocked) mapping."""
    owner = node_of_rank_blocked(node_sizes)
    if owner.shape[0] != grid.size:
        raise ValueError(f"sum(node_sizes)={owner.shape[0]} != grid size {grid.size}")
    return owner


def evaluate(grid: CartGrid, stencil: Stencil, node_of_pos: np.ndarray,
             num_nodes: Optional[int] = None, weighted: bool = False) -> MappingCost:
    """Evaluate J_sum / J_max of a mapping.

    Args:
      node_of_pos: (p,) node id owning each *grid position* (row-major).
      weighted: if True, use the stencil's per-offset byte weights instead of
        unit edge weights; ``"auto"`` uses them iff the stencil carries
        non-unit weights (:func:`~repro.core.stencil.resolve_weighted`).
    """
    weighted = resolve_weighted(weighted, stencil)
    node_of_pos = np.asarray(node_of_pos)
    if node_of_pos.shape != (grid.size,):
        raise ValueError(f"node_of_pos must have shape ({grid.size},)")
    n_nodes = int(num_nodes if num_nodes is not None else node_of_pos.max() + 1)
    per_node = np.zeros(n_nodes, dtype=np.float64)
    total = 0.0
    weights = stencil.weight_array() if weighted else np.ones(stencil.k)
    for off, w in zip(stencil.offsets, weights):
        valid, tgt = grid.shift_ranks(off)
        src_nodes = node_of_pos
        crossing = valid & (src_nodes != node_of_pos[tgt])
        total += w * float(crossing.sum())
        # w * count (not count repeated additions of w): the exact
        # accumulation IncrementalCost._per_node uses, so the two paths
        # are bit-identical for arbitrary float weights (w=0.1 over six
        # edges differs in the last ulp between the two orders —
        # tests/test_cost_weight_parity.py pins this).
        per_node += w * np.bincount(src_nodes[crossing],
                                    minlength=n_nodes).astype(np.float64)
    bottleneck = int(per_node.argmax()) if n_nodes else 0
    return MappingCost(j_sum=total, j_max=float(per_node.max(initial=0.0)),
                       per_node=per_node, bottleneck=bottleneck)
