"""Arbitrary sparse communication graphs as first-class mapping problems.

The paper's machinery exploits Cartesian stencil structure, but *Better
Process Mapping and Sparse Quadratic Assignment* (1702.04164) shows the
same local search applies to mapping as sparse QAP over any communication
graph — and this repo already *generates* those graphs: MoE all-to-all
dispatch (``models/moe.py``), traced collectives
(:class:`~repro.analysis.hlo.CollectiveStat`).  This module is the bridge:

* :class:`CommGraph` — a directed weighted graph in CSR form with a
  stable content hash, plus extractors:
  :meth:`CommGraph.from_stencil` (exact stencil round-trip),
  :meth:`CommGraph.from_hlo` (replica-group edges weighted by
  :meth:`~repro.analysis.hlo.CollectiveStat.wire_bytes_per_device`),
  :meth:`CommGraph.from_moe` (expert-parallel all-to-all from an
  :class:`~repro.configs.ArchConfig`), and :func:`arch_comm_graph`
  (a full-arch TP/DP/MoE composite).
* :class:`GraphGrid` — the graph re-expressed in the *grid protocol*
  (``dims`` / ``periodic`` / ``coords()`` / ``shift_ranks()``), so the
  entire refine stack — ``NeighborTable`` / ``IncrementalCost`` /
  ``PortfolioCost``, every registered refiner, ``evaluate``, linksim
  replay — runs on graphs **unmodified**.
* :class:`MaskedGraphGrid` — the induced-subgraph analog of
  :class:`~repro.core.refine.hier.MaskedGrid`, so the hierarchical
  ``hier:`` stage recurses into graph subproblems too.

The trick: ``shift_ranks(offset)`` returns one *partial permutation* of
positions (≤1 out-edge per source, ≤1 in-edge per target — what makes
``NeighborTable``'s single-valued inverse sound).  A ``CommGraph``
therefore decomposes its edge set into **slots**: partial permutations of
uniform weight.  Slot ``j`` answers ``shift_ranks((j + 1,))``; the slot
weights form a synthetic 1-D :class:`~repro.core.stencil.Stencil` with
offsets ``((1,), (2,), ...)``.  For :meth:`from_stencil` graphs the slots
*are* the original per-offset ``shift_ranks`` arrays (stored, not
re-derived), which is what makes the stencil round-trip bit-exact: the
graph path builds the very same ``NeighborTable`` arrays, weights, and
crossing counts as the grid path, so J_sum / J_max / per-node loads and
every scalar and batched swap delta agree to the last bit (pinned by
``tests/test_graph.py``).  General graphs derive slots by a deterministic
greedy coloring per weight class.

Usage::

    from repro.core import CommGraph, MappingProblem, parse_plan

    g = CommGraph.from_stencil(grid, stencil)        # exact round-trip
    g = CommGraph.from_hlo(hlo_module, num_devices=8)
    g = CommGraph.from_moe("mixtral_8x7b", num_devices=64)

    problem = MappingProblem.from_graph(g, node_sizes=(8,) * 8)
    sol = parse_plan("annealed:graphgreedy").solve(problem)
"""
from __future__ import annotations

import hashlib
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .stencil import Stencil

__all__ = ["CommGraph", "GraphGrid", "MaskedGraphGrid", "arch_comm_graph"]


# ---------------------------------------------------------------------------
# the graph


class CommGraph:
    """A directed, weighted communication graph in CSR form.

    ``indptr``/``indices``/``weights`` are the usual CSR triplet over
    ``n`` vertices (MPI ranks / devices): vertex ``u``'s out-edges are
    ``indices[indptr[u]:indptr[u+1]]`` with byte weights
    ``weights[...]``.  Edges are coalesced (one entry per ``(src, dst)``,
    duplicate weights summed), sorted by ``(src, dst)``, strictly
    positive, and never self-loops — construction canonicalizes, so two
    graphs built from the same edge multiset in any order are
    array-identical and share a :meth:`content_hash`.

    ``slots`` is the partial-permutation decomposition the cost core
    consumes (see the module docstring).  Stencil-extracted graphs carry
    their slots *and* provenance (mesh shape, periodicity, offsets,
    weights) explicitly, so the round trip back to the grid path is
    structural, not reconstructed.
    """

    def __init__(self, n: int, indptr: np.ndarray, indices: np.ndarray,
                 weights: np.ndarray, name: str = "graph",
                 provenance: Optional[dict] = None,
                 slots: Optional[List[Tuple[float, np.ndarray,
                                            np.ndarray]]] = None):
        self.n = int(n)
        self.indptr = np.ascontiguousarray(indptr, dtype=np.int64)
        self.indices = np.ascontiguousarray(indices, dtype=np.int64)
        self.weights = np.ascontiguousarray(weights, dtype=np.float64)
        self.name = str(name)
        self.provenance = provenance
        self._slots = slots
        self._hash: Optional[str] = None
        if self.n <= 0:
            raise ValueError("CommGraph needs at least one vertex")
        if self.indptr.shape != (self.n + 1,):
            raise ValueError(f"indptr must have shape ({self.n + 1},)")
        if self.indptr[0] != 0 or self.indptr[-1] != len(self.indices):
            raise ValueError("malformed indptr")
        if np.any(np.diff(self.indptr) < 0):
            raise ValueError("indptr must be non-decreasing")
        if len(self.indices) != len(self.weights):
            raise ValueError("indices/weights length mismatch")
        if len(self.indices) == 0:
            raise ValueError("CommGraph needs at least one edge (an "
                             "edgeless graph has nothing to map for)")
        if np.any((self.indices < 0) | (self.indices >= self.n)):
            raise ValueError("edge target out of range")
        if np.any(self.weights <= 0):
            raise ValueError("edge weights must be > 0 (drop zero-weight "
                             "edges at construction)")
        for a in (self.indptr, self.indices, self.weights):
            a.setflags(write=False)

    # -- construction -------------------------------------------------------

    @classmethod
    def from_edges(cls, n: int, src: Sequence[int], dst: Sequence[int],
                   weights: Union[float, Sequence[float]] = 1.0,
                   name: str = "graph",
                   provenance: Optional[dict] = None,
                   slots=None) -> "CommGraph":
        """Build from parallel edge arrays; duplicates coalesce (weights
        sum), zero/negative-weight edges and self-loops are dropped."""
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        w = np.broadcast_to(np.asarray(weights, dtype=np.float64),
                            src.shape).copy()
        if src.shape != dst.shape:
            raise ValueError("src/dst length mismatch")
        n = int(n)
        if len(src) and (src.min() < 0 or dst.min() < 0
                         or max(src.max(), dst.max()) >= n):
            raise ValueError("edge endpoint out of range")
        keep = (src != dst) & (w > 0)
        src, dst, w = src[keep], dst[keep], w[keep]
        # coalesce on (src, dst): sort, then segment-sum the weights
        order = np.lexsort((dst, src))
        src, dst, w = src[order], dst[order], w[order]
        if len(src):
            new = np.ones(len(src), dtype=bool)
            new[1:] = (src[1:] != src[:-1]) | (dst[1:] != dst[:-1])
            seg = np.cumsum(new) - 1
            usrc, udst = src[new], dst[new]
            uw = np.bincount(seg, weights=w, minlength=int(seg[-1]) + 1)
        else:
            usrc = udst = np.empty(0, dtype=np.int64)
            uw = np.empty(0, dtype=np.float64)
        indptr = np.zeros(n + 1, dtype=np.int64)
        np.add.at(indptr, usrc + 1, 1)
        indptr = np.cumsum(indptr)
        return cls(n, indptr, udst, uw, name=name,
                   provenance=provenance, slots=slots)

    @classmethod
    def from_stencil(cls, grid, stencil: Stencil,
                     name: Optional[str] = None) -> "CommGraph":
        """The exact graph of a stencil on a grid: one slot per offset,
        holding that offset's ``shift_ranks`` arrays verbatim.  The slot
        weights are the stencil weights in offset order (duplicates kept —
        never regrouped), so the graph path reproduces the grid path's
        arithmetic bit-for-bit."""
        slots = []
        src_all, dst_all, w_all = [], [], []
        for j, off in enumerate(stencil.offsets):
            valid, tgt = grid.shift_ranks(off)
            valid = np.ascontiguousarray(valid, dtype=bool)
            tgt = np.ascontiguousarray(tgt, dtype=np.int64)
            w = float(stencil.weights[j])
            slots.append((w, valid, tgt))
            s = np.nonzero(valid)[0]
            src_all.append(s)
            dst_all.append(tgt[s])
            w_all.append(np.full(len(s), w))
        prov = {
            "mesh_shape": tuple(int(d) for d in grid.dims),
            "periodic": tuple(bool(b) for b in grid.periodic),
            "offsets": stencil.offsets,
            "weights": stencil.weights,
        }
        return cls.from_edges(
            grid.size, np.concatenate(src_all), np.concatenate(dst_all),
            np.concatenate(w_all),
            name=name or f"stencil:{stencil.name or 'custom'}",
            provenance=prov, slots=slots)

    @classmethod
    def from_hlo(cls, module, num_devices: Optional[int] = None,
                 name: Optional[str] = None) -> "CommGraph":
        """Extract the device communication graph from traced HLO.

        ``module`` is an :class:`~repro.analysis.hlo.HloModule` (or HLO
        text, parsed here).  Per collective, per participant, out-edge
        weights follow the same ring/pairwise wire model as linksim:

        * ring collectives (all-reduce / all-gather / reduce-scatter) —
          one edge to the next group member in device-id ring order,
          weighted exactly
          :meth:`~repro.analysis.hlo.CollectiveStat.wire_bytes_per_device`
          (the whole per-device wire volume traverses one ring hop);
        * all-to-all — ``g - 1`` edges to every other member, each
          ``wire_bytes_per_device / (g - 1)``;
        * collective-permute — one edge per ``(src, dst)`` pair at
          ``payload_bytes * multiplier``.

        ``replica_groups={}`` (all devices) needs ``num_devices``; with
        explicit groups it is inferred from the largest id.  Duplicate
        ``(src, dst)`` edges across collectives coalesce by summing.
        """
        from ..analysis.hlo import parse_hlo
        import dataclasses
        if isinstance(module, str):
            module = parse_hlo(module)
        stats = list(module.collectives())
        if not stats:
            raise ValueError("HLO module has no collectives to extract")
        if num_devices is None:
            seen = -1
            for c in stats:
                for grp in (c.groups or []):
                    seen = max(seen, max(int(x) for x in grp))
                for s, d in (c.pairs or []):
                    seen = max(seen, int(s), int(d))
            if seen < 0:
                raise ValueError("num_devices required: module only has "
                                 "replica_groups={} collectives")
            num_devices = seen + 1
        n = int(num_devices)
        src, dst, w = [], [], []
        for c in stats:
            if c.pairs is not None:
                for s, d in c.pairs:
                    src.append(int(s))
                    dst.append(int(d))
                    w.append(c.payload_bytes * c.multiplier)
                continue
            groups = c.groups if c.groups else [list(range(n))]
            # wire_bytes_per_device reads group_size off the stat; pin the
            # resolved groups on a copy so the weights match it exactly
            # (the satellite property tests check this equality).
            cc = dataclasses.replace(c, groups=groups)
            wire = cc.wire_bytes_per_device()
            for grp in groups:
                members = sorted(int(x) for x in grp)
                g = len(members)
                if g <= 1:
                    continue
                if c.opcode.startswith(("all-to-all", "ragged")):
                    per_pair = wire / (g - 1)
                    for i, s in enumerate(members):
                        for d in members:
                            if d != s:
                                src.append(s)
                                dst.append(d)
                                w.append(per_pair)
                else:                         # ring in device-id order
                    for i, s in enumerate(members):
                        src.append(s)
                        dst.append(members[(i + 1) % g])
                        w.append(wire)
        return cls.from_edges(
            n, src, dst, w,
            name=name or f"hlo:{getattr(module, 'entry', 'module')}")

    @classmethod
    def from_moe(cls, arch, num_devices: int, *,
                 tokens_per_device: int = 4096,
                 dtype_bytes: Optional[int] = None,
                 name: Optional[str] = None) -> "CommGraph":
        """Expert-parallel all-to-all graph of an MoE arch.

        Devices split into contiguous EP groups of
        ``g = min(n_experts, num_devices)``; each MoE layer dispatches
        ``tokens_per_device * top_k`` activations of ``d_model`` and
        combines them back, so every directed pair inside a group carries
        ``round(2 * tokens * top_k * d_model * dtype_bytes * n_moe_layers
        / g)`` bytes.  Weights are rounded to whole bytes so linksim
        replay of the mapped graph agrees with the graph objective
        *exactly* (float64 edge sums of integers are exact below 2**53).
        """
        arch = _resolve_arch(arch)
        if arch.n_experts <= 0:
            raise ValueError(f"{arch.name!r} has no experts; from_moe needs "
                             "an MoE arch (n_experts > 0)")
        n = int(num_devices)
        g = min(arch.n_experts, n)
        if g < 2:
            raise ValueError("expert-parallel groups need >= 2 devices")
        if n % g:
            raise ValueError(f"num_devices={n} not divisible by EP group "
                             f"size {g}")
        if dtype_bytes is None:
            from ..analysis.hlo import DTYPE_BYTES
            dtype_bytes = DTYPE_BYTES.get(arch.compute_dtype, 2)
        n_moe_layers = arch.n_layers - arch.n_dense_layers
        payload = (2.0 * tokens_per_device * arch.top_k * arch.d_model
                   * dtype_bytes * n_moe_layers)
        per_pair = max(1.0, round(payload / g))
        src, dst = [], []
        for base in range(0, n, g):
            for s in range(base, base + g):
                for d in range(base, base + g):
                    if d != s:
                        src.append(s)
                        dst.append(d)
        return cls.from_edges(n, src, dst, per_pair,
                              name=name or f"moe:{arch.name}")

    # -- the grid protocol --------------------------------------------------

    def slots(self) -> List[Tuple[float, np.ndarray, np.ndarray]]:
        """The partial-permutation decomposition: ``[(weight, valid,
        tgt), ...]`` where each slot has ≤1 out-edge per source and ≤1
        in-edge per target (sound ``NeighborTable`` inverse).  Stored
        verbatim for stencil-extracted graphs; otherwise derived once by
        deterministic greedy coloring — weight classes descending, edges
        in CSR ``(src, dst)`` order, first slot with a free source *and*
        free target."""
        if self._slots is None:
            self._slots = self._greedy_slots()
        return self._slots

    def _greedy_slots(self):
        n = self.n
        src_of = np.repeat(np.arange(n, dtype=np.int64),
                           np.diff(self.indptr))
        slots = []
        for wval in np.unique(self.weights)[::-1]:
            sel = np.nonzero(self.weights == wval)[0]
            class_slots = []          # (valid, tgt, in_used)
            for e in sel:
                s, d = int(src_of[e]), int(self.indices[e])
                for valid, tgt, in_used in class_slots:
                    if not valid[s] and not in_used[d]:
                        valid[s] = True
                        tgt[s] = d
                        in_used[d] = True
                        break
                else:
                    valid = np.zeros(n, dtype=bool)
                    tgt = np.arange(n, dtype=np.int64)
                    in_used = np.zeros(n, dtype=bool)
                    valid[s] = True
                    tgt[s] = d
                    in_used[d] = True
                    class_slots.append((valid, tgt, in_used))
            slots += [(float(wval), valid, tgt)
                      for valid, tgt, _ in class_slots]
        for _, valid, tgt in slots:
            valid.setflags(write=False)
            tgt.setflags(write=False)
        return slots

    def slot_stencil(self) -> Stencil:
        """The synthetic 1-D stencil whose offset ``(j + 1,)`` selects slot
        ``j`` of :meth:`slots` (weights = slot weights, duplicates kept)."""
        slots = self.slots()
        return Stencil(tuple((j + 1,) for j in range(len(slots))),
                       weights=tuple(s[0] for s in slots),
                       name=f"slots:{self.name}")

    def grid(self) -> "GraphGrid":
        """This graph in the grid protocol (see :class:`GraphGrid`)."""
        return GraphGrid(self)

    # -- identity -----------------------------------------------------------

    @property
    def num_edges(self) -> int:
        return len(self.indices)

    def total_weight(self) -> float:
        return float(self.weights.sum())

    def content_hash(self) -> str:
        """Stable identity over the canonical CSR content (construction
        order never matters) plus stencil provenance when present — two
        differently-shaped grids with the same flattened edges must not
        collide, since base mappers see the provenance geometry."""
        if self._hash is None:
            h = hashlib.sha256()
            h.update(f"n={self.n};".encode())
            h.update(self.indptr.tobytes())
            h.update(self.indices.tobytes())
            h.update(self.weights.tobytes())
            if self.provenance is not None:
                p = self.provenance
                h.update(repr((tuple(p["mesh_shape"]), tuple(p["periodic"]),
                               tuple(p["offsets"]),
                               tuple(p["weights"]))).encode())
            self._hash = h.hexdigest()[:32]
        return self._hash

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (f"CommGraph({self.name!r}, n={self.n}, "
                f"edges={self.num_edges}, slots={len(self.slots())})")


# ---------------------------------------------------------------------------
# the grid protocol over a graph


class GraphGrid:
    """A :class:`CommGraph` wearing the grid protocol.

    Duck-types everything the cost/refine stack reads off a
    :class:`~repro.core.grid.CartGrid`: ``dims`` (``(n,)``), ``periodic``,
    ``ndim`` / ``size``, ``coords()`` and ``shift_ranks(offset)`` — where
    offset ``(j + 1,)`` answers with slot ``j``'s ``(valid, tgt)`` arrays.
    ``NeighborTable.build`` / ``evaluate`` / every refiner /
    ``stencil_collectives`` consume it unchanged.  Picklable (the sharded
    engine ships it to worker processes whole).
    """

    def __init__(self, graph: CommGraph):
        self.graph = graph

    # grid protocol ---------------------------------------------------------

    @property
    def dims(self) -> Tuple[int, ...]:
        return (self.graph.n,)

    @property
    def periodic(self) -> Tuple[bool, ...]:
        return (False,)

    @property
    def ndim(self) -> int:
        return 1

    @property
    def size(self) -> int:
        return self.graph.n

    def coords(self) -> np.ndarray:
        return np.arange(self.graph.n, dtype=np.int64)[:, None]

    def shift_ranks(self, offset) -> Tuple[np.ndarray, np.ndarray]:
        j = int(offset[0]) - 1
        slots = self.graph.slots()
        if not (0 <= j < len(slots)):
            raise ValueError(f"offset {tuple(offset)!r} names no slot of "
                             f"{self.graph!r} (use the slot_stencil)")
        _, valid, tgt = slots[j]
        return valid, tgt

    # extensions ------------------------------------------------------------

    def masked(self, active: np.ndarray) -> "MaskedGraphGrid":
        """The induced subgraph on ``active`` positions, in the same
        protocol — the graph analog of
        :class:`~repro.core.refine.hier.MaskedGrid` (``hier:`` calls this
        when the grid offers it)."""
        return MaskedGraphGrid(self, active)

    @property
    def cache_token(self) -> str:
        """Content identity for subproblem cache keys (two graphs with
        equal size and slot count must never share a hier subtree key)."""
        return self.graph.content_hash()

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"GraphGrid({self.graph!r})"


class MaskedGraphGrid(GraphGrid):
    """A :class:`GraphGrid` restricted to its ``active`` positions: slot
    edges survive only when *both* endpoints are active (the induced
    subgraph — exactly :class:`~repro.core.refine.hier.MaskedGrid`'s
    semantics on a Cartesian grid)."""

    def __init__(self, base: GraphGrid, active: np.ndarray):
        super().__init__(base.graph)
        active = np.asarray(active, dtype=bool)
        if active.shape != (base.size,):
            raise ValueError(f"active mask must have shape ({base.size},)")
        if isinstance(base, MaskedGraphGrid):
            active = active & base.active
        self.active = active
        self.active.setflags(write=False)

    def shift_ranks(self, offset) -> Tuple[np.ndarray, np.ndarray]:
        valid, tgt = super().shift_ranks(offset)
        return valid & self.active & self.active[tgt], tgt

    @property
    def cache_token(self) -> str:
        return (self.graph.content_hash() + ":masked:"
                + hashlib.sha256(self.active.tobytes()).hexdigest()[:16])

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (f"MaskedGraphGrid({self.graph!r}, "
                f"active={int(self.active.sum())}/{self.size})")


# ---------------------------------------------------------------------------
# full-arch composite builder


def _resolve_arch(arch):
    if isinstance(arch, str):
        from ..configs import get_arch
        return get_arch(arch)
    return arch


def arch_comm_graph(arch, num_devices: int, *,
                    model_parallel: Optional[int] = None,
                    tokens_per_device: int = 1024,
                    grad_accum: int = 64,
                    permute_seed: Optional[int] = 0,
                    name: Optional[str] = None) -> CommGraph:
    """The composite training communication graph of one arch on
    ``num_devices`` devices: tensor-parallel activation all-reduce rings
    (two per layer) inside each model group, data-parallel gradient
    all-reduce rings across groups (amortized by ``grad_accum``), and —
    for MoE archs — the expert-parallel all-to-all of
    :meth:`CommGraph.from_moe` over the model groups.

    ``permute_seed`` applies a deterministic device-id permutation to the
    finished graph — modeling a scheduler that hands out ranks in
    arbitrary order, which is precisely the situation where mapping beats
    the blocked identity (the graph benchmark's claim).  ``None`` keeps
    the natural model-major order.  All weights are whole bytes, so
    linksim replay is exact.
    """
    arch = _resolve_arch(arch)
    n = int(num_devices)
    if model_parallel is None:
        model_parallel = max(d for d in range(1, min(8, n) + 1) if n % d == 0)
    mp = int(model_parallel)
    if n % mp:
        raise ValueError(f"num_devices={n} not divisible by "
                         f"model_parallel={mp}")
    dp = n // mp
    from ..analysis.hlo import DTYPE_BYTES
    act_bytes = DTYPE_BYTES.get(arch.compute_dtype, 2)
    src, dst, w = [], [], []

    def ring(members, weight):
        g = len(members)
        if g < 2 or weight <= 0:
            return
        for i, s in enumerate(members):
            src.append(s)
            dst.append(members[(i + 1) % g])
            w.append(weight)

    # TP: 2 activation all-reduces per layer per step, ring inside each
    # model group (ranks d*mp + m for fixed d)
    b_tp = float(tokens_per_device) * arch.d_model * act_bytes
    w_tp = round(2.0 * b_tp * (mp - 1) / mp * 2 * arch.n_layers)
    for d in range(dp):
        ring([d * mp + m for m in range(mp)], w_tp)
    # DP: one gradient all-reduce per grad_accum micro-steps, sharded over
    # the mp-way model split, ring across each data group (fixed m)
    b_dp = arch.param_count() * act_bytes / mp / max(1, grad_accum)
    w_dp = round(2.0 * b_dp * (dp - 1) / dp)
    for m in range(mp):
        ring([d * mp + m for d in range(dp)], w_dp)
    # EP: MoE all-to-all over the model groups
    if arch.n_experts > 0 and mp >= 2:
        moe = CommGraph.from_moe(arch, mp,
                                 tokens_per_device=tokens_per_device)
        msrc = np.repeat(np.arange(mp, dtype=np.int64), np.diff(moe.indptr))
        for d in range(dp):
            base = d * mp
            src.extend((base + msrc).tolist())
            dst.extend((base + moe.indices).tolist())
            w.extend(moe.weights.tolist())
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    if permute_seed is not None:
        perm = np.random.default_rng(int(permute_seed)).permutation(n)
        src, dst = perm[src], perm[dst]
    return CommGraph.from_edges(n, src, dst, np.asarray(w, dtype=np.float64),
                                name=name or f"arch:{arch.name}")
