"""Core library: the paper's contribution — stencil-aware process-to-node
mapping for Cartesian grids (Hunold et al., CS.DC 2020)."""
from .cost import MappingCost, blocked_assignment, evaluate, node_of_rank_blocked
from .cost_delta import BatchSwapDelta, Delta, IncrementalCost, NeighborTable
from .grid import CartGrid, dims_create
from .mapping import (ANNEALED_PREFIX, MAPPERS, REFINE_PREFIXES,
                      REFINED_PREFIX, SCHEDULED_PREFIX, BlockedMapper,
                      GraphGreedyMapper, HyperplaneMapper, KDTreeMapper,
                      Mapper, MapperInapplicable, NodecartMapper,
                      RandomMapper, StencilStripsMapper, available_mappers,
                      get_mapper)
from .refine import (RefinedMapper, RefineResult, ScheduledRefiner,
                     SwapRefiner, refine_assignment)
from .remap import device_layout, layout_cost, mapped_device_array
from .stencil import Stencil

__all__ = [
    "CartGrid", "dims_create", "Stencil", "MappingCost", "evaluate",
    "blocked_assignment", "node_of_rank_blocked",
    "BatchSwapDelta", "Delta", "IncrementalCost", "NeighborTable",
    "Mapper", "MapperInapplicable", "MAPPERS", "REFINED_PREFIX",
    "SCHEDULED_PREFIX", "ANNEALED_PREFIX", "REFINE_PREFIXES",
    "get_mapper", "available_mappers",
    "BlockedMapper", "RandomMapper", "NodecartMapper", "HyperplaneMapper",
    "KDTreeMapper", "StencilStripsMapper", "GraphGreedyMapper",
    "SwapRefiner", "ScheduledRefiner", "RefineResult", "refine_assignment",
    "RefinedMapper",
    "device_layout", "layout_cost", "mapped_device_array",
]
