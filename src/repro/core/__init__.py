"""Core library: the paper's contribution — stencil-aware process-to-node
mapping for Cartesian grids (Hunold et al., CS.DC 2020)."""
from .cost import MappingCost, blocked_assignment, evaluate, node_of_rank_blocked
from .cost_delta import (BatchSwapDelta, Delta, IncrementalCost,
                         NeighborTable, PortfolioCost, PortfolioSwapDelta)
from .graph import CommGraph, GraphGrid, MaskedGraphGrid, arch_comm_graph
from .grid import CartGrid, dims_create
from .mapping import (ANNEALED_PREFIX, DEVICE_PREFIX, HIER_PREFIX, MAPPERS,
                      PORTFOLIO_PREFIX, REFINE_PREFIXES, REFINED_PREFIX,
                      SCHEDULED_PREFIX, SHARDED_PREFIX, BlockedMapper,
                      GraphGreedyMapper, HyperplaneMapper, KDTreeMapper,
                      Mapper, MapperInapplicable, NodecartMapper,
                      RandomMapper, StencilStripsMapper, available_mappers,
                      get_mapper, parse_mapper_options, split_mapper_name)
from .refine import (BaseStage, DevicePortfolioRefiner, HierRefiner,
                     MaskedGrid, PortfolioRefiner,
                     RefinedMapper, RefineResult, RefineStage,
                     ScheduledRefiner, ShardedPortfolioRefiner, Stage,
                     StageResult, SwapRefiner, hier_subtree_cache,
                     refine_assignment, stacked_crossing_counts)
from .plan import (CartResult, MappingPlan, MappingProblem, MappingSolution,
                   PlanCache, cart_create, default_plan_cache, graph_create,
                   parse_plan)
from .remap import (device_layout, elastic_portfolio_plan, ensure_refined,
                    layout_cost, mapped_device_array, repair_layout)
from .repair import (RepairInapplicable, RepairSeed, RepairStage,
                     absorbed_node_sizes, downweighted_node_sizes,
                     repair_plan, repair_seed, transfer_positions)
from .stencil import Stencil, resolve_weighted

__all__ = [
    "CommGraph", "GraphGrid", "MaskedGraphGrid", "arch_comm_graph",
    "CartGrid", "dims_create", "Stencil", "resolve_weighted", "MappingCost",
    "evaluate", "blocked_assignment", "node_of_rank_blocked",
    "BatchSwapDelta", "Delta", "IncrementalCost", "NeighborTable",
    "PortfolioCost", "PortfolioSwapDelta",
    "Mapper", "MapperInapplicable", "MAPPERS", "REFINED_PREFIX",
    "SCHEDULED_PREFIX", "ANNEALED_PREFIX", "PORTFOLIO_PREFIX",
    "SHARDED_PREFIX", "DEVICE_PREFIX", "HIER_PREFIX", "REFINE_PREFIXES",
    "get_mapper",
    "available_mappers",
    "split_mapper_name", "parse_mapper_options",
    "BlockedMapper", "RandomMapper", "NodecartMapper", "HyperplaneMapper",
    "KDTreeMapper", "StencilStripsMapper", "GraphGreedyMapper",
    "SwapRefiner", "ScheduledRefiner", "PortfolioRefiner",
    "ShardedPortfolioRefiner", "DevicePortfolioRefiner",
    "HierRefiner", "MaskedGrid", "hier_subtree_cache",
    "stacked_crossing_counts", "RefineResult",
    "refine_assignment", "RefinedMapper",
    "Stage", "StageResult", "BaseStage", "RefineStage",
    "MappingProblem", "MappingPlan", "MappingSolution", "parse_plan",
    "PlanCache", "default_plan_cache", "cart_create", "graph_create",
    "CartResult",
    "device_layout", "layout_cost", "mapped_device_array", "ensure_refined",
    "elastic_portfolio_plan", "repair_layout",
    "RepairInapplicable", "RepairSeed", "RepairStage", "repair_seed",
    "repair_plan", "transfer_positions", "absorbed_node_sizes",
    "downweighted_node_sizes",
]
