"""Core library: the paper's contribution — stencil-aware process-to-node
mapping for Cartesian grids (Hunold et al., CS.DC 2020)."""
from .cost import MappingCost, blocked_assignment, evaluate, node_of_rank_blocked
from .grid import CartGrid, dims_create
from .mapping import (MAPPERS, BlockedMapper, GraphGreedyMapper,
                      HyperplaneMapper, KDTreeMapper, Mapper,
                      MapperInapplicable, NodecartMapper, RandomMapper,
                      StencilStripsMapper, get_mapper)
from .remap import device_layout, layout_cost, mapped_device_array
from .stencil import Stencil

__all__ = [
    "CartGrid", "dims_create", "Stencil", "MappingCost", "evaluate",
    "blocked_assignment", "node_of_rank_blocked",
    "Mapper", "MapperInapplicable", "MAPPERS", "get_mapper",
    "BlockedMapper", "RandomMapper", "NodecartMapper", "HyperplaneMapper",
    "KDTreeMapper", "StencilStripsMapper", "GraphGreedyMapper",
    "device_layout", "layout_cost", "mapped_device_array",
]
