"""Incremental delta-evaluation of mapping cost (local-search engine).

:func:`~repro.core.cost.evaluate` re-walks every (rank, offset) edge of the
grid, which makes a local-search step O(p * k).  :class:`IncrementalCost`
precomputes the stencil neighbour table once (one ``grid.shift_ranks`` call
per offset, plus its inverse) and afterwards answers "what happens to
J_sum / per-node load if position ``p`` moves from node ``a`` to node ``b``"
by touching only the O(k) edges incident to the affected positions.

Two query paths share the same integer-count core:

* scalar — :meth:`IncrementalCost.delta_move` / :meth:`~IncrementalCost.delta_swap`
  score one proposal at a time (O(k) per call, Python-level);
* batch — :meth:`IncrementalCost.batch_swap_deltas` scores an *array* of
  swap proposals in a handful of numpy passes (O(m * k) work with no
  Python-per-proposal overhead).  This is what lets
  :class:`~repro.core.refine.SwapRefiner` evaluate the entire boundary
  frontier of a 48x48 grid in one shot instead of ~50k interpreted calls.

State is kept as *integer* crossing counts per (node, offset), so the
reconstructed ``j_sum`` matches a full recomputation bit-for-bit (same
``total += w * count`` accumulation order as ``evaluate``), as does
``per_node`` for **arbitrary float weights**: both sides accumulate
``w * count`` per offset in ascending-offset order (``evaluate`` used to
add ``w`` count times instead, which differs in the last ulp for weights
like 0.1 — fixed, and pinned by ``tests/test_cost_weight_parity.py``).
The batch path
accumulates per-offset counts in the same ascending-``j`` order, so its
``d_j_sum`` / ``new_per_node`` are bit-exact with the scalar
:meth:`~IncrementalCost.delta_swap` / :meth:`~IncrementalCost.peek_per_node`
results.

Usage::

    ic = IncrementalCost(grid, stencil, node_of_pos, num_nodes=N)
    d = ic.delta_swap(p, q)            # scalar preview
    ic.apply_swap(p, q)                # commit (counts updated in O(k))

    P, Q = candidate_pairs             # (m,) position arrays
    bd = ic.batch_swap_deltas(P, Q, with_loads=True)
    best = int(np.argmin(bd.d_j_sum))  # most J_sum-improving swap
    ic.apply_swap(int(P[best]), int(Q[best]))
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .cost import MappingCost
from .grid import CartGrid
from .stencil import Stencil, resolve_weighted

__all__ = ["IncrementalCost", "NeighborTable", "Delta", "BatchSwapDelta",
           "PortfolioCost", "PortfolioSwapDelta", "LOAD_CHUNK_ELEMS",
           "stacked_count_arrays"]


def stacked_count_arrays(table: "NeighborTable", assignments: np.ndarray,
                         num_nodes: int) -> Tuple[np.ndarray, np.ndarray]:
    """Integer crossing counts for stacked (K, p) assignments:
    ``((K, k) count_off, (K, N, k) count_node)``.

    THE crossing-count builder — :class:`PortfolioCost` initializes from
    it, and the sharded engine's numpy fallback
    (:func:`repro.core.refine.sharded.stacked_crossing_counts`) calls the
    same function, so the ``counts=`` fast path's "bit-interchangeable
    producers" contract is upheld mechanically rather than by keeping two
    copies of this loop in sync.
    """
    A = np.asarray(assignments, dtype=np.int64)
    K, k = A.shape[0], table.out_valid.shape[0]
    count_off = np.zeros((K, k), dtype=np.int64)
    count_node = np.zeros((K, int(num_nodes), k), dtype=np.int64)
    for j in range(k):
        valid, tgt = table.out_valid[j], table.out_tgt[j]
        crossing = valid[None, :] & (A != A[:, tgt])
        count_off[:, j] = crossing.sum(axis=1)
        rr, pp = np.nonzero(crossing)
        np.add.at(count_node[:, :, j], (rr, A[rr, pp]), 1)
    return count_off, count_node

#: Load-matrix scoring materializes (chunk, N) float matrices; callers chunk
#: proposals so chunk * N stays below this, bounding peak extra memory to
#: ~tens of MB no matter how large the frontier (or portfolio) is.
LOAD_CHUNK_ELEMS = 1 << 21


@dataclass(frozen=True)
class NeighborTable:
    """Per-offset forward and inverse neighbour lookups for one grid."""

    #: (k, p) bool — does position i have an out-neighbour under offset j?
    out_valid: np.ndarray
    #: (k, p) int — the out-neighbour's position (garbage where invalid).
    out_tgt: np.ndarray
    #: (k, p) bool — does position i have an in-neighbour under offset j?
    in_valid: np.ndarray
    #: (k, p) int — the in-neighbour's position (garbage where invalid).
    in_src: np.ndarray

    @staticmethod
    def build(grid: CartGrid, stencil: Stencil) -> "NeighborTable":
        p, k = grid.size, stencil.k
        out_valid = np.zeros((k, p), dtype=bool)
        out_tgt = np.zeros((k, p), dtype=np.int64)
        in_valid = np.zeros((k, p), dtype=bool)
        in_src = np.zeros((k, p), dtype=np.int64)
        for j, off in enumerate(stencil.offsets):
            valid, tgt = grid.shift_ranks(off)
            out_valid[j] = valid
            out_tgt[j] = tgt
            # a coordinate shift is injective on its valid domain, so the
            # inverse is single-valued: in_src[j][tgt[q]] = q.
            src = np.nonzero(valid)[0]
            in_valid[j][tgt[src]] = True
            in_src[j][tgt[src]] = src
        return NeighborTable(out_valid, out_tgt, in_valid, in_src)

    @staticmethod
    def from_graph(graph) -> "NeighborTable":
        """The table of a :class:`~repro.core.graph.CommGraph`: one row
        per slot of its partial-permutation decomposition (each slot is
        injective on its valid domain by construction, which is exactly
        what keeps the single-valued inverse above sound).  For
        stencil-extracted graphs this returns arrays bit-identical to
        ``build(grid, stencil)`` on the original grid."""
        return NeighborTable.build(graph.grid(), graph.slot_stencil())


@dataclass(frozen=True)
class Delta:
    """Effect of a proposed move/swap.  ``d_count_off[j]`` is the change in
    the number of crossing edges under offset j; ``d_count_node`` maps
    ``(node, offset) -> count change`` for the per-node outgoing loads."""

    d_j_sum: float
    d_count_off: np.ndarray                     # (k,) int64
    d_count_node: Dict[Tuple[int, int], int]    # (node, offset) -> int


@dataclass(frozen=True)
class BatchSwapDelta:
    """Vectorized effect of ``m`` proposed swaps (one row per pair).

    ``d_count_off[i, j]`` is the change in crossing edges under offset j if
    pair i is swapped; ``d_j_sum`` folds in the offset weights with the same
    ascending-offset accumulation as the scalar path, so
    ``d_j_sum[i] == delta_swap(p[i], q[i]).d_j_sum`` exactly.  When built
    ``with_loads``, ``new_per_node[i]`` equals
    ``peek_per_node(delta_swap(p[i], q[i]))`` bit-for-bit and ``new_j_max``
    is its row-max."""

    p: np.ndarray                         # (m,) int64
    q: np.ndarray                         # (m,) int64
    d_count_off: np.ndarray               # (m, k) int64
    d_j_sum: np.ndarray                   # (m,) float64
    new_per_node: Optional[np.ndarray]    # (m, N) float64 or None
    new_j_max: Optional[np.ndarray]       # (m,) float64 or None

    @property
    def size(self) -> int:
        return int(self.p.size)


class IncrementalCost:
    """Mutable mapping-cost state with O(k) move/swap deltas.

    Args:
      node_of_pos: (p,) node id owning each grid position (row-major); a
        private copy is taken.
      weighted: use the stencil's per-offset byte weights (as in
        ``evaluate(weighted=True)``); ``"auto"`` uses them iff the stencil
        carries non-unit weights.
    """

    def __init__(self, grid: CartGrid, stencil: Stencil,
                 node_of_pos: np.ndarray, num_nodes: Optional[int] = None,
                 weighted=False):
        node_of_pos = np.asarray(node_of_pos, dtype=np.int64)
        if node_of_pos.shape != (grid.size,):
            raise ValueError(f"node_of_pos must have shape ({grid.size},)")
        self.grid = grid
        self.stencil = stencil
        self.table = NeighborTable.build(grid, stencil)
        self.n_nodes = int(num_nodes if num_nodes is not None
                           else node_of_pos.max() + 1)
        self.weighted = resolve_weighted(weighted, stencil)
        self.weights = (stencil.weight_array() if self.weighted
                        else np.ones(stencil.k))
        self.node_of_pos = node_of_pos.copy()
        # integer crossing counts: (k,) total and (N, k) per source node
        k = stencil.k
        self._count_off = np.zeros(k, dtype=np.int64)
        self._count_node = np.zeros((self.n_nodes, k), dtype=np.int64)
        for j in range(k):
            valid, tgt = self.table.out_valid[j], self.table.out_tgt[j]
            crossing = valid & (self.node_of_pos != self.node_of_pos[tgt])
            self._count_off[j] = int(crossing.sum())
            np.add.at(self._count_node[:, j], self.node_of_pos[crossing], 1)
        self._per_node_cache: Optional[np.ndarray] = None

    @classmethod
    def from_graph(cls, graph, node_of_pos: np.ndarray,
                   num_nodes: Optional[int] = None,
                   weighted="auto") -> "IncrementalCost":
        """Cost state over a :class:`~repro.core.graph.CommGraph`: the
        graph's slot decomposition plays the stencil (offset ``(j+1,)`` =
        slot ``j``), so every delta query below works unchanged.  For
        stencil-extracted graphs the state — table, weights, counts — is
        bit-identical to the grid-path constructor."""
        return cls(graph.grid(), graph.slot_stencil(), node_of_pos,
                   num_nodes=num_nodes, weighted=weighted)

    # -- read-only views ----------------------------------------------------
    @property
    def j_sum(self) -> float:
        # identical accumulation order to evaluate(): total += w * count
        total = 0.0
        for j, w in enumerate(self.weights):
            total += float(self.weights[j]) * float(self._count_off[j])
        return total

    def _per_node(self) -> np.ndarray:
        # rebuilt from counts only after a commit (cache keeps repeated
        # j_max queries between swaps at O(N) instead of O(N*k))
        if self._per_node_cache is None:
            per_node = np.zeros(self.n_nodes, dtype=np.float64)
            for j, w in enumerate(self.weights):
                per_node += w * self._count_node[:, j]
            self._per_node_cache = per_node
        return self._per_node_cache

    @property
    def per_node(self) -> np.ndarray:
        return self._per_node().copy()

    @property
    def j_max(self) -> float:
        return float(self._per_node().max(initial=0.0))

    def cost(self) -> MappingCost:
        per_node = self.per_node
        bottleneck = int(per_node.argmax()) if self.n_nodes else 0
        return MappingCost(j_sum=self.j_sum,
                           j_max=float(per_node.max(initial=0.0)),
                           per_node=per_node, bottleneck=bottleneck)

    # -- edge enumeration ---------------------------------------------------
    def _edges_touching(self, positions: Sequence[int]) \
            -> List[Tuple[int, int, int]]:
        """Directed stencil edges (src, dst, offset) with an endpoint in
        ``positions``, each listed exactly once."""
        S = set(int(p) for p in positions)
        t = self.table
        edges: List[Tuple[int, int, int]] = []
        for s in S:
            for j in range(self.stencil.k):
                if t.out_valid[j, s]:
                    edges.append((s, int(t.out_tgt[j, s]), j))
                if t.in_valid[j, s]:
                    src = int(t.in_src[j, s])
                    if src not in S:   # else already listed as its out-edge
                        edges.append((src, s, j))
        return edges

    def _delta(self, overrides: Dict[int, int]) -> Delta:
        """Delta for reassigning ``overrides`` (position -> new node)."""
        node = self.node_of_pos
        d_count_off = np.zeros(self.stencil.k, dtype=np.int64)
        d_count_node: Dict[Tuple[int, int], int] = {}

        def bump(n: int, j: int, by: int):
            key = (n, j)
            d_count_node[key] = d_count_node.get(key, 0) + by

        for (u, v, j) in self._edges_touching(tuple(overrides)):
            old_u, old_v = int(node[u]), int(node[v])
            new_u = overrides.get(u, old_u)
            new_v = overrides.get(v, old_v)
            if old_u != old_v:
                d_count_off[j] -= 1
                bump(old_u, j, -1)
            if new_u != new_v:
                d_count_off[j] += 1
                bump(new_u, j, +1)
        d_j_sum = 0.0
        for j in range(self.stencil.k):
            d_j_sum += float(self.weights[j]) * float(d_count_off[j])
        return Delta(d_j_sum, d_count_off,
                     {k: v for k, v in d_count_node.items() if v != 0})

    # -- proposals ----------------------------------------------------------
    def delta_move(self, pos: int, new_node: int) -> Delta:
        """Delta if position ``pos`` is reassigned to ``new_node``.

        Note a bare move changes the per-node cardinalities — mapping
        pipelines that must respect the scheduler allocation should use
        :meth:`delta_swap` instead.
        """
        if not 0 <= new_node < self.n_nodes:
            raise ValueError(f"node {new_node} out of range")
        return self._delta({int(pos): int(new_node)})

    def delta_swap(self, p: int, q: int) -> Delta:
        """Delta if positions ``p`` and ``q`` exchange owning nodes."""
        p, q = int(p), int(q)
        return self._delta({p: int(self.node_of_pos[q]),
                            q: int(self.node_of_pos[p])})

    def delta_swap_j_sum(self, p: int, q: int) -> float:
        """J_sum-only fast path for swap proposals."""
        return self.delta_swap(p, q).d_j_sum

    def batch_swap_deltas(self, p_arr: Sequence[int], q_arr: Sequence[int],
                          with_loads: bool = False) -> BatchSwapDelta:
        """Score ``m`` swap proposals ``(p_arr[i], q_arr[i])`` in one shot.

        Enumerates, per offset, the same four directed-edge groups the
        scalar :meth:`delta_swap` walks — out-edges of p, out-edges of q,
        in-edges of p from outside the pair, in-edges of q from outside the
        pair — so every edge incident to a pair is counted exactly once and
        the integer ``d_count_off`` matches the scalar path bit-for-bit.

        ``with_loads=True`` additionally scatters the per-node count
        changes into an (m, N) matrix and returns the exact post-swap
        ``new_per_node`` / ``new_j_max`` (needed by J_max-objective
        refinement); it costs O(m * N) extra memory, so leave it off for
        pure J_sum scoring.
        """
        P = np.atleast_1d(np.asarray(p_arr, dtype=np.int64))
        Q = np.atleast_1d(np.asarray(q_arr, dtype=np.int64))
        if P.shape != Q.shape or P.ndim != 1:
            raise ValueError("p_arr and q_arr must be 1-d of equal length")
        if P.size and (P.min() < 0 or P.max() >= self.grid.size
                       or Q.min() < 0 or Q.max() >= self.grid.size):
            raise ValueError("positions out of range")
        node, t, k, m = self.node_of_pos, self.table, self.stencil.k, P.size
        A, B = node[P], node[Q]
        rows = np.arange(m)
        d_count_off = np.zeros((m, k), dtype=np.int64)
        new_per_node = (np.zeros((m, self.n_nodes), dtype=np.float64)
                        if with_loads else None)
        for j in range(k):
            dc = (np.zeros((m, self.n_nodes), dtype=np.int64)
                  if with_loads else None)
            # out-edges of p: source owner a -> b; target owner unchanged
            # unless the target is the partner (or, on degenerate periodic
            # axes, p itself).
            v1, t1 = t.out_valid[j, P], t.out_tgt[j, P]
            nv1 = np.where(t1 == Q, A, np.where(t1 == P, B, node[t1]))
            old1 = v1 & (node[t1] != A)
            new1 = v1 & (nv1 != B)
            # out-edges of q (mirror)
            v3, t3 = t.out_valid[j, Q], t.out_tgt[j, Q]
            nv3 = np.where(t3 == P, B, np.where(t3 == Q, A, node[t3]))
            old3 = v3 & (node[t3] != B)
            new3 = v3 & (nv3 != A)
            # in-edges from outside the pair (pair-internal edges are
            # already listed as out-edges above, same dedup as the scalar
            # ``src not in S`` rule)
            s2 = t.in_src[j, P]
            v2 = t.in_valid[j, P] & (s2 != Q) & (s2 != P)
            old2 = v2 & (node[s2] != A)
            new2 = v2 & (node[s2] != B)
            s4 = t.in_src[j, Q]
            v4 = t.in_valid[j, Q] & (s4 != P) & (s4 != Q)
            old4 = v4 & (node[s4] != B)
            new4 = v4 & (node[s4] != A)
            d_count_off[:, j] = (
                (new1.astype(np.int64) - old1) + (new2.astype(np.int64) - old2)
                + (new3.astype(np.int64) - old3) + (new4.astype(np.int64) - old4))
            if with_loads:
                # outgoing loads are counted at the *source* node
                np.subtract.at(dc, (rows[old1], A[old1]), 1)
                np.add.at(dc, (rows[new1], B[new1]), 1)
                np.subtract.at(dc, (rows[old3], B[old3]), 1)
                np.add.at(dc, (rows[new3], A[new3]), 1)
                n2 = node[s2]
                np.add.at(dc, (rows[new2 & ~old2], n2[new2 & ~old2]), 1)
                np.subtract.at(dc, (rows[old2 & ~new2], n2[old2 & ~new2]), 1)
                n4 = node[s4]
                np.add.at(dc, (rows[new4 & ~old4], n4[new4 & ~old4]), 1)
                np.subtract.at(dc, (rows[old4 & ~new4], n4[old4 & ~new4]), 1)
                # same order as peek_per_node: w_j * (count + d), j ascending
                new_per_node += self.weights[j] * (self._count_node[:, j][None, :] + dc)
        d_j_sum = np.zeros(m, dtype=np.float64)
        for j in range(k):
            d_j_sum += float(self.weights[j]) * d_count_off[:, j]
        new_j_max = (new_per_node.max(axis=1, initial=0.0)
                     if with_loads else None)
        return BatchSwapDelta(P, Q, d_count_off, d_j_sum,
                              new_per_node, new_j_max)

    def peek_per_node(self, delta: Delta) -> np.ndarray:
        """per_node as it would be after applying ``delta`` (no mutation),
        rebuilt from counts — exact w.r.t. the committed state."""
        counts = self._count_node.copy()
        for (n, j), by in delta.d_count_node.items():
            counts[n, j] += by
        per_node = np.zeros(self.n_nodes, dtype=np.float64)
        for j in range(self.stencil.k):
            per_node += self.weights[j] * counts[:, j]
        return per_node

    def peek_j_max(self, delta: Delta) -> float:
        """j_max after ``delta``, O(N + touched): adjusts only the touched
        nodes of the cached per_node (advisory — may differ from the exact
        count-rebuilt value by an ulp for non-dyadic float weights)."""
        per_node = self._per_node().copy()
        for (n, j), by in delta.d_count_node.items():
            per_node[n] += self.weights[j] * by
        return float(per_node.max(initial=0.0))

    # -- commits ------------------------------------------------------------
    def _apply(self, overrides: Dict[int, int], delta: Delta) -> Delta:
        self._count_off += delta.d_count_off
        for (n, j), by in delta.d_count_node.items():
            self._count_node[n, j] += by
        for pos, n in overrides.items():
            self.node_of_pos[pos] = n
        self._per_node_cache = None
        return delta

    def apply_move(self, pos: int, new_node: int) -> Delta:
        delta = self.delta_move(pos, new_node)
        return self._apply({int(pos): int(new_node)}, delta)

    def apply_swap(self, p: int, q: int) -> Delta:
        p, q = int(p), int(q)
        overrides = {p: int(self.node_of_pos[q]), q: int(self.node_of_pos[p])}
        delta = self._delta(overrides)
        return self._apply(overrides, delta)

    # -- boundary extraction (the refiner's candidate set) -------------------
    def boundary_positions(self) -> np.ndarray:
        """Positions with at least one crossing incident edge, ascending."""
        node, t = self.node_of_pos, self.table
        on_boundary = np.zeros(self.grid.size, dtype=bool)
        for j in range(self.stencil.k):
            valid, tgt = t.out_valid[j], t.out_tgt[j]
            crossing = valid & (node != node[tgt])
            on_boundary |= crossing
            # the target of a crossing out-edge is on the boundary too
            on_boundary[tgt[crossing]] = True
        return np.nonzero(on_boundary)[0]

    def neighbors_of(self, pos: int) -> np.ndarray:
        """Distinct stencil neighbours (out or in) of ``pos``, ascending."""
        t, pos = self.table, int(pos)
        out = t.out_tgt[t.out_valid[:, pos], pos]
        inc = t.in_src[t.in_valid[:, pos], pos]
        return np.unique(np.concatenate([out, inc]))

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (f"IncrementalCost(p={self.grid.size}, k={self.stencil.k}, "
                f"N={self.n_nodes}, j_sum={self.j_sum})")


@dataclass(frozen=True)
class PortfolioSwapDelta:
    """Vectorized effect of ``m`` swap proposals, each scored against its
    *own* portfolio state (row ``rows[i]`` of a :class:`PortfolioCost`).

    Integer fields are bit-exact with the scalar path: ``d_count_off[i]``
    equals ``IncrementalCost(..., assignments[rows[i]]).delta_swap(p[i],
    q[i]).d_count_off`` and ``new_per_node[i]`` equals the matching
    ``peek_per_node`` rebuild (same ascending-offset ``w * count``
    accumulation), so ``d_j_sum`` / ``new_j_max`` match bitwise too."""

    rows: np.ndarray                      # (m,) int64 portfolio state index
    p: np.ndarray                         # (m,) int64
    q: np.ndarray                         # (m,) int64
    d_count_off: np.ndarray               # (m, k) int64
    d_j_sum: np.ndarray                   # (m,) float64
    new_per_node: Optional[np.ndarray]    # (m, N) float64 or None
    new_j_max: Optional[np.ndarray]       # (m,) float64 or None
    d_count_node: Optional[np.ndarray]    # (m, N, k) int64 or None

    @property
    def size(self) -> int:
        return int(self.p.size)


class PortfolioCost:
    """K independent :class:`IncrementalCost` states advanced in lock-step.

    This is the portfolio-mode counterpart of
    :meth:`IncrementalCost.batch_swap_deltas`: instead of scoring ``m``
    proposals against one assignment, :meth:`swap_deltas` scores one
    proposal *per portfolio member* against that member's own assignment —
    the inner loop of :class:`~repro.core.refine.PortfolioRefiner`, where K
    simulated-annealing ladders each propose a swap per move and all K
    frontiers are scored in a handful of numpy passes.

    State layout mirrors the scalar class, stacked along a leading K axis:
    ``node`` is (K, p), the integer crossing counts are (K, k) and
    (K, N, k), and the cached per-node loads (K, N) are rebuilt from counts
    with the same ascending-offset accumulation — so every row of every
    quantity is bit-exact with a scalar ``IncrementalCost`` tracking the
    same assignment, for arbitrary float weights.  The neighbour table is
    built once and shared by all K states.

    Usage::

        pc = PortfolioCost(grid, stencil, assignments, num_nodes=N)  # (K, p)
        d = pc.swap_deltas(rows, P, Q)      # one proposal per listed row
        accept = d.new_j_max < pc.j_max()[rows]
        pc.apply_swaps(rows[accept], P[accept], Q[accept])
    """

    def __init__(self, grid: CartGrid, stencil: Stencil,
                 assignments: np.ndarray, num_nodes: Optional[int] = None,
                 weighted=False, table: Optional[NeighborTable] = None,
                 counts: Optional[Tuple[np.ndarray, np.ndarray]] = None):
        assignments = np.asarray(assignments, dtype=np.int64)
        if assignments.ndim != 2 or assignments.shape[1] != grid.size:
            raise ValueError(
                f"assignments must have shape (K, {grid.size})")
        self.grid = grid
        self.stencil = stencil
        self.table = table if table is not None \
            else NeighborTable.build(grid, stencil)
        self.n_starts = int(assignments.shape[0])
        self.n_nodes = int(num_nodes if num_nodes is not None
                           else assignments.max() + 1)
        self.weighted = resolve_weighted(weighted, stencil)
        self.weights = (stencil.weight_array() if self.weighted
                        else np.ones(stencil.k))
        self.node = assignments.copy()
        k = stencil.k
        if counts is not None:
            # precomputed integer crossing counts (e.g. the sharded
            # engine's jax.vmap kernel — see
            # :func:`repro.core.refine.sharded.stacked_crossing_counts`).
            # Counts are pure integers, so any correct producer is
            # bit-interchangeable with the loop below; shapes are checked,
            # values trusted.
            count_off, count_node = counts
            self._count_off = np.array(count_off, dtype=np.int64)
            self._count_node = np.array(count_node, dtype=np.int64)
            if self._count_off.shape != (self.n_starts, k) \
                    or self._count_node.shape != (self.n_starts,
                                                  self.n_nodes, k):
                raise ValueError("precomputed counts have wrong shapes")
        else:
            self._count_off, self._count_node = stacked_count_arrays(
                self.table, self.node, self.n_nodes)
        self._per_node = np.zeros((self.n_starts, self.n_nodes),
                                  dtype=np.float64)
        self._rebuild_rows(np.arange(self.n_starts))

    @classmethod
    def from_graph(cls, graph, assignments: np.ndarray,
                   num_nodes: Optional[int] = None, weighted="auto",
                   table: Optional[NeighborTable] = None,
                   counts=None) -> "PortfolioCost":
        """K stacked cost states over a
        :class:`~repro.core.graph.CommGraph` (slot decomposition as the
        stencil — see :meth:`IncrementalCost.from_graph`)."""
        return cls(graph.grid(), graph.slot_stencil(), assignments,
                   num_nodes=num_nodes, weighted=weighted, table=table,
                   counts=counts)

    def _rebuild_rows(self, rows: np.ndarray) -> None:
        # same ascending-offset `per_node += w * count` accumulation as the
        # scalar cache rebuild, so each row matches it bit-for-bit
        out = np.zeros((rows.size, self.n_nodes), dtype=np.float64)
        for j in range(self.stencil.k):
            out += self.weights[j] * self._count_node[rows, :, j]
        self._per_node[rows] = out

    # -- read-only views ----------------------------------------------------
    def j_sum(self) -> np.ndarray:
        """(K,) j_sum per state, same accumulation order as the scalar."""
        total = np.zeros(self.n_starts, dtype=np.float64)
        for j in range(self.stencil.k):
            total += float(self.weights[j]) * self._count_off[:, j]
        return total

    def per_node(self) -> np.ndarray:
        return self._per_node.copy()

    def j_max(self) -> np.ndarray:
        """(K,) bottleneck load per state (from the counts-rebuilt cache)."""
        return self._per_node.max(axis=1, initial=0.0)

    def assignment(self, row: int) -> np.ndarray:
        return self.node[int(row)].copy()

    def cost(self, row: int) -> MappingCost:
        per_node = self._per_node[int(row)].copy()
        bottleneck = int(per_node.argmax()) if self.n_nodes else 0
        j_sum = 0.0
        for j in range(self.stencil.k):
            j_sum += float(self.weights[j]) * float(self._count_off[row, j])
        return MappingCost(j_sum=j_sum,
                           j_max=float(per_node.max(initial=0.0)),
                           per_node=per_node, bottleneck=bottleneck)

    # -- boundary extraction ------------------------------------------------
    def boundary_masks(self) -> np.ndarray:
        """(K, p) bool: positions with a crossing incident edge, per state.
        ``np.nonzero(mask[i])[0]`` reproduces the scalar
        :meth:`IncrementalCost.boundary_positions` ordering exactly."""
        on_b = np.zeros((self.n_starts, self.grid.size), dtype=bool)
        for j in range(self.stencil.k):
            valid, tgt = self.table.out_valid[j], self.table.out_tgt[j]
            crossing = valid[None, :] & (self.node != self.node[:, tgt])
            on_b |= crossing
            rr, pp = np.nonzero(crossing)
            on_b[rr, tgt[pp]] = True
        return on_b

    # -- proposals ----------------------------------------------------------
    def swap_deltas(self, rows, p_arr, q_arr, with_loads: bool = True,
                    with_counts: bool = False) -> PortfolioSwapDelta:
        """Score ``m`` swap proposals, proposal i against state ``rows[i]``.

        Same four directed-edge groups per offset as
        :meth:`IncrementalCost.batch_swap_deltas`, with every node lookup
        routed through the proposal's own state row.  ``with_loads``
        materializes the exact post-swap (m, N) ``new_per_node`` /
        ``new_j_max`` (chunked over proposals so peak extra memory respects
        :data:`LOAD_CHUNK_ELEMS`); ``with_counts`` additionally returns the
        integer (m, N, k) per-node count changes (the commit payload
        :meth:`apply_swaps` uses).
        """
        rows = np.atleast_1d(np.asarray(rows, dtype=np.int64))
        P = np.atleast_1d(np.asarray(p_arr, dtype=np.int64))
        Q = np.atleast_1d(np.asarray(q_arr, dtype=np.int64))
        if not (rows.shape == P.shape == Q.shape) or rows.ndim != 1:
            raise ValueError("rows, p_arr, q_arr must be 1-d of equal length")
        if rows.size:
            if rows.min() < 0 or rows.max() >= self.n_starts:
                raise ValueError("portfolio rows out of range")
            if (P.min() < 0 or P.max() >= self.grid.size
                    or Q.min() < 0 or Q.max() >= self.grid.size):
                raise ValueError("positions out of range")
        m, k = P.size, self.stencil.k
        d_count_off = np.zeros((m, k), dtype=np.int64)
        new_per_node = (np.empty((m, self.n_nodes), dtype=np.float64)
                        if with_loads else None)
        d_count_node = (np.zeros((m, self.n_nodes, k), dtype=np.int64)
                        if with_counts else None)
        # the load/count paths materialize a (chunk, N, k) scratch, so the
        # chunk is sized against N * k to keep peak memory on budget
        chunk = m if not (with_loads or with_counts) else \
            max(1, LOAD_CHUNK_ELEMS // max(1, self.n_nodes * k))
        for s in range(0, m, max(chunk, 1)):
            e = min(s + chunk, m)
            self._swap_deltas_chunk(rows[s:e], P[s:e], Q[s:e],
                                    d_count_off[s:e],
                                    new_per_node[s:e] if with_loads else None,
                                    d_count_node[s:e] if with_counts else None)
        d_j_sum = np.zeros(m, dtype=np.float64)
        for j in range(k):
            d_j_sum += float(self.weights[j]) * d_count_off[:, j]
        new_j_max = (new_per_node.max(axis=1, initial=0.0)
                     if with_loads else None)
        return PortfolioSwapDelta(rows, P, Q, d_count_off, d_j_sum,
                                  new_per_node, new_j_max, d_count_node)

    def _swap_deltas_chunk(self, rows, P, Q, d_count_off, new_per_node,
                           d_count_node) -> None:
        """Whole-stencil vectorized scoring: every (offset, edge-group)
        quantity is computed as a (k, m) array in one pass, so the per-move
        cost of a portfolio ladder is a fixed handful of numpy ops instead
        of O(k) interpreted iterations."""
        node, t, m, k = self.node, self.table, P.size, self.stencil.k
        A, B = node[rows, P], node[rows, Q]                  # (m,)
        rows2, A2, B2 = rows[None, :], A[None, :], B[None, :]
        P2, Q2 = P[None, :], Q[None, :]
        # out-edges of p (target owner swaps if it is the partner or, on
        # degenerate periodic axes, p itself — same as the scalar path)
        T1 = t.out_tgt[:, P]                                 # (k, m)
        N1 = node[rows2, T1]
        NV1 = np.where(T1 == Q2, A2, np.where(T1 == P2, B2, N1))
        old1 = t.out_valid[:, P] & (N1 != A2)
        new1 = t.out_valid[:, P] & (NV1 != B2)
        # out-edges of q (mirror)
        T3 = t.out_tgt[:, Q]
        N3 = node[rows2, T3]
        NV3 = np.where(T3 == P2, B2, np.where(T3 == Q2, A2, N3))
        old3 = t.out_valid[:, Q] & (N3 != B2)
        new3 = t.out_valid[:, Q] & (NV3 != A2)
        # in-edges from outside the pair
        S2 = t.in_src[:, P]
        V2 = t.in_valid[:, P] & (S2 != Q2) & (S2 != P2)
        N2 = node[rows2, S2]
        old2 = V2 & (N2 != A2)
        new2 = V2 & (N2 != B2)
        S4 = t.in_src[:, Q]
        V4 = t.in_valid[:, Q] & (S4 != P2) & (S4 != Q2)
        N4 = node[rows2, S4]
        old4 = V4 & (N4 != B2)
        new4 = V4 & (N4 != A2)
        d_count_off[:] = (
            (new1.astype(np.int64) - old1) + (new2.astype(np.int64) - old2)
            + (new3.astype(np.int64) - old3)
            + (new4.astype(np.int64) - old4)).T
        if new_per_node is None and d_count_node is None:
            return
        own = d_count_node if d_count_node is not None else \
            np.zeros((m, self.n_nodes, k), dtype=np.int64)

        def scatter(mask, node_vals, by):
            jj, mm = np.nonzero(mask)
            np.add.at(own, (mm, node_vals[jj, mm], jj), by)

        scatter(old1, np.broadcast_to(A2, (k, m)), -1)
        scatter(new1, np.broadcast_to(B2, (k, m)), +1)
        scatter(old3, np.broadcast_to(B2, (k, m)), -1)
        scatter(new3, np.broadcast_to(A2, (k, m)), +1)
        scatter(new2 & ~old2, N2, +1)
        scatter(old2 & ~new2, N2, -1)
        scatter(new4 & ~old4, N4, +1)
        scatter(old4 & ~new4, N4, -1)
        if new_per_node is not None:
            # w_j * (count + d), j ascending — matches peek_per_node
            new_per_node[:] = 0.0
            for j in range(k):
                new_per_node += self.weights[j] * (
                    self._count_node[rows, :, j] + own[:, :, j])

    # -- commits ------------------------------------------------------------
    def commit(self, delta: PortfolioSwapDelta, idx=None) -> None:
        """Apply already-scored proposals (requires ``with_counts``); the
        optional ``idx`` selects a subset of the delta's proposals (the
        accepted ones).  Selected rows must be distinct.  The affected
        rows' per-node caches are rebuilt from counts, exactly as the
        scalar class does after a commit."""
        if delta.d_count_node is None:
            raise ValueError("commit needs a delta scored with_counts=True")
        sel = np.arange(delta.size) if idx is None \
            else np.atleast_1d(np.asarray(idx, dtype=np.int64))
        rows, P, Q = delta.rows[sel], delta.p[sel], delta.q[sel]
        if np.unique(rows).size != rows.size:
            raise ValueError("commit: one swap per row at most")
        if rows.size == 0:
            return
        self._count_off[rows] += delta.d_count_off[sel]
        self._count_node[rows] += delta.d_count_node[sel]
        pv, qv = self.node[rows, P].copy(), self.node[rows, Q].copy()
        self.node[rows, P] = qv
        self.node[rows, Q] = pv
        self._rebuild_rows(rows)

    def apply_swaps(self, rows, p_arr, q_arr) -> PortfolioSwapDelta:
        """Score-and-commit one swap per listed row (rows must be
        distinct)."""
        d = self.swap_deltas(rows, p_arr, q_arr, with_loads=False,
                             with_counts=True)
        self.commit(d)
        return d

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (f"PortfolioCost(K={self.n_starts}, p={self.grid.size}, "
                f"k={self.stencil.k}, N={self.n_nodes})")
