"""First-class mapping plans: typed problems, composable stages, a serving
cache, and the `cart_create` facade.

The paper's punchline is that stencil-aware mapping is cheap enough to sit
behind ``MPI_Cart_create`` — a *library entry point*.  This module is that
entry point for the repo:

* :class:`MappingProblem` — the full problem signature (mesh shape,
  stencil incl. per-offset byte weights, node sizes, objective) with a
  stable content hash;
* :class:`MappingPlan` — an ordered chain of
  :class:`~repro.core.refine.stage.Stage` objects
  (:class:`~repro.core.refine.stage.BaseStage` +
  :class:`~repro.core.refine.stage.RefineStage`), built directly or parsed
  from the registry string grammar by :func:`parse_plan`;
  ``plan.solve(problem)`` returns a :class:`MappingSolution` (assignment,
  J_sum/J_max, per-stage stats);
* :class:`PlanCache` — an in-memory LRU keyed by
  ``(problem.content_hash(), plan.key)`` with optional JSON disk spill
  under ``~/.cache/repro-maps/`` and hit/miss counters, so elastic
  re-meshes and repeated serving-time mesh builds reuse solved
  assignments instead of re-annealing
  (wired through :func:`~repro.core.remap.device_layout` /
  :func:`~repro.core.remap.mapped_device_array` /
  :func:`~repro.launch.mesh.make_mapped_mesh`);
* :func:`cart_create` — the MPI-style one-call facade: problem in, cached
  solution + device layout out.

``get_mapper`` is a thin compatibility front-end: it parses the same
grammar with :func:`parse_plan` and re-packages the stages as nested
:class:`~repro.core.refine.RefinedMapper` wrappers, so string spellings
and plan objects execute identical stage chains (bit-exact parity is
pinned by ``tests/test_plan.py``).  Chained prefixes
(``"portfolio[k=8]:refined:hyperplane"``) compose for free: each prefix
becomes one refine stage, applied inner-first.

Usage::

    from repro.core import MappingProblem, PlanCache, cart_create, parse_plan

    problem = MappingProblem((16, 28), Stencil.nearest_neighbor(2),
                             node_sizes=(256, 192))
    plan = parse_plan("portfolio[k=4]:hyperplane")
    sol = plan.solve(problem)                    # cold solve
    sol = default_plan_cache().solve(problem, plan)   # cached

    cart = cart_create((16, 16), chips_per_pod=16)    # one-call facade
    cart.layout, cart.solution.j_max, cart.from_cache
"""
from __future__ import annotations

import hashlib
import json
import math
import os
import re
import threading
import time
import uuid
from collections import OrderedDict
from contextlib import contextmanager
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple, Union

try:
    import fcntl
except ImportError:                       # pragma: no cover - non-POSIX
    fcntl = None

import numpy as np

from .cost import evaluate, rowmajor_rank_layout
from .grid import CartGrid
from .stencil import Stencil
from .refine.stage import BaseStage, RefineStage, Stage

__all__ = ["MappingProblem", "MappingPlan", "MappingSolution", "parse_plan",
           "PlanCache", "default_plan_cache", "resolve_cache",
           "blocked_node_sizes", "cart_create", "graph_create", "CartResult",
           "DEFAULT_CART_PLAN", "DEFAULT_GRAPH_PLAN", "DEFAULT_CACHE_DIR",
           "default_cache_dir"]


def blocked_node_sizes(p: int, chips_per_pod: int) -> Tuple[int, ...]:
    """The scheduler's blocked split of ``p`` chips into pods of
    ``chips_per_pod``, with a ragged tail pod when it doesn't divide
    evenly (elastic operation after failures).  The one place this
    convention lives — ``mapped_device_array`` and :func:`cart_create`
    both use it."""
    full, rem = divmod(int(p), int(chips_per_pod))
    return (int(chips_per_pod),) * full + ((rem,) if rem else ())

#: objectives a problem may declare (informational for solvers — the refine
#: stack always tracks the lexicographic pair — but part of the cache key).
_OBJECTIVES = ("lex", "j_sum", "j_max")

def default_cache_dir() -> Path:
    """The disk-spill location, resolved *now*: ``$REPRO_MAPS_CACHE_DIR``
    if set, else ``~/.cache/repro-maps``.  Read at every
    :class:`PlanCache` construction — never at import time — so tests and
    embedders that set the env var after importing this module still get
    their spill where they asked for it."""
    return Path(os.environ.get("REPRO_MAPS_CACHE_DIR",
                               "~/.cache/repro-maps")).expanduser()


#: import-time snapshot, kept for backwards compatibility only — the spill
#: path that actually gets used is :func:`default_cache_dir`'s live value.
DEFAULT_CACHE_DIR = default_cache_dir()

#: the facade's default plan: the annealed schedule is the best
#: single-ladder quality/latency point for a one-call entry (swap
#: ``plan="portfolio:hyperplane"`` in for more quality per cold solve).
DEFAULT_CART_PLAN = "annealed:hyperplane"

#: the graph facade's default plan: greedy BFS-ish packing seeded by the
#: heaviest edges, then the annealed schedule on the graph objective.
DEFAULT_GRAPH_PLAN = "annealed:graphgreedy"


# ---------------------------------------------------------------------------
# problem + solution


@dataclass(frozen=True)
class MappingProblem:
    """The full mapping-problem signature, hashable by content.

    Two problems with equal content hashes are the *same* problem for the
    cache: the hash covers mesh shape, periodicity, the stencil's offsets
    AND per-offset byte weights (weight changes must miss), node sizes,
    and the declared objective.  The stencil's cosmetic ``name`` is
    excluded.

    ``graph`` optionally attaches a :class:`~repro.core.graph.CommGraph`
    payload (build with :meth:`from_graph`).  A graph extracted from a
    stencil carries its provenance, so the problem keeps the original
    Cartesian signature — and the original content hash, so the cache
    serves it unchanged.  A general graph (HLO/MoE extractors) has no
    geometry: ``mesh_shape`` is ``(n,)``, ``grid()`` is the graph's
    :class:`~repro.core.graph.GraphGrid`, the stencil is the graph's slot
    stencil, and the hash covers the graph's canonical CSR content.
    """

    mesh_shape: Tuple[int, ...]
    stencil: Stencil
    node_sizes: Tuple[int, ...]
    objective: str = "lex"
    periodic: Optional[Tuple[bool, ...]] = None
    graph: Optional[object] = field(default=None, compare=False, repr=False)

    def __post_init__(self):
        shape = tuple(int(d) for d in self.mesh_shape)
        sizes = tuple(int(s) for s in self.node_sizes)
        object.__setattr__(self, "mesh_shape", shape)
        object.__setattr__(self, "node_sizes", sizes)
        if self.periodic is not None:
            object.__setattr__(self, "periodic",
                               tuple(bool(b) for b in self.periodic))
        if self.objective not in _OBJECTIVES:
            raise ValueError(f"objective must be one of {_OBJECTIVES}")
        if sum(sizes) != math.prod(shape):
            raise ValueError(f"sum(node_sizes)={sum(sizes)} != mesh size "
                             f"{math.prod(shape)}")
        if self.graph is not None and self.graph.n != math.prod(shape):
            raise ValueError(f"graph has {self.graph.n} vertices but the "
                             f"mesh has {math.prod(shape)} positions")
        self.grid()   # validates shape/periodic eagerly

    @classmethod
    def from_graph(cls, graph, node_sizes: Sequence[int],
                   objective: str = "lex") -> "MappingProblem":
        """Problem over a :class:`~repro.core.graph.CommGraph`.  A
        stencil-extracted graph round-trips to its original Cartesian
        signature (identical :meth:`content_hash` to the plain stencil
        problem — provenance is structural); a general graph becomes a
        1-D problem over the graph's own grid/slot-stencil forms."""
        prov = graph.provenance
        if prov is not None:
            return cls(prov["mesh_shape"],
                       Stencil(prov["offsets"], weights=prov["weights"],
                               name=graph.name),
                       node_sizes, objective=objective,
                       periodic=prov["periodic"], graph=graph)
        return cls((graph.n,), graph.slot_stencil(), node_sizes,
                   objective=objective, graph=graph)

    def grid(self) -> CartGrid:
        if self.graph is not None and self.graph.provenance is None:
            return self.graph.grid()
        return CartGrid(self.mesh_shape, periodic=self.periodic)

    def as_graph(self):
        """This problem's :class:`~repro.core.graph.CommGraph`: the
        attached payload, or (for plain stencil problems) the exact
        stencil extraction built on the fly."""
        if self.graph is not None:
            return self.graph
        from .graph import CommGraph
        return CommGraph.from_stencil(self.grid(), self.stencil)

    def graph_form(self) -> Tuple[object, Stencil]:
        """``(grid, stencil)`` of the graph realization — what ``graph:``
        flavored plans run their refine stages and final evaluation on.
        For stencil problems the forms are the exact round-trip, so costs
        and deltas match the geometric forms bit-for-bit."""
        g = self.as_graph()
        return g.grid(), g.slot_stencil()

    @property
    def num_nodes(self) -> int:
        return len(self.node_sizes)

    @property
    def is_ragged(self) -> bool:
        return len(set(self.node_sizes)) > 1

    def content_hash(self) -> str:
        if self.graph is not None and self.graph.provenance is None:
            payload = {
                "graph": self.graph.content_hash(),
                "node_sizes": list(self.node_sizes),
                "objective": self.objective,
            }
            blob = json.dumps(payload, sort_keys=True,
                              separators=(",", ":"))
            return hashlib.sha256(blob.encode()).hexdigest()[:32]
        payload = {
            "mesh_shape": list(self.mesh_shape),
            "periodic": list(self.grid().periodic),
            "offsets": [list(o) for o in self.stencil.offsets],
            "weights": list(self.stencil.weights),
            "node_sizes": list(self.node_sizes),
            "objective": self.objective,
        }
        blob = json.dumps(payload, sort_keys=True, separators=(",", ":"))
        return hashlib.sha256(blob.encode()).hexdigest()[:32]


@dataclass
class MappingSolution:
    """A solved plan: the assignment plus everything a caller needs to
    trust and reuse it (costs, provenance, per-stage stats)."""

    assignment: np.ndarray          # (p,) node-of-position
    j_sum: float
    j_max: float
    problem: MappingProblem
    plan_key: str
    stage_stats: List[dict] = field(default_factory=list)
    wall_time_s: float = 0.0
    from_cache: bool = False

    def key(self) -> Tuple[float, float]:
        """The refine stack's lexicographic objective pair."""
        return (self.j_max, self.j_sum)

    def layout(self) -> np.ndarray:
        """``L[logical coord] = device index`` realising this assignment
        with row-major intra-node rank order (the
        ``device_layout(intra_order="rowmajor")`` convention —
        :func:`~repro.core.cost.rowmajor_rank_layout`)."""
        return rowmajor_rank_layout(self.assignment).reshape(
            self.problem.mesh_shape)


# ---------------------------------------------------------------------------
# plans


class MappingPlan:
    """An ordered stage chain: one initial stage (:class:`BaseStage`, or a
    :class:`~repro.core.repair.RepairStage` warm-starting from a previous
    solution) followed by zero or more :class:`RefineStage` s.  ``key`` is
    the canonical spelling — stable across equal configurations — used for
    cache identity.

    ``graph=True`` (the ``"graph:"`` spelling flavor) runs the chain on
    the problem's :class:`~repro.core.graph.CommGraph` realization: the
    initial stage still sees the geometric grid/stencil (base mappers may
    exploit coordinates), but every refine stage and the final evaluation
    run on the graph's grid/slot-stencil forms.  For stencil problems the
    two realizations are cost-equivalent bit-for-bit (the parity the
    graph suite machine-checks); for graph-payload problems this is the
    native path.  ``key`` gains a ``graph:`` prefix so both flavors cache
    independently."""

    def __init__(self, stages: Sequence[Stage], name: Optional[str] = None,
                 graph: bool = False):
        stages = tuple(stages)
        if not stages:
            raise ValueError("a plan needs at least one stage")
        if not getattr(stages[0], "is_initial", False):
            raise ValueError("a plan's first stage must be an initial stage "
                             "(BaseStage or RepairStage)")
        if any(getattr(s, "is_initial", False) for s in stages[1:]):
            raise ValueError("only the first stage may be an initial stage")
        self.stages = stages
        self.name = name
        self.graph_flavor = bool(graph)

    @property
    def key(self) -> str:
        """Canonical spelling, refine stages outer-first (grammar order):
        ``portfolio[k=8]:refined:hyperplane``."""
        parts = [s.spec() for s in reversed(self.stages[1:])]
        parts.append(self.stages[0].spec())
        key = ":".join(parts)
        return f"graph:{key}" if self.graph_flavor else key

    @property
    def cacheable(self) -> bool:
        """False when any stage's configuration has no stable spelling
        (hand-built components holding nested objects) — such plans are
        always solved fresh, never keyed into a :class:`PlanCache`."""
        return all(getattr(s, "cacheable", True) for s in self.stages)

    def solve(self, problem: MappingProblem,
              cache: Optional["PlanCache"] = None) -> MappingSolution:
        """Run the stage chain; with ``cache``, memoize by
        ``(problem.content_hash(), self.key)``."""
        if cache is not None:
            return cache.solve(problem, self)
        t0 = time.perf_counter()
        grid = problem.grid()
        if self.graph_flavor:
            # the initial stage keeps the geometric forms (base mappers
            # may exploit coordinates); refine stages + the final cost run
            # on the graph realization — bit-equivalent for stencil
            # problems, native for graph payloads.
            rgrid, rstencil = problem.graph_form()
        else:
            rgrid, rstencil = grid, problem.stencil
        assignment: Optional[np.ndarray] = None
        stats: List[dict] = []
        for i, stage in enumerate(self.stages):
            g, s = (grid, problem.stencil) if i == 0 else (rgrid, rstencil)
            sr = stage.run(g, s, problem.node_sizes, assignment)
            assignment = sr.assignment
            stats.append(sr.stats)
        cost = evaluate(rgrid, rstencil, assignment,
                        num_nodes=problem.num_nodes, weighted="auto")
        # stats are JSON-normalized here so cold solves and cache hits
        # (which round-trip through JSON) have identical shapes
        return MappingSolution(assignment=assignment, j_sum=cost.j_sum,
                               j_max=cost.j_max, problem=problem,
                               plan_key=self.key,
                               stage_stats=_jsonable_stats(stats),
                               wall_time_s=time.perf_counter() - t0)

    def to_mapper(self):
        """Re-package the stages as the equivalent (nested)
        :class:`~repro.core.refine.RefinedMapper` chain — what
        ``get_mapper`` returns, with ``plan_key`` set at every level so
        the cache can key off mapper instances too."""
        from .refine import RefinedMapper
        if self.graph_flavor:
            raise TypeError(
                "graph-flavored plans have no Mapper form (the Mapper "
                "protocol has no problem/graph context); solve them as "
                "plans via parse_plan(...).solve / PlanCache.solve")
        if not isinstance(self.stages[0], BaseStage):
            raise TypeError(
                "only BaseStage-rooted plans have a Mapper form; a "
                f"{type(self.stages[0]).__name__}-rooted plan (warm-start "
                "repair) must be solved as a plan, not via get_mapper")
        mapper = self.stages[0].mapper
        key = self.stages[0].spec()
        cache_ok = self.stages[0].cacheable
        mapper.plan_key = key if cache_ok else None
        for i, stage in enumerate(self.stages[1:]):
            # the base's inapplicability fallback rides on the innermost
            # wrapper (where BaseStage.run would apply it)
            fb = self.stages[0].fallback if i == 0 else None
            mapper = RefinedMapper(mapper, refiner=stage.refiner,
                                   prefix=stage.prefix,
                                   budget=stage.budget, fallback=fb)
            key = f"{stage.spec()}:{key}"
            cache_ok = cache_ok and stage.cacheable
            mapper.plan_key = key if cache_ok else None
        return mapper

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"MappingPlan({self.key!r})"


def parse_plan(name: str, **kwargs) -> MappingPlan:
    """Parse a registry spelling into a :class:`MappingPlan`.

    This is the one implementation of the mapper-name grammar
    (``"<prefix>[<options>]:" * N + "<base>"`` — see
    :mod:`repro.core.mapping` for the contract): every prefix becomes one
    :class:`RefineStage` (applied inner-first), the base name one
    :class:`BaseStage`.  ``kwargs`` configure the *outermost* refiner —
    or the base algorithm when no prefix is present — exactly as
    ``get_mapper`` does; bracket options win over kwargs.  Chained
    prefixes (``"portfolio[k=8]:refined:hyperplane"``) need no special
    casing: the grammar is recursive in ``<base>``.

    The warm-start spelling ``"repair[<options>]:<fallback>"`` (or bare
    ``"repair"``) roots the plan in a
    :class:`~repro.core.repair.RepairStage` instead of a base algorithm;
    it requires the ``previous=`` keyword (the pre-churn solution) and
    accepts ``node_map=``.  ``<fallback>`` — itself any spelling of this
    grammar — is solved cold when the previous solution cannot seed the
    problem.  Refine prefixes chain over it as usual
    (``"portfolio[k=8]:repair:hyperplane"``).

    The base name accepts bracket options of its own
    (``"graphgreedy[seed=3]"``, ``"annealed:graphgreedy[seed=3]"``):
    they configure the base algorithm's constructor, win over ``kwargs``,
    and render canonically in the plan key (``graphgreedy{seed=3}``) so
    bracketed bases stay cacheable and composable under every refine
    prefix.

    A leading ``"graph:"`` selects the *graph problem flavor*: the same
    stage chain, run on the problem's
    :class:`~repro.core.graph.CommGraph` realization (see
    :class:`MappingPlan`).  It composes with everything —
    ``"graph:hier:annealed:graphgreedy[seed=3]"`` — and prefixes the
    plan key, so grid- and graph-flavored solves cache independently.
    """
    from .mapping import MAPPERS, REFINE_PREFIXES, _make_refiner, \
        split_mapper_name
    from .refine import SwapRefiner
    previous = kwargs.pop("previous", None)
    node_map = kwargs.pop("node_map", None)
    graph_flavor = name.startswith("graph:")
    if graph_flavor:
        name = name[len("graph:"):]
        if not name:
            raise ValueError("'graph:' needs a plan spelling after it, "
                             "e.g. 'graph:annealed:graphgreedy'")
    chain = []                      # (prefix, options), outer-first
    rest = name
    while True:
        parsed = split_mapper_name(rest, full_name=name)
        if parsed is None:
            break
        prefix, opts, rest = parsed
        chain.append((prefix, opts))
    is_repair = rest == "repair" or rest.startswith(("repair[", "repair:"))
    base_opts: Dict[str, object] = {}
    if not is_repair and rest not in MAPPERS:
        # base bracket options: "<base>[k=v,...]"
        from .mapping import parse_mapper_options
        m = re.fullmatch(r"(?P<base>[a-z][a-z0-9_]*)\[(?P<opts>.*)\]", rest)
        if m is not None and m.group("base") in MAPPERS:
            base_opts = parse_mapper_options(m.group("opts"), name=name)
            rest = m.group("base")
        else:
            raise KeyError(
                f"unknown mapper {rest!r}"
                + (f" (base of {name!r})" if rest != name else "")
                + f"; choose from {sorted(MAPPERS)}, "
                f"one of {[p + '<base>' for p in REFINE_PREFIXES]}, "
                "or 'repair[<options>]:<fallback>'")
    if not is_repair and previous is not None:
        raise ValueError(f"previous= is only meaningful for repair plans, "
                         f"not {name!r}")
    base_kwargs = kwargs if not chain else {}
    fallback = None
    refine_stages: List[Stage] = []
    for i, (prefix, opts) in enumerate(reversed(chain)):
        outermost = i == len(chain) - 1
        merged = {**kwargs, **opts} if outermost else dict(opts)
        # wrapper-level knobs (not refiner constructor args): `budget` caps
        # this stage's accepted swaps, `fallback` names the base algorithm
        # to start from when the primary is inapplicable — where chain
        # inapplicability originates, so it attaches to the BaseStage.
        budget = merged.pop("budget", None)
        fb = merged.pop("fallback", None)
        if fb is not None:
            fallback = fb
        if prefix == "refined":
            refiner = SwapRefiner(**merged)
        else:
            refiner = _make_refiner(prefix, merged)
        refine_stages.append(RefineStage(refiner, budget=budget,
                                         prefix=prefix, options=merged))
    if is_repair:
        from .mapping import parse_mapper_options
        from .repair import RepairStage
        head, _, fb_spelling = rest.partition(":")
        r_opts: Dict[str, object] = {}
        if head != "repair":
            if not (head.startswith("repair[") and head.endswith("]")):
                raise ValueError(
                    f"malformed repair spelling {head!r}"
                    + (f" in {name!r}" if rest != name else ""))
            r_opts = parse_mapper_options(head[len("repair["):-1], name=name)
        if previous is None:
            raise ValueError(
                "repair plans need the pre-churn solution: "
                "parse_plan(..., previous=<MappingSolution>)")
        if not fb_spelling and isinstance(fallback, str):
            fb_spelling = fallback      # prefix-level fallback= spelling
        first: Stage = RepairStage(
            previous, node_map=node_map,
            fallback=parse_plan(fb_spelling) if fb_spelling else None,
            **{**base_kwargs, **r_opts})
    else:
        merged_base = {**base_kwargs, **base_opts}   # bracket wins
        fb = merged_base.pop("fallback", None)
        if fb is not None:
            fallback = fb
        first = BaseStage(MAPPERS[rest], fallback=fallback, **merged_base)
    stages: List[Stage] = [first]
    stages += refine_stages
    return MappingPlan(stages,
                       name=f"graph:{name}" if graph_flavor else name,
                       graph=graph_flavor)


# ---------------------------------------------------------------------------
# the serving cache


@contextmanager
def _dir_lock(disk_dir: Path):
    """Advisory cross-process exclusion for spill-dir mutations: ``flock``
    on a ``.lock`` sidecar (two caches in different processes publishing
    the same key serialize their ``os.replace``).  No-op where flock is
    unavailable — the per-writer unique tmp names alone already prevent
    interleaved writes there."""
    if fcntl is None:                     # pragma: no cover - non-POSIX
        yield
        return
    with open(disk_dir / ".lock", "a+b") as lf:
        fcntl.flock(lf.fileno(), fcntl.LOCK_EX)
        try:
            yield
        finally:
            fcntl.flock(lf.fileno(), fcntl.LOCK_UN)


#: sentinel distinguishing "no ttl_s argument" from an explicit ``None``
#: (= never expire) in :meth:`PlanCache.put`.
_TTL_DEFAULT = object()


class PlanCache:
    """LRU cache of solved plans (and derived device layouts).

    Keys are ``(problem.content_hash(), plan key[, intra order])`` — pure
    content, so two meshes built from equal problem signatures share an
    entry no matter which objects spelled them.  ``disk_dir`` enables the
    JSON spill: entries evicted from (or missing in) memory are read back
    from ``<disk_dir>/<sha>.json`` and count as ``disk_hits``.  All
    counters are plain attributes (``hits`` / ``misses`` / ``disk_hits``
    / ``puts`` / ``evictions`` / ``expired`` / ``invalidations`` /
    ``disk_evictions``); access is thread-safe.

    Serving extensions (the :class:`repro.serving.PlanServer` owns one of
    these as its shared cache):

    * ``ttl_s`` — default time-to-live for new entries; :meth:`put` takes
      a per-entry override.  Expired entries are dropped lazily on
      :meth:`get` (memory and spill file both) and count as ``expired``.
    * :meth:`invalidate` — explicit drop of every entry derived from one
      ``problem.content_hash()`` (topology changed, machine re-ranked).
    * ``max_disk_bytes`` — budget for the disk spill; exceeding it LRU
      sweeps spill files oldest-access first (disk hits refresh the file
      mtime, so recency is *access* recency, not write recency).
    """

    def __init__(self, maxsize: int = 256,
                 disk_dir: Union[None, bool, str, Path] = None,
                 ttl_s: Optional[float] = None,
                 max_disk_bytes: Optional[int] = None):
        if maxsize < 1:
            raise ValueError("maxsize must be >= 1")
        if ttl_s is not None and not float(ttl_s) > 0:
            raise ValueError("ttl_s must be > 0 (or None for no expiry)")
        if max_disk_bytes is not None and int(max_disk_bytes) < 1:
            raise ValueError("max_disk_bytes must be >= 1 (or None)")
        self.maxsize = int(maxsize)
        self.ttl_s = None if ttl_s is None else float(ttl_s)
        self.max_disk_bytes = (None if max_disk_bytes is None
                               else int(max_disk_bytes))
        if disk_dir is True:
            disk_dir = default_cache_dir()
        self.disk_dir = None if not disk_dir else Path(disk_dir).expanduser()
        self._mem: "OrderedDict[str, dict]" = OrderedDict()
        self._exp: Dict[str, float] = {}   # key -> expiry epoch (if any)
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.disk_hits = 0
        self.puts = 0
        self.evictions = 0
        self.corrupt_drops = 0
        self.expired = 0
        self.invalidations = 0
        self.disk_evictions = 0
        self._tmp_swept_at = 0.0

    # -- raw key/value store ------------------------------------------------
    def _disk_path(self, key: str) -> Path:
        return self.disk_dir / (hashlib.sha256(key.encode()).hexdigest()[:40]
                                + ".json")

    def get(self, key: str) -> Optional[dict]:
        with self._lock:
            if key in self._mem:
                exp = self._exp.get(key)
                if exp is not None and time.time() >= exp:
                    del self._mem[key]          # lazy TTL drop; the spill
                    self._exp.pop(key, None)    # copy (same expiry) falls
                    self.expired += 1           # through to _disk_get
                else:
                    self._mem.move_to_end(key)
                    self.hits += 1
                    return dict(self._mem[key])
        found = self._disk_get(key)
        if found is not None:
            value, expires_at = found
            with self._lock:
                self.hits += 1
                self.disk_hits += 1
            self._mem_put(key, value, expires_at)
            return dict(value)
        with self._lock:
            self.misses += 1
        return None

    def _drop_spill(self, path: Path, text: str) -> None:
        """Unlink a spill file, revalidating under the writers' lock: a
        concurrent put may have just replaced it with a valid (or fresher)
        entry, which must survive."""
        try:
            with _dir_lock(self.disk_dir):
                if path.read_text() == text:
                    path.unlink()
        except OSError:
            pass

    def _disk_get(self, key: str) -> Optional[Tuple[dict, Optional[float]]]:
        if self.disk_dir is None:
            return None
        path = self._disk_path(key)
        try:
            text = path.read_text()
        except OSError:                   # no spill (or unreadable): a miss
            return None
        try:
            blob = json.loads(text)
            if blob.get("key") != key:   # hash-prefix collision: valid
                return None              # file, someone else's entry
            value = blob["value"]
            if not isinstance(value, dict):
                raise TypeError("spill value must be a dict")
        except (ValueError, KeyError, AttributeError, TypeError):
            # truncated/corrupt spill (crashed or interleaved writer): it
            # is a miss, and the bad file must not poison every future
            # read of this key — drop it.
            with self._lock:
                self.corrupt_drops += 1
            self._drop_spill(path, text)
            return None
        expires_at = blob.get("expires_at")
        expires_at = None if expires_at is None else float(expires_at)
        if expires_at is not None and time.time() >= expires_at:
            with self._lock:
                self.expired += 1
            self._drop_spill(path, text)
            return None
        try:                              # refresh access recency for the
            os.utime(path)                # max_disk_bytes LRU sweep
        except OSError:
            pass
        return value, expires_at

    def _mem_put(self, key: str, value: dict,
                 expires_at: Optional[float] = None) -> None:
        with self._lock:
            self._mem[key] = dict(value)
            self._mem.move_to_end(key)
            if expires_at is None:
                self._exp.pop(key, None)
            else:
                self._exp[key] = float(expires_at)
            while len(self._mem) > self.maxsize:
                k, _ = self._mem.popitem(last=False)
                self._exp.pop(k, None)
                self.evictions += 1

    #: a ``*.tmp`` older than this is a crashed writer's leftover — with
    #: per-writer unique names nobody will ever finish it.
    _TMP_STALE_S = 600.0

    def _clean_stale_tmp(self) -> None:
        # throttled: a leftover only *becomes* stale _TMP_STALE_S after a
        # crash, so scanning the spill dir more often than that per cache
        # instance buys nothing — and the scan is O(dir size) on the hot
        # write path.
        now = time.time()
        if now - self._tmp_swept_at < self._TMP_STALE_S:
            return
        self._tmp_swept_at = now
        cutoff = now - self._TMP_STALE_S
        try:
            for p in self.disk_dir.glob("*.tmp"):
                try:
                    if p.stat().st_mtime < cutoff:
                        p.unlink()
                except OSError:
                    pass
        except OSError:                   # pragma: no cover - racing rmdir
            pass

    def put(self, key: str, value: dict, ttl_s=_TTL_DEFAULT) -> None:
        """Store a JSON-able value dict under ``key`` (memory + disk).

        ``ttl_s`` overrides the cache-wide default time-to-live for this
        entry (``None`` = never expire).  The disk spill is crash- and
        concurrency-safe: each writer stages into its own
        ``<sha>.<pid>.<uuid>.tmp`` (two processes spilling the same key can
        never interleave bytes in a shared staging file), the publish is an
        atomic ``os.replace`` under an advisory ``flock``
        (:func:`_dir_lock`), and stale ``.tmp`` leftovers from crashed
        writers are swept so they cannot accumulate and poison the dir.
        When ``max_disk_bytes`` is set, the spill dir is LRU-swept back
        under budget after every publish.
        """
        if ttl_s is _TTL_DEFAULT:
            ttl_s = self.ttl_s
        expires_at = None if ttl_s is None else time.time() + float(ttl_s)
        self._mem_put(key, value, expires_at)
        with self._lock:
            self.puts += 1
        if self.disk_dir is None:
            return
        tmp = None
        try:
            self.disk_dir.mkdir(parents=True, exist_ok=True)
            self._clean_stale_tmp()
            path = self._disk_path(key)
            tmp = path.with_name(f"{path.stem}.{os.getpid()}."
                                 f"{uuid.uuid4().hex[:8]}.tmp")
            blob = {"key": key, "value": value}
            if expires_at is not None:
                blob["expires_at"] = expires_at
            tmp.write_text(json.dumps(blob, default=_jsonable))
            with _dir_lock(self.disk_dir):
                os.replace(tmp, path)
        except OSError:
            if tmp is not None:          # disk spill is best-effort, but
                try:                     # never leave our own litter
                    tmp.unlink()
                except OSError:
                    pass
        self._enforce_disk_budget()

    def _enforce_disk_budget(self) -> None:
        """Sweep spill files oldest-``st_mtime``-first until the dir is
        back under ``max_disk_bytes``.  Disk hits refresh mtime
        (:meth:`_disk_get`), so the sweep order is LRU by *access*."""
        if self.max_disk_bytes is None or self.disk_dir is None:
            return
        try:
            with _dir_lock(self.disk_dir):
                entries = []
                total = 0
                for p in self.disk_dir.glob("*.json"):
                    try:
                        st = p.stat()
                    except OSError:
                        continue
                    entries.append((st.st_mtime, st.st_size, p))
                    total += st.st_size
                entries.sort(key=lambda e: (e[0], e[2].name))
                for _, size, p in entries:
                    if total <= self.max_disk_bytes:
                        break
                    try:
                        p.unlink()
                    except OSError:
                        continue
                    total -= size
                    with self._lock:
                        self.disk_evictions += 1
        except OSError:                   # pragma: no cover - racing rmdir
            pass

    def invalidate(self, problem_hash: str) -> int:
        """Explicitly drop every entry derived from one
        ``problem.content_hash()`` — memory and disk spill both (the
        ``sol:`` solution *and* every ``lay:`` layout keyed to it).  Use
        when a topology's ground truth changed out from under its hash
        inputs (e.g. the machine was re-ranked) or a served plan must be
        force-recomputed.  Returns the number of distinct keys dropped (a
        key present both in memory and on disk counts once)."""
        h = str(problem_hash)

        def _match(key: str) -> bool:
            parts = key.split(":", 2)
            return len(parts) >= 3 and parts[1] == h

        doomed = set()
        with self._lock:
            for k in [k for k in self._mem if _match(k)]:
                del self._mem[k]
                self._exp.pop(k, None)
                doomed.add(k)
        if self.disk_dir is not None:
            try:
                with _dir_lock(self.disk_dir):
                    for p in self.disk_dir.glob("*.json"):
                        try:
                            blob = json.loads(p.read_text())
                            key = str(blob.get("key", ""))
                            if _match(key):
                                p.unlink()
                                doomed.add(key)
                        except (OSError, ValueError):
                            continue
            except OSError:               # pragma: no cover - racing rmdir
                pass
        with self._lock:
            self.invalidations += len(doomed)
        return len(doomed)

    def clear(self) -> None:
        """Drop the in-memory entries and reset counters (disk files stay)."""
        with self._lock:
            self._mem.clear()
            self._exp.clear()
            self.hits = self.misses = self.disk_hits = 0
            self.puts = self.evictions = self.corrupt_drops = 0
            self.expired = self.invalidations = self.disk_evictions = 0

    def stats(self) -> Dict[str, int]:
        with self._lock:
            out = {"size": len(self._mem), "hits": self.hits,
                   "misses": self.misses, "disk_hits": self.disk_hits,
                   "puts": self.puts, "evictions": self.evictions,
                   "corrupt_drops": self.corrupt_drops,
                   "expired": self.expired,
                   "invalidations": self.invalidations,
                   "disk_evictions": self.disk_evictions}
        if self.disk_dir is not None:
            files = n_bytes = 0
            try:
                for p in self.disk_dir.glob("*.json"):
                    try:
                        n_bytes += p.stat().st_size
                        files += 1
                    except OSError:
                        continue
            except OSError:               # pragma: no cover - racing rmdir
                pass
            out["disk_files"] = files
            out["disk_bytes"] = n_bytes
        return out

    # -- typed entry points ---------------------------------------------------
    # Hit paths hand back fresh copies (np.array copies; stats go through a
    # json round-trip), so callers can never mutate a live cache entry.

    def solve(self, problem: MappingProblem,
              plan: MappingPlan) -> MappingSolution:
        """``plan.solve(problem)``, memoized by content.  Plans without a
        stable content key (``plan.cacheable`` False) are solved fresh —
        an unsound key must never serve a wrong solution."""
        if not plan.cacheable:
            return plan.solve(problem, cache=None)
        key = f"sol:{problem.content_hash()}:{plan.key}"
        hit = self.get(key)
        if hit is not None:
            return MappingSolution(
                assignment=np.array(hit["assignment"], dtype=np.int64),
                j_sum=float(hit["j_sum"]), j_max=float(hit["j_max"]),
                problem=problem, plan_key=plan.key,
                stage_stats=_jsonable_stats(hit["stage_stats"]),
                wall_time_s=float(hit["wall_time_s"]), from_cache=True)
        sol = plan.solve(problem, cache=None)
        self.put(key, {
            "assignment": np.array(sol.assignment, dtype=np.int64),
            "j_sum": sol.j_sum, "j_max": sol.j_max,
            "stage_stats": _jsonable_stats(sol.stage_stats),
            "wall_time_s": sol.wall_time_s,
        })
        return sol

    def layout(self, problem: MappingProblem, plan_key: str,
               intra_order: str, compute) -> np.ndarray:
        """Memoize a device layout (``remap.device_layout`` output, which
        additionally depends on the intra-node rank order)."""
        key = f"lay:{problem.content_hash()}:{plan_key}:{intra_order}"
        hit = self.get(key)
        if hit is not None:
            return np.array(hit["layout"],
                            dtype=np.int64).reshape(problem.mesh_shape)
        L = np.asarray(compute(), dtype=np.int64)
        self.put(key, {"layout": L.reshape(-1).copy()})
        return L


def _jsonable(v):
    """json.dumps ``default=``: numpy scalars/arrays -> plain Python."""
    if isinstance(v, np.ndarray):
        return v.tolist()
    if isinstance(v, (np.integer, np.floating, np.bool_)):
        return v.item()
    return str(v)


def _jsonable_stats(stats: List[dict]) -> List[dict]:
    return json.loads(json.dumps(stats, default=_jsonable))


_default_cache: Optional[PlanCache] = None
_default_lock = threading.Lock()


def default_plan_cache() -> PlanCache:
    """The process-wide cache `device_layout`/`mapped_device_array`/
    `make_mapped_mesh`/`cart_create` use unless told otherwise (memory
    only; build your own ``PlanCache(disk_dir=True)`` to spill)."""
    global _default_cache
    with _default_lock:
        if _default_cache is None:
            _default_cache = PlanCache()
        return _default_cache


def resolve_cache(cache: Union[None, bool, PlanCache]) -> Optional[PlanCache]:
    """``None`` -> the process default, ``False`` -> caching off, a
    :class:`PlanCache` -> itself."""
    if cache is None:
        return default_plan_cache()
    if cache is False:
        return None
    if cache is True:
        return default_plan_cache()
    return cache


# ---------------------------------------------------------------------------
# the MPI-style facade


@dataclass
class CartResult:
    """What :func:`cart_create` hands back: the solved problem, the device
    layout realising it, and the solution provenance."""

    problem: MappingProblem
    plan_key: str
    solution: MappingSolution
    layout: np.ndarray              # mesh_shape -> device index

    @property
    def from_cache(self) -> bool:
        return self.solution.from_cache

    @property
    def j_sum(self) -> float:
        return self.solution.j_sum

    @property
    def j_max(self) -> float:
        return self.solution.j_max

    def mesh(self, devices: Optional[Sequence] = None,
             axes: Optional[Sequence[str]] = None):
        """Materialize a ``jax.sharding.Mesh`` over ``devices`` (default:
        ``jax.devices()``, pod-major runtime order) permuted by this
        layout (same convention as ``mapped_device_array``)."""
        import jax
        from jax.sharding import Mesh
        from .remap import apply_layout
        devs = list(devices) if devices is not None else list(jax.devices())
        if axes is None:
            if len(self.layout.shape) == 2:
                axes = ("data", "model")
            elif len(self.layout.shape) == 3:
                axes = ("pod", "data", "model")
            else:
                raise ValueError("pass axes for a rank-"
                                 f"{len(self.layout.shape)} mesh")
        return Mesh(apply_layout(devs, self.layout), tuple(axes))


def cart_create(mesh_shape: Sequence[int],
                stencil: Optional[Stencil] = None, *,
                node_sizes: Optional[Sequence[int]] = None,
                chips_per_pod: Optional[int] = None,
                periodic: Optional[Sequence[bool]] = None,
                objective: str = "lex",
                plan: Union[str, MappingPlan] = DEFAULT_CART_PLAN,
                cache: Union[None, bool, PlanCache] = None,
                reorder: bool = True) -> CartResult:
    """``MPI_Cart_create(reorder=1)``, library-shaped: one call from a mesh
    shape + stencil to a topology-aware device layout, served from the
    plan cache when the same problem signature was solved before.

    Args:
      mesh_shape: the virtual Cartesian grid (one entry per mesh axis).
      stencil: communication pattern (default: nearest-neighbor of the
        grid's rank; pass ``launch.mesh.stencil_for_plan``'s byte-weighted
        stencil for real workloads).
      node_sizes: chips per node/pod (ragged allowed — elastic pods).
        Exactly one of ``node_sizes`` / ``chips_per_pod`` is required;
        ``chips_per_pod`` splits the mesh blocked with a ragged tail pod
        when it doesn't divide evenly.
      periodic: per-axis wraparound (``MPI_Cart_create``'s ``periods``).
      objective: declared optimization target (part of the cache key).
      plan: a registry spelling (any ``parse_plan`` grammar, chained
        prefixes included) or a :class:`MappingPlan`.
      cache: ``None`` -> process-default :class:`PlanCache`, ``False`` ->
        no caching, or an explicit cache instance.
      reorder: ``False`` returns the identity (blocked) layout, like
        ``MPI_Cart_create(reorder=0)``.

    Returns a :class:`CartResult`; ``result.layout[logical coord] =
    device index`` (row-major intra-node order), ``result.mesh()``
    materializes the ``jax.sharding.Mesh``.
    """
    mesh_shape = tuple(int(d) for d in mesh_shape)
    p = math.prod(mesh_shape)
    if stencil is None:
        stencil = Stencil.nearest_neighbor(len(mesh_shape))
    if node_sizes is not None and chips_per_pod is not None:
        raise ValueError("pass node_sizes or chips_per_pod, not both")
    if node_sizes is not None:
        node_sizes = tuple(int(n) for n in node_sizes)
    elif chips_per_pod is not None:
        node_sizes = blocked_node_sizes(p, chips_per_pod)
    else:
        raise ValueError("cart_create needs node_sizes or chips_per_pod")
    problem = MappingProblem(mesh_shape, stencil, node_sizes,
                             objective=objective,
                             periodic=None if periodic is None
                             else tuple(periodic))
    if not reorder:
        plan = "blocked"
    if isinstance(plan, str):
        plan = parse_plan(plan)
    c = resolve_cache(cache)
    solution = plan.solve(problem, cache=c)
    return CartResult(problem=problem, plan_key=plan.key, solution=solution,
                      layout=solution.layout())


def graph_create(graph, *,
                 node_sizes: Optional[Sequence[int]] = None,
                 chips_per_pod: Optional[int] = None,
                 objective: str = "lex",
                 plan: Union[str, MappingPlan] = DEFAULT_GRAPH_PLAN,
                 cache: Union[None, bool, PlanCache] = None,
                 reorder: bool = True) -> CartResult:
    """:func:`cart_create` for arbitrary communication graphs: one call
    from a :class:`~repro.core.graph.CommGraph` (any extractor —
    ``from_stencil`` / ``from_hlo`` / ``from_moe`` / ``arch_comm_graph``)
    to a topology-aware device layout, served from the plan cache.

    The plan runs in the ``graph:`` flavor (prefixed automatically when
    ``plan`` is a spelling without it), so the refine stack optimizes the
    graph objective directly; ``result.layout`` maps logical position ->
    device index exactly as :func:`cart_create` (1-D for general graphs,
    the provenance mesh shape for stencil-extracted ones).

    Usage::

        g = CommGraph.from_moe("mixtral_8x7b", num_devices=64)
        r = graph_create(g, chips_per_pod=8)
        r.layout, r.j_max, r.from_cache
    """
    if node_sizes is None and chips_per_pod is None:
        raise ValueError("graph_create needs node_sizes or chips_per_pod")
    if node_sizes is not None and chips_per_pod is not None:
        raise ValueError("pass node_sizes or chips_per_pod, not both")
    problem = MappingProblem.from_graph(
        graph,
        node_sizes if node_sizes is not None
        else blocked_node_sizes(graph.n, chips_per_pod),
        objective=objective)
    if not reorder:
        plan = "graph:blocked"
    if isinstance(plan, str):
        plan = parse_plan(plan if plan.startswith("graph:")
                          else f"graph:{plan}")
    solution = plan.solve(problem, cache=resolve_cache(cache))
    return CartResult(problem=problem, plan_key=plan.key, solution=solution,
                      layout=solution.layout())
