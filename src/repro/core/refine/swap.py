"""Pairwise-swap local search over a node-of-position assignment.

Swaps exchange the owning nodes of two grid positions, so the per-node
cardinalities — the scheduler's allocation — are preserved by construction;
only improving swaps are accepted, so the objective is monotonically
non-increasing.  Candidate generation is boundary-driven: only positions
with a crossing incident edge can gain from a swap with one of their
stencil neighbours on a different node, which keeps a pass at
O(|boundary| * k^2) delta evaluations instead of O(p^2).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

import numpy as np

from ..cost import MappingCost
from ..cost_delta import IncrementalCost
from ..grid import CartGrid
from ..stencil import Stencil

__all__ = ["SwapRefiner", "RefineResult", "refine_assignment"]

_OBJECTIVES = ("j_sum", "j_max")
_POLICIES = ("first", "steepest")


@dataclass
class RefineResult:
    """Outcome of one refinement run."""

    assignment: np.ndarray       # (p,) refined node-of-position
    initial: MappingCost
    final: MappingCost
    swaps: int
    passes: int
    wall_time_s: float

    @property
    def improvement(self) -> float:
        return self.initial.j_sum - self.final.j_sum


class SwapRefiner:
    """Greedy boundary-vertex swap refinement.

    Args:
      objective: "j_sum" (total inter-node edges) or "j_max" (bottleneck
        node's outgoing edges, J_sum as tie-break).
      policy: "first" accepts the first improving swap while scanning the
        boundary; "steepest" scans the whole boundary each round and applies
        the single best swap.
      max_passes: full boundary sweeps before giving up.
      max_swaps: hard cap on accepted swaps (None = unlimited).
      weighted: score with the stencil's per-offset byte weights.
      tol: minimum improvement for a swap to count (guards float noise on
        weighted stencils; exact 0.0 works for unit weights).
      max_partners: cap on non-adjacent swap partners considered per
        boundary vertex (evenly subsampled, deterministic).  Partners are
        boundary vertices of the nodes p communicates with (KL/FM-style),
        which catches improving exchanges between cells that are not
        stencil neighbours of each other.
    """

    def __init__(self, objective: str = "j_sum", policy: str = "first",
                 max_passes: int = 8, max_swaps: Optional[int] = None,
                 weighted: bool = False, tol: float = 1e-12,
                 max_partners: int = 32):
        if objective not in _OBJECTIVES:
            raise ValueError(f"objective must be one of {_OBJECTIVES}")
        if policy not in _POLICIES:
            raise ValueError(f"policy must be one of {_POLICIES}")
        if max_passes <= 0:
            raise ValueError("max_passes must be positive")
        self.objective = objective
        self.policy = policy
        self.max_passes = int(max_passes)
        self.max_swaps = max_swaps
        self.weighted = weighted
        self.tol = float(tol)
        self.max_partners = int(max_partners)

    # -- scoring ------------------------------------------------------------
    def _gain(self, ic: IncrementalCost, p: int, q: int) -> float:
        """Positive improvement of the configured objective for swap (p, q)."""
        delta = ic.delta_swap(p, q)
        if self.objective == "j_sum":
            return -delta.d_j_sum
        # j_max: lexicographic (j_max, j_sum); fold the tie-break in with a
        # weight small enough not to override a strict j_max improvement.
        if not delta.d_count_node and delta.d_j_sum == 0.0:
            return 0.0
        d_max = ic.j_max - ic.peek_j_max(delta)  # both O(N) via cache
        if d_max != 0.0:
            return d_max
        return -delta.d_j_sum * 1e-9 if delta.d_j_sum < 0 else 0.0

    # -- driver -------------------------------------------------------------
    def refine(self, grid: CartGrid, stencil: Stencil,
               node_of_pos: np.ndarray,
               num_nodes: Optional[int] = None) -> RefineResult:
        t0 = time.perf_counter()
        ic = IncrementalCost(grid, stencil, node_of_pos, num_nodes=num_nodes,
                             weighted=self.weighted)
        initial = ic.cost()
        swaps = passes = 0
        budget = self.max_swaps if self.max_swaps is not None else np.inf
        while passes < self.max_passes and swaps < budget:
            passes += 1
            improved = False
            if self.policy == "steepest":
                improved, swaps = self._steepest_pass(ic, swaps, budget)
            else:
                improved, swaps = self._first_pass(ic, swaps, budget)
            if not improved:
                break
        return RefineResult(assignment=ic.node_of_pos.copy(), initial=initial,
                            final=ic.cost(), swaps=swaps, passes=passes,
                            wall_time_s=time.perf_counter() - t0)

    def _candidates(self, ic: IncrementalCost, p: int,
                    boundary: np.ndarray) -> np.ndarray:
        """Stencil-adjacent partners first (cheap locality), then boundary
        vertices of the nodes p's crossing edges touch."""
        node = ic.node_of_pos
        nbrs = ic.neighbors_of(p)
        adj = nbrs[node[nbrs] != node[p]]
        touched = np.unique(node[adj])
        if touched.size == 0:
            return adj
        far = boundary[np.isin(node[boundary], touched)]
        far = far[~np.isin(far, adj)]
        if far.size > self.max_partners:
            idx = (np.arange(self.max_partners)
                   * (far.size / self.max_partners)).astype(np.int64)
            far = far[idx]
        return np.concatenate([adj, far])

    def _first_pass(self, ic: IncrementalCost, swaps: int,
                    budget: float) -> Tuple[bool, int]:
        improved = False
        boundary = ic.boundary_positions()
        for p in boundary:
            if swaps >= budget:
                break
            for q in self._candidates(ic, p, boundary):
                if self._gain(ic, p, int(q)) > self.tol:
                    ic.apply_swap(p, int(q))
                    swaps += 1
                    improved = True
                    break   # p's neighbourhood changed; move on
        return improved, swaps

    def _steepest_pass(self, ic: IncrementalCost, swaps: int,
                       budget: float) -> Tuple[bool, int]:
        """One full boundary sweep, then apply the single best swap — so a
        steepest pass is one sweep and max_passes bounds total work."""
        if swaps >= budget:
            return False, swaps
        best_gain, best = self.tol, None
        boundary = ic.boundary_positions()
        for p in boundary:
            for q in self._candidates(ic, p, boundary):
                g = self._gain(ic, p, int(q))
                if g > best_gain:
                    best_gain, best = g, (int(p), int(q))
        if best is None:
            return False, swaps
        ic.apply_swap(*best)
        return True, swaps + 1


def refine_assignment(grid: CartGrid, stencil: Stencil,
                      node_of_pos: np.ndarray,
                      num_nodes: Optional[int] = None,
                      **refiner_kwargs) -> RefineResult:
    """One-call convenience: refine an assignment with default settings."""
    return SwapRefiner(**refiner_kwargs).refine(grid, stencil, node_of_pos,
                                                num_nodes=num_nodes)
