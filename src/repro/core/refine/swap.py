"""Pairwise-swap local search over a node-of-position assignment.

Swaps exchange the owning nodes of two grid positions, so the per-node
cardinalities — the scheduler's allocation — are preserved by construction;
only improving swaps are accepted, so the objective is monotonically
non-increasing.  Candidate generation is boundary-driven: only positions
with a crossing incident edge can gain from a swap with one of their
stencil neighbours on a different node, which keeps a pass at
O(|boundary| * k^2) delta evaluations instead of O(p^2).

Two engines implement the same search:

* ``engine="batch"`` (default) — builds the whole candidate frontier as
  ``(P, Q)`` index arrays and scores every pair in one
  :meth:`~repro.core.cost_delta.IncrementalCost.batch_swap_deltas` call.
  A steepest pass is then a single ``argmax`` over the gain array; a
  first-improvement pass applies a maximal set of spatially-disjoint
  improving swaps per batch (positions whose neighbourhood an accepted
  swap touched are masked out, so every applied delta is still exact).
* ``engine="scalar"`` — the PR-1 per-vertex Python loop, kept as the
  bit-exact reference the batch engine is tested and benchmarked against.

Usage::

    refiner = SwapRefiner(objective="j_max", policy="steepest")
    res = refiner.refine(grid, stencil, node_of_pos, num_nodes=N)
    res.assignment, res.final.j_sum, res.final.j_max, res.wall_time_s
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

import numpy as np

from ..cost import MappingCost
from ..cost_delta import LOAD_CHUNK_ELEMS, IncrementalCost
from ..grid import CartGrid
from ..stencil import Stencil

__all__ = ["SwapRefiner", "RefineResult", "refine_assignment"]

_OBJECTIVES = ("j_sum", "j_max")
_POLICIES = ("first", "steepest")
_ENGINES = ("batch", "scalar")

#: j_max batch scoring materializes (chunk, N) load matrices; this bounds
#: chunk * N so peak extra memory stays ~tens of MB regardless of frontier.
_LOAD_CHUNK_ELEMS = LOAD_CHUNK_ELEMS
#: soft cap on far (non-adjacent) candidate pairs per sweep: when the
#: frontier is huge (early refinement of a random-quality mapping) the
#: per-vertex partner cap is scaled down so one sweep stays bounded.
_MAX_FAR_PAIRS = 200_000


@dataclass
class RefineResult:
    """Outcome of one refinement run.  ``stats`` carries engine-specific
    extras (the portfolio engine reports per-ladder keys, kills, and stage
    wall-times there)."""

    assignment: np.ndarray       # (p,) refined node-of-position
    initial: MappingCost
    final: MappingCost
    swaps: int
    passes: int
    wall_time_s: float
    stats: Optional[dict] = None

    @property
    def improvement(self) -> float:
        return self.initial.j_sum - self.final.j_sum


class SwapRefiner:
    """Greedy boundary-vertex swap refinement.

    Args:
      objective: "j_sum" (total inter-node edges) or "j_max" (bottleneck
        node's outgoing edges, J_sum as tie-break).
      policy: "first" accepts improving swaps while scanning the boundary
        (the batch engine applies a maximal spatially-disjoint set per
        sweep); "steepest" scores the whole frontier each round and applies
        the single best swap.
      max_passes: full boundary sweeps before giving up.
      max_swaps: hard cap on accepted swaps (None = unlimited).
      weighted: score with the stencil's per-offset byte weights; the
        default ``"auto"`` uses them iff the stencil carries non-unit
        weights, so byte-weighted and unit-weight objectives share this one
        code path.
      tol: minimum improvement for a swap to count, in units of the mean
        offset weight (scaled at refine time, so the default guards float
        noise on byte-weighted stencils and stays exact-zero-equivalent for
        unit weights).
      max_partners: cap on non-adjacent swap partners considered per
        (boundary vertex, communicating node) pair (evenly subsampled,
        deterministic).  Partners are boundary vertices of the nodes p
        communicates with (KL/FM-style), which catches improving exchanges
        between cells that are not stencil neighbours of each other.
      engine: "batch" (vectorized frontier scoring) or "scalar" (PR-1
        reference loop).
    """

    def __init__(self, objective: str = "j_sum", policy: str = "first",
                 max_passes: int = 8, max_swaps: Optional[int] = None,
                 weighted="auto", tol: float = 1e-12,
                 max_partners: int = 32, engine: str = "batch"):
        if objective not in _OBJECTIVES:
            raise ValueError(f"objective must be one of {_OBJECTIVES}")
        if policy not in _POLICIES:
            raise ValueError(f"policy must be one of {_POLICIES}")
        if engine not in _ENGINES:
            raise ValueError(f"engine must be one of {_ENGINES}")
        if max_passes <= 0:
            raise ValueError("max_passes must be positive")
        self.objective = objective
        self.policy = policy
        self.max_passes = int(max_passes)
        self.max_swaps = max_swaps
        self.weighted = weighted
        self.tol = float(tol)
        self.max_partners = int(max_partners)
        self.engine = engine

    def as_stage(self, budget: Optional[int] = None):
        """Uniform :class:`~repro.core.refine.stage.RefineStage` adapter
        (``budget`` caps this stage's accepted swaps)."""
        from .stage import RefineStage
        return RefineStage(self, budget=budget, prefix="refined")

    def config(self) -> dict:
        """Full constructor configuration — the stage layer's canonical
        cache identity for hand-built refiners."""
        return {"objective": self.objective, "policy": self.policy,
                "max_passes": self.max_passes, "max_swaps": self.max_swaps,
                "weighted": self.weighted, "tol": self.tol,
                "max_partners": self.max_partners, "engine": self.engine}

    def _tol(self, ic: IncrementalCost) -> float:
        """Acceptance threshold in the objective's own units: byte-weighted
        deltas are ~mean-weight sized, so the raw tol would drown in float
        noise there; unit weights leave it bitwise unchanged."""
        return self.tol * float(np.mean(ic.weights))

    # -- driver -------------------------------------------------------------
    def refine(self, grid: CartGrid, stencil: Stencil,
               node_of_pos: np.ndarray,
               num_nodes: Optional[int] = None) -> RefineResult:
        t0 = time.perf_counter()
        ic = IncrementalCost(grid, stencil, node_of_pos, num_nodes=num_nodes,
                             weighted=self.weighted)
        initial = ic.cost()
        swaps = passes = 0
        budget = self.max_swaps if self.max_swaps is not None else np.inf
        while passes < self.max_passes and swaps < budget:
            passes += 1
            if self.engine == "scalar":
                if self.policy == "steepest":
                    improved, swaps = self._steepest_pass_scalar(ic, swaps,
                                                                 budget)
                else:
                    improved, swaps = self._first_pass_scalar(ic, swaps,
                                                              budget)
            elif self.policy == "steepest":
                improved, swaps = self._steepest_pass(ic, swaps, budget)
            else:
                improved, swaps = self._first_pass(ic, swaps, budget)
            if not improved:
                break
        return RefineResult(assignment=ic.node_of_pos.copy(), initial=initial,
                            final=ic.cost(), swaps=swaps, passes=passes,
                            wall_time_s=time.perf_counter() - t0)

    # -- batch engine -------------------------------------------------------
    def _frontier_pairs(self, ic: IncrementalCost) \
            -> Tuple[np.ndarray, np.ndarray]:
        """All candidate swap pairs as (P, Q) arrays, deduplicated with
        P < Q: every crossing stencil edge, plus for each boundary vertex
        up to ``max_partners`` boundary vertices of each node its crossing
        edges touch (evenly subsampled in boundary order)."""
        node, t, size = ic.node_of_pos, ic.table, ic.grid.size
        n_nodes = ic.n_nodes
        us, vs = [], []
        for j in range(ic.stencil.k):
            u = np.nonzero(t.out_valid[j] & (node != node[t.out_tgt[j]]))[0]
            us.append(u)
            vs.append(t.out_tgt[j][u])
        U = np.concatenate(us) if us else np.empty(0, dtype=np.int64)
        V = np.concatenate(vs) if vs else np.empty(0, dtype=np.int64)
        if U.size == 0:
            return (np.empty(0, dtype=np.int64),) * 2
        adj_codes = np.minimum(U, V) * size + np.maximum(U, V)
        # (boundary vertex, communicating node) pairs from both edge ends
        pt = np.unique(np.concatenate([U * n_nodes + node[V],
                                       V * n_nodes + node[U]]))
        p_of, tn_of = pt // n_nodes, pt % n_nodes
        boundary = np.nonzero(np.bincount(
            np.concatenate([U, V]), minlength=size))[0]
        order = np.argsort(node[boundary], kind="stable")
        members = boundary[order]                       # boundary, node-major
        cnt_node = np.bincount(node[boundary], minlength=n_nodes)
        starts = np.concatenate([[0], np.cumsum(cnt_node)[:-1]])
        cap = self.max_partners
        if p_of.size * cap > _MAX_FAR_PAIRS:
            cap = max(1, _MAX_FAR_PAIRS // p_of.size)
        cnt = cnt_node[tn_of]
        take = np.minimum(cnt, cap)
        rows = np.repeat(np.arange(p_of.size), take)
        seg_start = np.cumsum(take) - take
        within = np.arange(int(take.sum())) - np.repeat(seg_start, take)
        stride = cnt / np.maximum(take, 1)
        idx = starts[tn_of][rows] + (within * stride[rows]).astype(np.int64)
        Pf, Qf = p_of[rows], members[idx]
        keep = Pf != Qf
        far_codes = (np.minimum(Pf, Qf) * size + np.maximum(Pf, Qf))[keep]
        codes = np.unique(np.concatenate([adj_codes, far_codes]))
        return codes // size, codes % size

    def _batch_gains(self, ic: IncrementalCost, P: np.ndarray, Q: np.ndarray,
                     need_affected: bool = False) \
            -> Tuple[np.ndarray, Optional[np.ndarray], Optional[np.ndarray]]:
        """Per-pair gain of the configured objective (positive = improving).
        For j_max also returns the strict-improvement mask (gains driven by
        a real bottleneck drop rather than the J_sum tie-break) and, when
        ``need_affected`` (first-improvement's disjointness guard), the
        (m, N) bool mask of nodes whose load each swap would change."""
        if self.objective == "j_sum":
            bd = ic.batch_swap_deltas(P, Q)
            return -bd.d_j_sum, None, None
        # j_max scoring needs (m, N) load matrices; chunk so peak memory is
        # bounded no matter how large the frontier is.
        per_node, j_max_now, m = ic.per_node, ic.j_max, P.size
        chunk = max(1, _LOAD_CHUNK_ELEMS // max(1, ic.n_nodes))
        gains = np.empty(m, dtype=np.float64)
        strict = np.empty(m, dtype=bool)
        affected = (np.empty((m, ic.n_nodes), dtype=bool)
                    if need_affected else None)
        for s in range(0, m, chunk):
            e = min(s + chunk, m)
            bd = ic.batch_swap_deltas(P[s:e], Q[s:e], with_loads=True)
            primary = j_max_now - bd.new_j_max
            tie = np.where(bd.d_j_sum < 0, -bd.d_j_sum * 1e-9, 0.0)
            gains[s:e] = np.where(primary != 0.0, primary, tie)
            strict[s:e] = primary > 0.0
            if need_affected:
                affected[s:e] = bd.new_per_node != per_node[None, :]
        return gains, strict, affected

    def _steepest_pass(self, ic: IncrementalCost, swaps: int,
                       budget: float) -> Tuple[bool, int]:
        """One whole-frontier batch, then apply the single best swap."""
        if swaps >= budget:
            return False, swaps
        P, Q = self._frontier_pairs(ic)
        if P.size == 0:
            return False, swaps
        gains, _, _ = self._batch_gains(ic, P, Q)
        best = int(np.argmax(gains))
        if gains[best] <= self._tol(ic):
            return False, swaps
        ic.apply_swap(int(P[best]), int(Q[best]))
        return True, swaps + 1

    def _first_pass(self, ic: IncrementalCost, swaps: int,
                    budget: float) -> Tuple[bool, int]:
        """One whole-frontier batch, then greedily apply every improving
        swap whose endpoints are spatially disjoint from earlier accepted
        swaps (and their stencil neighbourhoods), so each applied delta is
        still exact against the committed state.

        Under j_max two extra guards keep the pass lexicographically
        monotone: only same-kind swaps are combined per sweep (all strict
        bottleneck drops, or all J_sum tie-breaks — mixing the two can
        re-raise the bottleneck a strict swap just lowered while a
        tie-break swap raises J_sum), and accepted swaps must touch
        disjoint *node* load sets (two distant swaps may each keep the max
        at M while jointly pushing a shared node past it).
        """
        P, Q = self._frontier_pairs(ic)
        if P.size == 0:
            return False, swaps
        gains, strict, affected = self._batch_gains(ic, P, Q,
                                                    need_affected=True)
        improving = gains > self._tol(ic)
        if strict is not None and bool(np.any(improving & strict)):
            improving &= strict
        cand = np.nonzero(improving)[0]
        if cand.size == 0:
            return False, swaps
        dirty = np.zeros(ic.grid.size, dtype=bool)
        dirty_nodes = np.zeros(ic.n_nodes, dtype=bool)
        applied = False
        for i in cand:
            if swaps >= budget:
                break
            p, q = int(P[i]), int(Q[i])
            if dirty[p] or dirty[q]:
                continue
            if affected is not None and bool(np.any(dirty_nodes
                                                    & affected[i])):
                continue
            ic.apply_swap(p, q)
            swaps += 1
            applied = True
            dirty[p] = dirty[q] = True
            dirty[ic.neighbors_of(p)] = True
            dirty[ic.neighbors_of(q)] = True
            if affected is not None:
                dirty_nodes |= affected[i]
        return applied, swaps

    # -- scalar reference engine (PR-1 loop) --------------------------------
    def _gain(self, ic: IncrementalCost, p: int, q: int) -> float:
        """Positive improvement of the configured objective for swap (p, q)."""
        delta = ic.delta_swap(p, q)
        if self.objective == "j_sum":
            return -delta.d_j_sum
        # j_max: lexicographic (j_max, j_sum); fold the tie-break in with a
        # weight small enough not to override a strict j_max improvement.
        if not delta.d_count_node and delta.d_j_sum == 0.0:
            return 0.0
        d_max = ic.j_max - ic.peek_j_max(delta)  # both O(N) via cache
        if d_max != 0.0:
            return d_max
        return -delta.d_j_sum * 1e-9 if delta.d_j_sum < 0 else 0.0

    def _candidates(self, ic: IncrementalCost, p: int,
                    boundary: np.ndarray) -> np.ndarray:
        """Stencil-adjacent partners first (cheap locality), then boundary
        vertices of the nodes p's crossing edges touch."""
        node = ic.node_of_pos
        nbrs = ic.neighbors_of(p)
        adj = nbrs[node[nbrs] != node[p]]
        touched = np.unique(node[adj])
        if touched.size == 0:
            return adj
        far = boundary[np.isin(node[boundary], touched)]
        far = far[~np.isin(far, adj)]
        if far.size > self.max_partners:
            idx = (np.arange(self.max_partners)
                   * (far.size / self.max_partners)).astype(np.int64)
            far = far[idx]
        return np.concatenate([adj, far])

    def _first_pass_scalar(self, ic: IncrementalCost, swaps: int,
                           budget: float) -> Tuple[bool, int]:
        improved = False
        boundary = ic.boundary_positions()
        tol = self._tol(ic)
        for p in boundary:
            if swaps >= budget:
                break
            for q in self._candidates(ic, p, boundary):
                if self._gain(ic, p, int(q)) > tol:
                    ic.apply_swap(p, int(q))
                    swaps += 1
                    improved = True
                    break   # p's neighbourhood changed; move on
        return improved, swaps

    def _steepest_pass_scalar(self, ic: IncrementalCost, swaps: int,
                              budget: float) -> Tuple[bool, int]:
        """One full boundary sweep, then apply the single best swap — so a
        steepest pass is one sweep and max_passes bounds total work."""
        if swaps >= budget:
            return False, swaps
        best_gain, best = self._tol(ic), None
        boundary = ic.boundary_positions()
        for p in boundary:
            for q in self._candidates(ic, p, boundary):
                g = self._gain(ic, p, int(q))
                if g > best_gain:
                    best_gain, best = g, (int(p), int(q))
        if best is None:
            return False, swaps
        ic.apply_swap(*best)
        return True, swaps + 1


def refine_assignment(grid: CartGrid, stencil: Stencil,
                      node_of_pos: np.ndarray,
                      num_nodes: Optional[int] = None,
                      **refiner_kwargs) -> RefineResult:
    """One-call convenience: refine an assignment with default settings."""
    return SwapRefiner(**refiner_kwargs).refine(grid, stencil, node_of_pos,
                                                num_nodes=num_nodes)
