"""Recursive multilevel mapping down a topology tree (``hier:``).

Flat refinement treats the machine as one level of N nodes; real machines
are trees (rack → pod → chip), and *High-Quality Hierarchical Process
Mapping* (Faraj et al., 2001.07134) shows the wins come from solving the
mapping level by level: group the nodes by the topology's per-level
fan-outs, solve the *much smaller* top-level problem (children as
"nodes"), then recurse into each child with exactly the grid region it
was assigned.  :class:`HierRefiner` is that scheme built out of this
repo's existing refiners:

* the node axis is grouped by ``fanouts`` (e.g. ``16x16`` — 16 groups of
  16 pods; auto-derived via :func:`~repro.core.grid.dims_create` from
  ``depth`` when unspecified), matching a
  :class:`~repro.topology.machine.TopologyTree`'s grouping levels;
* every restricted subproblem is the *induced subgraph* of the stencil
  graph on the subtree's grid region, realized by :class:`MaskedGrid` — a
  :class:`~repro.core.grid.CartGrid` view whose ``shift_ranks`` declares
  edges valid only when **both** endpoints are inside the region.
  Positions outside get a zero-degree ghost label, so they carry no load,
  never enter a boundary/frontier, and are never proposed for a swap —
  the existing refiners run on subproblems completely unmodified;
* each level's restricted problem is solved by any registered refine
  spelling (default ``annealed``; per level via
  ``hier[levels=rack:portfolio[k=8],pod:annealed]:<base>``), seeded from
  the incoming assignment with a keep-if-capacity repair so the base
  mapper's spatial structure survives into every subtree;
* sub-solutions are individually cached (content-keyed over the region,
  capacities, seed, stencil, and solver), so an elastic re-mesh that
  churns one subtree re-solves only that subtree — every untouched
  sibling is a cache hit;
* an optional bounded global polish pass (``polish=<swap budget>``) runs
  the deterministic scheduled refiner on the composed assignment to fix
  cross-subtree J_max.

Usage::

    get_mapper("hier:hyperplane")                       # auto 2-level
    get_mapper("hier[fanouts=16x16]:hyperplane")        # explicit tree
    get_mapper("hier[levels=rack:portfolio[k=8],pod:annealed]:kdtree")
    HierRefiner(fanouts="4x4", polish=64).refine(grid, stencil, a, n)
"""
from __future__ import annotations

import copy
import hashlib
import math
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..cost import evaluate
from ..grid import CartGrid, dims_create
from ..stencil import Stencil
from .swap import RefineResult, SwapRefiner

__all__ = ["MaskedGrid", "HierRefiner", "hier_subtree_cache"]


class MaskedGrid(CartGrid):
    """A grid view restricted to an ``active`` position subset: the
    induced subgraph of the stencil graph.

    ``shift_ranks`` ANDs edge validity with membership of *both*
    endpoints, so inactive positions have zero valid edges — zero load,
    never boundary, never swapped — which is what lets every flat refiner
    solve a subtree's restricted problem unchanged.  Geometry
    (``dims``/``size``/coords) is the base grid's, so position indices
    stay global.  NB: dataclass equality compares ``dims``/``periodic``
    only — treat masked grids as identity objects, not value objects.
    """

    def __init__(self, base: CartGrid, active: np.ndarray):
        super().__init__(dims=base.dims, periodic=base.periodic)
        active = np.asarray(active, dtype=bool)
        if active.shape != (base.size,):
            raise ValueError(f"active mask must be ({base.size},), "
                             f"got {active.shape}")
        object.__setattr__(self, "active", active.copy())

    def shift_ranks(self, offset: Sequence[int]) -> Tuple[np.ndarray, np.ndarray]:
        valid, tr = super().shift_ranks(offset)
        return valid & self.active & self.active[tr], tr

    @property
    def cache_token(self) -> str:
        """Content identity of the restriction, so table/subtree memos
        never serve a masked grid a plain-grid (or other-mask) entry."""
        return "masked:" + hashlib.sha256(
            self.active.tobytes()).hexdigest()[:16]

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return (f"MaskedGrid(dims={self.dims}, "
                f"active={int(self.active.sum())}/{self.size})")


# ---------------------------------------------------------------------------
# option parsing


def _parse_fanouts(fanouts, num_nodes: int, depth: int,
                   node_sizes=None) -> Tuple[int, ...]:
    """``"16x16"`` / ``16`` / None -> per-level fan-outs multiplying to
    ``num_nodes``.  None derives the split: balanced ``dims_create`` over
    ``depth`` levels for uniform pods, and the ragged-aware
    :func:`repro.topology.machine.derive_fanouts` grouping when
    ``node_sizes`` are uneven (subtree chip counts stay balanced instead
    of lumping the large pods under one parent)."""
    if fanouts is None:
        depth = max(1, int(depth))
        if node_sizes is not None and len(set(map(int, node_sizes))) > 1:
            from repro.topology.machine import derive_fanouts
            return derive_fanouts(node_sizes, depth)
        return dims_create(num_nodes, depth)
    if isinstance(fanouts, int):
        fo: Tuple[int, ...] = (fanouts,)
    else:
        try:
            fo = tuple(int(t) for t in str(fanouts).split("x"))
        except ValueError:
            raise ValueError(f"bad hier fanouts {fanouts!r}: expected "
                             "'<f1>x<f2>x...' (e.g. fanouts=16x16)")
    if any(f < 1 for f in fo) or math.prod(fo) != num_nodes:
        raise ValueError(f"hier fanouts {fo} must be positive and multiply "
                         f"to the node count {num_nodes}")
    return fo


def _parse_levels(levels: Optional[str], n_levels: int) \
        -> List[Tuple[str, Optional[str]]]:
    """``"rack:portfolio[k=8],pod:annealed"`` -> positional
    ``(name, solver-or-None)`` pairs, one per grouping level."""
    if not levels:
        return [(f"l{i + 1}", None) for i in range(n_levels)]
    from ..mapping import split_mapper_list
    entries = split_mapper_list(str(levels))
    if len(entries) != n_levels:
        raise ValueError(f"hier levels= names {len(entries)} levels "
                         f"({levels!r}) but the tree has {n_levels}")
    out = []
    for e in entries:
        name, sep, solver = e.partition(":")
        name = name.strip()
        if not name:
            raise ValueError(f"bad hier level entry {e!r} in {levels!r}")
        out.append((name, solver.strip() if sep and solver.strip() else None))
    return out


def _solver_refiners(spelling: str, context: str):
    """A per-level solver spelling — a refine-prefix chain *without* a
    base (``"annealed"``, ``"portfolio[k=8]"``,
    ``"annealed[sa_moves=50]:refined"``) — as refiner instances,
    inner-first."""
    from ..mapping import REFINE_PREFIXES, _make_refiner, split_mapper_name
    sentinel = "__hier_base__"
    chain, rest = [], f"{spelling}:{sentinel}"
    while True:
        parsed = split_mapper_name(rest, full_name=context)
        if parsed is None:
            break
        prefix, opts, rest = parsed
        if prefix == "hier":
            raise ValueError(f"hier level solvers cannot nest hier: "
                             f"({context!r})")
        chain.append((prefix, opts))
    if rest != sentinel or not chain:
        raise ValueError(
            f"bad hier level solver {spelling!r}{' in ' + context if context else ''}: "
            f"expected a refine-prefix chain from "
            f"{[p[:-1] for p in REFINE_PREFIXES if p != 'hier:']}")
    refiners = []
    for prefix, opts in reversed(chain):       # inner-first
        r = _make_refiner(prefix, opts)
        refiners.append(SwapRefiner(**opts) if r is None else r)
    return refiners


# ---------------------------------------------------------------------------
# the per-subtree solution cache

_subtree_cache = None


def hier_subtree_cache():
    """The process-wide cache of restricted subtree solutions, keyed by
    full subproblem content (region, capacities, seed labels, stencil,
    solver).  Elastic re-meshes that leave a subtree's inputs unchanged
    hit here and skip its re-solve entirely."""
    global _subtree_cache
    if _subtree_cache is None:
        from ..plan import PlanCache
        _subtree_cache = PlanCache(maxsize=2048)
    return _subtree_cache


def _subtree_key(grid: CartGrid, stencil: Stencil, active_idx: np.ndarray,
                 seed_labels: np.ndarray, caps: np.ndarray,
                 solver: str) -> str:
    h = hashlib.sha256()
    # cache_token distinguishes graph-backed grids (GraphGrid): two graphs
    # with equal size and slot weights must never share a subtree key.
    h.update(repr((grid.dims, grid.periodic,
                   getattr(grid, "cache_token", ""),
                   tuple(tuple(o) for o in stencil.offsets),
                   tuple(float(w) for w in stencil.weights),
                   tuple(int(c) for c in caps), solver)).encode())
    h.update(active_idx.astype(np.int64).tobytes())
    h.update(seed_labels.astype(np.int64).tobytes())
    return "hier:" + h.hexdigest()[:40]


# ---------------------------------------------------------------------------
# the refiner


class HierRefiner:
    """Recursive multilevel refinement (see module docstring).

    Args:
      fanouts: per-level fan-outs as ``"<f1>x<f2>..."`` (product must equal
        the node count); None derives a balanced ``depth``-level split.
      depth: number of grouping levels when ``fanouts`` is None.
      levels: per-level names/solvers,
        ``"rack:portfolio[k=8],pod:annealed"`` (positional; solver falls
        back to ``solver`` when omitted).
      solver: default restricted-problem solver — any refine-prefix chain
        without a base (``"annealed"``, ``"portfolio[k=8]"``).
      polish: accepted-swap budget for a final deterministic global polish
        pass over the composed assignment (0 = off).
      cache: reuse per-subtree solutions from :func:`hier_subtree_cache`
        (bypassed automatically while a stage ``budget`` caps swaps, so
        replayed swap counts can never evade the cap).
      max_swaps: total accepted-swap cap across all restricted solves and
        the polish pass (the plan layer's ``budget=`` threads in here).
    """

    def __init__(self, fanouts: Optional[str] = None, depth: int = 2,
                 levels: Optional[str] = None, solver: str = "annealed",
                 polish: int = 0, cache: bool = True,
                 max_swaps: Optional[int] = None):
        if int(depth) < 1:
            raise ValueError("hier depth must be >= 1")
        if int(polish) < 0:
            raise ValueError("hier polish budget must be >= 0")
        self.fanouts = fanouts
        self.depth = int(depth)
        self.levels = levels
        self.solver = str(solver)
        self.polish = int(polish)
        self.cache = bool(cache)
        self.max_swaps = max_swaps
        self.last_result: Optional[RefineResult] = None

    # -- plan-layer adapters -------------------------------------------------
    def as_stage(self, budget: Optional[int] = None):
        """Uniform :class:`~repro.core.refine.stage.RefineStage` adapter
        (``budget`` caps this stage's accepted swaps)."""
        from .stage import RefineStage
        return RefineStage(self, budget=budget, prefix="hier")

    def config(self) -> dict:
        """Full constructor configuration — the stage layer's canonical
        cache identity for hand-built refiners."""
        return {"fanouts": self.fanouts, "depth": self.depth,
                "levels": self.levels, "solver": self.solver,
                "polish": self.polish, "cache": self.cache,
                "max_swaps": self.max_swaps}

    # -- seeding -------------------------------------------------------------
    @staticmethod
    def _seed_labels(desired: np.ndarray, caps: np.ndarray) -> np.ndarray:
        """Child labels for a restricted solve: keep each position's
        desired child while capacity lasts (positions in row-major order),
        then fill the leftovers blocked — deterministic, and exactly
        realizes ``caps``."""
        f = len(caps)
        labels = np.full(desired.shape[0], -1, dtype=np.int64)
        for c in range(f):
            want = np.nonzero(desired == c)[0]
            labels[want[:caps[c]]] = c
        placed = np.bincount(labels[labels >= 0], minlength=f)
        fill = np.repeat(np.arange(f, dtype=np.int64), caps - placed)
        labels[labels < 0] = fill
        return labels

    # -- restricted solve ----------------------------------------------------
    def _solve_restricted(self, grid: CartGrid, stencil: Stencil,
                          active_idx: np.ndarray, seed_labels: np.ndarray,
                          caps: np.ndarray, solver: str, refiners,
                          budget: List, stats: Dict) -> Tuple[np.ndarray, int]:
        """Solve one subtree's induced-subgraph problem; returns
        ``(labels over active_idx, accepted swaps)``."""
        f = len(caps)
        use_cache = self.cache and self.max_swaps is None
        key = None
        if use_cache:
            key = _subtree_key(grid, stencil, active_idx, seed_labels, caps,
                               solver)
            hit = hier_subtree_cache().get(key)
            if hit is not None:
                stats["cache_hits"] += 1
                return (np.asarray(hit["labels"], dtype=np.int64),
                        int(hit["swaps"]))
            stats["cache_misses"] += 1
        p = grid.size
        m = active_idx.shape[0]
        full = np.full(p, f, dtype=np.int64)      # ghost label: zero edges
        full[active_idx] = seed_labels
        num = f + (1 if m < p else 0)
        if m < p:
            mask = np.zeros(p, dtype=bool)
            mask[active_idx] = True
            # grids that know their own induced-subgraph form (GraphGrid)
            # provide it; Cartesian grids get the coordinate mask.
            if hasattr(grid, "masked"):
                sub_grid = grid.masked(mask)
            else:
                sub_grid: CartGrid = MaskedGrid(grid, mask)
        else:
            sub_grid = grid
        swaps = 0
        for refiner in refiners:
            if budget[0] is not None and budget[0] <= 0:
                break
            r = refiner
            if budget[0] is not None and hasattr(refiner, "max_swaps"):
                r = copy.copy(refiner)
                cur = getattr(r, "max_swaps", None)
                r.max_swaps = budget[0] if cur is None \
                    else min(int(cur), budget[0])
            res = r.refine(sub_grid, stencil, full, num_nodes=num)
            full = np.asarray(res.assignment, dtype=np.int64)
            swaps += int(res.swaps)
            if budget[0] is not None:
                budget[0] -= int(res.swaps)
        out = full[active_idx]
        if not np.array_equal(np.bincount(out, minlength=f), caps):
            raise AssertionError(
                "restricted solve changed subtree child capacities")
        if use_cache:
            hier_subtree_cache().put(key, {"labels": out, "swaps": swaps})
        stats["solves"] += 1
        return out, swaps

    # -- the recursion -------------------------------------------------------
    def refine(self, grid: CartGrid, stencil: Stencil,
               node_of_pos: np.ndarray,
               num_nodes: Optional[int] = None) -> RefineResult:
        t0 = time.perf_counter()
        a = np.asarray(node_of_pos, dtype=np.int64).copy()
        n = int(num_nodes) if num_nodes is not None else int(a.max()) + 1
        node_sizes = np.bincount(a, minlength=n).astype(np.int64)
        initial = evaluate(grid, stencil, a, num_nodes=n, weighted="auto")

        fanouts = _parse_fanouts(self.fanouts, n, self.depth, node_sizes)
        level_specs = _parse_levels(self.levels, len(fanouts))
        context = f"hier[fanouts={'x'.join(map(str, fanouts))}]"
        per_level = [(name, sp or self.solver,
                      _solver_refiners(sp or self.solver, context))
                     for name, sp in level_specs]

        # cumulative chip offsets per pod; child c of a node covering pods
        # [lo, hi) with stride s covers pods [lo + c*s, lo + (c+1)*s)
        chip_starts = np.concatenate(([0], np.cumsum(node_sizes)))
        budget = [None if self.max_swaps is None else int(self.max_swaps)]
        stats: Dict[str, object] = {
            "backend": context, "solver": self.solver,
            "levels": [{"name": name, "fanout": f, "solver": sp}
                       for (name, sp, _), f in zip(per_level, fanouts)],
            "solves": 0, "cache_hits": 0, "cache_misses": 0,
            "polish_swaps": 0,
        }
        final = np.empty(grid.size, dtype=np.int64)
        total_swaps = 0

        def solve_node(level: int, lo_pod: int, hi_pod: int,
                       active_idx: np.ndarray, orig_pods: np.ndarray):
            nonlocal total_swaps
            if active_idx.size == 0:
                return
            if hi_pod - lo_pod == 1:
                final[active_idx] = lo_pod
                return
            name, solver, refiners = per_level[level]
            f = fanouts[level]
            stride = math.prod(fanouts[level + 1:])
            caps = np.asarray(
                [int(chip_starts[lo_pod + (c + 1) * stride]
                     - chip_starts[lo_pod + c * stride]) for c in range(f)],
                dtype=np.int64)
            inside = (orig_pods >= lo_pod) & (orig_pods < hi_pod)
            desired = np.where(inside, (orig_pods - lo_pod) // stride, -1)
            seed = self._seed_labels(desired, caps)
            labels, swaps = self._solve_restricted(
                grid, stencil, active_idx, seed, caps, solver, refiners,
                budget, stats)
            total_swaps += swaps
            for c in range(f):
                sel = labels == c
                solve_node(level + 1, lo_pod + c * stride,
                           lo_pod + (c + 1) * stride,
                           active_idx[sel], orig_pods[sel])

        solve_node(0, 0, n, np.arange(grid.size, dtype=np.int64), a)

        if not np.array_equal(np.bincount(final, minlength=n), node_sizes):
            raise AssertionError("hier composition broke node cardinalities")

        if self.polish > 0 and (budget[0] is None or budget[0] > 0):
            from .schedule import ScheduledRefiner
            cap = self.polish if budget[0] is None \
                else min(self.polish, budget[0])
            pol = ScheduledRefiner(anneal=False, rounds=1, max_swaps=cap)
            res = pol.refine(grid, stencil, final, num_nodes=n)
            final = np.asarray(res.assignment, dtype=np.int64)
            stats["polish_swaps"] = int(res.swaps)
            total_swaps += int(res.swaps)

        cost = evaluate(grid, stencil, final, num_nodes=n, weighted="auto")
        stats["composed"] = (float(cost.j_max), float(cost.j_sum))
        # never worse than the input: the seed composition realizes the
        # input's structure where possible, but a coarse top split can
        # regress a pathological case — keep the lexicographic best
        if (cost.j_max, cost.j_sum) > (initial.j_max, initial.j_sum):
            final, cost = a, initial
            stats["kept_input"] = True
        result = RefineResult(
            assignment=final, initial=initial, final=cost,
            swaps=total_swaps, passes=int(stats["solves"]),
            wall_time_s=time.perf_counter() - t0, stats=stats)
        self.last_result = result
        return result
