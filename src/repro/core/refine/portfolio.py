"""Multi-start annealing portfolio (seed-parallel plateau escape).

A single :class:`~repro.core.refine.ScheduledRefiner` ladder stalls on
J_max plateaus its one random walk cannot hop; general mapping tools
(Schulz & Träff 2017, "Better Process Mapping and Sparse Quadratic
Assignment"; Faraj et al. 2020, "High-Quality Hierarchical Process
Mapping") escape those with a *portfolio* of independent starts.
:class:`PortfolioRefiner` runs K such ladders as **one batched
computation**:

* the deterministic alternating j_sum/j_max rounds are seed-independent,
  so they run **once** and every ladder starts from their output;
* the K simulated-annealing ladders advance in lock-step — each ladder
  draws its proposal from its own :class:`numpy.random.Generator`, and all
  K (state, swap) deltas are scored by a single
  :meth:`~repro.core.cost_delta.PortfolioCost.swap_deltas` call per move
  (stacked ``(K, p)`` assignments, shared neighbour table, chunked load
  matrices) instead of K interpreted ladder loops;
* ladders whose best-seen bottleneck drifts beyond ``kill_factor`` times
  the portfolio leader's are killed at temperature boundaries
  (early-kill of dominated starts) — ladder 0 is never killed;
* surviving ladder states are deduplicated and polished with the
  schedule's phase objectives, and the lexicographically best
  ``(J_max, J_sum)`` over *everything seen* (input included) is returned.

Because ladder 0 uses ``default_rng(seeds[0])`` and the batched engine
reproduces the scalar ladder's draw order and float arithmetic (exactly,
for unit/dyadic weights), the portfolio's candidate set is a superset of
``ScheduledRefiner(anneal=True, seed=seeds[0])``'s — so ``portfolio:`` is
lexicographically never worse than ``annealed:`` on the same seed
(pinned by ``tests/test_portfolio.py``).

Usage::

    from repro.core import PortfolioRefiner, get_mapper
    res = PortfolioRefiner(k=8).refine(grid, stencil, a, num_nodes=N)
    m = get_mapper("portfolio:hyperplane")        # default K=8
    m = get_mapper("portfolio[k=4,seed=7]:kdtree")  # bracket options
"""
from __future__ import annotations

import math
import time
import warnings
from typing import Optional, Sequence, Tuple

import numpy as np

from ..cost_delta import IncrementalCost, PortfolioCost
from ..grid import CartGrid
from ..stencil import Stencil
from .schedule import ScheduledRefiner
from .swap import RefineResult

__all__ = ["PortfolioRefiner", "run_temperature"]


def run_temperature(pc: PortfolioCost, rngs, alive: np.ndarray,
                    done: np.ndarray, temps: np.ndarray, sa_moves: int,
                    eps: np.ndarray,
                    budget: Optional[int] = None,
                    allowed: Optional[np.ndarray] = None) -> np.ndarray:
    """Advance every alive, not-yet-done ladder of ``pc`` through one
    temperature of ``sa_moves`` Metropolis proposals, batched per move.

    This is THE ladder kernel: :class:`PortfolioRefiner` runs it once per
    temperature over all K ladders, and the sharded engine
    (:class:`~repro.core.refine.sharded.ShardedPortfolioRefiner`) runs it
    per seed block inside worker processes — both replicate
    :meth:`ScheduledRefiner._sa_ladder` move for move per ladder (same rng
    draw order: position, partner, then acceptance only for uphill moves;
    same boundary snapshot per temperature; same early-out rules), so a
    ladder's trajectory depends only on its own rng and start state, never
    on which batch it ran in.

    ``temps`` is the per-ladder *absolute* temperature (schedule scale and
    any adaptive retune multiplier already folded in); ``eps`` the
    per-ladder J_sum tie-break scale.  ``pc``, ``rngs`` and ``done`` are
    mutated in place; ``budget`` caps the call's accepted swaps (checked
    before each batched move, exactly as the single-process engine does).
    ``allowed`` (a (p,) bool mask, default all-True) restricts proposals to
    a position subset — both endpoints of every swap are drawn from
    ``boundary & allowed``, so positions outside it are *pinned* and can
    never move (the repair path's churn-untouched nodes).  ``None``
    preserves the historical draw sequence bit for bit.
    Returns the per-ladder accepted-swap counts.
    """
    K = pc.n_starts
    masks = pc.boundary_masks()
    if allowed is not None:
        masks = masks & np.asarray(allowed, dtype=bool)[None, :]
    boundaries = {i: np.nonzero(masks[i])[0]
                  for i in range(K) if alive[i] and not done[i]}
    stopped = set()         # no cross-node partner this temperature
    accepted = np.zeros(K, dtype=np.int64)
    total = 0
    for _ in range(sa_moves):
        if budget is not None and total >= budget:
            break
        rows, Ps, Qs = [], [], []
        for i, b in boundaries.items():
            if done[i] or i in stopped:
                continue
            if b.size < 2:
                done[i] = True
                continue
            p = int(b[rngs[i].integers(b.size)])
            partners = b[pc.node[i, b] != pc.node[i, p]]
            if partners.size == 0:
                stopped.add(i)
                continue
            q = int(partners[rngs[i].integers(partners.size)])
            rows.append(i)
            Ps.append(p)
            Qs.append(q)
        if not rows:
            break           # every ladder done/stopped this temperature
        rows_a = np.asarray(rows, dtype=np.int64)
        d = pc.swap_deltas(rows_a, Ps, Qs, with_loads=True,
                           with_counts=True)
        d_e = (d.new_j_max - pc.j_max()[rows_a]
               + d.d_j_sum * eps[rows_a])
        acc = [idx for idx, i in enumerate(rows)
               if (d_e[idx] <= 0.0
                   or rngs[i].random() < math.exp(-float(d_e[idx])
                                                  / float(temps[i])))]
        if acc:
            pc.commit(d, acc)
            total += len(acc)
            for idx in acc:
                accepted[rows[idx]] += 1
    return accepted


class PortfolioRefiner:
    """K-start batched annealing on top of the deterministic schedule.

    Args:
      k: number of independent annealing starts (ignored when ``seeds`` is
        given explicitly).
      seed: base rng seed; start i uses ``default_rng(seed + i)``, so
        ``seed`` alone pins the whole portfolio and start 0 matches
        ``ScheduledRefiner(anneal=True, seed=seed)``.
      seeds: explicit per-start seeds (overrides ``k``/``seed``).
      kill_factor: a start (other than start 0) is killed at a temperature
        boundary when its best-seen J_max exceeds ``kill_factor`` times the
        portfolio-wide best-seen J_max; ``None`` disables early-kill.
      polish_top: how many surviving ladders get the full post-ladder
        polish phases (start 0 always does; the rest are ranked by their
        exact ladder-end ``(J_max, J_sum)``).  Unpolished survivors still
        contribute their raw states as candidates.  ``None`` polishes every
        survivor — thorough but the polish stage then scales with K, which
        is what the default bounds.
      max_swaps: total accepted-swap budget across the shared prefix, all
        ladders, and the polish phases (None = unlimited, the default and
        bit-identical path; the ``portfolio <= annealed`` dominance
        guarantee is only stated for the unbudgeted engine).  Per-stage
        plan budgets (:class:`~repro.core.refine.stage.RefineStage`)
        thread into this.
      Remaining keyword arguments configure the underlying schedule —
      identical names and defaults as :class:`ScheduledRefiner`
      (``objectives``, ``rounds``, ``policy``, ``max_passes``, ``weighted``
      — ``"auto"`` keys byte-weighted scoring off the stencil — ``tol``,
      ``max_partners``, ``engine``, ``temperatures``, ``sa_moves``).
    """

    def __init__(self, k: int = 8, seed: int = 0,
                 seeds: Optional[Sequence[int]] = None,
                 kill_factor: Optional[float] = 1.5,
                 polish_top: Optional[int] = 3,
                 objectives: Sequence[str] = ("j_sum", "j_max"),
                 rounds: int = 4, policy: str = "first", max_passes: int = 8,
                 weighted="auto", tol: float = 1e-12,
                 max_partners: int = 32, engine: str = "batch",
                 temperatures: Sequence[float] = (2.0, 1.0, 0.5, 0.25),
                 sa_moves: int = 200, max_swaps: Optional[int] = None):
        if seeds is not None:
            raw = tuple(int(s) for s in seeds)
            # duplicate seeds replay identical trajectories — ladders burnt
            # for zero extra candidates.  Dedupe order-preserved (ladder 0
            # keeps its dominance role) and keep cache keys honest: config()
            # reflects the deduped tuple, never the raw spelling.
            seeds = tuple(dict.fromkeys(raw))
            if len(seeds) != len(raw):
                warnings.warn(
                    f"duplicate portfolio seeds {raw} collapsed to {seeds}: "
                    "identical seeds replay identical annealing trajectories",
                    UserWarning, stacklevel=2)
        else:
            seeds = tuple(int(seed) + i for i in range(int(k)))
        if not seeds:
            raise ValueError("portfolio needs at least one start")
        if kill_factor is not None and kill_factor < 1.0:
            raise ValueError("kill_factor must be >= 1.0 (or None)")
        if polish_top is not None and polish_top < 1:
            raise ValueError("polish_top must be >= 1 (or None)")
        self.seeds = seeds
        self.k = len(seeds)
        self.kill_factor = None if kill_factor is None else float(kill_factor)
        self.polish_top = None if polish_top is None else int(polish_top)
        if max_swaps is not None and int(max_swaps) < 0:
            raise ValueError("max_swaps must be >= 0 (or None)")
        self.max_swaps = None if max_swaps is None else int(max_swaps)
        # the shared schedule: its deterministic rounds are the common
        # prefix, its polish phases close each ladder, and its SA
        # parameters define the ladders themselves.
        self.schedule = ScheduledRefiner(
            objectives=objectives, rounds=rounds, policy=policy,
            max_passes=max_passes, weighted=weighted, tol=tol,
            max_partners=max_partners, engine=engine, anneal=True,
            temperatures=temperatures, sa_moves=sa_moves, seed=seeds[0])

    def as_stage(self, budget: Optional[int] = None):
        """Uniform :class:`~repro.core.refine.stage.RefineStage` adapter
        (``budget`` caps this stage's accepted swaps)."""
        from .stage import RefineStage
        return RefineStage(self, budget=budget, prefix="portfolio")

    def config(self) -> dict:
        """Full constructor configuration — the stage layer's canonical
        cache identity for hand-built refiners.  ``seeds`` subsumes
        ``k``/``seed``; the shared schedule's ``anneal``/``seed`` are
        implied."""
        cfg = {k: v for k, v in self.schedule.config().items()
               if k not in ("anneal", "seed", "max_swaps")}
        cfg.update({"seeds": self.seeds, "kill_factor": self.kill_factor,
                    "polish_top": self.polish_top,
                    "max_swaps": self.max_swaps})
        return cfg

    # -- batched SA ladders -------------------------------------------------
    def _batched_ladders(self, grid: CartGrid, stencil: Stencil,
                         start: np.ndarray, num_nodes: Optional[int],
                         budget: Optional[int] = None,
                         allowed: Optional[np.ndarray] = None) \
            -> Tuple[PortfolioCost, np.ndarray, int, int]:
        """Advance K ladders from ``start`` in lock-step.  Returns the
        portfolio state, the per-ladder alive mask (False = early-killed),
        total accepted swaps, and the count of killed ladders.

        Per-ladder control flow replicates
        :meth:`ScheduledRefiner._sa_ladder` move for move (same rng draw
        order: position, partner, then acceptance only for uphill moves;
        same per-temperature boundary snapshot; same early-out rules), so
        ladder i's trajectory equals a scalar ladder seeded ``seeds[i]``.
        Only the delta/energy arithmetic is batched across ladders.
        """
        from .engine import BoundaryController, SerialLadderEngine
        sched = self.schedule
        K = self.k
        eng = SerialLadderEngine(grid, stencil, start, self.seeds,
                                 num_nodes=num_nodes, weighted=sched.weighted,
                                 allowed=allowed)
        pc = eng.pc
        t_scale = float(np.mean(pc.weights))
        j_sum0 = pc.j_sum()
        eps = 1.0 / (1.0 + np.abs(j_sum0))          # (K,) per-ladder
        ctrl = BoundaryController(
            k=K, kill_factor=self.kill_factor,
            start_keys=np.stack([pc.j_max(), j_sum0], axis=1))
        accepted = 0
        for T0 in sched.temperatures:
            if budget is not None and accepted >= budget:
                break               # skip leftover temperatures' setup too
            T = max(T0 * t_scale, 1e-12)
            rep = eng.run_temperature(
                np.full(K, T), sched.sa_moves, ctrl.alive, eps,
                budget=None if budget is None else budget - accepted)
            accepted += int(rep.accepted.sum())
            # temperature boundary: exact keys, early-kill of dominated runs
            ctrl.update_best(np.stack([rep.j_max, rep.j_sum], axis=1))
            ctrl.kill()
        return pc, ctrl.alive, accepted, ctrl.killed

    # -- survivor selection + polish (shared with the sharded engine) -------
    def _polish_survivors(self, grid: CartGrid, stencil: Stencil,
                          num_nodes: Optional[int], consider,
                          nodes: np.ndarray, lad_j_max: np.ndarray,
                          lad_j_sum: np.ndarray, alive: np.ndarray,
                          swaps: int, passes: int):
        """Feed every surviving raw ladder state to ``consider`` (its exact
        key is already on hand, so it is a candidate for free), then run the
        full polish phases on the most promising survivors: start 0 always
        (the dominance guarantee vs the single annealed run), then the best
        survivors by ladder-end key, deduplicating identical end states.
        ``nodes`` is the (K, p) ladder-end assignment stack.  Returns the
        updated ``(swaps, passes, polish_order)``."""
        sched = self.schedule
        K = nodes.shape[0]
        for i in range(K):
            if alive[i]:
                consider(nodes[i].copy(),
                         (float(lad_j_max[i]), float(lad_j_sum[i])))
        ranked = sorted((i for i in range(K) if alive[i]),
                        key=lambda i: (lad_j_max[i], lad_j_sum[i], i))
        budget = len(ranked) if self.polish_top is None else self.polish_top
        seen = set()
        polish_order = []
        for i in [0] + ranked:
            if not alive[i] or len(polish_order) >= budget:
                continue
            key = nodes[i].tobytes()
            if key not in seen:
                seen.add(key)
                polish_order.append(i)
        for i in polish_order:
            cap = None if self.max_swaps is None \
                else max(0, self.max_swaps - swaps)
            _, s, p = sched.polish(grid, stencil, nodes[i].copy(), num_nodes,
                                   consider, max_swaps=cap)
            swaps += s
            passes += p
        return swaps, passes, polish_order

    # -- driver -------------------------------------------------------------
    def refine(self, grid: CartGrid, stencil: Stencil,
               node_of_pos: np.ndarray,
               num_nodes: Optional[int] = None,
               pinned: Optional[np.ndarray] = None) -> RefineResult:
        """Refine ``node_of_pos``.  ``pinned`` (a (p,) bool mask) freezes a
        position subset: the deterministic rounds and polish phases — which
        have no notion of pinning — are skipped, and the SA ladders draw
        both swap endpoints from unpinned positions only, so the result is
        guaranteed to agree with the input everywhere ``pinned`` is True
        (the repair path's churn-untouched nodes).  ``pinned=None`` is the
        historical engine, bit for bit."""
        t0 = time.perf_counter()
        sched = self.schedule
        cur = np.asarray(node_of_pos, dtype=np.int64).copy()
        if pinned is not None:
            pinned = np.asarray(pinned, dtype=bool).reshape(-1)
            if pinned.shape[0] != grid.size:
                raise ValueError(f"pinned mask has {pinned.shape[0]} "
                                 f"entries for a {grid.size}-position grid")
        initial = IncrementalCost(grid, stencil, cur, num_nodes=num_nodes,
                                  weighted=sched.weighted).cost()
        best, best_key = cur.copy(), (initial.j_max, initial.j_sum)

        def consider(candidate: np.ndarray, key: Tuple[float, float]):
            nonlocal best, best_key
            if key < best_key:
                best, best_key = candidate.copy(), key

        # 1. shared deterministic prefix (seed-independent, run once;
        # pin-oblivious, so the pinned path skips it)
        if pinned is None:
            cur, swaps, passes = sched.run_rounds(grid, stencil, cur,
                                                  num_nodes, consider,
                                                  max_swaps=self.max_swaps)
        else:
            swaps = passes = 0
        t_rounds = time.perf_counter() - t0

        # 2. K annealing ladders, batched (budget caps accepted moves at
        # move granularity — up to K acceptances land per batched move)
        budget = None if self.max_swaps is None else self.max_swaps - swaps
        pc, alive, sa_accepted, killed = self._batched_ladders(
            grid, stencil, cur, num_nodes, budget=budget,
            allowed=None if pinned is None else ~pinned)
        swaps += sa_accepted
        t_ladders = time.perf_counter() - t0 - t_rounds

        # 3. raw survivors are free candidates; the best of them get the
        # full polish phases (shared with the sharded engine's merge step)
        # — pin-oblivious, so the pinned path takes raw survivors only
        lad_j_max, lad_j_sum = pc.j_max(), pc.j_sum()
        if pinned is None:
            swaps, passes, polish_order = self._polish_survivors(
                grid, stencil, num_nodes, consider, pc.node,
                lad_j_max, lad_j_sum, alive, swaps, passes)
        else:
            K = pc.n_starts
            for i in range(K):
                if alive[i]:
                    consider(pc.node[i].copy(),
                             (float(lad_j_max[i]), float(lad_j_sum[i])))
            polish_order = []
            assert np.array_equal(best[pinned], node_of_pos[pinned]), \
                "pinned positions moved (ladder mask violated)"

        final = IncrementalCost(grid, stencil, best, num_nodes=num_nodes,
                                weighted=sched.weighted).cost()
        wall = time.perf_counter() - t0
        stats = {
            "k": self.k,
            "seeds": self.seeds,
            "pinned": 0 if pinned is None else int(pinned.sum()),
            "sa_accepted": sa_accepted,
            "killed": killed,
            "polished": len(polish_order),
            "ladder_keys": [(float(j), float(s)) for j, s in
                            zip(pc.j_max(), pc.j_sum())],
            "t_rounds_s": t_rounds,
            "t_ladders_s": t_ladders,
            "t_polish_s": wall - t_rounds - t_ladders,
        }
        return RefineResult(assignment=best, initial=initial, final=final,
                            swaps=swaps, passes=passes, wall_time_s=wall,
                            stats=stats)
