"""Sharded adaptive portfolio engine (process-parallel K-start annealing).

The paper's headline is that mapping *search* parallelizes: its distributed
algorithms beat high-quality sequential mappers (Glantz-Meyerhenke-Noe;
Schulz-Träff "Better Process Mapping and Sparse Quadratic Assignment") on
wall-time while matching quality.  :class:`ShardedPortfolioRefiner` is that
scaling step for the portfolio engine: K annealing ladders partitioned into
``shards`` seed blocks, each block advanced one temperature at a time by
:func:`~repro.core.refine.portfolio.run_temperature` inside
``multiprocessing`` workers (a picklable primitives-only task per block),
with the coordinator merging per-ladder keys at every temperature boundary
so the early-kill rule sees the *global* leader — exactly the
single-process rule.

**Bit-identity.**  A ladder's trajectory depends only on its own rng and
start state (the shared kernel guarantees the draw order), and every
cross-ladder coupling — best-seen bookkeeping, the kill rule, survivor
ranking and polish — runs on the coordinator over globally merged state.
``sharded[shards=S,k=K]:<base>`` is therefore bit-identical to
``portfolio[k=K]:<base>`` for any S when adaptive control is off (pinned by
``tests/test_sharded_portfolio.py``).  The one coupling that cannot shard
is a global ``max_swaps`` budget (one shared counter checked per batched
move), so budgeted runs delegate to the single-process engine, which *is*
that semantics.

**Adaptive control** (``restarts="auto"`` or an int cap):

* early-killed ladders return their unspent proposal budget — the
  remaining ``temperatures x sa_moves`` they would have run — to a shared
  pool;
* the pool funds *restart ladders* seeded fresh (``max(seeds)+1+j``, never
  colliding with originals) that start from the current portfolio leader's
  assignment and run the remaining temperatures;
* with ``retune=True``, each restart ladder's temperature is retuned at
  phase boundaries from its own observed accept rate: below
  ``accept_band[0]`` doubles its multiplier (reheat a frozen walk), above
  ``accept_band[1]`` halves it, always clamped to ``retune_bounds``.

Restart ladders never enter the kill rule's leader computation and are
never killed, and retune applies *only* to them — so the original K
ladders replay the single-process portfolio exactly, and the adaptive
engine's candidate set is a strict superset.  That is the structural
guarantee behind "adaptive on is lexicographically never worse on the
(J_max, J_sum) key" (also pinned by tests).

The optional jax path (:func:`stacked_crossing_counts`,
``vmap_counts=True``) computes each block's integer crossing-count state
with one ``jax.vmap``-batched kernel over the stacked assignment arrays
instead of the per-offset numpy loop.  Counts are pure integers, so both
producers are bit-interchangeable; without jax the numpy path is used
silently.

Usage::

    from repro.core import ShardedPortfolioRefiner, get_mapper
    res = ShardedPortfolioRefiner(shards=4, k=64).refine(grid, st, a,
                                                         num_nodes=N)
    m = get_mapper("sharded[shards=4,k=64]:hyperplane")
    m = get_mapper("sharded[shards=2,k=16,restarts=auto,retune=true]:kdtree")
"""
from __future__ import annotations

import copy
import functools
import math
import multiprocessing
import os
import pickle
import sys
import time
from collections import OrderedDict
from contextlib import contextmanager
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..cost_delta import (IncrementalCost, NeighborTable, PortfolioCost,
                          stacked_count_arrays)
from ..grid import CartGrid
from ..stencil import Stencil, resolve_weighted
from .engine import BoundaryController, RestartSeeder
from .portfolio import PortfolioRefiner, run_temperature
from .swap import RefineResult

__all__ = ["ShardedPortfolioRefiner", "stacked_crossing_counts",
           "IpcMeter", "measure_ipc"]

#: auto backend: fork+pickle round-trips per temperature only pay off once
#: the per-temperature batched numpy work dominates the IPC (measured
#: crossover on the 16x28 ragged suite instance at K in the tens).
_MP_AUTO_MIN_ELEMS = 1 << 14

_PICKLE_PROTO = pickle.HIGHEST_PROTOCOL

#: active :class:`IpcMeter` (coordinator-thread scoped via
#: :func:`measure_ipc`); ``None`` = no accounting on the dispatch path.
_IPC_METER: Optional["IpcMeter"] = None


class IpcMeter:
    """Measured IPC byte accounting for the stateless sharded protocol.

    Records ``len(pickle.dumps(...))`` of the *actual* ``_block_step``
    payload and result objects each dispatch ships — the bytes the mp
    backend pays per block per temperature (full assignment + rng state
    both directions), measured rather than estimated.  The serial backend
    builds byte-identical task objects, so metering works regardless of
    which backend executed.  ``benchmarks/serve_suite.py`` pins the
    resident-worker serving claim (per-boundary IPC reduction) against
    this baseline.
    """

    def __init__(self):
        self.bytes_out = 0      # coordinator -> worker (payloads)
        self.bytes_in = 0       # worker -> coordinator (results)
        self.messages = 0       # block payloads shipped
        self.dispatches = 0     # step() calls (one per temperature)

    def record(self, payloads, results) -> None:
        self.bytes_out += sum(len(pickle.dumps(p, _PICKLE_PROTO))
                              for p in payloads)
        self.bytes_in += sum(len(pickle.dumps(r, _PICKLE_PROTO))
                             for r in results)
        self.messages += len(payloads)
        self.dispatches += 1

    @property
    def bytes_total(self) -> int:
        return self.bytes_out + self.bytes_in


@contextmanager
def measure_ipc():
    """Meter the stateless protocol's IPC bytes for every sharded refine
    run inside the ``with`` body (single coordinator thread; nesting
    restores the outer meter on exit)."""
    global _IPC_METER
    meter = IpcMeter()
    prev, _IPC_METER = _IPC_METER, meter
    try:
        yield meter
    finally:
        _IPC_METER = prev


#: memoized "is jax importable" verdict (``None`` = undecided).  Resolved
#: once per process from spec discovery, NOT from ``sys.modules`` — the
#: PR-5 ``"jax" in sys.modules`` probe made the very first
#: ``use_jax="auto"`` call depend on whether anything else had imported
#: jax yet (import-order-dependent first-call behavior, pinned by a
#: regression test).  Spec discovery doesn't pay the import; the first
#: call that actually selects the jax backend does.
_JAX_SPEC: Optional[bool] = None


def _jax_importable() -> bool:
    global _JAX_SPEC
    if _JAX_SPEC is None:
        import importlib.util
        _JAX_SPEC = importlib.util.find_spec("jax") is not None
    return _JAX_SPEC


def _jax_available() -> bool:
    """Deprecated PR-5 probe, kept for backward compatibility; backend
    resolution now goes through :func:`_jax_importable` so it never
    depends on import order."""
    return "jax" in sys.modules


def _resolve_counts_backend(use_jax) -> bool:
    """Map a counts-backend option to "use the jax kernel?".  Accepts the
    historical ``True`` / ``False`` / ``"auto"`` plus the explicit
    spellings ``"jax"`` / ``"numpy"`` (threadable through ``config()`` and
    bracket options)."""
    if use_jax == "auto":
        return _jax_importable()
    if use_jax == "numpy":
        return False
    if use_jax == "jax":
        return True
    return bool(use_jax)


def stacked_crossing_counts(grid: CartGrid, stencil: Stencil,
                            assignments: np.ndarray, num_nodes: int,
                            use_jax="auto") \
        -> Tuple[np.ndarray, np.ndarray]:
    """Integer crossing counts for a stacked (K, p) assignment array:
    ``((K, k) count_off, (K, N, k) count_node)``, bit-equal to what
    :class:`~repro.core.cost_delta.PortfolioCost` builds in its own init
    loop (integers — exact on every path).  This is the state
    representation the device-resident engine
    (:mod:`repro.core.refine.device`) seeds its ladders from and the
    numpy fallback every backend shares.

    ``use_jax`` selects the backend: ``"jax"``/``True`` runs one
    ``jax.vmap``-batched kernel over the stacked assignments (crossing
    masks + ``segment_sum`` per offset, jitted once per shape),
    ``"numpy"``/``False`` the stacked numpy loop, and ``"auto"`` the jax
    kernel exactly when jax is *importable* — a property of the
    environment, never of import order.  Falls back to numpy when jax is
    selected but missing.
    """
    A = np.asarray(assignments, dtype=np.int64)
    table = _memo_table(grid, stencil)
    N = int(num_nodes)
    if _resolve_counts_backend(use_jax):
        try:
            return _jax_stacked_counts(table, A, N)
        except ImportError:
            pass
    return stacked_count_arrays(table, A, N)


@functools.lru_cache(maxsize=8)
def _jit_stacked_counts(num_nodes: int):
    """Build (and cache) the jitted stacked-counts kernel for one node
    count.  ``num_segments`` must be static under jit; table arrays are
    traced arguments, so one cached callable serves every grid/stencil —
    jax's own jit cache keys the shapes."""
    import jax
    import jax.numpy as jnp

    def one(a, out_valid, out_tgt):                  # a: (p,)
        crossing = out_valid & (a[None, :] != a[out_tgt])        # (k, p)
        count_off = crossing.sum(axis=1)
        # count_node[j, n] = #{i : crossing[j, i] and a[i] == n}
        count_node = jax.vmap(
            lambda c: jax.ops.segment_sum(c.astype(jnp.int32), a,
                                          num_segments=num_nodes))(crossing)
        return count_off, count_node                 # (k,), (k, N)

    return jax.jit(jax.vmap(one, in_axes=(0, None, None)))


def _jax_stacked_counts(table: NeighborTable, A: np.ndarray,
                        N: int) -> Tuple[np.ndarray, np.ndarray]:
    import jax.numpy as jnp
    co, cn = _jit_stacked_counts(N)(jnp.asarray(A),
                                    jnp.asarray(table.out_valid),
                                    jnp.asarray(table.out_tgt))
    return (np.asarray(co, dtype=np.int64),
            np.ascontiguousarray(np.asarray(cn, dtype=np.int64)
                                 .transpose(0, 2, 1)))


# ---------------------------------------------------------------------------
# the per-(block, temperature) worker task


#: NeighborTable memo keyed by (dims, periodic, offsets): persistent pool
#: workers rebuild block state every temperature, but the table is
#: trajectory-independent and grid-sized — build it once per process.
_TABLE_MEMO: "OrderedDict[tuple, NeighborTable]" = OrderedDict()
_TABLE_MEMO_MAX = 8


def _memo_table(grid: CartGrid, stencil: Stencil) -> NeighborTable:
    # cache_token keeps graph-backed and masked grids from colliding with
    # a plain CartGrid of the same dims (they answer shift_ranks
    # differently, so sharing a table would be silently wrong).
    key = (tuple(grid.dims), tuple(grid.periodic),
           getattr(grid, "cache_token", ""), stencil.offsets)
    table = _TABLE_MEMO.get(key)
    if table is None:
        table = NeighborTable.build(grid, stencil)
        _TABLE_MEMO[key] = table
        while len(_TABLE_MEMO) > _TABLE_MEMO_MAX:
            _TABLE_MEMO.popitem(last=False)
    else:
        _TABLE_MEMO.move_to_end(key)
    return table


def _block_step(payload: dict) -> dict:
    """Advance one seed block through one temperature of proposals.

    Module-level and primitives-only (dims/offsets/arrays/rng generators —
    all picklable) so it ships to ``multiprocessing`` workers; the serial
    backend calls it inline.  The block's cost state is rebuilt from its
    assignment rows each call (integer counts — exact), optionally via the
    jax.vmap kernel when the coordinator precomputed ``counts``.
    """
    grid = payload.get("grid")
    if grid is None:
        grid = CartGrid(tuple(payload["dims"]), periodic=payload["periodic"])
    stencil = Stencil(payload["offsets"], payload["weights"])
    pc = PortfolioCost(grid, stencil, payload["node"],
                       num_nodes=payload["num_nodes"],
                       weighted=payload["weighted"],
                       table=_memo_table(grid, stencil),
                       counts=payload.get("counts"))
    rngs = payload["rngs"]
    done = np.array(payload["done"], dtype=bool)
    accepted = run_temperature(pc, rngs, np.asarray(payload["alive"]), done,
                               payload["temps"], payload["sa_moves"],
                               payload["eps"])
    return {"node": pc.node, "rngs": rngs, "done": done,
            "accepted": accepted, "j_max": pc.j_max(), "j_sum": pc.j_sum()}


# ---------------------------------------------------------------------------
# the refiner


class ShardedPortfolioRefiner:
    """Shard the K-start annealing portfolio across worker processes, with
    optional adaptive restart/retune control.

    Args:
      shards: number of seed blocks (capped at K); each block is one
        worker task per temperature.
      restarts: adaptive control.  ``None`` (default) disables it — the
        engine is then bit-identical to
        ``PortfolioRefiner(k=K, seed=seed)`` for any shard count.
        ``"auto"`` restarts as many ladders as the killed-budget pool
        affords; an int additionally caps total restarts.
      retune: retune each *restart* ladder's temperature from its observed
        accept rate at phase boundaries (originals are never retuned — that
        is what keeps the dominance guarantee structural).
      accept_band: (low, high) accept-rate band; outside it a restart
        ladder's temperature multiplier doubles/halves.
      retune_bounds: (min, max) clamp on the multiplier.
      backend: ``"serial"`` runs blocks inline (still block-partitioned,
        still bit-identical), ``"mp"`` uses a process pool, ``"auto"``
        picks ``"mp"`` when ``shards > 1`` and the stacked state is large
        enough to amortize IPC.
      workers: process-pool size cap (default: min(shards, cpu count)).
      vmap_counts: counts backend for rebuilding block cost state —
        ``"jax"``/``True`` the jax.vmap kernel, ``"numpy"``/``False`` the
        stacked numpy loop, ``"auto"`` jax exactly when it is importable
        (an environment property; never depends on import order — results
        are bit-identical either way).  Serial backend only: mp workers
        are numpy-only by design (no jax in forked children), so the flag
        is inert there.
      Remaining arguments are :class:`PortfolioRefiner`'s, same defaults —
      a bare ``sharded:<base>`` equals a bare ``portfolio:<base>``.
    """

    def __init__(self, shards: int = 4, k: int = 8, seed: int = 0,
                 seeds: Optional[Sequence[int]] = None,
                 restarts=None, retune: bool = False,
                 accept_band: Tuple[float, float] = (0.05, 0.5),
                 retune_bounds: Tuple[float, float] = (0.25, 4.0),
                 backend: str = "auto", workers: Optional[int] = None,
                 vmap_counts="auto",
                 kill_factor: Optional[float] = 1.5,
                 polish_top: Optional[int] = 3,
                 objectives: Sequence[str] = ("j_sum", "j_max"),
                 rounds: int = 4, policy: str = "first", max_passes: int = 8,
                 weighted="auto", tol: float = 1e-12,
                 max_partners: int = 32, engine: str = "batch",
                 temperatures: Sequence[float] = (2.0, 1.0, 0.5, 0.25),
                 sa_moves: int = 200, max_swaps: Optional[int] = None):
        if int(shards) < 1:
            raise ValueError("shards must be >= 1")
        if restarts not in (None, "auto") and int(restarts) < 0:
            raise ValueError('restarts must be None, "auto", or an int >= 0')
        if backend not in ("auto", "serial", "mp"):
            raise ValueError('backend must be "auto", "serial", or "mp"')
        if vmap_counts not in (True, False, "auto", "jax", "numpy"):
            raise ValueError('vmap_counts must be True, False, "auto", '
                             '"jax", or "numpy"')
        lo, hi = float(accept_band[0]), float(accept_band[1])
        if not (0.0 <= lo <= hi <= 1.0):
            raise ValueError("accept_band must satisfy 0 <= low <= high <= 1")
        blo, bhi = float(retune_bounds[0]), float(retune_bounds[1])
        if not (0.0 < blo <= 1.0 <= bhi):
            raise ValueError("retune_bounds must bracket 1.0 "
                             "(0 < min <= 1 <= max)")
        self.shards = int(shards)
        self.restarts = restarts if restarts in (None, "auto") \
            else int(restarts)
        self.retune = bool(retune)
        self.accept_band = (lo, hi)
        self.retune_bounds = (blo, bhi)
        self.backend = backend
        self.workers = None if workers is None else int(workers)
        self.vmap_counts = vmap_counts
        # the single-process engine this one must replicate: seeds,
        # schedule, kill/polish rules, and the budget-delegation target.
        self.portfolio = PortfolioRefiner(
            k=k, seed=seed, seeds=seeds, kill_factor=kill_factor,
            polish_top=polish_top, objectives=objectives, rounds=rounds,
            policy=policy, max_passes=max_passes, weighted=weighted, tol=tol,
            max_partners=max_partners, engine=engine,
            temperatures=temperatures, sa_moves=sa_moves, max_swaps=None)
        self.schedule = self.portfolio.schedule
        self.seeds = self.portfolio.seeds
        self.k = self.portfolio.k
        #: restart ladder j is seeded ``max(seeds) + 1 + j`` — fresh and
        #: deterministic; the stream is issued through
        #: :class:`~repro.core.refine.engine.RestartSeeder`, which guards
        #: (warn + shift) against ever colliding with a user-supplied
        #: explicit ``seeds=`` list, so a restart can never replay an
        #: original ladder's trajectory.
        self._restart_seed_base = max(self.seeds) + 1
        if max_swaps is not None and int(max_swaps) < 0:
            raise ValueError("max_swaps must be >= 0 (or None)")
        self.max_swaps = None if max_swaps is None else int(max_swaps)

    def as_stage(self, budget: Optional[int] = None):
        """Uniform :class:`~repro.core.refine.stage.RefineStage` adapter
        (``budget`` caps this stage's accepted swaps)."""
        from .stage import RefineStage
        return RefineStage(self, budget=budget, prefix="sharded")

    def config(self) -> dict:
        """Full constructor configuration — the stage layer's canonical
        cache identity for hand-built refiners.  Execution-only knobs
        (backend/workers/vmap_counts) are included for faithfulness even
        though every backend returns bit-identical results."""
        cfg = self.portfolio.config()
        cfg.update({"shards": self.shards, "restarts": self.restarts,
                    "retune": self.retune, "accept_band": self.accept_band,
                    "retune_bounds": self.retune_bounds,
                    "backend": self.backend, "workers": self.workers,
                    "vmap_counts": self.vmap_counts,
                    "max_swaps": self.max_swaps})
        return cfg

    # -- backend ------------------------------------------------------------
    def _resolve_backend(self, problem_size: int) -> str:
        if self.backend != "auto":
            return self.backend
        if self.shards > 1 and self.k * problem_size >= _MP_AUTO_MIN_ELEMS:
            return "mp"
        return "serial"

    def _use_vmap_counts(self) -> bool:
        """Whether the coordinator should precompute block counts with the
        jax kernel.  Precomputing only to fall back to the numpy loop would
        *duplicate* the exact work ``PortfolioCost.__init__`` does anyway,
        so this is True only when the jax path will really run:
        :func:`_resolve_counts_backend` must select jax (``"auto"`` =
        jax importable — an environment property, never import order) and
        the import must actually succeed."""
        if not _resolve_counts_backend(self.vmap_counts):
            return False
        try:
            import jax  # noqa: F401
            return True
        except ImportError:
            return False

    # -- driver -------------------------------------------------------------
    def refine(self, grid: CartGrid, stencil: Stencil,
               node_of_pos: np.ndarray,
               num_nodes: Optional[int] = None) -> RefineResult:
        if self.max_swaps is not None:
            # a global accepted-swap budget couples every ladder at move
            # granularity (one shared counter, checked per batched move) —
            # exactly the coupling sharding removes.  The single-process
            # engine IS that semantics, so budgeted runs delegate to it.
            delegate = copy.copy(self.portfolio)
            delegate.max_swaps = self.max_swaps
            res = delegate.refine(grid, stencil, node_of_pos, num_nodes)
            res.stats.update({"shards": 1, "backend": "single-process",
                              "restarted": 0, "delegated": "max_swaps"})
            return res
        t0 = time.perf_counter()
        sched = self.schedule
        cur = np.asarray(node_of_pos, dtype=np.int64).copy()
        initial = IncrementalCost(grid, stencil, cur, num_nodes=num_nodes,
                                  weighted=sched.weighted).cost()
        best, best_key = cur.copy(), (initial.j_max, initial.j_sum)

        def consider(candidate: np.ndarray, key: Tuple[float, float]):
            nonlocal best, best_key
            if key < best_key:
                best, best_key = candidate.copy(), key

        # 1. shared deterministic prefix (seed-independent, run once)
        cur, swaps, passes = sched.run_rounds(grid, stencil, cur, num_nodes,
                                              consider, max_swaps=None)
        t_rounds = time.perf_counter() - t0

        # 2. sharded ladders with coordinator-side boundaries
        lad = self._sharded_ladders(grid, stencil, cur, num_nodes)
        swaps += lad["sa_accepted"]
        t_ladders = time.perf_counter() - t0 - t_rounds

        # 3. original survivors: the exact single-process selection + polish
        swaps, passes, polish_order = self.portfolio._polish_survivors(
            grid, stencil, num_nodes, consider, lad["nodes"],
            lad["lad_j_max"], lad["lad_j_sum"], lad["alive"], swaps, passes)

        # 4. adaptive extras: restart ladders are pure additional
        # candidates (raw + their own ranked polish), so the adaptive
        # engine can only improve on the base portfolio's selection.
        restart_polished = 0
        restarts = lad["restarts"]
        for r in restarts:
            consider(r["node"].copy(), (r["j_max"], r["j_sum"]))
        ranked = sorted(range(len(restarts)),
                        key=lambda j: (restarts[j]["j_max"],
                                       restarts[j]["j_sum"], j))
        r_budget = len(ranked) if self.portfolio.polish_top is None \
            else self.portfolio.polish_top
        seen = set()
        for j in ranked:
            if restart_polished >= r_budget:
                break
            key = restarts[j]["node"].tobytes()
            if key in seen:
                continue
            seen.add(key)
            _, s, p = sched.polish(grid, stencil, restarts[j]["node"].copy(),
                                   num_nodes, consider, max_swaps=None)
            swaps += s
            passes += p
            restart_polished += 1

        final = IncrementalCost(grid, stencil, best, num_nodes=num_nodes,
                                weighted=sched.weighted).cost()
        wall = time.perf_counter() - t0
        stats = {
            "k": self.k,
            "seeds": self.seeds,
            "shards": lad["shards"],
            "backend": lad["backend"],
            "sa_accepted": lad["sa_accepted"],
            "killed": lad["killed"],
            "restarted": len(restarts),
            "pool_moves_left": lad["pool_moves"],
            "restart_seeds": [r["seed"] for r in restarts],
            "restart_t_mults": [r["t_mult"] for r in restarts],
            "polished": len(polish_order),
            "restart_polished": restart_polished,
            "ladder_keys": [(float(j), float(s)) for j, s in
                            zip(lad["lad_j_max"], lad["lad_j_sum"])],
            "t_rounds_s": t_rounds,
            "t_ladders_s": t_ladders,
            "t_polish_s": wall - t_rounds - t_ladders,
        }
        return RefineResult(assignment=best, initial=initial, final=final,
                            swaps=swaps, passes=passes, wall_time_s=wall,
                            stats=stats)

    # -- the sharded ladder coordinator -------------------------------------
    def _sharded_ladders(self, grid: CartGrid, stencil: Stencil,
                         start: np.ndarray,
                         num_nodes: Optional[int]) -> dict:
        sched, port = self.schedule, self.portfolio
        K = self.k
        S = min(self.shards, K)
        n_nodes = int(num_nodes) if num_nodes is not None \
            else int(start.max() + 1)
        weighted = resolve_weighted(sched.weighted, stencil)
        weights = stencil.weight_array() if weighted else np.ones(stencil.k)
        t_scale = float(np.mean(weights))
        backend = self._resolve_backend(grid.size)
        vmap_counts = backend == "serial" and self._use_vmap_counts()

        # per-ladder start bookkeeping, identical floats to the
        # single-process engine (same integer counts, same ascending-offset
        # accumulation order)
        start_ic = IncrementalCost(grid, stencil, start, num_nodes=n_nodes,
                                   weighted=weighted)
        j_sum0, j_max0 = start_ic.j_sum, start_ic.j_max
        eps0 = float(1.0 / (1.0 + np.abs(j_sum0)))
        n_temps = len(sched.temperatures)
        ctrl = BoundaryController(
            k=K, kill_factor=port.kill_factor,
            start_keys=np.asarray([j_max0, j_sum0]),
            restarts=self.restarts, retune=self.retune,
            accept_band=self.accept_band, retune_bounds=self.retune_bounds,
            sa_moves=sched.sa_moves, n_temps=n_temps,
            seeder=RestartSeeder(self.seeds, start=self._restart_seed_base))
        alive = ctrl.alive
        cur_keys = np.broadcast_to(
            np.asarray([j_max0, j_sum0]), (K, 2)).copy()

        idx_blocks = [b for b in np.array_split(np.arange(K), S) if b.size]
        blocks = [{
            "node": np.broadcast_to(start, (b.size, grid.size)).copy(),
            "rngs": [np.random.default_rng(self.seeds[i]) for i in b],
            "done": np.zeros(b.size, dtype=bool),
        } for b in idx_blocks]
        base_payload = {
            "dims": tuple(grid.dims), "periodic": tuple(grid.periodic),
            "offsets": stencil.offsets, "weights": stencil.weights,
            "weighted": weighted, "num_nodes": n_nodes,
            "sa_moves": sched.sa_moves,
        }
        if type(grid) is not CartGrid:
            # graph-backed (GraphGrid) or masked grids answer shift_ranks
            # from their own structure — rebuilding a plain CartGrid from
            # dims in the worker would silently drop it.  Both pickle
            # fine (numpy arrays), so ship the object whole.
            base_payload["grid"] = grid
        restarts: List[dict] = []
        accepted = 0

        executor = None
        if backend == "mp" and S > 1:
            # fork keeps the workers cheap (no re-import; the tasks are
            # numpy-only, so jax's forked threadpools are never touched);
            # spawn is the non-POSIX fallback.  The executor — unlike
            # multiprocessing.Pool — *raises* BrokenProcessPool when a
            # worker dies at startup (e.g. spawn under a non-importable
            # __main__, REPL/stdin scripts), so a broken pool degrades to
            # the inline path instead of hanging a map() forever.
            from concurrent.futures import ProcessPoolExecutor
            methods = multiprocessing.get_all_start_methods()
            ctx = multiprocessing.get_context(
                "fork" if "fork" in methods else "spawn")
            n_proc = min(S, os.cpu_count() or 1)
            if self.workers is not None:
                n_proc = max(1, min(n_proc, self.workers))
            try:
                executor = ProcessPoolExecutor(max_workers=n_proc,
                                               mp_context=ctx)
            except (OSError, ValueError):    # pragma: no cover - no procs
                executor = None
        pool_ok = executor is not None

        def step(payloads):
            nonlocal pool_ok, backend
            results = None
            if pool_ok and len(payloads) > 1:
                try:
                    results = list(executor.map(_block_step, payloads))
                except Exception:
                    # dead workers (broken spawn main, OOM-killed child, a
                    # task that raised): results are bit-identical either
                    # way, so finish the run inline rather than failing the
                    # mapping.  The executor itself is NOT torn down here —
                    # the enclosing try/finally joins it exactly once,
                    # crash or not, so worker processes are never orphaned.
                    pool_ok = False
                    backend = "serial-fallback"
            if results is None:
                results = [_block_step(p) for p in payloads]
            if _IPC_METER is not None and payloads:
                _IPC_METER.record(payloads, results)
            return results

        def leader_state() -> Tuple[np.ndarray, float]:
            """Current portfolio leader (lexicographic best current key,
            originals then restarts, lowest index wins ties)."""
            cand = [((cur_keys[i, 0], cur_keys[i, 1], 0, i), None)
                    for i in range(K) if alive[i]]
            cand += [((r["j_max"], r["j_sum"], 1, j), r)
                     for j, r in enumerate(restarts)]
            key, r = min(cand, key=lambda c: c[0])
            if r is not None:
                return r["node"], r["j_sum"]
            i = key[3]
            for b, blk in zip(idx_blocks, blocks):
                pos = np.nonzero(b == i)[0]
                if pos.size:
                    return blk["node"][int(pos[0])], float(cur_keys[i, 1])
            raise AssertionError("leader not found")  # pragma: no cover

        try:
            for ti, T0 in enumerate(sched.temperatures):
                T = max(T0 * t_scale, 1e-12)
                payloads, specs = [], []
                for bi, b in enumerate(idx_blocks):
                    blk = blocks[bi]
                    if not (alive[b] & ~blk["done"]).any():
                        continue    # every ladder killed/ended: nothing to
                        # advance — skip the state rebuild (and, under mp,
                        # the round-trip); cur_keys[b] stays frozen, which
                        # is exactly what a no-op dispatch would produce
                    payload = {**base_payload, "node": blk["node"],
                               "rngs": blk["rngs"], "alive": alive[b],
                               "done": blk["done"],
                               "temps": np.full(b.size, T),
                               "eps": np.full(b.size, eps0)}
                    if vmap_counts:
                        payload["counts"] = stacked_crossing_counts(
                            grid, stencil, blk["node"], n_nodes,
                            use_jax=self.vmap_counts)
                    payloads.append(payload)
                    specs.append(("orig", bi, b))
                active = [r for r in restarts if not r["done"]]
                if active:
                    # blocking only buys parallel dispatch; ladder
                    # trajectories are blocking-invariant, so the serial
                    # backend batches all restarts into one kernel call
                    n_chunks = min(S, len(active)) if pool_ok else 1
                    for chunk in np.array_split(np.arange(len(active)),
                                                n_chunks):
                        if not chunk.size:
                            continue
                        rs = [active[int(c)] for c in chunk]
                        payloads.append({
                            **base_payload,
                            "node": np.stack([r["node"] for r in rs]),
                            "rngs": [r["rng"] for r in rs],
                            "alive": np.ones(len(rs), dtype=bool),
                            "done": np.array([r["done"] for r in rs]),
                            "temps": np.array(
                                [max(T0 * t_scale * r["t_mult"], 1e-12)
                                 for r in rs]),
                            "eps": np.array([r["eps"] for r in rs]),
                        })
                        specs.append(("restart", None, rs))
                for (kind, bi, ref), res in zip(specs, step(payloads)):
                    accepted += int(res["accepted"].sum())
                    if kind == "orig":
                        blocks[bi].update(node=res["node"],
                                          rngs=res["rngs"],
                                          done=res["done"])
                        cur_keys[ref] = np.stack(
                            [res["j_max"], res["j_sum"]], axis=1)
                    else:
                        for li, r in enumerate(ref):
                            r.update(node=res["node"][li],
                                     rng=res["rngs"][li],
                                     done=bool(res["done"][li]),
                                     j_max=float(res["j_max"][li]),
                                     j_sum=float(res["j_sum"][li]),
                                     accepted_last=int(res["accepted"][li]))
                # temperature boundary: the shared protocol
                # (:class:`~repro.core.refine.engine.BoundaryController`)
                # over globally merged keys — best-seen update, the
                # single-process kill rule (restarts never feed it), then
                # adaptive control: killed ladders fund restarts from the
                # leader; restart temperatures retune from accept rates
                ctrl.update_best(cur_keys)
                newly_killed = ctrl.kill()

                def spawn(seed: int) -> bool:
                    node, lead_j_sum = leader_state()
                    restarts.append({
                        "node": node.copy(),
                        "rng": np.random.default_rng(seed),
                        "seed": seed,
                        "done": False,
                        "eps": float(1.0 / (1.0 + abs(lead_j_sum))),
                        "t_mult": 1.0,
                        "j_max": math.inf, "j_sum": math.inf,
                        "accepted_last": 0,
                    })
                    return True

                ctrl.adapt(ti, newly_killed, restarts, spawn)
        finally:
            if executor is not None:
                # wait=True even on the crash path: shutdown(wait=False)
                # there would leave the worker processes unjoined (orphaned
                # children outliving the refine — the satellite regression
                # pinned by test_sharded_crash_leaves_no_orphans)
                executor.shutdown(wait=True, cancel_futures=True)

        nodes = np.empty((K, grid.size), dtype=np.int64)
        for b, blk in zip(idx_blocks, blocks):
            nodes[b] = blk["node"]
        # every restart ran at least one temperature (the spawn loop is
        # gated on rem > 0), so its key is finite and usable as a candidate
        assert all(math.isfinite(r["j_max"]) for r in restarts)
        return {"nodes": nodes, "lad_j_max": cur_keys[:, 0].copy(),
                "lad_j_sum": cur_keys[:, 1].copy(), "alive": alive,
                "restarts": restarts, "sa_accepted": accepted,
                "killed": ctrl.killed, "pool_moves": ctrl.pool_moves,
                "shards": S, "backend": backend}
