"""Alternating-objective refinement schedules (J_max-aware local search).

A single-objective :class:`~repro.core.refine.SwapRefiner` run stalls at the
first plateau of its own metric: a J_sum pass leaves bottleneck imbalance on
the table, and a J_max pass stops as soon as no single swap lowers the
bottleneck — exactly the weakness Schulz & Träff (Better Process Mapping and
Sparse Quadratic Assignment, 2017) identify for bottleneck metrics.
:class:`ScheduledRefiner` runs the two objectives in alternating phases so
each unlocks moves for the other, and (``anneal=True``) follows with a
simulated-annealing temperature ladder that accepts controlled uphill swaps
to hop J_max plateaus, re-polishing after every temperature.

The result is selected lexicographically by ``(J_max, J_sum)`` over every
phase boundary *including the input*, so a schedule can never return a
mapping that is lexicographically worse than what it was given — and since
its first phase is exactly the default ``refined:<base>`` pass, the
``refined2:``/``annealed:`` variants are J_max-no-worse than ``refined:``
by construction (for matching phase parameters).

Usage::

    from repro.core import ScheduledRefiner, get_mapper
    res = ScheduledRefiner(anneal=True).refine(grid, stencil, a, num_nodes=N)
    m = get_mapper("annealed:hyperplane")      # same engine, mapper-shaped
"""
from __future__ import annotations

import math
import time
from typing import Optional, Sequence, Tuple

import numpy as np

from ..cost_delta import IncrementalCost
from ..grid import CartGrid
from ..stencil import Stencil
from .swap import RefineResult, SwapRefiner

__all__ = ["ScheduledRefiner"]


class ScheduledRefiner:
    """Alternate j_sum/j_max :class:`SwapRefiner` phases, optionally followed
    by a simulated-annealing ladder; returns the lexicographically best
    ``(J_max, J_sum)`` assignment seen.

    Args:
      objectives: phase order within one round (each entry is a SwapRefiner
        objective).  The default runs J_sum first — matching the default
        ``refined:<base>`` pass exactly — then relieves the bottleneck.
      rounds: maximum schedule rounds; a round with zero accepted swaps
        stops early.
      policy / max_passes / weighted / tol / max_partners / engine:
        forwarded to each phase's :class:`SwapRefiner`.
      anneal: append the SA ladder after the deterministic schedule.
      temperatures: SA ladder (descending), in units of one unit-weight
        J_max step; scaled by the stencil's mean weight when ``weighted``.
      sa_moves: proposed swaps per temperature.
      seed: SA rng seed (the whole refiner stays deterministic).
      max_swaps: total accepted-swap budget across every phase and the SA
        ladder (None = unlimited — the default, bit-identical to the
        budget-free engine).  This is what per-stage plan budgets
        (:class:`~repro.core.refine.stage.RefineStage`) thread into.
    """

    def __init__(self, objectives: Sequence[str] = ("j_sum", "j_max"),
                 rounds: int = 4, policy: str = "first", max_passes: int = 8,
                 weighted="auto", tol: float = 1e-12,
                 max_partners: int = 32, engine: str = "batch",
                 anneal: bool = False,
                 temperatures: Sequence[float] = (2.0, 1.0, 0.5, 0.25),
                 sa_moves: int = 200, seed: int = 0,
                 max_swaps: Optional[int] = None):
        if not objectives:
            raise ValueError("objectives must be non-empty")
        if rounds < 0:
            raise ValueError("rounds must be >= 0 (0 = skip the "
                             "deterministic rounds, ladder/polish only)")
        # validate eagerly (same errors as SwapRefiner would raise later)
        for obj in objectives:
            SwapRefiner(objective=obj, policy=policy, max_passes=max_passes,
                        engine=engine)
        self.objectives = tuple(objectives)
        self.rounds = int(rounds)
        self.policy = policy
        self.max_passes = int(max_passes)
        self.weighted = weighted
        self.tol = float(tol)
        self.max_partners = int(max_partners)
        self.engine = engine
        self.anneal = bool(anneal)
        self.temperatures = tuple(float(t) for t in temperatures)
        self.sa_moves = int(sa_moves)
        self.seed = int(seed)
        if max_swaps is not None and int(max_swaps) < 0:
            raise ValueError("max_swaps must be >= 0 (or None)")
        self.max_swaps = None if max_swaps is None else int(max_swaps)

    def as_stage(self, budget: Optional[int] = None):
        """Uniform :class:`~repro.core.refine.stage.RefineStage` adapter
        (``budget`` caps this stage's accepted swaps)."""
        from .stage import RefineStage
        return RefineStage(self, budget=budget,
                           prefix="annealed" if self.anneal else "refined2")

    def config(self) -> dict:
        """Full constructor configuration — the stage layer's canonical
        cache identity for hand-built refiners."""
        return {"objectives": self.objectives, "rounds": self.rounds,
                "policy": self.policy, "max_passes": self.max_passes,
                "weighted": self.weighted, "tol": self.tol,
                "max_partners": self.max_partners, "engine": self.engine,
                "anneal": self.anneal, "temperatures": self.temperatures,
                "sa_moves": self.sa_moves, "seed": self.seed,
                "max_swaps": self.max_swaps}

    # -- phases -------------------------------------------------------------
    def _phase(self, objective: str,
               max_swaps: Optional[int] = None) -> SwapRefiner:
        return SwapRefiner(objective=objective, policy=self.policy,
                           max_passes=self.max_passes, weighted=self.weighted,
                           tol=self.tol, max_partners=self.max_partners,
                           engine=self.engine, max_swaps=max_swaps)

    def _sa_ladder(self, grid: CartGrid, stencil: Stencil,
                   assignment: np.ndarray, num_nodes: Optional[int],
                   rng: np.random.Generator,
                   budget: Optional[int] = None) -> Tuple[np.ndarray, int]:
        """One descending temperature ladder of Metropolis swap moves.
        Energy is J_max plus a J_sum tie-break term scaled below one
        bottleneck unit, so uphill acceptance is governed by the bottleneck.
        Proposals are sampled from a boundary snapshot refreshed once per
        temperature — a swap only perturbs the boundary locally, and any
        staleness merely shifts the proposal distribution, which the
        post-ladder polish phases absorb."""
        ic = IncrementalCost(grid, stencil, assignment, num_nodes=num_nodes,
                             weighted=self.weighted)
        t_scale = float(np.mean(ic.weights))
        eps = 1.0 / (1.0 + abs(ic.j_sum))
        accepted = 0
        for T in self.temperatures:
            T = max(T * t_scale, 1e-12)
            boundary = ic.boundary_positions()
            for _ in range(self.sa_moves):
                if budget is not None and accepted >= budget:
                    return ic.node_of_pos.copy(), accepted
                if boundary.size < 2:
                    return ic.node_of_pos.copy(), accepted
                p = int(boundary[rng.integers(boundary.size)])
                partners = boundary[ic.node_of_pos[boundary]
                                    != ic.node_of_pos[p]]
                if partners.size == 0:
                    break
                q = int(partners[rng.integers(partners.size)])
                delta = ic.delta_swap(p, q)
                d_e = (ic.peek_j_max(delta) - ic.j_max
                       + delta.d_j_sum * eps)
                if d_e <= 0.0 or rng.random() < math.exp(-d_e / T):
                    ic.apply_swap(p, q)
                    accepted += 1
        return ic.node_of_pos.copy(), accepted

    # -- schedule building blocks (shared with PortfolioRefiner) ------------
    def run_rounds(self, grid: CartGrid, stencil: Stencil, cur: np.ndarray,
                   num_nodes: Optional[int], consider,
                   max_swaps: Optional[int] = None) \
            -> Tuple[np.ndarray, int, int]:
        """The deterministic alternating-objective rounds: returns the final
        phase-chain state (the SA ladder's start point — *not* the
        lexicographic best) plus accepted-swap/pass counts.  ``consider`` is
        called with every phase result's ``(assignment, (j_max, j_sum))``;
        ``max_swaps`` caps total accepted swaps across all phases."""
        swaps = passes = 0
        for _ in range(self.rounds):
            round_swaps = 0
            for obj in self.objectives:
                cap = None if max_swaps is None else max_swaps - swaps
                res = self._phase(obj, cap).refine(grid, stencil, cur,
                                                   num_nodes=num_nodes)
                cur = res.assignment
                swaps += res.swaps
                passes += res.passes
                round_swaps += res.swaps
                consider(cur, (res.final.j_max, res.final.j_sum))
                if max_swaps is not None and swaps >= max_swaps:
                    return cur, swaps, passes
            if round_swaps == 0:
                break
        return cur, swaps, passes

    def polish(self, grid: CartGrid, stencil: Stencil, cur: np.ndarray,
               num_nodes: Optional[int], consider,
               max_swaps: Optional[int] = None) \
            -> Tuple[np.ndarray, int, int]:
        """One pass of the phase objectives over a (perturbed) state — what
        the annealed schedule runs after its SA ladder."""
        swaps = passes = 0
        for obj in self.objectives:
            cap = None if max_swaps is None else max_swaps - swaps
            res = self._phase(obj, cap).refine(grid, stencil, cur,
                                               num_nodes=num_nodes)
            cur = res.assignment
            swaps += res.swaps
            passes += res.passes
            consider(cur, (res.final.j_max, res.final.j_sum))
            if max_swaps is not None and swaps >= max_swaps:
                break
        return cur, swaps, passes

    # -- driver -------------------------------------------------------------
    def refine(self, grid: CartGrid, stencil: Stencil,
               node_of_pos: np.ndarray,
               num_nodes: Optional[int] = None) -> RefineResult:
        t0 = time.perf_counter()
        cur = np.asarray(node_of_pos, dtype=np.int64).copy()
        initial = IncrementalCost(grid, stencil, cur, num_nodes=num_nodes,
                                  weighted=self.weighted).cost()
        best, best_key = cur.copy(), (initial.j_max, initial.j_sum)

        def consider(candidate: np.ndarray, key: Tuple[float, float]):
            nonlocal best, best_key
            if key < best_key:
                best, best_key = candidate.copy(), key

        cur, swaps, passes = self.run_rounds(grid, stencil, cur, num_nodes,
                                             consider,
                                             max_swaps=self.max_swaps)

        if self.anneal and (self.max_swaps is None
                            or swaps < self.max_swaps):
            rng = np.random.default_rng(self.seed)
            budget = None if self.max_swaps is None \
                else self.max_swaps - swaps
            perturbed, accepted = self._sa_ladder(grid, stencil, cur,
                                                  num_nodes, rng,
                                                  budget=budget)
            swaps += accepted
            budget = None if self.max_swaps is None \
                else self.max_swaps - swaps
            cur, s, p = self.polish(grid, stencil, perturbed, num_nodes,
                                    consider, max_swaps=budget)
            swaps += s
            passes += p

        final = IncrementalCost(grid, stencil, best, num_nodes=num_nodes,
                                weighted=self.weighted).cost()
        return RefineResult(assignment=best, initial=initial, final=final,
                            swaps=swaps, passes=passes,
                            wall_time_s=time.perf_counter() - t0)
