"""Composable mapping-plan stages (the unit the plan API is built from).

A :class:`Stage` transforms a node-of-position assignment; a
:class:`~repro.core.plan.MappingPlan` is an ordered stage list.  Two kinds
exist:

* :class:`BaseStage` — produces the *initial* assignment by running a base
  mapping algorithm (any :class:`~repro.core.mapping.Mapper`), optionally
  falling back to a second base when the first is inapplicable (the
  elastic path uses ``fallback="blocked"`` so homogeneous-only algorithms
  still yield a refinable start on ragged pods).
* :class:`RefineStage` — improves an existing assignment with any refiner
  exposing ``refine(grid, stencil, node_of_pos, num_nodes)``
  (:class:`~repro.core.refine.SwapRefiner`,
  :class:`~repro.core.refine.ScheduledRefiner`,
  :class:`~repro.core.refine.PortfolioRefiner` — each also exposes
  ``as_stage(budget=...)``).  An optional per-stage ``budget`` caps the
  stage's accepted swaps (threaded into the refiner's ``max_swaps``).

Stages are deterministic and stateless across runs, so a stage chain's
output is a pure function of ``(grid, stencil, node_sizes)`` — which is
what makes :class:`~repro.core.plan.PlanCache` keys sound.

Usage::

    stages = [BaseStage("hyperplane"),
              RefineStage(SwapRefiner(), budget=50),
              ScheduledRefiner(anneal=True).as_stage()]
    assignment = None                    # BaseStage produces the first one
    for s in stages:
        assignment = s.run(grid, stencil, node_sizes, assignment).assignment
"""
from __future__ import annotations

import abc
import copy
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Union

import numpy as np

from ..grid import CartGrid
from ..stencil import Stencil
from ..mapping.base import Mapper, MapperInapplicable

__all__ = ["Stage", "StageResult", "BaseStage", "RefineStage"]


def _canon_value(v) -> str:
    """Canonical spelling of one option value for plan keys (stable across
    equal configurations; tuples/lists render without spaces)."""
    if isinstance(v, (tuple, list)):
        return "(" + ",".join(_canon_value(x) for x in v) + ")"
    return str(v)


def canon_options(options: Dict[str, object]) -> str:
    """``{"seed": 3, "k": 8}`` -> ``"k=8,seed=3"`` (sorted, canonical)."""
    return ",".join(f"{k}={_canon_value(options[k])}" for k in sorted(options))


#: value types whose canonical spelling is stable across processes (an
#: object attribute would render as a repr with a memory address — never a
#: sound cache key).
_PLAIN_TYPES = (int, float, bool, str, type(None))


def _is_plain(v) -> bool:
    if isinstance(v, _PLAIN_TYPES):
        return True
    if isinstance(v, (tuple, list)):
        return all(_is_plain(x) for x in v)
    return False


def _instance_config(obj):
    """Canonical configuration of a hand-built component, as
    ``(config_dict, cacheable)``: its ``config()`` dict when it has one,
    else its public instance attributes — but only *plain* values
    (numbers/strings/tuples) yield ``cacheable=True``; anything holding
    nested objects is unkeyable (reprs carry memory addresses, which are
    neither stable nor collision-free) and must never enter a
    :class:`~repro.core.plan.PlanCache`."""
    if hasattr(obj, "config"):
        cfg = dict(obj.config())
    else:
        cfg = {k: v for k, v in sorted(vars(obj).items())
               if not k.startswith("_")
               and k not in ("plan_key", "last_result")}
    return cfg, all(_is_plain(v) for v in cfg.values())


@dataclass
class StageResult:
    """One stage's output: the (new) assignment, JSON-able ``stats``, and —
    for refine stages — the full :class:`~repro.core.refine.RefineResult`."""

    assignment: np.ndarray
    stats: Dict[str, object] = field(default_factory=dict)
    result: Optional[object] = None   # RefineResult for RefineStage


class Stage(abc.ABC):
    """One step of a mapping plan: assignment in (or None), assignment out."""

    #: False when this stage's configuration has no stable spelling (e.g. a
    #: hand-built component holding nested objects) — plans containing such
    #: a stage are solved uncached.
    cacheable: bool = True

    #: True for stages that *produce* a plan's first assignment (run with
    #: ``assignment=None``): :class:`BaseStage` and
    #: :class:`~repro.core.repair.RepairStage`.  A plan's first stage must
    #: be initial; no later stage may be.
    is_initial: bool = False

    #: stable spelling of this stage, used in plan keys (cache identity)
    @abc.abstractmethod
    def spec(self) -> str:
        ...

    @abc.abstractmethod
    def run(self, grid: CartGrid, stencil: Stencil,
            node_sizes: Sequence[int],
            assignment: Optional[np.ndarray] = None) -> StageResult:
        ...

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"<{type(self).__name__} {self.spec()}>"


class BaseStage(Stage):
    """Produce the initial assignment with a base mapping algorithm.

    ``mapper`` is a registered base name, a :class:`Mapper` subclass, or an
    instance; ``kwargs`` go to the algorithm's constructor.  ``fallback``
    (same forms) is used when the primary raises
    :class:`MapperInapplicable` — without one, the exception propagates so
    plan callers can fall back themselves.
    """

    is_initial = True

    def __init__(self, mapper: Union[Mapper, type, str] = "hyperplane",
                 fallback: Union[Mapper, type, str, None] = None, **kwargs):
        was_instance = isinstance(mapper, Mapper)
        self.mapper = self._resolve(mapper, kwargs)
        self.fallback = None if fallback is None else self._resolve(fallback, {})
        # spec identity: spelled kwargs when built from a name/class (empty
        # = the algorithm's defaults, unambiguous); a hand-built instance
        # derives its configuration so differently-configured instances
        # never share a cache key — underivable ones mark the stage
        # uncacheable instead.
        if was_instance:
            self.kwargs, self.cacheable = _instance_config(self.mapper)
        else:
            self.kwargs, self.cacheable = dict(kwargs), True

    @staticmethod
    def _resolve(mapper, kwargs) -> Mapper:
        if isinstance(mapper, Mapper):
            if kwargs:
                raise ValueError("kwargs need a mapper name/class, "
                                 "not an instance")
            return mapper
        if isinstance(mapper, type) and issubclass(mapper, Mapper):
            return mapper(**kwargs)
        from ..mapping import MAPPERS
        try:
            cls = MAPPERS[mapper]
        except KeyError:
            raise KeyError(f"unknown base mapper {mapper!r}; choose from "
                           f"{sorted(MAPPERS)}")
        return cls(**kwargs)

    def spec(self) -> str:
        s = self.mapper.name
        if self.kwargs:
            s += "{" + canon_options(self.kwargs) + "}"
        if self.fallback is not None:
            s += f"@fallback={self.fallback.name}"
        return s

    def run(self, grid: CartGrid, stencil: Stencil,
            node_sizes: Sequence[int],
            assignment: Optional[np.ndarray] = None) -> StageResult:
        if assignment is not None:
            raise ValueError("BaseStage must be the first stage of a plan")
        used_fallback = False
        try:
            a = self.mapper.assignment(grid, stencil, node_sizes)
        except MapperInapplicable:
            if self.fallback is None:
                raise
            a = self.fallback.assignment(grid, stencil, node_sizes)
            used_fallback = True
        return StageResult(assignment=a,
                           stats={"stage": self.spec(), "kind": "base",
                                  "used_fallback": used_fallback})


class RefineStage(Stage):
    """Improve an assignment with a refiner; preserves the per-node
    cardinalities (the scheduler allocation) by construction and asserts
    it after every run.

    ``budget`` caps the stage's accepted swaps by threading the refiner's
    ``max_swaps`` (all shipped refiners support it; for a foreign refiner
    without the attribute the budget is recorded in stats but cannot be
    enforced).  ``prefix`` is the registry spelling this stage answers to
    (``refined`` / ``refined2`` / ``annealed`` / ``portfolio``), used for
    plan keys; ``options`` are the *spelled* refiner options for the same
    purpose — when None (hand-built stage), the refiner's full ``config()``
    is derived instead, so two differently-configured refiners never share
    a cache key ({} means "the spelling's defaults", which is unambiguous).
    """

    def __init__(self, refiner, budget: Optional[int] = None,
                 prefix: Optional[str] = None,
                 options: Optional[Dict[str, object]] = None):
        if not hasattr(refiner, "refine"):
            raise TypeError(f"refiner {refiner!r} has no refine() method")
        if budget is not None and int(budget) < 0:
            raise ValueError("budget must be >= 0 (or None)")
        self.refiner = refiner
        self.budget = None if budget is None else int(budget)
        self.prefix = prefix if prefix is not None \
            else type(refiner).__name__.lower()
        if options is None:
            self.options, self.cacheable = _instance_config(refiner)
        else:
            self.options, self.cacheable = dict(options), True

    def spec(self) -> str:
        s = self.prefix
        if self.options:
            s += "[" + canon_options(self.options) + "]"
        if self.budget is not None:
            s += f"@budget={self.budget}"
        return s

    def _budgeted(self):
        """The refiner to run: a shallow copy with ``max_swaps`` capped at
        the stage budget (min-combined with any existing cap)."""
        if self.budget is None or not hasattr(self.refiner, "max_swaps"):
            return self.refiner
        r = copy.copy(self.refiner)
        cur = getattr(r, "max_swaps", None)
        r.max_swaps = self.budget if cur is None else min(int(cur), self.budget)
        return r

    def run(self, grid: CartGrid, stencil: Stencil,
            node_sizes: Sequence[int],
            assignment: Optional[np.ndarray] = None) -> StageResult:
        if assignment is None:
            raise ValueError("RefineStage needs an assignment to refine "
                             "(put a BaseStage first)")
        assignment = np.asarray(assignment, dtype=np.int64)
        n = len(node_sizes)
        sizes = np.asarray([int(s) for s in node_sizes], dtype=np.int64)
        if not np.array_equal(np.bincount(assignment, minlength=n), sizes):
            raise AssertionError(
                "input assignment does not realize node_sizes (the blocked "
                "scheduler allocation)")
        res = self._budgeted().refine(grid, stencil, assignment, num_nodes=n)
        if not np.array_equal(np.bincount(res.assignment, minlength=n),
                              sizes):
            raise AssertionError("refinement changed per-node cardinalities")
        stats = {
            "stage": self.spec(), "kind": "refine", "budget": self.budget,
            "swaps": res.swaps, "passes": res.passes,
            "wall_time_s": res.wall_time_s,
            "initial": (res.initial.j_max, res.initial.j_sum),
            "final": (res.final.j_max, res.final.j_sum),
        }
        return StageResult(assignment=res.assignment, stats=stats, result=res)
