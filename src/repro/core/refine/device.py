"""Device-resident annealing portfolio: vmapped Metropolis ladders on the
accelerator.

The numpy portfolio (:mod:`repro.core.refine.portfolio`) advances K ladders
per move but runs the proposal loop in Python, so K tops out around 8-64.
This engine moves the *whole temperature* onto the accelerator:

* the integer crossing-count state for K stacked assignments — the
  ``(K, N, k)`` ``count_node`` arrays of
  :func:`~repro.core.refine.sharded.stacked_crossing_counts`, promoted here
  from an opt-in counts producer to the resident state representation —
  lives on the device for the entire run;
* proposals are drawn with ``jax.random`` (one key per ladder, split per
  move, so a ladder's stream depends only on its own seed — deterministic
  and batch-composition-independent);
* a vmapped Metropolis accept advances all K ladders per move (position
  from the temperature's boundary snapshot, cross-node partner, uphill
  acceptance ``u < exp(-d_e/T)`` — the same proposal shape as the host
  kernel);
* ``jax.lax.scan`` runs a full temperature of ``sa_moves`` moves as one
  jitted call, with exactly **one host round-trip per temperature
  boundary** (per-ladder keys, accepted counts, done flags — a few small
  vectors), where the shared boundary protocol
  (:class:`~repro.core.refine.engine.BoundaryController`: best-seen,
  early-kill, restart/retune) runs on the coordinator exactly as it does
  for the serial and sharded engines;
* each ladder additionally tracks its lexicographic **best-seen state on
  device** (the host engines only keep boundary keys), so at equal
  proposal budget the device portfolio's candidate set has up to 2K
  entries — end states plus walk minima — before polish.

Draw-for-draw parity with the numpy rng is not feasible (different
generators), so the correctness contract is carried by
``tests/test_device_portfolio.py``: integer-exact count state vs
``evaluate`` after every boundary, alive-mask monotonicity,
seed-determinism of the device rng stream, and the pinned dominance /
K-scaling claims of ``benchmarks/refine_suite.py --device``
(``results/BENCH_7.json``).

Restart ladders use **preallocated slots**: ``restart_slots`` extra rows
ride in the stacked state from the start (inactive until spawned), so a
spawn at a temperature boundary is a row write, never a shape change — the
jitted temperature kernel compiles once per (K + slots, p, N, k) shape.

Without jax (or for ``max_swaps`` budgets and ``pinned`` repair masks,
whose move-level coupling is host semantics), the refiner delegates to the
single-process :class:`~repro.core.refine.portfolio.PortfolioRefiner` —
same seeds, same schedule — so every spelling works in every environment.

Usage::

    from repro.core import DevicePortfolioRefiner, get_mapper
    res = DevicePortfolioRefiner(k=256).refine(grid, st, a, num_nodes=N)
    m = get_mapper("device[k=1024]:hyperplane")
    m = get_mapper("device[k=64,restarts=auto,retune=true]:kdtree")
"""
from __future__ import annotations

import copy
import functools
import math
import time
import warnings
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..cost_delta import IncrementalCost, PortfolioCost
from ..grid import CartGrid
from ..stencil import Stencil, resolve_weighted
from .engine import (BoundaryController, BoundaryReport, LadderEngine,
                     RestartSeeder)
from .portfolio import PortfolioRefiner
from .sharded import _memo_table, stacked_crossing_counts
from .swap import RefineResult

__all__ = ["DeviceLadderEngine", "DevicePortfolioRefiner", "jax_ready"]

#: memoized "does jax import and initialize?" verdict (None = undecided).
_JAX_READY: Optional[bool] = None


def jax_ready() -> bool:
    """True when jax actually imports (the device engine runs real jitted
    kernels, so spec discovery is not enough).  Cached per process."""
    global _JAX_READY
    if _JAX_READY is None:
        try:
            import jax  # noqa: F401
            _JAX_READY = True
        except Exception:           # pragma: no cover - no jax in image
            _JAX_READY = False
    return _JAX_READY


@functools.lru_cache(maxsize=16)
def _temperature_kernel(sa_moves: int):
    """Build (and cache) the jitted one-temperature kernel: ``sa_moves``
    is the static ``lax.scan`` length; every array shape is keyed by jax's
    own jit cache, so one callable serves every (rows, p, N, k) problem.

    The kernel replays the host ladder semantics per temperature: boundary
    snapshot once, then ``sa_moves`` batched Metropolis moves — position
    and partner drawn per ladder from the snapshot, the swap's exact
    integer count delta applied on accept, energy
    ``d_J_max + d_J_sum * eps`` — plus device-side best-seen tracking.
    All :math:`O(rows \\cdot p)` state stays on device; only the boundary
    report leaves.
    """
    import jax
    import jax.numpy as jnp

    def run(node, cn, keys, best_node, best_jmax, best_jsum, done, live,
            temps, eps, weights, out_valid, out_tgt, in_valid, in_src):
        R, p = node.shape
        N = cn.shape[1]
        k = cn.shape[2]

        def loads(c):                           # (R, N, k) int -> (R, N)
            return jnp.einsum("rnk,k->rn", c.astype(jnp.float32), weights)

        def off_sum(c):                         # (R, N, k) int -> (R,)
            return jnp.einsum("rk,k->r",
                              c.sum(axis=1).astype(jnp.float32), weights)

        # temperature-boundary snapshot: a position is on the boundary when
        # it is an endpoint of any crossing edge (same set as the host
        # engine's PortfolioCost.boundary_masks)
        out_cross = out_valid[None] & (node[:, None, :] != node[:, out_tgt])
        in_cross = in_valid[None] & (node[:, None, :] != node[:, in_src])
        bmask = out_cross.any(axis=1) | in_cross.any(axis=1)    # (R, p)
        done = done | (bmask.sum(axis=1) < 2)
        active = live & ~done
        logit_p = jnp.where(bmask, 0.0, -jnp.inf)               # (R, p)

        def ladder_delta(node_r, p_r, q_r, a_r, b_r):
            """Exact integer count_node delta of swapping positions
            ``p_r``/``q_r`` in one ladder: only edges with an endpoint in
            {p, q} change crossing status — the four directed edge groups,
            in-edges deduped against the out groups."""
            src = jnp.concatenate([
                jnp.full((k,), p_r, dtype=node_r.dtype),
                jnp.full((k,), q_r, dtype=node_r.dtype),
                in_src[:, p_r], in_src[:, q_r]])
            dst = jnp.concatenate([
                out_tgt[:, p_r], out_tgt[:, q_r],
                jnp.full((k,), p_r, dtype=node_r.dtype),
                jnp.full((k,), q_r, dtype=node_r.dtype)])
            valid = jnp.concatenate([
                out_valid[:, p_r], out_valid[:, q_r],
                in_valid[:, p_r] & (in_src[:, p_r] != p_r)
                & (in_src[:, p_r] != q_r),
                in_valid[:, q_r] & (in_src[:, q_r] != p_r)
                & (in_src[:, q_r] != q_r)])
            off = jnp.tile(jnp.arange(k, dtype=jnp.int32), 4)

            def remap(x):               # node of x after the swap
                return jnp.where(x == p_r, b_r,
                                 jnp.where(x == q_r, a_r, node_r[x]))

            s_old, d_old = node_r[src], node_r[dst]
            s_new, d_new = remap(src), remap(dst)
            old_c = valid & (s_old != d_old)
            new_c = valid & (s_new != d_new)
            dec = jax.ops.segment_sum(old_c.astype(jnp.int32),
                                      s_old * k + off, num_segments=N * k)
            inc = jax.ops.segment_sum(new_c.astype(jnp.int32),
                                      s_new * k + off, num_segments=N * k)
            return (inc - dec).reshape(N, k)

        rows = jnp.arange(R)

        def move(carry, _):
            node, cn, keys, bnode, bjmax, bjsum, acc = carry
            ks = jax.vmap(lambda kk: jax.random.split(kk, 4))(keys)
            keys_next, kp, kq, ku = ks[:, 0], ks[:, 1], ks[:, 2], ks[:, 3]
            # position, then cross-node partner, both from the snapshot
            # (current node values, like the host kernel's partner check)
            pi = jax.vmap(jax.random.categorical)(kp, logit_p)      # (R,)
            a = jnp.take_along_axis(node, pi[:, None], axis=1)[:, 0]
            partner = bmask & (node != a[:, None])
            has_q = partner.any(axis=1)
            qi = jax.vmap(jax.random.categorical)(
                kq, jnp.where(partner, 0.0, -jnp.inf))
            b = jnp.take_along_axis(node, qi[:, None], axis=1)[:, 0]
            d_cn = jax.vmap(ladder_delta)(node, pi, qi, a, b)
            cn_new = cn + d_cn
            jmax_old = loads(cn).max(axis=1)
            jmax_new = loads(cn_new).max(axis=1)
            d_jsum = jnp.einsum("rk,k->r",
                                d_cn.sum(axis=1).astype(jnp.float32), weights)
            d_e = jmax_new - jmax_old + d_jsum * eps
            u = jax.vmap(jax.random.uniform)(ku)
            accept = active & has_q & ((d_e <= 0.0)
                                       | (u < jnp.exp(-d_e / temps)))
            node_sw = node.at[rows, pi].set(b).at[rows, qi].set(a)
            node = jnp.where(accept[:, None], node_sw, node)
            cn = jnp.where(accept[:, None, None], cn_new, cn)
            acc = acc + accept.astype(jnp.int32)
            # device-side best-seen: strict lexicographic improvement only,
            # so frozen (inactive) ladders never touch their snapshot
            cur_jmax = jnp.where(accept, jmax_new, jmax_old)
            cur_jsum = off_sum(cn)
            better = (cur_jmax < bjmax) | ((cur_jmax == bjmax)
                                           & (cur_jsum < bjsum))
            bnode = jnp.where(better[:, None], node, bnode)
            bjmax = jnp.where(better, cur_jmax, bjmax)
            bjsum = jnp.where(better, cur_jsum, bjsum)
            return (node, cn, keys_next, bnode, bjmax, bjsum, acc), None

        acc0 = jnp.zeros(R, dtype=jnp.int32)
        carry = (node, cn, keys, best_node, best_jmax, best_jsum, acc0)
        carry, _ = jax.lax.scan(move, carry, None, length=sa_moves)
        node, cn, keys, best_node, best_jmax, best_jsum, acc = carry
        return (node, cn, keys, best_node, best_jmax, best_jsum, done,
                acc, loads(cn).max(axis=1), off_sum(cn))

    import jax
    return jax.jit(run)


class DeviceLadderEngine(LadderEngine):
    """K + ``restart_slots`` annealing ladders resident on the accelerator.

    Rows ``0..K-1`` are the original seeds; rows ``K..`` are restart slots,
    inactive until :meth:`spawn_restart` fills one at a temperature
    boundary.  All per-ladder arrays (``temps``/``eps``/``alive``) are
    full-height (K + slots); the controller's alive mask covers the
    originals and the engine tracks slot liveness itself.
    """

    name = "device"

    def __init__(self, grid: CartGrid, stencil: Stencil, start: np.ndarray,
                 seeds: Sequence[int], num_nodes: Optional[int] = None,
                 weighted=False, restart_slots: int = 0,
                 counts_backend="auto"):
        import jax
        import jax.numpy as jnp
        self._jnp = jnp
        self._jax = jax
        self.grid, self.stencil = grid, stencil
        table = _memo_table(grid, stencil)
        p = grid.size
        self.k = K = len(seeds)
        self.slots = int(restart_slots)
        R = self.rows = K + self.slots
        self.n_nodes = N = int(num_nodes) if num_nodes is not None \
            else int(np.max(start) + 1)
        self.weighted = resolve_weighted(weighted, stencil)
        weights = (stencil.weight_array() if self.weighted
                   else np.ones(stencil.k))
        # the resident state representation: stacked integer crossing
        # counts (one row per ladder, broadcast from the shared start)
        A = np.broadcast_to(np.asarray(start, dtype=np.int64), (1, p))
        co0, cn0 = stacked_crossing_counts(grid, stencil, A, N,
                                           use_jax=counts_backend)
        per0 = np.zeros(N, dtype=np.float64)
        jsum0 = 0.0
        for j in range(stencil.k):      # host-exact start key
            per0 += weights[j] * cn0[0, :, j]
            jsum0 += float(weights[j]) * float(co0[0, j])
        self.start_key = (float(per0.max(initial=0.0)), float(jsum0))
        self._node = jnp.asarray(np.broadcast_to(A, (R, p)), jnp.int32)
        self._cn = jnp.asarray(
            np.broadcast_to(cn0, (R, N, stencil.k)), jnp.int32)
        self._keys = jnp.asarray(np.stack(
            [np.asarray(jax.random.PRNGKey(int(s)))
             for s in tuple(seeds) + (0,) * self.slots]))
        self._best_node = self._node
        self._best_jmax = jnp.full(R, self.start_key[0], jnp.float32)
        self._best_jsum = jnp.full(R, self.start_key[1], jnp.float32)
        self._done = jnp.zeros(R, dtype=bool)
        self._weights = jnp.asarray(weights, jnp.float32)
        self._out_valid = jnp.asarray(table.out_valid)
        self._out_tgt = jnp.asarray(table.out_tgt, jnp.int32)
        self._in_valid = jnp.asarray(table.in_valid)
        self._in_src = jnp.asarray(table.in_src, jnp.int32)
        self._alive = np.ones(K, dtype=bool)
        self.n_spawned = 0
        self.boundaries = 0

    # -- LadderEngine -------------------------------------------------------
    def run_temperature(self, temps: np.ndarray, sa_moves: int,
                        alive: np.ndarray, eps: np.ndarray,
                        budget: Optional[int] = None) -> BoundaryReport:
        """One jitted ``lax.scan`` over ``sa_moves`` moves for every row;
        ``temps``/``eps`` are full-height (K + slots) with restart
        multipliers already folded in by the driver.  Exactly one host
        round-trip: the small boundary report below."""
        assert budget is None, "budgeted runs delegate to the host engine"
        jnp = self._jnp
        self._alive = np.asarray(alive, dtype=bool).copy()
        live = np.zeros(self.rows, dtype=bool)
        live[:self.k] = self._alive[:self.k]
        live[self.k:self.k + self.n_spawned] = True
        (self._node, self._cn, self._keys, self._best_node, self._best_jmax,
         self._best_jsum, self._done, acc, jmax, jsum) = \
            _temperature_kernel(int(sa_moves))(
                self._node, self._cn, self._keys, self._best_node,
                self._best_jmax, self._best_jsum, self._done,
                jnp.asarray(live),
                jnp.asarray(np.asarray(temps, dtype=np.float32)),
                jnp.asarray(np.asarray(eps, dtype=np.float32)),
                self._weights, self._out_valid, self._out_tgt,
                self._in_valid, self._in_src)
        self.boundaries += 1
        return BoundaryReport(j_max=np.asarray(jmax, dtype=np.float64),
                              j_sum=np.asarray(jsum, dtype=np.float64),
                              accepted=np.asarray(acc, dtype=np.int64),
                              done=np.asarray(self._done))

    def states(self) -> np.ndarray:
        return np.asarray(self._node[:self.k], dtype=np.int64)

    def set_alive(self, alive: np.ndarray) -> None:
        self._alive = np.asarray(alive, dtype=bool).copy()

    # -- device-specific surface --------------------------------------------
    def row_state(self, r: int) -> np.ndarray:
        """One row's current assignment (host copy) — the leader fetch the
        restart spawn path needs."""
        return np.asarray(self._node[int(r)], dtype=np.int64)

    def counts(self) -> np.ndarray:
        """(rows, N, k) resident integer count state (host copy) — the
        conformance tests recount it from the assignments after every
        boundary."""
        return np.asarray(self._cn, dtype=np.int64)

    def spawn_restart(self, node: np.ndarray, seed: int) -> Optional[int]:
        """Fill the next free restart slot with ``node`` and a fresh rng
        key; returns the slot index, or None when the slots are exhausted
        (the controller's spawn loop then stops without deducting)."""
        if self.n_spawned >= self.slots:
            return None
        jax, jnp = self._jax, self._jnp
        r = self.k + self.n_spawned
        co, cn = stacked_crossing_counts(
            self.grid, self.stencil, node[None, :], self.n_nodes)
        w = np.asarray(self._weights, dtype=np.float64)
        per = (cn[0].astype(np.float64) * w[None, :]).sum(axis=1)
        jmax = float(per.max(initial=0.0))
        jsum = float((co[0].astype(np.float64) * w).sum())
        self._node = self._node.at[r].set(
            jnp.asarray(node, jnp.int32))
        self._cn = self._cn.at[r].set(jnp.asarray(cn[0], jnp.int32))
        self._keys = self._keys.at[r].set(
            jnp.asarray(np.asarray(jax.random.PRNGKey(int(seed)))))
        self._best_node = self._best_node.at[r].set(
            jnp.asarray(node, jnp.int32))
        self._best_jmax = self._best_jmax.at[r].set(jmax)
        self._best_jsum = self._best_jsum.at[r].set(jsum)
        self._done = self._done.at[r].set(False)
        self.n_spawned += 1
        return r - self.k

    def snapshot(self) -> dict:
        """End-of-run fetch (one transfer): current and best-seen
        assignments for every row, plus the resident count state."""
        return {
            "nodes": np.asarray(self._node, dtype=np.int64),
            "best_nodes": np.asarray(self._best_node, dtype=np.int64),
            "counts": np.asarray(self._cn, dtype=np.int64),
            "best_jmax": np.asarray(self._best_jmax, dtype=np.float64),
            "best_jsum": np.asarray(self._best_jsum, dtype=np.float64),
        }


class DevicePortfolioRefiner:
    """K-start annealing portfolio with device-resident ladders.

    Args mirror :class:`~repro.core.refine.portfolio.PortfolioRefiner`
    (``k``/``seed``/``seeds``, ``kill_factor``, ``polish_top``, the
    schedule parameters) plus the sharded engine's adaptive control
    (``restarts``/``retune``/``accept_band``/``retune_bounds``) and:

      kill_factor: defaults to ``None`` here (the host engines default to
        1.5): killing a ladder in a lock-step vmapped computation saves no
        device work — every row advances anyway — so the only effect would
        be discarding candidates.  Set it to run the kill rule regardless
        (the alive mask is honored exactly: killed ladders freeze).
      restart_slots: preallocated restart rows (static shapes — the
        temperature kernel compiles once).  ``"auto"`` sizes the pool at K
        when ``restarts`` is enabled, 0 otherwise.
      counts_backend: backend for the crossing-count state seeding and the
        end-of-run exact rekeying (``"auto"``/``"jax"``/``"numpy"`` — see
        :func:`~repro.core.refine.sharded.stacked_crossing_counts`).
      engine_factory: replace :class:`DeviceLadderEngine` (testing seam).
        A factory is an opaque object, so hand-built instances carrying
        one have no stable spelling and their plans are **uncacheable**
        (``as_stage().cacheable`` is False — pinned by
        ``tests/test_plan.py``).

    ``max_swaps`` budgets and ``pinned`` masks couple ladders at move
    granularity on the host; such runs (and jax-less environments)
    delegate to the single-process portfolio with the same seeds and
    schedule, so every spelling works everywhere.
    """

    def __init__(self, k: int = 8, seed: int = 0,
                 seeds: Optional[Sequence[int]] = None,
                 kill_factor: Optional[float] = None,
                 polish_top: Optional[int] = 3,
                 restarts=None, retune: bool = False,
                 accept_band: Tuple[float, float] = (0.05, 0.5),
                 retune_bounds: Tuple[float, float] = (0.25, 4.0),
                 restart_slots="auto", counts_backend="auto",
                 objectives: Sequence[str] = ("j_sum", "j_max"),
                 rounds: int = 4, policy: str = "first", max_passes: int = 8,
                 weighted="auto", tol: float = 1e-12,
                 max_partners: int = 32, engine: str = "batch",
                 temperatures: Sequence[float] = (2.0, 1.0, 0.5, 0.25),
                 sa_moves: int = 200, max_swaps: Optional[int] = None,
                 engine_factory=None):
        if restarts not in (None, "auto") and int(restarts) < 0:
            raise ValueError('restarts must be None, "auto", or an int >= 0')
        lo, hi = float(accept_band[0]), float(accept_band[1])
        if not (0.0 <= lo <= hi <= 1.0):
            raise ValueError("accept_band must satisfy 0 <= low <= high <= 1")
        blo, bhi = float(retune_bounds[0]), float(retune_bounds[1])
        if not (0.0 < blo <= 1.0 <= bhi):
            raise ValueError("retune_bounds must bracket 1.0 "
                             "(0 < min <= 1 <= max)")
        if restart_slots != "auto" and int(restart_slots) < 0:
            raise ValueError('restart_slots must be "auto" or an int >= 0')
        if counts_backend not in (True, False, "auto", "jax", "numpy"):
            raise ValueError('counts_backend must be True, False, "auto", '
                             '"jax", or "numpy"')
        self.portfolio = PortfolioRefiner(
            k=k, seed=seed, seeds=seeds, kill_factor=kill_factor,
            polish_top=polish_top, objectives=objectives, rounds=rounds,
            policy=policy, max_passes=max_passes, weighted=weighted, tol=tol,
            max_partners=max_partners, engine=engine,
            temperatures=temperatures, sa_moves=sa_moves, max_swaps=None)
        self.schedule = self.portfolio.schedule
        self.seeds = self.portfolio.seeds
        self.k = self.portfolio.k
        self.kill_factor = self.portfolio.kill_factor
        self.restarts = restarts if restarts in (None, "auto") \
            else int(restarts)
        self.retune = bool(retune)
        self.accept_band = (lo, hi)
        self.retune_bounds = (blo, bhi)
        self.restart_slots = restart_slots if restart_slots == "auto" \
            else int(restart_slots)
        self.counts_backend = counts_backend
        if max_swaps is not None and int(max_swaps) < 0:
            raise ValueError("max_swaps must be >= 0 (or None)")
        self.max_swaps = None if max_swaps is None else int(max_swaps)
        self.engine_factory = engine_factory

    def as_stage(self, budget: Optional[int] = None):
        """Uniform :class:`~repro.core.refine.stage.RefineStage` adapter
        (``budget`` caps this stage's accepted swaps)."""
        from .stage import RefineStage
        return RefineStage(self, budget=budget, prefix="device")

    def config(self) -> dict:
        """Full constructor configuration — the stage layer's canonical
        cache identity for hand-built refiners.  ``engine_factory`` is an
        opaque object when set, which (correctly) marks the stage
        uncacheable."""
        cfg = self.portfolio.config()
        cfg.update({"restarts": self.restarts, "retune": self.retune,
                    "accept_band": self.accept_band,
                    "retune_bounds": self.retune_bounds,
                    "restart_slots": self.restart_slots,
                    "counts_backend": self.counts_backend,
                    "max_swaps": self.max_swaps,
                    "engine_factory": self.engine_factory})
        return cfg

    def _resolved_slots(self) -> int:
        if self.restarts is None:
            return 0
        if self.restart_slots == "auto":
            return self.k
        return int(self.restart_slots)

    # -- delegation ---------------------------------------------------------
    def _delegate(self, reason: str, grid, stencil, node_of_pos, num_nodes,
                  pinned) -> RefineResult:
        delegate = copy.copy(self.portfolio)
        delegate.max_swaps = self.max_swaps
        res = delegate.refine(grid, stencil, node_of_pos, num_nodes,
                              pinned=pinned)
        res.stats.update({"backend": "host-fallback", "delegated": reason})
        return res

    # -- driver -------------------------------------------------------------
    def refine(self, grid: CartGrid, stencil: Stencil,
               node_of_pos: np.ndarray,
               num_nodes: Optional[int] = None,
               pinned: Optional[np.ndarray] = None) -> RefineResult:
        if self.max_swaps is not None:
            return self._delegate("max_swaps", grid, stencil, node_of_pos,
                                  num_nodes, pinned)
        if pinned is not None:
            return self._delegate("pinned", grid, stencil, node_of_pos,
                                  num_nodes, pinned)
        if not jax_ready():         # pragma: no cover - jax in test image
            warnings.warn("jax unavailable: device portfolio delegating to "
                          "the single-process host engine", UserWarning,
                          stacklevel=2)
            return self._delegate("no-jax", grid, stencil, node_of_pos,
                                  num_nodes, pinned)
        t0 = time.perf_counter()
        sched = self.schedule
        K = self.k
        cur = np.asarray(node_of_pos, dtype=np.int64).copy()
        initial = IncrementalCost(grid, stencil, cur, num_nodes=num_nodes,
                                  weighted=sched.weighted).cost()
        best, best_key = cur.copy(), (initial.j_max, initial.j_sum)

        def consider(candidate: np.ndarray, key: Tuple[float, float]):
            nonlocal best, best_key
            if key < best_key:
                best, best_key = candidate.copy(), key

        # 1. shared deterministic prefix (seed-independent, run once)
        cur, swaps, passes = sched.run_rounds(grid, stencil, cur, num_nodes,
                                              consider, max_swaps=None)
        t_rounds = time.perf_counter() - t0

        # 2. device ladders under the shared boundary protocol
        n_nodes = int(num_nodes) if num_nodes is not None \
            else int(cur.max() + 1)
        weights = (stencil.weight_array()
                   if resolve_weighted(sched.weighted, stencil)
                   else np.ones(stencil.k))
        t_scale = float(np.mean(weights))
        slots = self._resolved_slots()
        factory = self.engine_factory or DeviceLadderEngine
        eng = factory(grid, stencil, cur, self.seeds, num_nodes=n_nodes,
                      weighted=sched.weighted, restart_slots=slots,
                      counts_backend=self.counts_backend)
        jmax0, jsum0 = eng.start_key
        eps0 = float(1.0 / (1.0 + abs(jsum0)))
        n_temps = len(sched.temperatures)
        ctrl = BoundaryController(
            k=K, kill_factor=self.kill_factor,
            start_keys=np.asarray([jmax0, jsum0]),
            restarts=self.restarts, retune=self.retune,
            accept_band=self.accept_band, retune_bounds=self.retune_bounds,
            sa_moves=sched.sa_moves, n_temps=n_temps,
            seeder=RestartSeeder(self.seeds))
        restarts: List[dict] = []
        accepted = 0
        rows = K + slots
        cur_keys = np.broadcast_to(np.asarray([jmax0, jsum0]), (K, 2)).copy()

        def leader() -> Tuple[np.ndarray, float]:
            """Current portfolio leader (lexicographic best current key,
            originals then restarts, lowest index wins ties) — one row
            fetched from the device."""
            cand = [((cur_keys[i, 0], cur_keys[i, 1], 0, i), i)
                    for i in range(K) if ctrl.alive[i]]
            cand += [((r["j_max"], r["j_sum"], 1, j), K + r["slot"])
                     for j, r in enumerate(restarts)]
            key, row = min(cand, key=lambda c: c[0])
            return eng.row_state(row), float(key[1])

        def spawn(seed: int) -> bool:
            node, lead_j_sum = leader()
            slot = eng.spawn_restart(node, seed)
            if slot is None:
                return False
            restarts.append({
                "slot": slot, "seed": seed, "done": False,
                "eps": float(1.0 / (1.0 + abs(lead_j_sum))),
                "t_mult": 1.0,
                "j_max": math.inf, "j_sum": math.inf,
                "accepted_last": 0,
            })
            return True

        for ti, T0 in enumerate(sched.temperatures):
            T = max(T0 * t_scale, 1e-12)
            temps = np.full(rows, T)
            eps = np.full(rows, eps0)
            for r in restarts:
                temps[K + r["slot"]] = max(T0 * t_scale * r["t_mult"], 1e-12)
                eps[K + r["slot"]] = r["eps"]
            rep = eng.run_temperature(temps, sched.sa_moves, ctrl.alive, eps)
            accepted += int(rep.accepted[:K].sum())
            cur_keys = np.stack([rep.j_max[:K], rep.j_sum[:K]], axis=1)
            for r in restarts:
                row = K + r["slot"]
                accepted += int(rep.accepted[row])
                r.update(j_max=float(rep.j_max[row]),
                         j_sum=float(rep.j_sum[row]),
                         done=bool(rep.done[row]),
                         accepted_last=int(rep.accepted[row]))
            # the shared boundary protocol, one host round-trip per
            # temperature: best-seen, kill (pushed back as the alive
            # mask), pool accounting / retune / restart spawn
            ctrl.update_best(cur_keys)
            newly_killed = ctrl.kill()
            eng.set_alive(ctrl.alive)
            ctrl.adapt(ti, newly_killed, restarts, spawn)
        t_ladders = time.perf_counter() - t0 - t_rounds

        # 3. survivors: end states AND device-tracked best-seen states are
        # candidates; exact host keys come from the shared integer counts
        # representation, then the single-process selection + polish
        snap = eng.snapshot()
        alive_rows = [i for i in range(K) if ctrl.alive[i]]
        slot_rows = [K + r["slot"] for r in restarts]
        pick = alive_rows + slot_rows
        cand = np.concatenate([snap["nodes"][pick], snap["best_nodes"][pick]])
        counts = stacked_crossing_counts(grid, stencil, cand, n_nodes,
                                         use_jax=self.counts_backend)
        cpc = PortfolioCost(grid, stencil, cand, num_nodes=n_nodes,
                            weighted=sched.weighted,
                            table=_memo_table(grid, stencil), counts=counts)
        swaps, passes, polish_order = self.portfolio._polish_survivors(
            grid, stencil, num_nodes, consider, cand, cpc.j_max(),
            cpc.j_sum(), np.ones(cand.shape[0], dtype=bool), swaps, passes)

        final = IncrementalCost(grid, stencil, best, num_nodes=num_nodes,
                                weighted=sched.weighted).cost()
        wall = time.perf_counter() - t0
        stats = {
            "k": self.k,
            "seeds": self.seeds,
            "backend": f"device[{_backend_name()}]",
            "counts_backend": self.counts_backend,
            "boundaries": eng.boundaries,
            "proposals": rows * n_temps * sched.sa_moves,
            "sa_accepted": accepted,
            "killed": ctrl.killed,
            "restarted": len(restarts),
            "restart_slots": slots,
            "restart_seeds": [r["seed"] for r in restarts],
            "restart_t_mults": [r["t_mult"] for r in restarts],
            "pool_moves_left": ctrl.pool_moves,
            "polished": len(polish_order),
            "ladder_keys": [(float(j), float(s)) for j, s in cur_keys],
            "t_rounds_s": t_rounds,
            "t_ladders_s": t_ladders,
            "t_polish_s": wall - t_rounds - t_ladders,
        }
        return RefineResult(assignment=best, initial=initial, final=final,
                            swaps=swaps, passes=passes, wall_time_s=wall,
                            stats=stats)


def _backend_name() -> str:
    try:
        import jax
        return jax.default_backend()
    except Exception:               # pragma: no cover - jax in test image
        return "none"
