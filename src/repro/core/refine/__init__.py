"""Local-search refinement of process-to-node mappings.

The paper's mappers (§V) are one-shot constructions; related work
(Glantz/Meyerhenke/Noe; Schulz/Träff "Better Process Mapping and Sparse
Quadratic Assignment") shows that cheap pairwise-swap local search on top of
a good initial mapping recovers most of the remaining J_sum/J_max gap.  This
package supplies that pass: :class:`SwapRefiner` walks the partition
boundary proposing node-exchanging swaps scored by the O(k) incremental
engine (:class:`~repro.core.cost_delta.IncrementalCost`), and
:class:`RefinedMapper` packages it as a drop-in :class:`~repro.core.mapping.Mapper`
so ``get_mapper("refined:<base>")`` upgrades any registered algorithm.
"""
from .swap import RefineResult, SwapRefiner, refine_assignment
from .mapper import RefinedMapper

__all__ = ["SwapRefiner", "RefineResult", "refine_assignment", "RefinedMapper"]
