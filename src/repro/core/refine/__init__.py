"""Local-search refinement of process-to-node mappings.

The paper's mappers (§V) are one-shot constructions; related work
(Glantz/Meyerhenke/Noe; Schulz/Träff "Better Process Mapping and Sparse
Quadratic Assignment"; Faraj/van der Grinten/Meyerhenke "High-Quality
Hierarchical Process Mapping") shows that cheap local search on top of a
good initial mapping recovers most of the remaining J_sum/J_max gap.  This
package supplies that pass in three tiers:

* :class:`SwapRefiner` — boundary swap local search scored by the batched
  numpy engine (:meth:`~repro.core.cost_delta.IncrementalCost.batch_swap_deltas`):
  the whole candidate frontier is evaluated per sweep in a handful of
  vectorized passes (``engine="scalar"`` keeps the PR-1 reference loop).
* :class:`ScheduledRefiner` — alternates j_sum/j_max SwapRefiner phases
  (optionally with a simulated-annealing temperature ladder) so bottleneck
  relief doesn't stall at the first J_max plateau.
* :class:`PortfolioRefiner` — K independent annealing starts advanced as
  one batched computation (:class:`~repro.core.cost_delta.PortfolioCost`),
  with early-kill of dominated ladders; never worse than a single
  ``annealed`` ladder on the same seed.
* :class:`ShardedPortfolioRefiner` — the portfolio partitioned into seed
  blocks run in parallel worker processes (K into the hundreds),
  bit-identical to the single-process portfolio for any shard count, with
  optional adaptive control: killed ladders' unspent budgets fund restarts
  from the leader, and restart temperatures retune from accept rates.
* :class:`DevicePortfolioRefiner` — the portfolio's K ladders resident on
  the accelerator (:mod:`repro.core.refine.device`): vmapped Metropolis
  moves over stacked integer crossing-count state, one ``lax.scan`` per
  temperature, one host round-trip per boundary.  Scales to K=1024; the
  shared boundary protocol lives in :mod:`repro.core.refine.engine`
  (:class:`LadderEngine` / :class:`BoundaryController`), so serial,
  sharded, and device drivers run identical kill/restart/retune rules.
* :class:`RefinedMapper` — packages any refiner as a drop-in
  :class:`~repro.core.mapping.Mapper`, so ``get_mapper("refined:<base>")``,
  ``"refined2:<base>"``, ``"annealed:<base>"`` and ``"portfolio:<base>"``
  (with bracket options, e.g. ``"portfolio[k=8]:<base>"``) upgrade any
  registered algorithm (see :mod:`repro.core.mapping` for the
  name-resolution contract).

Every refiner also exposes ``as_stage(budget=...)`` — the uniform
:class:`~repro.core.refine.stage.RefineStage` adapter the plan API
(:mod:`repro.core.plan`) composes into :class:`MappingPlan` chains, with
an optional per-stage accepted-swap budget.
"""
from .swap import RefineResult, SwapRefiner, refine_assignment
from .schedule import ScheduledRefiner
from .engine import (BoundaryController, BoundaryReport, LadderEngine,
                     RestartSeeder, SerialLadderEngine)
from .portfolio import PortfolioRefiner, run_temperature
from .sharded import ShardedPortfolioRefiner, stacked_crossing_counts
from .device import DeviceLadderEngine, DevicePortfolioRefiner, jax_ready
from .hier import HierRefiner, MaskedGrid, hier_subtree_cache
from .stage import BaseStage, RefineStage, Stage, StageResult
from .mapper import RefinedMapper

__all__ = ["SwapRefiner", "ScheduledRefiner", "PortfolioRefiner",
           "ShardedPortfolioRefiner", "DevicePortfolioRefiner",
           "HierRefiner", "MaskedGrid", "hier_subtree_cache",
           "run_temperature", "stacked_crossing_counts",
           "LadderEngine", "SerialLadderEngine", "DeviceLadderEngine",
           "BoundaryController", "BoundaryReport", "RestartSeeder",
           "jax_ready",
           "RefineResult", "refine_assignment", "RefinedMapper",
           "Stage", "StageResult", "BaseStage", "RefineStage"]
