"""`refined:<base>` — any registered mapper plus swap refinement.

The wrapper is a two-stage mapping plan in :class:`Mapper` clothing: a
:class:`~repro.core.refine.stage.BaseStage` runs the base algorithm (with
the optional inapplicability fallback), a
:class:`~repro.core.refine.stage.RefineStage` improves the node-of-position
assignment with :class:`SwapRefiner` (or any object with the same
``refine(grid, stencil, node_of_pos, num_nodes)`` signature, e.g.
:class:`~repro.core.refine.schedule.ScheduledRefiner`), and the wrapper
rebuilds a rank->coordinate bijection that realises the refined assignment
while respecting the blocked scheduler allocation: node i's ranks take node
i's grid positions in row-major position order (same convention as
``remap.device_layout(intra_order="rowmajor")``).

:func:`~repro.core.plan.parse_plan` builds the same stages without the
Mapper wrapper; ``get_mapper`` composes nested RefinedMappers from a parsed
plan, so both spellings execute identical stage chains.

Usage::

    RefinedMapper("hyperplane")                           # refined:hyperplane
    RefinedMapper("kdtree", refiner=ScheduledRefiner(),
                  prefix="refined2")                      # refined2:kdtree
"""
from __future__ import annotations

from typing import Optional, Sequence, Union

import numpy as np

from ..grid import CartGrid
from ..stencil import Stencil
from ..mapping.base import Mapper
from .stage import BaseStage, RefineStage
from .swap import RefineResult, SwapRefiner

__all__ = ["RefinedMapper"]


class RefinedMapper(Mapper):
    """Wrap ``base`` (a Mapper instance or registered name) with local search.

    Keyword arguments are forwarded to :class:`SwapRefiner` unless an
    explicit ``refiner`` is given; ``prefix`` sets the registry spelling the
    wrapper answers to (``refined`` for the plain swap pass, ``refined2`` /
    ``annealed`` for the scheduled engines, ``portfolio`` for the K-start
    batched annealing portfolio).  Raises whatever the base raises
    (``MapperInapplicable`` propagates so callers can fall back) — unless a
    ``fallback`` base is given, in which case the wrapper starts refinement
    from the fallback's assignment instead (used by the elastic mesh path,
    where homogeneous-only bases like Nodecart would otherwise leave a
    ragged pod entirely unrefined).  ``budget`` caps the refinement stage's
    accepted swaps (a per-stage plan budget).
    """

    requires_homogeneous = False

    def __init__(self, base: Union[Mapper, str] = "hyperplane",
                 refiner=None, prefix: str = "refined",
                 fallback: Union[Mapper, str, None] = None,
                 budget: Optional[int] = None, **refiner_kwargs):
        if isinstance(base, str):
            from ..mapping import get_mapper
            base = get_mapper(base)
        if isinstance(fallback, str):
            from ..mapping import get_mapper
            fallback = get_mapper(fallback)
        if refiner is not None and refiner_kwargs:
            raise ValueError("pass either refiner or refiner kwargs, not both")
        self.base = base
        self.fallback = fallback
        self.refiner = refiner if refiner is not None \
            else SwapRefiner(**refiner_kwargs)
        self.base_stage = BaseStage(base, fallback=fallback)
        self.refine_stage = RefineStage(self.refiner, budget=budget,
                                        prefix=prefix)
        self.name = f"{prefix}:{base.name}"
        self.last_result: Optional[RefineResult] = None

    @property
    def stages(self):
        """The plan this mapper executes, as stage objects."""
        return (self.base_stage, self.refine_stage)

    def coords(self, grid: CartGrid, stencil: Stencil,
               node_sizes: Sequence[int]) -> np.ndarray:
        sr = self.base_stage.run(grid, stencil, node_sizes)
        sr = self.refine_stage.run(grid, stencil, node_sizes, sr.assignment)
        self.last_result = sr.result
        refined = sr.assignment
        # blocked rank order is already node-sorted, so a stable node-sort of
        # positions lines rank r up with the r-th (node, position) pair.
        # (per-node cardinality preservation is asserted by RefineStage.)
        pos_by_node = np.argsort(refined, kind="stable")
        return np.stack(np.unravel_index(pos_by_node, grid.dims), axis=1)
