"""`refined:<base>` — any registered mapper plus swap refinement.

The wrapper runs the base algorithm, refines its node-of-position
assignment with :class:`SwapRefiner` (or any object with the same
``refine(grid, stencil, node_of_pos, num_nodes)`` signature, e.g.
:class:`~repro.core.refine.schedule.ScheduledRefiner`), then rebuilds a
rank->coordinate bijection that realises the refined assignment while
respecting the blocked scheduler allocation: node i's ranks take node i's
grid positions in row-major position order (same convention as
``remap.device_layout(intra_order="rowmajor")``).

Usage::

    RefinedMapper("hyperplane")                           # refined:hyperplane
    RefinedMapper("kdtree", refiner=ScheduledRefiner(),
                  prefix="refined2")                      # refined2:kdtree
"""
from __future__ import annotations

from typing import Optional, Sequence, Union

import numpy as np

from ..cost import node_of_rank_blocked
from ..grid import CartGrid
from ..stencil import Stencil
from ..mapping.base import Mapper, MapperInapplicable
from .swap import RefineResult, SwapRefiner

__all__ = ["RefinedMapper"]


class RefinedMapper(Mapper):
    """Wrap ``base`` (a Mapper instance or registered name) with local search.

    Keyword arguments are forwarded to :class:`SwapRefiner` unless an
    explicit ``refiner`` is given; ``prefix`` sets the registry spelling the
    wrapper answers to (``refined`` for the plain swap pass, ``refined2`` /
    ``annealed`` for the scheduled engines).  Raises whatever the base
    raises (``MapperInapplicable`` propagates so callers can fall back) —
    unless a ``fallback`` base is given, in which case the wrapper starts
    refinement from the fallback's assignment instead (used by the elastic
    mesh path, where homogeneous-only bases like Nodecart would otherwise
    leave a ragged pod entirely unrefined).
    """

    requires_homogeneous = False

    def __init__(self, base: Union[Mapper, str] = "hyperplane",
                 refiner=None, prefix: str = "refined",
                 fallback: Union[Mapper, str, None] = None, **refiner_kwargs):
        if isinstance(base, str):
            from ..mapping import get_mapper
            base = get_mapper(base)
        if isinstance(fallback, str):
            from ..mapping import get_mapper
            fallback = get_mapper(fallback)
        if refiner is not None and refiner_kwargs:
            raise ValueError("pass either refiner or refiner kwargs, not both")
        self.base = base
        self.fallback = fallback
        self.refiner = refiner if refiner is not None \
            else SwapRefiner(**refiner_kwargs)
        self.name = f"{prefix}:{base.name}"
        self.last_result: Optional[RefineResult] = None

    def coords(self, grid: CartGrid, stencil: Stencil,
               node_sizes: Sequence[int]) -> np.ndarray:
        try:
            node_of_pos = self.base.assignment(grid, stencil, node_sizes)
        except MapperInapplicable:
            if self.fallback is None:
                raise
            node_of_pos = self.fallback.assignment(grid, stencil, node_sizes)
        result = self.refiner.refine(grid, stencil, node_of_pos,
                                     num_nodes=len(node_sizes))
        self.last_result = result
        refined = result.assignment
        # blocked rank order is already node-sorted, so a stable node-sort of
        # positions lines rank r up with the r-th (node, position) pair.
        owner_of_rank = node_of_rank_blocked(node_sizes)
        if not np.array_equal(np.bincount(refined, minlength=len(node_sizes)),
                              np.bincount(owner_of_rank,
                                          minlength=len(node_sizes))):
            raise AssertionError("refinement changed per-node cardinalities")
        pos_by_node = np.argsort(refined, kind="stable")
        return np.stack(np.unravel_index(pos_by_node, grid.dims), axis=1)
