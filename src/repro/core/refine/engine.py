"""The shared boundary protocol of the annealing-portfolio engines.

Every portfolio engine — single-process
(:class:`~repro.core.refine.portfolio.PortfolioRefiner`), process-sharded
(:class:`~repro.core.refine.sharded.ShardedPortfolioRefiner`), and
device-resident (:class:`~repro.core.refine.device.DevicePortfolioRefiner`)
— advances K simulated-annealing ladders one *temperature* at a time and
runs the same coordinator rules at every temperature boundary:

1. **best-seen update** — each ladder's lexicographic best ``(J_max,
   J_sum)`` key over all boundaries so far;
2. **early-kill** — a ladder (never ladder 0) whose best-seen J_max
   exceeds ``kill_factor`` times the alive leader's is killed, and the
   alive mask is monotone non-increasing from then on;
3. **adaptive control** (optional) — killed ladders return their unspent
   proposal budget to a pool that funds *restart ladders* seeded fresh
   from the current leader, and each restart's temperature multiplier is
   retuned from its observed accept rate.

This module is that protocol, factored once:

* :class:`BoundaryReport` — what an engine hands back per temperature
  (per-ladder keys, accepted counts, done flags);
* :class:`LadderEngine` — the engine interface: resident ladder state in,
  one :meth:`~LadderEngine.run_temperature` call per temperature out.
  :class:`SerialLadderEngine` wraps the numpy kernel
  (:func:`~repro.core.refine.portfolio.run_temperature`) and preserves its
  draw order bit for bit; the sharded engine dispatches the same kernel
  per seed block; the device engine replays the protocol with
  ``jax``-resident state;
* :class:`BoundaryController` — rules 1-3 verbatim (the loops formerly
  duplicated between the portfolio and sharded coordinators), engine
  agnostic;
* :class:`RestartSeeder` — fresh restart seeds, guarded against colliding
  with user-supplied explicit ``seeds=`` lists (warn + shift, like the
  portfolio's duplicate-seed dedupe).

Float arithmetic order inside the controller is unchanged from the PR-3/5
coordinators, so the refactor is bit-invisible to the engines' pinned
bit-identity tests.
"""
from __future__ import annotations

import abc
import math
import warnings
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from ..cost_delta import PortfolioCost
from ..grid import CartGrid
from ..stencil import Stencil

__all__ = ["BoundaryReport", "LadderEngine", "SerialLadderEngine",
           "BoundaryController", "RestartSeeder"]


@dataclass
class BoundaryReport:
    """One engine's per-temperature result: exact per-ladder keys (rows in
    engine order — the K originals first, any restart rows after), the
    accepted-proposal counts of the temperature just run, and the sticky
    done flags (boundary shrank below two positions)."""

    j_max: np.ndarray      # (rows,) float
    j_sum: np.ndarray      # (rows,) float
    accepted: np.ndarray   # (rows,) int
    done: np.ndarray       # (rows,) bool


class LadderEngine(abc.ABC):
    """K resident annealing ladders advanced one temperature per call.

    The contract every engine implements (and
    ``tests/test_device_portfolio.py`` cross-checks): ladder state lives in
    the engine between calls, :meth:`run_temperature` advances every alive,
    not-done ladder through one temperature of ``sa_moves`` Metropolis
    proposals and reports exact keys at the boundary, and
    :meth:`set_alive`'s mask (the kill rule's output) is monotone — a
    ladder marked dead stops proposing and its state freezes.
    """

    #: engine spelling, for stats
    name: str = "engine"

    @abc.abstractmethod
    def run_temperature(self, temps: np.ndarray, sa_moves: int,
                        alive: np.ndarray, eps: np.ndarray,
                        budget: Optional[int] = None) -> BoundaryReport:
        """Advance one temperature (``temps``/``eps`` per-ladder absolute
        values, schedule scale folded in) and report the boundary."""

    @abc.abstractmethod
    def states(self) -> np.ndarray:
        """(K, p) current ladder assignments (host arrays)."""

    def set_alive(self, alive: np.ndarray) -> None:
        """Push the kill rule's alive mask (monotone non-increasing)."""


class SerialLadderEngine(LadderEngine):
    """The host engine: :class:`~repro.core.cost_delta.PortfolioCost` state
    plus the numpy ladder kernel
    (:func:`~repro.core.refine.portfolio.run_temperature`), preserving the
    historical rng draw order bit for bit — this class is a seam, not a
    reimplementation."""

    name = "serial"

    def __init__(self, grid: CartGrid, stencil: Stencil, start: np.ndarray,
                 seeds: Sequence[int], num_nodes: Optional[int] = None,
                 weighted=False, allowed: Optional[np.ndarray] = None):
        K = len(seeds)
        self.pc = PortfolioCost(grid, stencil,
                                np.broadcast_to(start, (K, grid.size)),
                                num_nodes=num_nodes, weighted=weighted)
        self.rngs = [np.random.default_rng(s) for s in seeds]
        self.done = np.zeros(K, dtype=bool)
        self.allowed = allowed

    def run_temperature(self, temps: np.ndarray, sa_moves: int,
                        alive: np.ndarray, eps: np.ndarray,
                        budget: Optional[int] = None) -> BoundaryReport:
        from .portfolio import run_temperature
        accepted = run_temperature(self.pc, self.rngs, alive, self.done,
                                   temps, sa_moves, eps, budget=budget,
                                   allowed=self.allowed)
        return BoundaryReport(j_max=self.pc.j_max(), j_sum=self.pc.j_sum(),
                              accepted=accepted, done=self.done.copy())

    def states(self) -> np.ndarray:
        return self.pc.node


class RestartSeeder:
    """Fresh, deterministic restart-ladder seeds: ``max(seeds) + 1``
    counting upward.  With the default arithmetic that can never collide
    with an original ladder's seed (every original is <= max), but the
    stream is guarded anyway: any candidate that *would* land on a
    user-supplied seed — e.g. a caller-chosen ``start`` base threaded into
    a sparse explicit ``seeds=`` list — is skipped with a warning, the same
    warn-and-shift contract as the portfolio's duplicate-seed dedupe, so a
    restart ladder never replays an original's trajectory."""

    def __init__(self, seeds: Sequence[int], start: Optional[int] = None):
        self._orig = frozenset(int(s) for s in seeds)
        if not self._orig:
            raise ValueError("restart seeding needs at least one original")
        self._next = int(max(self._orig) + 1 if start is None else start)

    def __call__(self) -> int:
        s = self._next
        shifted = 0
        while s in self._orig:
            s += 1
            shifted += 1
        if shifted:
            warnings.warn(
                f"restart seed {self._next} collides with an explicit "
                f"portfolio seed; shifted to {s} so the restart ladder "
                "cannot replay an original trajectory", UserWarning,
                stacklevel=2)
        self._next = s + 1
        return s


class BoundaryController:
    """The coordinator side of the boundary protocol (rules 1-3 of the
    module docstring), shared verbatim by the serial, sharded, and device
    drivers.

    ``alive``/``best_seen``/``killed``/``pool_moves`` are the live
    bookkeeping the drivers read back; ``restarts=None`` disables rule 3
    entirely (the single-process portfolio's historical behavior).
    ``start_keys`` is the (K, 2) per-ladder ``(J_max, J_sum)`` of the
    shared start state.
    """

    def __init__(self, k: int, kill_factor: Optional[float],
                 start_keys: np.ndarray, restarts=None, retune: bool = False,
                 accept_band: Tuple[float, float] = (0.05, 0.5),
                 retune_bounds: Tuple[float, float] = (0.25, 4.0),
                 sa_moves: int = 0, n_temps: int = 0,
                 seeder: Optional[RestartSeeder] = None):
        self.k = int(k)
        self.kill_factor = kill_factor
        self.alive = np.ones(self.k, dtype=bool)
        self.best_seen = np.array(np.broadcast_to(
            np.asarray(start_keys, dtype=np.float64), (self.k, 2)))
        self.restarts = restarts
        self.retune = bool(retune)
        self.accept_band = accept_band
        self.retune_bounds = retune_bounds
        self.sa_moves = int(sa_moves)
        self.n_temps = int(n_temps)
        self.seeder = seeder
        self.killed = 0
        self.pool_moves = 0

    # -- rule 1: best-seen update -------------------------------------------
    def update_best(self, cur_keys: np.ndarray) -> None:
        for i in range(self.k):
            if tuple(cur_keys[i]) < tuple(self.best_seen[i]):
                self.best_seen[i] = cur_keys[i]

    # -- rule 2: early-kill (ladder 0 exempt; alive is monotone) ------------
    def kill(self) -> int:
        newly_killed = 0
        if self.kill_factor is not None:
            lead = self.best_seen[self.alive, 0].min()
            for i in range(1, self.k):
                if self.alive[i] \
                        and self.best_seen[i, 0] > self.kill_factor * lead:
                    self.alive[i] = False
                    self.killed += 1
                    newly_killed += 1
        return newly_killed

    # -- rule 3: pool accounting + retune + restart spawn -------------------
    def adapt(self, ti: int, newly_killed: int, restarts: List[dict],
              spawn: Callable[[int], bool]) -> None:
        """Run the adaptive boundary rules after temperature index ``ti``:
        fund the pool with the newly killed ladders' unspent budgets,
        retune every live restart's temperature multiplier from its accept
        rate, then spawn as many fresh restarts as the pool affords.
        ``restarts`` is the driver's bookkeeping (dicts with ``done`` /
        ``accepted_last`` / ``t_mult``); ``spawn(seed)`` creates one
        restart ladder from the current leader and returns False when the
        engine is out of capacity (nothing is deducted for a refused
        spawn)."""
        rem = self.n_temps - ti - 1
        if self.restarts is None or rem <= 0:
            return
        self.pool_moves += newly_killed * rem * self.sa_moves
        if self.retune:
            lo, hi = self.accept_band
            blo, bhi = self.retune_bounds
            for r in restarts:
                if r["done"]:
                    continue
                rate = r["accepted_last"] / max(1, self.sa_moves)
                if rate < lo:
                    r["t_mult"] = min(r["t_mult"] * 2.0, bhi)
                elif rate > hi:
                    r["t_mult"] = max(r["t_mult"] * 0.5, blo)
        cost = rem * self.sa_moves
        cap = math.inf if self.restarts == "auto" \
            else int(self.restarts) - len(restarts)
        # cost == 0 (sa_moves=0 schedules) would spawn forever: a free
        # restart buys zero proposals, so spawn none
        while cost > 0 and self.pool_moves >= cost and cap > 0:
            if not spawn(self.seeder()):
                break
            self.pool_moves -= cost
            cap -= 1
