"""Cartesian process grids (paper §II).

A :class:`CartGrid` is the virtual topology the application requests:
``p`` processes arranged in a ``d``-dimensional grid with dimension sizes
``dims``.  Ranks are assigned to grid positions in row-major order (the
paper's w.l.o.g. convention), i.e. rank ``r`` sits at
``np.unravel_index(r, dims)``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Iterable, Sequence, Tuple

import numpy as np

__all__ = ["CartGrid", "dims_create"]


@dataclass(frozen=True)
class CartGrid:
    """A d-dimensional Cartesian grid of processes."""

    dims: Tuple[int, ...]
    periodic: Tuple[bool, ...] = field(default=None)  # type: ignore[assignment]

    def __post_init__(self):
        dims = tuple(int(d) for d in self.dims)
        if not dims or any(d <= 0 for d in dims):
            raise ValueError(f"grid dims must be positive, got {self.dims}")
        object.__setattr__(self, "dims", dims)
        per = self.periodic
        if per is None:
            per = (False,) * len(dims)
        per = tuple(bool(x) for x in per)
        if len(per) != len(dims):
            raise ValueError("periodic must match dims rank")
        object.__setattr__(self, "periodic", per)

    # -- basic geometry ----------------------------------------------------
    @property
    def ndim(self) -> int:
        return len(self.dims)

    @property
    def size(self) -> int:
        return int(math.prod(self.dims))

    def coords(self) -> np.ndarray:
        """(p, d) int array: row-major coordinates of every rank."""
        idx = np.arange(self.size)
        return np.stack(np.unravel_index(idx, self.dims), axis=1)

    def rank_of(self, coord: Sequence[int]) -> int:
        return int(np.ravel_multi_index(tuple(int(c) for c in coord), self.dims))

    def coord_of(self, rank: int) -> Tuple[int, ...]:
        if not 0 <= rank < self.size:
            raise ValueError(f"rank {rank} out of range for grid of size {self.size}")
        return tuple(int(c) for c in np.unravel_index(rank, self.dims))

    # -- stencil neighbourhoods ---------------------------------------------
    def shift_ranks(self, offset: Sequence[int]) -> Tuple[np.ndarray, np.ndarray]:
        """Vectorized neighbour lookup for one stencil offset.

        Returns ``(valid_mask, target_rank)`` over all source ranks, applying
        periodic wrap on axes marked periodic and truncating at the boundary
        otherwise (MPI_PROC_NULL semantics).
        """
        c = self.coords()
        t = c + np.asarray(offset, dtype=np.int64)[None, :]
        valid = np.ones(self.size, dtype=bool)
        for ax, (d, per) in enumerate(zip(self.dims, self.periodic)):
            if per:
                t[:, ax] %= d
            else:
                valid &= (t[:, ax] >= 0) & (t[:, ax] < d)
        t = np.clip(t, 0, np.asarray(self.dims) - 1)
        tr = np.ravel_multi_index(tuple(t.T), self.dims)
        return valid, tr

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return f"CartGrid(dims={self.dims}, periodic={self.periodic})"


def dims_create(p: int, ndims: int) -> Tuple[int, ...]:
    """``MPI_Dims_create``-style decomposition: dimension sizes as close to
    each other as possible, sorted in decreasing order (MPI 3.1 §7.5.2).

    Deterministic balanced prime-factor assignment: repeatedly fold the
    largest remaining prime factor into the currently smallest dimension.
    """
    if p <= 0 or ndims <= 0:
        raise ValueError("p and ndims must be positive")
    factors: list[int] = []
    x = p
    f = 2
    while f * f <= x:
        while x % f == 0:
            factors.append(f)
            x //= f
        f += 1
    if x > 1:
        factors.append(x)
    dims = [1] * ndims
    for f in sorted(factors, reverse=True):
        dims[int(np.argmin(dims))] *= f
    return tuple(sorted(dims, reverse=True))
